#!/usr/bin/env python3
"""Validate a Chrome trace_event file emitted by `repro ... --trace-out`.

Checks (stdlib only, like tools/bench_gate.py):

* the file is valid JSON with a ``traceEvents`` array of ``ph == "X"``
  complete events carrying ``ts``/``dur`` and the span/parent/trace ids
  in ``args``;
* **tree shape** — every non-root span's ``parent_id`` resolves to a
  recorded span in the same trace, and a parent's wall-clock window
  contains each child's. Containment is only enforced for the wall-clock
  categories (``mine``/``mr``/``serve``/``store``): the simulated-cluster
  spans (``rpc``/``net``) carry flow-model durations on a wall-clock
  start, so their windows are deliberately out of scale
  (DESIGN.md §Observability, the two-clock note);
* **mine mode** (``--mode mine``) — exactly one root ``mine`` span,
  ``level.k`` spans under it, and every ``map.task.*`` span carries the
  full Hadoop-style counter set with non-zero shuffle bytes overall;
  additionally, any ``profile.level.k`` workload-statistics span must
  carry all four autotuner stats and hang off a level span (or the mine
  root, in pipelined mode), and any chaos fault-injection span must be a
  ``fault.*``-named root. Both are instant markers (1 µs simulated
  duration), so like ``rpc``/``net`` they are exempt from wall-clock
  containment;
* **serve mode** (``--mode serve``) — at least one per-request root
  ``request`` span, each carrying its own trace id.

Exit status 0 on a clean trace; 1 with per-failure lines on stderr.
"""

import argparse
import json
import sys

WALL_CLOCK_CATS = {"mine", "mr", "serve", "store"}
MAP_COUNTERS = [
    "records_read",
    "map_output_records",
    "combine_output_records",
    "combiner_ratio",
    "shuffle_bytes",
]
PROFILE_STATS = [
    "density",
    "item_skew",
    "avg_basket_width",
    "candidate_fanout",
]


def load_events(path):
    with open(path) as f:
        doc = json.load(f)
    events = doc.get("traceEvents")
    if not isinstance(events, list) or not events:
        raise ValueError("traceEvents missing or empty")
    return events


def check_common(events):
    """Event well-formedness + tree shape. Returns (failures, by_id)."""
    failures = []
    by_id = {}
    for i, ev in enumerate(events):
        for field in ("name", "cat", "ph", "ts", "dur", "args"):
            if field not in ev:
                failures.append(f"event {i} ({ev.get('name')}): missing {field}")
        if ev.get("ph") != "X":
            failures.append(f"event {i} ({ev.get('name')}): ph {ev.get('ph')!r} != 'X'")
            continue
        args = ev.get("args", {})
        for field in ("trace_id", "span_id", "parent_id"):
            if field not in args:
                failures.append(f"event {i} ({ev.get('name')}): args missing {field}")
        sid = args.get("span_id")
        if sid in by_id:
            failures.append(f"duplicate span_id {sid}")
        by_id[sid] = ev
    if failures:
        return failures, by_id

    for ev in events:
        args = ev["args"]
        if args["parent_id"] == 0:
            continue
        parent = by_id.get(args["parent_id"])
        if parent is None:
            failures.append(
                f"{ev['name']}: parent span {args['parent_id']} never recorded")
            continue
        if parent["args"]["trace_id"] != args["trace_id"]:
            failures.append(
                f"{ev['name']}: trace id differs from parent {parent['name']}")
        # wall-clock containment only where both clocks are real
        if ev["cat"] in WALL_CLOCK_CATS and parent["cat"] in WALL_CLOCK_CATS:
            slack = 1.0  # µs rounding
            if ev["ts"] + slack < parent["ts"] or \
               ev["ts"] + ev["dur"] > parent["ts"] + parent["dur"] + slack:
                failures.append(
                    f"{ev['name']} [{ev['ts']}, {ev['ts'] + ev['dur']}] not inside "
                    f"{parent['name']} [{parent['ts']}, {parent['ts'] + parent['dur']}]")
    return failures, by_id


def check_mine(events):
    failures = []
    roots = [e for e in events
             if e["name"] == "mine" and e["args"]["parent_id"] == 0]
    if len(roots) != 1:
        failures.append(f"expected exactly one root mine span, found {len(roots)}")
        return failures
    root = roots[0]
    levels = [e for e in events if e["name"].startswith("level.")]
    if not levels:
        failures.append("no level.k spans recorded")
    for lv in levels:
        if lv["args"]["parent_id"] != root["args"]["span_id"]:
            failures.append(f"{lv['name']} is not a child of the mine root")
    maps = [e for e in events if e["name"].startswith("map.task.")]
    if not maps:
        failures.append("no map.task spans recorded")
    for m in maps:
        for counter in MAP_COUNTERS:
            if counter not in m["args"]:
                failures.append(f"{m['name']}: missing job counter {counter}")
    if maps and sum(m["args"].get("shuffle_bytes", 0) for m in maps) <= 0:
        failures.append("total map-side shuffle_bytes is zero")
    if not any(e["name"].startswith("reduce.task.") for e in events):
        failures.append("no reduce.task spans recorded")

    # workload-statistics spans: all four stats, parented to a level span
    # (sync mine) or the mine root (pipelined mine has no level spans)
    ok_parents = {lv["args"]["span_id"] for lv in levels}
    ok_parents.add(root["args"]["span_id"])
    for p in (e for e in events if e["name"].startswith("profile.level.")):
        if p["cat"] != "profile":
            failures.append(f"{p['name']}: cat {p['cat']!r} != 'profile'")
        if p["args"]["parent_id"] not in ok_parents:
            failures.append(
                f"{p['name']} not under a level span or the mine root")
        for stat in PROFILE_STATS:
            if stat not in p["args"]:
                failures.append(f"{p['name']}: missing workload stat {stat}")

    # chaos fault injections: named fault.*, recorded as roots so they
    # never distort the mine tree's wall-clock containment
    for c in (e for e in events if e["cat"] == "chaos"):
        if not c["name"].startswith("fault."):
            failures.append(f"chaos span {c['name']} is not named fault.*")
        if c["args"]["parent_id"] != 0:
            failures.append(f"{c['name']}: chaos fault spans must be roots")
    return failures


def check_serve(events):
    failures = []
    requests = [e for e in events
                if e["name"] == "request" and e["args"]["parent_id"] == 0]
    if not requests:
        failures.append("no root request spans recorded")
    trace_ids = [r["args"]["trace_id"] for r in requests]
    if len(set(trace_ids)) != len(trace_ids):
        failures.append("served requests share a trace id (must be per-request)")
    return failures


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("trace", help="Chrome trace_event JSON file")
    ap.add_argument("--mode", choices=["mine", "serve", "tree-only"],
                    default="tree-only",
                    help="extra shape checks for a known trace kind")
    args = ap.parse_args()

    try:
        events = load_events(args.trace)
    except (OSError, ValueError, json.JSONDecodeError) as e:
        print(f"FAIL {args.trace}: {e}", file=sys.stderr)
        sys.exit(1)

    failures, _ = check_common(events)
    if not failures:
        if args.mode == "mine":
            failures += check_mine(events)
        elif args.mode == "serve":
            failures += check_serve(events)

    if failures:
        for line in failures:
            print(f"FAIL {line}", file=sys.stderr)
        sys.exit(1)
    print(f"ok: {args.trace} — {len(events)} spans, tree and counters check out"
          f" (mode: {args.mode})")


if __name__ == "__main__":
    main()

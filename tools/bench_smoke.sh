#!/usr/bin/env bash
# Run one bench binary and validate the JSON artifact it emits — the
# shared step behind the CI bench smokes, so the emit/validate/upload
# boilerplate lives in one place instead of being copy-pasted per bench.
#
# Usage: tools/bench_smoke.sh <bench-name> <artifact.json> [bench args...]
# The artifact lands in the current directory (BENCH_OUT_DIR=$PWD).
set -euo pipefail

bench="$1"
artifact="$2"
shift 2

BENCH_OUT_DIR="$PWD" cargo bench --bench "$bench" -- "$@"
test -s "$artifact"
python3 -m json.tool "$artifact" > /dev/null
echo "ok: $bench emitted valid $artifact"

#!/usr/bin/env python3
"""Perf-trajectory gate: compare a push's bench JSON artifacts against the
checked-in baseline (BENCH_baseline.json).

Two kinds of tracked fields, both addressed by dot-paths into the bench
JSON (a trailing `#` segment resolves to the length of an array):

* ``wall_clock`` — *higher-is-better ratios* (speedups), deliberately not
  raw milliseconds so the gate is robust to absolute runner speed. A
  value may regress by at most the baseline ``tolerance`` factor: the
  gate fails when ``current < baseline / tolerance``. With the default
  tolerance of 1.25 this means ">25% wall-clock regression fails".
* ``correctness`` — exact-match fields (modes, cycle counts, oracle
  flags). Any drift fails, no tolerance.

``--update`` rewrites the baseline's ``wall_clock`` values from the
current artifacts (the refresh procedure documented in EXPERIMENTS.md);
correctness fields are never rewritten automatically — edit them by hand
when a drift is intentional, so the diff shows up in review.

Zero third-party dependencies: stdlib only, by design (the repo's rust
side is zero-dependency too).
"""

import argparse
import json
import os
import sys


def resolve(doc, path):
    """Walk a dot-path through nested dicts/lists; `#` = array length."""
    cur = doc
    for part in path.split("."):
        if part == "#":
            if not isinstance(cur, list):
                raise KeyError(f"{path}: `#` on a non-array")
            return len(cur)
        if isinstance(cur, list):
            cur = cur[int(part)]
        elif isinstance(cur, dict):
            cur = cur[part]
        else:
            raise KeyError(f"{path}: hit a leaf before the path ended")
    return cur


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--baseline", default="BENCH_baseline.json",
                    help="checked-in baseline file (default: %(default)s)")
    ap.add_argument("--dir", default=".",
                    help="directory holding the bench JSON artifacts")
    ap.add_argument("--update", action="store_true",
                    help="rewrite the baseline's wall_clock values from "
                         "the current artifacts instead of gating on them")
    args = ap.parse_args()

    with open(args.baseline) as f:
        base = json.load(f)
    tolerance = float(base.get("tolerance", 1.25))
    failures = []
    checked = 0

    for fname, spec in sorted(base.get("benches", {}).items()):
        path = os.path.join(args.dir, fname)
        if not os.path.exists(path):
            failures.append(f"{fname}: artifact missing from {args.dir}")
            continue
        with open(path) as f:
            current = json.load(f)

        for key, want in sorted(spec.get("wall_clock", {}).items()):
            try:
                got = float(resolve(current, key))
            except (KeyError, IndexError, TypeError, ValueError) as e:
                failures.append(f"{fname}: wall_clock {key}: unresolvable ({e})")
                continue
            if args.update:
                spec["wall_clock"][key] = got
                print(f"update {fname}: {key} = {got:.4f} (was {want})")
                continue
            checked += 1
            floor = float(want) / tolerance
            if got < floor:
                failures.append(
                    f"{fname}: {key} = {got:.4f} < floor {floor:.4f} "
                    f"(baseline {want}, tolerance {tolerance}x)")
            else:
                print(f"ok {fname}: {key} = {got:.4f} "
                      f">= floor {floor:.4f} (baseline {want})")

        for key, want in sorted(spec.get("correctness", {}).items()):
            try:
                got = resolve(current, key)
            except (KeyError, IndexError, TypeError, ValueError) as e:
                failures.append(f"{fname}: correctness {key}: unresolvable ({e})")
                continue
            checked += 1
            if got != want:
                failures.append(
                    f"{fname}: correctness {key} = {got!r} drifted "
                    f"from baseline {want!r}")
            else:
                print(f"ok {fname}: {key} = {got!r}")

    if args.update:
        with open(args.baseline, "w") as f:
            json.dump(base, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"baseline rewritten: {args.baseline}")

    if failures:
        for line in failures:
            print(f"FAIL {line}", file=sys.stderr)
        sys.exit(1)
    print(f"bench gate passed ({checked} fields within tolerance {tolerance}x)")


if __name__ == "__main__":
    main()

//! End-to-end driver (the EXPERIMENTS.md §E2E run): the full three-layer
//! stack on a realistic market-basket workload.
//!
//! Pipeline:
//!   1. generate a 10k-transaction T10.I4 dataset (Quest),
//!   2. write it through the DFS (block placement + replication 3),
//!   3. mine level-wise with Map/Reduce jobs on a 3-node FHSSC cluster,
//!      counting supports through the **Pallas/PJRT tensor engine** when
//!      artifacts are built (hash-tree fallback otherwise),
//!   4. differential-check the tensor path against the pure-rust engine,
//!   5. report the headline metrics the paper's §4 discusses.
//!
//! ```sh
//! make artifacts && cargo run --release --example market_basket
//! ```

use mr_apriori::prelude::*;
use mr_apriori::{coordinator, runtime::TensorService};

fn main() {
    let n_tx = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(10_000);

    // --- 1. workload -------------------------------------------------
    let db = QuestGenerator::new(QuestParams::t10_i4(n_tx)).generate();
    println!(
        "workload: {} transactions, {} distinct items, {:.1} avg basket",
        db.len(),
        db.n_items,
        db.total_items() as f64 / db.len() as f64
    );

    // --- 2/3. cluster + engines --------------------------------------
    let cluster = ClusterConfig::fhssc(3);
    let apriori = AprioriConfig { min_support: 0.02, max_k: 3 };

    // Tensor engine if artifacts exist (L1 item width must fit the widest
    // artifact: project the db to its frequent items first — the classic
    // dictionary shrink — which the driver handles via n_items).
    let tensor_service = TensorService::start_default().ok();
    let use_tensor = tensor_service.is_some() && db.n_items <= 256;

    // Run with the pure-rust engine first (the reference).
    let t_ref = std::time::Instant::now();
    let base = MrApriori::new(cluster.clone(), apriori.clone())
        .with_split_tx(1_000)
        .mine(&db)
        .expect("hash-tree run");
    let ref_secs = t_ref.elapsed().as_secs_f64();

    // --- 4. differential check of the tensor hot path ----------------
    // The T10.I4 dictionary is 1000 items — wider than the widest AOT
    // tile (256). Real deployments re-encode to frequent items after L1;
    // do that projection and count level-2 candidates on both engines.
    if let Some(svc) = &tensor_service {
        let frequent_items: Vec<u32> = base
            .result
            .level(1)
            .map(|(is, _)| is[0])
            .collect();
        if frequent_items.len() <= 256 {
            let (projected, _map) = db.project(&frequent_items);
            let sub_apriori = AprioriConfig { min_support: 0.02, max_k: 2 };
            let t_tensor = std::time::Instant::now();
            let tensor_run = MrApriori::new(cluster.clone(), sub_apriori.clone())
                .with_engine(build_engine(EngineKind::Tensor, Some(svc.handle())))
                .with_split_tx(1_000)
                .mine(&projected)
                .expect("tensor run");
            let tensor_secs = t_tensor.elapsed().as_secs_f64();
            let cpu_run = MrApriori::new(cluster.clone(), sub_apriori)
                .with_split_tx(1_000)
                .mine(&projected)
                .expect("cpu run");
            assert_eq!(
                tensor_run.result.frequent, cpu_run.result.frequent,
                "tensor engine must match the cpu engine exactly"
            );
            println!(
                "tensor-vs-cpu differential check: OK ({} itemsets, k<=2, tensor {:.2}s)",
                tensor_run.result.frequent.len(),
                tensor_secs
            );
        }
    } else if use_tensor {
        println!("artifacts not built; skipping tensor differential check");
    }

    // --- 5. headline metrics -----------------------------------------
    println!("\nlevel | candidates | frequent | wall(s)");
    for l in &base.result.levels {
        println!(
            "{:>5} | {:>10} | {:>8} | {:.3}",
            l.k, l.n_candidates, l.n_frequent, l.wall_secs
        );
    }
    let total_shuffle: usize = base.jobs.iter().map(|(_, s)| s.shuffle_records).sum();
    println!(
        "\nheadline: {} frequent itemsets from {} transactions in {:.2}s wall",
        base.result.frequent.len(),
        db.len(),
        ref_secs
    );
    println!(
        "  {} MR jobs, {} map tasks, locality {:.0}%, {} shuffle records, spill {:.0}%",
        base.jobs.len(),
        base.jobs.iter().map(|(_, s)| s.maps_total).sum::<usize>(),
        base.jobs
            .iter()
            .map(|(_, s)| s.locality_fraction())
            .sum::<f64>()
            / base.jobs.len().max(1) as f64
            * 100.0,
        total_shuffle,
        base.spill_fraction * 100.0
    );

    // Paper-style lateral comparison on this exact workload (simulated
    // hardware, fig-5 methodology):
    let job = JobConfig::default();
    println!("\nsimulated runtimes of this workload (paper §4 comparison):");
    for (name, cluster) in [
        ("standalone", ClusterConfig::standalone()),
        ("pseudo-distributed", ClusterConfig::pseudo_distributed()),
        ("3-node FHSSC", ClusterConfig::fhssc(3)),
        ("3-node FHDSC", ClusterConfig::fhdsc(3)),
    ] {
        let sim = coordinator::simulate(&cluster, &base.profile, 1_000, &job);
        println!("  {name:<20} {:>8.1}s", sim.total_secs);
    }

    let rules = generate_rules(&base.result, 0.5);
    println!("\n{} association rules (conf >= 0.5); top 5:", rules.len());
    for r in rules.iter().take(5) {
        println!("  {}", format_rule(r));
    }
}

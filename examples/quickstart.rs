//! Quickstart: mine frequent itemsets from a synthetic market-basket
//! dataset on a simulated 3-node Hadoop-like cluster.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use mr_apriori::prelude::*;

fn main() {
    // 1. A small Quest-style dataset (the standard Apriori benchmark
    //    family; the paper never names its own dataset).
    let db = QuestGenerator::new(QuestParams::dense(1_000)).generate();
    println!(
        "dataset: {} transactions, {} items, {} item occurrences",
        db.len(),
        db.n_items,
        db.total_items()
    );

    // 2. The paper's testbed: three identical Core2-Duo-class nodes.
    let cluster = ClusterConfig::fhssc(3);

    // 3. Mine with the Map/Reduce driver (level-wise jobs over the
    //    simulated HDFS + jobtracker substrate).
    let cfg = AprioriConfig { min_support: 0.15, max_k: 0 };
    let report = MrApriori::new(cluster, cfg.clone())
        .with_split_tx(100)
        .mine(&db)
        .expect("mining failed");

    println!("\nlevel | candidates | frequent");
    for l in &report.result.levels {
        println!("{:>5} | {:>10} | {:>8}", l.k, l.n_candidates, l.n_frequent);
    }
    println!(
        "\n{} frequent itemsets in {:.2}s ({} MapReduce jobs)",
        report.result.frequent.len(),
        report.wall_secs,
        report.jobs.len()
    );

    // 4. Cross-check against the single-machine classical baseline.
    let classical = ClassicalApriori::default().mine(&db, &cfg);
    assert_eq!(
        report.result.frequent, classical.frequent,
        "Map/Reduce result must equal the classical baseline"
    );
    println!("verified: Map/Reduce output == classical Apriori output");

    // 5. Turn the itemsets into association rules (the KDD payoff).
    let rules = generate_rules(&report.result, 0.6);
    println!("\ntop rules (confidence >= 0.6):");
    for r in rules.iter().take(10) {
        println!("  {}", format_rule(r));
    }
}

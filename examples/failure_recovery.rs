//! Failure recovery demo: the Hadoop behaviours the framework contributes —
//! task-attempt retry under injected failures, job abort when a task
//! exhausts attempts, and namenode re-replication after datanode loss.
//!
//! ```sh
//! cargo run --release --example failure_recovery
//! ```

use mr_apriori::mapreduce::runner::FailureSpec;
use mr_apriori::prelude::*;
use mr_apriori::data::split::plan_splits;

fn main() {
    let db = QuestGenerator::new(QuestParams::dense(2_000)).generate();
    let cluster = ClusterConfig::fhssc(4);
    let apriori = AprioriConfig { min_support: 0.05, max_k: 2 };

    // --- 1. baseline: no failures -------------------------------------
    let clean = MrApriori::new(cluster.clone(), apriori.clone())
        .with_split_tx(200)
        .mine(&db)
        .expect("clean run");
    println!(
        "clean run: {} itemsets, {} map attempts across {} jobs",
        clean.result.frequent.len(),
        clean.jobs.iter().map(|(_, s)| s.map_attempts).sum::<usize>(),
        clean.jobs.len()
    );

    // --- 2. 25% of map attempts fail: retries must recover ------------
    let flaky = JobConfig {
        failure: Some(FailureSpec {
            map_fail_prob: 0.25,
            reduce_fail_prob: 0.1,
            seed: 2012,
        }),
        ..Default::default()
    };
    let recovered = MrApriori::new(cluster.clone(), apriori.clone())
        .with_job(flaky)
        .with_split_tx(200)
        .mine(&db)
        .expect("flaky run should still succeed");
    let (attempts, failures): (usize, usize) = recovered
        .jobs
        .iter()
        .fold((0, 0), |(a, f), (_, s)| {
            (a + s.map_attempts, f + s.map_failures)
        });
    println!(
        "with 25% injected failures: {} itemsets (identical: {}), {} attempts, {} failures absorbed",
        recovered.result.frequent.len(),
        recovered.result.frequent == clean.result.frequent,
        attempts,
        failures
    );
    assert_eq!(recovered.result.frequent, clean.result.frequent);
    assert!(failures > 0);

    // --- 3. certain failure: the job must abort, not hang -------------
    let doomed = JobConfig {
        failure: Some(FailureSpec {
            map_fail_prob: 1.0,
            reduce_fail_prob: 0.0,
            seed: 1,
        }),
        max_attempts: 3,
        ..Default::default()
    };
    let err = MrApriori::new(cluster.clone(), apriori.clone())
        .with_job(doomed)
        .with_split_tx(200)
        .mine(&db)
        .expect_err("100% failure rate must abort");
    println!("doomed run aborted as expected: {err}");

    // --- 4. datanode loss: namenode re-replicates ---------------------
    let mut dfs = Dfs::new(&cluster);
    let splits = plan_splits(&db, 200);
    let blocks = dfs.write_splits(&splits).expect("placement");
    let before: Vec<usize> = blocks
        .iter()
        .map(|&b| dfs.locations(b).unwrap().len())
        .collect();
    let moved = dfs.decommission(2).expect("decommission node 2");
    let after: Vec<usize> = blocks
        .iter()
        .map(|&b| dfs.locations(b).unwrap().len())
        .collect();
    println!(
        "decommissioned node 2: {} replicas re-replicated; replication {}→{} (min)",
        moved,
        before.iter().min().unwrap(),
        after.iter().min().unwrap()
    );
    assert_eq!(before.iter().min(), after.iter().min());
    assert!(blocks.iter().all(|&b| !dfs.locations(b).unwrap().contains(&2)));
    println!("all block replicas off the dead node; job would rerun locally elsewhere");
}

//! Cluster-scaling study (fig-4 methodology as a runnable example):
//! profile one workload, then replay it on FHSSC/FHDSC clusters of
//! growing size and print the paper-style table + η ratios.
//!
//! ```sh
//! cargo run --release --example cluster_scaling
//! ```

use mr_apriori::prelude::*;
use mr_apriori::coordinator;

fn main() {
    // Profile the workload once on the reference cluster.
    let db = QuestGenerator::new(QuestParams::t10_i4(5_000)).generate();
    let apriori = AprioriConfig { min_support: 0.02, max_k: 3 };
    let report = MrApriori::new(ClusterConfig::fhssc(3), apriori)
        .with_split_tx(250)
        .mine(&db)
        .expect("profiling run");
    println!(
        "profiled workload: {} tx, {} levels, {} frequent itemsets\n",
        db.len(),
        report.profile.levels.len(),
        report.result.frequent.len()
    );

    let job = JobConfig::default();
    let ns: Vec<usize> = vec![2, 3, 4, 6, 8, 12, 16];
    let mut fhssc = Vec::new();
    let mut fhdsc = Vec::new();
    let model = EtaModel::default();

    println!("nodes | FHSSC(s) | FHDSC(s) |  η meas | η model");
    for &n in &ns {
        let hom = coordinator::simulate(&ClusterConfig::fhssc(n), &report.profile, 250, &job);
        let het = coordinator::simulate(&ClusterConfig::fhdsc(n), &report.profile, 250, &job);
        let eta = het.total_secs / hom.total_secs;
        println!(
            "{:>5} | {:>8.1} | {:>8.1} | {:>7.2} | {:>7.2}",
            n,
            hom.total_secs,
            het.total_secs,
            eta,
            model.eta_predicted(n)
        );
        fhssc.push(hom.total_secs);
        fhdsc.push(het.total_secs);
    }

    // Chart for shape inspection (who wins, how the gap grows).
    let mut table = BenchTable::new(
        "Fig 4 — FHDSC vs FHSSC processing time",
        "nodes",
        ns.iter().map(|&n| n as f64).collect(),
    );
    table.push_series(Series::new("FHSSC", fhssc));
    table.push_series(Series::new("FHDSC", fhdsc));
    println!("\n{}", table.to_ascii_chart());
}

//! Integration tests across the three-layer boundary: the AOT-compiled
//! Pallas artifact executed through PJRT must agree exactly with the
//! pure-rust engines for arbitrary shapes, including chunking boundaries
//! (transactions crossing the t-tile, candidates crossing the c-tile).
//!
//! These tests skip with a note when `make artifacts` hasn't run — the
//! Makefile's `test` target builds artifacts first, so CI runs them.

use mr_apriori::data::bitmap::{count_on_host, BitmapBlock, CandidateBlock};
use mr_apriori::data::Transaction;
use mr_apriori::prelude::*;
use mr_apriori::runtime::{ArtifactManifest, CountRequest, TensorService};
use mr_apriori::util::proptest::check;
use mr_apriori::util::rng::Xoshiro256;

fn service() -> Option<TensorService> {
    let dir = ArtifactManifest::default_dir();
    if !dir.join("manifest.json").exists() {
        mr_apriori::log!(Warn, "skipping runtime roundtrip: run `make artifacts`");
        return None;
    }
    Some(TensorService::start(ArtifactManifest::load(&dir).unwrap()))
}

fn random_case(
    rng: &mut Xoshiro256,
    n_items: usize,
) -> (Vec<Transaction>, Vec<Vec<u32>>) {
    let n_tx = rng.range_usize(0, 400);
    let txs: Vec<Transaction> = (0..n_tx)
        .map(|_| {
            let len = rng.range_usize(0, 10);
            Transaction::new((0..len).map(|_| rng.gen_range(n_items as u64) as u32))
        })
        .collect();
    let n_cands = rng.range_usize(1, 150);
    let cands: Vec<Vec<u32>> = (0..n_cands)
        .map(|_| {
            let k = rng.range_usize(1, 4.min(n_items));
            let mut v: Vec<u32> = rng
                .sample_distinct(n_items, k)
                .into_iter()
                .map(|x| x as u32)
                .collect();
            v.sort_unstable();
            v
        })
        .collect();
    (txs, cands)
}

#[test]
fn prop_tensor_service_matches_host_reference_at_chunk_boundaries() {
    let Some(svc) = service() else { return };
    let h = svc.handle();
    check(
        "tensor-vs-host",
        0x7E45,
        15,
        |rng| vec![rng.next_u64()],
        |params| {
            let mut rng = Xoshiro256::seed_from_u64(params[0]);
            let (txs, cands) = random_case(&mut rng, 64);
            let block = BitmapBlock::encode(&txs, 64, 256).unwrap();
            let cblock = CandidateBlock::encode(&cands, 64, 64).unwrap();
            let host = count_on_host(&block, &cblock);
            let got = h
                .count(CountRequest {
                    graph: "count_split".into(),
                    block,
                    cands: cblock,
                })
                .map_err(|e| e.to_string())?;
            if got[..] == host[..got.len()] {
                Ok(())
            } else {
                Err("tensor counts diverge from host reference".into())
            }
        },
    );
}

#[test]
fn exact_tile_boundary_shapes() {
    let Some(svc) = service() else { return };
    let h = svc.handle();
    // t exactly 256 (one tile), 257-ish (two tiles), candidates exactly 64
    // (one small-variant call) and 65 (two calls).
    for (n_tx, n_cands) in [(256usize, 64usize), (255, 65), (257, 63), (512, 128), (1, 1)] {
        let mut rng = Xoshiro256::seed_from_u64((n_tx * 1000 + n_cands) as u64);
        let txs: Vec<Transaction> = (0..n_tx)
            .map(|_| {
                let len = rng.range_usize(1, 8);
                Transaction::new((0..len).map(|_| rng.gen_range(64) as u32))
            })
            .collect();
        let cands: Vec<Vec<u32>> = (0..n_cands)
            .map(|_| {
                let k = rng.range_usize(1, 3);
                let mut v: Vec<u32> =
                    rng.sample_distinct(64, k).into_iter().map(|x| x as u32).collect();
                v.sort_unstable();
                v
            })
            .collect();
        let block = BitmapBlock::encode(&txs, 64, 256).unwrap();
        let cblock = CandidateBlock::encode(&cands, 64, 64).unwrap();
        let host = count_on_host(&block, &cblock);
        let got = h
            .count(CountRequest {
                graph: "count_split".into(),
                block,
                cands: cblock,
            })
            .unwrap();
        assert_eq!(got.len(), n_cands, "case ({n_tx},{n_cands})");
        assert_eq!(&got[..], &host[..n_cands], "case ({n_tx},{n_cands})");
    }
}

#[test]
fn tensor_engine_full_mining_run_matches_cpu() {
    let Some(svc) = service() else { return };
    let db = QuestGenerator::new(QuestParams {
        n_items: 60,
        ..QuestParams::dense(400)
    })
    .generate();
    let cfg = AprioriConfig { min_support: 0.1, max_k: 3 };
    let cpu = MrApriori::new(ClusterConfig::fhssc(2), cfg.clone())
        .with_split_tx(100)
        .mine(&db)
        .unwrap();
    let tensor = MrApriori::new(ClusterConfig::fhssc(2), cfg)
        .with_engine(build_engine(EngineKind::Tensor, Some(svc.handle())))
        .with_split_tx(100)
        .mine(&db)
        .unwrap();
    assert_eq!(tensor.result.frequent, cpu.result.frequent);
    assert!(!tensor.result.frequent.is_empty());
}

#[test]
fn pallas_and_ref_graphs_agree_through_pjrt() {
    let Some(svc) = service() else { return };
    let h = svc.handle();
    let mut rng = Xoshiro256::seed_from_u64(99);
    let (txs, cands) = random_case(&mut rng, 64);
    let mk = |graph: &str| CountRequest {
        graph: graph.into(),
        block: BitmapBlock::encode(&txs, 64, 256).unwrap(),
        cands: CandidateBlock::encode(&cands, 64, 64).unwrap(),
    };
    let a = h.count(mk("count_split")).unwrap();
    let b = h.count(mk("count_split_ref")).unwrap();
    assert_eq!(a, b, "pallas artifact must equal jnp-ref artifact");
}

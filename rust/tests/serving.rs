//! End-to-end invariants of the serving subsystem: served answers are
//! byte-identical to the direct `generate_rules` path across arbitrary
//! baskets, snapshot hot-swaps are atomic under concurrent load (every
//! answer attributes to exactly one published generation), and the
//! micro-batch refresh loop converges to the same state as a from-scratch
//! batch mine of the union database.

use std::sync::Arc;

use mr_apriori::prelude::*;
use mr_apriori::util::proptest::check;
use mr_apriori::util::rng::Xoshiro256;

fn small_db() -> TransactionDb {
    QuestGenerator::new(QuestParams::goswami_2k()).generate()
}

fn mine_cfg() -> AprioriConfig {
    AprioriConfig { min_support: 0.05, max_k: 3 }
}

fn mine(db: &TransactionDb) -> MiningResult {
    ClassicalApriori::default().mine(db, &mine_cfg())
}

#[test]
fn prop_served_answers_equal_direct_generate_rules() {
    let result = mine(&small_db());
    let rules = generate_rules(&result, 0.4);
    let cell = Arc::new(SnapshotCell::new(Arc::new(RuleIndex::build(&result, 0.4))));
    let server = RuleServer::start(
        Arc::clone(&cell),
        ServeOptions { workers: 2, queue_depth: 32, ..Default::default() },
    );
    check(
        "serve == direct over random baskets",
        0xD1FF,
        150,
        |rng| {
            let len = rng.range_usize(0, 7);
            (0..len).map(|_| rng.gen_range(120) as u32).collect::<Vec<_>>()
        },
        |basket| {
            let resp = server.query(basket, 5).map_err(|e| e.to_string())?;
            let direct = render_lines(&reference_recommend(&rules, basket, 5));
            if resp.render() == direct {
                Ok(())
            } else {
                Err(format!("served != direct for {basket:?}"))
            }
        },
    );
    let stats = server.shutdown();
    assert_eq!(stats.rejected, 0);
    assert!(stats.served >= 150);
}

#[test]
fn refresh_converges_to_batch_mine_of_union_db() {
    let mut db = small_db();
    let result0 = mine(&db);
    let cell = Arc::new(SnapshotCell::new(Arc::new(RuleIndex::build(&result0, 0.4))));
    let pre_swap = cell.load();

    let driver = MrApriori::new(ClusterConfig::fhssc(2), mine_cfg()).with_split_tx(200);
    let refresher = Refresher::new(driver, 0.4);
    let delta = synth_delta(150, db.n_items, 99);
    let (report, stats) = refresher.refresh_once(&mut db, delta, &cell).unwrap();
    assert_eq!(stats.generation, 1);
    assert_eq!(stats.total_tx, 2150);

    // the published snapshot answers exactly like a from-scratch batch
    // mine of the union database
    let union_result = mine(&db);
    assert_eq!(report.result.frequent, union_result.frequent);
    let union_rules = generate_rules(&union_result, 0.4);
    let idx = cell.load();
    let mut rng = Xoshiro256::seed_from_u64(5);
    for _ in 0..80 {
        let len = rng.range_usize(1, 5);
        let basket: Vec<u32> = (0..len).map(|_| rng.gen_range(120) as u32).collect();
        assert_eq!(
            render_lines(&idx.recommend(&basket, 5)),
            render_lines(&reference_recommend(&union_rules, &basket, 5)),
            "basket {basket:?}"
        );
    }
    // a reader that loaded before the swap still holds the old generation
    assert_eq!(pre_swap.n_transactions, 2000);
    assert_eq!(idx.n_transactions, 2150);
}

#[test]
fn concurrent_load_across_swaps_sees_only_published_generations() {
    // Three generations of the database; every served answer must be
    // byte-identical to the direct rules of the generation it reports —
    // a torn snapshot or a half-applied refresh would break the match.
    let db0 = small_db();
    let mut db = db0.clone();
    let result0 = mine(&db);
    let cell = Arc::new(SnapshotCell::new(Arc::new(RuleIndex::build(&result0, 0.4))));
    let server = Arc::new(RuleServer::start(
        Arc::clone(&cell),
        ServeOptions { workers: 3, queue_depth: 64, ..Default::default() },
    ));

    // precompute every generation's direct answers
    let mut direct_by_generation = vec![generate_rules(&result0, 0.4)];
    let driver = MrApriori::new(ClusterConfig::fhssc(2), mine_cfg()).with_split_tx(200);
    let refresher = Refresher::new(driver, 0.4);
    let deltas: Vec<_> = (0..2).map(|i| synth_delta(100, db.n_items, i as u64)).collect();
    {
        // dry-run the refreshes against a scratch cell to learn the
        // expected rules per generation without publishing anything
        let mut scratch_db = db0.clone();
        let scratch_cell = SnapshotCell::new(Arc::new(RuleIndex::build(&result0, 0.4)));
        for delta in &deltas {
            let (report, _) = refresher
                .refresh_once(&mut scratch_db, delta.clone(), &scratch_cell)
                .unwrap();
            direct_by_generation.push(generate_rules(&report.result, 0.4));
        }
    }

    let done = std::sync::atomic::AtomicBool::new(false);
    std::thread::scope(|scope| {
        let clients: Vec<_> = (0..3)
            .map(|c| {
                let (server, direct_by_generation, done) = (&server, &direct_by_generation, &done);
                scope.spawn(move || {
                    let mut rng = Xoshiro256::seed_from_u64(c);
                    let mut answered = 0u64;
                    loop {
                        let len = rng.range_usize(1, 5);
                        let basket: Vec<u32> =
                            (0..len).map(|_| rng.gen_range(120) as u32).collect();
                        let resp = server.query(&basket, 5).expect("answer");
                        answered += 1;
                        let direct = &direct_by_generation[resp.generation as usize];
                        assert_eq!(
                            resp.render(),
                            render_lines(&reference_recommend(direct, &basket, 5)),
                            "generation {} served != direct for {basket:?}",
                            resp.generation
                        );
                        if done.load(std::sync::atomic::Ordering::Acquire) {
                            break answered;
                        }
                    }
                })
            })
            .collect();
        for delta in deltas {
            let (_, stats) = refresher.refresh_once(&mut db, delta, &cell).unwrap();
            assert!(stats.generation >= 1);
        }
        done.store(true, std::sync::atomic::Ordering::Release);
        let total: u64 = clients.into_iter().map(|h| h.join().unwrap()).sum();
        assert!(total > 0);
    });
    assert_eq!(cell.generation(), 2);
    let stats = server.stats();
    assert_eq!(stats.rejected, 0);
    assert_eq!(stats.latency.count(), stats.served);
}

#[test]
fn admission_control_sheds_and_counts_without_blocking() {
    // Deterministic at the queue layer: fill to capacity with no
    // consumer, verify the (capacity + 1)-th push is rejected unchanged.
    use mr_apriori::serve::server::{BoundedQueue, PushError};
    let q = BoundedQueue::new(4);
    for i in 0..4 {
        assert!(q.try_push(i).is_ok());
    }
    match q.try_push(99) {
        Err(PushError::Full(v)) => assert_eq!(v, 99),
        other => panic!("expected Full rejection, got {other:?}"),
    }
    assert_eq!(q.len(), 4);
    // draining re-opens admission
    assert_eq!(q.pop(), Some(0));
    assert!(q.try_push(99).is_ok());
    q.close();
    assert!(matches!(q.try_push(5), Err(PushError::Closed(5))));
    assert_eq!(q.pop(), Some(1));
    assert_eq!(q.pop(), Some(2));
    assert_eq!(q.pop(), Some(3));
    assert_eq!(q.pop(), Some(99));
    assert_eq!(q.pop(), None);
}

#[test]
fn serving_layer_matches_mr_driver_output_not_just_classical() {
    // The serve path is built from the MR driver's result in production
    // (`repro serve`); pin that the index built from it equals the one
    // built from the classical baseline.
    let db = small_db();
    let classical = mine(&db);
    let report = MrApriori::new(ClusterConfig::fhssc(3), mine_cfg())
        .with_split_tx(250)
        .mine(&db)
        .unwrap();
    assert_eq!(report.result.frequent, classical.frequent);
    let from_mr = RuleIndex::build(&report.result, 0.4);
    let from_classical = RuleIndex::build(&classical, 0.4);
    assert_eq!(from_mr.n_rules(), from_classical.n_rules());
    let basket = vec![1u32, 2, 3];
    assert_eq!(
        render_lines(&from_mr.recommend(&basket, 10)),
        render_lines(&from_classical.recommend(&basket, 10))
    );
}

//! Differential coverage for the chunked TID containers and the resident
//! index cache: container transcoding at the array/bitmap/run thresholds
//! (including the 65535/65536/65537 chunk boundaries), every forced
//! kernel pairing against the sorted-merge oracle property-style, and
//! the cache's generation discipline end-to-end through `ExactCounter`,
//! the level loop, and the delta job.

use mr_apriori::coordinator::ExactCounter;
use mr_apriori::data::{intersect_sorted_count, Transaction};
use mr_apriori::engine::container::{ARRAY_MAX, CHUNK_SPAN};
use mr_apriori::engine::{Container, TidSet};
use mr_apriori::incremental::run_delta_count;
use mr_apriori::prelude::*;
use mr_apriori::util::proptest::check;

fn tx(items: &[u32]) -> Transaction {
    Transaction::new(items.iter().copied())
}

fn as_u32(tids: &[u16]) -> Vec<u32> {
    tids.iter().map(|&t| t as u32).collect()
}

fn merge_oracle(a: &[u16], b: &[u16]) -> Vec<u16> {
    a.iter().copied().filter(|t| b.binary_search(t).is_ok()).collect()
}

fn forced_variants(tids: &[u16], span: usize) -> [Container; 3] {
    [
        Container::array(tids.to_vec()),
        Container::bitmap_from_sorted(tids, span),
        Container::runs_from_sorted(tids),
    ]
}

#[test]
fn every_forced_pairing_matches_the_merge_oracle_property_style() {
    check(
        "container-kernels-vs-merge-oracle",
        0xC0_17A1,
        16,
        |rng| {
            let card_a = rng.range_usize(0, 6_000);
            let card_b = rng.range_usize(0, 6_000);
            let gen_set = |rng: &mut mr_apriori::util::rng::Xoshiro256, card: usize| {
                let mut v: Vec<u16> = rng
                    .sample_distinct(CHUNK_SPAN, card)
                    .into_iter()
                    .map(|t| t as u16)
                    .collect();
                v.sort_unstable();
                v
            };
            (gen_set(rng, card_a), gen_set(rng, card_b))
        },
        |(a, b)| {
            for s in [a, b] {
                let c = Container::from_sorted(s, CHUNK_SPAN);
                if c.decode() != *s {
                    return Err("from_sorted/decode roundtrip broke".into());
                }
                if c.cardinality() != s.len() {
                    return Err("cardinality diverged from input length".into());
                }
            }
            let want = merge_oracle(a, b);
            let want_count = intersect_sorted_count(&as_u32(a), &as_u32(b));
            if want.len() as u64 != want_count {
                return Err("test oracles disagree".into());
            }
            for ca in &forced_variants(a, CHUNK_SPAN) {
                for cb in &forced_variants(b, CHUNK_SPAN) {
                    if ca.intersect_count(cb) != want_count {
                        return Err(format!("count kernel broke on {ca:?} x {cb:?}"));
                    }
                    if ca.intersect(cb, CHUNK_SPAN).decode() != want {
                        return Err(format!("materializing kernel broke on {ca:?} x {cb:?}"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn transcoding_thresholds_straddle_the_array_bitmap_cutover() {
    // Stride-2 kills run compression, so the array/bitmap cost cross is
    // exactly at ARRAY_MAX elements.
    let stride2 = |card: usize| -> Vec<u16> { (0..card).map(|i| (2 * i) as u16).collect() };
    for card in [ARRAY_MAX - 1, ARRAY_MAX] {
        let c = Container::from_sorted(&stride2(card), CHUNK_SPAN);
        assert!(matches!(c, Container::Array(_)), "card {card} must stay an array");
        assert_eq!(c.cardinality(), card);
    }
    let c = Container::from_sorted(&stride2(ARRAY_MAX + 1), CHUNK_SPAN);
    assert!(matches!(c, Container::Bitmap { .. }), "card {} must densify", ARRAY_MAX + 1);
    assert_eq!(c.cardinality(), ARRAY_MAX + 1);

    // Consecutive TIDs compress to runs; empty and full chunks are the
    // two extremes of the same cost model.
    let run: Vec<u16> = (100..200).collect();
    assert!(matches!(Container::from_sorted(&run, CHUNK_SPAN), Container::Runs(_)));
    let empty = Container::from_sorted(&[], CHUNK_SPAN);
    assert_eq!((empty.cardinality(), empty.bytes()), (0, 0));
    let full: Vec<u16> = (0..CHUNK_SPAN).map(|t| t as u16).collect();
    let c = Container::from_sorted(&full, CHUNK_SPAN);
    assert!(matches!(c, Container::Runs(_)), "a full chunk must be one run");
    assert_eq!((c.cardinality(), c.bytes()), (CHUNK_SPAN, 4));
}

#[test]
fn intersections_transcode_across_the_thresholds() {
    // bitmap x bitmap with a sparse result sparsifies back to an array.
    let mul = |k: usize| -> Vec<u16> { (0..CHUNK_SPAN).step_by(k).map(|t| t as u16).collect() };
    let (a, b) = (
        Container::from_sorted(&mul(7), CHUNK_SPAN),
        Container::from_sorted(&mul(9), CHUNK_SPAN),
    );
    assert!(matches!(a, Container::Bitmap { .. }) && matches!(b, Container::Bitmap { .. }));
    let meet = a.intersect(&b, CHUNK_SPAN);
    assert!(matches!(meet, Container::Array(_)), "sparse meet must sparsify, got {meet:?}");
    assert_eq!(meet.decode(), mul(63));

    // run x run overlap stays a run; a fragmented run meet falls back to
    // the cost model and lands on an array.
    let range = |lo: u16, hi: u16| -> Vec<u16> { (lo..hi).collect() };
    let (a, b) = (
        Container::runs_from_sorted(&range(0, 30_000)),
        Container::runs_from_sorted(&range(20_000, 50_000)),
    );
    let meet = a.intersect(&b, CHUNK_SPAN);
    assert!(matches!(meet, Container::Runs(_)), "interval overlap must stay runs");
    assert_eq!(meet.decode(), range(20_000, 30_000));
    let evens: Vec<u16> = (0..200).step_by(2).map(|t| t as u16).collect();
    let threes: Vec<u16> = (0..200).step_by(3).map(|t| t as u16).collect();
    let meet = Container::runs_from_sorted(&evens).intersect(
        &Container::runs_from_sorted(&threes),
        CHUNK_SPAN,
    );
    assert!(matches!(meet, Container::Array(_)), "fragmented run meet must sparsify");
    let sixes: Vec<u16> = (0..200).step_by(6).map(|t| t as u16).collect();
    assert_eq!(meet.decode(), sixes);
}

#[test]
fn tidset_boundaries_around_the_chunk_span() {
    for n_tx in [CHUNK_SPAN - 1, CHUNK_SPAN, CHUNK_SPAN + 1] {
        // The full set intersected with itself: every chunk is one run,
        // and the count is exactly n_tx across the chunk boundary.
        let all: Vec<u32> = (0..n_tx as u32).collect();
        let full = TidSet::from_sorted_tids(&all, n_tx);
        assert_eq!(full.cardinality(), n_tx);
        assert_eq!(full.intersect_count(&full), n_tx as u64);
        assert_eq!(full.intersect(&full).decode(), all);
        // A full 2^16 chunk compresses to one run; a trailing span-1
        // chunk (n_tx = 65537) is cheaper as a 2-byte array than a
        // 4-byte run, so only "never a bitmap" holds across all three.
        let census = full.census();
        assert!(census.runs >= 1, "the full-span chunk must compress to one run");
        assert_eq!(census.bitmaps, 0, "full chunks never densify to bitmaps");

        // A straddling cluster against a stride pattern, vs the sorted
        // oracle the old representation used.
        let lo = CHUNK_SPAN.saturating_sub(6) as u32;
        let cluster: Vec<u32> = (lo..n_tx as u32).collect();
        let stride: Vec<u32> = (0..n_tx as u32).step_by(3).collect();
        let (xs, ys) = (
            TidSet::from_sorted_tids(&cluster, n_tx),
            TidSet::from_sorted_tids(&stride, n_tx),
        );
        let want = intersect_sorted_count(&cluster, &stride);
        assert_eq!(xs.intersect_count(&ys), want, "n_tx={n_tx}");
        assert_eq!(
            xs.intersect(&ys).decode(),
            cluster.iter().copied().filter(|t| t % 3 == 0).collect::<Vec<_>>(),
            "n_tx={n_tx}"
        );

        // A set living only past the boundary merge-joins correctly with
        // one that never reaches it.
        if n_tx > CHUNK_SPAN {
            let high = TidSet::from_sorted_tids(&[CHUNK_SPAN as u32], n_tx);
            let low = TidSet::from_sorted_tids(&[5, 1_000], n_tx);
            assert_eq!(high.intersect_count(&low), 0);
            assert!(high.intersect(&low).is_empty());
            assert_eq!(high.intersect_count(&full), 1);
        }
    }
}

#[test]
fn stale_generations_never_serve_a_grown_database() {
    let mut db = TransactionDb::new(vec![
        tx(&[0, 1, 2]),
        tx(&[0, 1]),
        tx(&[0, 2]),
        tx(&[1, 2]),
        tx(&[0, 1, 3]),
    ]);
    let cfg = AprioriConfig { min_support: 0.2, max_k: 0 };
    let driver = MrApriori::new(ClusterConfig::standalone(), cfg).with_split_tx(2);
    let target: Vec<Itemset> = vec![vec![0, 1]];
    assert_eq!(driver.count_exact(&db, &target).unwrap(), vec![3]);
    let gen_before = driver.cache_stats().generation;
    db.append(vec![tx(&[0, 1]), tx(&[0, 1, 4])]);
    // The second plan opens a new generation: if a stale split index
    // were ever served, the grown transactions would be invisible here.
    assert_eq!(driver.count_exact(&db, &target).unwrap(), vec![5]);
    assert!(driver.cache_stats().generation > gen_before);
}

#[test]
fn exact_counter_reuses_one_index_build_per_split() {
    let db = TransactionDb::new(vec![
        tx(&[0, 1]),
        tx(&[0, 1, 2]),
        tx(&[1, 2]),
        tx(&[0, 2]),
        tx(&[0, 1, 2]),
        tx(&[2]),
        tx(&[0, 1]),
        tx(&[1]),
    ]);
    let cfg = AprioriConfig { min_support: 0.1, max_k: 0 };
    // Speculation off: twin map attempts would add nondeterministic
    // cache traffic and break the exact hit/miss accounting below.
    let driver = MrApriori::new(ClusterConfig::standalone(), cfg)
        .with_split_tx(2)
        .with_job(JobConfig { speculative: false, ..JobConfig::default() });
    let mut counter = ExactCounter::new(&driver, &db).unwrap();
    let before = driver.cache_stats();
    assert_eq!(counter.count(&db, &[vec![0, 1]]).unwrap(), vec![4]);
    let mid = driver.cache_stats();
    assert_eq!(mid.misses - before.misses, 4, "first scan builds one index per split");
    assert_eq!(mid.hits, before.hits);
    assert_eq!(counter.count(&db, &[vec![1, 2]]).unwrap(), vec![3]);
    let after = driver.cache_stats();
    assert_eq!(after.misses, mid.misses, "the second scan must rebuild nothing");
    assert_eq!(after.hits - mid.hits, 4);
    assert_eq!(after.entries, 4);
    assert!(after.resident_bytes > 0);
}

#[test]
fn level_loop_builds_once_and_hits_on_deeper_levels() {
    let db = QuestGenerator::new(QuestParams::dense(120)).generate();
    let cfg = AprioriConfig { min_support: 0.05, max_k: 3 };
    let driver = MrApriori::new(ClusterConfig::standalone(), cfg)
        .with_split_tx(40)
        .with_job(JobConfig { speculative: false, ..JobConfig::default() });
    let report = driver.mine(&db).unwrap();
    let stats = driver.cache_stats();
    // Level 1 never touches the cache; every level >= 2 job scans the
    // same 3 splits, so exactly the first counting job builds.
    let counting_jobs = report.result.levels.len().saturating_sub(1) as u64;
    assert!(counting_jobs >= 1, "the dense profile must reach level 2");
    assert_eq!(stats.misses, 3);
    assert_eq!(stats.hits, counting_jobs * 3 - 3);
    assert_eq!(stats.entries, 3);
}

#[test]
fn delta_scans_never_reuse_the_main_databases_indexes() {
    let base = TransactionDb::new(vec![
        tx(&[0, 1]),
        tx(&[0, 1]),
        tx(&[0, 1]),
        tx(&[0, 1, 2]),
        tx(&[2]),
    ]);
    let cfg = AprioriConfig { min_support: 0.2, max_k: 0 };
    let driver = MrApriori::new(ClusterConfig::standalone(), cfg).with_split_tx(2);
    driver.mine(&base).unwrap(); // warm the cache with the base view
    let delta = vec![tx(&[0, 1]), tx(&[0, 1]), tx(&[2])];
    let tracked: Vec<Itemset> = vec![vec![0, 1], vec![2]];
    let (counts, _) = run_delta_count(&driver, &delta, base.n_items, &tracked).unwrap();
    // Delta-only supports: a stale base-view index would report 4 and 2.
    assert_eq!(counts.get(&vec![0, 1]).copied().unwrap_or(0), 2);
    assert_eq!(counts.get(&vec![2]).copied().unwrap_or(0), 1);
}

//! Chaos-harness integration: differential property tests over random
//! fault plans, and the refresh-cycle atomicity contract under node
//! loss.
//!
//! The replayability contract is differential, not temporal: for any
//! survivable [`FaultPlan`] (every block still has a live replica), the
//! mined output must be **byte-identical** to the fault-free run, with
//! attempts bounded and the blacklist append-only. Fault *timing* is
//! keyed to logical coordinates (level boundaries, map completions), so
//! a plan replays exactly from its spec string.

use mr_apriori::data::Transaction;
use mr_apriori::mapreduce::JobConfig;
use mr_apriori::prelude::*;
use std::sync::Arc;

fn quest(n: usize, seed: u64) -> TransactionDb {
    QuestGenerator::new(QuestParams::t10_i4(n).with_seed(seed)).generate()
}

/// Generous upper bound on map attempts for one job: every scheduled
/// map (originals + lost-node requeues + fetch-exhaustion re-executions)
/// may burn up to `max_attempts` genuine failures, plus speculation.
fn attempts_bounded(s: &JobStats, max_attempts: usize) -> bool {
    s.map_attempts
        <= (s.maps_total + s.lost_maps_requeued + s.maps_reexecuted) * max_attempts
            + s.speculative_launched
}

/// The core invariant, property-tested over random databases and random
/// survivable fault plans, for both schedules: chaos changes *how* the
/// answer is computed (requeues, retries, re-replication), never *what*
/// it is.
#[test]
fn random_survivable_fault_plans_preserve_results_byte_identically() {
    let max_attempts = JobConfig::default().max_attempts;
    for seed in 1u64..=6 {
        let n_nodes = 3 + (seed as usize % 2);
        let cluster = ClusterConfig::fhssc(n_nodes);
        let replication = Dfs::new(&cluster).replication;
        let db = quest(250 + (seed as usize * 37) % 200, seed ^ 0xD1FF);
        let cfg = AprioriConfig { min_support: 0.05, max_k: 3 };

        let clean = MrApriori::new(cluster.clone(), cfg.clone())
            .with_split_tx(80)
            .mine(&db)
            .unwrap_or_else(|e| panic!("seed {seed}: clean mine: {e}"));

        let plan = FaultPlan::random(seed, n_nodes, replication);
        assert!(plan.is_survivable(), "seed {seed}: {plan}");
        // the spec string is the replay artifact — it must round-trip
        assert_eq!(FaultPlan::parse(&plan.to_string()).unwrap(), plan);

        for pipelined in [false, true] {
            let clock = Arc::new(FaultClock::new(plan.clone()));
            let mut driver = MrApriori::new(cluster.clone(), cfg.clone())
                .with_split_tx(80)
                .with_chaos(Some(Arc::clone(&clock)));
            if pipelined {
                driver = driver.with_pipeline(PipelineConfig::pipelined());
            }
            let chaotic = driver
                .mine(&db)
                .unwrap_or_else(|e| panic!("seed {seed} (pipelined={pipelined}): {plan}: {e}"));

            // byte-identity: same itemsets, same counts, same order
            assert_eq!(
                chaotic.result.frequent, clean.result.frequent,
                "seed {seed} (pipelined={pipelined}): {plan}"
            );
            // attempts bounded: recovery must not retry unboundedly
            for (k, s) in &chaotic.jobs {
                assert!(
                    attempts_bounded(s, max_attempts),
                    "seed {seed} level {k}: unbounded attempts {s:?}"
                );
            }
            // the clock only ever kills nodes the plan names
            let killed = clock.dead_nodes();
            assert!(
                killed.iter().all(|n| plan.killed_nodes().contains(n)),
                "seed {seed}: dead {killed:?} not in plan {plan}"
            );
            // blacklist is append-only and duplicate-free by contract
            let bl = clock.blacklisted();
            let mut dedup = bl.clone();
            dedup.dedup();
            assert_eq!(bl, dedup, "seed {seed}: blacklist {bl:?}");
            assert!(bl.len() < n_nodes, "seed {seed}: blacklisted every node");
        }
    }
}

/// Hand-written plans at every trigger kind, exercised through the
/// synchronous level loop on one fixed database.
#[test]
fn each_fault_kind_is_recovered_from_in_isolation() {
    let db = quest(400, 0xFA117);
    let cfg = AprioriConfig { min_support: 0.05, max_k: 3 };
    let cluster = ClusterConfig::fhssc(3);
    let clean = MrApriori::new(cluster.clone(), cfg.clone())
        .with_split_tx(100)
        .mine(&db)
        .unwrap();
    for spec in [
        "kill:0@now",
        "kill:2@level:2",
        "kill:1@maps:2",
        "slow:1:6@now",
        "fetchfail:0:2@now;fetchfail:1:5@level:2",
        "kill:2@level:2;slow:0:3@now;fetchfail:0:2@now",
    ] {
        let clock = Arc::new(FaultClock::new(FaultPlan::parse(spec).unwrap()));
        let chaotic = MrApriori::new(cluster.clone(), cfg.clone())
            .with_split_tx(100)
            .with_chaos(Some(Arc::clone(&clock)))
            .mine(&db)
            .unwrap_or_else(|e| panic!("{spec}: {e}"));
        assert_eq!(chaotic.result.frequent, clean.result.frequent, "{spec}");
        assert!(clock.stats().faults_injected >= 1, "{spec}: plan never fired");
    }
}

/// Losing every node is not survivable — the driver must surface a
/// typed error rather than loop or return a partial result. Depending
/// on when the last node dies the error is either the placement's
/// ("exceeds live datanodes") or the scheduler's ("job stranded").
#[test]
fn losing_every_node_is_a_typed_error_not_a_hang() {
    let db = quest(200, 7);
    let cfg = AprioriConfig { min_support: 0.05, max_k: 2 };
    let plan = FaultPlan::parse("kill:0@now;kill:1@now").unwrap();
    assert!(!plan.is_survivable());
    let err = MrApriori::new(ClusterConfig::fhssc(2), cfg)
        .with_split_tx(50)
        .with_chaos(Some(Arc::new(FaultClock::new(plan))))
        .mine(&db)
        .unwrap_err();
    let msg = err.to_string();
    assert!(
        msg.contains("datanodes") || msg.contains("stranded"),
        "unexpected error: {msg}"
    );
}

fn delta(n: usize, n_items: usize, seed: u64) -> Vec<Transaction> {
    synth_delta(n, n_items, seed)
}

/// A refresh cycle that loses a node mid-mine publishes byte-identically
/// when the loss is survivable.
#[test]
fn incremental_refresh_survives_a_lost_node_byte_identically() {
    let db0 = quest(400, 21);
    let cfg = AprioriConfig { min_support: 0.05, max_k: 3 };
    let conf = 0.6;
    let inc = IncrementalConfig { enabled: true, ..Default::default() };
    let d = delta(60, db0.n_items, 0xADD);

    // fault-free reference cycle
    let driver = MrApriori::new(ClusterConfig::fhssc(3), cfg.clone()).with_split_tx(100);
    let (report0, state0) = MinedState::capture(&driver, &db0).unwrap();
    let refresher = Refresher::new(driver, conf).with_incremental(inc.clone());
    refresher.seed_state(state0.clone());
    let cell = SnapshotCell::new(Arc::new(RuleIndex::build(&report0.result, conf)));
    let mut db = db0.clone();
    let (want, _) = refresher.refresh_once(&mut db, d.clone(), &cell).unwrap();

    // same cycle with node 1 dead before the delta job schedules
    let clock = Arc::new(FaultClock::new(FaultPlan::parse("kill:1@now").unwrap()));
    let driver = MrApriori::new(ClusterConfig::fhssc(3), cfg.clone())
        .with_split_tx(100)
        .with_chaos(Some(Arc::clone(&clock)));
    let refresher = Refresher::new(driver, conf).with_incremental(inc);
    refresher.seed_state(state0);
    let cell = SnapshotCell::new(Arc::new(RuleIndex::build(&report0.result, conf)));
    let mut db = db0.clone();
    let (got, _) = refresher.refresh_once(&mut db, d, &cell).unwrap();

    assert_eq!(got.result.frequent, want.result.frequent);
    assert_eq!(clock.dead_nodes(), vec![1]);
}

/// ... and rolls back atomically when it is not: the append is undone,
/// the served snapshot and generation stay untouched, and retrying the
/// same delta after the fault clears does not double-append.
#[test]
fn unsurvivable_refresh_rolls_back_the_cycle_whole() {
    let db0 = quest(300, 33);
    let cfg = AprioriConfig { min_support: 0.05, max_k: 2 };
    let conf = 0.6;
    let d = delta(40, db0.n_items, 0xBAD);

    let plan = FaultPlan::parse("kill:0@now;kill:1@now;kill:2@now").unwrap();
    assert!(!plan.is_survivable());
    let driver = MrApriori::new(ClusterConfig::fhssc(3), cfg.clone())
        .with_split_tx(100)
        .with_chaos(Some(Arc::new(FaultClock::new(plan))));
    let base = driver.mine(&db0); // all nodes dead: even the base mine fails
    assert!(base.is_err());

    // seed the refresher from a healthy capture, then lose the cluster
    let healthy = MrApriori::new(ClusterConfig::fhssc(3), cfg.clone()).with_split_tx(100);
    let (report0, state0) = MinedState::capture(&healthy, &db0).unwrap();
    let refresher = Refresher::new(driver, conf)
        .with_incremental(IncrementalConfig { enabled: true, ..Default::default() });
    refresher.seed_state(state0);
    let index0 = Arc::new(RuleIndex::build(&report0.result, conf));
    let cell = SnapshotCell::new(Arc::clone(&index0));
    let gen_before = cell.generation();

    let mut db = db0.clone();
    let err = refresher.refresh_once(&mut db, d.clone(), &cell).unwrap_err();
    assert!(matches!(err, RefreshError::Mine(_)), "{err}");
    // rollback contract: db restored, snapshot and generation untouched
    assert_eq!(db.transactions, db0.transactions);
    assert_eq!(cell.generation(), gen_before);
    assert_eq!(cell.load().n_rules(), index0.n_rules());

    // after the fault clears, the same delta applies exactly once and
    // matches the cycle that never saw a fault
    let refresher = Refresher::new(
        MrApriori::new(ClusterConfig::fhssc(3), cfg.clone()).with_split_tx(100),
        conf,
    )
    .with_incremental(IncrementalConfig { enabled: true, ..Default::default() });
    let (_, state0) = MinedState::capture(&healthy, &db0).unwrap();
    refresher.seed_state(state0);
    let (retried, st) = refresher.refresh_once(&mut db, d, &cell).unwrap();
    assert_eq!(db.len(), db0.len() + 40);
    assert_eq!(st.delta_tx, 40);
    let full = healthy.mine(&db).unwrap();
    assert_eq!(retried.result.frequent, full.result.frequent);
}

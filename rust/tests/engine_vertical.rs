//! Differential coverage for the vertical TID-bitset engine: the naive
//! scan is the oracle, and every counting path the system exposes —
//! direct `count`, the mixed-length shared-scan regrouping, the
//! classical and pipelined MapReduce drivers, and the incremental
//! FUP-style state — must be byte-identical under `engine = vertical`.

use mr_apriori::data::Transaction;
use mr_apriori::engine::{count_mixed, NaiveEngine};
use mr_apriori::prelude::*;
use mr_apriori::util::proptest::check;
use mr_apriori::util::rng::Xoshiro256;

fn tx(items: &[u32]) -> Transaction {
    Transaction::new(items.iter().copied())
}

/// A randomized database stressing the engine's edges: empty
/// transactions, duplicate items fed to the constructor, a long "spine"
/// pattern so candidates with k ≥ 32 have non-zero support, and a
/// dictionary that is either narrow (dense bitset rows) or very wide
/// (sparse TID lists).
fn build_db(seed: u64, n_tx: usize, wide_dict: bool) -> (Vec<Transaction>, usize, Vec<u32>) {
    let mut rng = Xoshiro256::seed_from_u64(seed);
    // Narrow enough to stay on dense bitset rows but wide enough that the
    // 36-item spine (the k >= 32 candidates) fits either way.
    let n_items = if wide_dict { 5_000 } else { 40 };
    // Spine: 36 distinct items the long candidates slice from.
    let mut spine: Vec<u32> = rng
        .sample_distinct(n_items, 36.min(n_items))
        .into_iter()
        .map(|x| x as u32)
        .collect();
    spine.sort_unstable();
    let mut txs = Vec::with_capacity(n_tx);
    for _ in 0..n_tx {
        let roll = rng.gen_range(10);
        let items: Vec<u32> = if roll == 0 {
            Vec::new() // empty transaction
        } else if roll <= 2 {
            spine.clone() // spine superset rows keep k>=32 supports > 0
        } else {
            // duplicates on purpose — Transaction::new must dedup them
            let len = rng.range_usize(1, 12);
            (0..len)
                .flat_map(|_| {
                    let i = rng.gen_range(n_items as u64) as u32;
                    [i, i]
                })
                .collect()
        };
        txs.push(tx(&items));
    }
    (txs, n_items, spine)
}

/// Random candidate list mixing lengths 1..=3, out-of-dictionary ids,
/// duplicate entries, and k ∈ {31, 32, 33, 36} spine slices.
fn build_candidates(seed: u64, n_items: usize, spine: &[u32]) -> Vec<Itemset> {
    let mut rng = Xoshiro256::seed_from_u64(seed ^ 0xC0FFEE);
    let mut cands: Vec<Itemset> = Vec::new();
    for _ in 0..60 {
        let k = rng.range_usize(1, 4);
        let mut c: Vec<u32> = rng
            .sample_distinct(n_items, k.min(n_items))
            .into_iter()
            .map(|x| x as u32)
            .collect();
        c.sort_unstable();
        cands.push(c);
    }
    // u64-row boundary regime: candidates at and past 32 items
    for k in [31usize, 32, 33, 36] {
        if k <= spine.len() {
            cands.push(spine[..k].to_vec());
        }
    }
    cands.push(vec![n_items as u32 + 7]); // beyond the dictionary
    if let Some(first) = cands.first().cloned() {
        cands.push(first); // duplicate entry, counted per position
    }
    cands
}

#[test]
fn prop_vertical_matches_naive_oracle() {
    check(
        "vertical-vs-naive",
        0x7E12_41CA,
        24,
        |rng| {
            vec![
                rng.next_u64(),                   // content seed
                rng.range_usize(0, 200) as u64,   // n_tx
                rng.range_usize(0, 2) as u64,     // narrow or wide dictionary
            ]
        },
        |params| {
            let (txs, n_items, spine) = build_db(params[0], params[1] as usize, params[2] == 1);
            let cands = build_candidates(params[0], n_items, &spine);
            let want = NaiveEngine.count(&txs, &cands, n_items).unwrap();
            let direct = VerticalEngine.count(&txs, &cands, n_items).unwrap();
            if direct != want {
                return Err("direct count diverged from naive".into());
            }
            // the shared-scan regrouping path must scatter back identically
            let mixed = count_mixed(&VerticalEngine, &txs, &cands, n_items).unwrap();
            if mixed != want {
                return Err("count_mixed diverged from naive".into());
            }
            Ok(())
        },
    );
}

#[test]
fn word_boundary_slice_sizes_match_naive() {
    // n_tx pinned at the u64-word edges the dense rows pack into.
    for n_tx in [0usize, 1, 63, 64, 65, 127, 128, 129] {
        let (txs, n_items, spine) = build_db(0xB0DA + n_tx as u64, n_tx, false);
        let cands = build_candidates(0xB0DA, n_items, &spine);
        let want = NaiveEngine.count(&txs, &cands, n_items).unwrap();
        let got = VerticalEngine.count(&txs, &cands, n_items).unwrap();
        assert_eq!(got, want, "n_tx={n_tx}");
    }
}

fn driver(kind: EngineKind, cfg: &AprioriConfig) -> MrApriori {
    MrApriori::new(ClusterConfig::fhssc(2), cfg.clone())
        .with_engine(build_engine(kind, None))
        .with_split_tx(61)
}

#[test]
fn prop_classical_and_pipelined_paths_identical_under_vertical() {
    check(
        "vertical-mr-paths",
        0x5EED_0CA7,
        6,
        |rng| vec![rng.next_u64(), rng.range_usize(60, 260) as u64],
        |params| {
            let db = QuestGenerator::new(
                QuestParams::dense(params[1] as usize).with_seed(params[0]),
            )
            .generate();
            let cfg = AprioriConfig { min_support: 0.08, max_k: 4 };
            let base = driver(EngineKind::HashTree, &cfg).mine(&db).map_err(|e| e.to_string())?;
            // classical (synchronous) schedule
            let sync = driver(EngineKind::Vertical, &cfg).mine(&db).map_err(|e| e.to_string())?;
            if sync.result.frequent != base.result.frequent {
                return Err("synchronous vertical mine diverged".into());
            }
            // pipelined schedule with two-level batched shared scans
            let piped = driver(EngineKind::Vertical, &cfg)
                .with_pipeline(PipelineConfig::pipelined())
                .mine(&db)
                .map_err(|e| e.to_string())?;
            if piped.result.frequent != base.result.frequent {
                return Err("pipelined vertical mine diverged".into());
            }
            Ok(())
        },
    );
}

#[test]
fn incremental_path_identical_under_vertical() {
    // Capture + delta maintenance driven entirely through the vertical
    // engine (Δ-scan jobs and frontier ExactCounter recounts included)
    // must track a from-scratch mine exactly, generation by generation.
    let cfg = AprioriConfig { min_support: 0.3, max_k: 0 };
    let mut db = TransactionDb::new(vec![
        tx(&[0, 1]),
        tx(&[0, 1, 2]),
        tx(&[0]),
        tx(&[2, 3]),
        tx(&[1, 2]),
    ]);
    let vertical = MrApriori::new(ClusterConfig::standalone(), cfg.clone())
        .with_engine(build_engine(EngineKind::Vertical, None))
        .with_split_tx(2);
    let (_, mut state) = MinedState::capture(&vertical, &db).unwrap();
    let mut rng = Xoshiro256::seed_from_u64(0x1D_E17A);
    for generation in 0..4 {
        let delta: Vec<Transaction> = (0..rng.range_usize(1, 6))
            .map(|_| {
                let len = rng.range_usize(1, 4);
                let items: Vec<u32> =
                    (0..len).map(|_| rng.gen_range(5) as u32).collect();
                tx(&items)
            })
            .collect();
        db.append(delta.clone());
        match state
            .apply_delta(&vertical, &db, &delta, &IncrementalConfig::default())
            .unwrap()
        {
            DeltaApply::Applied(_) => {}
            DeltaApply::FrontierBlowup { .. } => {
                let (_, fresh) = MinedState::capture(&vertical, &db).unwrap();
                state = fresh;
            }
        }
        let full = ClassicalApriori::new(MatcherKind::Naive).mine(&db, &cfg);
        assert_eq!(
            state.to_result().frequent,
            full.frequent,
            "generation {generation} diverged"
        );
    }
}

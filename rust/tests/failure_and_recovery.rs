//! Failure-path integration: injected task failures, abort semantics,
//! datanode decommission during a workload, and under-replication reads.

use mr_apriori::data::split::plan_splits;
use mr_apriori::dfs::{Dfs, DfsError};
use mr_apriori::mapreduce::app::ItemCount;
use mr_apriori::mapreduce::runner::FailureSpec;
use mr_apriori::mapreduce::{JobConfig, JobRunner};
use mr_apriori::prelude::*;

fn quest(n: usize) -> TransactionDb {
    QuestGenerator::new(QuestParams::t10_i4(n)).generate()
}

#[test]
fn mining_survives_moderate_failure_rates() {
    let db = quest(600);
    let cfg = AprioriConfig { min_support: 0.05, max_k: 2 };
    let clean = MrApriori::new(ClusterConfig::fhssc(3), cfg.clone())
        .with_split_tx(100)
        .mine(&db)
        .unwrap();
    for seed in [1u64, 7, 42] {
        let job = JobConfig {
            failure: Some(FailureSpec {
                map_fail_prob: 0.3,
                reduce_fail_prob: 0.2,
                seed,
            }),
            max_attempts: 16,
            speculative: false,
            ..Default::default()
        };
        let flaky = MrApriori::new(ClusterConfig::fhssc(3), cfg.clone())
            .with_job(job)
            .with_split_tx(100)
            .mine(&db)
            .unwrap_or_else(|e| panic!("seed {seed}: {e}"));
        assert_eq!(flaky.result.frequent, clean.result.frequent, "seed {seed}");
        let failures: usize = flaky.jobs.iter().map(|(_, s)| s.map_failures).sum();
        assert!(failures > 0, "seed {seed}: injection had no effect");
    }
}

#[test]
fn certain_failure_aborts_the_whole_mining_run() {
    let db = quest(300);
    let cfg = AprioriConfig { min_support: 0.05, max_k: 2 };
    let job = JobConfig {
        failure: Some(FailureSpec {
            map_fail_prob: 1.0,
            reduce_fail_prob: 0.0,
            seed: 3,
        }),
        max_attempts: 2,
        ..Default::default()
    };
    let err = MrApriori::new(ClusterConfig::fhssc(2), cfg)
        .with_job(job)
        .with_split_tx(50)
        .mine(&db)
        .expect_err("must abort");
    assert!(err.to_string().contains("map task"));
}

#[test]
fn decommission_mid_workload_keeps_data_readable_and_jobs_running() {
    let db = quest(800);
    let cluster = ClusterConfig::fhssc(4);
    let splits = plan_splits(&db, 100);
    let mut dfs = Dfs::new(&cluster);
    let blocks = dfs.write_splits(&splits).unwrap();

    // run one job, then lose a node, then run again on the updated dfs
    let runner = JobRunner::new(&cluster, &dfs, &blocks);
    let (before, _) = runner
        .run(&ItemCount, &db, &splits, &JobConfig::default())
        .unwrap();

    dfs.decommission(1).unwrap();
    for &b in &blocks {
        let locs = dfs.locations(b).unwrap();
        assert!(!locs.contains(&1), "replica still on dead node");
        assert_eq!(locs.len(), 3, "re-replication restored factor 3");
    }
    let runner = JobRunner::new(&cluster, &dfs, &blocks);
    let (after, stats) = runner
        .run(&ItemCount, &db, &splits, &JobConfig::default())
        .unwrap();
    assert_eq!(before, after, "results unchanged after decommission");
    // node 1's trackers still pull tasks (compute is fine, storage is gone):
    // locality can dip below 1.0 but must stay sane.
    let loc = stats.locality_fraction();
    assert!((0.0..=1.0).contains(&loc));
}

#[test]
fn double_decommission_errors_and_underreplication_is_visible() {
    let db = quest(200);
    let cluster = ClusterConfig::fhssc(3);
    let splits = plan_splits(&db, 50);
    let mut dfs = Dfs::new(&cluster);
    let blocks = dfs.write_splits(&splits).unwrap();
    dfs.decommission(0).unwrap();
    assert!(matches!(
        dfs.decommission(0),
        Err(DfsError::AlreadyDecommissioned(0))
    ));
    // no spare nodes: blocks under-replicated but still readable
    dfs.decommission(1).unwrap();
    for &b in &blocks {
        let locs = dfs.locations(b).unwrap();
        assert_eq!(locs.len(), 1, "single replica remains");
        assert_eq!(locs[0], 2);
    }
}

#[test]
fn speculative_execution_counters_fire_on_real_runner() {
    // A large number of small tasks on a 2-node cluster: with aggressive
    // speculation thresholds some duplicates fire; results stay exact.
    let db = quest(1_000);
    let cluster = ClusterConfig::fhssc(2);
    let splits = plan_splits(&db, 20);
    let mut dfs = Dfs::new(&cluster);
    let blocks = dfs.write_splits(&splits).unwrap();
    let runner = JobRunner::new(&cluster, &dfs, &blocks);
    let cfg = JobConfig {
        speculative: true,
        speculation_slowdown: 0.0, // every running task is "late": max pressure
        n_reducers: 2,
        ..Default::default()
    };
    let (out, stats) = runner.run(&ItemCount, &db, &splits, &cfg).unwrap();
    let baseline = runner
        .run(&ItemCount, &db, &splits, &JobConfig { speculative: false, n_reducers: 2, ..Default::default() })
        .unwrap()
        .0;
    assert_eq!(out, baseline, "speculation must never change results");
    assert!(
        stats.map_attempts >= stats.maps_total,
        "attempts {} < tasks {}",
        stats.map_attempts,
        stats.maps_total
    );
}

//! Cross-algorithm equivalence: every miner in the crate — classical (3
//! matchers), record-filter, intersection, FP-Growth, and the distributed
//! MapReduce driver on every deployment preset — must produce identical
//! frequent itemsets on arbitrary workloads. This is the strongest
//! correctness statement the repo makes.

use mr_apriori::prelude::*;
use mr_apriori::util::proptest::check;
use mr_apriori::util::rng::Xoshiro256;

fn gen_params(rng: &mut Xoshiro256) -> Vec<u64> {
    vec![
        rng.next_u64(),                      // dataset seed
        rng.range_usize(30, 300) as u64,     // transactions
        rng.range_usize(10, 40) as u64,      // items
        (rng.range_usize(8, 25)) as u64,     // min-support %
    ]
}

fn build_db(params: &[u64]) -> TransactionDb {
    let p = QuestParams {
        n_transactions: params[1] as usize,
        n_items: params[2] as usize,
        avg_tx_len: 6.0,
        avg_pattern_len: 3.0,
        n_patterns: 12,
        corruption: 0.25,
        seed: params[0],
    };
    QuestGenerator::new(p).generate()
}

#[test]
fn prop_all_single_machine_miners_agree() {
    check(
        "miners-agree",
        0x314159,
        12,
        gen_params,
        |params| {
            let db = build_db(params);
            let cfg = AprioriConfig {
                min_support: params[3] as f64 / 100.0,
                max_k: 5,
            };
            let base = ClassicalApriori::new(MatcherKind::Naive).mine(&db, &cfg);
            let checks: Vec<(&str, MiningResult)> = vec![
                ("hash-tree", ClassicalApriori::new(MatcherKind::HashTree).mine(&db, &cfg)),
                ("trie", ClassicalApriori::new(MatcherKind::Trie).mine(&db, &cfg)),
                ("record-filter", RecordFilterApriori.mine(&db, &cfg)),
                ("intersection", IntersectionApriori.mine(&db, &cfg)),
                ("fp-growth", FpGrowth.mine(&db, &cfg)),
            ];
            for (name, r) in checks {
                if r.frequent != base.frequent {
                    return Err(format!(
                        "{name} diverged: {} vs {} itemsets",
                        r.frequent.len(),
                        base.frequent.len()
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_mapreduce_driver_matches_classical_on_every_preset() {
    check(
        "mr-driver-matches",
        0x271828,
        8,
        gen_params,
        |params| {
            let db = build_db(params);
            let cfg = AprioriConfig {
                min_support: params[3] as f64 / 100.0,
                max_k: 4,
            };
            let base = ClassicalApriori::default().mine(&db, &cfg);
            for cluster in [
                ClusterConfig::standalone(),
                ClusterConfig::pseudo_distributed(),
                ClusterConfig::fhssc(3),
                ClusterConfig::fhdsc(4),
            ] {
                let name = format!("{:?}x{}", cluster.mode, cluster.n_nodes());
                let report = MrApriori::new(cluster, cfg.clone())
                    .with_split_tx(37)
                    .mine(&db)
                    .map_err(|e| format!("{name}: {e}"))?;
                if report.result.frequent != base.frequent {
                    return Err(format!("{name} diverged"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_supports_are_exact_and_downward_closed() {
    check(
        "supports-exact-closed",
        0x161803,
        10,
        gen_params,
        |params| {
            let db = build_db(params);
            let cfg = AprioriConfig {
                min_support: params[3] as f64 / 100.0,
                max_k: 4,
            };
            let r = ClassicalApriori::default().mine(&db, &cfg);
            let threshold = cfg.threshold(db.len());
            for (is, sup) in &r.frequent {
                if *sup != db.support(is) as u64 {
                    return Err(format!("support of {is:?} wrong"));
                }
                if *sup < threshold {
                    return Err(format!("{is:?} below threshold"));
                }
                // downward closure: every (k-1)-subset present
                if is.len() > 1 {
                    for skip in 0..is.len() {
                        let sub: Vec<u32> = is
                            .iter()
                            .enumerate()
                            .filter(|&(i, _)| i != skip)
                            .map(|(_, &x)| x)
                            .collect();
                        if r.support_of(&sub).is_none() {
                            return Err(format!("closure violated: {sub:?} of {is:?}"));
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_rules_respect_confidence_and_support_math() {
    check(
        "rules-math",
        0x141421,
        10,
        gen_params,
        |params| {
            let db = build_db(params);
            let cfg = AprioriConfig {
                min_support: params[3] as f64 / 100.0,
                max_k: 3,
            };
            let r = ClassicalApriori::default().mine(&db, &cfg);
            let rules = generate_rules(&r, 0.4);
            for rule in &rules {
                if rule.confidence < 0.4 {
                    return Err("rule under confidence threshold".into());
                }
                // support(antecedent ∪ consequent) == rule.support, exactly
                let mut union: Vec<u32> = rule
                    .antecedent
                    .iter()
                    .chain(rule.consequent.iter())
                    .copied()
                    .collect();
                union.sort_unstable();
                if db.support(&union) as u64 != rule.support {
                    return Err(format!("rule support wrong for {union:?}"));
                }
                let sup_a = db.support(&rule.antecedent) as f64;
                let conf = rule.support as f64 / sup_a;
                if (conf - rule.confidence).abs() > 1e-9 {
                    return Err("confidence math wrong".into());
                }
            }
            Ok(())
        },
    );
}

/// Mining the projected (frequent-items-only) database must preserve all
/// itemsets above threshold — the dictionary-shrink the tensor path uses.
#[test]
fn prop_projection_preserves_frequent_itemsets() {
    check(
        "projection-preserves",
        0x173205,
        10,
        gen_params,
        |params| {
            let db = build_db(params);
            let cfg = AprioriConfig {
                min_support: params[3] as f64 / 100.0,
                max_k: 3,
            };
            let full = ClassicalApriori::default().mine(&db, &cfg);
            let frequent_items: Vec<u32> = full.level(1).map(|(is, _)| is[0]).collect();
            let (projected, back) = db.project(&frequent_items);
            let proj = ClassicalApriori::default().mine(&projected, &cfg);
            // map projected ids back and compare
            let mut mapped: Vec<(Itemset, u64)> = proj
                .frequent
                .iter()
                .map(|(is, s)| {
                    let mut orig: Vec<u32> = is.iter().map(|&i| back[i as usize]).collect();
                    orig.sort_unstable();
                    (orig, *s)
                })
                .collect();
            mapped.sort_by(|a, b| (a.0.len(), &a.0).cmp(&(b.0.len(), &b.0)));
            if mapped == full.frequent {
                Ok(())
            } else {
                Err(format!(
                    "projection changed results: {} vs {}",
                    mapped.len(),
                    full.frequent.len()
                ))
            }
        },
    );
}

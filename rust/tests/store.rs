//! Durable snapshot store invariants:
//!
//! * **codec round-trips** — randomized `MiningResult` / `MinedState` /
//!   `RuleIndex` values survive encode→decode exactly (property-tested);
//! * **corruption detection** — the exhaustive single-bit-flip corpus and
//!   every truncation prefix of a snapshot decode to a typed error,
//!   never a panic or a silently wrong value;
//! * **crash consistency** — the commit protocol interrupted after every
//!   write boundary still recovers a complete generation whose contents
//!   equal the uninterrupted run's at that generation;
//! * **warm restart** — a refresher killed mid-run and restarted from the
//!   store resumes at the last published generation *on the incremental
//!   delta path* (no re-mine of the base) and ends byte-identical to an
//!   uninterrupted run; a corrupted newest generation degrades to the
//!   previous one and still converges.

use std::sync::Arc;

use mr_apriori::data::Transaction;
use mr_apriori::incremental::verify_invariant;
use mr_apriori::prelude::*;
use mr_apriori::store::codec;
use mr_apriori::util::proptest::check;
use mr_apriori::util::rng::Xoshiro256;
use mr_apriori::util::tempdir::TempDir;

const MIN_SUPPORT: f64 = 0.2;
const MIN_CONF: f64 = 0.4;

fn cfg() -> AprioriConfig {
    AprioriConfig { min_support: MIN_SUPPORT, max_k: 0 }
}

fn driver() -> MrApriori {
    MrApriori::new(ClusterConfig::standalone(), cfg()).with_split_tx(16)
}

/// Small skewed base: low item ids dominate, so there is real frequent
/// structure for deltas to promote against.
fn base_db() -> TransactionDb {
    let mut rng = Xoshiro256::seed_from_u64(0xBA5E_D1);
    let txs: Vec<Transaction> = (0..40)
        .map(|_| {
            let len = rng.range_usize(2, 5);
            Transaction::new((0..len).map(|_| {
                let a = rng.gen_range(10) as u32;
                let b = rng.gen_range(10) as u32;
                a.min(b)
            }))
        })
        .collect();
    TransactionDb::new(txs)
}

/// Deterministic random db for the codec round-trip properties.
fn random_db(rng: &mut Xoshiro256) -> Vec<Vec<u32>> {
    (0..rng.range_usize(1, 20))
        .map(|_| {
            (0..rng.range_usize(0, 6))
                .map(|_| rng.gen_range(8) as u32)
                .collect()
        })
        .collect()
}

fn db_of(spec: &[Vec<u32>]) -> TransactionDb {
    TransactionDb::new(
        spec.iter()
            .map(|t| Transaction::new(t.iter().copied()))
            .collect(),
    )
}

/// Render a fixed random basket corpus against an index — the serving
/// byte-identity fingerprint.
fn render_corpus(idx: &RuleIndex, seed: u64) -> Vec<String> {
    let mut rng = Xoshiro256::seed_from_u64(seed);
    (0..30)
        .map(|_| {
            let len = rng.range_usize(1, 5);
            let basket: Vec<u32> = (0..len).map(|_| rng.gen_range(14) as u32).collect();
            render_lines(&idx.recommend(&basket, 5))
        })
        .collect()
}

// ---------------------------------------------------------- round-trips

#[test]
fn prop_mining_result_codec_roundtrip() {
    check(
        "MiningResult encode/decode is the identity",
        0x0DEC_1,
        40,
        random_db,
        |spec| {
            let result = ClassicalApriori::default().mine(&db_of(spec), &cfg());
            let back = codec::decode_mining_result(&codec::encode_mining_result(&result))
                .map_err(|e| e.to_string())?;
            if format!("{result:?}") == format!("{back:?}") {
                Ok(())
            } else {
                Err("decoded MiningResult differs".into())
            }
        },
    );
}

#[test]
fn prop_mined_state_codec_roundtrip() {
    let driver = driver();
    check(
        "MinedState encode/decode is the identity",
        0x0DEC_2,
        25,
        random_db,
        |spec| {
            let db = db_of(spec);
            if db.n_items == 0 {
                return Ok(()); // empty universe has no state to persist
            }
            let (_, state) = MinedState::capture(&driver, &db).map_err(|e| e.to_string())?;
            let back = codec::decode_mined_state(&codec::encode_mined_state(&state))
                .map_err(|e| e.to_string())?;
            if format!("{state:?}") == format!("{back:?}") {
                Ok(())
            } else {
                Err("decoded MinedState differs".into())
            }
        },
    );
}

#[test]
fn prop_rule_index_codec_roundtrip_serves_identically() {
    check(
        "decoded RuleIndex answers byte-identically",
        0x0DEC_3,
        25,
        random_db,
        |spec| {
            let result = ClassicalApriori::default().mine(&db_of(spec), &cfg());
            let idx = RuleIndex::build(&result, MIN_CONF);
            let back = codec::decode_rule_index(&codec::encode_rule_index(&idx))
                .map_err(|e| e.to_string())?;
            if back.n_rules() != idx.n_rules() || back.n_itemsets() != idx.n_itemsets() {
                return Err("decoded index sizes differ".into());
            }
            if render_corpus(&back, 7) == render_corpus(&idx, 7) {
                Ok(())
            } else {
                Err("decoded index serves different answers".into())
            }
        },
    );
}

#[test]
fn prop_delta_codec_roundtrip() {
    check(
        "transaction-delta encode/decode is the identity",
        0x0DEC_4,
        60,
        random_db,
        |spec| {
            let delta: Vec<Transaction> = spec
                .iter()
                .map(|t| Transaction::new(t.iter().copied()))
                .collect();
            let back =
                codec::decode_delta(&codec::encode_delta(&delta)).map_err(|e| e.to_string())?;
            if back == delta {
                Ok(())
            } else {
                Err("decoded delta differs".into())
            }
        },
    );
}

// --------------------------------------------------- corruption corpus

/// One realistic snapshot encoding (delta + state + result + index).
fn snapshot_bytes() -> Vec<u8> {
    let base = base_db();
    let delta = vec![Transaction::new([0u32, 1]), Transaction::new([2u32])];
    let mut union = base.clone();
    union.append(delta.clone());
    let (report, state) = MinedState::capture(&driver(), &union).unwrap();
    let index = RuleIndex::build(&report.result, MIN_CONF);
    codec::encode_snapshot(&SnapshotRef {
        generation: 5,
        base: BaseRef::of(&base),
        min_support: MIN_SUPPORT,
        max_k: 0,
        delta: &delta,
        result: &report.result,
        state: Some(&state),
        index: &index,
    })
}

#[test]
fn every_single_bit_flip_is_detected_never_a_panic_or_wrong_decode() {
    let good = snapshot_bytes();
    assert!(codec::decode_snapshot(&good).is_ok());
    // FNV-1a's byte step (xor, then multiply by an odd prime) is
    // invertible, so any single corrupted byte must change the digest;
    // header fields are covered by their own explicit checks. Flip the
    // low and high bit of every byte and demand a typed error each time.
    for i in 0..good.len() {
        for mask in [0x01u8, 0x80] {
            let mut bad = good.clone();
            bad[i] ^= mask;
            assert!(
                codec::decode_snapshot(&bad).is_err(),
                "bit flip at byte {i} (mask {mask:#04x}) decoded successfully"
            );
        }
    }
}

#[test]
fn every_truncation_prefix_is_detected() {
    let good = snapshot_bytes();
    for len in 0..good.len() {
        assert!(
            codec::decode_snapshot(&good[..len]).is_err(),
            "truncation to {len} of {} bytes decoded successfully",
            good.len()
        );
    }
}

// -------------------------------------------------- crash consistency

/// Deterministic content of generation `g` over the base: cumulative
/// delta of `g` fixed transactions, mined + indexed.
fn generation_parts(
    base: &TransactionDb,
    g: u64,
) -> (Vec<Transaction>, MiningResult, RuleIndex) {
    let delta: Vec<Transaction> = (0..g)
        .map(|i| Transaction::new([(i % 5) as u32, (i % 5) as u32 + 1]))
        .collect();
    let mut union = base.clone();
    union.append(delta.clone());
    let result = ClassicalApriori::default().mine(&union, &cfg());
    let index = RuleIndex::build(&result, MIN_CONF);
    (delta, result, index)
}

#[test]
fn commit_interrupted_at_every_boundary_recovers_an_intact_generation() {
    let base = base_db();
    for interrupt_at in 1..=3u64 {
        for step in CommitStep::ALL {
            let tmp = TempDir::new(&format!("crash_{interrupt_at}_{step:?}"));
            let store = SnapshotStore::open(tmp.path(), 8).unwrap();
            // publish generations 1..interrupt_at-1 cleanly
            for g in 1..interrupt_at {
                let (delta, result, index) = generation_parts(&base, g);
                store
                    .publish(&SnapshotRef {
                        generation: g,
                        base: BaseRef::of(&base),
                        min_support: MIN_SUPPORT,
                        max_k: 0,
                        delta: &delta,
                        result: &result,
                        state: None,
                        index: &index,
                    })
                    .unwrap();
            }
            // ...then kill the commit of `interrupt_at` at this boundary
            let (delta, result, index) = generation_parts(&base, interrupt_at);
            let committed = store
                .publish_with_hook(
                    &SnapshotRef {
                        generation: interrupt_at,
                        base: BaseRef::of(&base),
                        min_support: MIN_SUPPORT,
                        max_k: 0,
                        delta: &delta,
                        result: &result,
                        state: None,
                        index: &index,
                    },
                    &mut |s| s != step,
                )
                .unwrap();
            // the hook aborts after completing `step`, so the call always
            // reports an unfinished commit — even when the abort lands
            // after the manifest rename (only pruning was skipped)
            assert!(!committed);

            // expected landing: before the snapshot rename the new file
            // does not exist; after it but before the manifest rename the
            // old manifest still names g-1 (except g=1, where no manifest
            // exists yet and the scan finds the new intact file); after
            // the manifest rename the new generation is published.
            let expected = match step {
                CommitStep::SnapTempWritten | CommitStep::SnapSynced => {
                    interrupt_at.checked_sub(1).filter(|&g| g > 0)
                }
                CommitStep::SnapRenamed
                | CommitStep::ManifestTempWritten
                | CommitStep::ManifestSynced => {
                    if interrupt_at == 1 {
                        Some(1)
                    } else {
                        Some(interrupt_at - 1)
                    }
                }
                CommitStep::ManifestRenamed => Some(interrupt_at),
            };
            let recovered = store.load_latest().unwrap();
            match expected {
                None => assert!(
                    recovered.is_none(),
                    "interrupt at {step:?} of gen {interrupt_at}: expected empty store"
                ),
                Some(g) => {
                    let snap = recovered.unwrap_or_else(|| {
                        panic!("interrupt at {step:?} of gen {interrupt_at}: nothing recovered")
                    });
                    assert_eq!(snap.generation, g, "interrupt at {step:?}");
                    let (want_delta, want_result, _) = generation_parts(&base, g);
                    assert_eq!(snap.delta, want_delta, "interrupt at {step:?}");
                    assert_eq!(
                        snap.result.frequent, want_result.frequent,
                        "interrupt at {step:?}"
                    );
                }
            }

            // the restarted process republishes from the recovered point:
            // the final state must equal a never-interrupted run's
            let resume_from = expected.unwrap_or(0);
            for g in resume_from + 1..=4 {
                let (delta, result, index) = generation_parts(&base, g);
                store
                    .publish(&SnapshotRef {
                        generation: g,
                        base: BaseRef::of(&base),
                        min_support: MIN_SUPPORT,
                        max_k: 0,
                        delta: &delta,
                        result: &result,
                        state: None,
                        index: &index,
                    })
                    .unwrap();
            }
            let final_snap = store.load_latest().unwrap().unwrap();
            assert_eq!(final_snap.generation, 4);
            let (_, want, _) = generation_parts(&base, 4);
            assert_eq!(final_snap.result.frequent, want.frequent);
        }
    }
}

// ------------------------------------------------------- warm restart

fn delta_for(round: u64, n_items: usize) -> Vec<Transaction> {
    synth_delta(6, n_items, 0xD117A + round)
}

fn store_refresher(store: &Arc<SnapshotStore>, base: &TransactionDb) -> Refresher {
    Refresher::new(driver(), MIN_CONF)
        .with_incremental(IncrementalConfig {
            enabled: true,
            // an unbounded guard keeps every cycle on the delta path, so
            // "no re-mine after restart" is deterministic below
            max_frontier_blowup: 1e9,
        })
        .with_store(Arc::clone(store), BaseRef::of(base), base.len())
}

/// Uninterrupted reference: N incremental refresh cycles with
/// persistence; returns the per-generation corpus fingerprints, the
/// final database, and the final served index fingerprint.
fn reference_run(dir: &std::path::Path, rounds: u64) -> (Vec<Vec<String>>, TransactionDb) {
    let base = base_db();
    let store = Arc::new(SnapshotStore::open(dir, 8).unwrap());
    let mut db = base.clone();
    let result0 = ClassicalApriori::default().mine(&db, &cfg());
    let cell = SnapshotCell::new(Arc::new(RuleIndex::build(&result0, MIN_CONF)));
    let refresher = store_refresher(&store, &base);
    let mut fingerprints = Vec::new();
    for round in 0..rounds {
        let delta = delta_for(round, 14);
        refresher.refresh_once(&mut db, delta, &cell).unwrap();
        fingerprints.push(render_corpus(&cell.load(), 99));
    }
    (fingerprints, db)
}

#[test]
fn killed_and_restarted_refresher_serves_byte_identical_to_uninterrupted() {
    let ref_dir = TempDir::new("warm_ref");
    let (reference, reference_db) = reference_run(ref_dir.path(), 4);

    // interrupted run: two cycles, then the process "dies" (everything
    // in memory is dropped; only the store survives)
    let tmp = TempDir::new("warm_kill");
    let base = base_db();
    {
        let store = Arc::new(SnapshotStore::open(tmp.path(), 8).unwrap());
        let mut db = base.clone();
        let result0 = ClassicalApriori::default().mine(&db, &cfg());
        let cell = SnapshotCell::new(Arc::new(RuleIndex::build(&result0, MIN_CONF)));
        let refresher = store_refresher(&store, &base);
        for round in 0..2 {
            refresher
                .refresh_once(&mut db, delta_for(round, 14), &cell)
                .unwrap();
        }
    }

    // restart: pristine base + store only
    let store = Arc::new(SnapshotStore::open(tmp.path(), 8).unwrap());
    let mut db = base.clone();
    let resumed = resume_serving(&store, &mut db, BaseRef::of(&base))
        .unwrap()
        .expect("two generations persisted");
    assert_eq!(resumed.generation, 2);
    assert_eq!(resumed.min_confidence, MIN_CONF);
    // the recovered snapshot already serves byte-identically to the
    // uninterrupted run's generation 2...
    assert_eq!(render_corpus(&resumed.cell.load(), 99), reference[1]);
    // ...and the recovered border state is exact over the recovered db
    let state = resumed.state.clone().expect("incremental state persisted");
    verify_invariant(&state, &db).unwrap();

    // resume refreshing where the killed process left off
    let refresher = store_refresher(&store, &base);
    refresher.seed_state(state);
    for round in 2..4 {
        let (_, stats) = refresher
            .refresh_once(&mut db, delta_for(round, 14), &resumed.cell)
            .unwrap();
        // the whole point of persistence: the resumed refresher stays on
        // the delta path — no capture-mine of the base database
        assert!(
            stats.incremental.is_some() && !stats.fell_back,
            "round {round} re-mined after a warm restart"
        );
    }
    assert_eq!(resumed.cell.generation(), 4);
    assert_eq!(db.transactions, reference_db.transactions);
    assert_eq!(render_corpus(&resumed.cell.load(), 99), reference[3]);
    // end-to-end oracle: the served snapshot equals a from-scratch mine
    let full = ClassicalApriori::default().mine(&db, &cfg());
    let rebuilt = RuleIndex::build(&full, MIN_CONF);
    assert_eq!(render_corpus(&resumed.cell.load(), 99), render_corpus(&rebuilt, 99));
}

#[test]
fn corrupted_newest_generation_degrades_and_still_converges() {
    let ref_dir = TempDir::new("corrupt_ref");
    let (reference, reference_db) = reference_run(ref_dir.path(), 3);

    let tmp = TempDir::new("corrupt_resume");
    let base = base_db();
    {
        let store = Arc::new(SnapshotStore::open(tmp.path(), 8).unwrap());
        let mut db = base.clone();
        let result0 = ClassicalApriori::default().mine(&db, &cfg());
        let cell = SnapshotCell::new(Arc::new(RuleIndex::build(&result0, MIN_CONF)));
        let refresher = store_refresher(&store, &base);
        for round in 0..2 {
            refresher
                .refresh_once(&mut db, delta_for(round, 14), &cell)
                .unwrap();
        }
    }
    // scribble over generation 2 — recovery must land on generation 1
    let gen2 = tmp.path().join("gen-00000002.snap");
    let mut bytes = std::fs::read(&gen2).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0xFF;
    std::fs::write(&gen2, &bytes).unwrap();

    let store = Arc::new(SnapshotStore::open(tmp.path(), 8).unwrap());
    let mut db = base.clone();
    let resumed =
        resume_serving(&store, &mut db, BaseRef::of(&base)).unwrap().expect("gen 1 intact");
    assert_eq!(resumed.generation, 1);
    assert_eq!(render_corpus(&resumed.cell.load(), 99), reference[0]);

    // replaying the lost delta plus the next one converges with the
    // uninterrupted run (same deltas ⇒ same generations)
    let refresher = store_refresher(&store, &base);
    refresher.seed_state(resumed.state.clone().expect("state persisted"));
    for round in 1..3 {
        refresher
            .refresh_once(&mut db, delta_for(round, 14), &resumed.cell)
            .unwrap();
    }
    assert_eq!(resumed.cell.generation(), 3);
    assert_eq!(db.transactions, reference_db.transactions);
    assert_eq!(render_corpus(&resumed.cell.load(), 99), reference[2]);
}

// ----------------------------------------------- fabric store regression

/// Fingerprint a sharded cut against a fixed basket corpus.
fn render_cut(cut: &ShardedRuleIndex, seed: u64) -> Vec<String> {
    let mut rng = Xoshiro256::seed_from_u64(seed);
    (0..30)
        .map(|_| {
            let len = rng.range_usize(1, 5);
            let basket: Vec<u32> = (0..len).map(|_| rng.gen_range(14) as u32).collect();
            render_lines(&cut.recommend(&basket, 5))
        })
        .collect()
}

fn corrupt_mid_byte(path: &std::path::Path) {
    let mut bytes = std::fs::read(path).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0xFF;
    std::fs::write(path, &bytes).unwrap();
}

#[test]
fn torn_fabric_manifest_degrades_to_the_last_complete_cut_never_mixed() {
    // planted {0,1,2} block guarantees rules exist; the extra {0,1,3}
    // delta in generation 2 shifts supports and |D| (so lifts), making
    // the two cuts distinguishable by render
    let mut db1 = base_db();
    db1.append((0..12).map(|_| Transaction::new([0u32, 1, 2])).collect::<Vec<_>>());
    let mut db2 = db1.clone();
    db2.append((0..12).map(|_| Transaction::new([0u32, 1, 3])).collect::<Vec<_>>());
    let result1 = ClassicalApriori::default().mine(&db1, &cfg());
    let result2 = ClassicalApriori::default().mine(&db2, &cfg());
    let cut1 = ShardedRuleIndex::build(&result1, MIN_CONF, 3);
    let cut2 = ShardedRuleIndex::build(&result2, MIN_CONF, 3);

    // the cuts must be distinguishable for "never mixed" to mean anything
    assert_ne!(render_cut(&cut1, 0xFA_B), render_cut(&cut2, 0xFA_B));

    let tmp = TempDir::new("fabric_torn");
    let store = FabricStore::open(tmp.path(), 3, 2).unwrap().with_retain(8);
    store.publish(&cut1, 1).unwrap();
    store.publish(&cut2, 2).unwrap();

    // tear the manifest mid-byte: it must read as absent (typed codec
    // rejection), and recovery falls back to scanning shard files —
    // generation 2 is still complete, so it loads whole
    corrupt_mid_byte(&tmp.path().join("FABRIC"));
    assert!(store.load_manifest().is_none(), "a torn manifest must not decode");
    let (m, cut) = store.load_cut().expect("generation 2 is complete on disk");
    assert_eq!(m.generation, 2);
    assert_eq!(render_cut(&cut, 0xFA_B), render_cut(&cut2, 0xFA_B));

    // one corrupt replica of a gen-2 shard changes nothing: the loader
    // skips to the intact replica
    corrupt_mid_byte(&tmp.path().join("shard-1-r0-gen-2.shard"));
    let (m, cut) = store.load_cut().expect("replica 1 of shard 1 is intact");
    assert_eq!(m.generation, 2);
    assert_eq!(render_cut(&cut, 0xFA_B), render_cut(&cut2, 0xFA_B));

    // ...but once *every* replica of that shard is gone, generation 2 is
    // incomplete and the whole cut degrades to generation 1 — shards 0
    // and 2 of generation 2 are perfectly intact and must NOT be mixed
    // into the older cut
    corrupt_mid_byte(&tmp.path().join("shard-1-r1-gen-2.shard"));
    let (m, cut) = store.load_cut().expect("generation 1 is complete on disk");
    assert_eq!(m.generation, 1);
    assert_eq!(render_cut(&cut, 0xFA_B), render_cut(&cut1, 0xFA_B));
}

#[test]
fn full_mode_warm_restart_resumes_serving_without_state() {
    // Persistence is not incremental-only: a full-mode refresher's
    // generations warm-restart too (state is simply absent, and the next
    // refresh re-mines the union as full mode always does).
    let tmp = TempDir::new("full_mode");
    let base = base_db();
    {
        let store = Arc::new(SnapshotStore::open(tmp.path(), 4).unwrap());
        let mut db = base.clone();
        let result0 = ClassicalApriori::default().mine(&db, &cfg());
        let cell = SnapshotCell::new(Arc::new(RuleIndex::build(&result0, MIN_CONF)));
        let refresher = Refresher::new(driver(), MIN_CONF).with_store(
            Arc::clone(&store),
            BaseRef::of(&base),
            base.len(),
        );
        refresher
            .refresh_once(&mut db, delta_for(0, 14), &cell)
            .unwrap();
    }
    let store = SnapshotStore::open(tmp.path(), 4).unwrap();
    let mut db = base.clone();
    let resumed = resume_serving(&store, &mut db, BaseRef::of(&base)).unwrap().expect("warm");
    assert_eq!(resumed.generation, 1);
    assert!(resumed.state.is_none());
    let full = ClassicalApriori::default().mine(&db, &cfg());
    assert_eq!(resumed.result.frequent, full.frequent);
    assert_eq!(
        render_corpus(&resumed.cell.load(), 3),
        render_corpus(&RuleIndex::build(&full, MIN_CONF), 3)
    );
}

//! Differential invariants of the incremental mining subsystem: after
//! every delta in a randomized sequence, the maintained [`MinedState`]
//! must be byte-identical to a from-scratch full re-mine of the union
//! database — same frequent itemsets, same exact supports, same derived
//! rules — and the negative-border invariant must hold, through both
//! border promotions and frequent-itemset demotions (a rising absolute
//! threshold under noise deltas demotes; pattern-heavy deltas promote).

use std::cell::RefCell;
use std::sync::Arc;

use mr_apriori::data::Transaction;
use mr_apriori::incremental::verify_invariant;
use mr_apriori::prelude::*;
use mr_apriori::util::proptest::check;
use mr_apriori::util::rng::Xoshiro256;

const MIN_SUPPORT: f64 = 0.2;
const MIN_CONFIDENCE: f64 = 0.5;

fn mine_cfg() -> AprioriConfig {
    AprioriConfig { min_support: MIN_SUPPORT, max_k: 0 }
}

fn driver() -> MrApriori {
    MrApriori::new(ClusterConfig::standalone(), mine_cfg()).with_split_tx(16)
}

/// Small skewed base: low item ids are much more common, so the base
/// generation has real frequent structure to promote against.
fn base_db() -> TransactionDb {
    let mut rng = Xoshiro256::seed_from_u64(0xBA5E_D0);
    let txs: Vec<Transaction> = (0..40)
        .map(|_| {
            let len = rng.range_usize(2, 5);
            Transaction::new((0..len).map(|_| {
                let a = rng.gen_range(10) as u32;
                let b = rng.gen_range(10) as u32;
                a.min(b) // skew toward low ids
            }))
        })
        .collect();
    TransactionDb::new(txs)
}

/// One randomized delta batch: pattern-heavy (promotes), uniform noise
/// over a slightly larger universe (raises the threshold -> demotes,
/// and can introduce new item ids), or near-empty.
fn gen_delta(rng: &mut Xoshiro256) -> Vec<Transaction> {
    match rng.gen_range(3) {
        0 => {
            let pattern: Vec<u32> = {
                let len = rng.range_usize(2, 4);
                (0..len).map(|_| rng.gen_range(4) as u32).collect()
            };
            (0..rng.range_usize(2, 7))
                .map(|_| {
                    let mut items = pattern.clone();
                    items.push(rng.gen_range(10) as u32);
                    Transaction::new(items)
                })
                .collect()
        }
        1 => (0..rng.range_usize(2, 9))
            .map(|_| {
                let len = rng.range_usize(1, 5);
                Transaction::new((0..len).map(|_| rng.gen_range(12) as u32))
            })
            .collect(),
        _ => (0..rng.range_usize(0, 2))
            .map(|_| Transaction::new([rng.gen_range(12) as u32]))
            .collect(),
    }
}

#[test]
fn prop_incremental_state_equals_full_remine_after_every_delta() {
    let driver = driver();
    let base = base_db();
    // churn accounting across all cases: the sweep must exercise both
    // sides of the border, and at least some deltas must take the
    // incremental (non-fallback) path for the property to mean anything
    let promoted = RefCell::new(0usize);
    let demoted = RefCell::new(0usize);
    let applied = RefCell::new(0usize);
    check(
        "incremental MinedState == full re-mine across delta sequences",
        0x1CF0,
        20,
        |rng| (0..rng.range_usize(1, 5)).map(|_| gen_delta(rng)).collect::<Vec<_>>(),
        |batches| {
            let mut db = base.clone();
            let (_, mut state) =
                MinedState::capture(&driver, &db).map_err(|e| e.to_string())?;
            for (gen, delta) in batches.iter().enumerate() {
                db.append(delta.clone());
                let guard = IncrementalConfig { enabled: true, ..Default::default() };
                match state
                    .apply_delta(&driver, &db, delta, &guard)
                    .map_err(|e| e.to_string())?
                {
                    DeltaApply::Applied(stats) => {
                        *promoted.borrow_mut() += stats.promoted;
                        *demoted.borrow_mut() += stats.demoted;
                        *applied.borrow_mut() += 1;
                    }
                    DeltaApply::FrontierBlowup { .. } => {
                        let (_, fresh) =
                            MinedState::capture(&driver, &db).map_err(|e| e.to_string())?;
                        state = fresh;
                    }
                }
                let full = ClassicalApriori::default().mine(&db, &mine_cfg());
                let incremental = state.to_result();
                if incremental.frequent != full.frequent {
                    return Err(format!(
                        "generation {gen}: {} incremental vs {} full itemsets (or supports \
                         differ)",
                        incremental.frequent.len(),
                        full.frequent.len()
                    ));
                }
                let inc_rules = generate_rules(&incremental, MIN_CONFIDENCE);
                let full_rules = generate_rules(&full, MIN_CONFIDENCE);
                if render_lines(&inc_rules) != render_lines(&full_rules) {
                    return Err(format!("generation {gen}: derived rules differ"));
                }
                verify_invariant(&state, &db)
                    .map_err(|e| format!("generation {gen}: border invariant: {e}"))?;
            }
            Ok(())
        },
    );
    assert!(*applied.borrow() > 0, "no delta took the incremental path");
    assert!(*promoted.borrow() > 0, "sweep never promoted a border itemset");
    assert!(*demoted.borrow() > 0, "sweep never demoted a frequent itemset");
}

#[test]
fn incremental_refresher_serves_byte_identical_answers_across_generations() {
    // The serving-layer integration: an incremental-mode Refresher must
    // publish snapshots whose answers are byte-identical to the direct
    // generate_rules path over a from-scratch mine — the same check the
    // full-mode serving tests pin.
    let mut db = QuestGenerator::new(QuestParams::goswami_2k()).generate();
    let cfg = AprioriConfig { min_support: 0.05, max_k: 3 };
    let result0 = ClassicalApriori::default().mine(&db, &cfg);
    let cell = Arc::new(SnapshotCell::new(Arc::new(RuleIndex::build(&result0, 0.4))));

    let driver = MrApriori::new(ClusterConfig::fhssc(2), cfg.clone()).with_split_tx(200);
    let refresher = Refresher::new(driver, 0.4).with_incremental(IncrementalConfig {
        enabled: true,
        ..Default::default()
    });
    assert_eq!(refresher.mode(), RefreshMode::Incremental);

    let mut saw_delta_applied = false;
    for round in 0..3u64 {
        let delta = synth_delta(120, db.n_items, 40 + round);
        let (report, stats) = refresher.refresh_once(&mut db, delta, &cell).unwrap();
        if let Some(inc) = &stats.incremental {
            saw_delta_applied = true;
            // the blowup guard bounds full-db recounts on every applied
            // cycle: at most max_frontier_blowup (1.0) x the tracked set
            assert!(
                inc.frontier_recounted <= inc.tracked.max(1),
                "frontier {} vs {} tracked",
                inc.frontier_recounted,
                inc.tracked
            );
        }
        let full = ClassicalApriori::default().mine(&db, &cfg);
        assert_eq!(report.result.frequent, full.frequent, "round {round}");
        let rules = generate_rules(&full, 0.4);
        let idx = cell.load();
        let mut rng = Xoshiro256::seed_from_u64(7 + round);
        for _ in 0..40 {
            let len = rng.range_usize(1, 5);
            let basket: Vec<u32> = (0..len).map(|_| rng.gen_range(120) as u32).collect();
            assert_eq!(
                render_lines(&idx.recommend(&basket, 5)),
                render_lines(&reference_recommend(&rules, &basket, 5)),
                "round {round}, basket {basket:?}"
            );
        }
        // state stays exact after each generation (oracle-checked)
        verify_invariant(&refresher.state().expect("seeded"), &db).unwrap();
    }
    assert!(saw_delta_applied, "at least one cycle must take the delta path");
    assert_eq!(cell.generation(), 3);
}

#[test]
fn failed_incremental_cycle_rolls_the_database_back() {
    // Same rollback contract the full mode has: an Err leaves the db (and
    // the carried state) describing the still-served snapshot. Force the
    // error with a poisoned cluster: zero reducers make every job fail.
    let mut db = base_db();
    let cfg = mine_cfg();
    let bad_driver = MrApriori::new(ClusterConfig::standalone(), cfg.clone())
        .with_job(JobConfig { n_reducers: 0, ..Default::default() })
        .with_split_tx(16);
    let result0 = ClassicalApriori::default().mine(&db, &cfg);
    let cell = SnapshotCell::new(Arc::new(RuleIndex::build(&result0, 0.4)));
    let refresher = Refresher::new(bad_driver, 0.4).with_incremental(IncrementalConfig {
        enabled: true,
        ..Default::default()
    });
    let before_len = db.len();
    let delta = synth_delta(10, db.n_items, 1);
    assert!(refresher.refresh_once(&mut db, delta, &cell).is_err());
    assert_eq!(db.len(), before_len);
    assert!(refresher.state().is_none(), "failed seed must not install state");
    assert_eq!(cell.generation(), 0);
}

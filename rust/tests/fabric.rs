//! Serving-fabric invariants, end to end:
//!
//! * **differential property** — for random databases, baskets, shard
//!   counts, and killed-node sets (every shard keeps >= 1 live replica),
//!   the routed scatter-gather answer renders byte-identical to the
//!   single-index `reference_recommend` oracle;
//! * **atomic generation flips** — while a flipper thread runs the
//!   two-phase publish (prepare shard replicas, flip the manifest, swap
//!   the in-memory cut), every concurrent answer belongs to exactly one
//!   generation's oracle — never a mixed cut;
//! * **failover** — killing a replica's node changes no answer and does
//!   not block the refresher from publishing the next generation; losing
//!   *every* replica of a shard is a typed error, and recovery restores
//!   service.

use std::sync::Arc;

use mr_apriori::data::Transaction;
use mr_apriori::prelude::*;
use mr_apriori::util::proptest::check;
use mr_apriori::util::rng::Xoshiro256;
use mr_apriori::util::tempdir::TempDir;

const MIN_SUPPORT: f64 = 0.2;
const MIN_CONF: f64 = 0.3;
const REPLICAS: usize = 2;

fn cfg() -> AprioriConfig {
    AprioriConfig { min_support: MIN_SUPPORT, max_k: 0 }
}

fn db_of(spec: &[Vec<u32>]) -> TransactionDb {
    TransactionDb::new(
        spec.iter()
            .map(|t| Transaction::new(t.iter().copied()))
            .collect(),
    )
}

/// Small skewed base: low item ids dominate, plus a planted {0,1,2}
/// block so frequent pairs/triples (and thus rules) exist at MIN_CONF.
fn base_db() -> TransactionDb {
    let mut rng = Xoshiro256::seed_from_u64(0xFAB_BA5E);
    let mut txs: Vec<Transaction> = (0..40)
        .map(|_| {
            let len = rng.range_usize(2, 5);
            Transaction::new((0..len).map(|_| {
                let a = rng.gen_range(10) as u32;
                let b = rng.gen_range(10) as u32;
                a.min(b)
            }))
        })
        .collect();
    txs.extend((0..12).map(|_| Transaction::new([0u32, 1, 2])));
    TransactionDb::new(txs)
}

/// Estimated wire size per shard, as the router models replies.
fn wire_bytes(cut: &ShardedRuleIndex) -> Vec<u64> {
    cut.shard_rule_counts().iter().map(|&n| 16 + 56 * n).collect()
}

fn router_over(cut: ShardedRuleIndex, cluster: &ClusterConfig) -> QueryRouter {
    let bytes = wire_bytes(&cut);
    let placement = FabricPlacement::place(cluster, REPLICAS, &bytes).unwrap();
    QueryRouter::new(
        Arc::new(SnapshotCell::new(Arc::new(cut))),
        placement,
        cluster,
        5,
    )
}

// ------------------------------------------------ differential property

struct Case {
    spec: Vec<Vec<u32>>,
    baskets: Vec<Vec<u32>>,
    n_shards: usize,
    top_k: usize,
    /// Nodes to try killing, in order; a kill that would leave some
    /// shard with zero live replicas is revived (the router's documented
    /// serving limit — tested separately as a typed error).
    kill_order: Vec<usize>,
}

fn gen_case(rng: &mut Xoshiro256) -> Case {
    let spec = (0..rng.range_usize(4, 30))
        .map(|_| {
            (0..rng.range_usize(1, 6))
                .map(|_| rng.gen_range(8) as u32)
                .collect()
        })
        .collect();
    let baskets = (0..8)
        .map(|_| {
            // lengths up to 19 cross the indexed-basket bound, so the
            // oversized-scan path is exercised through the fabric too
            (0..rng.range_usize(1, 20))
                .map(|_| rng.gen_range(10) as u32)
                .collect()
        })
        .collect();
    let mut kill_order: Vec<usize> = (0..4).collect();
    rng.shuffle(&mut kill_order);
    kill_order.truncate(rng.range_usize(0, 4));
    Case {
        spec,
        baskets,
        n_shards: rng.range_usize(1, 7),
        top_k: rng.range_usize(1, 8),
        kill_order,
    }
}

#[test]
fn prop_routed_answers_match_the_single_index_oracle_under_replica_failures() {
    check(
        "scatter-gather == reference_recommend under random kills",
        0xFAB_D1FF,
        120,
        gen_case,
        |case| {
            let result = ClassicalApriori::default().mine(&db_of(&case.spec), &cfg());
            let rules = generate_rules(&result, MIN_CONF);
            let cut = ShardedRuleIndex::build(&result, MIN_CONF, case.n_shards);
            let cluster = ClusterConfig::fhssc(4);
            let router = router_over(cut, &cluster);
            for &n in &case.kill_order {
                router.set_node_down(n);
                if (0..case.n_shards).any(|s| router.live_replicas(s).is_empty()) {
                    router.set_node_up(n);
                }
            }
            for basket in &case.baskets {
                let routed = router.route(basket, case.top_k).map_err(|e| e.to_string())?;
                let want = render_lines(&reference_recommend(&rules, basket, case.top_k));
                if render_lines(&routed.recommendations) != want {
                    return Err(format!(
                        "basket {basket:?} (shards {}, top_k {}): fabric answer diverged",
                        case.n_shards, case.top_k
                    ));
                }
            }
            Ok(())
        },
    );
}

// --------------------------------------------- concurrent generation flip

#[test]
fn answers_stay_generation_consistent_across_concurrent_two_phase_flips() {
    let base = base_db();
    let result_a = ClassicalApriori::default().mine(&base, &cfg());
    // a delta heavy in one pair shifts supports enough to change rules
    let mut union = base.clone();
    union.append(
        (0..12)
            .map(|i| Transaction::new([0u32, 1, (i % 3) as u32 + 2]))
            .collect::<Vec<_>>(),
    );
    let result_b = ClassicalApriori::default().mine(&union, &cfg());
    let rules_a = generate_rules(&result_a, MIN_CONF);
    let rules_b = generate_rules(&result_b, MIN_CONF);

    let mut rng = Xoshiro256::seed_from_u64(0xF11B);
    // fixed baskets guaranteed to hit the planted rules (any non-empty
    // answer differs across the flip: |D| changes, so every lift does),
    // plus random ones
    let mut corpus: Vec<Vec<u32>> = vec![vec![0], vec![1], vec![0, 1]];
    corpus.extend(
        (0..13).map(|_| {
            (0..rng.range_usize(1, 5)).map(|_| rng.gen_range(10) as u32).collect::<Vec<u32>>()
        }),
    );
    let oracle = |rules: &[Rule]| -> Vec<String> {
        corpus
            .iter()
            .map(|b| render_lines(&reference_recommend(rules, b, 5)))
            .collect()
    };
    let oracle_a = oracle(&rules_a);
    let oracle_b = oracle(&rules_b);
    // a flip that changes nothing would make this test vacuous
    assert_ne!(oracle_a, oracle_b, "delta did not change any served answer");

    let cluster = ClusterConfig::fhssc(4);
    let router = Arc::new(router_over(
        ShardedRuleIndex::build(&result_a, MIN_CONF, 3),
        &cluster,
    ));
    let tmp = TempDir::new("fabric_flip");
    let store = FabricStore::open(tmp.path(), 3, REPLICAS).unwrap().with_retain(8);
    store.publish(&router.cut().load(), 0).unwrap();

    std::thread::scope(|scope| {
        for t in 0..3usize {
            let router = Arc::clone(&router);
            let (corpus, oracle_a, oracle_b) = (&corpus, &oracle_a, &oracle_b);
            scope.spawn(move || {
                for i in 0..400usize {
                    let at = (i + t * 7) % corpus.len();
                    let resp = router.route(&corpus[at], 5).unwrap();
                    // even generations hold cut A, odd ones cut B — any
                    // mixed-generation read breaks exactly one of these
                    let want = if resp.generation % 2 == 0 { oracle_a } else { oracle_b };
                    assert_eq!(
                        render_lines(&resp.recommendations),
                        want[at],
                        "generation {} served a mixed cut",
                        resp.generation
                    );
                }
            });
        }
        // the flipper: two-phase publish (prepare every shard replica,
        // flip the manifest), then swap the in-memory cut
        for g in 1..=6u64 {
            let result = if g % 2 == 0 { &result_a } else { &result_b };
            let next = Arc::new(ShardedRuleIndex::build(result, MIN_CONF, 3));
            store.publish(&next, g).unwrap();
            assert_eq!(router.cut().store(next), g);
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
    });

    // the store's committed cut is the final generation, intact
    let (m, loaded) = store.load_cut().unwrap();
    assert_eq!(m.generation, 6);
    let served: Vec<String> = corpus
        .iter()
        .map(|b| render_lines(&loaded.recommend(b, 5)))
        .collect();
    assert_eq!(served, oracle_a);
}

// ------------------------------------------------------------- failover

#[test]
fn killed_replica_fails_over_and_refresh_publishes_the_next_generation() {
    let base = base_db();
    let result0 = ClassicalApriori::default().mine(&base, &cfg());
    let rules0 = generate_rules(&result0, MIN_CONF);
    let cluster = ClusterConfig::fhssc(4);
    let router = router_over(ShardedRuleIndex::build(&result0, MIN_CONF, 4), &cluster);
    let tmp = TempDir::new("fabric_kill");
    let store = FabricStore::open(tmp.path(), 4, REPLICAS).unwrap().with_retain(8);
    store.publish(&router.cut().load(), 0).unwrap();

    let corpus: Vec<Vec<u32>> = (0..10).map(|i| vec![i as u32, (i + 1) as u32]).collect();

    // kill the primary of shard 0: every answer must still match
    let victim = router.placement().replicas_of(0)[0];
    router.set_node_down(victim);
    for basket in &corpus {
        let routed = router.route(basket, 5).unwrap();
        assert_eq!(
            render_lines(&routed.recommendations),
            render_lines(&reference_recommend(&rules0, basket, 5)),
        );
    }
    assert!(router.stats().failovers > 0, "the dead primary was never failed over");

    // the refresher publishes generation 1 around the dead node: the
    // two-phase cut commits with the surviving replicas only
    let mut union = base.clone();
    union.append(
        (0..12)
            .map(|i| Transaction::new([0u32, 1, (i % 3) as u32 + 2]))
            .collect::<Vec<_>>(),
    );
    let result1 = ClassicalApriori::default().mine(&union, &cfg());
    let rules1 = generate_rules(&result1, MIN_CONF);
    let next = Arc::new(ShardedRuleIndex::build(&result1, MIN_CONF, 4));
    let up = |s: usize, r: usize| !router.is_node_down(router.placement().replicas_of(s)[r]);
    let m = store.publish_partial(&next, 1, &up).unwrap();
    assert_eq!(m.generation, 1);
    assert_eq!(router.cut().store(Arc::clone(&next)), 1);

    // the committed cut reloads as generation 1 and serves its oracle
    let (m, loaded) = store.load_cut().unwrap();
    assert_eq!(m.generation, 1);
    for basket in &corpus {
        assert_eq!(
            render_lines(&loaded.recommend(basket, 5)),
            render_lines(&reference_recommend(&rules1, basket, 5)),
        );
        let routed = router.route(basket, 5).unwrap();
        assert_eq!(routed.generation, 1);
        assert_eq!(
            render_lines(&routed.recommendations),
            render_lines(&reference_recommend(&rules1, basket, 5)),
        );
    }

    // losing *every* replica of some shard is a typed error, not a
    // partial answer; recovery restores service
    for n in 0..cluster.n_nodes() {
        router.set_node_down(n);
    }
    assert!(matches!(
        router.route(&corpus[0], 5),
        Err(RouterError::ShardUnavailable { .. })
    ));
    for n in 0..cluster.n_nodes() {
        router.set_node_up(n);
    }
    assert!(router.route(&corpus[0], 5).is_ok());
}

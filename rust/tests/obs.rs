//! Observability integration: registry behaviour under concurrency, the
//! no-torn-cut snapshot contract, and the differential guarantee that
//! instrumenting a mine never changes what it computes.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use mr_apriori::metrics::Counter;
use mr_apriori::prelude::*;

#[test]
fn concurrent_registration_and_increments_are_lossless() {
    let reg = MetricsRegistry::new();
    std::thread::scope(|scope| {
        for t in 0..8u64 {
            let reg = &reg;
            scope.spawn(move || {
                // every thread races get-or-create on one shared key and
                // registers one private key of its own
                for _ in 0..1_000 {
                    reg.counter("shared.events").inc();
                }
                reg.counter(&format!("thread.{t}.events")).add(t);
            });
        }
    });
    let snap = reg.snapshot();
    assert_eq!(snap.counter("shared.events"), Some(8_000));
    for t in 0..8u64 {
        assert_eq!(snap.counter(&format!("thread.{t}.events")), Some(t));
    }
}

#[test]
fn snapshot_is_a_coherent_cut_under_concurrent_writers() {
    // The cut contract: the key set is captured under one lock (a key is
    // either absent or carries a value — never half-registered), and a
    // counter's value never goes backwards across successive cuts.
    let reg = MetricsRegistry::new();
    let stop = AtomicBool::new(false);
    std::thread::scope(|scope| {
        for _ in 0..4 {
            let (reg, stop) = (&reg, &stop);
            scope.spawn(move || {
                let mut i = 0u64;
                while !stop.load(Ordering::Relaxed) {
                    reg.counter("cut.events").inc();
                    reg.gauge(&format!("cut.gauge.{}", i % 16)).set(i as f64);
                    i += 1;
                }
            });
        }
        let mut last = 0;
        for _ in 0..200 {
            let snap = reg.snapshot();
            for (key, _) in &snap.entries {
                assert!(snap.get(key).is_some(), "torn cut: {key} has no value");
            }
            if let Some(v) = snap.counter("cut.events") {
                assert!(v >= last, "counter went backwards across cuts");
                last = v;
            }
        }
        stop.store(true, Ordering::Relaxed);
    });
}

#[test]
fn duplicate_registration_is_a_typed_error() {
    let reg = MetricsRegistry::new();
    let hits = Arc::new(Counter::new());
    reg.register_counter("engine.cache.hits", Arc::clone(&hits))
        .unwrap();
    let err = reg
        .register_counter("engine.cache.hits", hits)
        .unwrap_err();
    assert_eq!(
        err,
        RegistryError::DuplicateKey { key: "engine.cache.hits".into() }
    );
}

#[test]
fn sink_is_lossless_under_concurrent_producers_and_tees_the_flight_ring() {
    // Eight producers hammer one sink while the flight recorder tees
    // every record: the sink must keep all spans with unique ids, and
    // the ring must hold exactly its capacity after wrapping.
    let tmp = mr_apriori::util::tempdir::TempDir::new("obs_concurrent_tee");
    let sink = TraceSink::new();
    let flight = FlightRecorder::new(tmp.path(), 64);
    sink.attach_flight(Arc::clone(&flight));
    std::thread::scope(|scope| {
        for t in 0..8 {
            let root = TraceCtx::root(Arc::clone(&sink));
            scope.spawn(move || {
                for i in 0..250 {
                    let mut span = root.span("mr", format!("produce.{t}.{i}"));
                    span.add("i", i as f64);
                }
            });
        }
    });
    let events = sink.events();
    assert_eq!(events.len(), 2_000, "sink dropped spans under contention");
    let mut ids: Vec<u64> = events.iter().map(|e| e.span_id).collect();
    ids.sort_unstable();
    ids.dedup();
    assert_eq!(ids.len(), 2_000, "span ids collided under contention");
    assert_eq!(flight.observed(), 2_000, "flight tee missed records");
    assert_eq!(flight.recent().len(), 64, "ring must hold exactly capacity");
}

/// The tentpole differential check: a fully instrumented mine (tracing +
/// registry) is byte-identical to an uninstrumented one, and the trace it
/// leaves behind has the job → level → task tree with the Hadoop-style
/// counters on every map task.
#[test]
fn instrumented_mine_matches_uninstrumented_and_traces_the_job_tree() {
    let db = QuestGenerator::new(QuestParams::dense(400).with_seed(7)).generate();
    let cfg = AprioriConfig { min_support: 0.05, max_k: 3 };
    let plain = MrApriori::new(ClusterConfig::fhssc(3), cfg.clone()).with_split_tx(100);
    let want = plain.mine(&db).expect("plain mine");

    let sink = TraceSink::new();
    let registry = Arc::new(MetricsRegistry::new());
    let traced = MrApriori::new(ClusterConfig::fhssc(3), cfg)
        .with_split_tx(100)
        .with_trace(Some(TraceCtx::root(Arc::clone(&sink))))
        .with_registry(Arc::clone(&registry));
    let got = traced.mine(&db).expect("instrumented mine");

    assert_eq!(
        got.result.frequent, want.result.frequent,
        "instrumentation changed the mining output"
    );
    assert_eq!(got.result.levels.len(), want.result.levels.len());

    // trace tree: one mine root, levels under it, tasks under levels
    let events = sink.events();
    let mine: Vec<_> = events.iter().filter(|e| e.name == "mine").collect();
    assert_eq!(mine.len(), 1, "exactly one mine root span");
    let mine = mine[0];
    assert_eq!(mine.parent_id, 0);
    assert_eq!(mine.cat, "mine");
    let levels: Vec<_> = events
        .iter()
        .filter(|e| e.name.starts_with("level."))
        .collect();
    assert_eq!(levels.len(), got.result.levels.len());
    for l in &levels {
        assert_eq!(l.parent_id, mine.span_id, "{} not under mine", l.name);
        assert_eq!(l.trace_id, mine.trace_id);
    }
    let level_ids: Vec<u64> = levels.iter().map(|l| l.span_id).collect();
    let maps: Vec<_> = events
        .iter()
        .filter(|e| e.name.starts_with("map.task."))
        .collect();
    assert!(!maps.is_empty());
    for m in &maps {
        assert!(
            level_ids.contains(&m.parent_id),
            "{} not under a level span",
            m.name
        );
        for key in [
            "records_read",
            "map_output_records",
            "combine_output_records",
            "combiner_ratio",
            "shuffle_bytes",
        ] {
            assert!(
                m.args.iter().any(|(k, _)| k == key),
                "{} missing counter {key}",
                m.name
            );
        }
    }
    assert!(
        events.iter().any(|e| e.name.starts_with("reduce.task.")),
        "no reduce-task spans recorded"
    );

    // workload-statistics spans: one per level, parented to its level span,
    // carrying the four autotuner calibration inputs
    let profiles: Vec<_> = events
        .iter()
        .filter(|e| e.name.starts_with("profile.level."))
        .collect();
    assert_eq!(profiles.len(), got.result.levels.len());
    for p in &profiles {
        assert_eq!(p.cat, "profile");
        assert!(level_ids.contains(&p.parent_id), "{} not under a level", p.name);
        for key in ["density", "item_skew", "avg_basket_width", "candidate_fanout"] {
            assert!(
                p.args.iter().any(|(k, _)| k == key),
                "{} missing stat {key}",
                p.name
            );
        }
    }

    // the registry absorbed the per-job counters and the cache telemetry
    let snap = registry.snapshot();
    assert_eq!(snap.counter("mr.jobs"), Some(got.jobs.len() as u64));
    assert!(snap.gauge("mr.job.1.map_ms").is_some());
    assert!(snap.counter("mr.shuffle.records").unwrap_or(0) > 0);
    assert!(snap.counter("engine.cache.hits").is_some());
}

//! Property tests over the MapReduce substrate: for arbitrary datasets,
//! cluster shapes and job configurations, the engine must produce exactly
//! the single-machine ground truth, deterministically, with invariant
//! counters. Uses the in-tree property-test driver (`util::proptest`).

use std::collections::HashMap;

use mr_apriori::data::split::plan_splits;
use mr_apriori::data::{Transaction, TransactionDb};
use mr_apriori::dfs::Dfs;
use mr_apriori::mapreduce::app::ItemCount;
use mr_apriori::prelude::*;
use mr_apriori::util::proptest::check;
use mr_apriori::util::rng::Xoshiro256;

/// Random database generator for property tests.
fn gen_db(rng: &mut Xoshiro256) -> Vec<Vec<u32>> {
    let n_tx = rng.range_usize(0, 120);
    (0..n_tx)
        .map(|_| {
            let len = rng.range_usize(0, 12);
            (0..len).map(|_| rng.gen_range(40) as u32).collect()
        })
        .collect()
}

fn to_db(raw: &[Vec<u32>]) -> TransactionDb {
    TransactionDb::new(raw.iter().map(|r| Transaction::new(r.iter().copied())).collect())
}

fn ground_truth(db: &TransactionDb) -> Vec<(u32, u64)> {
    let mut counts: HashMap<u32, u64> = HashMap::new();
    for t in &db.transactions {
        for &i in &t.items {
            *counts.entry(i).or_insert(0) += 1;
        }
    }
    let mut v: Vec<_> = counts.into_iter().collect();
    v.sort_unstable();
    v
}

fn run_job(db: &TransactionDb, n_nodes: usize, split_tx: usize, cfg: &JobConfig) -> Vec<(u32, u64)> {
    let cluster = ClusterConfig::fhssc(n_nodes);
    let splits = plan_splits(db, split_tx);
    let mut dfs = Dfs::new(&cluster);
    let blocks = dfs.write_splits(&splits).unwrap();
    mr_apriori::mapreduce::JobRunner::new(&cluster, &dfs, &blocks)
        .run(&ItemCount, db, &splits, cfg)
        .unwrap()
        .0
}

#[test]
fn prop_output_equals_ground_truth_for_any_db_and_cluster() {
    check(
        "mr-output-equals-ground-truth",
        0xA11CE,
        30,
        |rng| {
            let raw = gen_db(rng);
            let n_nodes = rng.range_usize(1, 5);
            let split_tx = rng.range_usize(1, 40);
            let n_reducers = rng.range_usize(1, 6);
            (raw, vec![n_nodes as u64, split_tx as u64, n_reducers as u64])
        },
        |(raw, params)| {
            let db = to_db(raw);
            let cfg = JobConfig {
                n_reducers: params[2] as usize,
                ..Default::default()
            };
            let got = run_job(&db, params[0] as usize, params[1] as usize, &cfg);
            let want = ground_truth(&db);
            if got == want {
                Ok(())
            } else {
                Err(format!("got {got:?}, want {want:?}"))
            }
        },
    );
}

#[test]
fn prop_combiner_and_reducer_count_do_not_change_output() {
    check(
        "mr-combiner-reducers-invariant",
        0xBEE,
        20,
        |rng| gen_db(rng),
        |raw| {
            let db = to_db(raw);
            let base = run_job(
                &db,
                2,
                16,
                &JobConfig { n_reducers: 1, enable_combiner: false, ..Default::default() },
            );
            for n_reducers in [2usize, 5] {
                for combiner in [false, true] {
                    let cfg = JobConfig {
                        n_reducers,
                        enable_combiner: combiner,
                        ..Default::default()
                    };
                    let got = run_job(&db, 3, 10, &cfg);
                    if got != base {
                        return Err(format!(
                            "divergence at reducers={n_reducers} combiner={combiner}"
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_stats_invariants() {
    check(
        "mr-stats-invariants",
        0xCAFE,
        25,
        |rng| {
            let raw = gen_db(rng);
            let split_tx = rng.range_usize(1, 30);
            (raw, vec![split_tx as u64])
        },
        |(raw, params)| {
            let db = to_db(raw);
            let cluster = ClusterConfig::fhssc(3);
            let splits = plan_splits(&db, params[0] as usize);
            let mut dfs = Dfs::new(&cluster);
            let blocks = dfs.write_splits(&splits).unwrap();
            let (_, stats) = mr_apriori::mapreduce::JobRunner::new(&cluster, &dfs, &blocks)
                .run(&ItemCount, &db, &splits, &JobConfig::default())
                .unwrap();
            if stats.maps_total != splits.len() {
                return Err(format!(
                    "maps_total {} != splits {}",
                    stats.maps_total,
                    splits.len()
                ));
            }
            if stats.map_attempts < stats.maps_total {
                return Err("attempts < tasks".into());
            }
            let loc = stats.locality_fraction();
            if !(0.0..=1.0).contains(&loc) {
                return Err(format!("locality {loc} out of range"));
            }
            // replication 3 on 3 nodes => all local
            if !splits.is_empty() && loc != 1.0 {
                return Err(format!("expected all-local, got {loc}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_deterministic_across_repeated_runs() {
    check(
        "mr-determinism",
        0xD00D,
        10,
        |rng| gen_db(rng),
        |raw| {
            let db = to_db(raw);
            let cfg = JobConfig { n_reducers: 4, ..Default::default() };
            let a = run_job(&db, 3, 7, &cfg);
            let b = run_job(&db, 3, 7, &cfg);
            if a == b { Ok(()) } else { Err("non-deterministic output".into()) }
        },
    );
}

#[test]
fn prop_failure_injection_preserves_results_when_recoverable() {
    check(
        "mr-failure-recovery",
        0xFA11,
        15,
        |rng| {
            let raw = gen_db(rng);
            let seed = rng.next_u64();
            (raw, vec![seed])
        },
        |(raw, params)| {
            let db = to_db(raw);
            let clean = run_job(&db, 2, 10, &JobConfig::default());
            let cfg = JobConfig {
                failure: Some(mr_apriori::mapreduce::runner::FailureSpec {
                    map_fail_prob: 0.2,
                    reduce_fail_prob: 0.1,
                    seed: params[0],
                }),
                speculative: false,
                max_attempts: 12, // generous: recovery must happen
                ..Default::default()
            };
            let got = run_job(&db, 2, 10, &cfg);
            if got == clean {
                Ok(())
            } else {
                Err("failure-recovered run diverged".into())
            }
        },
    );
}

/// Tentpole invariant: the pipelined job DAG (optimistic look-ahead
/// candidates, overlapped reduce lanes, batched shared-scan counting) must
/// emit **byte-identical** frequent itemsets to the synchronous per-level
/// driver, for arbitrary workloads, presets and batch depths.
#[test]
fn prop_pipelined_driver_equals_synchronous_driver() {
    check(
        "mr-pipelined-equivalence",
        0xF1F0,
        10,
        |rng| {
            let raw = gen_db(rng);
            let min_sup_pct = rng.range_usize(3, 15) as u64;
            let n_nodes = rng.range_usize(1, 4) as u64;
            let split_tx = rng.range_usize(1, 40) as u64;
            (raw, vec![min_sup_pct, n_nodes, split_tx])
        },
        |(raw, params)| {
            let db = to_db(raw);
            let cfg = mr_apriori::apriori::AprioriConfig {
                min_support: params[0] as f64 / 100.0,
                max_k: 5,
            };
            let cluster = ClusterConfig::fhssc(params[1] as usize);
            let split_tx = params[2] as usize;
            let sync = MrApriori::new(cluster.clone(), cfg.clone())
                .with_split_tx(split_tx)
                .mine(&db)
                .map_err(|e| e.to_string())?;
            for batch_levels in [1usize, 2] {
                let piped = MrApriori::new(cluster.clone(), cfg.clone())
                    .with_split_tx(split_tx)
                    .with_pipeline(PipelineConfig {
                        enabled: true,
                        batch_levels,
                        ..Default::default()
                    })
                    .mine(&db)
                    .map_err(|e| e.to_string())?;
                if piped.result.frequent != sync.result.frequent {
                    return Err(format!(
                        "pipelined (batch_levels={batch_levels}) diverged: {} vs {} itemsets",
                        piped.result.frequent.len(),
                        sync.result.frequent.len()
                    ));
                }
            }
            Ok(())
        },
    );
}

/// Shuffle invariants under overlapping jobs: a successor job's map wave
/// running while the predecessor's reduce wave is in flight must not
/// change either job's shuffle volume, output, or counters.
#[test]
fn overlapping_jobs_preserve_shuffle_invariants() {
    let db = to_db(&{
        let mut rng = Xoshiro256::seed_from_u64(0x0E27);
        gen_db(&mut rng)
    });
    let cluster = ClusterConfig::fhssc(3);
    let splits = plan_splits(&db, 8);
    let mut dfs = Dfs::new(&cluster);
    let blocks = dfs.write_splits(&splits).unwrap();
    let runner = mr_apriori::mapreduce::JobRunner::new(&cluster, &dfs, &blocks);
    let cfg = JobConfig { n_reducers: 3, ..Default::default() };

    // Baseline: both jobs strictly sequential.
    let (seq_a, stats_seq_a) = runner.run(&ItemCount, &db, &splits, &cfg).unwrap();
    let (seq_b, stats_seq_b) = runner.run(&ItemCount, &db, &splits, &cfg).unwrap();
    assert_eq!(seq_a, seq_b);

    // Overlapped: B's map wave runs while A's reduce wave is in flight.
    let mo_a = runner.map_stage(&ItemCount, &db, &splits, &cfg).unwrap();
    let ((out_a, stats_a), (out_b, stats_b)) = std::thread::scope(|s| {
        let lane_a = s.spawn(|| runner.reduce_stage(&ItemCount, &db, &splits, mo_a, &cfg).unwrap());
        let mo_b = runner.map_stage(&ItemCount, &db, &splits, &cfg).unwrap();
        let b = runner.reduce_stage(&ItemCount, &db, &splits, mo_b, &cfg).unwrap();
        (lane_a.join().unwrap(), b)
    });
    assert_eq!(out_a, seq_a, "overlap changed job A's output");
    assert_eq!(out_b, seq_a, "overlap changed job B's output");
    assert_eq!(stats_a.shuffle_records, stats_seq_a.shuffle_records);
    assert_eq!(stats_b.shuffle_records, stats_seq_b.shuffle_records);
    assert_eq!(stats_a.maps_total, splits.len());
    assert_eq!(stats_b.maps_total, splits.len());
    assert_eq!(stats_a.output_records, out_a.len());
    assert_eq!(stats_b.output_records, out_b.len());
}

#[test]
fn prop_simulator_monotone_in_work() {
    use mr_apriori::mapreduce::{SimJobSpec, SimMapTask};
    check(
        "sim-monotone-work",
        0x51A1,
        40,
        |rng| {
            vec![
                rng.range_usize(1, 64) as u64,  // n maps
                rng.range_usize(1, 4) as u64,   // nodes
                (rng.gen_range(50) + 1) * 100_000, // work
            ]
        },
        |params| {
            let (n_maps, n_nodes, work) =
                (params[0] as usize, params[1] as usize, params[2] as f64);
            let mk = |w: f64| SimJobSpec {
                map_tasks: (0..n_maps)
                    .map(|i| SimMapTask {
                        bytes: 1_000_000,
                        work: w,
                        replicas: vec![i % n_nodes],
                        spilled: false,
                    })
                    .collect(),
                n_reducers: n_nodes,
                shuffle_bytes_per_map: 10_000,
                reduce_work: 1000.0,
                ..Default::default()
            };
            let sim = Simulator::new(ClusterConfig::fhssc(n_nodes));
            let lo = sim.run(&mk(work)).total_secs;
            let hi = sim.run(&mk(work * 2.0)).total_secs;
            if hi > lo {
                Ok(())
            } else {
                Err(format!("2x work not slower: {hi} vs {lo}"))
            }
        },
    );
}

//! Config-system integration and cross-cutting determinism: a checked-in
//! config file drives the same run twice to identical reports; presets map
//! to the paper's deployments; the simulator is bit-deterministic.

use std::path::PathBuf;

use mr_apriori::cluster::DeployMode;
use mr_apriori::coordinator;
use mr_apriori::prelude::*;

fn write_tmp(name: &str, text: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("mr_apriori_cfg_test");
    std::fs::create_dir_all(&dir).unwrap();
    let p = dir.join(name);
    std::fs::write(&p, text).unwrap();
    p
}

#[test]
fn config_file_drives_a_full_run() {
    let p = write_tmp(
        "run.toml",
        r#"
        preset = "fhssc"
        nodes = 3
        min_support = 0.05
        max_k = 2
        split_tx = 100
        n_reducers = 2
        transactions = 500
        seed = 11
        "#,
    );
    let cfg = ExperimentConfig::load(&p).unwrap();
    assert_eq!(cfg.cluster().mode, DeployMode::FullyDistributed);
    let db = QuestGenerator::new(QuestParams::t10_i4(cfg.transactions).with_seed(cfg.seed))
        .generate();
    let run = |cfg: &ExperimentConfig| {
        MrApriori::new(cfg.cluster(), cfg.apriori.clone())
            .with_job(cfg.job.clone())
            .with_split_tx(cfg.split_tx)
            .mine(&db)
            .unwrap()
    };
    let a = run(&cfg);
    let b = run(&cfg);
    assert_eq!(a.result.frequent, b.result.frequent);
    assert!(!a.result.frequent.is_empty());
    assert_eq!(a.profile.levels.len(), b.profile.levels.len());
}

#[test]
fn presets_map_to_paper_deployments() {
    for (text, mode, n) in [
        ("preset = \"standalone\"", DeployMode::Standalone, 1),
        ("preset = \"pseudo\"", DeployMode::PseudoDistributed, 1),
        ("preset = \"fhssc\"\nnodes = 5", DeployMode::FullyDistributed, 5),
        ("preset = \"fhdsc\"\nnodes = 7", DeployMode::FullyDistributed, 7),
    ] {
        let cfg = ExperimentConfig::parse(text).unwrap();
        let cluster = cfg.cluster();
        assert_eq!(cluster.mode, mode, "{text}");
        assert_eq!(cluster.n_nodes(), n, "{text}");
    }
}

#[test]
fn simulator_replay_is_bit_deterministic_across_processes_shapes() {
    let db = QuestGenerator::new(QuestParams::t10_i4(800)).generate();
    let cfg = AprioriConfig { min_support: 0.03, max_k: 2 };
    let report = MrApriori::new(ClusterConfig::fhssc(3), cfg)
        .with_split_tx(100)
        .mine(&db)
        .unwrap();
    let job = JobConfig::default();
    for cluster in [
        ClusterConfig::standalone(),
        ClusterConfig::fhssc(2),
        ClusterConfig::fhssc(8),
        ClusterConfig::fhdsc(5),
    ] {
        let a = coordinator::simulate(&cluster, &report.profile, 100, &job);
        let b = coordinator::simulate(&cluster, &report.profile, 100, &job);
        assert_eq!(a.total_secs.to_bits(), b.total_secs.to_bits());
        assert_eq!(a.map_secs.to_bits(), b.map_secs.to_bits());
        assert_eq!(a.shuffle_secs.to_bits(), b.shuffle_secs.to_bits());
    }
}

#[test]
fn dataset_io_roundtrip_preserves_mining_results() {
    let db = QuestGenerator::new(QuestParams::goswami_2k()).generate();
    let p = write_tmp("roundtrip.dat", "");
    mr_apriori::data::io::write_dat(&db, &p).unwrap();
    let back = mr_apriori::data::io::read_dat(&p).unwrap();
    let cfg = AprioriConfig { min_support: 0.05, max_k: 3 };
    let a = ClassicalApriori::default().mine(&db, &cfg);
    let b = ClassicalApriori::default().mine(&back, &cfg);
    assert_eq!(a.frequent, b.frequent);
    std::fs::remove_file(&p).ok();
}

#[test]
fn eta_model_consistent_with_simulator_across_sizes() {
    // The analytic heterogeneity model must upper-bound the simulated η
    // (it ignores pull-based straggler avoidance) while both stay > 1.
    let db = QuestGenerator::new(QuestParams::t10_i4(1_500)).generate();
    let cfg = AprioriConfig { min_support: 0.03, max_k: 2 };
    let report = MrApriori::new(ClusterConfig::fhssc(3), cfg)
        .with_split_tx(150)
        .mine(&db)
        .unwrap();
    let job = JobConfig::default();
    let model = EtaModel::default();
    for n in [2usize, 4, 8] {
        let hom = coordinator::simulate(&ClusterConfig::fhssc(n), &report.profile, 150, &job);
        let het = coordinator::simulate(&ClusterConfig::fhdsc(n), &report.profile, 150, &job);
        let measured = het.total_secs / hom.total_secs;
        let predicted = model.eta_predicted(n);
        assert!(measured > 1.0, "n={n}");
        assert!(
            predicted >= measured * 0.9,
            "n={n}: model {predicted} should not undercut measured {measured}"
        );
    }
}

//! Analytical performance models.
//!
//! * [`EtaModel`] — the paper's §4 efficiency statement
//!   `η = FHDSC / FHSSC`, with `FHDSC = FHSSC = ln N`. Taken literally the
//!   model says η ≡ 1; our reading (the only one consistent with fig 4,
//!   where FHDSC is *slower*) is that the *coordination overhead* of both
//!   configurations grows as ln N while the heterogeneity gap contributes
//!   the ratio. The bench overlays measured η against both readings.
//! * [`KernelRoofline`] — the L1 VMEM-footprint / MXU-utilization
//!   estimator DESIGN.md §Hardware-Adaptation commits to (interpret-mode
//!   pallas gives no hardware counters, so TPU efficiency is projected
//!   from tile shapes).

/// The η = FHDSC/FHSSC model of §4.
#[derive(Debug, Clone)]
pub struct EtaModel {
    /// Coefficient on the ln N coordination term (seconds).
    pub coordination_s: f64,
}

impl Default for EtaModel {
    fn default() -> Self {
        Self { coordination_s: 2.0 }
    }
}

impl EtaModel {
    /// The paper's literal claim: FHDSC = FHSSC = ln N ⇒ η(N) = 1.
    pub fn eta_paper_literal(_n: usize) -> f64 {
        1.0
    }

    /// Coordination overhead ~ ln N (the quantity the paper presumably
    /// means by "FHDSC = FHSSC = log_e N").
    pub fn coordination_overhead(&self, n: usize) -> f64 {
        self.coordination_s * (n.max(1) as f64).ln()
    }

    /// Predicted η from hardware heterogeneity: with work spread evenly
    /// over N nodes, the wave finishes with the slowest node, so
    /// η ≈ cpu_homogeneous / cpu_min(heterogeneous mix). Uses the fhdsc
    /// preset mix from `cluster::ClusterConfig::fhdsc`.
    pub fn eta_predicted(&self, n: usize) -> f64 {
        let het = crate::cluster::ClusterConfig::fhdsc(n);
        // Slot-weighted wave model: time ∝ 1 / Σ slots·cpu, gated by the
        // straggler; blend the two like the sim does (last-wave effect).
        let hom = crate::cluster::ClusterConfig::fhssc(n);
        let rate = |c: &crate::cluster::ClusterConfig| -> f64 {
            c.nodes.iter().map(|p| p.slots as f64 * p.cpu_factor).sum()
        };
        let throughput_ratio = rate(&hom) / rate(&het);
        let straggler_ratio = hom.min_cpu() / het.min_cpu();
        // Geometric blend: long jobs are throughput-bound, the tail is
        // straggler-bound.
        (throughput_ratio * straggler_ratio).sqrt()
    }

    /// Fit `a + b·ln N` to measured (n, seconds) pairs by least squares;
    /// returns (a, b) — used to check the sim's ln N coordination term is
    /// recoverable from measurements, the shape the paper asserts.
    pub fn fit_log(points: &[(usize, f64)]) -> (f64, f64) {
        assert!(points.len() >= 2);
        let n = points.len() as f64;
        let (mut sx, mut sy, mut sxx, mut sxy) = (0.0, 0.0, 0.0, 0.0);
        for &(x, y) in points {
            let lx = (x.max(1) as f64).ln();
            sx += lx;
            sy += y;
            sxx += lx * lx;
            sxy += lx * y;
        }
        let b = (n * sxy - sx * sy) / (n * sxx - sx * sx);
        let a = (sy - b * sx) / n;
        (a, b)
    }
}

/// L1 kernel roofline estimates from tile shapes (DESIGN.md §Perf).
#[derive(Debug, Clone)]
pub struct KernelRoofline {
    /// Transaction tile rows.
    pub tile_t: usize,
    /// Item width.
    pub i: usize,
    /// Candidate width.
    pub c: usize,
    /// Bytes per element (4 = f32 on CPU-PJRT; 2 = bf16 on real TPU).
    pub elem_bytes: usize,
}

impl KernelRoofline {
    /// VMEM bytes resident per grid step: candidate matrix + sizes row
    /// stay resident; the tx tile + mask are double-buffered; plus the
    /// (tile_t × c) matmul intermediate and the (1 × c) accumulator.
    pub fn vmem_bytes(&self) -> usize {
        let resident = self.c * self.i + self.c; // cand + sizes
        let streamed = 2 * (self.tile_t * self.i + self.tile_t); // dbl-buffered tx+mask
        let intermediate = self.tile_t * self.c + self.c;
        (resident + streamed + intermediate) * self.elem_bytes
    }

    /// FLOPs per grid step (the matmul dominates: 2·T·I·C).
    pub fn flops_per_step(&self) -> f64 {
        2.0 * self.tile_t as f64 * self.i as f64 * self.c as f64
    }

    /// HBM bytes moved per grid step (the streamed tx tile; candidates
    /// amortize to ~0 over the sweep).
    pub fn hbm_bytes_per_step(&self) -> f64 {
        (self.tile_t * (self.i + 1)) as f64 * self.elem_bytes as f64
    }

    /// Arithmetic intensity (FLOPs / HBM byte).
    pub fn arithmetic_intensity(&self) -> f64 {
        self.flops_per_step() / self.hbm_bytes_per_step()
    }

    /// Estimated MXU utilization on a TPUv4-like core (275 TFLOP/s bf16,
    /// 1.2 TB/s HBM): min(1, achievable/peak) under the roofline.
    pub fn mxu_utilization_estimate(&self) -> f64 {
        const PEAK_FLOPS: f64 = 275e12;
        const HBM_BPS: f64 = 1.2e12;
        let ai = self.arithmetic_intensity();
        let achievable = (ai * HBM_BPS).min(PEAK_FLOPS);
        // Tile-shape efficiency: MXU is 128×128; partial tiles waste lanes.
        let lane_eff = |d: usize| -> f64 {
            let rem = d % 128;
            if rem == 0 {
                1.0
            } else {
                d as f64 / (d as f64 + (128 - rem) as f64)
            }
        };
        (achievable / PEAK_FLOPS) * lane_eff(self.tile_t) * lane_eff(self.c)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eta_literal_is_unity() {
        for n in [1, 2, 8, 32] {
            assert_eq!(EtaModel::eta_paper_literal(n), 1.0);
        }
    }

    #[test]
    fn eta_predicted_exceeds_one_for_heterogeneous() {
        let m = EtaModel::default();
        for n in [2, 3, 5, 8, 16] {
            let eta = m.eta_predicted(n);
            assert!(eta > 1.0, "n={n}: η={eta} must exceed 1 (FHDSC slower)");
            assert!(eta < 10.0, "n={n}: η={eta} implausibly large");
        }
    }

    #[test]
    fn coordination_grows_logarithmically() {
        let m = EtaModel::default();
        let d1 = m.coordination_overhead(4) - m.coordination_overhead(2);
        let d2 = m.coordination_overhead(8) - m.coordination_overhead(4);
        assert!((d1 - d2).abs() < 1e-12, "equal ratios, equal increments");
        assert_eq!(m.coordination_overhead(1), 0.0);
    }

    #[test]
    fn fit_log_recovers_known_coefficients() {
        let pts: Vec<(usize, f64)> = [2usize, 3, 4, 6, 8, 12, 16]
            .iter()
            .map(|&n| (n, 5.0 + 3.0 * (n as f64).ln()))
            .collect();
        let (a, b) = EtaModel::fit_log(&pts);
        assert!((a - 5.0).abs() < 1e-9);
        assert!((b - 3.0).abs() < 1e-9);
    }

    #[test]
    fn roofline_medium_tile_fits_vmem() {
        // The medium artifact (t=1024 tiled at 256, i=256, c=256).
        let r = KernelRoofline { tile_t: 256, i: 256, c: 256, elem_bytes: 4 };
        assert!(
            r.vmem_bytes() < 8 * 1024 * 1024,
            "VMEM {} must stay under 8 MiB",
            r.vmem_bytes()
        );
        assert!(r.arithmetic_intensity() > 100.0, "matmul should be compute-bound");
        let util = r.mxu_utilization_estimate();
        assert!(util >= 0.5, "MXU estimate {util} below the DESIGN.md target");
        assert!(util <= 1.0);
    }

    #[test]
    fn roofline_small_tiles_waste_lanes() {
        let small = KernelRoofline { tile_t: 64, i: 64, c: 64, elem_bytes: 4 };
        let big = KernelRoofline { tile_t: 256, i: 256, c: 256, elem_bytes: 4 };
        assert!(small.mxu_utilization_estimate() < big.mxu_utilization_estimate());
    }
}

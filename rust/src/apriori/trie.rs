//! Candidate prefix trie — the hash tree's main competitor in the Apriori
//! literature (Bodon's trie-based Apriori). Candidates of one level are
//! stored edge-per-item; counting walks transaction items down shared
//! prefixes, so common prefixes are probed once per transaction instead of
//! once per candidate.

use std::collections::BTreeMap;

use crate::data::{ItemId, Transaction};

use super::Itemset;

#[derive(Default)]
struct TrieNode {
    children: BTreeMap<ItemId, TrieNode>,
    /// Candidate index if a candidate ends here.
    terminal: Option<usize>,
}

/// Prefix trie over one level's candidates.
pub struct CandidateTrie {
    root: TrieNode,
    k: usize,
    n_candidates: usize,
}

impl CandidateTrie {
    pub fn build(candidates: &[Itemset]) -> Self {
        let k = candidates.first().map(|c| c.len()).unwrap_or(0);
        assert!(
            candidates.iter().all(|c| c.len() == k),
            "trie requires uniform candidate length (engine::count_grouped handles mixing)"
        );
        let mut root = TrieNode::default();
        for (idx, cand) in candidates.iter().enumerate() {
            let mut node = &mut root;
            for &item in cand {
                node = node.children.entry(item).or_default();
            }
            debug_assert!(node.terminal.is_none(), "duplicate candidate {cand:?}");
            node.terminal = Some(idx);
        }
        Self { root, k, n_candidates: candidates.len() }
    }

    pub fn len(&self) -> usize {
        self.n_candidates
    }

    pub fn is_empty(&self) -> bool {
        self.n_candidates == 0
    }

    /// Increment `counts[i]` for every candidate `i` ⊆ `tx`.
    pub fn count_transaction(&self, tx: &Transaction, counts: &mut [u64]) {
        if self.k == 0 || tx.items.len() < self.k {
            return;
        }
        descend(&self.root, &tx.items, self.k, counts);
    }

    pub fn count_all(&self, txs: &[Transaction]) -> Vec<u64> {
        let mut counts = vec![0u64; self.n_candidates];
        for t in txs {
            self.count_transaction(t, &mut counts);
        }
        counts
    }
}

fn descend(node: &TrieNode, items: &[ItemId], remaining: usize, counts: &mut [u64]) {
    if remaining == 0 {
        if let Some(idx) = node.terminal {
            counts[idx] += 1;
        }
        return;
    }
    if items.len() < remaining {
        return; // not enough items left to complete a candidate
    }
    // Sorted invariant on both sides: children are BTreeMap-ordered and
    // transaction items ascend, so each child is matched at most once.
    let last_start = items.len() - remaining;
    for (i, &item) in items[..=last_start].iter().enumerate() {
        if let Some(child) = node.children.get(&item) {
            descend(child, &items[i + 1..], remaining - 1, counts);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apriori::hash_tree::HashTree;
    use crate::data::quest::{QuestGenerator, QuestParams};
    use crate::data::TransactionDb;
    use crate::util::rng::Xoshiro256;

    #[test]
    fn tiny_handchecked() {
        let db = TransactionDb::new(vec![
            Transaction::new([0u32, 1, 2]),
            Transaction::new([0u32, 2]),
            Transaction::new([1u32, 2]),
        ]);
        let cands: Vec<Itemset> = vec![vec![0, 1], vec![0, 2], vec![1, 2]];
        let trie = CandidateTrie::build(&cands);
        assert_eq!(trie.count_all(&db.transactions), vec![1, 2, 2]);
    }

    #[test]
    fn agrees_with_hash_tree_and_naive() {
        let db = QuestGenerator::new(QuestParams::dense(300)).generate();
        let mut rng = Xoshiro256::seed_from_u64(33);
        for k in [1usize, 2, 3, 5] {
            let mut cands: Vec<Itemset> = (0..250)
                .map(|_| {
                    let mut v: Vec<u32> = rng
                        .sample_distinct(db.n_items, k)
                        .into_iter()
                        .map(|x| x as u32)
                        .collect();
                    v.sort_unstable();
                    v
                })
                .collect();
            cands.sort();
            cands.dedup();
            let trie = CandidateTrie::build(&cands);
            let tree = HashTree::build(&cands);
            let naive: Vec<u64> = cands.iter().map(|c| db.support(c) as u64).collect();
            assert_eq!(trie.count_all(&db.transactions), naive, "trie k={k}");
            assert_eq!(tree.count_all(&db.transactions), naive, "tree k={k}");
        }
    }

    #[test]
    fn empty_and_short() {
        let trie = CandidateTrie::build(&[]);
        assert!(trie.is_empty());
        assert!(trie.count_all(&[Transaction::new([1u32])]).is_empty());

        let trie = CandidateTrie::build(&[vec![3, 4, 5]]);
        let mut counts = vec![0u64];
        trie.count_transaction(&Transaction::new([3u32, 4]), &mut counts);
        assert_eq!(counts[0], 0);
    }

    #[test]
    fn k1_counts_items() {
        let cands: Vec<Itemset> = vec![vec![0], vec![2]];
        let trie = CandidateTrie::build(&cands);
        let txs = [
            Transaction::new([0u32, 1]),
            Transaction::new([2u32]),
            Transaction::new([0u32, 2]),
        ];
        assert_eq!(trie.count_all(&txs), vec![2, 2]);
    }
}

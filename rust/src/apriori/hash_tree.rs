//! The hash tree — Agrawal & Srikant's candidate-matching structure.
//!
//! Counting is Apriori's hot loop: for each transaction, find every
//! candidate k-itemset it contains. The hash tree prunes that search:
//! interior nodes hash the next item of the candidate; leaves hold small
//! candidate buckets checked exhaustively. `count_all` walks the tree with
//! the classic "pick each remaining item, recurse" traversal, touching
//! only subtrees reachable from the transaction's items.
//!
//! Because different transaction items can hash to the same child, one
//! transaction can reach the same leaf along several paths; the leaf check
//! is path-independent (`contains_all` against the whole transaction), so
//! every leaf carries an id and a per-transaction **visit stamp** dedupes
//! arrivals — the same trick the original A&S implementation used.

use crate::data::{ItemId, Transaction};

use super::Itemset;

const FANOUT: usize = 8;
const LEAF_CAP: usize = 16;

enum Node {
    Interior(Vec<Option<Box<Node>>>),
    /// (leaf id, [(candidate index, itemset)])
    Leaf(usize, Vec<(usize, Itemset)>),
}

/// Hash tree over one level's candidates (all the same length `k`).
pub struct HashTree {
    root: Node,
    k: usize,
    n_candidates: usize,
    n_leaves: usize,
}

/// Reusable per-counting-pass scratch (leaf visit stamps).
pub struct Workspace {
    stamps: Vec<u32>,
    tick: u32,
}

impl Workspace {
    fn new(n_leaves: usize) -> Self {
        Self { stamps: vec![0; n_leaves], tick: 0 }
    }
}

fn hash_item(item: ItemId) -> usize {
    (item as usize) % FANOUT
}

impl HashTree {
    /// Build from the level's candidate list (indices into that list are
    /// the counter slots the counting pass increments).
    pub fn build(candidates: &[Itemset]) -> Self {
        let k = candidates.first().map(|c| c.len()).unwrap_or(0);
        assert!(
            candidates.iter().all(|c| c.len() == k),
            "hash tree requires uniform candidate length (engine::count_grouped handles mixing)"
        );
        let mut tree = Self {
            root: Node::Leaf(0, Vec::new()),
            k,
            n_candidates: candidates.len(),
            n_leaves: 1,
        };
        for (idx, cand) in candidates.iter().enumerate() {
            let k = tree.k;
            let mut next_leaf = tree.n_leaves;
            insert(&mut tree.root, idx, cand, 0, k, &mut next_leaf);
            tree.n_leaves = next_leaf;
        }
        tree
    }

    pub fn len(&self) -> usize {
        self.n_candidates
    }

    pub fn is_empty(&self) -> bool {
        self.n_candidates == 0
    }

    /// Fresh workspace sized for this tree.
    pub fn workspace(&self) -> Workspace {
        Workspace::new(self.n_leaves)
    }

    /// Increment `counts[i]` for every candidate `i` contained in `tx`.
    pub fn count_transaction(&self, tx: &Transaction, counts: &mut [u64], ws: &mut Workspace) {
        if self.k == 0 || tx.items.len() < self.k {
            return;
        }
        ws.tick = ws.tick.wrapping_add(1);
        if ws.tick == 0 {
            // stamp wrap: reset (once per 2^32 transactions)
            ws.stamps.iter_mut().for_each(|s| *s = 0);
            ws.tick = 1;
        }
        visit(&self.root, &tx.items, 0, self.k, counts, tx, ws);
    }

    /// Count a whole slice of transactions.
    pub fn count_all(&self, txs: &[Transaction]) -> Vec<u64> {
        let mut counts = vec![0u64; self.n_candidates];
        let mut ws = self.workspace();
        for t in txs {
            self.count_transaction(t, &mut counts, &mut ws);
        }
        counts
    }
}

fn insert(
    node: &mut Node,
    idx: usize,
    cand: &Itemset,
    depth: usize,
    k: usize,
    next_leaf: &mut usize,
) {
    match node {
        Node::Interior(children) => {
            let h = hash_item(cand[depth]);
            let child = children[h].get_or_insert_with(|| {
                let id = *next_leaf;
                *next_leaf += 1;
                Box::new(Node::Leaf(id, Vec::new()))
            });
            insert(child, idx, cand, depth + 1, k, next_leaf);
        }
        Node::Leaf(_, bucket) => {
            bucket.push((idx, cand.clone()));
            // split overfull leaves while there are items left to hash on
            if bucket.len() > LEAF_CAP && depth < k {
                let drained = std::mem::take(bucket);
                let mut children: Vec<Option<Box<Node>>> = (0..FANOUT).map(|_| None).collect();
                for (i, c) in drained {
                    let h = hash_item(c[depth]);
                    let child = children[h].get_or_insert_with(|| {
                        let id = *next_leaf;
                        *next_leaf += 1;
                        Box::new(Node::Leaf(id, Vec::new()))
                    });
                    insert(child, i, &c, depth + 1, k, next_leaf);
                }
                *node = Node::Interior(children);
            }
        }
    }
}

/// Recursive traversal: at an interior node at depth `d`, try every
/// transaction item that could be the candidate's d-th item (leaving
/// enough items after it to complete a k-itemset). Leaves are processed
/// at most once per transaction via the workspace stamp.
#[allow(clippy::too_many_arguments)]
fn visit(
    node: &Node,
    items: &[ItemId],
    depth: usize,
    k: usize,
    counts: &mut [u64],
    tx: &Transaction,
    ws: &mut Workspace,
) {
    match node {
        Node::Leaf(id, bucket) => {
            if ws.stamps[*id] == ws.tick {
                return; // already handled for this transaction
            }
            ws.stamps[*id] = ws.tick;
            for (idx, cand) in bucket {
                if tx.contains_all(cand) {
                    counts[*idx] += 1;
                }
            }
        }
        Node::Interior(children) => {
            let remaining = k - depth; // items still needed
            if items.len() < remaining {
                return;
            }
            // choose position for the depth-th candidate item; must leave
            // remaining-1 items after it
            let last_start = items.len() - remaining;
            for (i, &item) in items[..=last_start].iter().enumerate() {
                if let Some(child) = &children[hash_item(item)] {
                    visit(child, &items[i + 1..], depth + 1, k, counts, tx, ws);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::quest::{QuestGenerator, QuestParams};
    use crate::data::TransactionDb;
    use crate::util::rng::Xoshiro256;

    fn naive_counts(db: &TransactionDb, cands: &[Itemset]) -> Vec<u64> {
        cands.iter().map(|c| db.support(c) as u64).collect()
    }

    #[test]
    fn tiny_handchecked() {
        let db = TransactionDb::new(vec![
            Transaction::new([0u32, 1, 2]),
            Transaction::new([0u32, 2]),
            Transaction::new([1u32, 2]),
        ]);
        let cands: Vec<Itemset> = vec![vec![0, 1], vec![0, 2], vec![1, 2]];
        let tree = HashTree::build(&cands);
        assert_eq!(tree.len(), 3);
        assert_eq!(tree.count_all(&db.transactions), vec![1, 2, 2]);
    }

    #[test]
    fn unit_candidates_no_double_count_across_hash_collisions() {
        // Regression: items 0 and 8 hash to the same child (fanout 8); a
        // transaction containing both used to reach that leaf twice and
        // double-count candidate [0]. 100 unit candidates force splits.
        let cands: Vec<Itemset> = (0..100u32).map(|i| vec![i]).collect();
        let tree = HashTree::build(&cands);
        let tx = Transaction::new([0u32, 8, 16, 24]);
        let mut counts = vec![0u64; cands.len()];
        let mut ws = tree.workspace();
        tree.count_transaction(&tx, &mut counts, &mut ws);
        assert_eq!(counts[0], 1, "candidate [0] must count once");
        assert_eq!(counts[8], 1);
        assert_eq!(counts[16], 1);
        assert_eq!(counts.iter().sum::<u64>(), 4);
    }

    #[test]
    fn matches_naive_on_random_candidates() {
        let db = QuestGenerator::new(QuestParams::dense(400)).generate();
        let mut rng = Xoshiro256::seed_from_u64(21);
        for k in [1usize, 2, 3, 4] {
            let mut cands: Vec<Itemset> = (0..300)
                .map(|_| {
                    let mut v: Vec<u32> = rng
                        .sample_distinct(db.n_items, k)
                        .into_iter()
                        .map(|x| x as u32)
                        .collect();
                    v.sort_unstable();
                    v
                })
                .collect();
            cands.sort();
            cands.dedup();
            let tree = HashTree::build(&cands);
            assert_eq!(
                tree.count_all(&db.transactions),
                naive_counts(&db, &cands),
                "k={k}"
            );
        }
    }

    #[test]
    fn leaf_split_with_many_candidates() {
        // > LEAF_CAP candidates sharing hash paths forces interior splits.
        let cands: Vec<Itemset> = (0..200u32).map(|i| vec![i, i + 200]).collect();
        let tree = HashTree::build(&cands);
        let tx = Transaction::new((0..400u32).collect::<Vec<_>>());
        let counts = tree.count_all(std::slice::from_ref(&tx));
        assert!(counts.iter().all(|&c| c == 1), "every pair contained once");
    }

    #[test]
    fn short_transactions_skipped() {
        let cands: Vec<Itemset> = vec![vec![0, 1, 2]];
        let tree = HashTree::build(&cands);
        let counts = tree.count_all(&[Transaction::new([0u32, 1])]);
        assert_eq!(counts, vec![0]);
    }

    #[test]
    fn empty_tree_counts_nothing() {
        let tree = HashTree::build(&[]);
        assert!(tree.is_empty());
        let counts = tree.count_all(&[Transaction::new([1u32, 2])]);
        assert!(counts.is_empty());
    }

    #[test]
    fn duplicate_candidates_get_independent_slots() {
        let cands: Vec<Itemset> = vec![vec![1, 2], vec![1, 2]];
        let tree = HashTree::build(&cands);
        let counts = tree.count_all(&[Transaction::new([0u32, 1, 2, 3])]);
        assert_eq!(counts, vec![1, 1]);
    }

    #[test]
    fn workspace_reuse_across_many_transactions() {
        let db = QuestGenerator::new(QuestParams::dense(300)).generate();
        let cands: Vec<Itemset> = (0..60u32).map(|i| vec![i]).collect();
        let tree = HashTree::build(&cands);
        // one shared workspace across the whole pass must equal per-tx fresh
        let a = tree.count_all(&db.transactions);
        let mut b = vec![0u64; cands.len()];
        for t in &db.transactions {
            let mut fresh = tree.workspace();
            tree.count_transaction(t, &mut b, &mut fresh);
        }
        assert_eq!(a, b);
    }
}

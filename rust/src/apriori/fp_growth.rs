//! FP-Growth (Han et al. 2000) — the stronger published comparator for the
//! baseline ablation: mines the same frequent itemsets without candidate
//! generation, via recursive conditional FP-trees.

use std::collections::HashMap;
use std::time::Instant;

use crate::data::{ItemId, TransactionDb};

use super::{AprioriConfig, Itemset, LevelStats, MiningResult};

/// Node of an FP-tree. Children keyed by item; `count` is the number of
/// transactions whose prefix path ends at/through this node.
#[derive(Debug, Default)]
struct FpNode {
    children: HashMap<ItemId, usize>, // item -> node index
    item: ItemId,
    count: u64,
    parent: Option<usize>,
}

/// Arena-allocated FP-tree with per-item node lists (the "header table").
#[derive(Debug)]
struct FpTree {
    nodes: Vec<FpNode>,
    /// item -> indices of nodes carrying that item.
    header: HashMap<ItemId, Vec<usize>>,
}

impl FpTree {
    fn new() -> Self {
        Self {
            nodes: vec![FpNode::default()], // root
            header: HashMap::new(),
        }
    }

    /// Insert one (ordered) transaction path with multiplicity `count`.
    fn insert(&mut self, path: &[ItemId], count: u64) {
        let mut cur = 0usize;
        for &item in path {
            let next = match self.nodes[cur].children.get(&item) {
                Some(&n) => n,
                None => {
                    let n = self.nodes.len();
                    self.nodes.push(FpNode {
                        children: HashMap::new(),
                        item,
                        count: 0,
                        parent: Some(cur),
                    });
                    self.nodes[cur].children.insert(item, n);
                    self.header.entry(item).or_default().push(n);
                    n
                }
            };
            self.nodes[next].count += count;
            cur = next;
        }
    }

    /// Conditional pattern base of `item`: (prefix path, count) pairs.
    fn pattern_base(&self, item: ItemId) -> Vec<(Vec<ItemId>, u64)> {
        let mut base = Vec::new();
        if let Some(nodes) = self.header.get(&item) {
            for &n in nodes {
                let count = self.nodes[n].count;
                let mut path = Vec::new();
                let mut cur = self.nodes[n].parent;
                while let Some(p) = cur {
                    if p == 0 {
                        break;
                    }
                    path.push(self.nodes[p].item);
                    cur = self.nodes[p].parent;
                }
                path.reverse();
                if !path.is_empty() {
                    base.push((path, count));
                }
            }
        }
        base
    }

    fn item_support(&self, item: ItemId) -> u64 {
        self.header
            .get(&item)
            .map(|ns| ns.iter().map(|&n| self.nodes[n].count).sum())
            .unwrap_or(0)
    }

    fn items(&self) -> Vec<ItemId> {
        let mut v: Vec<ItemId> = self.header.keys().copied().collect();
        v.sort_unstable();
        v
    }
}

/// FP-Growth miner.
#[derive(Debug, Clone, Default)]
pub struct FpGrowth;

impl FpGrowth {
    pub fn mine(&self, db: &TransactionDb, cfg: &AprioriConfig) -> MiningResult {
        let t0 = Instant::now();
        let threshold = cfg.threshold(db.len());
        let mut result = MiningResult {
            n_transactions: db.len(),
            ..Default::default()
        };

        // Pass 1: item supports; keep frequent items, order by descending
        // support (FP-tree compression heuristic), ties by item id.
        let mut supports: Vec<u64> = vec![0; db.n_items];
        for t in &db.transactions {
            for &i in &t.items {
                supports[i as usize] += 1;
            }
        }
        let mut order: Vec<ItemId> = (0..db.n_items as u32)
            .filter(|&i| supports[i as usize] >= threshold)
            .collect();
        order.sort_by_key(|&i| (std::cmp::Reverse(supports[i as usize]), i));
        let rank: HashMap<ItemId, usize> =
            order.iter().enumerate().map(|(r, &i)| (i, r)).collect();

        // Pass 2: build the global FP-tree over rank-ordered frequent items.
        let mut tree = FpTree::new();
        for t in &db.transactions {
            let mut path: Vec<ItemId> = t
                .items
                .iter()
                .copied()
                .filter(|i| rank.contains_key(i))
                .collect();
            path.sort_by_key(|i| rank[i]);
            tree.insert(&path, 1);
        }

        // Recursive growth.
        let mut found: Vec<(Itemset, u64)> = Vec::new();
        grow(&tree, &mut Vec::new(), threshold, cfg, &mut found);
        for (is, _) in &mut found {
            is.sort_unstable();
        }
        result.frequent = found;
        result.normalize();

        // FP-growth has no per-level loop; report a single aggregate stat
        // so comparisons can still chart "work".
        let max_k = result
            .frequent
            .iter()
            .map(|(is, _)| is.len())
            .max()
            .unwrap_or(0);
        result.levels.push(LevelStats {
            k: max_k,
            n_candidates: 0, // no candidate generation — the algorithm's point
            n_frequent: result.frequent.len(),
            work_units: tree.nodes.len() as f64,
            wall_secs: t0.elapsed().as_secs_f64(),
        });
        result
    }
}

/// Mine `tree` (conditional on `suffix`), appending discoveries.
fn grow(
    tree: &FpTree,
    suffix: &mut Vec<ItemId>,
    threshold: u64,
    cfg: &AprioriConfig,
    out: &mut Vec<(Itemset, u64)>,
) {
    for item in tree.items() {
        let support = tree.item_support(item);
        if support < threshold {
            continue;
        }
        suffix.push(item);
        if cfg.max_k == 0 || suffix.len() <= cfg.max_k {
            out.push((suffix.clone(), support));
            // Build the conditional tree and recurse.
            let base = tree.pattern_base(item);
            if !base.is_empty() {
                // conditional item supports
                let mut csup: HashMap<ItemId, u64> = HashMap::new();
                for (path, count) in &base {
                    for &i in path {
                        *csup.entry(i).or_insert(0) += count;
                    }
                }
                let mut cond = FpTree::new();
                for (path, count) in &base {
                    let filtered: Vec<ItemId> = path
                        .iter()
                        .copied()
                        .filter(|i| csup[i] >= threshold)
                        .collect();
                    if !filtered.is_empty() {
                        cond.insert(&filtered, *count);
                    }
                }
                grow(&cond, suffix, threshold, cfg, out);
            }
        }
        suffix.pop();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apriori::classical::{tests::textbook_db, ClassicalApriori};
    use crate::data::quest::{QuestGenerator, QuestParams};

    #[test]
    fn matches_classical_on_textbook() {
        let db = textbook_db();
        let cfg = AprioriConfig { min_support: 2.0 / 9.0, max_k: 0 };
        let a = ClassicalApriori::default().mine(&db, &cfg);
        let b = FpGrowth.mine(&db, &cfg);
        assert_eq!(a.frequent, b.frequent);
    }

    #[test]
    fn matches_classical_on_quest_profiles() {
        for (params, min_support) in [
            (QuestParams::goswami_2k(), 0.05),
            (QuestParams::dense(300), 0.15),
        ] {
            let db = QuestGenerator::new(params).generate();
            let cfg = AprioriConfig { min_support, max_k: 0 };
            let a = ClassicalApriori::default().mine(&db, &cfg);
            let b = FpGrowth.mine(&db, &cfg);
            assert_eq!(a.frequent, b.frequent);
        }
    }

    #[test]
    fn respects_max_k() {
        let db = textbook_db();
        let cfg = AprioriConfig { min_support: 2.0 / 9.0, max_k: 2 };
        let r = FpGrowth.mine(&db, &cfg);
        assert!(r.frequent.iter().all(|(is, _)| is.len() <= 2));
    }

    #[test]
    fn empty_and_all_infrequent() {
        let db = TransactionDb::new(vec![]);
        assert!(FpGrowth.mine(&db, &AprioriConfig::default()).frequent.is_empty());
        let db = textbook_db();
        let cfg = AprioriConfig { min_support: 0.999, max_k: 0 };
        assert!(FpGrowth.mine(&db, &cfg).frequent.is_empty());
    }

    #[test]
    fn reports_no_candidates() {
        let db = textbook_db();
        let cfg = AprioriConfig { min_support: 2.0 / 9.0, max_k: 0 };
        let r = FpGrowth.mine(&db, &cfg);
        assert_eq!(r.levels[0].n_candidates, 0);
        assert_eq!(r.levels[0].n_frequent, r.frequent.len());
    }
}

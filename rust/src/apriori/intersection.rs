//! "Intersection" Apriori — reference [8]'s tidset approach (the same idea
//! Eclat develops fully): keep, for every frequent itemset, the sorted
//! list of transaction ids containing it; the support of a k-candidate is
//! the length of the intersection of a parent's tidset with the last
//! item's tidset. No database re-scan after the first pass.

use std::collections::HashMap;
use std::time::Instant;

use crate::data::TransactionDb;

use super::candidates;
use super::{AprioriConfig, Itemset, LevelStats, MiningResult};

/// Sorted transaction-id list.
type TidSet = Vec<u32>;

/// Tidset intersection through the shared galloping primitive
/// ([`crate::data::intersect_sorted_into`]) — the same code the vertical
/// engine's sparse TID index intersects with, so an optimization there
/// benefits this miner too.
fn intersect(a: &TidSet, b: &TidSet) -> TidSet {
    let mut out = Vec::with_capacity(a.len().min(b.len()));
    crate::data::intersect_sorted_into(a, b, &mut out);
    out
}

/// Tidset-intersection miner.
#[derive(Debug, Clone, Default)]
pub struct IntersectionApriori;

impl IntersectionApriori {
    pub fn mine(&self, db: &TransactionDb, cfg: &AprioriConfig) -> MiningResult {
        let threshold = cfg.threshold(db.len());
        let mut result = MiningResult {
            n_transactions: db.len(),
            ..Default::default()
        };

        // Pass 1: vertical layout — tidset per item.
        let t0 = Instant::now();
        let mut item_tids: Vec<TidSet> = vec![Vec::new(); db.n_items];
        for (tid, t) in db.transactions.iter().enumerate() {
            for &item in &t.items {
                item_tids[item as usize].push(tid as u32);
            }
        }
        let mut frequent_prev: Vec<(Itemset, TidSet)> = Vec::new();
        for (item, tids) in item_tids.iter().enumerate() {
            if tids.len() as u64 >= threshold {
                frequent_prev.push((vec![item as u32], tids.clone()));
            }
        }
        frequent_prev.sort_by(|a, b| a.0.cmp(&b.0));
        result.levels.push(LevelStats {
            k: 1,
            n_candidates: db.n_items,
            n_frequent: frequent_prev.len(),
            work_units: db.total_items() as f64,
            wall_secs: t0.elapsed().as_secs_f64(),
        });
        result
            .frequent
            .extend(frequent_prev.iter().map(|(is, t)| (is.clone(), t.len() as u64)));

        // Singleton tidsets persist across every level: a k-candidate's
        // tidset is parent(k-1)-tidset ∩ tidset(last item).
        let singleton_tids: HashMap<u32, TidSet> = frequent_prev
            .iter()
            .map(|(is, t)| (is[0], t.clone()))
            .collect();

        // Levels k >= 2: candidate tidset = parent tidset ∩ last item tidset.
        let mut k = 2usize;
        while !frequent_prev.is_empty() && cfg.level_allowed(k) {
            let t0 = Instant::now();
            let prev_sets: Vec<Itemset> =
                frequent_prev.iter().map(|(is, _)| is.clone()).collect();
            let tid_lookup: HashMap<&[u32], &TidSet> = frequent_prev
                .iter()
                .map(|(is, t)| (is.as_slice(), t))
                .collect();
            let cands = candidates::generate(&prev_sets);
            if cands.is_empty() {
                break;
            }
            let mut work = 0f64;
            let mut frequent_k: Vec<(Itemset, TidSet)> = Vec::new();
            for cand in &cands {
                let parent = &cand[..cand.len() - 1];
                let last = cand[cand.len() - 1];
                let (Some(pt), Some(lt)) =
                    (tid_lookup.get(parent), singleton_tids.get(&last))
                else {
                    continue; // pruned parents can't appear, but be safe
                };
                work += (pt.len() + lt.len()) as f64;
                let tids = intersect(pt, lt);
                if tids.len() as u64 >= threshold {
                    frequent_k.push((cand.clone(), tids));
                }
            }
            frequent_k.sort_by(|a, b| a.0.cmp(&b.0));
            result.levels.push(LevelStats {
                k,
                n_candidates: cands.len(),
                n_frequent: frequent_k.len(),
                work_units: work,
                wall_secs: t0.elapsed().as_secs_f64(),
            });
            result
                .frequent
                .extend(frequent_k.iter().map(|(is, t)| (is.clone(), t.len() as u64)));
            frequent_prev = frequent_k;
            k += 1;
        }
        result.normalize();
        result
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apriori::classical::{tests::textbook_db, ClassicalApriori};
    use crate::data::quest::{QuestGenerator, QuestParams};

    #[test]
    fn intersect_sorted_merge() {
        assert_eq!(intersect(&vec![1, 3, 5, 7], &vec![3, 4, 5, 8]), vec![3, 5]);
        assert_eq!(intersect(&vec![], &vec![1]), Vec::<u32>::new());
        assert_eq!(intersect(&vec![2, 4], &vec![2, 4]), vec![2, 4]);
        assert_eq!(intersect(&vec![1, 2], &vec![3, 4]), Vec::<u32>::new());
    }

    #[test]
    fn matches_classical_on_textbook() {
        let db = textbook_db();
        let cfg = AprioriConfig { min_support: 2.0 / 9.0, max_k: 0 };
        let a = ClassicalApriori::default().mine(&db, &cfg);
        let b = IntersectionApriori.mine(&db, &cfg);
        assert_eq!(a.frequent, b.frequent);
    }

    #[test]
    fn matches_classical_on_quest() {
        let db = QuestGenerator::new(QuestParams::goswami_2k()).generate();
        let cfg = AprioriConfig { min_support: 0.05, max_k: 0 };
        let a = ClassicalApriori::default().mine(&db, &cfg);
        let b = IntersectionApriori.mine(&db, &cfg);
        assert_eq!(a.frequent, b.frequent);
    }

    #[test]
    fn no_rescan_work_shrinks_with_level() {
        // Tidset work at deep levels is bounded by surviving tidset sizes,
        // which shrink monotonically along a branch.
        let db = QuestGenerator::new(QuestParams::dense(400)).generate();
        let cfg = AprioriConfig { min_support: 0.2, max_k: 0 };
        let r = IntersectionApriori.mine(&db, &cfg);
        assert!(r.levels.len() >= 2);
        // every reported support is exact
        for (is, sup) in &r.frequent {
            assert_eq!(*sup, db.support(is) as u64);
        }
    }
}

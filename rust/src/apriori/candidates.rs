//! Level-wise candidate generation: the F(k-1) ⋈ F(k-1) self-join with
//! Apriori subset pruning (Agrawal & Srikant '94, Algorithm "apriori-gen").
//!
//! Both steps rely on the canonical sorted form of [`Itemset`]s:
//! * **join**: two frequent (k-1)-itemsets sharing their first k-2 items
//!   produce one k-candidate;
//! * **prune**: a candidate survives only if *every* (k-1)-subset is
//!   frequent — the downward-closure property that gives Apriori its name.

use std::collections::HashSet;

use super::Itemset;

/// Generate level-k candidates from the sorted list of frequent
/// (k-1)-itemsets. `frequent` must be sorted lexicographically (the
/// canonical `MiningResult` order); the output is sorted too.
pub fn generate(frequent: &[Itemset]) -> Vec<Itemset> {
    if frequent.is_empty() {
        return Vec::new();
    }
    let k_minus_1 = frequent[0].len();
    debug_assert!(frequent.iter().all(|f| f.len() == k_minus_1));
    let lookup: HashSet<&[u32]> = frequent.iter().map(|f| f.as_slice()).collect();

    let mut out = Vec::new();
    // Join: pairs sharing the (k-2)-prefix. frequent is sorted, so equal
    // prefixes are contiguous — scan prefix groups and pair within.
    let mut g0 = 0;
    while g0 < frequent.len() {
        let prefix = &frequent[g0][..k_minus_1 - 1];
        let mut g1 = g0 + 1;
        while g1 < frequent.len() && &frequent[g1][..k_minus_1 - 1] == prefix {
            g1 += 1;
        }
        for a in g0..g1 {
            for b in (a + 1)..g1 {
                // last items differ and are ordered (sorted input)
                let mut cand: Itemset = frequent[a].clone();
                cand.push(frequent[b][k_minus_1 - 1]);
                if prune_ok(&cand, &lookup) {
                    out.push(cand);
                }
            }
        }
        g0 = g1;
    }
    out.sort();
    out
}

/// Does every (k-1)-subset of `cand` appear in the frequent set?
fn prune_ok(cand: &Itemset, frequent: &HashSet<&[u32]>) -> bool {
    // The two subsets formed by dropping the last two positions are the
    // join parents — always frequent — but checking them is cheap and
    // keeps the code obviously correct.
    let mut sub = Vec::with_capacity(cand.len() - 1);
    for skip in 0..cand.len() {
        sub.clear();
        sub.extend(cand.iter().enumerate().filter(|&(i, _)| i != skip).map(|(_, &x)| x));
        if !frequent.contains(sub.as_slice()) {
            return false;
        }
    }
    true
}

/// Level-1 "candidates": every item in the universe (the first pass scans
/// and counts all items; no generation needed). Provided for symmetry so
/// drivers can treat k=1 uniformly.
pub fn unit_candidates(n_items: usize) -> Vec<Itemset> {
    (0..n_items as u32).map(|i| vec![i]).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn iss(xs: &[&[u32]]) -> Vec<Itemset> {
        xs.iter().map(|x| x.to_vec()).collect()
    }

    #[test]
    fn textbook_join_and_prune() {
        // Classic A&S example: F3 = {abc, abd, acd, ace, bcd}
        // join -> abcd (from abc+abd), acde (from acd+ace)
        // prune: abcd ok (abc,abd,acd,bcd all in F3);
        //        acde pruned (cde missing, ade missing).
        let f3 = iss(&[
            &[0, 1, 2],
            &[0, 1, 3],
            &[0, 2, 3],
            &[0, 2, 4],
            &[1, 2, 3],
        ]);
        let c4 = generate(&f3);
        assert_eq!(c4, iss(&[&[0, 1, 2, 3]]));
    }

    #[test]
    fn pairs_from_singletons() {
        let f1 = iss(&[&[2], &[5], &[9]]);
        let c2 = generate(&f1);
        assert_eq!(c2, iss(&[&[2, 5], &[2, 9], &[5, 9]]));
    }

    #[test]
    fn empty_and_singleton_input() {
        assert!(generate(&[]).is_empty());
        assert!(generate(&iss(&[&[1]])).is_empty()); // nothing to join with
    }

    #[test]
    fn no_join_across_different_prefixes() {
        // {0,1} and {2,3} share no (k-2)-prefix -> no candidate.
        let f2 = iss(&[&[0, 1], &[2, 3]]);
        assert!(generate(&f2).is_empty());
    }

    #[test]
    fn prune_removes_unsupported_subsets() {
        // F2 = {01, 02, 12, 13}: join gives 012 (from 01+02) and 123
        // (13 joins nothing with prefix 1 except 12 -> 123).
        // 012: subsets 01,02,12 all present -> kept.
        // 123: subsets 12,13,23 -> 23 missing -> pruned.
        let f2 = iss(&[&[0, 1], &[0, 2], &[1, 2], &[1, 3]]);
        assert_eq!(generate(&f2), iss(&[&[0, 1, 2]]));
    }

    #[test]
    fn output_sorted_and_unique() {
        let f1 = iss(&[&[1], &[3], &[5], &[7]]);
        let c2 = generate(&f1);
        let mut sorted = c2.clone();
        sorted.sort();
        sorted.dedup();
        assert_eq!(c2, sorted);
        assert_eq!(c2.len(), 6); // C(4,2)
    }

    #[test]
    fn unit_candidates_cover_universe() {
        assert_eq!(unit_candidates(3), iss(&[&[0], &[1], &[2]]));
        assert!(unit_candidates(0).is_empty());
    }
}

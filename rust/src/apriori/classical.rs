//! Classical single-machine Apriori — the paper's standalone baseline
//! (and the "classical Apriori" row in reference [8]'s comparison).

use std::time::Instant;

use crate::data::TransactionDb;

use super::candidates;
use super::hash_tree::HashTree;
use super::trie::CandidateTrie;
use super::{AprioriConfig, Itemset, LevelStats, MiningResult};

/// Which candidate-matching structure the counting loop uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum MatcherKind {
    /// Agrawal–Srikant hash tree (the paper-era default).
    #[default]
    HashTree,
    /// Bodon-style prefix trie.
    Trie,
    /// Direct `contains_all` scan per candidate — O(|C|·|D|); the oracle.
    Naive,
}

/// The classical miner.
#[derive(Debug, Clone, Default)]
pub struct ClassicalApriori {
    pub matcher: MatcherKind,
}

impl ClassicalApriori {
    pub fn new(matcher: MatcherKind) -> Self {
        Self { matcher }
    }

    fn count_level(&self, db: &TransactionDb, cands: &[Itemset]) -> Vec<u64> {
        match self.matcher {
            MatcherKind::HashTree => HashTree::build(cands).count_all(&db.transactions),
            MatcherKind::Trie => CandidateTrie::build(cands).count_all(&db.transactions),
            MatcherKind::Naive => cands.iter().map(|c| db.support(c) as u64).collect(),
        }
    }

    /// Mine all frequent itemsets level-by-level.
    pub fn mine(&self, db: &TransactionDb, cfg: &AprioriConfig) -> MiningResult {
        let threshold = cfg.threshold(db.len());
        let mut result = MiningResult {
            n_transactions: db.len(),
            ..Default::default()
        };
        // L1: count every item.
        let mut k = 1usize;
        let mut cands = candidates::unit_candidates(db.n_items);
        while !cands.is_empty() && cfg.level_allowed(k) {
            let t0 = Instant::now();
            let counts = self.count_level(db, &cands);
            let mut frequent_k: Vec<(Itemset, u64)> = cands
                .iter()
                .cloned()
                .zip(counts)
                .filter(|&(_, c)| c >= threshold)
                .collect();
            frequent_k.sort_by(|a, b| a.0.cmp(&b.0));
            result.levels.push(LevelStats {
                k,
                n_candidates: cands.len(),
                n_frequent: frequent_k.len(),
                work_units: (cands.len() * db.len()) as f64,
                wall_secs: t0.elapsed().as_secs_f64(),
            });
            let fk: Vec<Itemset> = frequent_k.iter().map(|(is, _)| is.clone()).collect();
            result.frequent.extend(frequent_k);
            if fk.is_empty() {
                break;
            }
            cands = candidates::generate(&fk);
            k += 1;
        }
        result.normalize();
        result
    }
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;
    use crate::data::quest::{QuestGenerator, QuestParams};
    use crate::data::{Transaction, TransactionDb};

    /// The textbook 9-transaction example (Han & Kamber) with min_sup 2/9.
    pub fn textbook_db() -> TransactionDb {
        let rows: Vec<Vec<u32>> = vec![
            vec![0, 1, 4],
            vec![1, 3],
            vec![1, 2],
            vec![0, 1, 3],
            vec![0, 2],
            vec![1, 2],
            vec![0, 2],
            vec![0, 1, 2, 4],
            vec![0, 1, 2],
        ];
        TransactionDb::new(rows.into_iter().map(Transaction::new).collect())
    }

    #[test]
    fn textbook_example_all_matchers() {
        let db = textbook_db();
        let cfg = AprioriConfig { min_support: 2.0 / 9.0, max_k: 0 };
        for matcher in [MatcherKind::HashTree, MatcherKind::Trie, MatcherKind::Naive] {
            let r = ClassicalApriori::new(matcher).mine(&db, &cfg);
            // Known result: L1 = 5 items; L2 = {01,02,04,12,13,14? no}
            // supports: 01=4, 02=4, 04=2, 12=4, 13=2, 14=2, 23=0? ...
            assert_eq!(r.level(1).count(), 5, "{matcher:?}");
            let l2: Vec<_> = r.level(2).cloned().collect();
            assert_eq!(
                l2,
                vec![
                    (vec![0, 1], 4),
                    (vec![0, 2], 4),
                    (vec![0, 4], 2),
                    (vec![1, 2], 4),
                    (vec![1, 3], 2),
                    (vec![1, 4], 2),
                ],
                "{matcher:?}"
            );
            let l3: Vec<_> = r.level(3).cloned().collect();
            assert_eq!(
                l3,
                vec![(vec![0, 1, 2], 2), (vec![0, 1, 4], 2)],
                "{matcher:?}"
            );
            assert_eq!(r.level(4).count(), 0);
        }
    }

    #[test]
    fn matchers_agree_on_quest_data() {
        let db = QuestGenerator::new(QuestParams::dense(300)).generate();
        let cfg = AprioriConfig { min_support: 0.15, max_k: 4 };
        let a = ClassicalApriori::new(MatcherKind::HashTree).mine(&db, &cfg);
        let b = ClassicalApriori::new(MatcherKind::Trie).mine(&db, &cfg);
        let c = ClassicalApriori::new(MatcherKind::Naive).mine(&db, &cfg);
        assert_eq!(a.frequent, b.frequent);
        assert_eq!(b.frequent, c.frequent);
        assert!(!a.frequent.is_empty());
    }

    #[test]
    fn every_reported_support_is_correct_and_above_threshold() {
        let db = QuestGenerator::new(QuestParams::dense(200)).generate();
        let cfg = AprioriConfig { min_support: 0.2, max_k: 0 };
        let r = ClassicalApriori::default().mine(&db, &cfg);
        let threshold = cfg.threshold(db.len());
        for (is, sup) in &r.frequent {
            assert_eq!(*sup, db.support(is) as u64, "support of {is:?}");
            assert!(*sup >= threshold);
        }
    }

    #[test]
    fn downward_closure_holds() {
        let db = QuestGenerator::new(QuestParams::dense(200)).generate();
        let cfg = AprioriConfig { min_support: 0.15, max_k: 0 };
        let r = ClassicalApriori::default().mine(&db, &cfg);
        for (is, _) in r.frequent.iter().filter(|(is, _)| is.len() > 1) {
            for skip in 0..is.len() {
                let sub: Vec<u32> = is
                    .iter()
                    .enumerate()
                    .filter(|&(i, _)| i != skip)
                    .map(|(_, &x)| x)
                    .collect();
                assert!(
                    r.support_of(&sub).is_some(),
                    "subset {sub:?} of frequent {is:?} missing"
                );
            }
        }
    }

    #[test]
    fn max_k_caps_levels() {
        let db = textbook_db();
        let cfg = AprioriConfig { min_support: 2.0 / 9.0, max_k: 2 };
        let r = ClassicalApriori::default().mine(&db, &cfg);
        assert!(r.level(3).count() == 0);
        assert_eq!(r.levels.len(), 2);
    }

    #[test]
    fn high_threshold_yields_nothing_beyond_l1() {
        let db = textbook_db();
        let cfg = AprioriConfig { min_support: 0.99, max_k: 0 };
        let r = ClassicalApriori::default().mine(&db, &cfg);
        assert!(r.frequent.is_empty());
    }

    #[test]
    fn empty_db_mines_empty() {
        let db = TransactionDb::new(vec![]);
        let r = ClassicalApriori::default().mine(&db, &AprioriConfig::default());
        assert!(r.frequent.is_empty());
        assert_eq!(r.n_transactions, 0);
    }
}

//! Association-rule generation from mined frequent itemsets — the KDD
//! step the paper's Figure 1 pipeline ends with (interpretation).
//!
//! For every frequent itemset Z and non-empty proper subset A ⊂ Z, the
//! rule A ⇒ (Z \ A) holds when confidence(A ⇒ B) = sup(Z)/sup(A) meets
//! the threshold. Lift is reported for interpretation.

use crate::data::ItemId;

use super::{Itemset, MiningResult};

/// One association rule A ⇒ B with its quality measures.
#[derive(Debug, Clone, PartialEq)]
pub struct Rule {
    pub antecedent: Itemset,
    pub consequent: Itemset,
    /// Absolute support of A ∪ B.
    pub support: u64,
    /// sup(A∪B) / sup(A).
    pub confidence: f64,
    /// confidence / (sup(B)/|D|).
    pub lift: f64,
}

/// Generate all rules meeting `min_confidence` from a mining result.
/// Requires the result to contain every frequent subset (all miners in
/// this crate guarantee that by downward closure).
pub fn generate_rules(result: &MiningResult, min_confidence: f64) -> Vec<Rule> {
    let n = result.n_transactions as f64;
    let mut rules = Vec::new();
    for (itemset, support) in result.frequent.iter().filter(|(is, _)| is.len() >= 2) {
        // enumerate non-empty proper subsets as antecedents
        let k = itemset.len();
        if k > 63 {
            // u64 subset masks cover k <= 63; an itemset past that would
            // enumerate > 2^63 rules, so no real mining result contains
            // one. Skip it rather than overflow the shift (the u32 masks
            // used previously broke at k = 32 already).
            debug_assert!(k <= 63, "generate_rules: skipping itemset of len {k} > 63");
            continue;
        }
        for mask in 1..((1u64 << k) - 1) {
            let antecedent: Itemset = (0..k)
                .filter(|&i| mask & (1 << i) != 0)
                .map(|i| itemset[i])
                .collect();
            let consequent: Itemset = (0..k)
                .filter(|&i| mask & (1 << i) == 0)
                .map(|i| itemset[i])
                .collect();
            let Some(sup_a) = result.support_of(&antecedent) else {
                continue;
            };
            let confidence = *support as f64 / sup_a as f64;
            if confidence + 1e-12 < min_confidence {
                continue;
            }
            let lift = match result.support_of(&consequent) {
                Some(sup_b) if sup_b > 0 && n > 0.0 => {
                    confidence / (sup_b as f64 / n)
                }
                _ => f64::NAN,
            };
            rules.push(Rule {
                antecedent,
                consequent,
                support: *support,
                confidence,
                lift,
            });
        }
    }
    // deterministic report order: by confidence desc, then antecedent
    rules.sort_by(|a, b| {
        b.confidence
            .total_cmp(&a.confidence)
            .then_with(|| a.antecedent.cmp(&b.antecedent))
            .then_with(|| a.consequent.cmp(&b.consequent))
    });
    rules
}

/// Pretty-print a rule like `{0,1} => {4} (sup=2, conf=0.50, lift=2.25)`.
pub fn format_rule(r: &Rule) -> String {
    fn set(s: &[ItemId]) -> String {
        let inner: Vec<String> = s.iter().map(|i| i.to_string()).collect();
        format!("{{{}}}", inner.join(","))
    }
    format!(
        "{} => {} (sup={}, conf={:.2}, lift={:.2})",
        set(&r.antecedent),
        set(&r.consequent),
        r.support,
        r.confidence,
        r.lift
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apriori::classical::{tests::textbook_db, ClassicalApriori};
    use crate::apriori::AprioriConfig;

    fn mined() -> MiningResult {
        ClassicalApriori::default().mine(
            &textbook_db(),
            &AprioriConfig { min_support: 2.0 / 9.0, max_k: 0 },
        )
    }

    #[test]
    fn textbook_rules_from_014() {
        // {0,1,4} has sup 2; sup({0,4})=2 so {0,4}=>{1} has conf 1.0.
        let rules = generate_rules(&mined(), 0.9);
        assert!(rules.iter().any(|r| {
            r.antecedent == vec![0, 4] && r.consequent == vec![1] && r.confidence == 1.0
        }));
        // all reported rules respect the threshold
        assert!(rules.iter().all(|r| r.confidence >= 0.9));
    }

    #[test]
    fn confidence_and_lift_math() {
        let rules = generate_rules(&mined(), 0.0);
        // {0} => {1}: sup(01)=4, sup(0)=6 -> conf 2/3; sup(1)=7, n=9
        let r = rules
            .iter()
            .find(|r| r.antecedent == vec![0] && r.consequent == vec![1])
            .unwrap();
        assert!((r.confidence - 4.0 / 6.0).abs() < 1e-12);
        assert!((r.lift - (4.0 / 6.0) / (7.0 / 9.0)).abs() < 1e-12);
        assert_eq!(r.support, 4);
    }

    #[test]
    fn rule_count_matches_subset_enumeration() {
        // With min_confidence 0 every split of every frequent k>=2 itemset
        // appears: sum over itemsets of (2^k - 2).
        let m = mined();
        let expected: usize = m
            .frequent
            .iter()
            .filter(|(is, _)| is.len() >= 2)
            .map(|(is, _)| (1usize << is.len()) - 2)
            .sum();
        assert_eq!(generate_rules(&m, 0.0).len(), expected);
    }

    #[test]
    fn empty_result_no_rules() {
        let empty = MiningResult::default();
        assert!(generate_rules(&empty, 0.5).is_empty());
    }

    #[test]
    fn oversized_itemset_is_skipped_not_overflowed() {
        // Regression: subset masks were u32, so a k >= 32 itemset hit
        // `1u32 << 32`. With u64 masks, k <= 63 enumerates correctly and
        // k > 63 is skipped (debug builds flag the impossible input).
        let wide: Itemset = (0..70).collect();
        let r = MiningResult {
            frequent: vec![(wide, 3), (vec![100, 101], 2), (vec![100], 4), (vec![101], 2)],
            levels: vec![],
            n_transactions: 10,
        };
        if cfg!(debug_assertions) {
            // the hook is left alone (it is process-global and tests run
            // concurrently), so this prints one expected backtrace
            let outcome = std::panic::catch_unwind(|| generate_rules(&r, 0.0));
            assert!(outcome.is_err(), "debug build must flag a k > 63 itemset");
        } else {
            // release builds skip the oversized itemset but still rule
            // the well-formed remainder
            let rules = generate_rules(&r, 0.0);
            assert_eq!(rules.len(), 2); // {100}=>{101} and {101}=>{100}
            assert!(rules.iter().all(|rule| rule.antecedent.len() == 1));
        }
    }

    #[test]
    fn formatting() {
        let r = Rule {
            antecedent: vec![0, 1],
            consequent: vec![4],
            support: 2,
            confidence: 0.5,
            lift: 2.25,
        };
        assert_eq!(format_rule(&r), "{0,1} => {4} (sup=2, conf=0.50, lift=2.25)");
    }
}

//! Post-processing of mining results: **closed** and **maximal** frequent
//! itemsets — the condensed representations downstream users usually want
//! instead of the raw (exponentially redundant) frequent set.
//!
//! * closed: no proper superset has the *same* support;
//! * maximal: no proper superset is frequent at all (maximal ⊆ closed).

use std::collections::HashMap;

use super::{Itemset, MiningResult};

/// Is `a` a proper subset of `b` (both sorted)?
fn proper_subset(a: &[u32], b: &[u32]) -> bool {
    a.len() < b.len() && crate::data::is_subset(a, b)
}

/// Closed frequent itemsets: those with no proper superset of equal
/// support. O(F²) pairwise check restricted to adjacent sizes by grouping
/// (an itemset's closure witness can be found among supersets exactly one
/// item larger, because support is monotone along the lattice).
pub fn closed_itemsets(result: &MiningResult) -> Vec<(Itemset, u64)> {
    let by_len = group_by_len(result);
    result
        .frequent
        .iter()
        .filter(|(is, sup)| {
            let Some(next) = by_len.get(&(is.len() + 1)) else {
                return true; // no supersets mined -> closed within the result
            };
            !next
                .iter()
                .any(|(sup2, is2)| *sup2 == *sup && proper_subset(is, is2))
        })
        .cloned()
        .collect()
}

/// Maximal frequent itemsets: those with no frequent proper superset.
pub fn maximal_itemsets(result: &MiningResult) -> Vec<(Itemset, u64)> {
    let by_len = group_by_len(result);
    result
        .frequent
        .iter()
        .filter(|(is, _)| {
            let Some(next) = by_len.get(&(is.len() + 1)) else {
                return true;
            };
            !next.iter().any(|(_, is2)| proper_subset(is, is2))
        })
        .cloned()
        .collect()
}

fn group_by_len(result: &MiningResult) -> HashMap<usize, Vec<(u64, &Itemset)>> {
    let mut m: HashMap<usize, Vec<(u64, &Itemset)>> = HashMap::new();
    for (is, sup) in &result.frequent {
        m.entry(is.len()).or_default().push((*sup, is));
    }
    m
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apriori::classical::{tests::textbook_db, ClassicalApriori};
    use crate::apriori::AprioriConfig;
    use crate::data::quest::{QuestGenerator, QuestParams};

    fn mined() -> MiningResult {
        ClassicalApriori::default().mine(
            &textbook_db(),
            &AprioriConfig { min_support: 2.0 / 9.0, max_k: 0 },
        )
    }

    #[test]
    fn subset_check() {
        assert!(proper_subset(&[1], &[1, 2]));
        assert!(proper_subset(&[1, 3], &[1, 2, 3]));
        assert!(!proper_subset(&[1, 2], &[1, 2]));
        assert!(!proper_subset(&[1, 4], &[1, 2, 3]));
        assert!(!proper_subset(&[2, 1], &[1]));
        assert!(proper_subset(&[], &[1]));
    }

    #[test]
    fn maximal_subset_of_closed_subset_of_frequent() {
        let r = mined();
        let closed = closed_itemsets(&r);
        let maximal = maximal_itemsets(&r);
        assert!(closed.len() <= r.frequent.len());
        assert!(maximal.len() <= closed.len());
        for m in &maximal {
            assert!(closed.contains(m), "maximal {m:?} must be closed");
        }
    }

    #[test]
    fn textbook_maximal_sets() {
        // Frequent: L3 = {012, 014}; L2 leftovers {13} (1,3 only in 13).
        let r = mined();
        let maximal: Vec<Itemset> = maximal_itemsets(&r).into_iter().map(|(is, _)| is).collect();
        assert!(maximal.contains(&vec![0, 1, 2]));
        assert!(maximal.contains(&vec![0, 1, 4]));
        assert!(maximal.contains(&vec![1, 3]));
        // items covered by L3 supersets must not be maximal
        assert!(!maximal.contains(&vec![0, 1]));
        assert!(!maximal.contains(&vec![0]));
    }

    #[test]
    fn closed_preserves_support_information() {
        // Every frequent itemset's support must be derivable as the max
        // support over closed supersets (the closure property).
        let r = mined();
        let closed = closed_itemsets(&r);
        for (is, sup) in &r.frequent {
            let derived = closed
                .iter()
                .filter(|(c, _)| c.as_slice() == is.as_slice() || proper_subset(is, c))
                .map(|&(_, s)| s)
                .max();
            assert_eq!(derived, Some(*sup), "closure failed for {is:?}");
        }
    }

    #[test]
    fn condensation_on_quest_data() {
        let db = QuestGenerator::new(QuestParams::dense(250)).generate();
        let cfg = AprioriConfig { min_support: 0.15, max_k: 0 };
        let r = ClassicalApriori::default().mine(&db, &cfg);
        let closed = closed_itemsets(&r);
        let maximal = maximal_itemsets(&r);
        assert!(
            maximal.len() < r.frequent.len(),
            "dense data must condense: {} maximal of {} frequent",
            maximal.len(),
            r.frequent.len()
        );
        // closure property holds at scale
        for (is, sup) in &r.frequent {
            let derived = closed
                .iter()
                .filter(|(c, _)| c.as_slice() == is.as_slice() || proper_subset(is, c))
                .map(|&(_, s)| s)
                .max();
            assert_eq!(derived, Some(*sup));
        }
    }

    #[test]
    fn empty_result_stays_empty() {
        let r = MiningResult::default();
        assert!(closed_itemsets(&r).is_empty());
        assert!(maximal_itemsets(&r).is_empty());
    }

    /// Mine a seeded dense Quest workload for the property drivers.
    fn mine_case(d: usize, seed: u64) -> MiningResult {
        let db = QuestGenerator::new(QuestParams::dense(d).with_seed(seed)).generate();
        ClassicalApriori::default().mine(&db, &AprioriConfig { min_support: 0.1, max_k: 4 })
    }

    #[test]
    fn prop_maximal_subset_of_closed_subset_of_frequent() {
        crate::util::proptest::check(
            "maximal ⊆ closed ⊆ frequent",
            0xC105ED,
            10,
            |rng| (rng.range_usize(30, 180), rng.next_u64()),
            |&(d, seed)| {
                let r = mine_case(d, seed);
                let closed = closed_itemsets(&r);
                let maximal = maximal_itemsets(&r);
                for c in &closed {
                    if !r.frequent.contains(c) {
                        return Err(format!("closed {c:?} not frequent"));
                    }
                }
                for m in &maximal {
                    if !closed.contains(m) {
                        return Err(format!("maximal {m:?} not closed"));
                    }
                }
                if maximal.len() > closed.len() || closed.len() > r.frequent.len() {
                    return Err("condensation sizes out of order".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_closed_supports_round_trip_against_full_result() {
        crate::util::proptest::check(
            "closed supports round-trip",
            0x2042D,
            10,
            |rng| (rng.range_usize(30, 180), rng.next_u64()),
            |&(d, seed)| {
                let r = mine_case(d, seed);
                let closed = closed_itemsets(&r);
                // every closed itemset keeps its exact support from the
                // full result...
                for (is, sup) in &closed {
                    if r.support_of(is) != Some(*sup) {
                        return Err(format!("closed support drifted for {is:?}"));
                    }
                }
                // ...and every frequent support is recoverable as the max
                // over closed supersets (the closure property)
                for (is, sup) in &r.frequent {
                    let derived = closed
                        .iter()
                        .filter(|(c, _)| c.as_slice() == is.as_slice() || proper_subset(is, c))
                        .map(|&(_, s)| s)
                        .max();
                    if derived != Some(*sup) {
                        return Err(format!("closure failed for {is:?}"));
                    }
                }
                Ok(())
            },
        );
    }
}

//! SON / partition-based Map/Reduce Apriori (Savasere–Omiecinski–Navathe
//! partitioning, popularized for MapReduce by Lin et al.) — the standard
//! improvement over the paper's one-job-per-level design, included as the
//! "future work" extension DESIGN.md calls out.
//!
//! Exactly **two** MR jobs regardless of itemset depth:
//!
//! 1. **Local mining**: each map task mines its split completely with the
//!    support threshold scaled to the split size, emitting every locally
//!    frequent itemset as a global candidate. Monotonicity guarantees no
//!    false negatives: a globally frequent itemset is locally frequent in
//!    at least one partition.
//! 2. **Global count**: candidates are broadcast; each map task counts
//!    exact supports on its split (any [`SupportEngine`]); the reducer
//!    sums and applies the global threshold, removing false positives.

use crate::cluster::ClusterConfig;
use crate::data::split::{plan_splits, Split};
use crate::data::{Transaction, TransactionDb};
use crate::dfs::Dfs;
use crate::engine::{EngineKind, SupportEngine};
use crate::mapreduce::app::MapReduceApp;
use crate::mapreduce::{JobConfig, JobRunner, JobStats};

use super::classical::ClassicalApriori;
use super::mr::CandidateCountApp;
use super::{AprioriConfig, Itemset, MiningResult};

/// Phase-1 app: mine each split locally, emit candidates.
struct LocalMineApp {
    /// Global min-support fraction (rescaled per split inside `map`).
    min_support: f64,
    max_k: usize,
    n_items: usize,
}

impl MapReduceApp for LocalMineApp {
    type K = Itemset;
    /// Value is the local support — informative only; phase 2 recounts.
    type V = u64;

    fn map(&self, _s: &Split, input: &[Transaction], emit: &mut dyn FnMut(Itemset, u64)) {
        let mut local = TransactionDb::new(input.to_vec());
        local.n_items = self.n_items;
        let cfg = AprioriConfig {
            min_support: self.min_support,
            max_k: self.max_k,
        };
        let result = ClassicalApriori::default().mine(&local, &cfg);
        for (itemset, support) in result.frequent {
            emit(itemset, support);
        }
    }

    fn combine(&self, _k: &Itemset, values: &[u64]) -> Option<u64> {
        Some(values.iter().sum())
    }

    /// Union of local candidates: keep every itemset seen anywhere.
    fn reduce(&self, _k: &Itemset, values: &[u64]) -> Option<u64> {
        Some(values.iter().sum())
    }

    fn map_cost_hint(&self, n_tx: usize) -> f64 {
        // local mining is super-linear-ish; a reasonable planning proxy
        (n_tx * n_tx / 8).max(n_tx) as f64
    }
}

/// Result of a SON run.
#[derive(Debug)]
pub struct SonReport {
    pub result: MiningResult,
    /// Candidates surviving phase 1 (global candidate set size).
    pub n_candidates: usize,
    /// Stats of the two jobs (phase1, phase2).
    pub phase1: JobStats,
    pub phase2: JobStats,
}

/// The SON driver — same cluster substrate as the level-wise coordinator.
pub struct SonApriori {
    pub cluster: ClusterConfig,
    pub apriori: AprioriConfig,
    pub job: JobConfig,
    pub split_tx: usize,
    engine: Box<dyn SupportEngine>,
}

impl SonApriori {
    pub fn new(cluster: ClusterConfig, apriori: AprioriConfig) -> Self {
        Self {
            cluster,
            apriori,
            job: JobConfig { n_reducers: 3, ..Default::default() },
            split_tx: 1000,
            engine: crate::engine::build_engine(EngineKind::HashTree, None),
        }
    }

    pub fn with_engine(mut self, engine: Box<dyn SupportEngine>) -> Self {
        self.engine = engine;
        self
    }

    pub fn with_split_tx(mut self, split_tx: usize) -> Self {
        assert!(split_tx > 0);
        self.split_tx = split_tx;
        self
    }

    pub fn mine(&self, db: &TransactionDb) -> Result<SonReport, crate::coordinator::MineError> {
        let splits = plan_splits(db, self.split_tx);
        let mut dfs = Dfs::new(&self.cluster);
        let blocks = dfs.write_splits(&splits)?;
        let runner = JobRunner::new(&self.cluster, &dfs, &blocks);

        // ---- phase 1: local mining -> global candidate set ----
        let p1 = LocalMineApp {
            min_support: self.apriori.min_support,
            max_k: self.apriori.max_k,
            n_items: db.n_items,
        };
        let (cands_kv, phase1) = runner.run(&p1, db, &splits, &self.job)?;
        let candidates: Vec<Itemset> = cands_kv.into_iter().map(|(k, _)| k).collect();
        let n_candidates = candidates.len();

        // ---- phase 2: exact global count + threshold ----
        let threshold = self.apriori.threshold(db.len());
        let p2 = CandidateCountApp::new(candidates, self.engine.as_ref(), db.n_items, threshold);
        let (frequent, phase2) = runner.run(&p2, db, &splits, &self.job)?;

        let mut result = MiningResult {
            frequent,
            levels: Vec::new(),
            n_transactions: db.len(),
        };
        result.normalize();
        Ok(SonReport { result, n_candidates, phase1, phase2 })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apriori::classical::tests::textbook_db;
    use crate::data::quest::{QuestGenerator, QuestParams};

    #[test]
    fn son_matches_classical_on_textbook() {
        let db = textbook_db();
        let cfg = AprioriConfig { min_support: 2.0 / 9.0, max_k: 0 };
        let classical = ClassicalApriori::default().mine(&db, &cfg);
        let son = SonApriori::new(ClusterConfig::fhssc(3), cfg)
            .with_split_tx(3)
            .mine(&db)
            .unwrap();
        assert_eq!(son.result.frequent, classical.frequent);
        // monotonicity: the candidate set must contain every final itemset
        assert!(son.n_candidates >= son.result.frequent.len());
    }

    #[test]
    fn son_matches_classical_on_quest() {
        let db = QuestGenerator::new(QuestParams::goswami_2k()).generate();
        let cfg = AprioriConfig { min_support: 0.05, max_k: 0 };
        let classical = ClassicalApriori::default().mine(&db, &cfg);
        let son = SonApriori::new(ClusterConfig::fhssc(3), cfg)
            .with_split_tx(250)
            .mine(&db)
            .unwrap();
        assert_eq!(son.result.frequent, classical.frequent);
    }

    #[test]
    fn son_is_exactly_two_jobs_even_for_deep_itemsets() {
        // dense data with deep frequent itemsets: the level-wise driver
        // needs one job per level, SON always needs two.
        let db = QuestGenerator::new(QuestParams::dense(300)).generate();
        let cfg = AprioriConfig { min_support: 0.2, max_k: 0 };
        let classical = ClassicalApriori::default().mine(&db, &cfg);
        let max_k = classical
            .frequent
            .iter()
            .map(|(is, _)| is.len())
            .max()
            .unwrap_or(0);
        assert!(max_k >= 3, "workload should have deep itemsets, got {max_k}");
        let son = SonApriori::new(ClusterConfig::fhssc(3), cfg)
            .with_split_tx(60)
            .mine(&db)
            .unwrap();
        assert_eq!(son.result.frequent, classical.frequent);
        // two jobs: their stats exist and counted every split each
        assert_eq!(son.phase1.maps_total, son.phase2.maps_total);
        assert!(son.phase1.maps_total >= 5);
    }

    #[test]
    fn son_skewed_partitions_still_exact() {
        // Non-uniform splits (last one tiny) — local thresholds rescale.
        let db = QuestGenerator::new(QuestParams::t10_i4(505)).generate();
        let cfg = AprioriConfig { min_support: 0.04, max_k: 3 };
        let classical = ClassicalApriori::default().mine(&db, &cfg);
        let son = SonApriori::new(ClusterConfig::fhssc(2), cfg)
            .with_split_tx(100) // 5 full + 1 five-tx split
            .mine(&db)
            .unwrap();
        assert_eq!(son.result.frequent, classical.frequent);
    }
}

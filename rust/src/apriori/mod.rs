//! Apriori frequent-itemset mining: the paper's application layer.
//!
//! * level-wise candidate generation ([`candidates`]) — the F(k-1)⋈F(k-1)
//!   join with subset pruning;
//! * candidate matchers — [`hash_tree`] (Agrawal & Srikant's original
//!   structure) and [`trie`] (prefix-tree alternative);
//! * the Map/Reduce jobs ([`mr`]) the coordinator schedules per level;
//! * single-machine baselines from the paper's related work [8]:
//!   [`classical`], [`record_filter`], [`intersection`] (tidsets), plus
//!   [`fp_growth`] as the stronger published comparator;
//! * association-[`rules`] generation from the mined itemsets;
//! * extensions: [`son`] (two-job partition/SON Map-Reduce Apriori) and
//!   [`postprocess`] (closed/maximal itemset reduction).

pub mod candidates;
pub mod classical;
pub mod fp_growth;
pub mod hash_tree;
pub mod intersection;
pub mod mr;
pub mod postprocess;
pub mod record_filter;
pub mod rules;
pub mod son;
pub mod trie;

use crate::data::ItemId;

/// A sorted, deduplicated itemset. Kept as a plain `Vec` — itemsets are
/// short (k ≤ ~10) and the sort order is the canonical form every module
/// relies on.
pub type Itemset = Vec<ItemId>;

/// Mining parameters shared by every algorithm in this crate.
#[derive(Debug, Clone)]
pub struct AprioriConfig {
    /// Minimum support as a fraction of |D| (0, 1].
    pub min_support: f64,
    /// Stop after this level even if candidates remain (0 = unbounded).
    pub max_k: usize,
}

impl Default for AprioriConfig {
    fn default() -> Self {
        Self { min_support: 0.01, max_k: 0 }
    }
}

impl AprioriConfig {
    /// Absolute support threshold for a database of `n_tx` transactions
    /// (ceil, min 1 — an itemset must appear at least once).
    pub fn threshold(&self, n_tx: usize) -> u64 {
        ((self.min_support * n_tx as f64).ceil() as u64).max(1)
    }

    pub fn level_allowed(&self, k: usize) -> bool {
        self.max_k == 0 || k <= self.max_k
    }
}

/// Per-level execution record.
#[derive(Debug, Clone, Default)]
pub struct LevelStats {
    pub k: usize,
    pub n_candidates: usize,
    pub n_frequent: usize,
    /// Work units spent counting this level (tx·candidate probes).
    pub work_units: f64,
    pub wall_secs: f64,
}

/// The output of any miner: frequent itemsets with absolute supports,
/// sorted by (len, lexicographic) — a canonical order every algorithm
/// produces so results are directly comparable.
#[derive(Debug, Clone, Default)]
pub struct MiningResult {
    pub frequent: Vec<(Itemset, u64)>,
    pub levels: Vec<LevelStats>,
    pub n_transactions: usize,
}

impl MiningResult {
    /// Canonicalize ordering (miners call this before returning).
    pub fn normalize(&mut self) {
        self.frequent
            .sort_by(|a, b| (a.0.len(), &a.0).cmp(&(b.0.len(), &b.0)));
    }

    /// Frequent itemsets of one size.
    pub fn level(&self, k: usize) -> impl Iterator<Item = &(Itemset, u64)> {
        self.frequent.iter().filter(move |(is, _)| is.len() == k)
    }

    /// Support lookup (linear scan; result sets are small).
    pub fn support_of(&self, itemset: &[ItemId]) -> Option<u64> {
        self.frequent
            .iter()
            .find(|(is, _)| is.as_slice() == itemset)
            .map(|&(_, s)| s)
    }

    /// Total counting work across levels.
    pub fn total_work(&self) -> f64 {
        self.levels.iter().map(|l| l.work_units).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn threshold_math() {
        let c = AprioriConfig { min_support: 0.1, max_k: 0 };
        assert_eq!(c.threshold(100), 10);
        assert_eq!(c.threshold(101), 11); // ceil
        assert_eq!(c.threshold(5), 1);
        let tiny = AprioriConfig { min_support: 0.0001, max_k: 0 };
        assert_eq!(tiny.threshold(100), 1); // floor at 1
    }

    #[test]
    fn level_gate() {
        let unbounded = AprioriConfig::default();
        assert!(unbounded.level_allowed(99));
        let capped = AprioriConfig { max_k: 2, ..Default::default() };
        assert!(capped.level_allowed(2));
        assert!(!capped.level_allowed(3));
    }

    #[test]
    fn result_normalize_and_lookup() {
        let mut r = MiningResult {
            frequent: vec![
                (vec![1, 2], 5),
                (vec![0], 9),
                (vec![1], 7),
                (vec![0, 1, 2], 2),
            ],
            levels: vec![],
            n_transactions: 10,
        };
        r.normalize();
        assert_eq!(r.frequent[0].0, vec![0]);
        assert_eq!(r.frequent[2].0, vec![1, 2]);
        assert_eq!(r.frequent[3].0, vec![0, 1, 2]);
        assert_eq!(r.support_of(&[1, 2]), Some(5));
        assert_eq!(r.support_of(&[9]), None);
        assert_eq!(r.level(1).count(), 2);
        assert_eq!(r.level(3).count(), 1);
    }
}

//! The paper's Map/Reduce jobs: one counting job per Apriori level.
//!
//! §3.3 of the paper, made concrete:
//!
//! * **Level 1** ([`ItemCountApp`]): map emits `(item, 1)` per item
//!   occurrence in its split; combine/reduce sum; the reducer applies the
//!   min-support filter (`reduce` returning `None` drops the key).
//! * **Level k ≥ 2** ([`CandidateCountApp`]): the candidate set — the
//!   paper's "subsets file" — is broadcast to every mapper (Hadoop's
//!   distributed-cache pattern). Each map task counts all candidates
//!   against its split through a pluggable [`SupportEngine`] (hash tree,
//!   trie, or the Pallas/PJRT tensor path) and emits `(itemset, count)`
//!   only for non-zero counts; the reducer sums partials and filters.
//!
//! Keys are full itemsets (not indices), exactly like the paper's
//! `<Key, Value>` design — the shuffle dedupes/aggregates by itemset.

use crate::data::columnar::FlatBlock;
use crate::data::{split::Split, Transaction};
use crate::engine::{IndexCache, SupportEngine, VerticalIndex};
use crate::mapreduce::app::MapReduceApp;

use super::Itemset;

/// Level-1 job: count item supports, filter by threshold.
pub struct ItemCountApp {
    /// Absolute min-support threshold (already scaled by |D|).
    pub threshold: u64,
    /// Emit *all* counted items from reduce, below-threshold ones
    /// included — the state-capture mode the incremental subsystem uses
    /// to learn negative-border supports. The frequent/border split then
    /// happens at the coordinator, which also zero-fills items the map
    /// never saw.
    pub capture_all: bool,
}

impl ItemCountApp {
    pub fn new(threshold: u64) -> Self {
        Self { threshold, capture_all: false }
    }
}

impl MapReduceApp for ItemCountApp {
    type K = Itemset;
    type V = u64;

    fn map(&self, _s: &Split, input: &[Transaction], emit: &mut dyn FnMut(Itemset, u64)) {
        for t in input {
            for &item in &t.items {
                emit(vec![item], 1);
            }
        }
    }

    fn combine(&self, _k: &Itemset, values: &[u64]) -> Option<u64> {
        Some(values.iter().sum())
    }

    fn reduce(&self, _k: &Itemset, values: &[u64]) -> Option<u64> {
        let support: u64 = values.iter().sum();
        (self.capture_all || support >= self.threshold).then_some(support)
    }

    fn map_cost_hint(&self, n_tx: usize) -> f64 {
        n_tx as f64 * 10.0 // one probe per item occurrence, avg basket ~10
    }

    fn record_bytes_hint(&self) -> usize {
        12 // one item id + count
    }
}

/// Level-k job (k ≥ 2): candidates broadcast, counting via an engine.
///
/// `candidates` may mix adjacent levels (the pipelined driver's batched
/// jobs, SON's phase 2): counting then goes through the engine's
/// shared-scan [`count_batch`](SupportEngine::count_batch) path, so one
/// pass over the split serves every level in the batch. The per-length
/// grouping is computed once at construction — map tasks run once per
/// split and must not regroup.
pub struct CandidateCountApp<'e> {
    pub candidates: Vec<Itemset>,
    groups: crate::engine::LevelGroups,
    pub engine: &'e dyn SupportEngine,
    /// Dictionary width for the engine (tensor tile selection).
    pub n_items: usize,
    pub threshold: u64,
    /// Keep below-threshold counts in the reduce output (state capture /
    /// targeted exact scans). Zero-count candidates are still absent —
    /// the map never emits them — so callers zero-fill from the known
    /// candidate list.
    pub capture_all: bool,
    /// Resident index cache + the generation this job counts under.
    /// When set, map tasks fetch (or build once) the split's
    /// [`VerticalIndex`] keyed by `(split.id, generation)` instead of
    /// calling the engine — only valid when the engine is the vertical
    /// one, which the coordinator guarantees before attaching.
    cache: Option<(&'e IndexCache, u64)>,
}

impl<'e> CandidateCountApp<'e> {
    pub fn new(
        candidates: Vec<Itemset>,
        engine: &'e dyn SupportEngine,
        n_items: usize,
        threshold: u64,
    ) -> Self {
        let groups = crate::engine::LevelGroups::build(&candidates);
        Self {
            candidates,
            groups,
            engine,
            n_items,
            threshold,
            capture_all: false,
            cache: None,
        }
    }

    /// State-capture mode: reduce emits every counted candidate, the
    /// threshold only partitions frequent from border at the caller.
    pub fn with_capture(mut self) -> Self {
        self.capture_all = true;
        self
    }

    /// Route this job's map tasks through the resident [`IndexCache`]
    /// under `generation`. Every job of the same dataset view passes the
    /// same generation, so the first map task per split builds the index
    /// and every later job (or speculative twin) reuses it.
    pub fn with_cache(mut self, cache: &'e IndexCache, generation: u64) -> Self {
        self.cache = Some((cache, generation));
        self
    }
}

impl<'e> MapReduceApp for CandidateCountApp<'e> {
    type K = Itemset;
    type V = u64;

    fn map(&self, s: &Split, input: &[Transaction], emit: &mut dyn FnMut(Itemset, u64)) {
        let counts = match self.cache {
            Some((cache, generation)) => {
                let index = cache.get_or_build(s.id, generation, || {
                    VerticalIndex::build(&FlatBlock::from_transactions(input, self.n_items))
                });
                self.groups.count_with_index(&index, &self.candidates)
            }
            None => self
                .groups
                .count(self.engine, input, &self.candidates, self.n_items)
                .expect("support engine failed in map task"),
        };
        for (cand, count) in self.candidates.iter().zip(counts) {
            if count > 0 {
                emit(cand.clone(), count);
            }
        }
    }

    // Map output is already aggregated per split; the combiner would be a
    // no-op sum over singleton groups, but keep it for speculative twins.
    fn combine(&self, _k: &Itemset, values: &[u64]) -> Option<u64> {
        Some(values.iter().sum())
    }

    fn reduce(&self, _k: &Itemset, values: &[u64]) -> Option<u64> {
        let support: u64 = values.iter().sum();
        (self.capture_all || support >= self.threshold).then_some(support)
    }

    fn map_cost_hint(&self, n_tx: usize) -> f64 {
        (n_tx * self.candidates.len().max(1)) as f64
    }

    fn reduce_cost_hint(&self, n_values: usize) -> f64 {
        n_values as f64
    }

    fn record_bytes_hint(&self) -> usize {
        // k item ids (4B each) + 8B count; k≈3 typical
        20
    }

    fn n_candidates(&self) -> usize {
        self.candidates.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apriori::classical::tests::textbook_db;
    use crate::apriori::{candidates, AprioriConfig};
    use crate::cluster::ClusterConfig;
    use crate::data::split::plan_splits;
    use crate::dfs::Dfs;
    use crate::engine::{HashTreeEngine, NaiveEngine, VerticalEngine};
    use crate::mapreduce::{JobConfig, JobRunner};

    fn run_app<A: MapReduceApp>(app: &A, n_nodes: usize) -> Vec<(A::K, A::V)> {
        let db = textbook_db();
        let splits = plan_splits(&db, 3);
        let cluster = ClusterConfig::fhssc(n_nodes);
        let mut dfs = Dfs::new(&cluster);
        let blocks = dfs.write_splits(&splits).unwrap();
        let runner = JobRunner::new(&cluster, &dfs, &blocks);
        let cfg = JobConfig { n_reducers: 2, ..Default::default() };
        runner.run(app, &db, &splits, &cfg).unwrap().0
    }

    #[test]
    fn item_count_level1_matches_textbook() {
        let cfg = AprioriConfig { min_support: 2.0 / 9.0, max_k: 0 };
        let out = run_app(&ItemCountApp::new(cfg.threshold(9)), 3);
        assert_eq!(
            out,
            vec![
                (vec![0], 6),
                (vec![1], 7),
                (vec![2], 6),
                (vec![3], 2),
                (vec![4], 2),
            ]
        );
    }

    #[test]
    fn candidate_count_level2_matches_textbook() {
        let f1: Vec<Itemset> = vec![vec![0], vec![1], vec![2], vec![3], vec![4]];
        let c2 = candidates::generate(&f1);
        let app = CandidateCountApp::new(c2, &HashTreeEngine, 5, 2);
        let out = run_app(&app, 3);
        assert_eq!(
            out,
            vec![
                (vec![0, 1], 4),
                (vec![0, 2], 4),
                (vec![0, 4], 2),
                (vec![1, 2], 4),
                (vec![1, 3], 2),
                (vec![1, 4], 2),
            ]
        );
    }

    #[test]
    fn engines_produce_identical_job_output() {
        let f1: Vec<Itemset> = (0..5u32).map(|i| vec![i]).collect();
        let c2 = candidates::generate(&f1);
        let a = run_app(&CandidateCountApp::new(c2.clone(), &HashTreeEngine, 5, 1), 2);
        let b = run_app(&CandidateCountApp::new(c2.clone(), &NaiveEngine, 5, 1), 2);
        let c = run_app(&CandidateCountApp::new(c2, &VerticalEngine, 5, 1), 2);
        assert_eq!(a, b);
        assert_eq!(a, c);
    }

    #[test]
    fn batched_job_counts_identically_through_the_vertical_engine() {
        // The vertical engine's count_batch shares one index build across
        // both levels of a batched job; the job output must still be
        // byte-identical to the horizontal matcher's.
        let f1: Vec<Itemset> = (0..5u32).map(|i| vec![i]).collect();
        let c2 = candidates::generate(&f1);
        let c3 = candidates::generate(&c2);
        let mut mixed = c2;
        mixed.extend(c3);
        let a = run_app(&CandidateCountApp::new(mixed.clone(), &HashTreeEngine, 5, 1), 3);
        let b = run_app(&CandidateCountApp::new(mixed, &VerticalEngine, 5, 1), 3);
        assert_eq!(a, b);
    }

    #[test]
    fn batched_two_level_job_matches_per_level_jobs() {
        let f1: Vec<Itemset> = (0..5u32).map(|i| vec![i]).collect();
        let c2 = candidates::generate(&f1);
        let c3 = candidates::generate(&c2);
        assert!(!c3.is_empty());
        let run = |cands: Vec<Itemset>| {
            run_app(&CandidateCountApp::new(cands, &HashTreeEngine, 5, 1), 3)
        };
        let mut mixed = c2.clone();
        mixed.extend(c3.clone());
        let mut batched = run(mixed);
        let mut separate = run(c2);
        separate.extend(run(c3));
        batched.sort();
        separate.sort();
        assert_eq!(batched, separate);
    }

    #[test]
    fn threshold_filters_in_reduce() {
        let app = ItemCountApp::new(7);
        let out = run_app(&app, 2);
        assert_eq!(out, vec![(vec![1], 7)]); // only item 1 reaches 7
    }

    #[test]
    fn capture_mode_keeps_below_threshold_counts() {
        // capture_all bypasses only the reduce filter: the same counts
        // come back, plus every below-threshold key the maps emitted.
        let filtered = run_app(&ItemCountApp::new(6), 3);
        let captured = run_app(&ItemCountApp { threshold: 6, capture_all: true }, 3);
        assert_eq!(captured.len(), 5); // all five items of the textbook db
        for (is, s) in &filtered {
            assert_eq!(captured.iter().find(|(c, _)| c == is), Some(&(is.clone(), *s)));
        }
        assert!(captured.iter().any(|(_, s)| *s < 6));

        let f1: Vec<Itemset> = (0..5u32).map(|i| vec![i]).collect();
        let c2 = candidates::generate(&f1);
        let strict = run_app(&CandidateCountApp::new(c2.clone(), &HashTreeEngine, 5, 4), 3);
        let capture =
            run_app(&CandidateCountApp::new(c2, &HashTreeEngine, 5, 4).with_capture(), 3);
        assert!(capture.len() > strict.len());
        for (is, s) in &strict {
            assert_eq!(capture.iter().find(|(c, _)| c == is), Some(&(is.clone(), *s)));
        }
    }

    #[test]
    fn cost_hints_scale() {
        let app = CandidateCountApp::new(vec![vec![0, 1]; 50], &HashTreeEngine, 5, 1);
        assert_eq!(app.map_cost_hint(100), 5000.0);
        assert!(ItemCountApp::new(1).map_cost_hint(10) > 0.0);
    }
}

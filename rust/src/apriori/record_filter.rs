//! "Record filter" Apriori — the first of reference [8]'s (Goswami et al.)
//! three approaches: at level k, only transactions with at least k items
//! can possibly contain a k-candidate, so the counting scan keeps a
//! *shrinking working set* of records, physically dropping short
//! transactions between levels instead of re-testing them.

use std::time::Instant;

use crate::data::{Transaction, TransactionDb};

use super::candidates;
use super::hash_tree::HashTree;
use super::{AprioriConfig, Itemset, LevelStats, MiningResult};

/// Record-filter miner.
#[derive(Debug, Clone, Default)]
pub struct RecordFilterApriori;

impl RecordFilterApriori {
    pub fn mine(&self, db: &TransactionDb, cfg: &AprioriConfig) -> MiningResult {
        let threshold = cfg.threshold(db.len());
        let mut result = MiningResult {
            n_transactions: db.len(),
            ..Default::default()
        };
        // The working set: shrinks as k grows (the algorithm's whole idea).
        let mut records: Vec<Transaction> = db.transactions.clone();
        let mut k = 1usize;
        let mut cands = candidates::unit_candidates(db.n_items);
        while !cands.is_empty() && cfg.level_allowed(k) {
            let t0 = Instant::now();
            // filter: drop records shorter than k (they can't contain any
            // k-candidate; supports over the full db are unaffected).
            records.retain(|t| t.len() >= k);
            let counts = HashTree::build(&cands).count_all(&records);
            let mut frequent_k: Vec<(Itemset, u64)> = cands
                .iter()
                .cloned()
                .zip(counts)
                .filter(|&(_, c)| c >= threshold)
                .collect();
            frequent_k.sort_by(|a, b| a.0.cmp(&b.0));
            result.levels.push(LevelStats {
                k,
                n_candidates: cands.len(),
                n_frequent: frequent_k.len(),
                // the saving: work scales with the filtered record count
                work_units: (cands.len() * records.len()) as f64,
                wall_secs: t0.elapsed().as_secs_f64(),
            });
            let fk: Vec<Itemset> = frequent_k.iter().map(|(is, _)| is.clone()).collect();
            result.frequent.extend(frequent_k);
            if fk.is_empty() {
                break;
            }
            cands = candidates::generate(&fk);
            k += 1;
        }
        result.normalize();
        result
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apriori::classical::{tests::textbook_db, ClassicalApriori};
    use crate::data::quest::{QuestGenerator, QuestParams};

    #[test]
    fn matches_classical_on_textbook() {
        let db = textbook_db();
        let cfg = AprioriConfig { min_support: 2.0 / 9.0, max_k: 0 };
        let a = ClassicalApriori::default().mine(&db, &cfg);
        let b = RecordFilterApriori.mine(&db, &cfg);
        assert_eq!(a.frequent, b.frequent);
    }

    #[test]
    fn matches_classical_on_quest() {
        let db = QuestGenerator::new(QuestParams::goswami_2k()).generate();
        let cfg = AprioriConfig { min_support: 0.05, max_k: 0 };
        let a = ClassicalApriori::default().mine(&db, &cfg);
        let b = RecordFilterApriori.mine(&db, &cfg);
        assert_eq!(a.frequent, b.frequent);
    }

    #[test]
    fn filtering_reduces_work_at_deep_levels() {
        // A db mixing singleton and long transactions: by k=2 the
        // singletons are filtered, so work_units must undercut classical's.
        let mut txs: Vec<Transaction> = (0..300u32).map(|i| Transaction::new([i % 10])).collect();
        txs.extend((0..100u32).map(|_| Transaction::new([0u32, 1, 2, 3])));
        let db = TransactionDb::new(txs);
        let cfg = AprioriConfig { min_support: 0.05, max_k: 0 };
        let cl = ClassicalApriori::default().mine(&db, &cfg);
        let rf = RecordFilterApriori.mine(&db, &cfg);
        assert_eq!(cl.frequent, rf.frequent);
        let cl_k2 = cl.levels.iter().find(|l| l.k == 2).unwrap();
        let rf_k2 = rf.levels.iter().find(|l| l.k == 2).unwrap();
        assert!(
            rf_k2.work_units < cl_k2.work_units / 2.0,
            "record filter should cut k=2 work: {} vs {}",
            rf_k2.work_units,
            cl_k2.work_units
        );
    }
}

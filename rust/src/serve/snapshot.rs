//! Atomic snapshot hot-swap: the hand-rolled `arc-swap` substitute
//! (DESIGN.md §Substitutions).
//!
//! A [`SnapshotCell`] holds the current [`Arc`] of an immutable value
//! (for serving, a [`super::index::RuleIndex`]). Readers [`load`] a clone
//! of the `Arc`; a refresher [`store`]s a replacement built entirely
//! off-cell. The mutex guards only the pointer-sized clone/swap — never
//! an index rebuild — so readers cannot block behind a refresh, and a
//! reader that loaded the old generation keeps a valid `Arc` for as long
//! as it needs (no torn or dangling reads, by `Arc`'s refcount).
//!
//! Each successful `store` bumps a generation counter, published with
//! `Release`/`Acquire` ordering so a reader that observes generation `g`
//! via [`generation`] is guaranteed a subsequent `load` returns that
//! generation or newer. Responses carry the generation they were served
//! from, which is what lets the differential bench attribute every answer
//! to exactly one snapshot.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// A swappable `Arc` cell with a monotonically increasing generation.
#[derive(Debug)]
pub struct SnapshotCell<T> {
    current: Mutex<Arc<T>>,
    generation: AtomicU64,
}

impl<T> SnapshotCell<T> {
    /// Wrap an initial snapshot as generation 0.
    pub fn new(initial: Arc<T>) -> Self {
        Self::with_generation(initial, 0)
    }

    /// Wrap an initial snapshot at an explicit generation — the warm
    /// restart path: a recovered snapshot keeps its persisted generation
    /// number, so response generations continue the pre-kill sequence.
    pub fn with_generation(initial: Arc<T>, generation: u64) -> Self {
        Self {
            current: Mutex::new(initial),
            generation: AtomicU64::new(generation),
        }
    }

    /// The current snapshot. The critical section is one `Arc` clone.
    pub fn load(&self) -> Arc<T> {
        self.current.lock().unwrap().clone()
    }

    /// Snapshot plus the generation it belongs to, read atomically
    /// (both under the same lock acquisition).
    pub fn load_with_generation(&self) -> (Arc<T>, u64) {
        let guard = self.current.lock().unwrap();
        let snap = guard.clone();
        let generation = self.generation.load(Ordering::Acquire);
        (snap, generation)
    }

    /// Publish a new snapshot; returns its generation.
    pub fn store(&self, next: Arc<T>) -> u64 {
        let mut guard = self.current.lock().unwrap();
        let old = std::mem::replace(&mut *guard, next);
        let generation = self.generation.fetch_add(1, Ordering::AcqRel) + 1;
        drop(guard);
        // If this was the last reference, tearing the old index down can
        // be expensive — do it after the lock so readers never wait on it.
        drop(old);
        generation
    }

    /// Generation of the most recently published snapshot.
    pub fn generation(&self) -> u64 {
        self.generation.load(Ordering::Acquire)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicBool;

    #[test]
    fn store_bumps_generation_and_load_sees_it() {
        let cell = SnapshotCell::new(Arc::new(1u64));
        assert_eq!(cell.generation(), 0);
        assert_eq!(*cell.load(), 1);
        assert_eq!(cell.store(Arc::new(2)), 1);
        assert_eq!(cell.generation(), 1);
        let (snap, generation) = cell.load_with_generation();
        assert_eq!((*snap, generation), (2, 1));
    }

    #[test]
    fn with_generation_resumes_the_sequence() {
        let cell = SnapshotCell::with_generation(Arc::new(7u64), 41);
        assert_eq!(cell.generation(), 41);
        assert_eq!(*cell.load(), 7);
        assert_eq!(cell.store(Arc::new(8)), 42);
        let (snap, generation) = cell.load_with_generation();
        assert_eq!((*snap, generation), (8, 42));
    }

    #[test]
    fn old_snapshot_outlives_the_swap() {
        let cell = SnapshotCell::new(Arc::new(vec![7u64; 64]));
        let held = cell.load();
        cell.store(Arc::new(vec![8u64; 64]));
        // the pre-swap reader still sees a fully intact old snapshot
        assert!(held.iter().all(|&x| x == 7));
        assert!(cell.load().iter().all(|&x| x == 8));
    }

    #[test]
    fn concurrent_readers_never_see_torn_snapshots() {
        // Each snapshot is internally self-consistent (all elements equal);
        // a torn read would surface as a mixed vector or a generation that
        // was never published.
        let cell = Arc::new(SnapshotCell::new(Arc::new(vec![0u64; 256])));
        let stop = Arc::new(AtomicBool::new(false));
        let readers: Vec<_> = (0..4)
            .map(|_| {
                let cell = Arc::clone(&cell);
                let stop = Arc::clone(&stop);
                std::thread::spawn(move || {
                    let mut last_generation = 0;
                    while !stop.load(Ordering::Relaxed) {
                        let (snap, generation) = cell.load_with_generation();
                        let first = snap[0];
                        assert!(snap.iter().all(|&x| x == first), "torn snapshot");
                        assert_eq!(first, generation, "snapshot/generation mismatch");
                        assert!(generation >= last_generation, "generation went backwards");
                        last_generation = generation;
                    }
                })
            })
            .collect();
        for generation in 1..=100u64 {
            cell.store(Arc::new(vec![generation; 256]));
        }
        stop.store(true, Ordering::Relaxed);
        for r in readers {
            r.join().unwrap();
        }
        assert_eq!(cell.generation(), 100);
    }
}

//! The online front-end: a multi-threaded [`RuleServer`] draining a
//! bounded request queue against the current [`RuleIndex`] snapshot.
//!
//! Shape, mirroring a production rule-serving tier:
//!
//! * **admission control** — [`BoundedQueue::try_push`] never blocks the
//!   caller: a full queue rejects the request (load shedding) instead of
//!   growing an unbounded backlog, and the rejection is counted;
//! * **worker pool** — `workers` OS threads pop requests, [`load`] the
//!   snapshot once per request (one `Arc` clone; never blocked by a
//!   concurrent refresh), answer from the immutable index, and reply
//!   through a per-request channel;
//! * **tail latency** — every request records enqueue-to-answer latency
//!   into a shared wait-free [`LatencyHistogram`], so p50/p95/p99 come
//!   from the server itself, not the load generator.
//!
//! [`load`]: super::snapshot::SnapshotCell::load

use std::collections::VecDeque;
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::apriori::rules::Rule;
use crate::data::ItemId;
use crate::fabric::QueryRouter;
use crate::metrics::histogram::{HistogramSnapshot, LatencyHistogram};
use crate::metrics::Counter;
use crate::obs::{MetricsRegistry, RegistryError, TraceCtx};

use super::index::{render_lines, RuleIndex};
use super::snapshot::SnapshotCell;

/// Why a request was not (or will never be) answered.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ServeError {
    /// Admission control shed the request: the queue was at capacity.
    QueueFull,
    /// The request aged past the configured deadline while queued; the
    /// worker shed it instead of computing a stale answer.
    DeadlineExceeded,
    /// The server is shutting down and accepts no new requests.
    Closed,
    /// The worker disappeared before replying (it panicked).
    Lost,
    /// Fabric backend only: a shard had no live replica, so a complete
    /// (byte-identical) answer was impossible. A partial answer is never
    /// returned.
    Unavailable,
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Self::QueueFull => write!(f, "request rejected: queue at capacity"),
            Self::DeadlineExceeded => write!(f, "request shed: deadline exceeded in queue"),
            Self::Closed => write!(f, "server is shut down"),
            Self::Lost => write!(f, "worker dropped the request"),
            Self::Unavailable => write!(f, "a shard has no live replica"),
        }
    }
}

impl std::error::Error for ServeError {}

/// Rejected push, handing the item back to the caller.
#[derive(Debug)]
pub enum PushError<T> {
    Full(T),
    Closed(T),
}

/// Admission class of a request — which lane of the two-class queue it
/// takes. Workers drain the user lane strictly first, so background
/// traffic (refresh-triggered probe queries) can never starve user
/// requests; each lane has its own capacity and its own shed counters.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum QueryClass {
    /// Foreground traffic: the lane with strict priority.
    #[default]
    User,
    /// Background traffic (refresh validation probes, warm-up): served
    /// only when the user lane is empty, from its own smaller lane.
    Internal,
}

/// A bounded two-class MPMC queue: non-blocking producers (admission
/// control, per-lane capacity), blocking consumers (worker parking) that
/// drain the user lane strictly before the internal one. Close-able for
/// shutdown.
#[derive(Debug)]
pub struct BoundedQueue<T> {
    state: Mutex<QueueState<T>>,
    ready: Condvar,
    capacity: usize,
    internal_capacity: usize,
}

#[derive(Debug)]
struct QueueState<T> {
    user: VecDeque<T>,
    internal: VecDeque<T>,
    closed: bool,
}

impl<T> BoundedQueue<T> {
    /// Single-class constructor: the internal lane gets the same
    /// capacity as the user lane.
    pub fn new(capacity: usize) -> Self {
        Self::with_lanes(capacity, capacity)
    }

    /// Two-class constructor with separate per-lane capacities.
    pub fn with_lanes(capacity: usize, internal_capacity: usize) -> Self {
        assert!(capacity > 0, "queue capacity must be >= 1");
        assert!(internal_capacity > 0, "internal queue capacity must be >= 1");
        Self {
            state: Mutex::new(QueueState {
                user: VecDeque::new(),
                internal: VecDeque::new(),
                closed: false,
            }),
            ready: Condvar::new(),
            capacity,
            internal_capacity,
        }
    }

    /// Admit `item` into the user lane if there is room; never blocks.
    pub fn try_push(&self, item: T) -> Result<(), PushError<T>> {
        self.try_push_class(item, QueryClass::User)
    }

    /// Admit `item` into its class's lane if there is room; never
    /// blocks, and never counts one lane's backlog against the other's
    /// capacity.
    pub fn try_push_class(&self, item: T, class: QueryClass) -> Result<(), PushError<T>> {
        let mut st = self.state.lock().unwrap();
        if st.closed {
            return Err(PushError::Closed(item));
        }
        let (lane, cap) = match class {
            QueryClass::User => (&mut st.user, self.capacity),
            QueryClass::Internal => (&mut st.internal, self.internal_capacity),
        };
        if lane.len() >= cap {
            return Err(PushError::Full(item));
        }
        lane.push_back(item);
        self.ready.notify_one();
        Ok(())
    }

    /// Block until an item is available; user lane strictly first;
    /// `None` once closed and both lanes drained.
    pub fn pop(&self) -> Option<T> {
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(item) = st.user.pop_front() {
                return Some(item);
            }
            if let Some(item) = st.internal.pop_front() {
                return Some(item);
            }
            if st.closed {
                return None;
            }
            st = self.ready.wait(st).unwrap();
        }
    }

    /// Stop admitting; consumers drain the backlog, then see `None`.
    pub fn close(&self) {
        self.state.lock().unwrap().closed = true;
        self.ready.notify_all();
    }

    /// Total queued items across both lanes.
    pub fn len(&self) -> usize {
        let st = self.state.lock().unwrap();
        st.user.len() + st.internal.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// One answered basket query.
#[derive(Debug, Clone)]
pub struct QueryResponse {
    /// Snapshot generation the answer was computed from.
    pub generation: u64,
    /// Top-k rules, in the index's deterministic global order.
    pub recommendations: Vec<Rule>,
}

impl QueryResponse {
    /// Canonical wire form — what the differential checks byte-compare.
    pub fn render(&self) -> String {
        render_lines(&self.recommendations)
    }
}

/// A submitted request's reply handle.
#[derive(Debug)]
pub struct QueryTicket {
    rx: mpsc::Receiver<Result<QueryResponse, ServeError>>,
}

impl QueryTicket {
    /// Block until the worker answers (or sheds the request — a queued
    /// request that outlives the deadline waits out as
    /// [`ServeError::DeadlineExceeded`]).
    pub fn wait(self) -> Result<QueryResponse, ServeError> {
        self.rx.recv().map_err(|_| ServeError::Lost)?
    }
}

/// Worker-pool sizing, admission bounds, and the queue deadline.
#[derive(Debug, Clone)]
pub struct ServeOptions {
    pub workers: usize,
    pub queue_depth: usize,
    /// Capacity of the internal (background) lane. Internal traffic has
    /// its own, typically smaller, admission bound and is only served
    /// when the user lane is empty.
    pub internal_queue_depth: usize,
    /// Shed a request that has waited in the queue at least this long by
    /// the time a worker picks it up — bounded staleness under overload,
    /// counted separately from queue-overflow sheds. `None` disables it;
    /// `Some(Duration::ZERO)` sheds unconditionally (the comparison is
    /// inclusive, so it cannot depend on clock granularity).
    pub deadline: Option<std::time::Duration>,
    /// Tracing hook: when set, every answered request opens a `request`
    /// span as a fresh trace rooted in this context's sink (one trace id
    /// per request), and the fabric backend nests its scatter + RPC
    /// spans beneath it. `None` — the default — is the zero-cost off
    /// path.
    pub trace: Option<TraceCtx>,
}

impl Default for ServeOptions {
    fn default() -> Self {
        Self {
            workers: 2,
            queue_depth: 64,
            internal_queue_depth: 16,
            deadline: None,
            trace: None,
        }
    }
}

/// Counters + latency view at one point in time. All shed counters are
/// per class: user traffic and internal (background) traffic never blur.
#[derive(Debug, Clone)]
pub struct ServerStats {
    /// User requests answered.
    pub served: u64,
    /// User overflow sheds: admission control turned the request away.
    pub rejected: u64,
    /// User deadline sheds: admitted, but aged out before a worker got
    /// to it. Never recorded into the latency histogram — tails describe
    /// answered requests only.
    pub deadline_shed: u64,
    /// Internal (background-lane) requests answered. Internal answers
    /// are excluded from the latency histogram too: tails describe the
    /// user-facing SLO.
    pub internal_served: u64,
    /// Internal overflow sheds (the internal lane's own capacity).
    pub internal_rejected: u64,
    /// Internal deadline sheds.
    pub internal_deadline_shed: u64,
    /// Fabric backend only: queries refused because a shard lost every
    /// replica. Always 0 on the local backend.
    pub unavailable: u64,
    pub latency: HistogramSnapshot,
}

struct Job {
    basket: Vec<ItemId>,
    top_k: usize,
    class: QueryClass,
    enqueued: Instant,
    reply: mpsc::Sender<Result<QueryResponse, ServeError>>,
}

/// What answers a query: the classic single-process index, or the
/// sharded serving fabric (scatter-gather with replica failover). Both
/// produce byte-identical answers per generation; only cost, capacity,
/// and failure modes differ.
#[derive(Debug, Clone)]
pub enum Backend {
    /// One in-process `RuleIndex` behind a hot-swap cell.
    Local(Arc<SnapshotCell<RuleIndex>>),
    /// The sharded fabric: `QueryRouter` scatter-gather.
    Fabric(Arc<QueryRouter>),
}

impl Backend {
    fn answer(
        &self,
        basket: &[ItemId],
        top_k: usize,
        ctx: Option<&TraceCtx>,
    ) -> Result<QueryResponse, ServeError> {
        match self {
            Self::Local(cell) => {
                let (index, generation) = cell.load_with_generation();
                Ok(QueryResponse {
                    generation,
                    recommendations: index.recommend(basket, top_k),
                })
            }
            Self::Fabric(router) => match router.route_traced(basket, top_k, ctx) {
                Ok(routed) => Ok(QueryResponse {
                    generation: routed.generation,
                    recommendations: routed.recommendations,
                }),
                Err(_) => Err(ServeError::Unavailable),
            },
        }
    }
}

struct ServerInner {
    backend: Backend,
    queue: BoundedQueue<Job>,
    deadline: Option<std::time::Duration>,
    trace: Option<TraceCtx>,
    // Instruments live behind `Arc` so [`RuleServer::register_metrics`]
    // can share them with a registry; increments stay wait-free.
    served: Arc<Counter>,
    rejected: Arc<Counter>,
    deadline_shed: Arc<Counter>,
    internal_served: Arc<Counter>,
    internal_rejected: Arc<Counter>,
    internal_deadline_shed: Arc<Counter>,
    /// Fabric backend only: queries refused because a shard had no live
    /// replica (never answered partially).
    unavailable: Arc<Counter>,
    latency: Arc<LatencyHistogram>,
}

/// The serving tier. Start it over a [`SnapshotCell`]; refreshes swap the
/// cell underneath while this keeps answering.
pub struct RuleServer {
    inner: Arc<ServerInner>,
    workers: Vec<JoinHandle<()>>,
}

impl RuleServer {
    /// Spawn the worker pool over the classic single-index backend.
    pub fn start(snapshot: Arc<SnapshotCell<RuleIndex>>, opts: ServeOptions) -> Self {
        Self::start_with_backend(Backend::Local(snapshot), opts)
    }

    /// Spawn the worker pool over an explicit backend (local index or
    /// the sharded fabric).
    pub fn start_with_backend(backend: Backend, opts: ServeOptions) -> Self {
        assert!(opts.workers > 0, "need at least one worker");
        let inner = Arc::new(ServerInner {
            backend,
            queue: BoundedQueue::with_lanes(opts.queue_depth, opts.internal_queue_depth),
            deadline: opts.deadline,
            trace: opts.trace,
            served: Arc::new(Counter::new()),
            rejected: Arc::new(Counter::new()),
            deadline_shed: Arc::new(Counter::new()),
            internal_served: Arc::new(Counter::new()),
            internal_rejected: Arc::new(Counter::new()),
            internal_deadline_shed: Arc::new(Counter::new()),
            unavailable: Arc::new(Counter::new()),
            latency: Arc::new(LatencyHistogram::new()),
        });
        let workers = (0..opts.workers)
            .map(|_| {
                let inner = Arc::clone(&inner);
                std::thread::spawn(move || worker_loop(&inner))
            })
            .collect();
        Self { inner, workers }
    }

    /// Non-blocking admission into the user lane: `Err(QueueFull)` is
    /// load shedding, not a failure of the server.
    pub fn submit(&self, basket: &[ItemId], top_k: usize) -> Result<QueryTicket, ServeError> {
        self.submit_class(basket, top_k, QueryClass::User)
    }

    /// Non-blocking admission into the internal (background) lane: the
    /// refresh loop's validation probes go here, so they can never crowd
    /// user traffic out of admission or out of a worker.
    pub fn submit_internal(
        &self,
        basket: &[ItemId],
        top_k: usize,
    ) -> Result<QueryTicket, ServeError> {
        self.submit_class(basket, top_k, QueryClass::Internal)
    }

    fn submit_class(
        &self,
        basket: &[ItemId],
        top_k: usize,
        class: QueryClass,
    ) -> Result<QueryTicket, ServeError> {
        let (tx, rx) = mpsc::channel();
        let job = Job {
            basket: basket.to_vec(),
            top_k,
            class,
            enqueued: Instant::now(),
            reply: tx,
        };
        match self.inner.queue.try_push_class(job, class) {
            Ok(()) => Ok(QueryTicket { rx }),
            Err(PushError::Full(_)) => {
                let counter = match class {
                    QueryClass::User => &self.inner.rejected,
                    QueryClass::Internal => &self.inner.internal_rejected,
                };
                counter.inc();
                Err(ServeError::QueueFull)
            }
            Err(PushError::Closed(_)) => Err(ServeError::Closed),
        }
    }

    /// Closed-loop convenience: submit and wait.
    pub fn query(&self, basket: &[ItemId], top_k: usize) -> Result<QueryResponse, ServeError> {
        self.submit(basket, top_k)?.wait()
    }

    /// Register the server's counters and the user-facing latency
    /// histogram under `prefix` (conventionally `serve`).
    pub fn register_metrics(
        &self,
        registry: &MetricsRegistry,
        prefix: &str,
    ) -> Result<(), RegistryError> {
        let i = &self.inner;
        registry.register_counter(&format!("{prefix}.served"), Arc::clone(&i.served))?;
        registry.register_counter(&format!("{prefix}.rejected"), Arc::clone(&i.rejected))?;
        registry.register_counter(
            &format!("{prefix}.deadline_shed"),
            Arc::clone(&i.deadline_shed),
        )?;
        registry.register_counter(
            &format!("{prefix}.internal.served"),
            Arc::clone(&i.internal_served),
        )?;
        registry.register_counter(
            &format!("{prefix}.internal.rejected"),
            Arc::clone(&i.internal_rejected),
        )?;
        registry.register_counter(
            &format!("{prefix}.internal.deadline_shed"),
            Arc::clone(&i.internal_deadline_shed),
        )?;
        registry.register_counter(&format!("{prefix}.unavailable"), Arc::clone(&i.unavailable))?;
        registry.register_histogram(&format!("{prefix}.latency"), Arc::clone(&i.latency))
    }

    /// The user-facing latency histogram (enqueue-to-answer; internal
    /// refresh probes excluded) — the SLO watcher judges its burn-rate
    /// windows against this.
    pub fn latency_histogram(&self) -> Arc<LatencyHistogram> {
        Arc::clone(&self.inner.latency)
    }

    pub fn stats(&self) -> ServerStats {
        ServerStats {
            served: self.inner.served.get(),
            rejected: self.inner.rejected.get(),
            deadline_shed: self.inner.deadline_shed.get(),
            internal_served: self.inner.internal_served.get(),
            internal_rejected: self.inner.internal_rejected.get(),
            internal_deadline_shed: self.inner.internal_deadline_shed.get(),
            unavailable: self.inner.unavailable.get(),
            latency: self.inner.latency.snapshot(),
        }
    }

    /// Stop admitting, drain the backlog, join the pool.
    pub fn shutdown(mut self) -> ServerStats {
        self.drain();
        self.stats()
    }

    fn drain(&mut self) {
        self.inner.queue.close();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for RuleServer {
    fn drop(&mut self) {
        self.drain();
    }
}

fn worker_loop(inner: &ServerInner) {
    while let Some(job) = inner.queue.pop() {
        // Deadline check at dequeue: under overload a request can age out
        // while queued; answering it would spend worker time on a reply
        // the client has likely abandoned. Shed it (counted apart from
        // overflow sheds, per class; no latency sample — tails are
        // answers only).
        if let Some(deadline) = inner.deadline {
            // Inclusive: Instant is only guaranteed non-decreasing, so a
            // zero deadline must not hinge on elapsed() being nonzero.
            if job.enqueued.elapsed() >= deadline {
                let counter = match job.class {
                    QueryClass::User => &inner.deadline_shed,
                    QueryClass::Internal => &inner.internal_deadline_shed,
                };
                counter.inc();
                let _ = job.reply.send(Err(ServeError::DeadlineExceeded));
                continue;
            }
        }
        // Each answered request is its own trace: a fresh root span in
        // the serve run's sink, so the fabric's scatter + per-replica
        // RPC spans group under one trace id per query.
        let mut span = inner.trace.as_ref().map(|c| {
            let root = TraceCtx::root(Arc::clone(c.sink()));
            let mut s = root.span("serve", "request");
            s.add(
                "class",
                match job.class {
                    QueryClass::User => 0.0,
                    QueryClass::Internal => 1.0,
                },
            );
            s.add("queue_us", job.enqueued.elapsed().as_micros() as f64);
            s.add("top_k", job.top_k as f64);
            s.add("basket_len", job.basket.len() as f64);
            s
        });
        let ctx = span.as_ref().map(|s| s.ctx());
        // One snapshot/cut load per request; a concurrent refresh never
        // blocks this (SnapshotCell's critical section is an Arc clone,
        // and the fabric router loads its cut the same way).
        match inner.backend.answer(&job.basket, job.top_k, ctx.as_ref()) {
            Ok(response) => {
                match job.class {
                    QueryClass::User => {
                        // Only user answers feed the histogram: the tails
                        // are the user-facing SLO, not probe latency.
                        inner.latency.record(job.enqueued.elapsed());
                        inner.served.inc();
                    }
                    QueryClass::Internal => {
                        inner.internal_served.inc();
                    }
                }
                drop(span);
                // A dropped ticket means the client stopped waiting.
                let _ = job.reply.send(Ok(response));
            }
            Err(e) => {
                inner.unavailable.inc();
                if let Some(s) = span.as_mut() {
                    s.add("unavailable", 1.0);
                }
                drop(span);
                let _ = job.reply.send(Err(e));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apriori::classical::{tests::textbook_db, ClassicalApriori};
    use crate::apriori::rules::generate_rules;
    use crate::apriori::AprioriConfig;
    use crate::serve::index::reference_recommend;

    fn textbook_index(min_confidence: f64) -> (Arc<SnapshotCell<RuleIndex>>, Vec<Rule>) {
        let result = ClassicalApriori::default().mine(
            &textbook_db(),
            &AprioriConfig { min_support: 2.0 / 9.0, max_k: 0 },
        );
        let rules = generate_rules(&result, min_confidence);
        let index = RuleIndex::build(&result, min_confidence);
        (Arc::new(SnapshotCell::new(Arc::new(index))), rules)
    }

    #[test]
    fn bounded_queue_rejects_when_full_and_drains_in_order() {
        let q = BoundedQueue::new(2);
        assert!(q.try_push(1).is_ok());
        assert!(q.try_push(2).is_ok());
        match q.try_push(3) {
            Err(PushError::Full(item)) => assert_eq!(item, 3),
            other => panic!("expected Full, got {other:?}"),
        }
        assert_eq!(q.len(), 2);
        assert_eq!(q.pop(), Some(1));
        assert!(q.try_push(4).is_ok());
        q.close();
        match q.try_push(5) {
            Err(PushError::Closed(item)) => assert_eq!(item, 5),
            other => panic!("expected Closed, got {other:?}"),
        }
        // backlog drains even after close, then the sentinel
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), Some(4));
        assert_eq!(q.pop(), None);
        assert!(q.is_empty());
    }

    #[test]
    fn bounded_queue_unblocks_consumers_across_threads() {
        let q = Arc::new(BoundedQueue::new(8));
        let consumer = {
            let q = Arc::clone(&q);
            std::thread::spawn(move || {
                let mut got = Vec::new();
                while let Some(x) = q.pop() {
                    got.push(x);
                }
                got
            })
        };
        for i in 0..20 {
            while q.try_push(i).is_err() {
                std::thread::yield_now();
            }
        }
        q.close();
        let got = consumer.join().unwrap();
        assert_eq!(got, (0..20).collect::<Vec<_>>());
    }

    #[test]
    fn served_answers_equal_direct_reference() {
        let (cell, rules) = textbook_index(0.3);
        let server = RuleServer::start(Arc::clone(&cell), ServeOptions::default());
        for basket in [vec![0u32], vec![0, 1], vec![1, 3], vec![0, 2, 4]] {
            let resp = server.query(&basket, 5).unwrap();
            assert_eq!(resp.generation, 0);
            assert_eq!(
                resp.render(),
                render_lines(&reference_recommend(&rules, &basket, 5)),
                "basket {basket:?}"
            );
        }
        let stats = server.shutdown();
        assert_eq!(stats.served, 4);
        assert_eq!(stats.rejected, 0);
        assert_eq!(stats.latency.count(), 4);
    }

    #[test]
    fn concurrent_clients_all_get_answers() {
        let (cell, _) = textbook_index(0.0);
        let server = Arc::new(RuleServer::start(
            cell,
            ServeOptions { workers: 3, queue_depth: 128, ..Default::default() },
        ));
        let clients: Vec<_> = (0..4)
            .map(|c| {
                let server = Arc::clone(&server);
                std::thread::spawn(move || {
                    let mut answered = 0;
                    for i in 0..50u32 {
                        let basket = vec![(c + i) % 5, i % 3];
                        match server.query(&basket, 3) {
                            Ok(_) => answered += 1,
                            Err(ServeError::QueueFull) => {}
                            Err(e) => panic!("unexpected error: {e}"),
                        }
                    }
                    answered
                })
            })
            .collect();
        let answered: u64 = clients.into_iter().map(|c| c.join().unwrap()).sum();
        // Closed-loop clients never overrun a 128-deep queue.
        assert_eq!(answered, 200);
        let stats = server.stats();
        assert_eq!(stats.served, 200);
        assert_eq!(stats.latency.count(), 200);
    }

    #[test]
    fn zero_deadline_sheds_every_request_and_counts_separately() {
        let (cell, _) = textbook_index(0.3);
        let server = RuleServer::start(
            cell,
            ServeOptions {
                workers: 2,
                queue_depth: 16,
                deadline: Some(std::time::Duration::ZERO),
                ..Default::default()
            },
        );
        for _ in 0..5 {
            assert_eq!(server.query(&[0, 1], 5), Err(ServeError::DeadlineExceeded));
        }
        let stats = server.shutdown();
        assert_eq!(stats.served, 0);
        assert_eq!(stats.rejected, 0); // admission accepted everything
        assert_eq!(stats.deadline_shed, 5); // ...the workers shed it all
        assert_eq!(stats.latency.count(), 0); // sheds leave no samples
    }

    #[test]
    fn generous_deadline_changes_nothing_under_light_load() {
        let (cell, rules) = textbook_index(0.3);
        let server = RuleServer::start(
            cell,
            ServeOptions {
                workers: 2,
                queue_depth: 16,
                deadline: Some(std::time::Duration::from_secs(30)),
                ..Default::default()
            },
        );
        let basket = vec![0u32, 1];
        let resp = server.query(&basket, 5).unwrap();
        assert_eq!(
            resp.render(),
            render_lines(&reference_recommend(&rules, &basket, 5))
        );
        let stats = server.shutdown();
        assert_eq!((stats.served, stats.deadline_shed), (1, 0));
    }

    #[test]
    fn queue_drains_user_lane_strictly_before_internal() {
        let q = BoundedQueue::with_lanes(4, 4);
        q.try_push_class("bg-1", QueryClass::Internal).unwrap();
        q.try_push_class("user-1", QueryClass::User).unwrap();
        q.try_push_class("bg-2", QueryClass::Internal).unwrap();
        q.try_push_class("user-2", QueryClass::User).unwrap();
        q.close();
        // every user item first, then the internal backlog, both FIFO
        assert_eq!(q.pop(), Some("user-1"));
        assert_eq!(q.pop(), Some("user-2"));
        assert_eq!(q.pop(), Some("bg-1"));
        assert_eq!(q.pop(), Some("bg-2"));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn lanes_have_independent_capacity() {
        let q = BoundedQueue::with_lanes(2, 1);
        // a full internal lane never blocks user admission...
        q.try_push_class(0, QueryClass::Internal).unwrap();
        assert!(matches!(
            q.try_push_class(1, QueryClass::Internal),
            Err(PushError::Full(1))
        ));
        q.try_push(2).unwrap();
        q.try_push(3).unwrap();
        // ...and a full user lane never blocks internal admission
        assert!(matches!(q.try_push(4), Err(PushError::Full(4))));
        assert_eq!(q.len(), 3);
    }

    #[test]
    fn internal_probes_answer_without_touching_user_counters() {
        let (cell, rules) = textbook_index(0.3);
        let server = RuleServer::start(Arc::clone(&cell), ServeOptions::default());
        let basket = vec![0u32, 1];
        let resp = server
            .submit_internal(&basket, 5)
            .unwrap()
            .wait()
            .unwrap();
        assert_eq!(
            resp.render(),
            render_lines(&reference_recommend(&rules, &basket, 5))
        );
        let user = server.query(&basket, 5).unwrap();
        assert_eq!(user.render(), resp.render());
        let stats = server.shutdown();
        assert_eq!(stats.served, 1);
        assert_eq!(stats.internal_served, 1);
        assert_eq!(stats.internal_rejected, 0);
        // internal answers leave no latency samples — tails are user SLO
        assert_eq!(stats.latency.count(), 1);
    }

    #[test]
    fn internal_overflow_and_deadline_sheds_count_per_class() {
        let (cell, _) = textbook_index(0.3);
        // no workers pulling yet: start with 1 worker but flood admission
        // first via a zero deadline so everything is shed at dequeue
        let server = RuleServer::start(
            cell,
            ServeOptions {
                workers: 1,
                queue_depth: 16,
                internal_queue_depth: 2,
                deadline: Some(std::time::Duration::ZERO),
                ..Default::default()
            },
        );
        let mut admitted = 0;
        let mut overflowed = 0;
        let mut tickets = Vec::new();
        for _ in 0..8 {
            match server.submit_internal(&[0, 1], 5) {
                Ok(t) => {
                    admitted += 1;
                    tickets.push(t);
                }
                Err(ServeError::QueueFull) => overflowed += 1,
                Err(e) => panic!("unexpected: {e}"),
            }
        }
        // conservation: every burst request either admitted or overflowed
        // (the exact split races with the draining worker), and each
        // class-specific counter matches its observed outcome exactly
        assert_eq!(admitted + overflowed, 8);
        assert!(admitted >= 2, "an empty 2-deep lane admits at least 2");
        for t in tickets {
            assert_eq!(t.wait(), Err(ServeError::DeadlineExceeded));
        }
        let stats = server.shutdown();
        assert_eq!(stats.internal_rejected, overflowed);
        assert_eq!(stats.internal_deadline_shed, admitted);
        // nothing leaked into the user-class counters
        assert_eq!(stats.rejected, 0);
        assert_eq!(stats.deadline_shed, 0);
        assert_eq!(stats.served, 0);
        assert_eq!(stats.latency.count(), 0);
    }

    #[test]
    fn fabric_backend_serves_identically_and_survives_a_replica_kill() {
        use crate::cluster::ClusterConfig;
        use crate::fabric::{FabricPlacement, QueryRouter, ShardedRuleIndex};

        let result = ClassicalApriori::default().mine(
            &textbook_db(),
            &AprioriConfig { min_support: 2.0 / 9.0, max_k: 0 },
        );
        let rules = generate_rules(&result, 0.3);
        let cut = ShardedRuleIndex::build(&result, 0.3, 3);
        let cluster = ClusterConfig::fhssc(4);
        let bytes: Vec<u64> = cut.shard_rule_counts().iter().map(|&n| 56 * n + 16).collect();
        let placement = FabricPlacement::place(&cluster, 2, &bytes).unwrap();
        let router = Arc::new(QueryRouter::new(
            Arc::new(SnapshotCell::new(Arc::new(cut))),
            placement,
            &cluster,
            5,
        ));
        let server = RuleServer::start_with_backend(
            Backend::Fabric(Arc::clone(&router)),
            ServeOptions::default(),
        );
        let basket = vec![0u32, 1];
        let before = server.query(&basket, 5).unwrap();
        assert_eq!(
            before.render(),
            render_lines(&reference_recommend(&rules, &basket, 5))
        );
        // kill one node: every query still gets the identical answer
        router.set_node_down(0);
        let after = server.query(&basket, 5).unwrap();
        assert_eq!(after.render(), before.render());
        // kill everything: Unavailable, never a partial answer
        for n in 0..4 {
            router.set_node_down(n);
        }
        assert_eq!(server.query(&basket, 5), Err(ServeError::Unavailable));
        let stats = server.shutdown();
        assert_eq!(stats.served, 2);
        assert_eq!(stats.unavailable, 1);
    }

    #[test]
    fn traced_requests_nest_scatter_under_per_request_traces() {
        use crate::cluster::ClusterConfig;
        use crate::fabric::{FabricPlacement, QueryRouter, ShardedRuleIndex};
        use crate::obs::{TraceCtx, TraceSink};

        let result = ClassicalApriori::default().mine(
            &textbook_db(),
            &AprioriConfig { min_support: 2.0 / 9.0, max_k: 0 },
        );
        let cut = ShardedRuleIndex::build(&result, 0.3, 2);
        let cluster = ClusterConfig::fhssc(4);
        let bytes: Vec<u64> = cut.shard_rule_counts().iter().map(|&n| 56 * n + 16).collect();
        let placement = FabricPlacement::place(&cluster, 2, &bytes).unwrap();
        let router = Arc::new(QueryRouter::new(
            Arc::new(SnapshotCell::new(Arc::new(cut))),
            placement,
            &cluster,
            5,
        ));
        let sink = TraceSink::new();
        let registry = MetricsRegistry::new();
        let server = RuleServer::start_with_backend(
            Backend::Fabric(router),
            ServeOptions {
                trace: Some(TraceCtx::root(Arc::clone(&sink))),
                ..Default::default()
            },
        );
        server.register_metrics(&registry, "serve").unwrap();
        server.query(&[0, 1], 5).unwrap();
        server.query(&[1, 2], 5).unwrap();
        let stats = server.shutdown();
        assert_eq!(stats.served, 2);
        assert_eq!(registry.snapshot().counter("serve.served"), Some(2));

        let events = sink.events();
        let requests: Vec<_> = events.iter().filter(|e| e.name == "request").collect();
        assert_eq!(requests.len(), 2);
        assert_ne!(
            requests[0].trace_id, requests[1].trace_id,
            "each request is its own trace"
        );
        for req in &requests {
            let scatter = events
                .iter()
                .find(|e| e.name == "scatter" && e.trace_id == req.trace_id)
                .expect("scatter under each request");
            assert_eq!(scatter.parent_id, req.span_id);
            assert!(
                events
                    .iter()
                    .any(|e| e.cat == "rpc" && e.parent_id == scatter.span_id),
                "per-replica RPC spans under the scatter"
            );
        }
    }

    #[test]
    fn responses_follow_a_snapshot_swap() {
        let (cell, _) = textbook_index(0.3);
        let server = RuleServer::start(Arc::clone(&cell), ServeOptions::default());
        let before = server.query(&[0, 1], 5).unwrap();
        assert_eq!(before.generation, 0);
        // swap in an empty index (simulates a refresh to a new generation)
        let empty = RuleIndex::build(&crate::apriori::MiningResult::default(), 0.3);
        cell.store(Arc::new(empty));
        let after = server.query(&[0, 1], 5).unwrap();
        assert_eq!(after.generation, 1);
        assert!(after.recommendations.is_empty());
        assert!(!before.recommendations.is_empty());
    }
}

//! Micro-batch refresh: keep the serving snapshot tracking a growing
//! transaction stream without ever pausing reads.
//!
//! Each cycle of the [`Refresher`]:
//!
//! 1. appends a delta of transactions to the [`TransactionDb`]
//!    ([`TransactionDb::append`]);
//! 2. refreshes the mining output in the background — the snapshot in
//!    service is untouched while this runs. Two strategies:
//!    * **full** ([`RefreshMode::Full`], the default): re-mine the whole
//!      union database through the existing Map/Reduce driver
//!      ([`MrApriori`], pipelined config welcome);
//!    * **incremental** ([`RefreshMode::Incremental`]): FUP-style border
//!      maintenance over a persistent [`MinedState`] — one counting job
//!      over the delta plus targeted scans for the promoted frontier,
//!      falling back to a full capture-mine when the frontier trips the
//!      [`IncrementalConfig`] blowup guard (and on the first cycle,
//!      which seeds the state);
//! 3. rebuilds a fresh [`RuleIndex`] from the new [`MiningResult`] and
//!    rules;
//! 4. publishes it with one [`SnapshotCell::store`] — readers that
//!    loaded mid-rebuild keep the old generation, the next load sees the
//!    new one, and nothing in between exists.
//!
//! Both strategies publish byte-identical snapshots to a from-scratch
//! batch run over the union database — `benches/ablation_serving.rs`
//! asserts it for full mode, `tests/incremental.rs` for incremental mode
//! across randomized promote/demote churn.
//!
//! [`MinedState`]: crate::incremental::MinedState

use std::sync::{Arc, Mutex};

use crate::coordinator::{MineError, MrApriori, RunReport, WorkloadProfile};
use crate::data::{ItemId, Transaction, TransactionDb};
use crate::incremental::{DeltaApply, DeltaStats, IncrementalConfig, MinedState};
use crate::metrics::Timer;
use crate::obs::TraceCtx;
use crate::store::{BaseRef, SnapshotRef, SnapshotStore, StoreError};
use crate::util::rng::Xoshiro256;

use super::index::RuleIndex;
use super::snapshot::SnapshotCell;

/// Why a refresh cycle failed. Either way the cycle's rollback contract
/// holds: the database append is undone, the carried [`MinedState`] is
/// restored, and the still-served snapshot stays untouched.
#[derive(Debug)]
pub enum RefreshError {
    /// The background mine (full or delta) failed.
    Mine(MineError),
    /// The durable snapshot commit failed — the generation was never
    /// published (a generation is only served once it is on disk).
    Store(StoreError),
}

impl std::fmt::Display for RefreshError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Self::Mine(e) => write!(f, "refresh mine failed: {e}"),
            Self::Store(e) => write!(f, "snapshot persist failed: {e}"),
        }
    }
}

impl std::error::Error for RefreshError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Self::Mine(e) => Some(e),
            Self::Store(e) => Some(e),
        }
    }
}

impl From<MineError> for RefreshError {
    fn from(e: MineError) -> Self {
        Self::Mine(e)
    }
}

impl From<StoreError> for RefreshError {
    fn from(e: StoreError) -> Self {
        Self::Store(e)
    }
}

/// How a refresh cycle recomputes the mining output.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum RefreshMode {
    /// Re-mine the whole union database every cycle (the verified v1
    /// strategy; refresh latency grows with |D|).
    #[default]
    Full,
    /// FUP-style border maintenance: cost scales with the delta and the
    /// promoted frontier, with automatic full-re-mine fallback.
    Incremental,
}

impl std::str::FromStr for RefreshMode {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "full" => Ok(Self::Full),
            "incremental" => Ok(Self::Incremental),
            other => Err(format!(
                "unknown refresh mode '{other}' (want full|incremental)"
            )),
        }
    }
}

/// What one completed refresh cycle did.
#[derive(Debug, Clone)]
pub struct RefreshStats {
    /// Generation the new snapshot was published as.
    pub generation: u64,
    /// Transactions appended this cycle.
    pub delta_tx: usize,
    /// Database size after the append.
    pub total_tx: usize,
    /// Frequent itemsets / rules in the new snapshot.
    pub n_frequent: usize,
    pub n_rules: usize,
    /// Background cost split: mining (full or delta) vs index rebuild.
    pub mine_secs: f64,
    pub build_secs: f64,
    /// Delta-application accounting when the cycle went through border
    /// maintenance; `None` for full re-mine cycles (including the
    /// incremental mode's seed and fallback cycles).
    pub incremental: Option<DeltaStats>,
    /// An incremental cycle gave up (frontier blowup) and re-mined.
    pub fell_back: bool,
    /// Resident-index-cache activity during this cycle's mining work
    /// (per-cycle deltas of the driver's cumulative totals).
    pub cache_hits: u64,
    pub cache_misses: u64,
}

/// Owns the mining driver and the confidence floor. In incremental mode
/// it also carries the [`MinedState`] across cycles (behind a mutex so
/// the `&self` API stays shareable with the serving threads; refreshes
/// are serialized by design, so the lock is uncontended).
pub struct Refresher {
    driver: MrApriori,
    min_confidence: f64,
    incremental: IncrementalConfig,
    state: Mutex<Option<MinedState>>,
    store: Option<StoreSink>,
    trace: Option<TraceCtx>,
}

/// Where (and relative to which base) published generations persist.
struct StoreSink {
    store: Arc<SnapshotStore>,
    base: BaseRef,
    /// Length of the immutable base database: `db.transactions[base_tx..]`
    /// is the cumulative delta each snapshot journals.
    base_tx: usize,
}

impl Refresher {
    pub fn new(driver: MrApriori, min_confidence: f64) -> Self {
        assert!(
            (0.0..=1.0).contains(&min_confidence),
            "min_confidence must be in [0, 1]"
        );
        Self {
            driver,
            min_confidence,
            incremental: IncrementalConfig::default(),
            state: Mutex::new(None),
            store: None,
            trace: None,
        }
    }

    /// Trace every refresh cycle into `ctx`'s sink: each cycle becomes a
    /// root `refresh.cycle` span with the mine and (when a store is
    /// attached) `store.publish` spans nested under it. The driver's own
    /// job/level spans land in the same sink when it was built
    /// `with_trace` on the same context.
    pub fn with_trace(mut self, trace: Option<TraceCtx>) -> Self {
        self.trace = trace;
        self
    }

    /// Persist every generation this refresher publishes into `store`.
    /// `base` identifies the immutable base database (`BaseRef::of` of
    /// the pristine, pre-delta db) — the durable commit lands *before*
    /// the in-memory hot swap, so a served generation is always
    /// recoverable, and a failed commit rolls the whole cycle back.
    pub fn with_store(mut self, store: Arc<SnapshotStore>, base: BaseRef, base_tx: usize) -> Self {
        self.store = Some(StoreSink { store, base, base_tx });
        self
    }

    /// Switch to incremental (border-maintenance) refresh with the given
    /// guard settings (a disabled config keeps full mode). The state
    /// seeds itself on the first cycle.
    pub fn with_incremental(mut self, cfg: IncrementalConfig) -> Self {
        self.incremental = cfg;
        self
    }

    /// Derived from the config so the routing flag cannot drift from it.
    pub fn mode(&self) -> RefreshMode {
        if self.incremental.enabled {
            RefreshMode::Incremental
        } else {
            RefreshMode::Full
        }
    }

    /// A copy of the current mined state (incremental mode only; `None`
    /// before the first cycle or in full mode). Test/debug hook.
    pub fn state(&self) -> Option<MinedState> {
        self.state.lock().unwrap().clone()
    }

    /// Install a carried state directly — the warm-restart path: a
    /// recovered [`MinedState`] makes the very next incremental cycle
    /// take the delta path instead of the cold capture-mine that
    /// otherwise seeds the state.
    pub fn seed_state(&self, state: MinedState) {
        *self.state.lock().unwrap() = Some(state);
    }

    /// One micro-batch cycle: append, re-mine (or delta-apply), rebuild,
    /// **persist** (when a store is attached), hot-swap. Returns the
    /// mining report (the differential tests query its `result`
    /// directly) alongside the cycle stats.
    ///
    /// The durable commit happens *before* the in-memory swap, so every
    /// generation a reader can observe is already recoverable. Any
    /// failure — mine or persist — rolls the cycle back whole: the
    /// append is undone, the carried state restored, and the old
    /// snapshot stays in service; retrying with the same delta must not
    /// double-append it.
    pub fn refresh_once(
        &self,
        db: &mut TransactionDb,
        delta: Vec<Transaction>,
        cell: &SnapshotCell<RuleIndex>,
    ) -> Result<(RunReport, RefreshStats), RefreshError> {
        let delta_tx = delta.len();
        let mut cycle_span = self.trace.as_ref().map(|c| {
            let mut s = c.span("serve", "refresh.cycle");
            s.add("delta_tx", delta_tx as f64);
            s
        });
        let cycle_ctx = cycle_span.as_ref().map(|s| s.ctx());
        let (old_len, old_n_items) = (db.len(), db.n_items);
        // Backup for the persist-failure rollback (the mine-failure path
        // never mutates the state, so it only needs the db rollback).
        let state_backup = self.store.as_ref().map(|_| self.state.lock().unwrap().clone());
        db.append(delta);
        let rollback = |db: &mut TransactionDb| {
            db.transactions.truncate(old_len);
            db.n_items = old_n_items;
        };
        let mine_timer = Timer::start();
        let cache_before = self.driver.cache_stats();
        let mined = match self.mode() {
            RefreshMode::Full => self.driver.mine(db).map(|r| (r, None, false)),
            RefreshMode::Incremental => self.refresh_incremental(db, old_len),
        };
        let cache_after = self.driver.cache_stats();
        let (report, incremental, fell_back) = match mined {
            Ok(out) => out,
            Err(e) => {
                rollback(db);
                return Err(e.into());
            }
        };
        let mine_secs = mine_timer.secs();
        let build_timer = Timer::start();
        let index = RuleIndex::build(&report.result, self.min_confidence);
        let build_secs = build_timer.secs();
        let (n_frequent, n_rules) = (index.n_itemsets(), index.n_rules());
        if let Some(sink) = &self.store {
            let generation = cell.generation() + 1;
            let outcome = {
                let state_guard = self.state.lock().unwrap();
                sink.store.publish_traced(
                    &SnapshotRef {
                        generation,
                        base: sink.base,
                        min_support: self.driver.apriori.min_support,
                        max_k: self.driver.apriori.max_k,
                        delta: &db.transactions[sink.base_tx..],
                        result: &report.result,
                        state: state_guard.as_ref(),
                        index: &index,
                    },
                    cycle_ctx.as_ref(),
                )
            };
            if let Err(e) = outcome {
                rollback(db);
                if let Some(backup) = state_backup {
                    *self.state.lock().unwrap() = backup;
                }
                return Err(e.into());
            }
        }
        let generation = cell.store(Arc::new(index));
        if let Some(s) = cycle_span.as_mut() {
            s.add("generation", generation as f64);
            s.add("mine_ms", mine_secs * 1e3);
            s.add("build_ms", build_secs * 1e3);
            s.add("n_frequent", n_frequent as f64);
            s.add("n_rules", n_rules as f64);
            s.add("fell_back", if fell_back { 1.0 } else { 0.0 });
        }
        drop(cycle_span);
        let stats = RefreshStats {
            generation,
            delta_tx,
            total_tx: db.len(),
            n_frequent,
            n_rules,
            mine_secs,
            build_secs,
            incremental,
            fell_back,
            cache_hits: cache_after.hits - cache_before.hits,
            cache_misses: cache_after.misses - cache_before.misses,
        };
        Ok((report, stats))
    }

    /// The incremental strategy: delta-apply against the carried state,
    /// seeding or falling back to a full capture-mine as needed. The
    /// state is only replaced on success, so an `Err` leaves it
    /// consistent with the rolled-back database.
    fn refresh_incremental(
        &self,
        db: &TransactionDb,
        old_len: usize,
    ) -> Result<(RunReport, Option<DeltaStats>, bool), MineError> {
        let mut slot = self.state.lock().unwrap();
        let delta = &db.transactions[old_len..];
        if let Some(state) = slot.as_mut() {
            match state.apply_delta(&self.driver, db, delta, &self.incremental)? {
                DeltaApply::Applied(stats) => {
                    let report = synthesize_report(state, db);
                    return Ok((report, Some(stats), false));
                }
                DeltaApply::FrontierBlowup { .. } => {
                    let (report, fresh) = MinedState::capture(&self.driver, db)?;
                    *slot = Some(fresh);
                    return Ok((report, None, true));
                }
            }
        }
        // First cycle: seed the state with a full capture-mine.
        let (report, fresh) = MinedState::capture(&self.driver, db)?;
        *slot = Some(fresh);
        Ok((report, None, false))
    }

    /// Run a bounded sequence of micro-batches back-to-back, stopping at
    /// the first failed cycle. Library convenience for callers that
    /// don't need per-cycle work between refreshes (`repro serve`
    /// hand-rolls the loop instead, to interleave its post-swap
    /// validation probes).
    pub fn run_micro_batches(
        &self,
        db: &mut TransactionDb,
        batches: Vec<Vec<Transaction>>,
        cell: &SnapshotCell<RuleIndex>,
    ) -> Result<Vec<RefreshStats>, RefreshError> {
        batches
            .into_iter()
            .map(|delta| self.refresh_once(db, delta, cell).map(|(_, s)| s))
            .collect()
    }
}

/// A [`RunReport`] for a delta-applied generation: the result comes from
/// the state (byte-identical `frequent` to a full re-mine), while the
/// job/profile sections stay empty — no full scan happened, so there is
/// no replayable workload profile to report.
fn synthesize_report(state: &MinedState, db: &TransactionDb) -> RunReport {
    RunReport {
        result: state.to_result(),
        jobs: Vec::new(),
        profile: WorkloadProfile {
            n_tx: db.len(),
            db_bytes: db.approx_bytes(),
            levels: Vec::new(),
        },
        wall_secs: 0.0,
        spill_fraction: 0.0,
    }
}

/// Deterministic delta traffic: `n` noise-like baskets of 3..=8 uniform
/// items over `n_items`. Deliberately pattern-free — a refresh must keep
/// served answers exact even when the delta shifts every support, which
/// uniform noise does to all of them at once.
pub fn synth_delta(n: usize, n_items: usize, seed: u64) -> Vec<Transaction> {
    assert!(n_items > 0, "need a non-empty item universe");
    let mut rng = Xoshiro256::seed_from_u64(seed);
    (0..n)
        .map(|_| {
            let len = rng.range_usize(3, 9).min(n_items);
            Transaction::new((0..len).map(|_| rng.gen_range(n_items as u64) as ItemId))
        })
        .collect()
}

/// Deterministic query traffic: `n` baskets of 1..=3 distinct items drawn
/// from `singles` (typically the frequent 1-itemsets of the generation
/// being served). Shared by `repro serve` and `benches/ablation_serving`
/// so the CLI smoke and the bench drive the same workload shape.
pub fn synth_baskets(singles: &[ItemId], n: usize, seed: u64) -> Vec<Vec<ItemId>> {
    assert!(!singles.is_empty(), "need at least one item to query");
    let mut rng = Xoshiro256::seed_from_u64(seed);
    (0..n)
        .map(|_| {
            let len = rng.range_usize(1, 4).min(singles.len());
            rng.sample_distinct(singles.len(), len)
                .into_iter()
                .map(|i| singles[i])
                .collect()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apriori::classical::{tests::textbook_db, ClassicalApriori};
    use crate::apriori::rules::generate_rules;
    use crate::apriori::AprioriConfig;
    use crate::cluster::ClusterConfig;
    use crate::serve::index::{reference_recommend, render_lines};

    fn cfg() -> AprioriConfig {
        AprioriConfig { min_support: 2.0 / 9.0, max_k: 0 }
    }

    #[test]
    fn synth_delta_is_deterministic_and_well_formed() {
        let a = synth_delta(50, 20, 7);
        let b = synth_delta(50, 20, 7);
        assert_eq!(a, b);
        assert_ne!(a, synth_delta(50, 20, 8));
        for t in &a {
            assert!(!t.is_empty() && t.len() <= 8);
            assert!(t.items.iter().all(|&i| (i as usize) < 20));
        }
    }

    #[test]
    fn synth_baskets_deterministic_and_bounded() {
        let singles = vec![3u32, 5, 9, 11];
        let a = synth_baskets(&singles, 50, 42);
        assert_eq!(a, synth_baskets(&singles, 50, 42));
        assert_ne!(a, synth_baskets(&singles, 50, 43));
        for b in &a {
            assert!((1..=3).contains(&b.len()));
            assert!(b.iter().all(|i| singles.contains(i)));
        }
        // fewer singles than the basket length bound still works
        for b in synth_baskets(&[7], 10, 1) {
            assert_eq!(b, vec![7]);
        }
    }

    #[test]
    fn db_and_snapshot_stay_consistent_after_a_cycle() {
        // The cycle's contract: after refresh_once returns Ok, the db and
        // the published snapshot describe the same generation (a failed
        // mine rolls the append back, so Err leaves both untouched).
        let mut db = textbook_db();
        let result0 = ClassicalApriori::default().mine(&db, &cfg());
        let cell = SnapshotCell::new(Arc::new(RuleIndex::build(&result0, 0.5)));
        let driver = MrApriori::new(ClusterConfig::standalone(), cfg()).with_split_tx(4);
        let refresher = Refresher::new(driver, 0.5);
        let (_, stats) = refresher
            .refresh_once(&mut db, synth_delta(4, db.n_items, 1), &cell)
            .unwrap();
        assert_eq!(stats.total_tx, db.len());
        assert_eq!(cell.load().n_transactions, db.len());
    }

    #[test]
    fn refresh_swaps_in_the_union_databases_rules() {
        let mut db = textbook_db();
        let result0 = ClassicalApriori::default().mine(&db, &cfg());
        let cell = SnapshotCell::new(Arc::new(RuleIndex::build(&result0, 0.3)));
        let held = cell.load(); // a reader mid-request across the swap

        let driver = MrApriori::new(ClusterConfig::standalone(), cfg()).with_split_tx(4);
        let refresher = Refresher::new(driver, 0.3);
        let delta = synth_delta(6, db.n_items, 42);
        let (report, stats) = refresher.refresh_once(&mut db, delta, &cell).unwrap();

        assert_eq!(stats.generation, 1);
        assert_eq!(cell.generation(), 1);
        assert_eq!(stats.delta_tx, 6);
        assert_eq!(stats.total_tx, 15);
        assert_eq!(db.len(), 15);
        assert_eq!(stats.n_rules, generate_rules(&report.result, 0.3).len());

        // the swapped-in index answers exactly like a direct batch mine
        // of the union database
        let union_result = ClassicalApriori::default().mine(&db, &cfg());
        assert_eq!(report.result.frequent, union_result.frequent);
        let rules = generate_rules(&union_result, 0.3);
        let idx = cell.load();
        for basket in [vec![0u32, 1], vec![1, 2], vec![0, 4]] {
            assert_eq!(
                render_lines(&idx.recommend(&basket, 5)),
                render_lines(&reference_recommend(&rules, &basket, 5))
            );
        }
        // the pre-swap reader still holds a valid generation-0 snapshot
        assert_eq!(held.n_transactions, 9);
        assert_eq!(idx.n_transactions, 15);
    }

    #[test]
    fn refresh_mode_parses_and_defaults_full() {
        use std::str::FromStr;
        assert_eq!(RefreshMode::from_str("full").unwrap(), RefreshMode::Full);
        assert_eq!(
            RefreshMode::from_str("incremental").unwrap(),
            RefreshMode::Incremental
        );
        assert!(RefreshMode::from_str("magic").is_err());
        let driver = MrApriori::new(ClusterConfig::standalone(), cfg());
        assert_eq!(Refresher::new(driver, 0.5).mode(), RefreshMode::Full);
    }

    #[test]
    fn incremental_mode_publishes_the_same_snapshot_as_full_remine() {
        let mut db = textbook_db();
        let result0 = ClassicalApriori::default().mine(&db, &cfg());
        let cell = SnapshotCell::new(Arc::new(RuleIndex::build(&result0, 0.3)));
        let driver = MrApriori::new(ClusterConfig::standalone(), cfg()).with_split_tx(4);
        let refresher = Refresher::new(driver, 0.3).with_incremental(IncrementalConfig {
            enabled: true,
            ..Default::default()
        });
        assert_eq!(refresher.mode(), RefreshMode::Incremental);
        assert!(refresher.state().is_none());

        // cycle 1 seeds the state (full capture-mine, no delta stats)
        let (r1, s1) = refresher
            .refresh_once(&mut db, synth_delta(5, db.n_items, 3), &cell)
            .unwrap();
        assert!(s1.incremental.is_none());
        assert!(!s1.fell_back);
        assert!(refresher.state().is_some());
        assert_eq!(
            r1.result.frequent,
            ClassicalApriori::default().mine(&db, &cfg()).frequent
        );

        // cycle 2 applies the delta through border maintenance
        let (r2, s2) = refresher
            .refresh_once(&mut db, synth_delta(6, db.n_items, 4), &cell)
            .unwrap();
        let inc = s2.incremental.expect("delta-applied cycle");
        assert_eq!(inc.delta_tx, 6);
        assert_eq!(inc.n_frequent, r2.result.frequent.len());
        let full = ClassicalApriori::default().mine(&db, &cfg());
        assert_eq!(r2.result.frequent, full.frequent);
        // the published snapshot serves the union generation's rules
        let rules = generate_rules(&full, 0.3);
        let idx = cell.load();
        for basket in [vec![0u32, 1], vec![1, 2], vec![0, 4]] {
            assert_eq!(
                render_lines(&idx.recommend(&basket, 5)),
                render_lines(&reference_recommend(&rules, &basket, 5))
            );
        }
        assert_eq!(cell.generation(), 2);
    }

    #[test]
    fn incremental_zero_guard_falls_back_on_a_promoted_frontier() {
        let mut db = textbook_db();
        let result0 = ClassicalApriori::default().mine(&db, &cfg());
        let cell = SnapshotCell::new(Arc::new(RuleIndex::build(&result0, 0.5)));
        let driver = MrApriori::new(ClusterConfig::standalone(), cfg()).with_split_tx(4);
        let refresher = Refresher::new(driver, 0.5).with_incremental(IncrementalConfig {
            enabled: true,
            max_frontier_blowup: 0.0,
        });
        // cycle 1 seeds the state
        refresher
            .refresh_once(&mut db, synth_delta(4, db.n_items, 10), &cell)
            .unwrap();
        // cycle 2: a delta dominated by a brand-new item makes that item
        // frequent, minting pair candidates the state has never counted
        // — a guaranteed nonzero frontier, which a zero blowup guard
        // must reject in favor of a full re-mine
        let new_item = db.n_items as u32;
        let delta: Vec<Transaction> =
            (0..8).map(|_| Transaction::new([0, new_item])).collect();
        let (report, stats) = refresher.refresh_once(&mut db, delta, &cell).unwrap();
        assert!(stats.fell_back, "zero guard must reject the promoted frontier");
        assert!(stats.incremental.is_none());
        assert_eq!(
            report.result.frequent,
            ClassicalApriori::default().mine(&db, &cfg()).frequent
        );
        // the fallback re-seeded the state, ready for the next delta
        assert_eq!(refresher.state().unwrap().n_transactions, db.len());
    }

    use crate::util::tempdir::TempDir;

    #[test]
    fn refresher_persists_each_published_generation_before_serving_it() {
        use crate::store::{BaseRef, SnapshotStore};
        let tmp = TempDir::new("refresh_persist");
        let store = Arc::new(SnapshotStore::open(tmp.path(), 4).unwrap());
        let mut db = textbook_db();
        let base = BaseRef::of(&db);
        let base_tx = db.len();
        let result0 = ClassicalApriori::default().mine(&db, &cfg());
        let cell = SnapshotCell::new(Arc::new(RuleIndex::build(&result0, 0.3)));
        let driver = MrApriori::new(ClusterConfig::standalone(), cfg()).with_split_tx(4);
        let refresher = Refresher::new(driver, 0.3).with_store(Arc::clone(&store), base, base_tx);

        let (r1, s1) = refresher
            .refresh_once(&mut db, synth_delta(4, db.n_items, 1), &cell)
            .unwrap();
        let (r2, s2) = refresher
            .refresh_once(&mut db, synth_delta(3, db.n_items, 2), &cell)
            .unwrap();
        assert_eq!((s1.generation, s2.generation), (1, 2));

        let snap = store.load_latest().unwrap().expect("generation 2 durable");
        assert_eq!(snap.generation, 2);
        assert_eq!(snap.base, base);
        // the journal is cumulative: both deltas, in append order
        assert_eq!(snap.delta.len(), 7);
        assert_eq!(snap.delta, db.transactions[base_tx..].to_vec());
        assert_eq!(snap.result.frequent, r2.result.frequent);
        assert!(snap.state.is_none(), "full mode persists no border state");
        // generation 1 is retained history
        assert_eq!(
            store.load_generation(1).unwrap().result.frequent,
            r1.result.frequent
        );
    }

    #[test]
    fn failed_persist_rolls_back_append_state_and_served_snapshot() {
        use crate::store::{BaseRef, SnapshotStore};
        let tmp = TempDir::new("refresh_persist_fail");
        let store = Arc::new(SnapshotStore::open(tmp.path(), 4).unwrap());
        let mut db = textbook_db();
        let base = BaseRef::of(&db);
        let base_tx = db.len();
        let result0 = ClassicalApriori::default().mine(&db, &cfg());
        let cell = SnapshotCell::new(Arc::new(RuleIndex::build(&result0, 0.3)));
        let driver = MrApriori::new(ClusterConfig::standalone(), cfg()).with_split_tx(4);
        let refresher = Refresher::new(driver, 0.3)
            .with_incremental(IncrementalConfig { enabled: true, ..Default::default() })
            .with_store(Arc::clone(&store), base, base_tx);

        // cycle 1 succeeds and installs a carried state
        refresher
            .refresh_once(&mut db, synth_delta(4, db.n_items, 1), &cell)
            .unwrap();
        let state_before = refresher.state().expect("seeded");
        let len_before = db.len();

        // make the next durable commit fail: the store directory is gone
        std::fs::remove_dir_all(tmp.path()).unwrap();
        let err = refresher
            .refresh_once(&mut db, synth_delta(5, db.n_items, 2), &cell)
            .unwrap_err();
        assert!(matches!(&err, RefreshError::Store(_)), "got {err}");
        // full rollback: db, carried state, and the served snapshot
        assert_eq!(db.len(), len_before);
        assert_eq!(
            format!("{:?}", refresher.state().unwrap().levels),
            format!("{:?}", state_before.levels)
        );
        assert_eq!(cell.generation(), 1);
        assert_eq!(cell.load().n_transactions, len_before);
    }

    #[test]
    fn traced_cycle_nests_store_publish_under_refresh_cycle() {
        use crate::obs::{TraceCtx, TraceSink};
        use crate::store::{BaseRef, SnapshotStore};
        let tmp = TempDir::new("refresh_traced");
        let store = Arc::new(SnapshotStore::open(tmp.path(), 4).unwrap());
        let mut db = textbook_db();
        let base = BaseRef::of(&db);
        let base_tx = db.len();
        let result0 = ClassicalApriori::default().mine(&db, &cfg());
        let cell = SnapshotCell::new(Arc::new(RuleIndex::build(&result0, 0.3)));
        let sink = TraceSink::new();
        let root = TraceCtx::root(Arc::clone(&sink));
        let driver = MrApriori::new(ClusterConfig::standalone(), cfg()).with_split_tx(4);
        let refresher = Refresher::new(driver, 0.3)
            .with_store(Arc::clone(&store), base, base_tx)
            .with_trace(Some(root));
        refresher
            .refresh_once(&mut db, synth_delta(4, db.n_items, 1), &cell)
            .unwrap();
        let events = sink.events();
        let cycle = events
            .iter()
            .find(|e| e.name == "refresh.cycle")
            .expect("cycle span");
        assert_eq!(cycle.cat, "serve");
        let publish = events
            .iter()
            .find(|e| e.name == "store.publish")
            .expect("publish span");
        assert_eq!(publish.cat, "store");
        assert_eq!(publish.parent_id, cycle.span_id);
        assert!(publish.args.iter().any(|(k, v)| k == "bytes" && *v > 0.0));
        assert!(cycle
            .args
            .iter()
            .any(|(k, v)| k == "generation" && *v == 1.0));
    }

    #[test]
    fn micro_batches_advance_generations_monotonically() {
        let mut db = textbook_db();
        let result0 = ClassicalApriori::default().mine(&db, &cfg());
        let cell = SnapshotCell::new(Arc::new(RuleIndex::build(&result0, 0.5)));
        let driver = MrApriori::new(ClusterConfig::standalone(), cfg()).with_split_tx(8);
        let refresher = Refresher::new(driver, 0.5);
        let batches = vec![
            synth_delta(3, db.n_items, 1),
            synth_delta(4, db.n_items, 2),
            synth_delta(5, db.n_items, 3),
        ];
        let stats = refresher.run_micro_batches(&mut db, batches, &cell).unwrap();
        assert_eq!(stats.len(), 3);
        assert_eq!(
            stats.iter().map(|s| s.generation).collect::<Vec<_>>(),
            vec![1, 2, 3]
        );
        assert_eq!(stats.last().unwrap().total_tx, 9 + 3 + 4 + 5);
        assert_eq!(cell.generation(), 3);
    }
}

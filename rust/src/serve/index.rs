//! The immutable rule snapshot: everything a query needs, precomputed.
//!
//! [`RuleIndex::build`] runs [`generate_rules`] once over a
//! [`MiningResult`] and freezes the output into two lookup structures:
//!
//! * an itemset -> support hash map (O(1) vs the `MiningResult`'s linear
//!   `support_of` scan);
//! * an antecedent-keyed rule index, so a basket query enumerates the
//!   basket's subsets (bounded by the longest antecedent actually mined)
//!   and resolves each with one hash probe — sublinear in the number of
//!   rules, which is what dominates at serving min-confidence levels.
//!
//! The index preserves `generate_rules`' deterministic global order
//! (confidence desc, then antecedent, then consequent), so
//! [`RuleIndex::recommend`] returns byte-identical answers to the direct
//! [`reference_recommend`] path — the differential property the serving
//! tests and `benches/ablation_serving.rs` pin.

use std::collections::HashMap;

use crate::apriori::rules::{format_rule, generate_rules, Rule};
use crate::apriori::{Itemset, MiningResult};
use crate::data::{is_subset, ItemId};

/// Basket sizes up to this use indexed subset enumeration (at most
/// 2^16 hash probes, further pruned by antecedent length); larger
/// baskets fall back to a full rule scan with identical output.
const MAX_INDEXED_BASKET: usize = 16;

/// Are sorted `a` and sorted `b` disjoint?
fn is_disjoint(a: &[ItemId], b: &[ItemId]) -> bool {
    let (mut i, mut j) = (0, 0);
    while i < a.len() && j < b.len() {
        match a[i].cmp(&b[j]) {
            std::cmp::Ordering::Equal => return false,
            std::cmp::Ordering::Less => i += 1,
            std::cmp::Ordering::Greater => j += 1,
        }
    }
    true
}

/// Does rule `r` apply to `basket`? The serving semantics: the user holds
/// every antecedent item and none of the consequent items (recommending
/// something already in the basket is useless).
fn applies(r: &Rule, basket: &[ItemId]) -> bool {
    is_subset(&r.antecedent, basket) && is_disjoint(&r.consequent, basket)
}

/// Sort + dedup a basket into the canonical itemset form.
fn normalize_basket(basket: &[ItemId]) -> Itemset {
    let mut b = basket.to_vec();
    b.sort_unstable();
    b.dedup();
    b
}

/// An immutable, query-ready snapshot of one mining generation.
#[derive(Debug)]
pub struct RuleIndex {
    /// All rules meeting `min_confidence`, in `generate_rules` order.
    rules: Vec<Rule>,
    /// Itemset -> absolute support, for every frequent itemset.
    support: HashMap<Itemset, u64>,
    /// Antecedent -> indices into `rules` (ascending, i.e. global order).
    by_antecedent: HashMap<Itemset, Vec<u32>>,
    /// Longest antecedent present — the subset-enumeration prune bound.
    max_antecedent_len: usize,
    /// |D| of the generation this snapshot was mined from.
    pub n_transactions: usize,
    /// The confidence floor the snapshot was built with.
    pub min_confidence: f64,
}

impl RuleIndex {
    /// Freeze a mining result into a serving snapshot.
    pub fn build(result: &MiningResult, min_confidence: f64) -> Self {
        Self::from_parts(
            generate_rules(result, min_confidence),
            result.frequent.clone(),
            result.n_transactions,
            min_confidence,
        )
    }

    /// Assemble an index from its persisted parts (the `store` codec's
    /// decode path). `rules` must be in `generate_rules`' global order —
    /// the lookup structures are derived from it exactly as [`build`]
    /// derives them, so a decoded index serves byte-identically to the
    /// one that was encoded.
    ///
    /// [`build`]: Self::build
    pub fn from_parts(
        rules: Vec<Rule>,
        support: Vec<(Itemset, u64)>,
        n_transactions: usize,
        min_confidence: f64,
    ) -> Self {
        let mut by_antecedent: HashMap<Itemset, Vec<u32>> = HashMap::new();
        let mut max_antecedent_len = 0;
        for (i, r) in rules.iter().enumerate() {
            max_antecedent_len = max_antecedent_len.max(r.antecedent.len());
            by_antecedent.entry(r.antecedent.clone()).or_default().push(i as u32);
        }
        Self {
            support: support.into_iter().collect(),
            rules,
            by_antecedent,
            max_antecedent_len,
            n_transactions,
            min_confidence,
        }
    }

    pub fn n_rules(&self) -> usize {
        self.rules.len()
    }

    pub fn n_itemsets(&self) -> usize {
        self.support.len()
    }

    /// The rules in the deterministic global order (persistence +
    /// diagnostics; not needed on the query path).
    pub fn rules(&self) -> &[Rule] {
        &self.rules
    }

    /// The support table in canonical (len, lexicographic) order, so two
    /// identical indexes always serialize to identical bytes regardless
    /// of hash-map iteration order.
    pub fn support_entries(&self) -> Vec<(Itemset, u64)> {
        let mut entries: Vec<(Itemset, u64)> =
            self.support.iter().map(|(is, s)| (is.clone(), *s)).collect();
        entries.sort_by(|a, b| (a.0.len(), &a.0).cmp(&(b.0.len(), &b.0)));
        entries
    }

    /// O(1) support lookup (the `MiningResult` scan, precomputed).
    pub fn support_of(&self, itemset: &[ItemId]) -> Option<u64> {
        self.support.get(itemset).copied()
    }

    /// Top-k recommendations for a basket: rules whose antecedent the
    /// basket covers and whose consequent it lacks, in the global
    /// (confidence desc, antecedent, consequent) order, truncated to `k`.
    /// Identical to [`reference_recommend`] over the same generation.
    pub fn recommend(&self, basket: &[ItemId], top_k: usize) -> Vec<Rule> {
        let basket = normalize_basket(basket);
        if basket.is_empty() || top_k == 0 {
            return Vec::new();
        }
        if basket.len() > MAX_INDEXED_BASKET {
            // Rare oversized basket: full scan, same order, same output.
            return self
                .rules
                .iter()
                .filter(|r| applies(r, &basket))
                .take(top_k)
                .cloned()
                .collect();
        }
        // Enumerate only the basket subsets a mined antecedent can match
        // (sizes 1..=max_antecedent_len), one hash probe each. Gosper's
        // hack walks the masks of each fixed popcount directly instead of
        // filtering all 2^m masks.
        let m = basket.len();
        let limit = 1u32 << m;
        let mut hits: Vec<u32> = Vec::new();
        for s in 1..=self.max_antecedent_len.min(m) {
            let mut mask = (1u32 << s) - 1;
            while mask < limit {
                let subset: Itemset = (0..m)
                    .filter(|&i| mask & (1 << i) != 0)
                    .map(|i| basket[i])
                    .collect();
                if let Some(ids) = self.by_antecedent.get(&subset) {
                    hits.extend_from_slice(ids);
                }
                // next mask with the same popcount, in increasing order
                let c = mask & mask.wrapping_neg();
                let r = mask + c;
                mask = (((r ^ mask) >> 2) / c) | r;
            }
        }
        // Ascending rule ids == the deterministic global rule order.
        hits.sort_unstable();
        hits.iter()
            .map(|&i| &self.rules[i as usize])
            .filter(|r| is_disjoint(&r.consequent, &basket))
            .take(top_k)
            .cloned()
            .collect()
    }
}

/// The direct (index-free) answer: filter `generate_rules` output for the
/// basket. This is the serving layer's correctness oracle — `recommend`
/// must match it byte-for-byte after [`render_lines`].
pub fn reference_recommend(rules: &[Rule], basket: &[ItemId], top_k: usize) -> Vec<Rule> {
    let basket = normalize_basket(basket);
    if basket.is_empty() || top_k == 0 {
        return Vec::new();
    }
    rules
        .iter()
        .filter(|r| applies(r, &basket))
        .take(top_k)
        .cloned()
        .collect()
}

/// Canonical wire rendering of an answer: one `format_rule` line per
/// recommendation. Byte equality of two renders is the differential
/// check's definition of "identical answers".
pub fn render_lines(rules: &[Rule]) -> String {
    rules.iter().map(format_rule).collect::<Vec<_>>().join("\n")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apriori::classical::{tests::textbook_db, ClassicalApriori};
    use crate::apriori::AprioriConfig;
    use crate::util::proptest::check;

    fn mined() -> MiningResult {
        ClassicalApriori::default().mine(
            &textbook_db(),
            &AprioriConfig { min_support: 2.0 / 9.0, max_k: 0 },
        )
    }

    #[test]
    fn subset_and_disjoint_merges() {
        assert!(is_subset(&[], &[1]));
        assert!(is_subset(&[1, 3], &[1, 2, 3]));
        assert!(!is_subset(&[1, 4], &[1, 2, 3]));
        assert!(is_subset(&[2], &[2]));
        assert!(is_disjoint(&[1, 3], &[2, 4]));
        assert!(!is_disjoint(&[1, 3], &[3]));
        assert!(is_disjoint(&[], &[1]));
    }

    #[test]
    fn support_lookup_matches_result() {
        let r = mined();
        let idx = RuleIndex::build(&r, 0.5);
        for (is, sup) in &r.frequent {
            assert_eq!(idx.support_of(is), Some(*sup));
        }
        assert_eq!(idx.support_of(&[99]), None);
        assert_eq!(idx.n_itemsets(), r.frequent.len());
    }

    #[test]
    fn recommend_matches_reference_on_textbook_baskets() {
        let r = mined();
        let idx = RuleIndex::build(&r, 0.2);
        let rules = generate_rules(&r, 0.2);
        for basket in [
            vec![0u32],
            vec![0, 1],
            vec![0, 4],
            vec![1, 2, 3],
            vec![0, 1, 2, 3, 4],
            vec![7, 8], // no frequent items at all
        ] {
            for k in [1, 3, 100] {
                let served = idx.recommend(&basket, k);
                let direct = reference_recommend(&rules, &basket, k);
                assert_eq!(
                    render_lines(&served),
                    render_lines(&direct),
                    "basket {basket:?} k={k}"
                );
            }
        }
    }

    #[test]
    fn prop_recommend_equals_reference_on_random_baskets() {
        let r = mined();
        let idx = RuleIndex::build(&r, 0.0);
        let rules = generate_rules(&r, 0.0);
        check(
            "index equals direct generate_rules filter",
            0x5EED,
            300,
            |rng| {
                let len = rng.range_usize(0, 6);
                (0..len)
                    .map(|_| rng.gen_range(6) as ItemId)
                    .collect::<Vec<_>>()
            },
            |basket| {
                let served = render_lines(&idx.recommend(basket, 5));
                let direct = render_lines(&reference_recommend(&rules, basket, 5));
                if served == direct {
                    Ok(())
                } else {
                    Err(format!("served:\n{served}\ndirect:\n{direct}"))
                }
            },
        );
    }

    #[test]
    fn gosper_enumeration_matches_reference_on_wider_baskets() {
        let r = mined();
        let idx = RuleIndex::build(&r, 0.0);
        let rules = generate_rules(&r, 0.0);
        // 10 distinct items (indexed path), frequent ones plus noise
        let basket: Vec<ItemId> = vec![0, 1, 2, 3, 4, 10, 20, 30, 40, 50];
        assert_eq!(
            render_lines(&idx.recommend(&basket, 50)),
            render_lines(&reference_recommend(&rules, &basket, 50))
        );
    }

    #[test]
    fn oversized_basket_falls_back_to_scan() {
        let r = mined();
        let idx = RuleIndex::build(&r, 0.0);
        let rules = generate_rules(&r, 0.0);
        // 20 distinct items > MAX_INDEXED_BASKET, includes the frequent ones
        let basket: Vec<ItemId> = (0..20).collect();
        let served = idx.recommend(&basket, 10);
        let direct = reference_recommend(&rules, &basket, 10);
        assert_eq!(render_lines(&served), render_lines(&direct));
    }

    #[test]
    fn consequent_items_already_held_are_not_recommended() {
        let r = mined();
        let idx = RuleIndex::build(&r, 0.0);
        let basket = vec![0u32, 1, 2, 4];
        for rec in idx.recommend(&basket, 50) {
            assert!(is_disjoint(&rec.consequent, &basket));
            assert!(is_subset(&rec.antecedent, &basket));
        }
    }

    #[test]
    fn empty_basket_and_zero_k_yield_nothing() {
        let idx = RuleIndex::build(&mined(), 0.0);
        assert!(idx.recommend(&[], 5).is_empty());
        assert!(idx.recommend(&[0, 1], 0).is_empty());
        assert!(idx.n_rules() > 0);
    }
}

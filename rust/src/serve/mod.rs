//! The online rule-serving subsystem — the consumption side of the KDD
//! pipeline the paper's Figure 1 ends with.
//!
//! The mining stack below this layer produces a batch [`MiningResult`];
//! this layer turns it into a queryable, refreshable, concurrent product:
//!
//! * [`index`] — [`index::RuleIndex`], an immutable snapshot holding
//!   itemset supports plus an antecedent-keyed rule index; basket
//!   queries return top-k consequents in sublinear time, byte-identical
//!   to the direct `generate_rules` path;
//! * [`snapshot`] — [`snapshot::SnapshotCell`], the atomic hot-swap cell
//!   (hand-rolled arc-swap) that lets a refresh publish a new generation
//!   without readers ever blocking;
//! * [`server`] — [`server::RuleServer`], a worker pool over a bounded
//!   admission-controlled queue, recording per-request latency into the
//!   `metrics` p50/p95/p99 histogram; its [`server::Backend`] picks the
//!   answer path: the local index, or the sharded [`crate::fabric`]
//!   (scatter-gather with replica failover);
//! * [`refresh`] — [`refresh::Refresher`], the micro-batch loop:
//!   append delta transactions, re-mine in the background through the
//!   Map/Reduce driver, rebuild the index, hot-swap it in.
//!
//! `repro serve` wires the four together as a one-shot closed-loop run;
//! `benches/ablation_serving.rs` measures QPS and tail latency with and
//! without a concurrent refresh and asserts the differential property.
//!
//! [`MiningResult`]: crate::apriori::MiningResult

pub mod index;
pub mod refresh;
pub mod server;
pub mod snapshot;

/// `[serve]` section of an experiment config: worker-pool sizing,
/// admission bounds, query shape, and the micro-batch refresh knobs.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Worker threads answering queries.
    pub workers: usize,
    /// Bounded request-queue depth (admission control threshold).
    pub queue_depth: usize,
    /// Depth of the internal (background) admission lane — refresh
    /// validation probes; strictly lower priority than user traffic.
    pub internal_queue_depth: usize,
    /// Recommendations returned per query.
    pub top_k: usize,
    /// Confidence floor for the rules the index serves.
    pub min_confidence: f64,
    /// Delta transactions appended per micro-batch refresh.
    pub refresh_tx: usize,
    /// Micro-batch refresh cycles to run (0 = serve a frozen snapshot).
    pub refresh_batches: usize,
    /// Queue deadline in milliseconds: requests older than this when a
    /// worker dequeues them are shed (0 = no deadline).
    pub deadline_ms: u64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self {
            workers: 2,
            queue_depth: 64,
            internal_queue_depth: 16,
            top_k: 5,
            min_confidence: 0.6,
            refresh_tx: 500,
            refresh_batches: 0,
            deadline_ms: 0,
        }
    }
}

//! Metrics and reporting: wall-clock timers, counters, the bench-table
//! emitter that prints paper-style rows (markdown + CSV) for every figure
//! reproduction, and the serving layer's tail-latency histogram.

pub mod bench;
pub mod histogram;

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

/// A shareable monotonic event counter (relaxed atomics: the consumers —
/// cache hit/miss telemetry in the serve log — only need eventual
/// per-counter totals, not cross-counter ordering).
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// A simple scoped timer.
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Self { start: Instant::now() }
    }

    pub fn secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn millis(&self) -> f64 {
        self.secs() * 1e3
    }
}

impl Default for Timer {
    fn default() -> Self {
        Self::start()
    }
}

/// Median / mean / p95 / p99 / min / max over repeated measurements —
/// the aggregation every bench row reports. The tail percentiles give
/// ablation tables their tail columns, so a regression that only hurts
/// the slowest runs still shows up.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub median: f64,
    /// 95th percentile by the nearest-rank method (`ceil(0.95·n)`-th
    /// smallest sample); equals `max` for `n < 20`.
    pub p95: f64,
    /// 99th percentile, same nearest-rank method; equals `max` for
    /// `n < 100`.
    pub p99: f64,
    pub min: f64,
    pub max: f64,
}

impl Summary {
    pub fn of(samples: &[f64]) -> Self {
        assert!(!samples.is_empty(), "Summary::of(empty)");
        let mut s = samples.to_vec();
        s.sort_by(f64::total_cmp);
        let n = s.len();
        let rank = |q: f64| ((q * n as f64).ceil() as usize).clamp(1, n);
        Self {
            n,
            mean: s.iter().sum::<f64>() / n as f64,
            median: if n % 2 == 1 {
                s[n / 2]
            } else {
                (s[n / 2 - 1] + s[n / 2]) / 2.0
            },
            p95: s[rank(0.95) - 1],
            p99: s[rank(0.99) - 1],
            min: s[0],
            max: s[n - 1],
        }
    }
}

/// Time `f` over `n` iterations after `warmup` runs; returns per-iteration
/// seconds. The in-tree criterion substitute (DESIGN.md §Substitutions).
pub fn measure<F: FnMut()>(warmup: usize, n: usize, mut f: F) -> Summary {
    for _ in 0..warmup {
        f();
    }
    let samples: Vec<f64> = (0..n.max(1))
        .map(|_| {
            let t = Timer::start();
            f();
            t.secs()
        })
        .collect();
    Summary::of(&samples)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_math() {
        let s = Summary::of(&[3.0, 1.0, 2.0]);
        assert_eq!(s.n, 3);
        assert_eq!(s.median, 2.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
        assert_eq!(s.p95, 3.0, "n < 20: nearest-rank p95 is the max");
        assert!((s.mean - 2.0).abs() < 1e-12);
        let e = Summary::of(&[4.0, 1.0, 2.0, 3.0]);
        assert_eq!(e.median, 2.5);
    }

    #[test]
    fn summary_p95_nearest_rank() {
        // 1..=100: ceil(0.95 * 100) = 95 -> the 95th smallest sample.
        let samples: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(Summary::of(&samples).p95, 95.0);
        // 1..=20: ceil(0.95 * 20) = 19.
        let samples: Vec<f64> = (1..=20).map(|i| i as f64).collect();
        assert_eq!(Summary::of(&samples).p95, 19.0);
        assert_eq!(Summary::of(&[7.0]).p95, 7.0);
    }

    #[test]
    fn summary_p99_nearest_rank() {
        // 1..=100: ceil(0.99 * 100) = 99 -> the 99th smallest sample.
        let samples: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(Summary::of(&samples).p99, 99.0);
        // 1..=200: ceil(0.99 * 200) = 198.
        let samples: Vec<f64> = (1..=200).map(|i| i as f64).collect();
        assert_eq!(Summary::of(&samples).p99, 198.0);
        // small n: p99 collapses to the max, and the tail stays ordered
        let s = Summary::of(&[3.0, 1.0, 2.0]);
        assert_eq!(s.p99, 3.0);
        assert!(s.p99 >= s.p95);
    }

    #[test]
    #[should_panic(expected = "empty")]
    fn summary_rejects_empty() {
        Summary::of(&[]);
    }

    #[test]
    fn measure_runs_and_times() {
        let mut runs = 0;
        let s = measure(2, 5, || {
            runs += 1;
            std::hint::black_box(());
        });
        assert_eq!(runs, 7);
        assert_eq!(s.n, 5);
        assert!(s.min >= 0.0);
    }

    #[test]
    fn counter_accumulates_across_threads() {
        let c = Counter::new();
        std::thread::scope(|scope| {
            for _ in 0..4 {
                scope.spawn(|| {
                    for _ in 0..1000 {
                        c.inc();
                    }
                });
            }
        });
        c.add(5);
        assert_eq!(c.get(), 4005);
    }

    #[test]
    fn timer_monotonic() {
        let t = Timer::start();
        std::thread::sleep(std::time::Duration::from_millis(2));
        assert!(t.millis() >= 2.0);
    }
}

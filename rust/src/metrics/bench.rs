//! Paper-style result tables: each bench regenerates a figure by printing
//! the same rows/series the paper plots, as aligned text, markdown and
//! CSV, plus an ASCII sparkline chart for quick shape inspection.

use std::fmt::Write as _;

/// One plotted series (a line in the paper's figure).
#[derive(Debug, Clone)]
pub struct Series {
    pub name: String,
    pub values: Vec<f64>,
}

impl Series {
    pub fn new(name: impl Into<String>, values: Vec<f64>) -> Self {
        Self { name: name.into(), values }
    }
}

/// A figure reproduction: an x-axis plus one or more series.
#[derive(Debug, Clone)]
pub struct BenchTable {
    pub title: String,
    pub x_label: String,
    pub x: Vec<f64>,
    pub series: Vec<Series>,
}

impl BenchTable {
    pub fn new(title: impl Into<String>, x_label: impl Into<String>, x: Vec<f64>) -> Self {
        Self {
            title: title.into(),
            x_label: x_label.into(),
            x,
            series: Vec::new(),
        }
    }

    pub fn push_series(&mut self, s: Series) -> &mut Self {
        assert_eq!(
            s.values.len(),
            self.x.len(),
            "series '{}' length mismatch",
            s.name
        );
        self.series.push(s);
        self
    }

    /// Markdown table (the form EXPERIMENTS.md embeds).
    pub fn to_markdown(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "### {}", self.title);
        let _ = write!(out, "| {} |", self.x_label);
        for s in &self.series {
            let _ = write!(out, " {} |", s.name);
        }
        let _ = writeln!(out);
        let _ = write!(out, "|---|");
        for _ in &self.series {
            let _ = write!(out, "---|");
        }
        let _ = writeln!(out);
        for (i, x) in self.x.iter().enumerate() {
            let _ = write!(out, "| {} |", trim_num(*x));
            for s in &self.series {
                let _ = write!(out, " {} |", trim_num(s.values[i]));
            }
            let _ = writeln!(out);
        }
        out
    }

    /// CSV (one row per x, columns = series).
    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        let _ = write!(out, "{}", self.x_label);
        for s in &self.series {
            let _ = write!(out, ",{}", s.name);
        }
        let _ = writeln!(out);
        for (i, x) in self.x.iter().enumerate() {
            let _ = write!(out, "{}", trim_num(*x));
            for s in &self.series {
                let _ = write!(out, ",{}", trim_num(s.values[i]));
            }
            let _ = writeln!(out);
        }
        out
    }

    /// ASCII chart: one sparkline row per series, normalized over the
    /// table's global max — enough to eyeball "who wins / where's the knee".
    pub fn to_ascii_chart(&self) -> String {
        const GLYPHS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
        let max = self
            .series
            .iter()
            .flat_map(|s| s.values.iter())
            .fold(0.0f64, |a, &b| a.max(b));
        let mut out = String::new();
        let _ = writeln!(out, "{} (max={})", self.title, trim_num(max));
        let width = self.series.iter().map(|s| s.name.len()).max().unwrap_or(0);
        for s in &self.series {
            let line: String = s
                .values
                .iter()
                .map(|&v| {
                    if max <= 0.0 {
                        GLYPHS[0]
                    } else {
                        let idx = ((v / max) * 7.0).round() as usize;
                        GLYPHS[idx.min(7)]
                    }
                })
                .collect();
            let _ = writeln!(out, "{:>width$} {}", s.name, line, width = width);
        }
        out
    }

    /// Print everything to stdout (what bench binaries call) and return
    /// the markdown for EXPERIMENTS.md capture.
    pub fn emit(&self) -> String {
        let md = self.to_markdown();
        println!("{md}");
        println!("{}", self.to_ascii_chart());
        println!("--- csv ---\n{}", self.to_csv());
        md
    }
}

fn trim_num(v: f64) -> String {
    if v == 0.0 {
        return "0".into();
    }
    if v.fract() == 0.0 && v.abs() < 1e12 {
        format!("{}", v as i64)
    } else if v.abs() >= 100.0 {
        format!("{v:.1}")
    } else {
        format!("{v:.3}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn table() -> BenchTable {
        let mut t = BenchTable::new("Fig X", "n", vec![1.0, 2.0, 3.0]);
        t.push_series(Series::new("a", vec![1.0, 4.0, 9.0]));
        t.push_series(Series::new("b", vec![2.0, 2.0, 2.0]));
        t
    }

    #[test]
    fn markdown_shape() {
        let md = table().to_markdown();
        assert!(md.contains("### Fig X"));
        assert!(md.contains("| n | a | b |"));
        assert!(md.contains("| 2 | 4 | 2 |"));
    }

    #[test]
    fn csv_shape() {
        let csv = table().to_csv();
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines[0], "n,a,b");
        assert_eq!(lines[2], "2,4,2");
        assert_eq!(lines.len(), 4);
    }

    #[test]
    fn ascii_chart_has_one_row_per_series() {
        let chart = table().to_ascii_chart();
        assert_eq!(chart.lines().count(), 3); // title + 2 series
        assert!(chart.contains('█')); // the max point saturates
    }

    #[test]
    #[should_panic(expected = "length mismatch")]
    fn series_length_validated() {
        let mut t = BenchTable::new("t", "x", vec![1.0]);
        t.push_series(Series::new("bad", vec![1.0, 2.0]));
    }

    #[test]
    fn num_formatting() {
        assert_eq!(trim_num(0.0), "0");
        assert_eq!(trim_num(3.0), "3");
        assert_eq!(trim_num(0.5), "0.500");
        assert_eq!(trim_num(123.456), "123.5");
    }
}

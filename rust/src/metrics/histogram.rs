//! Lock-free log-linear latency histogram — the serving layer's tail
//! instrument (p50/p95/p99 over request latencies).
//!
//! Values (nanoseconds) are bucketed into power-of-two octaves, each
//! subdivided into 4 linear sub-buckets, HDR-style: 252 fixed buckets
//! cover the full `u64` range with <= 25% relative error per bucket.
//! Recording is a single relaxed atomic increment, so every server worker
//! shares one histogram with no lock on the request path. Quantiles are
//! computed from an immutable [`HistogramSnapshot`]; snapshots subtract
//! (`diff`) so a closed-loop bench can report per-phase tails from one
//! continuously recording histogram. In-tree because the offline crate
//! set has no hdrhistogram (DESIGN.md §Substitutions).

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Sub-buckets per octave (2 bits of mantissa below the leading bit).
const SUBS: usize = 4;
/// Octaves 2..=63 get `SUBS` buckets each; values < `SUBS` are exact.
const BUCKETS: usize = 63 * SUBS;

/// Bucket index for a nanosecond value. Monotone in `n`.
fn bucket_of(n: u64) -> usize {
    if n < SUBS as u64 {
        return n as usize;
    }
    let octave = 63 - n.leading_zeros() as usize; // >= 2
    let sub = ((n >> (octave - 2)) & 0b11) as usize;
    (octave - 1) * SUBS + sub
}

/// Inclusive upper bound of a bucket — quantiles report this value, so
/// the coarsening never *under*-states a tail.
fn bucket_upper(b: usize) -> u64 {
    if b < SUBS {
        return b as u64;
    }
    let octave = b / SUBS + 1;
    let sub = (b % SUBS) as u64;
    let width = 1u64 << (octave - 2);
    // The true bound is <= u64::MAX, but the top bucket's intermediate
    // sum is exactly 2^64; wrapping arithmetic lands on the right value.
    (1u64 << octave)
        .wrapping_add((sub + 1) * width)
        .wrapping_sub(1)
}

/// Concurrent recording side. `record` is wait-free; readers take a
/// [`snapshot`](Self::snapshot) and query that.
#[derive(Debug)]
pub struct LatencyHistogram {
    buckets: [AtomicU64; BUCKETS],
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    pub fn new() -> Self {
        Self {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
        }
    }

    /// Record one latency observation.
    pub fn record(&self, latency: Duration) {
        let nanos = latency.as_nanos().min(u64::MAX as u128) as u64;
        self.buckets[bucket_of(nanos)].fetch_add(1, Ordering::Relaxed);
    }

    /// A point-in-time copy of the bucket counts.
    pub fn snapshot(&self) -> HistogramSnapshot {
        HistogramSnapshot {
            buckets: std::array::from_fn(|i| self.buckets[i].load(Ordering::Relaxed)),
        }
    }
}

/// Immutable bucket counts; all quantile math happens here.
#[derive(Debug, Clone)]
pub struct HistogramSnapshot {
    buckets: [u64; BUCKETS],
}

impl HistogramSnapshot {
    /// Total observations.
    pub fn count(&self) -> u64 {
        self.buckets.iter().sum()
    }

    /// Observations recorded since `earlier` (per-bucket saturating
    /// subtraction) — the per-phase view of a shared histogram.
    pub fn diff(&self, earlier: &HistogramSnapshot) -> HistogramSnapshot {
        HistogramSnapshot {
            buckets: std::array::from_fn(|i| {
                self.buckets[i].saturating_sub(earlier.buckets[i])
            }),
        }
    }

    /// The `q`-quantile (`q` clamped to [0, 1]) as a duration, reported
    /// at the covering bucket's upper bound. Empty snapshot -> zero.
    pub fn quantile(&self, q: f64) -> Duration {
        let total = self.count();
        if total == 0 {
            return Duration::ZERO;
        }
        let rank = ((q.clamp(0.0, 1.0) * total as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (b, &n) in self.buckets.iter().enumerate() {
            seen += n;
            if seen >= rank {
                return Duration::from_nanos(bucket_upper(b));
            }
        }
        Duration::from_nanos(bucket_upper(BUCKETS - 1))
    }

    /// The standard serving triple.
    pub fn p50_p95_p99(&self) -> (Duration, Duration, Duration) {
        (self.quantile(0.50), self.quantile(0.95), self.quantile(0.99))
    }

    /// Fraction of observations strictly above `threshold` — the SLO
    /// watcher's burn-rate numerator. Bucketing is conservative: a
    /// bucket straddling the threshold counts as above (its upper bound
    /// exceeds it), so the burn rate never under-reports. Empty
    /// snapshot -> 0.
    pub fn fraction_above(&self, threshold: Duration) -> f64 {
        let total = self.count();
        if total == 0 {
            return 0.0;
        }
        let nanos = threshold.as_nanos().min(u64::MAX as u128) as u64;
        let over: u64 = self
            .buckets
            .iter()
            .enumerate()
            .filter(|(b, _)| bucket_upper(*b) > nanos)
            .map(|(_, &n)| n)
            .sum();
        over as f64 / total as f64
    }

    /// Combine two snapshots by per-bucket addition — the scatter-gather
    /// aggregation: per-shard histograms merge into one fabric-level
    /// distribution without double-counting, because each observation
    /// lives in exactly one source snapshot's bucket. Saturating so two
    /// adversarial snapshots can't wrap a count.
    pub fn merge(&self, other: &HistogramSnapshot) -> HistogramSnapshot {
        HistogramSnapshot {
            buckets: std::array::from_fn(|i| {
                self.buckets[i].saturating_add(other.buckets[i])
            }),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buckets_are_monotone_and_bounded() {
        let mut prev = 0;
        for n in (0..4096u64).chain([1 << 20, 1 << 40, u64::MAX / 2, u64::MAX]) {
            let b = bucket_of(n);
            assert!(b >= prev, "bucket_of not monotone at {n}");
            assert!(b < BUCKETS);
            assert!(bucket_upper(b) >= n, "upper bound below value at {n}");
            prev = b;
        }
    }

    #[test]
    fn bucket_relative_error_bounded() {
        for n in [10u64, 100, 1_000, 50_000, 1_000_000, 123_456_789] {
            let upper = bucket_upper(bucket_of(n));
            assert!(upper >= n);
            assert!(
                (upper - n) as f64 <= 0.25 * n as f64 + 1.0,
                "bucket too coarse at {n}: upper {upper}"
            );
        }
    }

    #[test]
    fn quantiles_of_uniform_stream() {
        let h = LatencyHistogram::new();
        for us in 1..=1000u64 {
            h.record(Duration::from_micros(us));
        }
        let s = h.snapshot();
        assert_eq!(s.count(), 1000);
        let (p50, p95, p99) = s.p50_p95_p99();
        assert!(p50 >= Duration::from_micros(500) && p50 <= Duration::from_micros(625));
        assert!(p95 >= Duration::from_micros(950) && p95 <= Duration::from_micros(1188));
        assert!(p99 >= p95 && p95 >= p50);
    }

    #[test]
    fn empty_snapshot_is_zero() {
        let s = LatencyHistogram::new().snapshot();
        assert_eq!(s.count(), 0);
        assert_eq!(s.quantile(0.99), Duration::ZERO);
    }

    #[test]
    fn fraction_above_splits_a_bimodal_stream() {
        let h = LatencyHistogram::new();
        for _ in 0..90 {
            h.record(Duration::from_micros(10));
        }
        for _ in 0..10 {
            h.record(Duration::from_millis(10));
        }
        let s = h.snapshot();
        let f = s.fraction_above(Duration::from_millis(1));
        assert!((f - 0.10).abs() < 1e-9, "10% of the stream is slow, got {f}");
        assert_eq!(s.fraction_above(Duration::from_secs(1)), 0.0);
        // everything exceeds a sub-bucket threshold
        assert_eq!(s.fraction_above(Duration::ZERO), 1.0);
        assert_eq!(
            LatencyHistogram::new().snapshot().fraction_above(Duration::ZERO),
            0.0
        );
    }

    #[test]
    fn diff_isolates_a_phase() {
        let h = LatencyHistogram::new();
        for _ in 0..100 {
            h.record(Duration::from_micros(10));
        }
        let mid = h.snapshot();
        for _ in 0..100 {
            h.record(Duration::from_millis(10));
        }
        let phase2 = h.snapshot().diff(&mid);
        assert_eq!(phase2.count(), 100);
        // phase 2 saw only the slow requests
        assert!(phase2.quantile(0.5) >= Duration::from_millis(10));
    }

    #[test]
    fn merge_combines_two_known_distributions() {
        // Shard A saw 200 fast requests, shard B 100 slow ones; the merge
        // must hold all 300 with quantiles of the combined stream.
        let a = LatencyHistogram::new();
        for _ in 0..200 {
            a.record(Duration::from_micros(10));
        }
        let b = LatencyHistogram::new();
        for _ in 0..100 {
            b.record(Duration::from_millis(10));
        }
        let (sa, sb) = (a.snapshot(), b.snapshot());
        let merged = sa.merge(&sb);
        assert_eq!(merged.count(), 300);
        // p50 falls in the fast mode (2/3 of mass), p95 in the slow mode.
        assert!(merged.quantile(0.5) < Duration::from_millis(1));
        assert!(merged.quantile(0.95) >= Duration::from_millis(10));
        // Merging is commutative and the oracle agrees: one histogram
        // fed both streams bucket-equals the merge of the two.
        let both = LatencyHistogram::new();
        for _ in 0..200 {
            both.record(Duration::from_micros(10));
        }
        for _ in 0..100 {
            both.record(Duration::from_millis(10));
        }
        let oracle = both.snapshot();
        for q in [0.1, 0.5, 0.9, 0.95, 0.99] {
            assert_eq!(merged.quantile(q), oracle.quantile(q), "q={q}");
            assert_eq!(merged.quantile(q), sb.merge(&sa).quantile(q), "q={q}");
        }
        // No double-counting: merging with an empty snapshot is identity.
        let empty = LatencyHistogram::new().snapshot();
        assert_eq!(merged.merge(&empty).count(), 300);
    }

    #[test]
    fn concurrent_recording_loses_nothing() {
        let h = std::sync::Arc::new(LatencyHistogram::new());
        let threads: Vec<_> = (0..4)
            .map(|t| {
                let h = h.clone();
                std::thread::spawn(move || {
                    for i in 0..1000u64 {
                        h.record(Duration::from_nanos(100 + t * 1000 + i));
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        assert_eq!(h.snapshot().count(), 4000);
    }
}

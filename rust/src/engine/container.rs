//! Roaring-style chunked TID containers for the vertical engine.
//!
//! A TID set over `n_tx` transactions is split into 2^16-TID chunks; each
//! chunk independently picks the cheapest of three physical layouts by a
//! byte-cost model (Singh et al.'s occupancy study, PAPERS.md 1511.07017:
//! the winning representation flips with density, so the whole-row choice
//! `vertical.rs` made before this module loses on skewed data):
//!
//! - **Array**: sorted `u16` low bits, 2 bytes/TID. Wins when sparse.
//! - **Bitmap**: one bit per slot of the chunk's span, 8 bytes/word.
//!   Wins when dense. The bitmap is sized to the chunk's *span*
//!   (`min(2^16, n_tx - base)`), not a fixed 1024 words, so a narrow
//!   database costs the same as the old whole-row dense layout.
//! - **Runs**: `(start, run_len - 1)` pairs, 4 bytes/run. Wins on
//!   clustered TIDs; a full chunk is the single run `(0, 0xFFFF)`.
//!
//! Every layout pairing has a dedicated intersection kernel (galloping
//! array merge, word AND+popcount, run×any range arithmetic), and
//! materialized intersections transcode the result back through the same
//! cost model so a densifying or sparsifying chain of intersections stays
//! in its cheapest layout.

use std::cmp::Ordering;

/// Low bits of a TID that address within one chunk.
pub const CHUNK_BITS: u32 = 16;
/// TIDs per chunk.
pub const CHUNK_SPAN: usize = 1 << CHUNK_BITS;
/// Largest cardinality an array container may hold (roaring's 4096: past
/// this, a full-span bitmap is never larger than the array).
pub const ARRAY_MAX: usize = 4096;

/// Gallop when the longer array is at least this many times the shorter.
const GALLOP_RATIO: usize = 16;

/// One chunk's physical layout. All constructors take TIDs as chunk-local
/// low bits, strictly ascending.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Container {
    /// Sorted chunk-local TIDs.
    Array(Vec<u16>),
    /// One bit per slot over the chunk's span; `card` caches the popcount.
    Bitmap { words: Vec<u64>, card: u32 },
    /// Sorted disjoint `(start, run_len - 1)` intervals.
    Runs(Vec<(u16, u16)>),
}

impl Container {
    /// Pick the cheapest layout for `tids` (strictly ascending, all
    /// `< span`) by byte cost: runs win only when strictly cheapest, and
    /// arrays win cost ties against bitmaps.
    pub fn from_sorted(tids: &[u16], span: usize) -> Self {
        debug_assert!(span >= 1 && span <= CHUNK_SPAN);
        debug_assert!(tids.windows(2).all(|w| w[0] < w[1]));
        debug_assert!(tids.iter().all(|&t| (t as usize) < span));
        let card = tids.len();
        let run_cost = 4 * count_runs(tids);
        let array_cost = if card <= ARRAY_MAX {
            2 * card
        } else {
            usize::MAX
        };
        let bitmap_cost = span.div_ceil(64) * 8;
        if card > 0 && run_cost < array_cost && run_cost < bitmap_cost {
            Self::runs_from_sorted(tids)
        } else if array_cost <= bitmap_cost {
            Self::Array(tids.to_vec())
        } else {
            Self::bitmap_from_sorted(tids, span)
        }
    }

    /// Force the array layout (tests and the bench's kernel cross-checks).
    pub fn array(tids: Vec<u16>) -> Self {
        debug_assert!(tids.windows(2).all(|w| w[0] < w[1]));
        Self::Array(tids)
    }

    /// Force the bitmap layout over `span` slots.
    pub fn bitmap_from_sorted(tids: &[u16], span: usize) -> Self {
        let mut words = vec![0u64; span.div_ceil(64)];
        for &t in tids {
            words[t as usize / 64] |= 1u64 << (t % 64);
        }
        Self::Bitmap { words, card: tids.len() as u32 }
    }

    /// Force the run-length layout.
    pub fn runs_from_sorted(tids: &[u16]) -> Self {
        let mut runs: Vec<(u16, u16)> = Vec::new();
        for &t in tids {
            match runs.last_mut() {
                Some((start, len)) if *start as usize + *len as usize + 1 == t as usize => {
                    *len += 1;
                }
                _ => runs.push((t, 0)),
            }
        }
        Self::Runs(runs)
    }

    pub fn cardinality(&self) -> usize {
        match self {
            Self::Array(a) => a.len(),
            Self::Bitmap { card, .. } => *card as usize,
            Self::Runs(r) => r.iter().map(|&(_, len)| len as usize + 1).sum(),
        }
    }

    /// Payload bytes of this layout (what the cost model compares).
    pub fn bytes(&self) -> usize {
        match self {
            Self::Array(a) => 2 * a.len(),
            Self::Bitmap { words, .. } => 8 * words.len(),
            Self::Runs(r) => 4 * r.len(),
        }
    }

    pub fn contains(&self, t: u16) -> bool {
        match self {
            Self::Array(a) => a.binary_search(&t).is_ok(),
            Self::Bitmap { words, .. } => bitmap_contains(words, t),
            Self::Runs(r) => {
                let i = r.partition_point(|&(start, _)| start <= t);
                i > 0 && t as usize <= r[i - 1].0 as usize + r[i - 1].1 as usize
            }
        }
    }

    /// Decode to strictly-ascending chunk-local TIDs.
    pub fn decode(&self) -> Vec<u16> {
        match self {
            Self::Array(a) => a.clone(),
            Self::Bitmap { words, card } => {
                let mut out = Vec::with_capacity(*card as usize);
                for (wi, &word) in words.iter().enumerate() {
                    let mut w = word;
                    while w != 0 {
                        out.push((wi * 64 + w.trailing_zeros() as usize) as u16);
                        w &= w - 1;
                    }
                }
                out
            }
            Self::Runs(r) => {
                let mut out = Vec::with_capacity(self.cardinality());
                for &(start, len) in r {
                    for t in start..=start + len {
                        out.push(t);
                    }
                }
                out
            }
        }
    }

    /// `|self ∩ other|` without materializing the result. Each of the six
    /// layout pairings has its own kernel.
    pub fn intersect_count(&self, other: &Self) -> u64 {
        use Container::*;
        match (self, other) {
            (Array(a), Array(b)) => array_x_array_count(a, b),
            (Bitmap { words: a, .. }, Bitmap { words: b, .. }) => {
                a.iter().zip(b).map(|(x, y)| (x & y).count_ones() as u64).sum()
            }
            (Array(a), Bitmap { words, .. }) | (Bitmap { words, .. }, Array(a)) => {
                array_x_bitmap_count(a, words)
            }
            (Runs(r), Array(a)) | (Array(a), Runs(r)) => runs_x_array_count(r, a),
            (Runs(r), Bitmap { words, .. }) | (Bitmap { words, .. }, Runs(r)) => {
                runs_x_bitmap_count(r, words)
            }
            (Runs(a), Runs(b)) => runs_x_runs_count(a, b),
        }
    }

    /// Materialize `self ∩ other`, transcoding the result back through the
    /// cost model (a densifying AND chain sparsifies into arrays or runs
    /// as soon as that is cheaper, and vice versa).
    pub fn intersect(&self, other: &Self, span: usize) -> Self {
        use Container::*;
        match (self, other) {
            (Bitmap { words: a, .. }, Bitmap { words: b, .. }) => {
                let words: Vec<u64> = a.iter().zip(b).map(|(x, y)| x & y).collect();
                finalize_bitmap(words, span)
            }
            (Runs(r), Bitmap { words, .. }) | (Bitmap { words, .. }, Runs(r)) => {
                let mut masked = vec![0u64; words.len()];
                for &(start, len) in r {
                    let (s, e) = (start as usize, start as usize + len as usize);
                    bitmap_range_copy(words, &mut masked, s, e);
                }
                finalize_bitmap(masked, span)
            }
            (Runs(a), Runs(b)) => finalize_runs(runs_x_runs(a, b), span),
            // Any pairing with an array stays at or under ARRAY_MAX TIDs,
            // so filter into an array and let the cost model re-pick.
            (Array(a), Array(b)) => {
                let (small, large) = if a.len() <= b.len() { (a, b) } else { (b, a) };
                let mut out = Vec::new();
                for &t in small {
                    if large.binary_search(&t).is_ok() {
                        out.push(t);
                    }
                }
                Self::from_sorted(&out, span)
            }
            (Array(a), b) | (b, Array(a)) => {
                let mut out = Vec::new();
                for &t in a {
                    if b.contains(t) {
                        out.push(t);
                    }
                }
                Self::from_sorted(&out, span)
            }
        }
    }
}

/// Number of maximal consecutive runs in a strictly-ascending TID list.
fn count_runs(tids: &[u16]) -> usize {
    let mut n = 0usize;
    let mut prev = usize::MAX - 1;
    for &t in tids {
        if prev + 1 != t as usize {
            n += 1;
        }
        prev = t as usize;
    }
    n
}

fn bitmap_contains(words: &[u64], t: u16) -> bool {
    words.get(t as usize / 64).is_some_and(|&w| (w >> (t % 64)) & 1 == 1)
}

fn array_x_array_count(a: &[u16], b: &[u16]) -> u64 {
    let (small, large) = if a.len() <= b.len() { (a, b) } else { (b, a) };
    if small.is_empty() {
        return 0;
    }
    if large.len() / small.len() >= GALLOP_RATIO {
        return gallop_count(small, large);
    }
    let (mut i, mut j, mut n) = (0usize, 0usize, 0u64);
    while i < small.len() && j < large.len() {
        match small[i].cmp(&large[j]) {
            Ordering::Less => i += 1,
            Ordering::Greater => j += 1,
            Ordering::Equal => {
                n += 1;
                i += 1;
                j += 1;
            }
        }
    }
    n
}

/// Exponential probe + bounded binary search per element of `small`.
fn gallop_count(small: &[u16], large: &[u16]) -> u64 {
    let mut lo = 0usize;
    let mut n = 0u64;
    for &x in small {
        let mut bound = 1usize;
        while lo + bound < large.len() && large[lo + bound] < x {
            bound *= 2;
        }
        let hi = (lo + bound + 1).min(large.len());
        let idx = lo + large[lo..hi].partition_point(|&y| y < x);
        if idx < large.len() && large[idx] == x {
            n += 1;
            lo = idx + 1;
        } else {
            lo = idx;
        }
        if lo >= large.len() {
            break;
        }
    }
    n
}

fn array_x_bitmap_count(a: &[u16], words: &[u64]) -> u64 {
    a.iter().filter(|&&t| bitmap_contains(words, t)).count() as u64
}

fn runs_x_array_count(runs: &[(u16, u16)], a: &[u16]) -> u64 {
    let mut i = 0usize;
    let mut n = 0u64;
    for &(start, len) in runs {
        let end = start as usize + len as usize;
        while i < a.len() && (a[i] as usize) < start as usize {
            i += 1;
        }
        let begin = i;
        while i < a.len() && a[i] as usize <= end {
            i += 1;
        }
        n += (i - begin) as u64;
    }
    n
}

fn runs_x_bitmap_count(runs: &[(u16, u16)], words: &[u64]) -> u64 {
    let mut n = 0u64;
    for &(start, len) in runs {
        n += bitmap_range_count(words, start as usize, start as usize + len as usize);
    }
    n
}

fn runs_x_runs_count(a: &[(u16, u16)], b: &[(u16, u16)]) -> u64 {
    let (mut i, mut j, mut n) = (0usize, 0usize, 0u64);
    while i < a.len() && j < b.len() {
        let (a0, a1) = (a[i].0 as u64, a[i].0 as u64 + a[i].1 as u64);
        let (b0, b1) = (b[j].0 as u64, b[j].0 as u64 + b[j].1 as u64);
        let (lo, hi) = (a0.max(b0), a1.min(b1));
        if lo <= hi {
            n += hi - lo + 1;
        }
        if a1 <= b1 {
            i += 1;
        } else {
            j += 1;
        }
    }
    n
}

/// Materialized run×run intersection: the overlapping intervals.
fn runs_x_runs(a: &[(u16, u16)], b: &[(u16, u16)]) -> Vec<(u16, u16)> {
    let mut out = Vec::new();
    let (mut i, mut j) = (0usize, 0usize);
    while i < a.len() && j < b.len() {
        let (a0, a1) = (a[i].0 as usize, a[i].0 as usize + a[i].1 as usize);
        let (b0, b1) = (b[j].0 as usize, b[j].0 as usize + b[j].1 as usize);
        let (lo, hi) = (a0.max(b0), a1.min(b1));
        if lo <= hi {
            out.push((lo as u16, (hi - lo) as u16));
        }
        if a1 <= b1 {
            i += 1;
        } else {
            j += 1;
        }
    }
    out
}

/// Popcount of `words` over the inclusive slot range `[start, end]`.
fn bitmap_range_count(words: &[u64], start: usize, end: usize) -> u64 {
    let w0 = start / 64;
    let w1 = end / 64;
    let mut n = 0u64;
    for w in w0..=w1 {
        let mut word = match words.get(w) {
            Some(&x) => x,
            None => break,
        };
        if w == w0 {
            word &= !0u64 << (start % 64);
        }
        if w == w1 && end % 64 < 63 {
            word &= (1u64 << (end % 64 + 1)) - 1;
        }
        n += word.count_ones() as u64;
    }
    n
}

/// OR the inclusive slot range `[start, end]` of `src` into `dst`.
fn bitmap_range_copy(src: &[u64], dst: &mut [u64], start: usize, end: usize) {
    let w0 = start / 64;
    let w1 = end / 64;
    for w in w0..=w1 {
        let mut word = match src.get(w) {
            Some(&x) => x,
            None => break,
        };
        if w == w0 {
            word &= !0u64 << (start % 64);
        }
        if w == w1 && end % 64 < 63 {
            word &= (1u64 << (end % 64 + 1)) - 1;
        }
        dst[w] |= word;
    }
}

/// Re-pick the layout for a freshly ANDed bitmap: sparsify to an array
/// (or runs) when at or under [`ARRAY_MAX`].
fn finalize_bitmap(words: Vec<u64>, span: usize) -> Container {
    let card: u64 = words.iter().map(|w| w.count_ones() as u64).sum();
    if card as usize <= ARRAY_MAX {
        let bm = Container::Bitmap { words, card: card as u32 };
        Container::from_sorted(&bm.decode(), span)
    } else {
        Container::Bitmap { words, card: card as u32 }
    }
}

/// Re-pick the layout for a freshly intersected run list, keeping the
/// runs when they remain the cheapest layout.
fn finalize_runs(runs: Vec<(u16, u16)>, span: usize) -> Container {
    let card: usize = runs.iter().map(|&(_, len)| len as usize + 1).sum();
    let run_cost = 4 * runs.len();
    let array_cost = if card <= ARRAY_MAX {
        2 * card
    } else {
        usize::MAX
    };
    let bitmap_cost = span.div_ceil(64) * 8;
    if !runs.is_empty() && run_cost <= array_cost.min(bitmap_cost) {
        Container::Runs(runs)
    } else {
        Container::from_sorted(&Container::Runs(runs).decode(), span)
    }
}

/// Tally of chunk layouts across a set (the occupancy sweep reports it).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ContainerCensus {
    pub arrays: usize,
    pub bitmaps: usize,
    pub runs: usize,
}

impl ContainerCensus {
    pub fn total(&self) -> usize {
        self.arrays + self.bitmaps + self.runs
    }
}

impl std::ops::AddAssign for ContainerCensus {
    fn add_assign(&mut self, rhs: Self) {
        self.arrays += rhs.arrays;
        self.bitmaps += rhs.bitmaps;
        self.runs += rhs.runs;
    }
}

/// A TID set over `n_tx` transactions as sorted `(chunk_key, container)`
/// pairs; chunks with no TIDs are absent. Intersections merge-join on the
/// chunk key, so two sets only pay for chunks they share.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct TidSet {
    chunks: Vec<(u32, Container)>,
    n_tx: usize,
}

/// Slots chunk `key` spans: the last chunk of a database is truncated.
fn chunk_span(key: u32, n_tx: usize) -> usize {
    (n_tx - key as usize * CHUNK_SPAN).min(CHUNK_SPAN)
}

impl TidSet {
    /// Build from strictly-ascending TIDs, all `< n_tx`.
    pub fn from_sorted_tids(tids: &[u32], n_tx: usize) -> Self {
        debug_assert!(tids.windows(2).all(|w| w[0] < w[1]));
        debug_assert!(tids.iter().all(|&t| (t as usize) < n_tx));
        let mut chunks = Vec::new();
        let mut low = Vec::new();
        let mut i = 0usize;
        while i < tids.len() {
            let key = tids[i] >> CHUNK_BITS;
            low.clear();
            while i < tids.len() && tids[i] >> CHUNK_BITS == key {
                low.push((tids[i] & (CHUNK_SPAN as u32 - 1)) as u16);
                i += 1;
            }
            chunks.push((key, Container::from_sorted(&low, chunk_span(key, n_tx))));
        }
        Self { chunks, n_tx }
    }

    pub fn cardinality(&self) -> usize {
        self.chunks.iter().map(|(_, c)| c.cardinality()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.chunks.is_empty()
    }

    /// Resident bytes: each chunk pays its payload plus a 4-byte key.
    pub fn bytes(&self) -> usize {
        self.chunks.iter().map(|(_, c)| 4 + c.bytes()).sum()
    }

    pub fn census(&self) -> ContainerCensus {
        let mut census = ContainerCensus::default();
        for (_, c) in &self.chunks {
            match c {
                Container::Array(_) => census.arrays += 1,
                Container::Bitmap { .. } => census.bitmaps += 1,
                Container::Runs(_) => census.runs += 1,
            }
        }
        census
    }

    /// Decode to strictly-ascending global TIDs.
    pub fn decode(&self) -> Vec<u32> {
        let mut out = Vec::with_capacity(self.cardinality());
        for (key, c) in &self.chunks {
            let base = key << CHUNK_BITS;
            out.extend(c.decode().into_iter().map(|t| base | t as u32));
        }
        out
    }

    /// `|self ∩ other|` via a merge-join over shared chunks.
    pub fn intersect_count(&self, other: &Self) -> u64 {
        let (mut i, mut j, mut n) = (0usize, 0usize, 0u64);
        while i < self.chunks.len() && j < other.chunks.len() {
            match self.chunks[i].0.cmp(&other.chunks[j].0) {
                Ordering::Less => i += 1,
                Ordering::Greater => j += 1,
                Ordering::Equal => {
                    n += self.chunks[i].1.intersect_count(&other.chunks[j].1);
                    i += 1;
                    j += 1;
                }
            }
        }
        n
    }

    /// Materialize `self ∩ other`; result chunks transcode to their
    /// cheapest layout and empty chunks are dropped.
    pub fn intersect(&self, other: &Self) -> Self {
        let mut chunks = Vec::new();
        let (mut i, mut j) = (0usize, 0usize);
        while i < self.chunks.len() && j < other.chunks.len() {
            match self.chunks[i].0.cmp(&other.chunks[j].0) {
                Ordering::Less => i += 1,
                Ordering::Greater => j += 1,
                Ordering::Equal => {
                    let key = self.chunks[i].0;
                    let span = chunk_span(key, self.n_tx);
                    let c = self.chunks[i].1.intersect(&other.chunks[j].1, span);
                    if c.cardinality() > 0 {
                        chunks.push((key, c));
                    }
                    i += 1;
                    j += 1;
                }
            }
        }
        Self { chunks, n_tx: self.n_tx }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cost_model_picks_expected_layouts() {
        // Scattered small set over a wide span: array.
        let sparse: Vec<u16> = (0..100u16).map(|i| i * 13).collect();
        let c = Container::from_sorted(&sparse, CHUNK_SPAN);
        assert!(matches!(c, Container::Array(_)), "{c:?}");
        // Consecutive prefix: a single run beats both.
        let prefix: Vec<u16> = (0..100u16).collect();
        let c = Container::from_sorted(&prefix, CHUNK_SPAN);
        assert!(matches!(c, Container::Runs(_)), "{c:?}");
        // Half the slots of a narrow span: bitmap.
        let dense: Vec<u16> = (0..192u16).map(|i| i * 2).collect();
        let c = Container::from_sorted(&dense, 384);
        assert!(matches!(c, Container::Bitmap { .. }), "{c:?}");
        // Empty stays an (empty) array.
        let empty = Container::from_sorted(&[], CHUNK_SPAN);
        assert_eq!(empty, Container::Array(Vec::new()));
        assert_eq!(empty.cardinality(), 0);
    }

    #[test]
    fn every_kernel_pairing_matches_the_merge_oracle() {
        let span = 2048usize;
        let mut a: Vec<u16> = (0..500u32).map(|i| (i * 7 % 2048) as u16).collect();
        a.sort_unstable();
        a.dedup();
        let mut b: Vec<u16> = (0..900u32).map(|i| ((i * 5 + 3) % 2048) as u16).collect();
        b.sort_unstable();
        b.dedup();
        let oracle: Vec<u16> = a.iter().copied().filter(|t| b.binary_search(t).is_ok()).collect();
        let variants = |t: &[u16]| {
            vec![
                Container::array(t.to_vec()),
                Container::bitmap_from_sorted(t, span),
                Container::runs_from_sorted(t),
            ]
        };
        for ca in variants(&a) {
            for cb in variants(&b) {
                assert_eq!(ca.intersect_count(&cb), oracle.len() as u64);
                let materialized = ca.intersect(&cb, span);
                assert_eq!(materialized.decode(), oracle);
            }
        }
    }

    #[test]
    fn tidset_chunk_merge_join_counts_across_boundaries() {
        let n_tx = 3 * CHUNK_SPAN + 17;
        // One set clustered near the chunk edges, one striding everything.
        let a: Vec<u32> = (0..n_tx as u32)
            .filter(|t| t % 65536 < 40 || t % 65536 > 65500)
            .collect();
        let b: Vec<u32> = (0..n_tx as u32).step_by(3).collect();
        let sa = TidSet::from_sorted_tids(&a, n_tx);
        let sb = TidSet::from_sorted_tids(&b, n_tx);
        let oracle: Vec<u32> = a.iter().copied().filter(|t| t % 3 == 0).collect();
        assert_eq!(sa.intersect_count(&sb), oracle.len() as u64);
        assert_eq!(sa.intersect(&sb).decode(), oracle);
        assert_eq!(sa.decode(), a);
        assert_eq!(sa.cardinality(), a.len());
    }

    #[test]
    fn full_chunk_is_one_run() {
        let all: Vec<u16> = (0..CHUNK_SPAN as u32).map(|t| t as u16).collect();
        let c = Container::from_sorted(&all, CHUNK_SPAN);
        assert_eq!(c, Container::Runs(vec![(0, 0xFFFF)]));
        assert_eq!(c.cardinality(), CHUNK_SPAN);
        assert_eq!(c.intersect_count(&c), CHUNK_SPAN as u64);
        assert_eq!(c.bytes(), 4);
    }
}

//! The vertical TID counting engine over chunked containers.
//!
//! Every other CPU engine matches candidates *horizontally*: stream each
//! transaction through a matcher structure and increment the candidates
//! it contains. This engine flips the layout (Apriori-TID / Eclat): one
//! pass over the split builds a per-item **TID index** — which
//! transactions contain item *i* — and each candidate's support is then
//! the size of the intersection of its k item rows, with no further
//! touches of the transaction data at all.
//!
//! Each item row is a [`TidSet`]: roaring-style 2^16-TID chunks that
//! independently pick a sorted-array, dense-bitmap, or run-length layout
//! by byte cost (see [`super::container`]). This replaces the old
//! whole-row dense/sparse dichotomy — a split scales to millions of
//! transactions without drowning its sparse items in zero words, and
//! clustered or ubiquitous items collapse to run containers.
//!
//! Candidates are processed in (length, lexicographic) order so
//! lexicographic siblings share their (k−1)-prefix: the prefix
//! intersection is materialized once (transcoding each result chunk to
//! its cheapest layout) and reused for every sibling, leaving one
//! non-materializing count-intersection per candidate.
//! [`VerticalEngine::count_batch`] is a genuine shared scan — the index
//! is built **once** and answers every level of a batched multi-level
//! job — and the resident [`super::IndexCache`] extends the same reuse
//! across jobs within one dataset generation.

use crate::apriori::Itemset;
use crate::data::columnar::FlatBlock;
use crate::data::{ItemId, Transaction};

use super::container::{ContainerCensus, TidSet};
use super::{EngineError, SupportEngine};

/// A built item→TID index over one transaction slice: one chunked
/// [`TidSet`] row per item.
pub struct VerticalIndex {
    rows: Vec<TidSet>,
    n_tx: usize,
    n_items: usize,
}

impl VerticalIndex {
    /// Build the index from a flattened block; every item row picks its
    /// chunk layouts by occupancy.
    pub fn build(block: &FlatBlock) -> Self {
        let n_items = block.n_items();
        let n_tx = block.len();
        let rows = block
            .tid_lists()
            .iter()
            .map(|list| TidSet::from_sorted_tids(list, n_tx))
            .collect();
        Self { rows, n_tx, n_items }
    }

    /// Chunk-layout tally across every item row (what the occupancy
    /// sweep reports per profile).
    pub fn container_census(&self) -> ContainerCensus {
        let mut census = ContainerCensus::default();
        for row in &self.rows {
            census += row.census();
        }
        census
    }

    /// Resident index size in bytes — the number the ablation reports as
    /// "peak index bytes" per split and the cache charges to the
    /// simulated datanode.
    pub fn bytes(&self) -> usize {
        self.rows.iter().map(TidSet::bytes).sum()
    }

    /// Count every candidate into `counts` (aligned with `candidates`).
    /// Candidates are visited in (length, lexicographic) order
    /// internally so prefix reuse kicks in regardless of input order;
    /// results scatter back to the caller's order.
    pub fn count_into(&self, candidates: &[Itemset], counts: &mut [u64]) {
        debug_assert_eq!(candidates.len(), counts.len());
        let mut order: Vec<usize> = (0..candidates.len()).collect();
        order.sort_by(|&a, &b| {
            let (ca, cb) = (&candidates[a], &candidates[b]);
            (ca.len(), ca).cmp(&(cb.len(), cb))
        });
        // The shared (k−1)-prefix accumulator; valid for `prefix_key`.
        let mut acc = TidSet::default();
        let mut prefix_key: Option<&[ItemId]> = None;
        for &ci in &order {
            let cand = &candidates[ci];
            counts[ci] = match cand.len() {
                // The empty itemset is contained in every transaction.
                0 => self.n_tx as u64,
                _ if self.unmatchable(cand) => 0,
                1 => self.row(cand[0]).cardinality() as u64,
                // Pairs skip the accumulator: one direct row×row count.
                2 => self.row(cand[0]).intersect_count(self.row(cand[1])),
                k => {
                    let prefix = &cand[..k - 1];
                    if prefix_key != Some(prefix) {
                        acc = self.row(prefix[0]).intersect(self.row(prefix[1]));
                        for &item in &prefix[2..] {
                            acc = acc.intersect(self.row(item));
                        }
                        prefix_key = Some(prefix);
                    }
                    acc.intersect_count(self.row(cand[k - 1]))
                }
            };
        }
    }

    fn row(&self, item: ItemId) -> &TidSet {
        &self.rows[item as usize]
    }

    /// A candidate the index can't match: an item beyond the dictionary
    /// (never occurs → support 0) or a non-canonical itemset. Canonical
    /// itemsets are strictly ascending; the sorted-merge oracle
    /// (`Transaction::contains_all`) matches nothing otherwise, and the
    /// vertical path must agree byte-for-byte.
    fn unmatchable(&self, cand: &[ItemId]) -> bool {
        cand.iter().any(|&i| (i as usize) >= self.n_items)
            || cand.windows(2).any(|w| w[0] >= w[1])
    }
}

/// The vertical engine: build the TID index per call (the one pass over
/// the slice), answer candidates by row intersection. Mixed-length
/// candidate lists are native — no per-length structure is needed — and
/// the batched path shares one index build across every group.
pub struct VerticalEngine;

impl VerticalEngine {
    fn build_index(txs: &[Transaction], n_items: usize) -> VerticalIndex {
        VerticalIndex::build(&FlatBlock::from_transactions(txs, n_items))
    }
}

impl SupportEngine for VerticalEngine {
    fn count(
        &self,
        txs: &[Transaction],
        candidates: &[Itemset],
        n_items: usize,
    ) -> Result<Vec<u64>, EngineError> {
        if candidates.is_empty() {
            return Ok(Vec::new());
        }
        let index = Self::build_index(txs, n_items);
        let mut counts = vec![0u64; candidates.len()];
        index.count_into(candidates, &mut counts);
        Ok(counts)
    }

    /// Genuine shared scan: the transaction slice is read **once** (the
    /// index build) and the same index answers every level's group.
    fn count_batch(
        &self,
        txs: &[Transaction],
        groups: &[Vec<Itemset>],
        n_items: usize,
    ) -> Result<Vec<Vec<u64>>, EngineError> {
        let index = Self::build_index(txs, n_items);
        Ok(groups
            .iter()
            .map(|g| {
                let mut counts = vec![0u64; g.len()];
                index.count_into(g, &mut counts);
                counts
            })
            .collect())
    }

    fn name(&self) -> &'static str {
        "vertical"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::TransactionDb;
    use crate::engine::NaiveEngine;

    fn tx(items: &[u32]) -> Transaction {
        Transaction::new(items.iter().copied())
    }

    fn check_against_naive(txs: &[Transaction], cands: &[Itemset], n_items: usize) {
        let naive = NaiveEngine.count(txs, cands, n_items).unwrap();
        let vertical = VerticalEngine.count(txs, cands, n_items).unwrap();
        assert_eq!(vertical, naive);
    }

    #[test]
    fn container_layouts_picked_by_occupancy() {
        // 4 items over 4 full txs: each item row is one consecutive run.
        let dense_txs: Vec<Transaction> = (0..4).map(|_| tx(&[0, 1, 2, 3])).collect();
        let idx = VerticalIndex::build(&FlatBlock::from_transactions(&dense_txs, 4));
        let census = idx.container_census();
        assert_eq!(census.total(), 4);
        assert_eq!(census.runs, 4);
        assert!(idx.bytes() > 0);
        // 1 item occurrence over a 10_000-wide dictionary: one tiny array
        // container; the other 9_999 rows hold no chunks at all.
        let sparse_txs = vec![tx(&[9_999])];
        let idx = VerticalIndex::build(&FlatBlock::from_transactions(&sparse_txs, 10_000));
        let census = idx.container_census();
        assert_eq!((census.arrays, census.bitmaps, census.runs), (1, 0, 0));
        assert_eq!(idx.bytes(), 6); // one 4-byte chunk key + one u16 TID
    }

    #[test]
    fn counts_match_naive_on_both_representations() {
        let db = TransactionDb::new(vec![
            tx(&[0, 1, 2]),
            tx(&[0, 2]),
            tx(&[1]),
            tx(&[]),
            tx(&[0, 1, 2, 3]),
        ]);
        let cands: Vec<Itemset> = vec![
            vec![],
            vec![0],
            vec![3],
            vec![0, 1],
            vec![0, 2],
            vec![1, 3],
            vec![0, 1, 2],
            vec![0, 1, 2, 3],
            vec![7], // beyond the dictionary
        ];
        // dense (narrow dictionary)
        check_against_naive(&db.transactions, &cands, db.n_items);
        // sparse: same data under a very wide dictionary hint
        check_against_naive(&db.transactions, &cands, 50_000);
    }

    #[test]
    fn non_canonical_candidates_count_zero() {
        let txs = vec![tx(&[0, 1, 2])];
        for cands in [vec![vec![1u32, 1]], vec![vec![2u32, 1]]] {
            check_against_naive(&txs, &cands, 3);
            assert_eq!(VerticalEngine.count(&txs, &cands, 3).unwrap(), vec![0]);
        }
    }

    #[test]
    fn word_boundary_transaction_counts() {
        // n_tx straddling the u64 word edge: 63, 64, 65, 128, 129.
        for n_tx in [63usize, 64, 65, 128, 129] {
            let txs: Vec<Transaction> = (0..n_tx)
                .map(|i| tx(&[(i % 3) as u32, 3, (i % 5) as u32 + 4]))
                .collect();
            let cands: Vec<Itemset> =
                vec![vec![3], vec![0, 3], vec![2, 3], vec![0, 3, 4], vec![1, 2]];
            check_against_naive(&txs, &cands, 9);
        }
    }

    #[test]
    fn prefix_reuse_spans_lexicographic_siblings() {
        // Many siblings sharing the prefix [0, 1]; processed unsorted to
        // exercise the internal ordering + scatter-back.
        let txs: Vec<Transaction> = (0..70)
            .map(|i| tx(&[0, 1, 2 + (i % 4) as u32, 6 + (i % 3) as u32]))
            .collect();
        let cands: Vec<Itemset> = vec![
            vec![0, 1, 5],
            vec![0, 1, 2],
            vec![0, 1, 7],
            vec![0, 1, 3],
            vec![0, 2, 3],
            vec![0, 1, 4],
        ];
        check_against_naive(&txs, &cands, 9);
    }

    #[test]
    fn empty_slice_and_empty_candidates() {
        assert!(VerticalEngine.count(&[], &[], 5).unwrap().is_empty());
        let counts = VerticalEngine
            .count(&[], &[vec![0], vec![0, 1]], 5)
            .unwrap();
        assert_eq!(counts, vec![0, 0]);
    }

    #[test]
    fn batch_shares_one_index_and_matches_per_group_counts() {
        let txs: Vec<Transaction> = (0..100)
            .map(|i| tx(&[(i % 7) as u32, (i % 11) as u32, (i % 13) as u32]))
            .collect();
        let groups: Vec<Vec<Itemset>> = vec![
            (0..13u32).map(|i| vec![i]).collect(),
            vec![vec![0, 1], vec![1, 2], vec![3, 5]],
            Vec::new(),
            vec![vec![0, 1, 2]],
        ];
        let batched = VerticalEngine.count_batch(&txs, &groups, 13).unwrap();
        assert_eq!(batched.len(), groups.len());
        for (group, got) in groups.iter().zip(&batched) {
            let want = NaiveEngine.count(&txs, group, 13).unwrap();
            assert_eq!(got, &want);
        }
        assert!(batched[2].is_empty());
    }

    #[test]
    fn long_candidates_cross_the_u32_mask_regime() {
        // k >= 32: supports must stay exact far past any 32-bit subset
        // mask (the regime where horizontal matchers hit edge cases).
        let spine: Vec<u32> = (0..40).collect();
        let mut txs: Vec<Transaction> = (0..5).map(|_| tx(&spine)).collect();
        txs.push(tx(&spine[..33]));
        txs.push(tx(&[1, 2, 3]));
        let cands: Vec<Itemset> = vec![
            spine[..31].to_vec(),
            spine[..32].to_vec(),
            spine[..33].to_vec(),
            spine.clone(),
        ];
        let counts = VerticalEngine.count(&txs, &cands, 40).unwrap();
        assert_eq!(counts, vec![6, 6, 6, 5]);
        check_against_naive(&txs, &cands, 40);
    }
}

//! The vertical TID-bitset counting engine.
//!
//! Every other CPU engine matches candidates *horizontally*: stream each
//! transaction through a matcher structure and increment the candidates
//! it contains. This engine flips the layout (Apriori-TID / Eclat): one
//! pass over the split builds a per-item **TID index** — which
//! transactions contain item *i* — and each candidate's support is then
//! the size of the intersection of its k item rows, with no further
//! touches of the transaction data at all.
//!
//! Two interchangeable index representations, chosen per split by
//! occupancy ([`FlatBlock::density`]):
//!
//! * **dense** — one `Vec<u64>` bitset row per item (`ceil(n_tx/64)`
//!   words); a candidate is answered by word-wise AND + popcount, 64
//!   transactions per instruction;
//! * **sparse** — one sorted TID list per item, intersected by galloping
//!   (exponential-probe) merge; wins when rows would be mostly empty
//!   and the dense matrix mostly zero words.
//!
//! Candidates are processed in (length, lexicographic) order so
//! lexicographic siblings share their (k−1)-prefix: the prefix
//! intersection is computed once into a scratch accumulator and reused
//! for every sibling, leaving one AND+popcount (or one galloping
//! count-intersection) per candidate. [`VerticalEngine::count_batch`] is
//! a genuine shared scan — the index is built **once** and answers every
//! level of a batched multi-level job.

use crate::apriori::Itemset;
use crate::data::columnar::FlatBlock;
use crate::data::{intersect_sorted_count, intersect_sorted_into, ItemId, Transaction};

use super::{EngineError, SupportEngine};

/// Use dense bitset rows once a 64-transaction word carries at least one
/// expected set bit; below that the dense matrix is mostly zero words
/// and sorted TID lists are both smaller and faster to intersect.
const DENSE_MIN_DENSITY: f64 = 1.0 / 64.0;

enum Repr {
    /// `rows[item * words .. (item + 1) * words]` is item's TID bitset.
    Dense { words: usize, rows: Vec<u64> },
    /// `lists[item]` is item's sorted TID list.
    Sparse { lists: Vec<Vec<u32>> },
}

/// A built item→TID index over one transaction slice.
pub struct VerticalIndex {
    repr: Repr,
    n_tx: usize,
    n_items: usize,
}

impl VerticalIndex {
    /// Build the index from a flattened block, picking the dense or
    /// sparse representation by occupancy.
    pub fn build(block: &FlatBlock) -> Self {
        let n_items = block.n_items();
        let n_tx = block.len();
        let repr = if block.density() >= DENSE_MIN_DENSITY {
            let words = n_tx.div_ceil(64);
            let mut rows = vec![0u64; n_items * words];
            for (tid, tx) in block.iter().enumerate() {
                let (word, bit) = (tid / 64, tid % 64);
                for &item in tx {
                    rows[item as usize * words + word] |= 1u64 << bit;
                }
            }
            Repr::Dense { words, rows }
        } else {
            // Pre-size each list from a counting pass so the build never
            // regrows mid-insert.
            let mut lens = vec![0usize; n_items];
            for tx in block.iter() {
                for &item in tx {
                    lens[item as usize] += 1;
                }
            }
            let mut lists: Vec<Vec<u32>> =
                lens.iter().map(|&n| Vec::with_capacity(n)).collect();
            for (tid, tx) in block.iter().enumerate() {
                for &item in tx {
                    lists[item as usize].push(tid as u32);
                }
            }
            Repr::Sparse { lists }
        };
        Self { repr, n_tx, n_items }
    }

    /// Did occupancy pick the bitset representation?
    pub fn is_dense(&self) -> bool {
        matches!(self.repr, Repr::Dense { .. })
    }

    /// Resident index size in bytes — the number the ablation reports as
    /// "peak index bytes" per split.
    pub fn bytes(&self) -> usize {
        match &self.repr {
            Repr::Dense { rows, .. } => std::mem::size_of_val(rows.as_slice()),
            Repr::Sparse { lists } => lists
                .iter()
                .map(|l| std::mem::size_of_val(l.as_slice()))
                .sum(),
        }
    }

    /// Count every candidate into `counts` (aligned with `candidates`).
    /// Candidates are visited in (length, lexicographic) order
    /// internally so prefix reuse kicks in regardless of input order;
    /// results scatter back to the caller's order.
    pub fn count_into(&self, candidates: &[Itemset], counts: &mut [u64]) {
        debug_assert_eq!(candidates.len(), counts.len());
        let mut order: Vec<usize> = (0..candidates.len()).collect();
        order.sort_by(|&a, &b| {
            let (ca, cb) = (&candidates[a], &candidates[b]);
            (ca.len(), ca).cmp(&(cb.len(), cb))
        });
        match &self.repr {
            Repr::Dense { words, rows } => {
                self.count_dense(*words, rows, candidates, &order, counts)
            }
            Repr::Sparse { lists } => self.count_sparse(lists, candidates, &order, counts),
        }
    }

    /// A candidate the index can't match: an item beyond the dictionary
    /// (never occurs → support 0) or a non-canonical itemset. Canonical
    /// itemsets are strictly ascending; the sorted-merge oracle
    /// (`Transaction::contains_all`) matches nothing otherwise, and the
    /// vertical path must agree byte-for-byte.
    fn unmatchable(&self, cand: &[ItemId]) -> bool {
        cand.iter().any(|&i| (i as usize) >= self.n_items)
            || cand.windows(2).any(|w| w[0] >= w[1])
    }

    fn count_dense(
        &self,
        words: usize,
        rows: &[u64],
        candidates: &[Itemset],
        order: &[usize],
        counts: &mut [u64],
    ) {
        let row = |item: ItemId| &rows[item as usize * words..(item as usize + 1) * words];
        // The shared (k−1)-prefix accumulator; valid for `prefix_key`.
        let mut acc: Vec<u64> = vec![0; words];
        let mut prefix_key: Option<&[ItemId]> = None;
        for &ci in order {
            let cand = &candidates[ci];
            counts[ci] = match cand.len() {
                // The empty itemset is contained in every transaction.
                0 => self.n_tx as u64,
                _ if self.unmatchable(cand) => 0,
                1 => row(cand[0]).iter().map(|w| w.count_ones() as u64).sum(),
                k => {
                    let prefix = &cand[..k - 1];
                    if prefix_key != Some(prefix) {
                        acc.copy_from_slice(row(prefix[0]));
                        for &item in &prefix[1..] {
                            for (a, w) in acc.iter_mut().zip(row(item)) {
                                *a &= w;
                            }
                        }
                        prefix_key = Some(prefix);
                    }
                    acc.iter()
                        .zip(row(cand[k - 1]))
                        .map(|(a, w)| (a & w).count_ones() as u64)
                        .sum()
                }
            };
        }
    }

    fn count_sparse(
        &self,
        lists: &[Vec<u32>],
        candidates: &[Itemset],
        order: &[usize],
        counts: &mut [u64],
    ) {
        // Shared prefix accumulator + ping-pong scratch, reused across
        // the whole candidate list (no per-candidate allocation).
        let mut acc: Vec<u32> = Vec::new();
        let mut tmp: Vec<u32> = Vec::new();
        let mut prefix_key: Option<&[ItemId]> = None;
        for &ci in order {
            let cand = &candidates[ci];
            counts[ci] = match cand.len() {
                0 => self.n_tx as u64,
                _ if self.unmatchable(cand) => 0,
                1 => lists[cand[0] as usize].len() as u64,
                k => {
                    let prefix = &cand[..k - 1];
                    if prefix_key != Some(prefix) {
                        acc.clear();
                        acc.extend_from_slice(&lists[prefix[0] as usize]);
                        for &item in &prefix[1..] {
                            intersect_sorted_into(&acc, &lists[item as usize], &mut tmp);
                            std::mem::swap(&mut acc, &mut tmp);
                        }
                        prefix_key = Some(prefix);
                    }
                    intersect_sorted_count(&acc, &lists[cand[k - 1] as usize])
                }
            };
        }
    }
}

/// The vertical engine: build the TID index per call (the one pass over
/// the slice), answer candidates by row intersection. Mixed-length
/// candidate lists are native — no per-length structure is needed — and
/// the batched path shares one index build across every group.
pub struct VerticalEngine;

impl VerticalEngine {
    fn build_index(txs: &[Transaction], n_items: usize) -> VerticalIndex {
        VerticalIndex::build(&FlatBlock::from_transactions(txs, n_items))
    }
}

impl SupportEngine for VerticalEngine {
    fn count(
        &self,
        txs: &[Transaction],
        candidates: &[Itemset],
        n_items: usize,
    ) -> Result<Vec<u64>, EngineError> {
        if candidates.is_empty() {
            return Ok(Vec::new());
        }
        let index = Self::build_index(txs, n_items);
        let mut counts = vec![0u64; candidates.len()];
        index.count_into(candidates, &mut counts);
        Ok(counts)
    }

    /// Genuine shared scan: the transaction slice is read **once** (the
    /// index build) and the same index answers every level's group.
    fn count_batch(
        &self,
        txs: &[Transaction],
        groups: &[Vec<Itemset>],
        n_items: usize,
    ) -> Result<Vec<Vec<u64>>, EngineError> {
        let index = Self::build_index(txs, n_items);
        Ok(groups
            .iter()
            .map(|g| {
                let mut counts = vec![0u64; g.len()];
                index.count_into(g, &mut counts);
                counts
            })
            .collect())
    }

    fn name(&self) -> &'static str {
        "vertical"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::TransactionDb;
    use crate::engine::NaiveEngine;

    fn tx(items: &[u32]) -> Transaction {
        Transaction::new(items.iter().copied())
    }

    fn check_against_naive(txs: &[Transaction], cands: &[Itemset], n_items: usize) {
        let naive = NaiveEngine.count(txs, cands, n_items).unwrap();
        let vertical = VerticalEngine.count(txs, cands, n_items).unwrap();
        assert_eq!(vertical, naive);
    }

    #[test]
    fn dense_and_sparse_picked_by_occupancy() {
        // 4 items over 4 txs, every tx full -> density 1 -> dense
        let dense_txs: Vec<Transaction> = (0..4).map(|_| tx(&[0, 1, 2, 3])).collect();
        let idx = VerticalIndex::build(&FlatBlock::from_transactions(&dense_txs, 4));
        assert!(idx.is_dense());
        assert!(idx.bytes() > 0);
        // 1 item occurrence over a 10_000-wide dictionary -> sparse
        let sparse_txs = vec![tx(&[9_999])];
        let idx = VerticalIndex::build(&FlatBlock::from_transactions(&sparse_txs, 10_000));
        assert!(!idx.is_dense());
        assert_eq!(idx.bytes(), 4);
    }

    #[test]
    fn counts_match_naive_on_both_representations() {
        let db = TransactionDb::new(vec![
            tx(&[0, 1, 2]),
            tx(&[0, 2]),
            tx(&[1]),
            tx(&[]),
            tx(&[0, 1, 2, 3]),
        ]);
        let cands: Vec<Itemset> = vec![
            vec![],
            vec![0],
            vec![3],
            vec![0, 1],
            vec![0, 2],
            vec![1, 3],
            vec![0, 1, 2],
            vec![0, 1, 2, 3],
            vec![7], // beyond the dictionary
        ];
        // dense (narrow dictionary)
        check_against_naive(&db.transactions, &cands, db.n_items);
        // sparse: same data under a very wide dictionary hint
        check_against_naive(&db.transactions, &cands, 50_000);
    }

    #[test]
    fn non_canonical_candidates_count_zero() {
        let txs = vec![tx(&[0, 1, 2])];
        for cands in [vec![vec![1u32, 1]], vec![vec![2u32, 1]]] {
            check_against_naive(&txs, &cands, 3);
            assert_eq!(VerticalEngine.count(&txs, &cands, 3).unwrap(), vec![0]);
        }
    }

    #[test]
    fn word_boundary_transaction_counts() {
        // n_tx straddling the u64 word edge: 63, 64, 65, 128, 129.
        for n_tx in [63usize, 64, 65, 128, 129] {
            let txs: Vec<Transaction> = (0..n_tx)
                .map(|i| tx(&[(i % 3) as u32, 3, (i % 5) as u32 + 4]))
                .collect();
            let cands: Vec<Itemset> =
                vec![vec![3], vec![0, 3], vec![2, 3], vec![0, 3, 4], vec![1, 2]];
            check_against_naive(&txs, &cands, 9);
        }
    }

    #[test]
    fn prefix_reuse_spans_lexicographic_siblings() {
        // Many siblings sharing the prefix [0, 1]; processed unsorted to
        // exercise the internal ordering + scatter-back.
        let txs: Vec<Transaction> = (0..70)
            .map(|i| tx(&[0, 1, 2 + (i % 4) as u32, 6 + (i % 3) as u32]))
            .collect();
        let cands: Vec<Itemset> = vec![
            vec![0, 1, 5],
            vec![0, 1, 2],
            vec![0, 1, 7],
            vec![0, 1, 3],
            vec![0, 2, 3],
            vec![0, 1, 4],
        ];
        check_against_naive(&txs, &cands, 9);
    }

    #[test]
    fn empty_slice_and_empty_candidates() {
        assert!(VerticalEngine.count(&[], &[], 5).unwrap().is_empty());
        let counts = VerticalEngine
            .count(&[], &[vec![0], vec![0, 1]], 5)
            .unwrap();
        assert_eq!(counts, vec![0, 0]);
    }

    #[test]
    fn batch_shares_one_index_and_matches_per_group_counts() {
        let txs: Vec<Transaction> = (0..100)
            .map(|i| tx(&[(i % 7) as u32, (i % 11) as u32, (i % 13) as u32]))
            .collect();
        let groups: Vec<Vec<Itemset>> = vec![
            (0..13u32).map(|i| vec![i]).collect(),
            vec![vec![0, 1], vec![1, 2], vec![3, 5]],
            Vec::new(),
            vec![vec![0, 1, 2]],
        ];
        let batched = VerticalEngine.count_batch(&txs, &groups, 13).unwrap();
        assert_eq!(batched.len(), groups.len());
        for (group, got) in groups.iter().zip(&batched) {
            let want = NaiveEngine.count(&txs, group, 13).unwrap();
            assert_eq!(got, &want);
        }
        assert!(batched[2].is_empty());
    }

    #[test]
    fn long_candidates_cross_the_u32_mask_regime() {
        // k >= 32: supports must stay exact far past any 32-bit subset
        // mask (the regime where horizontal matchers hit edge cases).
        let spine: Vec<u32> = (0..40).collect();
        let mut txs: Vec<Transaction> = (0..5).map(|_| tx(&spine)).collect();
        txs.push(tx(&spine[..33]));
        txs.push(tx(&[1, 2, 3]));
        let cands: Vec<Itemset> = vec![
            spine[..31].to_vec(),
            spine[..32].to_vec(),
            spine[..33].to_vec(),
            spine.clone(),
        ];
        let counts = VerticalEngine.count(&txs, &cands, 40).unwrap();
        assert_eq!(counts, vec![6, 6, 6, 5]);
        check_against_naive(&txs, &cands, 40);
    }
}

//! Pluggable support-count engines — the hot path behind every map task.
//!
//! A [`SupportEngine`] answers one question: given a slice of transactions
//! and a level's candidate itemsets, how many transactions contain each
//! candidate? Interchangeable implementations:
//!
//! * [`HashTreeEngine`] / [`TrieEngine`] — pure-rust horizontal CPU
//!   matchers (per-transaction structure probes);
//! * [`VerticalEngine`] — vertical counting over chunked TID containers
//!   (sorted-array / dense-bitmap / run-length per 2^16-TID chunk, see
//!   [`container`]): candidates answered by row intersection with
//!   shared-prefix reuse, and index builds reused across jobs through
//!   the resident [`IndexCache`] (see [`vertical`] and [`index_cache`]);
//! * [`TensorEngine`] — bitmap-encodes the slice and candidates and runs
//!   the AOT-compiled Pallas kernel through the PJRT runtime (the
//!   three-layer hot path);
//! * [`NaiveEngine`] — the O(|C|·|D|) oracle used in differential tests.
//!
//! All engines are `Send + Sync` so one instance can serve every
//! tasktracker thread (the tensor engine funnels into the PJRT service
//! thread internally).

pub mod container;
pub mod index_cache;
pub mod vertical;

use crate::apriori::hash_tree::HashTree;
use crate::apriori::trie::CandidateTrie;
use crate::apriori::Itemset;
use crate::data::bitmap::{BitmapBlock, CandidateBlock, EncodeError};
use crate::data::Transaction;
use crate::runtime::{CountRequest, TensorServiceHandle};

pub use container::{Container, ContainerCensus, TidSet};
pub use index_cache::{CacheStats, IndexCache};
pub use vertical::{VerticalEngine, VerticalIndex};

/// Engine selector for configs and CLIs.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum EngineKind {
    HashTree,
    Trie,
    /// Vertical TID-bitset counting (word-parallel, shared-prefix
    /// reuse) — the measured-fastest CPU engine and the default
    /// everywhere (`MrApriori::new`, `ExperimentConfig`, here).
    #[default]
    Vertical,
    Naive,
    /// The Pallas/PJRT path (requires built artifacts).
    Tensor,
}

impl std::str::FromStr for EngineKind {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "hash-tree" | "hashtree" => Ok(Self::HashTree),
            "trie" => Ok(Self::Trie),
            "vertical" => Ok(Self::Vertical),
            "naive" => Ok(Self::Naive),
            "tensor" => Ok(Self::Tensor),
            other => Err(format!(
                "unknown engine '{other}' (want hash-tree|trie|vertical|naive|tensor)"
            )),
        }
    }
}

/// The CLI/config name — round-trips through [`EngineKind::from_str`].
impl std::fmt::Display for EngineKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            Self::HashTree => "hash-tree",
            Self::Trie => "trie",
            Self::Vertical => "vertical",
            Self::Naive => "naive",
            Self::Tensor => "tensor",
        })
    }
}

#[derive(Debug)]
pub enum EngineError {
    Tensor(crate::runtime::service::ServiceError),
    /// Bitmap encoding rejected an item outside the encoder width (the
    /// caller failed to project the db to the engine's dictionary).
    Encode(EncodeError),
}

impl std::fmt::Display for EngineError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Self::Tensor(e) => write!(f, "tensor runtime: {e}"),
            Self::Encode(e) => write!(f, "bitmap encode: {e}"),
        }
    }
}

impl std::error::Error for EngineError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Self::Tensor(e) => Some(e),
            Self::Encode(e) => Some(e),
        }
    }
}

impl From<crate::runtime::service::ServiceError> for EngineError {
    fn from(e: crate::runtime::service::ServiceError) -> Self {
        Self::Tensor(e)
    }
}

impl From<EncodeError> for EngineError {
    fn from(e: EncodeError) -> Self {
        Self::Encode(e)
    }
}

/// The counting contract. `n_items` is the (projected) dictionary width —
/// the tensor engine uses it to pick an artifact tile shape.
pub trait SupportEngine: Send + Sync {
    fn count(
        &self,
        txs: &[Transaction],
        candidates: &[Itemset],
        n_items: usize,
    ) -> Result<Vec<u64>, EngineError>;

    /// Count candidates from several adjacent levels in **one logical scan**
    /// of `txs`. `groups[g]` holds one level's candidate list (uniform
    /// length within a group); the result is aligned group-for-group.
    ///
    /// The default delegates to [`SupportEngine::count`] per group — one
    /// pass over the slice per level. Structure-based engines override it
    /// with a genuine shared scan (build one matcher per level, stream each
    /// transaction through all of them), which is what lets a batched
    /// multi-level counting job read each split once instead of once per
    /// level.
    fn count_batch(
        &self,
        txs: &[Transaction],
        groups: &[Vec<Itemset>],
        n_items: usize,
    ) -> Result<Vec<Vec<u64>>, EngineError> {
        groups.iter().map(|g| self.count(txs, g, n_items)).collect()
    }

    fn name(&self) -> &'static str;
}

/// Count a possibly mixed-length candidate list through the engine's
/// batched shared-scan path, returning counts aligned with `candidates`'
/// order. Uniform-length lists (the common single-level job) go straight
/// to [`SupportEngine::count`]; mixed lists (a batched multi-level job)
/// are regrouped by length, counted via [`SupportEngine::count_batch`] in
/// one scan, and scattered back.
pub fn count_mixed(
    engine: &dyn SupportEngine,
    txs: &[Transaction],
    candidates: &[Itemset],
    n_items: usize,
) -> Result<Vec<u64>, EngineError> {
    LevelGroups::build(candidates).count(engine, txs, candidates, n_items)
}

/// A candidate list's per-length grouping, precomputed **once per job** so
/// the map-task hot path ([`count`](Self::count), called once per split)
/// never regroups or clones candidates per split.
#[derive(Debug, Clone)]
pub struct LevelGroups {
    /// One uniform-length candidate list per level, ascending length.
    groups: Vec<Vec<Itemset>>,
    /// `index[g][j]` = position of `groups[g][j]` in the original list.
    index: Vec<Vec<usize>>,
    n_candidates: usize,
}

impl LevelGroups {
    pub fn build(candidates: &[Itemset]) -> Self {
        let by_len = indices_by_len(candidates);
        let groups = by_len
            .values()
            .map(|idxs| idxs.iter().map(|&i| candidates[i].clone()).collect())
            .collect();
        let index = by_len.into_values().collect();
        Self {
            groups,
            index,
            n_candidates: candidates.len(),
        }
    }

    /// Single level (or empty) — the shared-scan batch path is a no-op win.
    pub fn is_uniform(&self) -> bool {
        self.groups.len() <= 1
    }

    /// Count through the engine, scattering counts back into the original
    /// candidate order. `candidates` must be the list this was built from
    /// (used verbatim on the uniform fast path).
    pub fn count(
        &self,
        engine: &dyn SupportEngine,
        txs: &[Transaction],
        candidates: &[Itemset],
        n_items: usize,
    ) -> Result<Vec<u64>, EngineError> {
        debug_assert_eq!(candidates.len(), self.n_candidates);
        if self.is_uniform() {
            return engine.count(txs, candidates, n_items);
        }
        let counted = engine.count_batch(txs, &self.groups, n_items)?;
        let mut counts = vec![0u64; self.n_candidates];
        for (idxs, group_counts) in self.index.iter().zip(counted) {
            for (&i, c) in idxs.iter().zip(group_counts) {
                counts[i] = c;
            }
        }
        Ok(counts)
    }

    /// Count through a prebuilt [`VerticalIndex`] — the resident-cache
    /// path, where the split's index already exists and no transaction
    /// scan happens at all. Scatters back exactly like [`Self::count`].
    pub fn count_with_index(&self, index: &VerticalIndex, candidates: &[Itemset]) -> Vec<u64> {
        debug_assert_eq!(candidates.len(), self.n_candidates);
        let mut counts = vec![0u64; self.n_candidates];
        if self.is_uniform() {
            index.count_into(candidates, &mut counts);
            return counts;
        }
        for (group, idxs) in self.groups.iter().zip(&self.index) {
            let mut group_counts = vec![0u64; group.len()];
            index.count_into(group, &mut group_counts);
            for (&i, c) in idxs.iter().zip(group_counts) {
                counts[i] = c;
            }
        }
        counts
    }
}

/// Candidate indices keyed by itemset length, in ascending-length order —
/// the regrouping step both `count_grouped` and [`count_mixed`] share.
fn indices_by_len(candidates: &[Itemset]) -> std::collections::BTreeMap<usize, Vec<usize>> {
    let mut by_len: std::collections::BTreeMap<usize, Vec<usize>> = Default::default();
    for (i, c) in candidates.iter().enumerate() {
        by_len.entry(c.len()).or_default().push(i);
    }
    by_len
}

/// Group candidate indices by itemset length: the hash tree and trie
/// require a uniform k per structure, but the engine contract accepts
/// mixed-length candidate lists (one structure per length, counts merged
/// back into the caller's order).
fn count_grouped(
    txs: &[Transaction],
    candidates: &[Itemset],
    count_level: impl Fn(&[Itemset]) -> Vec<u64>,
) -> Vec<u64> {
    let by_len = indices_by_len(candidates);
    let mut counts = vec![0u64; candidates.len()];
    for idxs in by_len.values() {
        if idxs.len() == candidates.len() {
            // common case: uniform level, no regrouping copy
            return count_level(candidates);
        }
        let group: Vec<Itemset> = idxs.iter().map(|&i| candidates[i].clone()).collect();
        for (&i, c) in idxs.iter().zip(count_level(&group)) {
            counts[i] = c;
        }
    }
    let _ = txs;
    counts
}

/// Agrawal–Srikant hash tree per call (build cost amortizes over the
/// transaction slice, which is a whole map split).
pub struct HashTreeEngine;

impl SupportEngine for HashTreeEngine {
    fn count(
        &self,
        txs: &[Transaction],
        candidates: &[Itemset],
        _n_items: usize,
    ) -> Result<Vec<u64>, EngineError> {
        Ok(count_grouped(txs, candidates, |group| {
            HashTree::build(group).count_all(txs)
        }))
    }

    /// Shared scan: one hash tree per level, each transaction streamed
    /// through all of them in a single pass over the slice.
    fn count_batch(
        &self,
        txs: &[Transaction],
        groups: &[Vec<Itemset>],
        _n_items: usize,
    ) -> Result<Vec<Vec<u64>>, EngineError> {
        let trees: Vec<HashTree> = groups.iter().map(|g| HashTree::build(g)).collect();
        let mut workspaces: Vec<_> = trees.iter().map(|t| t.workspace()).collect();
        let mut counts: Vec<Vec<u64>> = groups.iter().map(|g| vec![0u64; g.len()]).collect();
        for tx in txs {
            for ((tree, ws), c) in trees.iter().zip(&mut workspaces).zip(&mut counts) {
                tree.count_transaction(tx, c, ws);
            }
        }
        Ok(counts)
    }

    fn name(&self) -> &'static str {
        "hash-tree"
    }
}

/// Prefix-trie matcher.
pub struct TrieEngine;

impl SupportEngine for TrieEngine {
    fn count(
        &self,
        txs: &[Transaction],
        candidates: &[Itemset],
        _n_items: usize,
    ) -> Result<Vec<u64>, EngineError> {
        Ok(count_grouped(txs, candidates, |group| {
            CandidateTrie::build(group).count_all(txs)
        }))
    }

    /// Shared scan: one trie per level, probed together per transaction.
    fn count_batch(
        &self,
        txs: &[Transaction],
        groups: &[Vec<Itemset>],
        _n_items: usize,
    ) -> Result<Vec<Vec<u64>>, EngineError> {
        let tries: Vec<CandidateTrie> = groups.iter().map(|g| CandidateTrie::build(g)).collect();
        let mut counts: Vec<Vec<u64>> = groups.iter().map(|g| vec![0u64; g.len()]).collect();
        for tx in txs {
            for (trie, c) in tries.iter().zip(&mut counts) {
                trie.count_transaction(tx, c);
            }
        }
        Ok(counts)
    }

    fn name(&self) -> &'static str {
        "trie"
    }
}

/// Direct scan oracle.
pub struct NaiveEngine;

impl SupportEngine for NaiveEngine {
    fn count(
        &self,
        txs: &[Transaction],
        candidates: &[Itemset],
        _n_items: usize,
    ) -> Result<Vec<u64>, EngineError> {
        Ok(candidates
            .iter()
            .map(|c| txs.iter().filter(|t| t.contains_all(c)).count() as u64)
            .collect())
    }

    fn name(&self) -> &'static str {
        "naive"
    }
}

/// The three-layer hot path: bitmap-encode, ship to the PJRT service,
/// run the AOT-compiled Pallas kernel.
pub struct TensorEngine {
    handle: TensorServiceHandle,
    /// Row padding granularity (matches the kernel's smallest tile).
    pad_to: usize,
}

impl TensorEngine {
    pub fn new(handle: TensorServiceHandle) -> Self {
        Self { handle, pad_to: 256 }
    }
}

impl SupportEngine for TensorEngine {
    fn count(
        &self,
        txs: &[Transaction],
        candidates: &[Itemset],
        n_items: usize,
    ) -> Result<Vec<u64>, EngineError> {
        if candidates.is_empty() {
            return Ok(Vec::new());
        }
        let block = BitmapBlock::encode(txs, n_items, self.pad_to)?;
        let cands = CandidateBlock::encode(candidates, n_items, 64)?;
        let counts = self.handle.count(CountRequest {
            graph: "count_split".into(),
            block,
            cands,
        })?;
        Ok(counts.into_iter().map(u64::from).collect())
    }

    /// Batched path: the transaction slice is bitmap-encoded **once** and
    /// the encoded block shared across the per-level kernel calls — the
    /// encode is the host-side scan, so this is the tensor engine's
    /// shared-scan analogue.
    fn count_batch(
        &self,
        txs: &[Transaction],
        groups: &[Vec<Itemset>],
        n_items: usize,
    ) -> Result<Vec<Vec<u64>>, EngineError> {
        let mut block = Some(BitmapBlock::encode(txs, n_items, self.pad_to)?);
        let last = groups.iter().rposition(|g| !g.is_empty());
        groups
            .iter()
            .enumerate()
            .map(|(gi, g)| {
                if g.is_empty() {
                    return Ok(Vec::new());
                }
                // The request owns its block; move the encode into the
                // final call and clone only for the earlier ones.
                let block = if Some(gi) == last {
                    block.take().expect("taken only on the last group")
                } else {
                    block.as_ref().expect("not yet taken").clone()
                };
                let cands = CandidateBlock::encode(g, n_items, 64)?;
                let counts = self.handle.count(CountRequest {
                    graph: "count_split".into(),
                    block,
                    cands,
                })?;
                Ok(counts.into_iter().map(u64::from).collect())
            })
            .collect()
    }

    fn name(&self) -> &'static str {
        "tensor"
    }
}

/// Build an engine. The tensor engine needs the PJRT service handle.
pub fn build_engine(
    kind: EngineKind,
    tensor: Option<TensorServiceHandle>,
) -> Box<dyn SupportEngine> {
    match kind {
        EngineKind::HashTree => Box::new(HashTreeEngine),
        EngineKind::Trie => Box::new(TrieEngine),
        EngineKind::Vertical => Box::new(VerticalEngine),
        EngineKind::Naive => Box::new(NaiveEngine),
        EngineKind::Tensor => Box::new(TensorEngine::new(
            tensor.expect("tensor engine requires a TensorServiceHandle"),
        )),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::quest::{QuestGenerator, QuestParams};
    use crate::runtime::{ArtifactManifest, TensorService};
    use crate::util::rng::Xoshiro256;

    fn sample(n_items: usize) -> (Vec<Transaction>, Vec<Itemset>) {
        let db = QuestGenerator::new(QuestParams {
            n_items,
            ..QuestParams::dense(200)
        })
        .generate();
        let mut rng = Xoshiro256::seed_from_u64(5);
        let mut cands: Vec<Itemset> = (0..120)
            .map(|_| {
                let k = rng.range_usize(1, 4);
                let mut v: Vec<u32> = rng
                    .sample_distinct(n_items, k)
                    .into_iter()
                    .map(|x| x as u32)
                    .collect();
                v.sort_unstable();
                v
            })
            .collect();
        cands.sort();
        cands.dedup();
        (db.transactions, cands)
    }

    #[test]
    fn cpu_engines_agree_with_naive() {
        let (txs, cands) = sample(60);
        let naive = NaiveEngine.count(&txs, &cands, 60).unwrap();
        assert_eq!(HashTreeEngine.count(&txs, &cands, 60).unwrap(), naive);
        assert_eq!(TrieEngine.count(&txs, &cands, 60).unwrap(), naive);
        assert_eq!(VerticalEngine.count(&txs, &cands, 60).unwrap(), naive);
    }

    #[test]
    fn tensor_engine_agrees_with_naive() {
        let dir = ArtifactManifest::default_dir();
        if !dir.join("manifest.json").exists() {
            crate::log!(Warn, "skipping tensor engine test: run `make artifacts`");
            return;
        }
        let svc = TensorService::start(ArtifactManifest::load(&dir).unwrap());
        let engine = TensorEngine::new(svc.handle());
        let (txs, cands) = sample(60);
        let naive = NaiveEngine.count(&txs, &cands, 60).unwrap();
        assert_eq!(engine.count(&txs, &cands, 60).unwrap(), naive);
        assert_eq!(engine.name(), "tensor");
    }

    #[test]
    fn tensor_engine_shared_across_threads() {
        let dir = ArtifactManifest::default_dir();
        if !dir.join("manifest.json").exists() {
            crate::log!(Warn, "skipping tensor engine test: run `make artifacts`");
            return;
        }
        let svc = TensorService::start(ArtifactManifest::load(&dir).unwrap());
        let engine = TensorEngine::new(svc.handle());
        let (txs, cands) = sample(40);
        let expected = NaiveEngine.count(&txs, &cands, 40).unwrap();
        std::thread::scope(|s| {
            for _ in 0..4 {
                let (engine, txs, cands, expected) = (&engine, &txs, &cands, &expected);
                s.spawn(move || {
                    assert_eq!(&engine.count(txs, cands, 40).unwrap(), expected);
                });
            }
        });
    }

    #[test]
    fn empty_candidates_ok() {
        let (txs, _) = sample(30);
        for e in [
            EngineKind::HashTree,
            EngineKind::Trie,
            EngineKind::Vertical,
            EngineKind::Naive,
        ] {
            let engine = build_engine(e, None);
            assert!(engine.count(&txs, &[], 30).unwrap().is_empty());
        }
    }

    /// Split a mixed-length candidate list into per-length groups.
    fn level_groups(cands: &[Itemset]) -> Vec<Vec<Itemset>> {
        use std::collections::BTreeMap;
        let mut by_len: BTreeMap<usize, Vec<Itemset>> = BTreeMap::new();
        for c in cands {
            by_len.entry(c.len()).or_default().push(c.clone());
        }
        by_len.into_values().collect()
    }

    #[test]
    fn shared_scan_batch_matches_per_level_counts() {
        let (txs, cands) = sample(60);
        let groups = level_groups(&cands);
        assert!(groups.len() > 1, "sample should span several levels");
        for e in [
            EngineKind::HashTree,
            EngineKind::Trie,
            EngineKind::Vertical,
            EngineKind::Naive,
        ] {
            let engine = build_engine(e, None);
            let batched = engine.count_batch(&txs, &groups, 60).unwrap();
            assert_eq!(batched.len(), groups.len(), "{}", engine.name());
            for (group, got) in groups.iter().zip(&batched) {
                let want = NaiveEngine.count(&txs, group, 60).unwrap();
                assert_eq!(got, &want, "{} level k={}", engine.name(), group[0].len());
            }
        }
    }

    #[test]
    fn count_mixed_preserves_caller_order() {
        let (txs, cands) = sample(50);
        let want = NaiveEngine.count(&txs, &cands, 50).unwrap();
        for e in [
            EngineKind::HashTree,
            EngineKind::Trie,
            EngineKind::Vertical,
            EngineKind::Naive,
        ] {
            let engine = build_engine(e, None);
            let got = count_mixed(engine.as_ref(), &txs, &cands, 50).unwrap();
            assert_eq!(got, want, "{}", engine.name());
        }
        // uniform-length fast path
        let pairs: Vec<Itemset> = cands.iter().filter(|c| c.len() == 2).cloned().collect();
        let got = count_mixed(&TrieEngine, &txs, &pairs, 50).unwrap();
        assert_eq!(got, NaiveEngine.count(&txs, &pairs, 50).unwrap());
    }

    #[test]
    fn batch_with_empty_groups() {
        let (txs, cands) = sample(40);
        let pairs: Vec<Itemset> = cands.iter().filter(|c| c.len() == 2).cloned().collect();
        let groups = vec![pairs.clone(), Vec::new()];
        for e in [
            EngineKind::HashTree,
            EngineKind::Trie,
            EngineKind::Vertical,
            EngineKind::Naive,
        ] {
            let engine = build_engine(e, None);
            let batched = engine.count_batch(&txs, &groups, 40).unwrap();
            assert_eq!(batched[0], NaiveEngine.count(&txs, &pairs, 40).unwrap());
            assert!(batched[1].is_empty());
        }
    }

    #[test]
    fn kind_parses() {
        assert_eq!("hash-tree".parse::<EngineKind>().unwrap(), EngineKind::HashTree);
        assert_eq!("trie".parse::<EngineKind>().unwrap(), EngineKind::Trie);
        assert_eq!("vertical".parse::<EngineKind>().unwrap(), EngineKind::Vertical);
        assert_eq!("naive".parse::<EngineKind>().unwrap(), EngineKind::Naive);
        assert_eq!("tensor".parse::<EngineKind>().unwrap(), EngineKind::Tensor);
        assert!("x".parse::<EngineKind>().is_err());
    }

    #[test]
    fn kind_display_round_trips_through_parse() {
        for e in [
            EngineKind::HashTree,
            EngineKind::Trie,
            EngineKind::Vertical,
            EngineKind::Naive,
            EngineKind::Tensor,
        ] {
            assert_eq!(e.to_string().parse::<EngineKind>().unwrap(), e);
        }
    }
}

//! Pluggable support-count engines — the hot path behind every map task.
//!
//! A [`SupportEngine`] answers one question: given a slice of transactions
//! and a level's candidate itemsets, how many transactions contain each
//! candidate? Three interchangeable implementations:
//!
//! * [`HashTreeEngine`] / [`TrieEngine`] — pure-rust CPU matchers;
//! * [`TensorEngine`] — bitmap-encodes the slice and candidates and runs
//!   the AOT-compiled Pallas kernel through the PJRT runtime (the
//!   three-layer hot path);
//! * [`NaiveEngine`] — the O(|C|·|D|) oracle used in differential tests.
//!
//! All engines are `Send + Sync` so one instance can serve every
//! tasktracker thread (the tensor engine funnels into the PJRT service
//! thread internally).

use crate::apriori::hash_tree::HashTree;
use crate::apriori::trie::CandidateTrie;
use crate::apriori::Itemset;
use crate::data::bitmap::{BitmapBlock, CandidateBlock};
use crate::data::Transaction;
use crate::runtime::{CountRequest, TensorServiceHandle};

/// Engine selector for configs and CLIs.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum EngineKind {
    #[default]
    HashTree,
    Trie,
    Naive,
    /// The Pallas/PJRT path (requires built artifacts).
    Tensor,
}

impl std::str::FromStr for EngineKind {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "hash-tree" | "hashtree" => Ok(Self::HashTree),
            "trie" => Ok(Self::Trie),
            "naive" => Ok(Self::Naive),
            "tensor" => Ok(Self::Tensor),
            other => Err(format!(
                "unknown engine '{other}' (want hash-tree|trie|naive|tensor)"
            )),
        }
    }
}

#[derive(Debug, thiserror::Error)]
pub enum EngineError {
    #[error("tensor runtime: {0}")]
    Tensor(#[from] crate::runtime::service::ServiceError),
}

/// The counting contract. `n_items` is the (projected) dictionary width —
/// the tensor engine uses it to pick an artifact tile shape.
pub trait SupportEngine: Send + Sync {
    fn count(
        &self,
        txs: &[Transaction],
        candidates: &[Itemset],
        n_items: usize,
    ) -> Result<Vec<u64>, EngineError>;

    fn name(&self) -> &'static str;
}

/// Group candidate indices by itemset length: the hash tree and trie
/// require a uniform k per structure, but the engine contract accepts
/// mixed-length candidate lists (one structure per length, counts merged
/// back into the caller's order).
fn count_grouped(
    txs: &[Transaction],
    candidates: &[Itemset],
    count_level: impl Fn(&[Itemset]) -> Vec<u64>,
) -> Vec<u64> {
    use std::collections::BTreeMap;
    let mut by_len: BTreeMap<usize, Vec<usize>> = BTreeMap::new();
    for (i, c) in candidates.iter().enumerate() {
        by_len.entry(c.len()).or_default().push(i);
    }
    let mut counts = vec![0u64; candidates.len()];
    for idxs in by_len.values() {
        if idxs.len() == candidates.len() {
            // common case: uniform level, no regrouping copy
            return count_level(candidates);
        }
        let group: Vec<Itemset> = idxs.iter().map(|&i| candidates[i].clone()).collect();
        for (&i, c) in idxs.iter().zip(count_level(&group)) {
            counts[i] = c;
        }
    }
    let _ = txs;
    counts
}

/// Agrawal–Srikant hash tree per call (build cost amortizes over the
/// transaction slice, which is a whole map split).
pub struct HashTreeEngine;

impl SupportEngine for HashTreeEngine {
    fn count(
        &self,
        txs: &[Transaction],
        candidates: &[Itemset],
        _n_items: usize,
    ) -> Result<Vec<u64>, EngineError> {
        Ok(count_grouped(txs, candidates, |group| {
            HashTree::build(group).count_all(txs)
        }))
    }

    fn name(&self) -> &'static str {
        "hash-tree"
    }
}

/// Prefix-trie matcher.
pub struct TrieEngine;

impl SupportEngine for TrieEngine {
    fn count(
        &self,
        txs: &[Transaction],
        candidates: &[Itemset],
        _n_items: usize,
    ) -> Result<Vec<u64>, EngineError> {
        Ok(count_grouped(txs, candidates, |group| {
            CandidateTrie::build(group).count_all(txs)
        }))
    }

    fn name(&self) -> &'static str {
        "trie"
    }
}

/// Direct scan oracle.
pub struct NaiveEngine;

impl SupportEngine for NaiveEngine {
    fn count(
        &self,
        txs: &[Transaction],
        candidates: &[Itemset],
        _n_items: usize,
    ) -> Result<Vec<u64>, EngineError> {
        Ok(candidates
            .iter()
            .map(|c| txs.iter().filter(|t| t.contains_all(c)).count() as u64)
            .collect())
    }

    fn name(&self) -> &'static str {
        "naive"
    }
}

/// The three-layer hot path: bitmap-encode, ship to the PJRT service,
/// run the AOT-compiled Pallas kernel.
pub struct TensorEngine {
    handle: TensorServiceHandle,
    /// Row padding granularity (matches the kernel's smallest tile).
    pad_to: usize,
}

impl TensorEngine {
    pub fn new(handle: TensorServiceHandle) -> Self {
        Self { handle, pad_to: 256 }
    }
}

impl SupportEngine for TensorEngine {
    fn count(
        &self,
        txs: &[Transaction],
        candidates: &[Itemset],
        n_items: usize,
    ) -> Result<Vec<u64>, EngineError> {
        if candidates.is_empty() {
            return Ok(Vec::new());
        }
        let block = BitmapBlock::encode(txs, n_items, self.pad_to);
        let cands = CandidateBlock::encode(candidates, n_items, 64);
        let counts = self.handle.count(CountRequest {
            graph: "count_split".into(),
            block,
            cands,
        })?;
        Ok(counts.into_iter().map(u64::from).collect())
    }

    fn name(&self) -> &'static str {
        "tensor"
    }
}

/// Build an engine. The tensor engine needs the PJRT service handle.
pub fn build_engine(
    kind: EngineKind,
    tensor: Option<TensorServiceHandle>,
) -> Box<dyn SupportEngine> {
    match kind {
        EngineKind::HashTree => Box::new(HashTreeEngine),
        EngineKind::Trie => Box::new(TrieEngine),
        EngineKind::Naive => Box::new(NaiveEngine),
        EngineKind::Tensor => Box::new(TensorEngine::new(
            tensor.expect("tensor engine requires a TensorServiceHandle"),
        )),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::quest::{QuestGenerator, QuestParams};
    use crate::runtime::{ArtifactManifest, TensorService};
    use crate::util::rng::Xoshiro256;

    fn sample(n_items: usize) -> (Vec<Transaction>, Vec<Itemset>) {
        let db = QuestGenerator::new(QuestParams {
            n_items,
            ..QuestParams::dense(200)
        })
        .generate();
        let mut rng = Xoshiro256::seed_from_u64(5);
        let mut cands: Vec<Itemset> = (0..120)
            .map(|_| {
                let k = rng.range_usize(1, 4);
                let mut v: Vec<u32> = rng
                    .sample_distinct(n_items, k)
                    .into_iter()
                    .map(|x| x as u32)
                    .collect();
                v.sort_unstable();
                v
            })
            .collect();
        cands.sort();
        cands.dedup();
        (db.transactions, cands)
    }

    #[test]
    fn cpu_engines_agree_with_naive() {
        let (txs, cands) = sample(60);
        let naive = NaiveEngine.count(&txs, &cands, 60).unwrap();
        assert_eq!(HashTreeEngine.count(&txs, &cands, 60).unwrap(), naive);
        assert_eq!(TrieEngine.count(&txs, &cands, 60).unwrap(), naive);
    }

    #[test]
    fn tensor_engine_agrees_with_naive() {
        let dir = ArtifactManifest::default_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping tensor engine test: run `make artifacts`");
            return;
        }
        let svc = TensorService::start(ArtifactManifest::load(&dir).unwrap());
        let engine = TensorEngine::new(svc.handle());
        let (txs, cands) = sample(60);
        let naive = NaiveEngine.count(&txs, &cands, 60).unwrap();
        assert_eq!(engine.count(&txs, &cands, 60).unwrap(), naive);
        assert_eq!(engine.name(), "tensor");
    }

    #[test]
    fn tensor_engine_shared_across_threads() {
        let dir = ArtifactManifest::default_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping tensor engine test: run `make artifacts`");
            return;
        }
        let svc = TensorService::start(ArtifactManifest::load(&dir).unwrap());
        let engine = TensorEngine::new(svc.handle());
        let (txs, cands) = sample(40);
        let expected = NaiveEngine.count(&txs, &cands, 40).unwrap();
        std::thread::scope(|s| {
            for _ in 0..4 {
                let (engine, txs, cands, expected) = (&engine, &txs, &cands, &expected);
                s.spawn(move || {
                    assert_eq!(&engine.count(txs, cands, 40).unwrap(), expected);
                });
            }
        });
    }

    #[test]
    fn empty_candidates_ok() {
        let (txs, _) = sample(30);
        for e in [EngineKind::HashTree, EngineKind::Trie, EngineKind::Naive] {
            let engine = build_engine(e, None);
            assert!(engine.count(&txs, &[], 30).unwrap().is_empty());
        }
    }

    #[test]
    fn kind_parses() {
        assert_eq!("hash-tree".parse::<EngineKind>().unwrap(), EngineKind::HashTree);
        assert_eq!("trie".parse::<EngineKind>().unwrap(), EngineKind::Trie);
        assert_eq!("naive".parse::<EngineKind>().unwrap(), EngineKind::Naive);
        assert_eq!("tensor".parse::<EngineKind>().unwrap(), EngineKind::Tensor);
        assert!("x".parse::<EngineKind>().is_err());
    }
}

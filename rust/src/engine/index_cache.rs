//! Split-keyed, generation-invalidated resident cache for vertical
//! indexes.
//!
//! Within one dataset generation the blocks behind a split never change,
//! so every job that scans the split — a synchronous level job, a
//! `DeltaCountApp` Δ-scan, an `ExactCounter` frontier recount, or a
//! speculative twin of any of them — can reuse one [`VerticalIndex`]
//! build instead of re-inverting the block per job. The coordinator
//! bumps the generation whenever the dataset view changes (a fresh mine,
//! a delta database, an ad-hoc recount plan), which atomically drops
//! every entry of the previous view: a stale generation is never served.
//!
//! Concurrency: lookups and inserts take a mutex; index *builds* happen
//! outside it, so parallel map tasks for different splits build in
//! parallel. Two speculative twins of the same task may both build —
//! the copies are identical by construction, the last insert wins, and
//! both twins proceed with their own `Arc`.
//!
//! The resident bytes are charged to the simulated datanode by the
//! coordinator (like `dfs::BlockStore` checkpoint blocks), so cache
//! pressure shows up in spill accounting rather than being free.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::metrics::Counter;
use crate::obs::{MetricsRegistry, RegistryError};

use super::VerticalIndex;

/// Observable cache state; hit/miss totals are cumulative since the
/// cache was created (the serve log prints per-cycle deltas).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub entries: usize,
    pub resident_bytes: usize,
    pub generation: u64,
}

#[derive(Default)]
struct Inner {
    generation: u64,
    entries: HashMap<usize, Arc<VerticalIndex>>,
}

/// The resident index cache. One per [`crate::coordinator::MrApriori`].
/// The hit/miss counters live behind `Arc` so the same instruments can
/// be registered with a [`MetricsRegistry`] — the cache keeps its
/// wait-free increments, the registry snapshots the shared atomics.
#[derive(Default)]
pub struct IndexCache {
    inner: Mutex<Inner>,
    hits: Arc<Counter>,
    misses: Arc<Counter>,
}

impl IndexCache {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register the cache's counters under `<prefix>.hits` /
    /// `<prefix>.misses` (conventionally `engine.cache`).
    pub fn register_metrics(
        &self,
        registry: &MetricsRegistry,
        prefix: &str,
    ) -> Result<(), RegistryError> {
        registry.register_counter(&format!("{prefix}.hits"), Arc::clone(&self.hits))?;
        registry.register_counter(&format!("{prefix}.misses"), Arc::clone(&self.misses))
    }

    /// Open a new generation: every entry of the previous one is dropped
    /// and the returned id must accompany subsequent lookups. Call this
    /// once per dataset view (mine plan, delta database, recount plan).
    pub fn begin_generation(&self) -> u64 {
        let mut inner = self.inner.lock().unwrap();
        inner.generation += 1;
        inner.entries.clear();
        inner.generation
    }

    /// The split's index for `generation`, building it via `build` on a
    /// miss. A `generation` older than the current one is never served
    /// from (and never stored into) the cache — the caller gets a fresh
    /// uncached build, which keeps a straggling task of a superseded job
    /// correct without letting it poison the current view.
    pub fn get_or_build<F>(&self, split_id: usize, generation: u64, build: F) -> Arc<VerticalIndex>
    where
        F: FnOnce() -> VerticalIndex,
    {
        {
            let inner = self.inner.lock().unwrap();
            if inner.generation == generation {
                if let Some(index) = inner.entries.get(&split_id) {
                    self.hits.inc();
                    return Arc::clone(index);
                }
            }
        }
        self.misses.inc();
        // Build outside the lock: different splits build concurrently.
        let built = Arc::new(build());
        let mut inner = self.inner.lock().unwrap();
        if inner.generation == generation {
            inner.entries.insert(split_id, Arc::clone(&built));
        }
        built
    }

    /// Bytes of index payload currently resident (current generation).
    pub fn resident_bytes(&self) -> usize {
        let inner = self.inner.lock().unwrap();
        inner.entries.values().map(|i| i.bytes()).sum()
    }

    pub fn stats(&self) -> CacheStats {
        let inner = self.inner.lock().unwrap();
        CacheStats {
            hits: self.hits.get(),
            misses: self.misses.get(),
            entries: inner.entries.len(),
            resident_bytes: inner.entries.values().map(|i| i.bytes()).sum(),
            generation: inner.generation,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::columnar::FlatBlock;
    use crate::data::Transaction;

    fn index(rows: &[Vec<u32>]) -> VerticalIndex {
        let txs: Vec<Transaction> = rows
            .iter()
            .map(|it| Transaction::new(it.iter().copied()))
            .collect();
        VerticalIndex::build(&FlatBlock::from_transactions(&txs, 4))
    }

    #[test]
    fn hit_serves_the_cached_build() {
        let cache = IndexCache::new();
        let generation = cache.begin_generation();
        let first = cache.get_or_build(7, generation, || index(&[vec![0, 1]]));
        let again = cache.get_or_build(7, generation, || panic!("must not rebuild"));
        assert!(Arc::ptr_eq(&first, &again));
        let stats = cache.stats();
        assert_eq!((stats.hits, stats.misses, stats.entries), (1, 1, 1));
        assert!(stats.resident_bytes > 0);
        assert_eq!(stats.resident_bytes, cache.resident_bytes());
    }

    #[test]
    fn begin_generation_drops_every_entry() {
        let cache = IndexCache::new();
        let gen1 = cache.begin_generation();
        cache.get_or_build(0, gen1, || index(&[vec![0]]));
        cache.get_or_build(1, gen1, || index(&[vec![1]]));
        assert_eq!(cache.stats().entries, 2);
        let gen2 = cache.begin_generation();
        assert_eq!(cache.stats().entries, 0);
        // The new generation rebuilds from scratch.
        cache.get_or_build(0, gen2, || index(&[vec![0, 1]]));
        let stats = cache.stats();
        assert_eq!((stats.hits, stats.misses, stats.entries), (0, 3, 1));
    }

    #[test]
    fn stale_generation_is_never_served_or_stored() {
        let cache = IndexCache::new();
        let gen1 = cache.begin_generation();
        cache.get_or_build(3, gen1, || index(&[vec![0]]));
        let gen2 = cache.begin_generation();
        // A straggler still holding gen1 must get a fresh build...
        let mut built = false;
        cache.get_or_build(3, gen1, || {
            built = true;
            index(&[vec![1]])
        });
        assert!(built);
        // ...and must not have populated gen2's table.
        let mut built2 = false;
        cache.get_or_build(3, gen2, || {
            built2 = true;
            index(&[vec![2]])
        });
        assert!(built2);
    }
}

//! HDFS-like distributed block store (namenode + datanodes), simulated.
//!
//! The unit of storage is one input split (`data::split::Split`) — exactly
//! how Hadoop's FileInputFormat aligns map splits with HDFS blocks. The
//! namenode places `replication` replicas per block on distinct datanodes
//! using Hadoop's default policy shape (spread across nodes, fill the
//! least-used first), tracks per-node usage against capacity, and exposes
//! the locality lookups the jobtracker uses for data-local scheduling.
//!
//! **Storage over-commit** is deliberately allowed: the paper's fig-5 knee
//! at ~12 000 transactions comes from exhausting the 80 GB/node disks, at
//! which point Hadoop spills and every access pays extra I/O. Blocks placed
//! beyond a node's capacity are flagged `spilled`; the cost model charges
//! them a configurable read-amplification penalty.

use std::collections::HashMap;

use crate::cluster::{ClusterConfig, NodeId};
use crate::data::split::Split;

/// Identifier of one stored block.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct BlockId(pub u64);

/// `split_id` marker for blocks that back no input split (e.g. the
/// durable snapshot store's checkpoint blocks).
pub const NO_SPLIT: usize = usize::MAX;

/// The thin byte-level storage interface the durable snapshot store
/// (`store::SnapshotStore`) charges its writes through, so checkpoint
/// bytes count against simulated datanode capacity exactly like input
/// splits do (and show up in `spill_fraction` once disks over-commit).
pub trait BlockStore {
    /// Account `bytes` as one replicated block; returns its id.
    fn put_bytes(&mut self, bytes: u64) -> Result<BlockId, DfsError>;
    /// Release a block's replicas (the namenode delete) — the snapshot
    /// store credits pruned generations through this, so long-running
    /// serves don't accumulate phantom usage.
    fn remove_block(&mut self, id: BlockId) -> Result<(), DfsError>;
    /// Cluster-wide storage utilization in `[0, ∞)`: used / capacity.
    fn utilization(&self) -> f64;
}

/// Namenode metadata for one block.
#[derive(Debug, Clone)]
pub struct BlockMeta {
    pub id: BlockId,
    pub bytes: u64,
    /// The split this block backs (1:1 in our FileInputFormat model).
    pub split_id: usize,
    /// Replica holders, primary first.
    pub replicas: Vec<NodeId>,
    /// True if any replica landed past its node's capacity.
    pub spilled: bool,
}

/// One simulated datanode's storage accounting.
#[derive(Debug, Clone)]
pub struct DatanodeState {
    pub node: NodeId,
    pub capacity: u64,
    pub used: u64,
    pub blocks: Vec<BlockId>,
    /// True once the node is decommissioned (no new placements; replicas
    /// already here are re-replicated elsewhere).
    pub decommissioned: bool,
}

impl DatanodeState {
    pub fn over_capacity(&self) -> bool {
        self.used > self.capacity
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DfsError {
    UnknownBlock(BlockId),
    NotEnoughNodes { want: usize, have: usize },
    AlreadyDecommissioned(NodeId),
}

impl std::fmt::Display for DfsError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Self::UnknownBlock(id) => write!(f, "unknown block {id:?}"),
            Self::NotEnoughNodes { want, have } => {
                write!(f, "replication {want} exceeds live datanodes {have}")
            }
            Self::AlreadyDecommissioned(node) => {
                write!(f, "node {node} already decommissioned")
            }
        }
    }
}

impl std::error::Error for DfsError {}

/// The whole filesystem: namenode state + datanode accounting.
#[derive(Debug, Clone)]
pub struct Dfs {
    pub replication: usize,
    blocks: HashMap<BlockId, BlockMeta>,
    nodes: Vec<DatanodeState>,
    /// Rack id per node (from the cluster config).
    rack_of: Vec<usize>,
    next_id: u64,
    /// Insertion-ordered ids (for deterministic iteration in reports).
    order: Vec<BlockId>,
}

impl Dfs {
    /// Stand up a DFS over a cluster's nodes.
    pub fn new(cluster: &ClusterConfig) -> Self {
        let nodes = cluster
            .nodes
            .iter()
            .enumerate()
            .map(|(i, p)| DatanodeState {
                node: i,
                capacity: p.storage_bytes,
                used: 0,
                blocks: Vec::new(),
                decommissioned: false,
            })
            .collect();
        Self {
            replication: cluster.replication,
            blocks: HashMap::new(),
            nodes,
            rack_of: cluster.rack_of.clone(),
            next_id: 0,
            order: Vec::new(),
        }
    }

    fn live_nodes(&self) -> Vec<NodeId> {
        self.nodes
            .iter()
            .filter(|n| !n.decommissioned)
            .map(|n| n.node)
            .collect()
    }

    /// Place one block with Hadoop's rack-aware policy: first replica on
    /// the least-used node, second on a *different rack* (fault domain),
    /// third back on the second replica's rack, remaining replicas by
    /// least usage. Single-rack clusters (the paper's testbed) degrade to
    /// plain least-used placement. Deterministic tie-break on node id.
    pub fn put_block(&mut self, split: &Split) -> Result<BlockId, DfsError> {
        self.place_block(split.bytes as u64, split.id)
    }

    /// Placement core shared by [`put_block`] (input splits) and the
    /// [`BlockStore`] byte interface (splitless checkpoint blocks).
    ///
    /// [`put_block`]: Self::put_block
    fn place_block(&mut self, bytes: u64, split_id: usize) -> Result<BlockId, DfsError> {
        let live = self.live_nodes();
        if live.len() < self.replication {
            return Err(DfsError::NotEnoughNodes {
                want: self.replication,
                have: live.len(),
            });
        }
        let mut by_usage: Vec<NodeId> = live;
        by_usage.sort_by_key(|&n| (self.nodes[n].used, n));
        let mut chosen: Vec<NodeId> = Vec::with_capacity(self.replication);
        // replica 1: least-used anywhere
        chosen.push(by_usage[0]);
        // replica 2: least-used on a different rack, if one exists
        if self.replication >= 2 {
            let r1_rack = self.rack_of[chosen[0]];
            let off_rack = by_usage
                .iter()
                .copied()
                .find(|&n| !chosen.contains(&n) && self.rack_of[n] != r1_rack);
            let pick = off_rack
                .or_else(|| by_usage.iter().copied().find(|n| !chosen.contains(n)));
            chosen.push(pick.expect("enough live nodes"));
        }
        // replica 3: same rack as replica 2, different node (uplink saving)
        if self.replication >= 3 {
            let r2_rack = self.rack_of[chosen[1]];
            let same_rack = by_usage
                .iter()
                .copied()
                .find(|&n| !chosen.contains(&n) && self.rack_of[n] == r2_rack);
            let pick = same_rack
                .or_else(|| by_usage.iter().copied().find(|n| !chosen.contains(n)));
            chosen.push(pick.expect("enough live nodes"));
        }
        // remaining replicas: least-used distinct
        while chosen.len() < self.replication {
            let pick = by_usage
                .iter()
                .copied()
                .find(|n| !chosen.contains(n))
                .expect("enough live nodes");
            chosen.push(pick);
        }

        let id = BlockId(self.next_id);
        self.next_id += 1;
        let mut spilled = false;
        for &n in &chosen {
            let dn = &mut self.nodes[n];
            dn.used += bytes;
            dn.blocks.push(id);
            spilled |= dn.over_capacity();
        }
        let meta = BlockMeta {
            id,
            bytes,
            split_id,
            replicas: chosen,
            spilled,
        };
        self.blocks.insert(id, meta);
        self.order.push(id);
        Ok(id)
    }

    /// Write a whole split plan; returns block ids aligned with the splits.
    pub fn write_splits(&mut self, splits: &[Split]) -> Result<Vec<BlockId>, DfsError> {
        splits.iter().map(|s| self.put_block(s)).collect()
    }

    pub fn meta(&self, id: BlockId) -> Result<&BlockMeta, DfsError> {
        self.blocks.get(&id).ok_or(DfsError::UnknownBlock(id))
    }

    /// Replica locations of a block (primary first).
    pub fn locations(&self, id: BlockId) -> Result<&[NodeId], DfsError> {
        self.meta(id).map(|m| m.replicas.as_slice())
    }

    pub fn is_local(&self, id: BlockId, node: NodeId) -> bool {
        self.blocks
            .get(&id)
            .map(|m| m.replicas.contains(&node))
            .unwrap_or(false)
    }

    pub fn datanode(&self, node: NodeId) -> &DatanodeState {
        &self.nodes[node]
    }

    pub fn blocks_in_order(&self) -> impl Iterator<Item = &BlockMeta> {
        self.order.iter().map(|id| &self.blocks[id])
    }

    pub fn n_blocks(&self) -> usize {
        self.order.len()
    }

    /// Fraction of blocks with at least one spilled replica — the signal
    /// the fig-5 cost model converts into a read-amplification penalty.
    pub fn spill_fraction(&self) -> f64 {
        if self.order.is_empty() {
            return 0.0;
        }
        let spilled = self.blocks.values().filter(|b| b.spilled).count();
        spilled as f64 / self.blocks.len() as f64
    }

    /// Cluster-wide storage utilization in [0, ∞): used / capacity.
    pub fn utilization(&self) -> f64 {
        let used: u64 = self.nodes.iter().map(|n| n.used).sum();
        let cap: u64 = self.nodes.iter().map(|n| n.capacity).sum();
        if cap == 0 {
            return 0.0;
        }
        used as f64 / cap as f64
    }

    /// Account splitless bytes (checkpoint blocks) as one replicated
    /// block — the [`BlockStore`] entry point, also directly usable.
    pub fn put_bytes(&mut self, bytes: u64) -> Result<BlockId, DfsError> {
        self.place_block(bytes, NO_SPLIT)
    }

    /// Delete a block: free its bytes on every replica holder and drop
    /// the namenode metadata. Spill flags on *other* blocks are
    /// placement-time history and stay as recorded.
    pub fn remove_block(&mut self, id: BlockId) -> Result<(), DfsError> {
        let meta = self.blocks.remove(&id).ok_or(DfsError::UnknownBlock(id))?;
        for &n in &meta.replicas {
            let dn = &mut self.nodes[n];
            dn.used = dn.used.saturating_sub(meta.bytes);
            dn.blocks.retain(|&b| b != id);
        }
        self.order.retain(|&b| b != id);
        Ok(())
    }

    /// Decommission a node: mark it dead and re-replicate every block it
    /// held onto other live nodes (namenode behaviour on datanode loss).
    /// Returns the number of re-replicated block replicas.
    pub fn decommission(&mut self, node: NodeId) -> Result<usize, DfsError> {
        if self.nodes[node].decommissioned {
            return Err(DfsError::AlreadyDecommissioned(node));
        }
        self.nodes[node].decommissioned = true;
        let lost: Vec<BlockId> = self.nodes[node].blocks.clone();
        let mut moved = 0;
        for id in lost {
            let meta = self.blocks.get_mut(&id).unwrap();
            meta.replicas.retain(|&r| r != node);
            let bytes = meta.bytes;
            let have: Vec<NodeId> = meta.replicas.clone();
            // pick the least-used live node not already holding a replica
            let mut candidates: Vec<NodeId> = self
                .nodes
                .iter()
                .filter(|n| !n.decommissioned && !have.contains(&n.node))
                .map(|n| n.node)
                .collect();
            candidates.sort_by_key(|&n| (self.nodes[n].used, n));
            if let Some(&target) = candidates.first() {
                self.blocks.get_mut(&id).unwrap().replicas.push(target);
                let dn = &mut self.nodes[target];
                dn.used += bytes;
                dn.blocks.push(id);
                moved += 1;
            }
            // else: under-replicated, but readable from remaining replicas.
        }
        self.nodes[node].used = 0;
        self.nodes[node].blocks.clear();
        Ok(moved)
    }

    /// Number of datanodes still alive (not decommissioned).
    pub fn n_live(&self) -> usize {
        self.nodes.iter().filter(|n| !n.decommissioned).count()
    }

    pub fn is_decommissioned(&self, node: NodeId) -> bool {
        self.nodes[node].decommissioned
    }

    /// Heartbeat reaping: decommission every newly-dead node (idempotent
    /// — nodes already processed are skipped) and clamp the replication
    /// target to the surviving population so later placements keep
    /// succeeding instead of erroring `NotEnoughNodes`. Returns the
    /// number of re-replicated block replicas.
    pub fn reap_dead_nodes(&mut self, dead: &[NodeId]) -> usize {
        let mut moved = 0;
        for &node in dead {
            if node < self.nodes.len() && !self.nodes[node].decommissioned {
                moved += self.decommission(node).unwrap_or(0);
            }
        }
        self.replication = self.replication.min(self.n_live()).max(1);
        moved
    }
}

impl BlockStore for Dfs {
    fn put_bytes(&mut self, bytes: u64) -> Result<BlockId, DfsError> {
        Dfs::put_bytes(self, bytes)
    }

    fn remove_block(&mut self, id: BlockId) -> Result<(), DfsError> {
        Dfs::remove_block(self, id)
    }

    fn utilization(&self) -> f64 {
        Dfs::utilization(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::quest::{QuestGenerator, QuestParams};
    use crate::data::split::plan_splits;

    fn setup(n_nodes: usize, n_tx: usize, split_tx: usize) -> (Dfs, Vec<Split>) {
        let db = QuestGenerator::new(QuestParams::t10_i4(n_tx)).generate();
        let splits = plan_splits(&db, split_tx);
        let dfs = Dfs::new(&ClusterConfig::fhssc(n_nodes));
        (dfs, splits)
    }

    #[test]
    fn replicas_distinct_and_replicated() {
        let (mut dfs, splits) = setup(4, 1000, 100);
        let ids = dfs.write_splits(&splits).unwrap();
        assert_eq!(ids.len(), splits.len());
        for id in &ids {
            let locs = dfs.locations(*id).unwrap();
            assert_eq!(locs.len(), 3); // fhssc(4) -> replication 3
            let mut uniq = locs.to_vec();
            uniq.sort_unstable();
            uniq.dedup();
            assert_eq!(uniq.len(), 3, "replicas must be on distinct nodes");
        }
    }

    #[test]
    fn placement_balances_usage() {
        let (mut dfs, splits) = setup(4, 2000, 50);
        dfs.write_splits(&splits).unwrap();
        let used: Vec<u64> = (0..4).map(|n| dfs.datanode(n).used).collect();
        let max = *used.iter().max().unwrap() as f64;
        let min = *used.iter().min().unwrap() as f64;
        assert!(max / min.max(1.0) < 1.5, "usage skew too high: {used:?}");
    }

    #[test]
    fn locality_lookup() {
        let (mut dfs, splits) = setup(3, 300, 100);
        let ids = dfs.write_splits(&splits).unwrap();
        let id = ids[0];
        let locs = dfs.locations(id).unwrap().to_vec();
        for n in 0..3 {
            assert_eq!(dfs.is_local(id, n), locs.contains(&n));
        }
        assert!(matches!(
            dfs.locations(BlockId(999)),
            Err(DfsError::UnknownBlock(_))
        ));
    }

    #[test]
    fn spill_appears_past_capacity() {
        let db = QuestGenerator::new(QuestParams::t10_i4(2000)).generate();
        let splits = plan_splits(&db, 100);
        let total_bytes: usize = splits.iter().map(|s| s.bytes).sum();
        // Capacity sized so ~half the replicated volume fits.
        let cap = (total_bytes as u64 * 3) / (2 * 3);
        let cluster = ClusterConfig::fhssc(3).with_storage_per_node(cap / 3 * 2);
        let mut dfs = Dfs::new(&cluster);
        dfs.write_splits(&splits).unwrap();
        assert!(dfs.spill_fraction() > 0.0, "expected spill");
        assert!(dfs.utilization() > 1.0);
        // And with plentiful storage there is no spill.
        let mut roomy = Dfs::new(&ClusterConfig::fhssc(3));
        roomy.write_splits(&splits).unwrap();
        assert_eq!(roomy.spill_fraction(), 0.0);
    }

    #[test]
    fn replication_exceeding_nodes_errors() {
        let (mut dfs, splits) = setup(3, 100, 50);
        dfs.replication = 4;
        assert!(matches!(
            dfs.put_block(&splits[0]),
            Err(DfsError::NotEnoughNodes { want: 4, have: 3 })
        ));
    }

    #[test]
    fn decommission_rereplicates() {
        let (mut dfs, splits) = setup(4, 500, 50);
        let ids = dfs.write_splits(&splits).unwrap();
        let victim = 1;
        let held = dfs.datanode(victim).blocks.len();
        assert!(held > 0);
        let moved = dfs.decommission(victim).unwrap();
        assert_eq!(moved, held, "every lost replica re-replicated");
        for id in &ids {
            let locs = dfs.locations(*id).unwrap();
            assert_eq!(locs.len(), 3, "replication restored");
            assert!(!locs.contains(&victim));
        }
        assert!(matches!(
            dfs.decommission(victim),
            Err(DfsError::AlreadyDecommissioned(1))
        ));
    }

    #[test]
    fn decommission_without_spare_leaves_underreplicated() {
        let (mut dfs, splits) = setup(3, 300, 100);
        let ids = dfs.write_splits(&splits).unwrap();
        dfs.decommission(0).unwrap();
        for id in &ids {
            let locs = dfs.locations(*id).unwrap();
            assert_eq!(locs.len(), 2, "no spare node: under-replicated");
        }
    }

    #[test]
    fn reap_is_idempotent_and_clamps_replication() {
        let (mut dfs, splits) = setup(3, 300, 100);
        let ids = dfs.write_splits(&splits).unwrap();
        assert_eq!(dfs.n_live(), 3);
        dfs.reap_dead_nodes(&[1]);
        assert!(dfs.is_decommissioned(1));
        assert_eq!(dfs.n_live(), 2);
        assert_eq!(dfs.replication, 2, "clamped to survivors");
        // same dead list again: no error, no change
        dfs.reap_dead_nodes(&[1]);
        assert_eq!(dfs.n_live(), 2);
        // new placements succeed at the clamped factor
        let id = dfs.put_bytes(100).unwrap();
        assert_eq!(dfs.locations(id).unwrap().len(), 2);
        for id in &ids {
            assert!(!dfs.locations(*id).unwrap().contains(&1));
        }
    }

    #[test]
    fn rack_aware_placement_spans_racks() {
        // 6 nodes, 2 racks: replicas 1+2 on different racks, replica 3 on
        // replica 2's rack (Hadoop's default policy).
        let db = QuestGenerator::new(QuestParams::t10_i4(600)).generate();
        let splits = plan_splits(&db, 50);
        let cluster = ClusterConfig::fhssc(6).with_racks(2);
        let mut dfs = Dfs::new(&cluster);
        let ids = dfs.write_splits(&splits).unwrap();
        for id in ids {
            let locs = dfs.locations(id).unwrap();
            assert_eq!(locs.len(), 3);
            let racks: Vec<usize> = locs.iter().map(|&n| cluster.rack_of[n]).collect();
            assert_ne!(racks[0], racks[1], "replicas 1+2 must span racks: {racks:?}");
            assert_eq!(racks[1], racks[2], "replica 3 shares replica 2's rack: {racks:?}");
        }
    }

    #[test]
    fn single_rack_placement_unchanged() {
        // The paper's single-switch testbed: rack policy degrades to plain
        // least-used placement and stays balanced.
        let (mut dfs, splits) = setup(4, 1000, 100);
        dfs.write_splits(&splits).unwrap();
        let used: Vec<u64> = (0..4).map(|n| dfs.datanode(n).used).collect();
        let max = *used.iter().max().unwrap() as f64;
        let min = *used.iter().min().unwrap() as f64;
        assert!(max / min.max(1.0) < 1.5, "balance kept: {used:?}");
    }

    #[test]
    fn splitless_bytes_account_like_blocks_and_spill_past_capacity() {
        let cluster = ClusterConfig::fhssc(3).with_storage_per_node(1000);
        let mut dfs = Dfs::new(&cluster);
        let id = dfs.put_bytes(600).unwrap();
        let meta = dfs.meta(id).unwrap();
        assert_eq!(meta.split_id, NO_SPLIT);
        assert_eq!(meta.bytes, 600);
        assert_eq!(meta.replicas.len(), 3);
        assert!(!meta.spilled);
        assert!(dfs.utilization() > 0.0);
        // a second checkpoint block overflows the 1000-byte nodes
        let id2 = dfs.put_bytes(600).unwrap();
        assert!(dfs.meta(id2).unwrap().spilled);
        assert!(dfs.spill_fraction() > 0.0);
        // the trait object view agrees with the inherent methods
        let bs: &mut dyn BlockStore = &mut dfs;
        let id3 = bs.put_bytes(10).unwrap();
        assert!(bs.utilization() > 1.0);
        assert!(id3 > id2);
        // removal credits every replica holder and forgets the block
        bs.remove_block(id2).unwrap();
        assert!(matches!(bs.remove_block(id2), Err(DfsError::UnknownBlock(_))));
        assert!(dfs.utilization() < 1.0);
        assert_eq!(dfs.n_blocks(), 2);
        assert!(!dfs.is_local(id2, 0));
        dfs.remove_block(id).unwrap();
        dfs.remove_block(id3).unwrap();
        assert_eq!(dfs.utilization(), 0.0);
    }

    #[test]
    fn deterministic_block_order() {
        let (mut a, splits) = setup(3, 500, 50);
        let (mut b, _) = setup(3, 500, 50);
        a.write_splits(&splits).unwrap();
        b.write_splits(&splits).unwrap();
        let oa: Vec<_> = a.blocks_in_order().map(|m| m.replicas.clone()).collect();
        let ob: Vec<_> = b.blocks_in_order().map(|m| m.replicas.clone()).collect();
        assert_eq!(oa, ob);
    }
}

//! Small in-tree substrates that would normally be external crates.
//!
//! The build is fully offline against a fixed vendored crate set (see
//! `.cargo/config.toml`), so the pieces a Hadoop-like system usually pulls
//! from the ecosystem — a JSON parser for the artifact manifest, a seedable
//! PRNG for workload generation, a tiny property-testing loop — live here.

pub mod json;
pub mod proptest;
pub mod rng;
pub mod tempdir;

//! Minimal JSON parser + writer for the artifact manifest and run reports.
//!
//! Recursive-descent over the full JSON grammar (RFC 8259) minus `\u`
//! surrogate-pair edge-cases we never emit. In-tree because the offline
//! vendored crate set has no serde_json (DESIGN.md §Substitutions).

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value. Object keys are sorted (BTreeMap) so serialization
/// is deterministic — run reports diff cleanly across runs.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Builder helpers for report emission.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: impl Into<String>) -> JsonError {
        JsonError { pos: self.pos, msg: msg.into() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            Err(self.err(format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(m)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            a.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(a)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'n') => s.push('\n'),
                    Some(b't') => s.push('\t'),
                    Some(b'r') => s.push('\r'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (c as char).to_digit(16).ok_or_else(|| self.err("bad \\u"))?;
                        }
                        s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => s.push(c as char),
                Some(c) => {
                    // Re-decode multibyte UTF-8: back up and take the full char.
                    let start = self.pos - 1;
                    let len = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    let end = (start + len).min(self.b.len());
                    let chunk = std::str::from_utf8(&self.b[start..end])
                        .map_err(|_| self.err("bad utf8"))?;
                    s.push_str(chunk);
                    self.pos = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let txt = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        txt.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err(format!("bad number '{txt}'")))
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\t' => write!(f, "\\t")?,
                        '\r' => write!(f, "\\r")?,
                        c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{}", Json::Str(k.clone()), v)?;
                }
                write!(f, "}}")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("3.5").unwrap(), Json::Num(3.5));
        assert_eq!(Json::parse("-12").unwrap(), Json::Num(-12.0));
        assert_eq!(Json::parse("1e3").unwrap(), Json::Num(1000.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(j.get("c").unwrap().as_str().unwrap(), "x\ny");
        let arr = j.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[1].as_f64().unwrap(), 2.0);
        assert_eq!(arr[2].get("b").unwrap(), &Json::Null);
    }

    #[test]
    fn parses_manifest_shape() {
        let j = Json::parse(
            r#"{"format":1,"modules":[{"graph":"count_split","variant":"small","path":"x.hlo.txt","t":256,"i":64,"c":64}]}"#,
        )
        .unwrap();
        let m = &j.get("modules").unwrap().as_arr().unwrap()[0];
        assert_eq!(m.get("t").unwrap().as_usize().unwrap(), 256);
        assert_eq!(m.get("variant").unwrap().as_str().unwrap(), "small");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn unicode_escapes_and_utf8() {
        assert_eq!(
            Json::parse("\"\\u0041\\u00e9\"").unwrap(),
            Json::Str("Aé".into())
        );
        assert_eq!(Json::parse("\"héllo→\"").unwrap(), Json::Str("héllo→".into()));
    }

    #[test]
    fn roundtrip_display_parse() {
        let src = r#"{"a":[1,2.5,"s"],"b":{"c":true,"d":null},"e":"q\"uo"}"#;
        let j = Json::parse(src).unwrap();
        let re = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, re);
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(Default::default()));
        assert_eq!(Json::parse(" [ ] ").unwrap(), Json::Arr(vec![]));
    }
}

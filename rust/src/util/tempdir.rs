//! Self-cleaning scratch directories for tests and benches.
//!
//! Public (not `cfg(test)`) because integration tests and bench binaries
//! are separate crates — the same reason `util::proptest` is public.

use std::path::{Path, PathBuf};

/// A uniquely named directory under the system temp root, removed (best
/// effort) on drop. The name combines the caller's tag with the process
/// id, so concurrent test binaries never collide as long as tags are
/// unique within one process.
#[derive(Debug)]
pub struct TempDir(PathBuf);

impl TempDir {
    /// Reserve (and clear any stale copy of) `<tmp>/mr_apriori_<tag>_<pid>`.
    /// The directory itself is created lazily by whatever uses the path
    /// (e.g. `SnapshotStore::open`).
    pub fn new(tag: &str) -> Self {
        let path = std::env::temp_dir()
            .join(format!("mr_apriori_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&path);
        Self(path)
    }

    pub fn path(&self) -> &Path {
        &self.0
    }
}

impl Drop for TempDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cleans_up_on_drop_and_names_are_tag_unique() {
        let a = TempDir::new("util_a");
        let b = TempDir::new("util_b");
        assert_ne!(a.path(), b.path());
        std::fs::create_dir_all(a.path()).unwrap();
        std::fs::write(a.path().join("x"), b"x").unwrap();
        let kept = a.path().to_path_buf();
        drop(a);
        assert!(!kept.exists());
        drop(b);
    }
}

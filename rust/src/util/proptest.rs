//! Tiny property-testing driver (the vendored crate set has no proptest).
//!
//! `check` runs a property over `n` randomly generated cases from a seeded
//! [`Xoshiro256`]; on failure it retries the *same seed* derivation chain so
//! the failing case is exactly reproducible from the printed seed, and
//! performs greedy input-size shrinking when the generator supports it via
//! [`Shrink`].

use super::rng::Xoshiro256;

/// Types that can propose smaller versions of themselves for shrinking.
pub trait Shrink: Sized {
    /// Candidate strictly-smaller inputs, most aggressive first.
    fn shrink(&self) -> Vec<Self> {
        Vec::new()
    }
}

impl<T: Clone> Shrink for Vec<T> {
    fn shrink(&self) -> Vec<Self> {
        if self.is_empty() {
            return Vec::new();
        }
        let mut out = Vec::new();
        out.push(self[..self.len() / 2].to_vec()); // first half
        out.push(self[self.len() / 2..].to_vec()); // second half
        if self.len() > 1 {
            out.push(self[1..].to_vec()); // drop head
            out.push(self[..self.len() - 1].to_vec()); // drop tail
        }
        out
    }
}

impl Shrink for usize {
    fn shrink(&self) -> Vec<Self> {
        match *self {
            0 => Vec::new(),
            1 => vec![0],
            n => vec![n / 2, n - 1],
        }
    }
}

impl Shrink for u64 {
    fn shrink(&self) -> Vec<Self> {
        match *self {
            0 => Vec::new(),
            1 => vec![0],
            n => vec![n / 2, n - 1],
        }
    }
}

impl<A: Shrink + Clone, B: Shrink + Clone> Shrink for (A, B) {
    fn shrink(&self) -> Vec<Self> {
        let mut out: Vec<Self> = self
            .0
            .shrink()
            .into_iter()
            .map(|a| (a, self.1.clone()))
            .collect();
        out.extend(self.1.shrink().into_iter().map(|b| (self.0.clone(), b)));
        out
    }
}

/// Outcome of a property over one input.
pub type PropResult = Result<(), String>;

/// Run `prop` over `cases` inputs drawn from `gen`. Panics with the seed,
/// case index and (shrunk) debug form of the failing input.
pub fn check<T, G, P>(name: &str, seed: u64, cases: usize, mut gen: G, mut prop: P)
where
    T: Shrink + Clone + std::fmt::Debug,
    G: FnMut(&mut Xoshiro256) -> T,
    P: FnMut(&T) -> PropResult,
{
    let mut rng = Xoshiro256::seed_from_u64(seed);
    for case in 0..cases {
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            let minimal = shrink_failure(input, &mut prop);
            panic!(
                "property '{name}' failed (seed={seed}, case={case}): {msg}\n  minimal input: {minimal:?}"
            );
        }
    }
}

fn shrink_failure<T, P>(mut failing: T, prop: &mut P) -> T
where
    T: Shrink + Clone,
    P: FnMut(&T) -> PropResult,
{
    // Greedy descent, capped so a pathological shrink lattice terminates.
    for _ in 0..200 {
        let mut advanced = false;
        for cand in failing.shrink() {
            if prop(&cand).is_err() {
                failing = cand;
                advanced = true;
                break;
            }
        }
        if !advanced {
            break;
        }
    }
    failing
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_a_true_property() {
        check(
            "reverse-reverse-id",
            1,
            200,
            |r| (0..r.range_usize(0, 20)).map(|_| r.next_u64()).collect::<Vec<_>>(),
            |v| {
                let mut w = v.clone();
                w.reverse();
                w.reverse();
                if w == *v { Ok(()) } else { Err("mismatch".into()) }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property 'always-small' failed")]
    fn fails_a_false_property_with_seed_in_message() {
        check(
            "always-small",
            2,
            500,
            |r| (0..r.range_usize(0, 64)).map(|_| r.next_u64()).collect::<Vec<_>>(),
            |v| {
                if v.len() < 30 { Ok(()) } else { Err(format!("len {}", v.len())) }
            },
        );
    }

    #[test]
    fn shrinks_vec_failures_toward_minimal() {
        // Property "contains no element > 100" fails; shrinker should find a
        // small witness (not necessarily size-1, but much smaller than 64).
        let mut witness_len = usize::MAX;
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            check(
                "no-big",
                3,
                100,
                |r| (0..64).map(|_| r.gen_range(200)).collect::<Vec<u64>>(),
                |v| {
                    if v.iter().any(|&x| x > 100) {
                        Err(format!("witness-len={}", v.len()))
                    } else {
                        Ok(())
                    }
                },
            );
        }));
        assert!(result.is_err());
        let msg = *result.unwrap_err().downcast::<String>().unwrap();
        // minimal input printed after shrinking; parse its length.
        let start = msg.find("minimal input: [").unwrap();
        let body = &msg[start + "minimal input: [".len()..];
        let end = body.find(']').unwrap();
        let n = if body[..end].trim().is_empty() {
            0
        } else {
            body[..end].split(',').count()
        };
        witness_len = witness_len.min(n);
        assert!(witness_len <= 4, "expected shrunk witness, got len {witness_len}");
    }

    #[test]
    fn usize_shrink_descends_to_zero() {
        let mut v = 1000usize;
        let mut steps = 0;
        while let Some(&next) = v.shrink().first() {
            v = next;
            steps += 1;
            assert!(steps < 100);
        }
        assert_eq!(v, 0);
    }
}

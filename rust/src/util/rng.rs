//! Deterministic, seedable PRNG (xoshiro256** + splitmix64 seeding).
//!
//! Every stochastic component in the system — the Quest workload generator,
//! block-placement tie-breaking, failure injection, the property-test
//! driver — takes an explicit seed so experiments are exactly repeatable.

/// xoshiro256** by Blackman & Vigna (public domain reference
/// implementation), seeded via splitmix64 like the reference code suggests.
#[derive(Debug, Clone)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Xoshiro256 {
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Self { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)` via Lemire's multiply-shift rejection method.
    #[inline]
    pub fn gen_range(&mut self, n: u64) -> u64 {
        assert!(n > 0, "gen_range(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut lo = m as u64;
        if lo < n {
            let t = n.wrapping_neg() % n;
            while lo < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                lo = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in `[lo, hi)`.
    #[inline]
    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi, "empty range {lo}..{hi}");
        lo + self.gen_range((hi - lo) as u64) as usize
    }

    /// Uniform f64 in `[0, 1)` (53-bit mantissa method).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli trial.
    #[inline]
    pub fn bool_with(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Poisson sample via Knuth's method (fine for small means; the Quest
    /// generator uses means <= ~30).
    pub fn poisson(&mut self, mean: f64) -> usize {
        assert!(mean >= 0.0);
        if mean == 0.0 {
            return 0;
        }
        let l = (-mean).exp();
        let mut k = 0usize;
        let mut p = 1.0;
        loop {
            p *= self.next_f64();
            if p <= l {
                return k;
            }
            k += 1;
            if k > 10_000 {
                return k; // pathological mean guard
            }
        }
    }

    /// Geometric-like exponential sample with the given mean.
    pub fn exponential(&mut self, mean: f64) -> f64 {
        let u = 1.0 - self.next_f64(); // (0, 1]
        -mean * u.ln()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (partial Fisher–Yates over
    /// an index table; O(n) but n here is an item-dictionary size).
    pub fn sample_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "sample_distinct k={k} > n={n}");
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = self.range_usize(i, n);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Derive an independent child stream (for per-node / per-task rngs).
    pub fn fork(&mut self) -> Self {
        Self::seed_from_u64(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Xoshiro256::seed_from_u64(42);
        let mut b = Xoshiro256::seed_from_u64(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Xoshiro256::seed_from_u64(1);
        let mut b = Xoshiro256::seed_from_u64(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn gen_range_in_bounds_and_covers() {
        let mut r = Xoshiro256::seed_from_u64(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.gen_range(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues hit: {seen:?}");
    }

    #[test]
    fn next_f64_unit_interval() {
        let mut r = Xoshiro256::seed_from_u64(4);
        for _ in 0..1000 {
            let f = r.next_f64();
            assert!((0.0..1.0).contains(&f));
        }
    }

    #[test]
    fn poisson_mean_roughly_matches() {
        let mut r = Xoshiro256::seed_from_u64(5);
        let n = 20_000;
        let mean = 10.0;
        let total: usize = (0..n).map(|_| r.poisson(mean)).sum();
        let emp = total as f64 / n as f64;
        assert!((emp - mean).abs() < 0.2, "empirical mean {emp}");
    }

    #[test]
    fn sample_distinct_is_distinct() {
        let mut r = Xoshiro256::seed_from_u64(6);
        for _ in 0..100 {
            let k = r.range_usize(0, 20);
            let mut s = r.sample_distinct(50, k);
            s.sort_unstable();
            s.dedup();
            assert_eq!(s.len(), k);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Xoshiro256::seed_from_u64(7);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn fork_streams_independent() {
        let mut parent = Xoshiro256::seed_from_u64(8);
        let mut c1 = parent.fork();
        let mut c2 = parent.fork();
        assert_ne!(c1.next_u64(), c2.next_u64());
    }

    #[test]
    fn exponential_positive_and_mean() {
        let mut r = Xoshiro256::seed_from_u64(9);
        let n = 20_000;
        let total: f64 = (0..n).map(|_| r.exponential(4.0)).sum();
        let emp = total / n as f64;
        assert!(total > 0.0);
        assert!((emp - 4.0).abs() < 0.2, "empirical mean {emp}");
    }
}

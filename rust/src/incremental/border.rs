//! Negative-border bookkeeping: the frequent/border split per level and
//! the invariant checker the differential tests lean on.
//!
//! The **negative border** of a frequent-itemset collection is the set of
//! itemsets that are not frequent themselves but whose every proper
//! subset is — level 1's infrequent singletons, plus, for each k ≥ 2, the
//! apriori-gen candidates of F(k-1) that missed the threshold. Tracking
//! the border **with exact supports** is what makes FUP-style updates
//! sound: after a delta, any itemset that newly crosses min-support is
//! either already tracked (frequent or border, so one delta-only count
//! updates it exactly) or a candidate generated from a *promoted* border
//! itemset (the frontier, re-counted against the full database once).
//! Nothing outside those two classes can become frequent, by downward
//! closure.

use crate::apriori::{candidates, Itemset};
use crate::data::TransactionDb;

use super::state::MinedState;

/// One level of tracked state: the frequent itemsets and the level's
/// negative border, both with exact absolute supports over the full
/// database, both sorted lexicographically.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct LevelState {
    pub frequent: Vec<(Itemset, u64)>,
    pub border: Vec<(Itemset, u64)>,
}

impl LevelState {
    /// Every tracked itemset of the level (frequent first, then border).
    pub fn tracked(&self) -> impl Iterator<Item = &(Itemset, u64)> {
        self.frequent.iter().chain(self.border.iter())
    }
}

/// Partition one level's full count table by the threshold. `counted`
/// must be sorted (the coordinator's capture and the delta rebuild both
/// emit candidate-list order, which is sorted), so both halves stay
/// sorted.
pub fn split_level(counted: &[(Itemset, u64)], threshold: u64) -> LevelState {
    let mut level = LevelState::default();
    for (is, s) in counted {
        if *s >= threshold {
            level.frequent.push((is.clone(), *s));
        } else {
            level.border.push((is.clone(), *s));
        }
    }
    level
}

/// Check the full state invariant against the database oracle:
///
/// 1. the tracked universe is exactly `unit_candidates ∪ generate(F_k)`
///    level by level (frequent ⊎ border, no gaps, no strays);
/// 2. every tracked support equals `db.support` (exactness);
/// 3. the threshold splits frequent from border correctly;
/// 4. the level chain extends as far as apriori-gen produces candidates
///    (within `max_k`).
///
/// O(|tracked| · |D|) — a test/debug tool, not a serving-path check.
pub fn verify_invariant(state: &MinedState, db: &TransactionDb) -> Result<(), String> {
    if state.n_transactions != db.len() {
        return Err(format!(
            "state covers {} transactions, db has {}",
            state.n_transactions,
            db.len()
        ));
    }
    if state.n_items != db.n_items {
        return Err(format!(
            "state universe {} != db universe {}",
            state.n_items, db.n_items
        ));
    }
    let threshold = state.apriori.threshold(state.n_transactions);
    let mut prev_frequent: Vec<Itemset> = Vec::new();
    for (i, level) in state.levels.iter().enumerate() {
        let k = i + 1;
        if !state.apriori.level_allowed(k) {
            return Err(format!("level {k} tracked past max_k"));
        }
        let expect: Vec<Itemset> = if k == 1 {
            candidates::unit_candidates(state.n_items)
        } else {
            candidates::generate(&prev_frequent)
        };
        let tracked: Vec<Itemset> = {
            let mut all: Vec<Itemset> =
                level.tracked().map(|(is, _)| is.clone()).collect();
            all.sort();
            all
        };
        if tracked != expect {
            return Err(format!(
                "level {k}: tracked set != candidate set ({} vs {} itemsets)",
                tracked.len(),
                expect.len()
            ));
        }
        for (is, s) in level.tracked() {
            let oracle = db.support(is) as u64;
            if *s != oracle {
                return Err(format!("level {k}: {is:?} support {s} != oracle {oracle}"));
            }
        }
        if let Some((is, s)) = level.frequent.iter().find(|(_, s)| *s < threshold) {
            return Err(format!("level {k}: frequent {is:?} below threshold ({s})"));
        }
        if let Some((is, s)) = level.border.iter().find(|(_, s)| *s >= threshold) {
            return Err(format!("level {k}: border {is:?} at/above threshold ({s})"));
        }
        prev_frequent = level.frequent.iter().map(|(is, _)| is.clone()).collect();
    }
    // The chain must not stop early: if the last level still has frequent
    // itemsets, the next level's candidate set must be empty or gated.
    if !prev_frequent.is_empty() {
        let next_k = state.levels.len() + 1;
        if state.apriori.level_allowed(next_k) && !candidates::generate(&prev_frequent).is_empty()
        {
            return Err(format!("level chain stops at {} with candidates left", next_k - 1));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apriori::classical::{tests::textbook_db, ClassicalApriori};
    use crate::apriori::AprioriConfig;
    use crate::cluster::ClusterConfig;
    use crate::coordinator::MrApriori;

    #[test]
    fn split_level_partitions_by_threshold() {
        let counted = vec![
            (vec![0], 5),
            (vec![1], 2),
            (vec![2], 0),
            (vec![3], 3),
        ];
        let level = split_level(&counted, 3);
        assert_eq!(level.frequent, vec![(vec![0], 5), (vec![3], 3)]);
        assert_eq!(level.border, vec![(vec![1], 2), (vec![2], 0)]);
        assert_eq!(level.tracked().count(), 4);
    }

    #[test]
    fn captured_textbook_state_passes_the_invariant() {
        let db = textbook_db();
        let cfg = AprioriConfig { min_support: 2.0 / 9.0, max_k: 0 };
        let driver = MrApriori::new(ClusterConfig::standalone(), cfg.clone()).with_split_tx(3);
        let (report, state) = MinedState::capture(&driver, &db).unwrap();
        verify_invariant(&state, &db).unwrap();
        let classical = ClassicalApriori::default().mine(&db, &cfg);
        assert_eq!(state.to_result().frequent, classical.frequent);
        assert_eq!(report.result.frequent, classical.frequent);
    }

    #[test]
    fn invariant_rejects_a_tampered_state() {
        let db = textbook_db();
        let cfg = AprioriConfig { min_support: 2.0 / 9.0, max_k: 0 };
        let driver = MrApriori::new(ClusterConfig::standalone(), cfg).with_split_tx(3);
        let (_, state) = MinedState::capture(&driver, &db).unwrap();

        let mut wrong_support = state.clone();
        wrong_support.levels[0].frequent[0].1 += 1;
        assert!(verify_invariant(&wrong_support, &db).is_err());

        let mut missing_border = state.clone();
        missing_border.levels[0].border.pop();
        assert!(verify_invariant(&missing_border, &db).is_err());

        let mut stale_size = state;
        stale_size.n_transactions += 1;
        assert!(verify_invariant(&stale_size, &db).is_err());
    }
}

//! Delta-aware incremental mining: FUP-style border maintenance so a
//! refresh costs O(|Δ|) instead of O(|D|).
//!
//! The batch stack below this module is stateless — every run scans the
//! whole database. This module adds the one piece of state that makes
//! micro-batch refresh scale: a [`MinedState`] holding the frequent
//! itemsets, their exact supports, **and the negative border** (the
//! infrequent itemsets all of whose proper subsets are frequent, with
//! exact supports too). On a delta:
//!
//! * [`delta_job`] runs one MapReduce counting job **over Δ only**
//!   ([`DeltaCountApp`], shared-scan via `SupportEngine::count_batch`)
//!   and the stored base counts absorb the increments;
//! * [`state`] rebuilds the levels under the new threshold, promoting
//!   border itemsets that crossed it and demoting frequent ones that
//!   fell below, re-counting only the *promoted frontier* (candidates
//!   that exist solely because of a promotion) against the full
//!   database via targeted scan jobs;
//! * [`border`] keeps the border invariant checkable — the differential
//!   tests assert the state equals a from-scratch mine after every
//!   generation.
//!
//! `serve::refresh::Refresher` drives this as its `incremental` mode,
//! falling back to a full capture-mine whenever the frontier trips
//! [`IncrementalConfig::max_frontier_blowup`].

pub mod border;
pub mod delta_job;
pub mod state;

pub use border::{split_level, verify_invariant, LevelState};
pub use delta_job::{run_delta_count, DeltaCountApp};
pub use state::{DeltaApply, DeltaStats, MinedState};

/// `[incremental]` section of an experiment config.
#[derive(Debug, Clone)]
pub struct IncrementalConfig {
    /// Route micro-batch refreshes through border maintenance instead of
    /// full re-mining.
    pub enabled: bool,
    /// Fall back to a full re-mine when the promoted frontier (itemsets
    /// needing a full-database recount) exceeds this multiple of the
    /// tracked-set size. 0 disables incremental application entirely
    /// (any frontier falls back); larger values tolerate bigger
    /// promotion cascades before giving up.
    pub max_frontier_blowup: f64,
}

impl Default for IncrementalConfig {
    fn default() -> Self {
        Self {
            enabled: false,
            max_frontier_blowup: 1.0,
        }
    }
}

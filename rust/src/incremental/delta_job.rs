//! The delta counting job: one MapReduce pass over **Δ only**.
//!
//! [`DeltaCountApp`] counts every tracked itemset (frequent + negative
//! border, all levels mixed) against the delta's splits. Unlike the
//! level jobs it never threshold-filters — the point is the exact delta
//! increment of every tracked support, which the state layer adds to the
//! stored base counts. Counting goes through the same
//! [`SupportEngine::count_batch`] shared-scan machinery as the batched
//! pipelined jobs ([`crate::engine::LevelGroups`]): one matcher per
//! itemset length, each delta transaction streamed through all of them
//! in a single pass.

use std::collections::HashMap;

use crate::apriori::mr::CandidateCountApp;
use crate::apriori::Itemset;
use crate::coordinator::{MineError, MrApriori};
use crate::data::{split::Split, Transaction, TransactionDb};
use crate::engine::{IndexCache, SupportEngine};
use crate::mapreduce::{app::MapReduceApp, run_adhoc_chaos, JobStats};

/// Count a fixed (possibly mixed-length) tracked-itemset list over the
/// delta with no threshold filter. A thin wrapper over
/// [`CandidateCountApp`] in capture mode with threshold 0 — the delta
/// path must count byte-for-byte like the batch path it increments, so
/// it delegates rather than re-implementing the shared-scan map task.
pub struct DeltaCountApp<'e> {
    inner: CandidateCountApp<'e>,
}

impl<'e> DeltaCountApp<'e> {
    pub fn new(tracked: Vec<Itemset>, engine: &'e dyn SupportEngine, n_items: usize) -> Self {
        // Threshold 0 + capture_all: a delta job never filters — every
        // tracked itemset's increment matters (absent from the output
        // simply means +0).
        Self {
            inner: CandidateCountApp::new(tracked, engine, n_items, 0).with_capture(),
        }
    }

    /// The tracked itemsets this job counts, in job order.
    pub fn tracked(&self) -> &[Itemset] {
        &self.inner.candidates
    }

    /// Route the wrapped counting app through the resident index cache
    /// (see [`CandidateCountApp::with_cache`]); only meaningful when the
    /// engine is the vertical one.
    pub fn with_cache(mut self, cache: &'e IndexCache, generation: u64) -> Self {
        self.inner = self.inner.with_cache(cache, generation);
        self
    }
}

impl MapReduceApp for DeltaCountApp<'_> {
    type K = Itemset;
    type V = u64;

    fn map(&self, s: &Split, input: &[Transaction], emit: &mut dyn FnMut(Itemset, u64)) {
        self.inner.map(s, input, emit);
    }

    fn combine(&self, k: &Itemset, values: &[u64]) -> Option<u64> {
        self.inner.combine(k, values)
    }

    fn reduce(&self, k: &Itemset, values: &[u64]) -> Option<u64> {
        self.inner.reduce(k, values)
    }

    fn map_cost_hint(&self, n_tx: usize) -> f64 {
        self.inner.map_cost_hint(n_tx)
    }

    fn reduce_cost_hint(&self, n_values: usize) -> f64 {
        self.inner.reduce_cost_hint(n_values)
    }

    fn record_bytes_hint(&self) -> usize {
        self.inner.record_bytes_hint()
    }
}

/// Run the delta job with the driver's cluster/engine/job settings and
/// return the per-itemset delta counts (itemsets the delta never touches
/// are simply absent — their increment is 0). An empty delta or an empty
/// tracked set short-circuits without scheduling a job.
pub fn run_delta_count(
    driver: &MrApriori,
    delta: &[Transaction],
    n_items: usize,
    tracked: &[Itemset],
) -> Result<(HashMap<Itemset, u64>, JobStats), MineError> {
    if delta.is_empty() || tracked.is_empty() {
        return Ok((HashMap::new(), JobStats::default()));
    }
    let delta_db = TransactionDb {
        transactions: delta.to_vec(),
        n_items,
    };
    let mut app = DeltaCountApp::new(tracked.to_vec(), driver.engine(), n_items);
    if driver.engine().name() == "vertical" {
        // The delta database is a distinct dataset view whose split ids
        // overlap the main database's, so it gets its own generation —
        // which also drops the superseded view's resident indexes.
        let generation = driver.index_cache().begin_generation();
        app = app.with_cache(driver.index_cache(), generation);
    }
    // Thread the driver's fault clock through so Δ-jobs fired from a
    // refresh cycle inject (and recover from) the same plan as the level
    // loops: dead nodes are reaped from the throwaway placement and a
    // stranded job retries once against the survivors.
    let (out, stats) = run_adhoc_chaos(
        &driver.cluster,
        &delta_db,
        driver.split_tx,
        &app,
        &driver.job,
        driver.chaos(),
    )?;
    Ok((out.into_iter().collect(), stats))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apriori::classical::tests::textbook_db;
    use crate::apriori::AprioriConfig;
    use crate::cluster::ClusterConfig;
    use crate::serve::refresh::synth_delta;

    fn driver() -> MrApriori {
        let cfg = AprioriConfig { min_support: 2.0 / 9.0, max_k: 0 };
        MrApriori::new(ClusterConfig::fhssc(2), cfg).with_split_tx(4)
    }

    #[test]
    fn delta_counts_match_oracle_over_mixed_levels() {
        let base = textbook_db();
        let delta = synth_delta(25, base.n_items, 11);
        let delta_db = TransactionDb { transactions: delta.clone(), n_items: base.n_items };
        let tracked: Vec<Itemset> = vec![
            vec![0],
            vec![4],
            vec![0, 1],
            vec![1, 2],
            vec![0, 1, 2],
        ];
        let (counts, stats) = run_delta_count(&driver(), &delta, base.n_items, &tracked).unwrap();
        assert!(stats.maps_total >= 1);
        for is in &tracked {
            let want = delta_db.support(is) as u64;
            assert_eq!(counts.get(is).copied().unwrap_or(0), want, "{is:?}");
        }
        // only delta occurrences count — the base db is never scanned
        assert!(counts.values().all(|&c| c <= delta.len() as u64));
    }

    #[test]
    fn empty_delta_or_tracked_set_short_circuits() {
        let base = textbook_db();
        let (counts, stats) =
            run_delta_count(&driver(), &[], base.n_items, &[vec![0]]).unwrap();
        assert!(counts.is_empty());
        assert_eq!(stats.maps_total, 0);
        let delta = synth_delta(3, base.n_items, 1);
        let (counts, _) = run_delta_count(&driver(), &delta, base.n_items, &[]).unwrap();
        assert!(counts.is_empty());
    }
}

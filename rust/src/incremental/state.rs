//! The stateful mining layer: a [`MinedState`] that tracks frequent
//! itemsets **and** the negative border with exact supports, and folds
//! transaction deltas in with cost proportional to the delta.
//!
//! The update per delta Δ (FUP for insertions, level-wise):
//!
//! 1. **Delta scan** — one MapReduce job over Δ only
//!    ([`run_delta_count`]) increments every tracked support exactly;
//!    singletons for item ids Δ introduces enter with base support 0.
//! 2. **Level-wise rebuild** — with the new threshold
//!    `ceil(min_support · |D ∪ Δ|)`, recompute each level's candidate
//!    set from the (new) previous frequent level. Tracked candidates
//!    have exact supports already; the untracked remainder is the
//!    **promoted frontier** — candidates that exist only because a
//!    border itemset crossed the threshold — and is re-counted against
//!    the full database via one targeted scan job per level (a shared
//!    [`ExactCounter`], so splits are planned and blocks placed once
//!    per delta). Demotions cascade for free: a demoted itemset's
//!    supersets drop out of the candidate sets.
//! 3. **Blowup guard** — if the cumulative frontier exceeds
//!    [`IncrementalConfig::max_frontier_blowup`] × the tracked-set size,
//!    the update aborts untouched and the caller full re-mines
//!    ([`MinedState::capture`]); incremental refresh must never cost
//!    more than the batch path it replaces.
//!
//! Soundness: by downward closure, an itemset can only become frequent
//! if all its proper subsets are; walking levels bottom-up, every new
//! frequent itemset is either tracked (exact support via step 1) or in
//! the frontier (exact support via step 2), so the resulting state is
//! byte-identical to a from-scratch mine of the union database —
//! `tests/incremental.rs` proves it property-style, churn included.

use std::collections::{HashMap, HashSet};

use crate::apriori::{candidates, AprioriConfig, Itemset, LevelStats, MiningResult};
use crate::coordinator::{ExactCounter, MineError, MiningCapture, MrApriori, RunReport};
use crate::data::{ItemId, Transaction, TransactionDb};

use super::border::{split_level, LevelState};
use super::delta_job::run_delta_count;
use super::IncrementalConfig;

/// What one applied delta did to the state.
#[derive(Debug, Clone, Default)]
pub struct DeltaStats {
    pub delta_tx: usize,
    /// Itemsets whose delta increments one shared-scan Δ-job counted.
    pub tracked: usize,
    /// Promoted-frontier itemsets re-counted against the full database —
    /// the number the ablation compares to the total frequent count.
    pub frontier_recounted: usize,
    /// Border itemsets that crossed min-support.
    pub promoted: usize,
    /// Previously frequent itemsets that fell below it (or lost a
    /// frequent subset).
    pub demoted: usize,
    pub n_frequent: usize,
}

/// Outcome of [`MinedState::apply_delta`].
#[derive(Debug)]
pub enum DeltaApply {
    /// Folded in; the state now describes the union database.
    Applied(DeltaStats),
    /// The promoted frontier tripped the blowup guard; the state is
    /// untouched and the caller should fall back to a full re-mine.
    FrontierBlowup { frontier: usize, tracked: usize },
}

/// The persistent mining state: frequent itemsets + negative border,
/// exact supports, per level. Everything the next delta needs and
/// nothing derived (rules/indexes are rebuilt downstream per snapshot).
#[derive(Debug, Clone)]
pub struct MinedState {
    pub apriori: AprioriConfig,
    /// |D| the supports are exact over.
    pub n_transactions: usize,
    /// Item-universe width (level-1 tracking spans ids `0..n_items`).
    pub n_items: usize,
    /// `levels[i]` holds k = i + 1. The chain ends at the first level
    /// with no frequent itemsets (its border is still tracked) or where
    /// apriori-gen yields no candidates.
    pub levels: Vec<LevelState>,
}

impl MinedState {
    /// Seed a state from a capture-mode mining run.
    pub fn from_capture(
        apriori: AprioriConfig,
        n_transactions: usize,
        capture: &MiningCapture,
    ) -> Self {
        debug_assert_eq!(capture.threshold, apriori.threshold(n_transactions));
        let levels = capture
            .levels
            .iter()
            .map(|lc| split_level(&lc.counted, capture.threshold))
            .collect();
        Self {
            apriori,
            n_transactions,
            n_items: capture.n_items,
            levels,
        }
    }

    /// Full capture-mine of `db` — the cold-start path and the blowup
    /// fallback. Returns the report too so callers can build a serving
    /// index without re-deriving anything.
    pub fn capture(
        driver: &MrApriori,
        db: &TransactionDb,
    ) -> Result<(RunReport, MinedState), MineError> {
        let (report, capture) = driver.mine_captured(db)?;
        let state = Self::from_capture(driver.apriori.clone(), db.len(), &capture);
        Ok((report, state))
    }

    /// Absolute threshold the current generation's split uses.
    pub fn threshold(&self) -> u64 {
        self.apriori.threshold(self.n_transactions)
    }

    pub fn n_frequent(&self) -> usize {
        self.levels.iter().map(|l| l.frequent.len()).sum()
    }

    pub fn n_border(&self) -> usize {
        self.levels.iter().map(|l| l.border.len()).sum()
    }

    /// Total tracked itemsets (what every delta job scans for).
    pub fn n_tracked(&self) -> usize {
        self.n_frequent() + self.n_border()
    }

    /// The state as a canonical [`MiningResult`] — byte-identical
    /// `frequent` to a from-scratch mine of the same database. Level
    /// stats carry counts only (no wall/work: no full scan happened).
    pub fn to_result(&self) -> MiningResult {
        let mut result = MiningResult {
            n_transactions: self.n_transactions,
            ..Default::default()
        };
        for (i, level) in self.levels.iter().enumerate() {
            result.levels.push(LevelStats {
                k: i + 1,
                n_candidates: level.frequent.len() + level.border.len(),
                n_frequent: level.frequent.len(),
                work_units: 0.0,
                wall_secs: 0.0,
            });
            result.frequent.extend(level.frequent.iter().cloned());
        }
        result.normalize();
        result
    }

    /// Fold a delta in. `union_db` must already contain the delta (the
    /// refresher appends before calling); `driver` supplies the cluster,
    /// engine and job settings for the Δ-scan and frontier jobs and must
    /// carry the same `AprioriConfig` the state was captured with.
    pub fn apply_delta(
        &mut self,
        driver: &MrApriori,
        union_db: &TransactionDb,
        delta: &[Transaction],
        guard: &IncrementalConfig,
    ) -> Result<DeltaApply, MineError> {
        assert_eq!(
            union_db.len(),
            self.n_transactions + delta.len(),
            "apply_delta expects the delta already appended to the union database"
        );
        let n_new = union_db.len();
        let t_new = self.apriori.threshold(n_new);
        let n_items_new = union_db.n_items;

        // -- tracked support table, plus the delta's new singletons --
        let mut support: HashMap<Itemset, u64> = HashMap::new();
        for level in &self.levels {
            for (is, s) in level.tracked() {
                support.insert(is.clone(), *s);
            }
        }
        for id in self.n_items..n_items_new {
            support.insert(vec![id as ItemId], 0);
        }
        let tracked_total = support.len();

        // -- one shared-scan counting job over Δ only --
        let tracked_list: Vec<Itemset> = {
            let mut v: Vec<Itemset> = support.keys().cloned().collect();
            v.sort_by(|a, b| (a.len(), a).cmp(&(b.len(), b)));
            v
        };
        let (delta_counts, _job) =
            run_delta_count(driver, delta, n_items_new, &tracked_list)?;
        for (is, c) in delta_counts {
            if let Some(s) = support.get_mut(&is) {
                *s += c;
            }
        }

        // -- level-wise rebuild, re-counting only the promoted frontier --
        // One scan context for all frontier levels: splits planned and
        // blocks placed once per delta, lazily (deltas without
        // promotions never touch the full database at all).
        let mut counter: Option<ExactCounter<'_>> = None;
        let mut new_levels: Vec<LevelState> = Vec::new();
        let mut frontier_total = 0usize;
        let mut prev: Vec<Itemset> = Vec::new();
        let mut k = 1usize;
        while self.apriori.level_allowed(k) {
            let cands: Vec<Itemset> = if k == 1 {
                candidates::unit_candidates(n_items_new)
            } else {
                candidates::generate(&prev)
            };
            if cands.is_empty() {
                break;
            }
            let unknown: Vec<Itemset> = cands
                .iter()
                .filter(|c| !support.contains_key(*c))
                .cloned()
                .collect();
            frontier_total += unknown.len();
            if frontier_total as f64 > guard.max_frontier_blowup * tracked_total.max(1) as f64 {
                return Ok(DeltaApply::FrontierBlowup {
                    frontier: frontier_total,
                    tracked: tracked_total,
                });
            }
            if !unknown.is_empty() {
                if counter.is_none() {
                    counter = Some(ExactCounter::new(driver, union_db)?);
                }
                let counts = counter
                    .as_mut()
                    .expect("just seeded")
                    .count(union_db, &unknown)?;
                for (is, c) in unknown.into_iter().zip(counts) {
                    support.insert(is, c);
                }
            }
            let mut level = LevelState::default();
            for c in cands {
                let s = support[&c];
                if s >= t_new {
                    level.frequent.push((c, s));
                } else {
                    level.border.push((c, s));
                }
            }
            let chain_done = level.frequent.is_empty();
            prev = level.frequent.iter().map(|(is, _)| is.clone()).collect();
            new_levels.push(level);
            if chain_done {
                break;
            }
            k += 1;
        }

        // -- promote/demote accounting, then commit --
        let old_frequent: HashSet<&Itemset> = self
            .levels
            .iter()
            .flat_map(|l| l.frequent.iter().map(|(is, _)| is))
            .collect();
        let mut promoted = 0usize;
        let mut survived: HashSet<&Itemset> = HashSet::new();
        for level in &new_levels {
            for (is, _) in &level.frequent {
                if old_frequent.contains(is) {
                    survived.insert(is);
                } else {
                    promoted += 1;
                }
            }
        }
        let demoted = old_frequent.len() - survived.len();
        let stats = DeltaStats {
            delta_tx: delta.len(),
            tracked: tracked_total,
            frontier_recounted: frontier_total,
            promoted,
            demoted,
            n_frequent: new_levels.iter().map(|l| l.frequent.len()).sum(),
        };
        self.levels = new_levels;
        self.n_transactions = n_new;
        self.n_items = n_items_new;
        Ok(DeltaApply::Applied(stats))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apriori::classical::ClassicalApriori;
    use crate::cluster::ClusterConfig;
    use crate::data::Transaction;
    use crate::incremental::border::verify_invariant;

    fn tx(items: &[u32]) -> Transaction {
        Transaction::new(items.iter().copied())
    }

    fn tiny_db() -> TransactionDb {
        TransactionDb::new(vec![tx(&[0, 1]), tx(&[0, 1]), tx(&[0]), tx(&[2])])
    }

    fn driver(min_support: f64) -> MrApriori {
        let cfg = AprioriConfig { min_support, max_k: 0 };
        MrApriori::new(ClusterConfig::standalone(), cfg).with_split_tx(2)
    }

    fn assert_matches_full_mine(state: &MinedState, db: &TransactionDb) {
        let full = ClassicalApriori::default().mine(db, &state.apriori);
        assert_eq!(state.to_result().frequent, full.frequent);
        verify_invariant(state, db).unwrap();
    }

    #[test]
    fn promotion_demotion_and_frontier_recount_hand_worked() {
        // Base (t = ceil(0.5·4) = 2): F1 = {0}:3 {1}:2, border {2}:1;
        // F2 = {0,1}:2.
        let mut db = tiny_db();
        let driver = driver(0.5);
        let (_, mut state) = MinedState::capture(&driver, &db).unwrap();
        assert_eq!(state.n_frequent(), 3);
        assert_matches_full_mine(&state, &db);

        // Δ1 = two {2} baskets: t rises to 3. {1} demotes (kills {0,1}),
        // {2} promotes from the border, and the fresh candidate {0,2}
        // is the frontier — re-counted against the full db (support 0).
        let delta1 = vec![tx(&[2]), tx(&[2])];
        db.append(delta1.clone());
        let outcome = state
            .apply_delta(&driver, &db, &delta1, &IncrementalConfig::default())
            .unwrap();
        let stats = match outcome {
            DeltaApply::Applied(s) => s,
            other => panic!("expected Applied, got {other:?}"),
        };
        assert_eq!(stats.promoted, 1); // {2}
        assert_eq!(stats.demoted, 2); // {1} and {0,1}
        assert_eq!(stats.frontier_recounted, 1); // {0,2}
        assert_eq!(state.n_frequent(), 2); // {0}, {2}
        assert_matches_full_mine(&state, &db);

        // Δ2 re-promotes pressure on {0,1}: it was dropped from tracking
        // when {1} demoted, so it must come back via the frontier path.
        let delta2 = vec![tx(&[0, 1]), tx(&[0, 1]), tx(&[0, 1])];
        db.append(delta2.clone());
        let outcome = state
            .apply_delta(&driver, &db, &delta2, &IncrementalConfig::default())
            .unwrap();
        let stats = match outcome {
            DeltaApply::Applied(s) => s,
            other => panic!("expected Applied, got {other:?}"),
        };
        // t = ceil(0.5·9) = 5: {0}:6 and {1}:5 frequent, {2}:3 demoted
        // again, and the revived candidate {0,1} (support 5) promotes
        // through a frontier recount.
        assert!(stats.frontier_recounted >= 1);
        assert_eq!(state.n_frequent(), 3);
        assert_matches_full_mine(&state, &db);
    }

    #[test]
    fn delta_with_new_items_grows_the_universe() {
        let mut db = tiny_db();
        let driver = driver(0.25);
        let (_, mut state) = MinedState::capture(&driver, &db).unwrap();
        assert_eq!(state.n_items, 3);
        let delta = vec![tx(&[5]), tx(&[5]), tx(&[0, 5])];
        db.append(delta.clone());
        let outcome = state
            .apply_delta(&driver, &db, &delta, &IncrementalConfig::default())
            .unwrap();
        assert!(matches!(outcome, DeltaApply::Applied(_)));
        assert_eq!(state.n_items, 6);
        // t = ceil(0.25·7) = 2; {5}:3 is frequent despite base support 0
        assert!(state.levels[0]
            .frequent
            .iter()
            .any(|(is, s)| is == &vec![5] && *s == 3));
        assert_matches_full_mine(&state, &db);
    }

    #[test]
    fn empty_delta_is_a_noop_rebuild() {
        let mut db = tiny_db();
        let driver = driver(0.5);
        let (_, mut state) = MinedState::capture(&driver, &db).unwrap();
        let before = state.clone();
        let outcome = state
            .apply_delta(&driver, &db, &[], &IncrementalConfig::default())
            .unwrap();
        let stats = match outcome {
            DeltaApply::Applied(s) => s,
            other => panic!("expected Applied, got {other:?}"),
        };
        assert_eq!(stats.delta_tx, 0);
        assert_eq!(stats.frontier_recounted, 0);
        assert_eq!((stats.promoted, stats.demoted), (0, 0));
        assert_eq!(state.levels, before.levels);
        assert_matches_full_mine(&state, &db);
    }

    #[test]
    fn zero_blowup_guard_forces_fallback_on_any_frontier() {
        let mut db = tiny_db();
        let driver = driver(0.5);
        let (_, mut state) = MinedState::capture(&driver, &db).unwrap();
        let before = state.clone();
        let guard = IncrementalConfig { enabled: true, max_frontier_blowup: 0.0 };
        // the Δ1 from the hand-worked test creates a 1-itemset frontier
        let delta = vec![tx(&[2]), tx(&[2])];
        db.append(delta.clone());
        match state.apply_delta(&driver, &db, &delta, &guard).unwrap() {
            DeltaApply::FrontierBlowup { frontier, tracked } => {
                assert_eq!(frontier, 1);
                assert_eq!(tracked, before.n_tracked());
            }
            other => panic!("expected FrontierBlowup, got {other:?}"),
        }
        // the state is untouched — the caller now captures from scratch
        assert_eq!(state.levels, before.levels);
        assert_eq!(state.n_transactions, before.n_transactions);
        let (_, fresh) = MinedState::capture(&driver, &db).unwrap();
        assert_matches_full_mine(&fresh, &db);
    }

    #[test]
    fn max_k_caps_the_incremental_chain_too() {
        let db0 = TransactionDb::new(vec![
            tx(&[0, 1, 2]),
            tx(&[0, 1, 2]),
            tx(&[0, 1, 2]),
            tx(&[3]),
        ]);
        let cfg = AprioriConfig { min_support: 0.5, max_k: 2 };
        let driver = MrApriori::new(ClusterConfig::standalone(), cfg.clone()).with_split_tx(2);
        let mut db = db0;
        let (_, mut state) = MinedState::capture(&driver, &db).unwrap();
        assert!(state.levels.len() <= 2);
        let delta = vec![tx(&[0, 1, 2])];
        db.append(delta.clone());
        state
            .apply_delta(&driver, &db, &delta, &IncrementalConfig::default())
            .unwrap();
        assert!(state.levels.len() <= 2);
        let full = ClassicalApriori::default().mine(&db, &cfg);
        assert_eq!(state.to_result().frequent, full.frequent);
    }
}

//! Experiment configuration: presets matching the paper's deployments and
//! a minimal TOML-subset loader (`key = value` scalars, `[section]`
//! headers, comments) so runs are reproducible from checked-in files.
//! In-tree because the offline crate set has no toml/serde (DESIGN.md
//! §Substitutions).

use std::collections::BTreeMap;
use std::path::Path;

use crate::apriori::AprioriConfig;
use crate::chaos::ChaosConfig;
use crate::cluster::ClusterConfig;
use crate::coordinator::PipelineConfig;
use crate::engine::EngineKind;
use crate::fabric::FabricConfig;
use crate::incremental::IncrementalConfig;
use crate::mapreduce::JobConfig;
use crate::obs::{ObsConfig, SloConfig};
use crate::serve::ServeConfig;
use crate::store::StoreConfig;

/// Deployment preset (paper §3.1 + fig 4/5 series).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Preset {
    Standalone,
    Pseudo,
    #[default]
    Fhssc,
    Fhdsc,
}

impl std::str::FromStr for Preset {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "standalone" => Ok(Self::Standalone),
            "pseudo" | "pseudo-distributed" => Ok(Self::Pseudo),
            "fhssc" => Ok(Self::Fhssc),
            "fhdsc" => Ok(Self::Fhdsc),
            other => Err(format!(
                "unknown preset '{other}' (want standalone|pseudo|fhssc|fhdsc)"
            )),
        }
    }
}

/// Everything one experiment run needs.
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    pub preset: Preset,
    /// Cluster size for fhssc/fhdsc presets.
    pub nodes: usize,
    pub apriori: AprioriConfig,
    pub engine: EngineKind,
    /// Transactions per map split.
    pub split_tx: usize,
    pub job: JobConfig,
    /// Pipelined job-DAG execution (off = the paper's synchronous loop).
    pub pipeline: PipelineConfig,
    /// Online rule-serving layer (`[serve]` section; `repro serve`).
    pub serve: ServeConfig,
    /// Sharded serving fabric (`[fabric]` section; `shards = 0` keeps
    /// the classic single-index backend).
    pub fabric: FabricConfig,
    /// Delta-aware refresh strategy (`[incremental]` section;
    /// `--refresh-mode incremental`).
    pub incremental: IncrementalConfig,
    /// Durable snapshot store (`[store]` section; `--store-dir`).
    pub store: StoreConfig,
    /// Observability (`[obs]` section; `--log-level` / `--trace-out`).
    pub obs: ObsConfig,
    /// Deterministic fault injection (`[chaos]` section;
    /// `mine --fault-plan`). Off by default.
    pub chaos: ChaosConfig,
    /// Serve-side SLO watching (`[slo]` section; `--slo-p99-ms`).
    /// Off by default (`p99_ms = 0`).
    pub slo: SloConfig,
    /// Workload: transactions to generate (Quest T10.I4) when no input
    /// file is given.
    pub transactions: usize,
    pub seed: u64,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        Self {
            preset: Preset::Fhssc,
            nodes: 3,
            apriori: AprioriConfig::default(),
            // The measured-fastest engine (EXPERIMENTS.md §Perf); the
            // paper-faithful baselines remain `engine = trie|hash-tree`.
            engine: EngineKind::Vertical,
            split_tx: 1000,
            job: JobConfig { n_reducers: 3, ..Default::default() },
            pipeline: PipelineConfig::default(),
            serve: ServeConfig::default(),
            fabric: FabricConfig::default(),
            incremental: IncrementalConfig::default(),
            store: StoreConfig::default(),
            obs: ObsConfig::default(),
            chaos: ChaosConfig::default(),
            slo: SloConfig::default(),
            transactions: 10_000,
            seed: 0xACE5_2012,
        }
    }
}

#[derive(Debug)]
pub enum ConfigError {
    Io(std::io::Error),
    Parse { line: usize, msg: String },
    BadValue { key: String, msg: String },
}

impl std::fmt::Display for ConfigError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Self::Io(e) => write!(f, "io: {e}"),
            Self::Parse { line, msg } => write!(f, "line {line}: {msg}"),
            Self::BadValue { key, msg } => write!(f, "key '{key}': {msg}"),
        }
    }
}

impl std::error::Error for ConfigError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Self::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for ConfigError {
    fn from(e: std::io::Error) -> Self {
        Self::Io(e)
    }
}

impl ExperimentConfig {
    /// Instantiate the cluster for this config.
    pub fn cluster(&self) -> ClusterConfig {
        match self.preset {
            Preset::Standalone => ClusterConfig::standalone(),
            Preset::Pseudo => ClusterConfig::pseudo_distributed(),
            Preset::Fhssc => ClusterConfig::fhssc(self.nodes),
            Preset::Fhdsc => ClusterConfig::fhdsc(self.nodes),
        }
    }

    /// Load a `key = value` TOML-subset file. Unknown keys error (typos
    /// should fail loudly in experiment configs).
    pub fn load(path: &Path) -> Result<Self, ConfigError> {
        Self::parse(&std::fs::read_to_string(path)?)
    }

    pub fn parse(text: &str) -> Result<Self, ConfigError> {
        let kv = parse_kv(text)?;
        let mut cfg = Self::default();
        for (key, value) in &kv {
            let bad = |msg: &str| ConfigError::BadValue {
                key: key.clone(),
                msg: msg.to_string(),
            };
            match key.as_str() {
                "preset" => {
                    cfg.preset = value.parse().map_err(|e: String| bad(&e))?;
                }
                "nodes" => {
                    cfg.nodes = value.parse().map_err(|_| bad("want integer"))?;
                    if cfg.nodes == 0 {
                        return Err(bad("must be >= 1"));
                    }
                }
                "min_support" => {
                    let v: f64 = value.parse().map_err(|_| bad("want float"))?;
                    if !(0.0..=1.0).contains(&v) || v == 0.0 {
                        return Err(bad("must be in (0, 1]"));
                    }
                    cfg.apriori.min_support = v;
                }
                "max_k" => {
                    cfg.apriori.max_k = value.parse().map_err(|_| bad("want integer"))?;
                }
                "engine" => {
                    cfg.engine = value.parse().map_err(|e: String| bad(&e))?;
                }
                "split_tx" => {
                    cfg.split_tx = value.parse().map_err(|_| bad("want integer"))?;
                    if cfg.split_tx == 0 {
                        return Err(bad("must be >= 1"));
                    }
                }
                "n_reducers" => {
                    cfg.job.n_reducers = value.parse().map_err(|_| bad("want integer"))?;
                }
                "combiner" => {
                    cfg.job.enable_combiner =
                        value.parse().map_err(|_| bad("want true|false"))?;
                }
                "speculative" => {
                    cfg.job.speculative = value.parse().map_err(|_| bad("want true|false"))?;
                }
                "pipeline" => {
                    cfg.pipeline.enabled = value.parse().map_err(|_| bad("want true|false"))?;
                }
                "batch_levels" => {
                    cfg.pipeline.batch_levels =
                        value.parse().map_err(|_| bad("want integer"))?;
                    if !(1..=2).contains(&cfg.pipeline.batch_levels) {
                        return Err(bad("must be 1 or 2"));
                    }
                }
                "max_blowup" => {
                    let v: f64 = value.parse().map_err(|_| bad("want float"))?;
                    // NaN would silently disable both the blowup guard and
                    // the batched look-ahead (all comparisons false).
                    if !v.is_finite() || v < 0.0 {
                        return Err(bad("must be a finite value >= 0"));
                    }
                    cfg.pipeline.max_blowup = v;
                }
                "transactions" => {
                    cfg.transactions = value.parse().map_err(|_| bad("want integer"))?;
                }
                "seed" => {
                    cfg.seed = value.parse().map_err(|_| bad("want integer"))?;
                }
                "serve.workers" => {
                    cfg.serve.workers = value.parse().map_err(|_| bad("want integer"))?;
                    if cfg.serve.workers == 0 {
                        return Err(bad("must be >= 1"));
                    }
                }
                "serve.queue_depth" => {
                    cfg.serve.queue_depth = value.parse().map_err(|_| bad("want integer"))?;
                    if cfg.serve.queue_depth == 0 {
                        return Err(bad("must be >= 1"));
                    }
                }
                "serve.internal_queue_depth" => {
                    cfg.serve.internal_queue_depth =
                        value.parse().map_err(|_| bad("want integer"))?;
                    if cfg.serve.internal_queue_depth == 0 {
                        return Err(bad("must be >= 1"));
                    }
                }
                "serve.top_k" => {
                    cfg.serve.top_k = value.parse().map_err(|_| bad("want integer"))?;
                    if cfg.serve.top_k == 0 {
                        return Err(bad("must be >= 1"));
                    }
                }
                "serve.min_confidence" => {
                    let v: f64 = value.parse().map_err(|_| bad("want float"))?;
                    if !(0.0..=1.0).contains(&v) {
                        return Err(bad("must be in [0, 1]"));
                    }
                    cfg.serve.min_confidence = v;
                }
                "serve.refresh_tx" => {
                    cfg.serve.refresh_tx = value.parse().map_err(|_| bad("want integer"))?;
                    if cfg.serve.refresh_tx == 0 {
                        return Err(bad("must be >= 1"));
                    }
                }
                "serve.refresh_batches" => {
                    cfg.serve.refresh_batches =
                        value.parse().map_err(|_| bad("want integer"))?;
                }
                "serve.deadline_ms" => {
                    cfg.serve.deadline_ms = value.parse().map_err(|_| bad("want integer"))?;
                }
                "fabric.shards" => {
                    // 0 is legal: it means "fabric off".
                    cfg.fabric.shards = value.parse().map_err(|_| bad("want integer"))?;
                }
                "fabric.replicas" => {
                    cfg.fabric.replicas = value.parse().map_err(|_| bad("want integer"))?;
                    if cfg.fabric.replicas == 0 {
                        return Err(bad("must be >= 1"));
                    }
                }
                "fabric.hedge_ms" => {
                    cfg.fabric.hedge_ms = value.parse().map_err(|_| bad("want integer"))?;
                }
                "incremental.enabled" => {
                    cfg.incremental.enabled =
                        value.parse().map_err(|_| bad("want true|false"))?;
                }
                "incremental.max_frontier_blowup" => {
                    let v: f64 = value.parse().map_err(|_| bad("want float"))?;
                    // NaN would make the guard comparison always-false,
                    // silently unbounding frontier recounts.
                    if !v.is_finite() || v < 0.0 {
                        return Err(bad("must be a finite value >= 0"));
                    }
                    cfg.incremental.max_frontier_blowup = v;
                }
                "store.dir" => {
                    cfg.store.dir = Some(std::path::PathBuf::from(value));
                }
                "store.retain" => {
                    cfg.store.retain = value.parse().map_err(|_| bad("want integer"))?;
                    if cfg.store.retain == 0 {
                        return Err(bad("must be >= 1"));
                    }
                }
                "store.no_persist" => {
                    cfg.store.no_persist =
                        value.parse().map_err(|_| bad("want true|false"))?;
                }
                "obs.log_level" => {
                    cfg.obs.log_level = value.parse().map_err(|e: String| bad(&e))?;
                }
                "chaos.plan" => {
                    // Validate the spec at load time so a typo'd plan
                    // fails before any mining starts.
                    crate::chaos::FaultPlan::parse(value).map_err(|e| bad(&e))?;
                    cfg.chaos.plan = Some(value.clone());
                }
                "chaos.seed" => {
                    cfg.chaos.seed = value.parse().map_err(|_| bad("want integer"))?;
                }
                "slo.p99_ms" => {
                    cfg.slo.p99_ms = value.parse().map_err(|_| bad("want float"))?;
                    cfg.slo.validate().map_err(|e| bad(&e))?;
                }
                "slo.window_ms" => {
                    cfg.slo.window_ms = value.parse().map_err(|_| bad("want integer"))?;
                    cfg.slo.validate().map_err(|e| bad(&e))?;
                }
                "slo.min_requests" => {
                    cfg.slo.min_requests = value.parse().map_err(|_| bad("want integer"))?;
                }
                other => {
                    return Err(ConfigError::BadValue {
                        key: other.to_string(),
                        msg: "unknown key".into(),
                    })
                }
            }
        }
        Ok(cfg)
    }
}

/// `key = value` lines; `#` comments; quoted or bare strings; `[name]`
/// section headers prefix subsequent keys as `name.key` (TOML semantics
/// for the flat one-level tables this config uses). Like TOML, opening
/// the same section twice is an error — silently merging split tables
/// hides copy-paste mistakes in experiment configs. A `[` without its
/// closing `]` falls through to the `key = value` check and errors there.
fn parse_kv(text: &str) -> Result<BTreeMap<String, String>, ConfigError> {
    let mut out = BTreeMap::new();
    let mut section = String::new();
    let mut seen_sections = std::collections::BTreeSet::new();
    for (i, raw) in text.lines().enumerate() {
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        if let Some(name) = line.strip_prefix('[').and_then(|l| l.strip_suffix(']')) {
            let name = name.trim();
            if name.is_empty() || name.contains(['[', ']', '=']) {
                return Err(ConfigError::Parse {
                    line: i + 1,
                    msg: format!("bad section header '{line}'"),
                });
            }
            if !seen_sections.insert(name.to_string()) {
                return Err(ConfigError::Parse {
                    line: i + 1,
                    msg: format!("duplicate section '[{name}]'"),
                });
            }
            section = format!("{name}.");
            continue;
        }
        let Some((k, v)) = line.split_once('=') else {
            return Err(ConfigError::Parse {
                line: i + 1,
                msg: format!("expected 'key = value', got '{line}'"),
            });
        };
        let key = k.trim().to_string();
        let mut value = v.trim().to_string();
        if value.len() >= 2 && value.starts_with('"') && value.ends_with('"') {
            value = value[1..value.len() - 1].to_string();
        }
        if key.is_empty() || value.is_empty() {
            return Err(ConfigError::Parse {
                line: i + 1,
                msg: "empty key or value".into(),
            });
        }
        out.insert(format!("{section}{key}"), value);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::DeployMode;

    #[test]
    fn default_roundtrip_presets() {
        let c = ExperimentConfig::default();
        assert_eq!(c.cluster().mode, DeployMode::FullyDistributed);
        assert_eq!(c.cluster().n_nodes(), 3);
    }

    #[test]
    fn parses_full_config() {
        let cfg = ExperimentConfig::parse(
            r#"
            # fig-5 style run
            preset = "fhdsc"
            nodes = 5
            min_support = 0.02
            max_k = 3
            engine = "tensor"
            split_tx = 500
            n_reducers = 4
            combiner = false
            speculative = true
            transactions = 12000
            seed = 42
            "#,
        )
        .unwrap();
        assert_eq!(cfg.preset, Preset::Fhdsc);
        assert_eq!(cfg.nodes, 5);
        assert_eq!(cfg.apriori.min_support, 0.02);
        assert_eq!(cfg.apriori.max_k, 3);
        assert_eq!(cfg.engine, crate::engine::EngineKind::Tensor);
        assert_eq!(cfg.split_tx, 500);
        assert_eq!(cfg.job.n_reducers, 4);
        assert!(!cfg.job.enable_combiner);
        assert!(cfg.job.speculative);
        assert_eq!(cfg.transactions, 12000);
        assert_eq!(cfg.seed, 42);
        assert_eq!(cfg.cluster().n_nodes(), 5);
    }

    #[test]
    fn pipeline_keys_parse_and_validate() {
        let cfg = ExperimentConfig::parse(
            "pipeline = true\nbatch_levels = 2\nmax_blowup = 4.5\n",
        )
        .unwrap();
        assert!(cfg.pipeline.enabled);
        assert_eq!(cfg.pipeline.batch_levels, 2);
        assert_eq!(cfg.pipeline.max_blowup, 4.5);
        assert!(!ExperimentConfig::default().pipeline.enabled);
        assert!(ExperimentConfig::parse("batch_levels = 0").is_err());
        assert!(ExperimentConfig::parse("batch_levels = 3").is_err());
        assert!(ExperimentConfig::parse("max_blowup = -1").is_err());
        assert!(ExperimentConfig::parse("max_blowup = nan").is_err());
        assert!(ExperimentConfig::parse("max_blowup = inf").is_err());
        assert!(ExperimentConfig::parse("pipeline = maybe").is_err());
    }

    #[test]
    fn serve_section_parses_and_validates() {
        let cfg = ExperimentConfig::parse(
            r#"
            nodes = 4
            [serve]
            workers = 8
            queue_depth = 256
            top_k = 3
            min_confidence = 0.75
            refresh_tx = 250
            refresh_batches = 2
            "#,
        )
        .unwrap();
        assert_eq!(cfg.nodes, 4);
        assert_eq!(cfg.serve.workers, 8);
        assert_eq!(cfg.serve.queue_depth, 256);
        assert_eq!(cfg.serve.top_k, 3);
        assert_eq!(cfg.serve.min_confidence, 0.75);
        assert_eq!(cfg.serve.refresh_tx, 250);
        assert_eq!(cfg.serve.refresh_batches, 2);
        // defaults hold when the section is absent
        let d = ExperimentConfig::default().serve;
        assert_eq!((d.workers, d.queue_depth, d.refresh_batches), (2, 64, 0));
        // validations
        assert!(ExperimentConfig::parse("[serve]\nworkers = 0").is_err());
        assert!(ExperimentConfig::parse("[serve]\nqueue_depth = 0").is_err());
        assert!(ExperimentConfig::parse("[serve]\ntop_k = 0").is_err());
        assert!(ExperimentConfig::parse("[serve]\nmin_confidence = 1.5").is_err());
        assert!(ExperimentConfig::parse("[serve]\nrefresh_tx = 0").is_err());
        assert!(ExperimentConfig::parse("[serve]\nrefresh_batches = 0").is_ok());
    }

    #[test]
    fn obs_section_parses_and_validates() {
        use crate::obs::LogLevel;
        let cfg = ExperimentConfig::parse("[obs]\nlog_level = debug").unwrap();
        assert_eq!(cfg.obs.log_level, LogLevel::Debug);
        let cfg = ExperimentConfig::parse("[obs]\nlog_level = \"warn\"").unwrap();
        assert_eq!(cfg.obs.log_level, LogLevel::Warn);
        // default holds when the section is absent
        assert_eq!(ExperimentConfig::default().obs.log_level, LogLevel::Info);
        let err = ExperimentConfig::parse("[obs]\nlog_level = loud").unwrap_err();
        assert!(
            matches!(err, ConfigError::BadValue { ref key, .. } if key == "obs.log_level"),
            "got {err}"
        );
    }

    #[test]
    fn section_headers_prefix_and_reject_malformed() {
        // a key inside an unknown section fails as an unknown (prefixed) key
        let err = ExperimentConfig::parse("[mesh]\nx = 1").unwrap_err();
        assert!(matches!(err, ConfigError::BadValue { key, .. } if key == "mesh.x"));
        // header with trailing comment is fine
        assert!(ExperimentConfig::parse("[serve] # section\nworkers = 2").is_ok());
        assert!(ExperimentConfig::parse("[]\nworkers = 2").is_err());
        assert!(ExperimentConfig::parse("[a=b]\nx = 1").is_err());
        // an empty section is a no-op
        assert!(ExperimentConfig::parse("[serve]").is_ok());
    }

    #[test]
    fn unclosed_bracket_is_a_parse_error_with_the_line_number() {
        let err = ExperimentConfig::parse("nodes = 2\n[serve\nworkers = 4").unwrap_err();
        match err {
            ConfigError::Parse { line, msg } => {
                assert_eq!(line, 2);
                assert!(msg.contains("[serve"), "{msg}");
            }
            other => panic!("expected Parse error, got {other}"),
        }
        // same with the bracket eaten by an inline comment
        assert!(ExperimentConfig::parse("[serve # ]\nworkers = 4").is_err());
    }

    #[test]
    fn duplicate_section_rejected_even_with_distinct_keys() {
        let err = ExperimentConfig::parse(
            "[serve]\nworkers = 2\n[incremental]\nenabled = true\n[serve]\ntop_k = 3\n",
        )
        .unwrap_err();
        match err {
            ConfigError::Parse { line, msg } => {
                assert_eq!(line, 5);
                assert!(msg.contains("duplicate section '[serve]'"), "{msg}");
            }
            other => panic!("expected Parse error, got {other}"),
        }
        // distinct sections with the same keys are fine
        assert!(ExperimentConfig::parse("[serve]\nworkers = 2\n[incremental]\nenabled = true")
            .is_ok());
    }

    #[test]
    fn keys_before_any_section_stay_top_level() {
        // top-level keys may precede every section header; a section never
        // retroactively captures them
        let cfg = ExperimentConfig::parse(
            "nodes = 6\nmin_support = 0.03\n[serve]\nworkers = 3\n",
        )
        .unwrap();
        assert_eq!(cfg.nodes, 6);
        assert_eq!(cfg.apriori.min_support, 0.03);
        assert_eq!(cfg.serve.workers, 3);
        // ...but a top-level key *after* a section header is prefixed and
        // therefore unknown — sections run to end of file
        let err = ExperimentConfig::parse("[serve]\nworkers = 3\nnodes = 6").unwrap_err();
        assert!(matches!(err, ConfigError::BadValue { key, .. } if key == "serve.nodes"));
    }

    #[test]
    fn incremental_section_parses_and_validates() {
        let cfg = ExperimentConfig::parse(
            r#"
            [incremental]
            enabled = true
            max_frontier_blowup = 2.5
            "#,
        )
        .unwrap();
        assert!(cfg.incremental.enabled);
        assert_eq!(cfg.incremental.max_frontier_blowup, 2.5);
        // defaults hold when the section is absent
        let d = ExperimentConfig::default().incremental;
        assert!(!d.enabled);
        assert_eq!(d.max_frontier_blowup, 1.0);
        // validations
        assert!(ExperimentConfig::parse("[incremental]\nenabled = maybe").is_err());
        assert!(ExperimentConfig::parse("[incremental]\nmax_frontier_blowup = -1").is_err());
        assert!(ExperimentConfig::parse("[incremental]\nmax_frontier_blowup = nan").is_err());
        assert!(ExperimentConfig::parse("[incremental]\nmax_frontier_blowup = inf").is_err());
        assert!(ExperimentConfig::parse("[incremental]\nmax_frontier_blowup = 0").is_ok());
    }

    #[test]
    fn full_sectioned_config_round_trips_every_field() {
        // One config exercising every section; parsing it twice must give
        // identical values, and each value lands in its struct unchanged.
        let text = r#"
            preset = "fhssc"
            nodes = 4
            min_support = 0.04
            transactions = 900
            [serve]
            workers = 5
            queue_depth = 128
            deadline_ms = 250
            [incremental]
            enabled = true
            max_frontier_blowup = 3.0
            "#;
        let a = ExperimentConfig::parse(text).unwrap();
        let b = ExperimentConfig::parse(text).unwrap();
        assert_eq!(a.nodes, 4);
        assert_eq!(a.apriori.min_support, 0.04);
        assert_eq!(a.transactions, 900);
        assert_eq!(a.serve.workers, 5);
        assert_eq!(a.serve.queue_depth, 128);
        assert_eq!(a.serve.deadline_ms, 250);
        assert!(a.incremental.enabled);
        assert_eq!(a.incremental.max_frontier_blowup, 3.0);
        assert_eq!(format!("{a:?}"), format!("{b:?}"));
    }

    #[test]
    fn store_section_parses_and_validates() {
        let cfg = ExperimentConfig::parse(
            r#"
            [store]
            dir = "/tmp/snapshots"
            retain = 3
            no_persist = false
            "#,
        )
        .unwrap();
        assert_eq!(cfg.store.dir.as_deref(), Some(std::path::Path::new("/tmp/snapshots")));
        assert_eq!(cfg.store.retain, 3);
        assert!(!cfg.store.no_persist);
        assert!(cfg.store.writes_enabled());
        // defaults: persistence off, sane retain window
        let d = ExperimentConfig::default().store;
        assert!(d.dir.is_none());
        assert_eq!(d.retain, crate::store::StoreConfig::DEFAULT_RETAIN);
        assert!(!d.writes_enabled());
        // validations
        assert!(ExperimentConfig::parse("[store]\nretain = 0").is_err());
        assert!(ExperimentConfig::parse("[store]\nno_persist = maybe").is_err());
        // no_persist freezes an otherwise-enabled store
        let frozen =
            ExperimentConfig::parse("[store]\ndir = \"/tmp/x\"\nno_persist = true").unwrap();
        assert!(!frozen.store.writes_enabled());
    }

    #[test]
    fn fabric_section_parses_and_validates() {
        let cfg = ExperimentConfig::parse(
            r#"
            [fabric]
            shards = 4
            replicas = 2
            hedge_ms = 3
            "#,
        )
        .unwrap();
        assert_eq!(cfg.fabric.shards, 4);
        assert_eq!(cfg.fabric.replicas, 2);
        assert_eq!(cfg.fabric.hedge_ms, 3);
        assert!(cfg.fabric.enabled());
        // defaults: fabric off, sane replica count and hedge floor
        let d = ExperimentConfig::default().fabric;
        assert!(!d.enabled());
        assert_eq!((d.shards, d.replicas, d.hedge_ms), (0, 2, 5));
        // shards = 0 is explicit "off", not an error
        assert!(!ExperimentConfig::parse("[fabric]\nshards = 0").unwrap().fabric.enabled());
        // validations
        assert!(ExperimentConfig::parse("[fabric]\nreplicas = 0").is_err());
        assert!(ExperimentConfig::parse("[fabric]\nshards = many").is_err());
        assert!(ExperimentConfig::parse("[fabric]\nhedge_ms = -1").is_err());
    }

    #[test]
    fn chaos_section_parses_and_validates() {
        let cfg = ExperimentConfig::parse(
            r#"
            [chaos]
            plan = "kill:1@level:2;storeio:1@now"
            seed = 7
            "#,
        )
        .unwrap();
        assert_eq!(cfg.chaos.plan.as_deref(), Some("kill:1@level:2;storeio:1@now"));
        assert_eq!(cfg.chaos.seed, 7);
        assert!(cfg.chaos.enabled());
        // an explicit plan wins over the seed
        let plan = cfg.chaos.resolve(3, 3).unwrap().unwrap();
        assert_eq!(plan.to_string(), "kill:1@level:2;storeio:1@now");
        // seed alone derives a survivable random plan
        let seeded = ExperimentConfig::parse("[chaos]\nseed = 7").unwrap();
        let plan = seeded.chaos.resolve(4, 3).unwrap().unwrap();
        assert!(plan.is_survivable(4, 3));
        // defaults: chaos off
        let d = ExperimentConfig::default().chaos;
        assert!(!d.enabled());
        assert!(d.resolve(3, 3).unwrap().is_none());
        // a typo'd spec fails at load time, naming the key
        let err = ExperimentConfig::parse("[chaos]\nplan = \"boom:1@now\"").unwrap_err();
        assert!(matches!(err, ConfigError::BadValue { ref key, .. } if key == "chaos.plan"));
        assert!(ExperimentConfig::parse("[chaos]\nseed = many").is_err());
    }

    #[test]
    fn slo_section_parses_and_validates() {
        let cfg = ExperimentConfig::parse(
            r#"
            [slo]
            p99_ms = 5.5
            window_ms = 2000
            min_requests = 10
            "#,
        )
        .unwrap();
        assert_eq!(cfg.slo.p99_ms, 5.5);
        assert_eq!(cfg.slo.window_ms, 2000);
        assert_eq!(cfg.slo.min_requests, 10);
        assert!(cfg.slo.enabled());
        // defaults: watcher off
        let d = ExperimentConfig::default().slo;
        assert!(!d.enabled());
        assert!(d.validate().is_ok());
        // bad values fail at load time, naming the key
        let err = ExperimentConfig::parse("[slo]\np99_ms = -2").unwrap_err();
        assert!(matches!(err, ConfigError::BadValue { ref key, .. } if key == "slo.p99_ms"));
        let err = ExperimentConfig::parse("[slo]\nwindow_ms = 0").unwrap_err();
        assert!(matches!(err, ConfigError::BadValue { ref key, .. } if key == "slo.window_ms"));
        assert!(ExperimentConfig::parse("[slo]\nmin_requests = many").is_err());
    }

    #[test]
    fn internal_queue_depth_parses_and_validates() {
        let cfg = ExperimentConfig::parse("[serve]\ninternal_queue_depth = 8").unwrap();
        assert_eq!(cfg.serve.internal_queue_depth, 8);
        assert_eq!(ExperimentConfig::default().serve.internal_queue_depth, 16);
        assert!(ExperimentConfig::parse("[serve]\ninternal_queue_depth = 0").is_err());
    }

    #[test]
    fn engine_key_selects_the_vertical_engine() {
        let cfg = ExperimentConfig::parse("engine = \"vertical\"").unwrap();
        assert_eq!(cfg.engine, crate::engine::EngineKind::Vertical);
        // round-trips through the Display name the CLI prints
        assert_eq!(cfg.engine.to_string(), "vertical");
    }

    #[test]
    fn unknown_key_rejected() {
        let err = ExperimentConfig::parse("bogus = 1").unwrap_err();
        assert!(matches!(err, ConfigError::BadValue { key, .. } if key == "bogus"));
    }

    #[test]
    fn invalid_values_rejected() {
        assert!(ExperimentConfig::parse("min_support = 0").is_err());
        assert!(ExperimentConfig::parse("min_support = 1.5").is_err());
        assert!(ExperimentConfig::parse("nodes = 0").is_err());
        assert!(ExperimentConfig::parse("split_tx = 0").is_err());
        assert!(ExperimentConfig::parse("preset = \"mesh\"").is_err());
        assert!(ExperimentConfig::parse("engine = gpu").is_err());
        assert!(ExperimentConfig::parse("just a line").is_err());
    }

    #[test]
    fn comments_and_blanks_skipped() {
        let cfg = ExperimentConfig::parse("# only comments\n\n  \nnodes = 2 # inline\n").unwrap();
        assert_eq!(cfg.nodes, 2);
    }

    #[test]
    fn preset_parse_all() {
        for (s, p) in [
            ("standalone", Preset::Standalone),
            ("pseudo", Preset::Pseudo),
            ("fhssc", Preset::Fhssc),
            ("fhdsc", Preset::Fhdsc),
        ] {
            assert_eq!(s.parse::<Preset>().unwrap(), p);
        }
    }
}

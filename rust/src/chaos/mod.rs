//! Deterministic chaos: seedable fault plans injected through one clock.
//!
//! The paper's case for running Apriori on Hadoop is commodity-cluster
//! fault tolerance, so the repo needs a way to *exercise* machine
//! failure without giving up reproducibility. A [`FaultPlan`] is a list
//! of fault events keyed to **logical** execution coordinates — "kill
//! node 2 at level 3", "fail the fetch of map 5's output twice", "one
//! transient store I/O error" — rather than wall-clock instants, so the
//! same plan replays identically on any machine. One [`FaultClock`]
//! built from the plan is shared (via `Arc`) by the job runner, the
//! multi-level drivers, the snapshot store, and the refresher; each
//! consumer asks the clock whether its next action is faulted.
//!
//! Because triggers are logical, *which* map attempt observes a
//! `@maps:N` kill may vary across runs of a genuinely multi-threaded
//! runner — the replayable contract is the differential invariant
//! (`tests/chaos.rs`): under any plan that leaves at least one live
//! node holding every block, the mined output is byte-identical to the
//! fault-free run, per-task attempts stay bounded, and the blacklist
//! only grows.

use std::collections::{BTreeMap, BTreeSet};
use std::fmt;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use crate::cluster::NodeId;
use crate::metrics::Counter;
use crate::obs::{MetricsRegistry, RegistryError, TraceCtx};
use crate::util::rng::Xoshiro256;

/// When an event fires, in logical (replayable) coordinates.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultTrigger {
    /// At the start of Apriori level `k` (drivers call
    /// [`FaultClock::begin_level`]).
    AtLevel(usize),
    /// After the `n`-th map-task completion across the run (the runner
    /// calls [`FaultClock::on_map_completion`]).
    AfterMaps(usize),
    /// Immediately, when the clock is built.
    Now,
}

/// What goes wrong.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultKind {
    /// The tasktracker + datanode on `node` stop heartbeating: running
    /// attempts are lost, completed map output on its local disk is
    /// gone, its DFS replicas need re-replication.
    KillNode(NodeId),
    /// `node` keeps working but `factor`× slower (speculation bait).
    SlowNode { node: NodeId, factor: f64 },
    /// The next `times` reducer fetches of `map_task`'s output fail
    /// (serve-side of the shuffle went away mid-transfer).
    ShuffleFetchFail { map_task: usize, times: usize },
    /// The next `times` snapshot-store syscalls fail transiently.
    StoreIo { times: usize },
}

/// One scheduled fault.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultEvent {
    pub trigger: FaultTrigger,
    pub kind: FaultKind,
}

/// A seedable, replayable schedule of faults.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct FaultPlan {
    /// The seed the plan was derived from (0 for hand-written specs);
    /// carried for reports so a failing chaos run names its replay key.
    pub seed: u64,
    pub events: Vec<FaultEvent>,
}

impl FaultPlan {
    /// Parse the CLI/config grammar: `;`-separated events, each
    /// `KIND@TRIGGER`.
    ///
    /// Kinds: `kill:NODE`, `slow:NODE:FACTOR`, `fetchfail:TASK:TIMES`,
    /// `storeio:TIMES`. Triggers: `level:K`, `maps:N`, `now`.
    ///
    /// Example: `kill:1@level:2;slow:0:4@now;storeio:2@now`.
    pub fn parse(spec: &str) -> Result<Self, String> {
        let mut events = Vec::new();
        for part in spec.split(';').map(str::trim).filter(|p| !p.is_empty()) {
            let (kind_s, trig_s) = part
                .split_once('@')
                .ok_or_else(|| format!("fault '{part}': missing '@TRIGGER'"))?;
            let trigger = match trig_s.split_once(':') {
                Some(("level", k)) => FaultTrigger::AtLevel(parse_num(k, part, "level")?),
                Some(("maps", n)) => FaultTrigger::AfterMaps(parse_num(n, part, "maps")?),
                None if trig_s == "now" => FaultTrigger::Now,
                _ => {
                    return Err(format!(
                        "fault '{part}': unknown trigger '{trig_s}' (want level:K|maps:N|now)"
                    ))
                }
            };
            let fields: Vec<&str> = kind_s.split(':').collect();
            let kind = match fields.as_slice() {
                ["kill", node] => FaultKind::KillNode(parse_num(node, part, "node")?),
                ["slow", node, factor] => FaultKind::SlowNode {
                    node: parse_num(node, part, "node")?,
                    factor: factor
                        .parse::<f64>()
                        .ok()
                        .filter(|f| *f >= 1.0)
                        .ok_or_else(|| format!("fault '{part}': factor must be ≥ 1"))?,
                },
                ["fetchfail", task, times] => FaultKind::ShuffleFetchFail {
                    map_task: parse_num(task, part, "task")?,
                    times: parse_num(times, part, "times")?,
                },
                ["storeio", times] => FaultKind::StoreIo { times: parse_num(times, part, "times")? },
                _ => {
                    return Err(format!(
                        "fault '{part}': unknown kind '{kind_s}' \
                         (want kill:N|slow:N:F|fetchfail:T:N|storeio:N)"
                    ))
                }
            };
            events.push(FaultEvent { trigger, kind });
        }
        if events.is_empty() {
            return Err("empty fault plan".into());
        }
        Ok(Self { seed: 0, events })
    }

    /// A random *survivable* plan: at most `replication - 1` distinct
    /// nodes are killed (so every block keeps a live replica) and at
    /// least one node always survives. Deterministic in `seed` — the
    /// proptest's replay key.
    pub fn random(seed: u64, n_nodes: usize, replication: usize) -> Self {
        assert!(n_nodes > 0, "need at least one node");
        let mut rng = Xoshiro256::seed_from_u64(seed ^ 0xC4A0_5BAD_F00D);
        let max_kills = replication.saturating_sub(1).min(n_nodes.saturating_sub(1));
        let n_kills = rng.range_usize(0, max_kills + 1);
        let victims = rng.sample_distinct(n_nodes, n_kills);
        let mut events = Vec::new();
        for &node in &victims {
            let trigger = match rng.gen_range(3) {
                0 => FaultTrigger::Now,
                1 => FaultTrigger::AtLevel(rng.range_usize(1, 4)),
                _ => FaultTrigger::AfterMaps(rng.range_usize(1, 9)),
            };
            events.push(FaultEvent { trigger, kind: FaultKind::KillNode(node) });
        }
        // a straggler that is not one of the kills, when one is free
        if rng.bool_with(0.5) {
            if let Some(node) = (0..n_nodes).find(|n| !victims.contains(n)) {
                events.push(FaultEvent {
                    trigger: FaultTrigger::Now,
                    kind: FaultKind::SlowNode { node, factor: 2.0 + rng.next_f64() * 6.0 },
                });
            }
        }
        for _ in 0..rng.range_usize(0, 3) {
            events.push(FaultEvent {
                trigger: FaultTrigger::Now,
                kind: FaultKind::ShuffleFetchFail {
                    map_task: rng.range_usize(0, 8),
                    times: rng.range_usize(1, 3),
                },
            });
        }
        Self { seed, events }
    }

    /// Distinct nodes this plan kills, in node order.
    pub fn killed_nodes(&self) -> Vec<NodeId> {
        let set: BTreeSet<NodeId> = self
            .events
            .iter()
            .filter_map(|e| match e.kind {
                FaultKind::KillNode(n) => Some(n),
                _ => None,
            })
            .collect();
        set.into_iter().collect()
    }

    /// Whether the plan provably leaves every block a live replica:
    /// fewer than `replication` distinct kills and at least one
    /// survivor. (The differential invariant only holds for survivable
    /// plans.)
    pub fn is_survivable(&self, n_nodes: usize, replication: usize) -> bool {
        let kills = self.killed_nodes().len();
        kills < replication && kills < n_nodes
    }
}

fn parse_num<T: std::str::FromStr>(s: &str, part: &str, what: &str) -> Result<T, String> {
    s.parse::<T>()
        .map_err(|_| format!("fault '{part}': bad {what} '{s}'"))
}

impl fmt::Display for FaultPlan {
    /// Round-trips through [`FaultPlan::parse`] (for seeded plans the
    /// rendered spec is the replayable artifact a report can print).
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        for (i, e) in self.events.iter().enumerate() {
            if i > 0 {
                f.write_str(";")?;
            }
            match e.kind {
                FaultKind::KillNode(n) => write!(f, "kill:{n}")?,
                FaultKind::SlowNode { node, factor } => write!(f, "slow:{node}:{factor}")?,
                FaultKind::ShuffleFetchFail { map_task, times } => {
                    write!(f, "fetchfail:{map_task}:{times}")?
                }
                FaultKind::StoreIo { times } => write!(f, "storeio:{times}")?,
            }
            match e.trigger {
                FaultTrigger::AtLevel(k) => write!(f, "@level:{k}")?,
                FaultTrigger::AfterMaps(n) => write!(f, "@maps:{n}")?,
                FaultTrigger::Now => write!(f, "@now")?,
            }
        }
        Ok(())
    }
}

/// The `[chaos]` experiment-config section: an explicit fault-plan spec
/// and/or a seed for a random survivable plan. Both default to "off".
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct ChaosConfig {
    /// A [`FaultPlan::parse`] spec (`kill:1@level:2;...`). Takes
    /// precedence over `seed` when both are set.
    pub plan: Option<String>,
    /// When nonzero (and no spec is given), derive a random survivable
    /// plan from this seed via [`FaultPlan::random`].
    pub seed: u64,
}

impl ChaosConfig {
    pub fn enabled(&self) -> bool {
        self.plan.is_some() || self.seed != 0
    }

    /// Resolve the section into a plan: parse the spec when present,
    /// else derive from the seed; `Ok(None)` when chaos is off.
    pub fn resolve(
        &self,
        n_nodes: usize,
        replication: usize,
    ) -> Result<Option<FaultPlan>, String> {
        if let Some(spec) = &self.plan {
            return FaultPlan::parse(spec).map(Some);
        }
        if self.seed != 0 {
            return Ok(Some(FaultPlan::random(self.seed, n_nodes, replication)));
        }
        Ok(None)
    }
}

/// Cumulative injection totals (mirrors the `chaos.*` counters).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct ChaosStats {
    pub faults_injected: u64,
    pub nodes_killed: u64,
    pub fetch_faults: u64,
    pub store_faults: u64,
    pub blacklisted: u64,
}

/// The shared fault clock: owns the plan, advances on logical progress
/// callbacks, and answers "is this action faulted?" queries from every
/// subsystem. All methods take `&self`; share it with `Arc`.
#[derive(Debug)]
pub struct FaultClock {
    plan: FaultPlan,
    /// One flag per plan event; an event fires exactly once.
    fired: Mutex<Vec<bool>>,
    dead: Mutex<BTreeSet<NodeId>>,
    slow: Mutex<BTreeMap<NodeId, f64>>,
    /// map task → remaining injected fetch failures.
    shuffle_budget: Mutex<BTreeMap<usize, usize>>,
    /// Remaining injected transient store I/O errors.
    store_budget: AtomicUsize,
    maps_done: AtomicUsize,
    /// Append-only record of blacklisted nodes (monotonicity evidence
    /// for the proptest); the runner reports, the clock never removes.
    blacklist_log: Mutex<Vec<NodeId>>,
    faults_injected: Arc<Counter>,
    nodes_killed: Arc<Counter>,
    fetch_faults: Arc<Counter>,
    store_faults: Arc<Counter>,
    blacklists: Arc<Counter>,
    /// Attach-once trace context: when set, every fault the clock fires
    /// is recorded as a `cat: chaos` span, so flight-recorder dumps and
    /// `repro analyze` show the injections inline with the stages they
    /// perturbed.
    trace: OnceLock<TraceCtx>,
}

impl FaultClock {
    pub fn new(plan: FaultPlan) -> Self {
        let clock = Self {
            fired: Mutex::new(vec![false; plan.events.len()]),
            plan,
            dead: Mutex::new(BTreeSet::new()),
            slow: Mutex::new(BTreeMap::new()),
            shuffle_budget: Mutex::new(BTreeMap::new()),
            store_budget: AtomicUsize::new(0),
            maps_done: AtomicUsize::new(0),
            blacklist_log: Mutex::new(Vec::new()),
            faults_injected: Arc::new(Counter::new()),
            nodes_killed: Arc::new(Counter::new()),
            fetch_faults: Arc::new(Counter::new()),
            store_faults: Arc::new(Counter::new()),
            blacklists: Arc::new(Counter::new()),
            trace: OnceLock::new(),
        };
        clock.fire_due(|t| matches!(t, FaultTrigger::Now));
        clock
    }

    pub fn plan(&self) -> &FaultPlan {
        &self.plan
    }

    /// Attach a trace context (at most one per clock; later attaches are
    /// no-ops). From now on every fault the clock fires records a
    /// `cat: chaos` span. The plan's `@now` events fired at construction
    /// — before any sink could exist — so they are recorded
    /// retroactively here, keeping the trace's injection history
    /// complete.
    pub fn attach_trace(&self, ctx: TraceCtx) {
        if self.trace.set(ctx).is_err() {
            return;
        }
        let fired = self.fired.lock().unwrap();
        for (i, e) in self.plan.events.iter().enumerate() {
            if fired[i] {
                self.record_fault_span(e);
            }
        }
    }

    /// One injection as an instantaneous `cat: chaos` span carrying the
    /// fault's coordinates — the analyzer cross-references `fault.slow`
    /// spans against flagged stragglers.
    fn record_fault_span(&self, event: &FaultEvent) {
        let Some(ctx) = self.trace.get() else { return };
        let name = match event.kind {
            FaultKind::KillNode(_) => "fault.kill",
            FaultKind::SlowNode { .. } => "fault.slow",
            FaultKind::ShuffleFetchFail { .. } => "fault.fetchfail",
            FaultKind::StoreIo { .. } => "fault.storeio",
        };
        let mut span = ctx.span("chaos", name);
        span.set_dur_us(1);
        match event.kind {
            FaultKind::KillNode(n) => span.add("node", n as f64),
            FaultKind::SlowNode { node, factor } => {
                span.add("node", node as f64);
                span.add("factor", factor);
            }
            FaultKind::ShuffleFetchFail { map_task, times } => {
                span.add("map_task", map_task as f64);
                span.add("times", times as f64);
            }
            FaultKind::StoreIo { times } => span.add("times", times as f64),
        }
        match event.trigger {
            FaultTrigger::AtLevel(k) => span.add("at_level", k as f64),
            FaultTrigger::AfterMaps(n) => span.add("after_maps", n as f64),
            FaultTrigger::Now => span.add("at_start", 1.0),
        }
    }

    /// Fire every not-yet-fired event whose trigger satisfies `due`.
    fn fire_due(&self, due: impl Fn(FaultTrigger) -> bool) {
        let mut fired = self.fired.lock().unwrap();
        for (i, e) in self.plan.events.iter().enumerate() {
            if fired[i] || !due(e.trigger) {
                continue;
            }
            fired[i] = true;
            self.faults_injected.inc();
            self.record_fault_span(e);
            match e.kind {
                FaultKind::KillNode(n) => {
                    if self.dead.lock().unwrap().insert(n) {
                        self.nodes_killed.inc();
                    }
                }
                FaultKind::SlowNode { node, factor } => {
                    self.slow.lock().unwrap().insert(node, factor);
                }
                FaultKind::ShuffleFetchFail { map_task, times } => {
                    *self.shuffle_budget.lock().unwrap().entry(map_task).or_insert(0) += times;
                }
                FaultKind::StoreIo { times } => {
                    self.store_budget.fetch_add(times, Ordering::Relaxed);
                }
            }
        }
    }

    /// Driver callback: Apriori level `k` is starting. Fires every
    /// pending `@level:j` event with `j ≤ k` (a mine that converges
    /// before a scheduled level still observes earlier ones).
    pub fn begin_level(&self, k: usize) {
        self.fire_due(|t| matches!(t, FaultTrigger::AtLevel(j) if j <= k));
    }

    /// Runner callback: one map task just completed (first successful
    /// attempt). Fires pending `@maps:n` events once the cross-run
    /// completion count reaches `n`.
    pub fn on_map_completion(&self) {
        let done = self.maps_done.fetch_add(1, Ordering::Relaxed) + 1;
        self.fire_due(|t| matches!(t, FaultTrigger::AfterMaps(n) if n <= done));
    }

    /// Has the tasktracker/datanode on `node` stopped heartbeating?
    pub fn is_dead(&self, node: NodeId) -> bool {
        self.dead.lock().unwrap().contains(&node)
    }

    /// Every node currently dead, in node order.
    pub fn dead_nodes(&self) -> Vec<NodeId> {
        self.dead.lock().unwrap().iter().copied().collect()
    }

    /// Work multiplier for `node` (1.0 = healthy).
    pub fn slow_factor(&self, node: NodeId) -> f64 {
        self.slow.lock().unwrap().get(&node).copied().unwrap_or(1.0)
    }

    /// Should this fetch of `map_task`'s output fail? Consumes one unit
    /// of the task's injected-failure budget.
    pub fn take_shuffle_fault(&self, map_task: usize) -> bool {
        let mut budget = self.shuffle_budget.lock().unwrap();
        match budget.get_mut(&map_task) {
            Some(n) if *n > 0 => {
                *n -= 1;
                self.fetch_faults.inc();
                true
            }
            _ => false,
        }
    }

    /// Should this store syscall fail transiently? Consumes one unit of
    /// the injected I/O-error budget.
    pub fn take_store_fault(&self) -> bool {
        let mut cur = self.store_budget.load(Ordering::Relaxed);
        while cur > 0 {
            match self.store_budget.compare_exchange_weak(
                cur,
                cur - 1,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => {
                    self.store_faults.inc();
                    return true;
                }
                Err(actual) => cur = actual,
            }
        }
        false
    }

    /// Runner report: `node` was blacklisted. Append-only.
    pub fn note_blacklisted(&self, node: NodeId) {
        let mut log = self.blacklist_log.lock().unwrap();
        if !log.contains(&node) {
            log.push(node);
            self.blacklists.inc();
        }
    }

    /// The blacklist in report order (only ever grows).
    pub fn blacklisted(&self) -> Vec<NodeId> {
        self.blacklist_log.lock().unwrap().clone()
    }

    pub fn stats(&self) -> ChaosStats {
        ChaosStats {
            faults_injected: self.faults_injected.get(),
            nodes_killed: self.nodes_killed.get(),
            fetch_faults: self.fetch_faults.get(),
            store_faults: self.store_faults.get(),
            blacklisted: self.blacklists.get(),
        }
    }

    /// Register the clock's counters under `prefix` (conventionally
    /// `chaos`): faults fired, nodes killed, fetch/store faults
    /// injected, nodes blacklisted.
    pub fn register_metrics(
        &self,
        registry: &MetricsRegistry,
        prefix: &str,
    ) -> Result<(), RegistryError> {
        registry.register_counter(
            &format!("{prefix}.faults_injected"),
            Arc::clone(&self.faults_injected),
        )?;
        registry
            .register_counter(&format!("{prefix}.nodes_killed"), Arc::clone(&self.nodes_killed))?;
        registry
            .register_counter(&format!("{prefix}.fetch_faults"), Arc::clone(&self.fetch_faults))?;
        registry
            .register_counter(&format!("{prefix}.store_faults"), Arc::clone(&self.store_faults))?;
        registry.register_counter(&format!("{prefix}.blacklisted"), Arc::clone(&self.blacklists))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_grammar_round_trips() {
        let spec = "kill:1@level:2;slow:0:4@now;fetchfail:3:2@maps:5;storeio:2@now";
        let plan = FaultPlan::parse(spec).unwrap();
        assert_eq!(plan.events.len(), 4);
        assert_eq!(plan.to_string(), spec);
        assert_eq!(FaultPlan::parse(&plan.to_string()).unwrap(), plan);
        assert_eq!(
            plan.events[0],
            FaultEvent { trigger: FaultTrigger::AtLevel(2), kind: FaultKind::KillNode(1) }
        );
    }

    #[test]
    fn bad_specs_are_typed_errors() {
        for bad in [
            "",
            "kill:1",            // no trigger
            "kill@now",          // missing node
            "slow:1:0.5@now",    // factor < 1
            "boom:1@now",        // unknown kind
            "kill:1@when:soon",  // unknown trigger
            "kill:x@now",        // non-numeric node
        ] {
            assert!(FaultPlan::parse(bad).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn random_plans_are_deterministic_and_survivable() {
        for seed in 0..50u64 {
            let a = FaultPlan::random(seed, 4, 3);
            assert_eq!(a, FaultPlan::random(seed, 4, 3), "seed {seed}");
            assert!(a.is_survivable(4, 3), "seed {seed}: {a}");
            assert!(a.killed_nodes().len() <= 2);
        }
        assert_ne!(FaultPlan::random(1, 4, 3), FaultPlan::random(2, 4, 3));
    }

    #[test]
    fn now_events_fire_at_construction() {
        let clock = FaultClock::new(FaultPlan::parse("kill:2@now;slow:1:3@now").unwrap());
        assert!(clock.is_dead(2));
        assert!(!clock.is_dead(1));
        assert_eq!(clock.slow_factor(1), 3.0);
        assert_eq!(clock.slow_factor(0), 1.0);
        assert_eq!(clock.dead_nodes(), vec![2]);
        let s = clock.stats();
        assert_eq!((s.faults_injected, s.nodes_killed), (2, 1));
    }

    #[test]
    fn level_and_map_triggers_fire_once_and_catch_up() {
        let clock = FaultClock::new(FaultPlan::parse("kill:0@level:2;kill:1@maps:3").unwrap());
        assert!(clock.dead_nodes().is_empty());
        clock.begin_level(1);
        assert!(!clock.is_dead(0));
        clock.begin_level(3); // skipped past 2: still fires
        assert!(clock.is_dead(0));
        for _ in 0..2 {
            clock.on_map_completion();
        }
        assert!(!clock.is_dead(1));
        clock.on_map_completion();
        assert!(clock.is_dead(1));
        clock.begin_level(4); // no double fire
        assert_eq!(clock.stats().nodes_killed, 2);
    }

    #[test]
    fn fetch_and_store_budgets_are_consumed() {
        let clock = FaultClock::new(FaultPlan::parse("fetchfail:5:2@now;storeio:1@now").unwrap());
        assert!(clock.take_shuffle_fault(5));
        assert!(clock.take_shuffle_fault(5));
        assert!(!clock.take_shuffle_fault(5), "budget exhausted");
        assert!(!clock.take_shuffle_fault(4), "other tasks unaffected");
        assert!(clock.take_store_fault());
        assert!(!clock.take_store_fault());
        let s = clock.stats();
        assert_eq!((s.fetch_faults, s.store_faults), (2, 1));
    }

    #[test]
    fn blacklist_log_is_append_only_and_deduped() {
        let clock = FaultClock::new(FaultPlan::parse("storeio:0@now").unwrap());
        clock.note_blacklisted(3);
        clock.note_blacklisted(1);
        clock.note_blacklisted(3);
        assert_eq!(clock.blacklisted(), vec![3, 1]);
        assert_eq!(clock.stats().blacklisted, 2);
    }

    #[test]
    fn fault_injections_record_chaos_spans_including_retroactive_now_events() {
        use crate::obs::{TraceCtx, TraceSink};
        let clock = FaultClock::new(
            FaultPlan::parse("slow:1:3@now;kill:0@level:2;fetchfail:4:2@maps:1").unwrap(),
        );
        // the @now event fired before any trace existed
        let sink = TraceSink::new();
        clock.attach_trace(TraceCtx::root(Arc::clone(&sink)));
        let ev = sink.events();
        assert_eq!(ev.len(), 1, "retroactive span for the already-fired @now fault");
        assert_eq!(ev[0].cat, "chaos");
        assert_eq!(ev[0].name, "fault.slow");
        assert!(ev[0].args.contains(&("node".into(), 1.0)));
        assert!(ev[0].args.contains(&("factor".into(), 3.0)));

        clock.begin_level(2);
        clock.on_map_completion();
        let ev = sink.events();
        assert_eq!(ev.len(), 3, "live spans per subsequently fired fault");
        let kill = ev.iter().find(|e| e.name == "fault.kill").unwrap();
        assert!(kill.args.contains(&("node".into(), 0.0)));
        assert!(kill.args.contains(&("at_level".into(), 2.0)));
        let fetch = ev.iter().find(|e| e.name == "fault.fetchfail").unwrap();
        assert!(fetch.args.contains(&("map_task".into(), 4.0)));
        assert!(fetch.args.contains(&("after_maps".into(), 1.0)));

        // second attach is a no-op; nothing double-records
        clock.attach_trace(TraceCtx::root(TraceSink::new()));
        clock.begin_level(3); // nothing left to fire
        assert_eq!(sink.len(), 3);
    }

    #[test]
    fn metrics_registry_sees_the_counters() {
        let clock = FaultClock::new(FaultPlan::parse("kill:1@now;storeio:1@now").unwrap());
        let reg = MetricsRegistry::new();
        clock.register_metrics(&reg, "chaos").unwrap();
        clock.take_store_fault();
        let snap = reg.snapshot();
        assert_eq!(snap.counter("chaos.nodes_killed"), Some(1));
        assert_eq!(snap.counter("chaos.store_faults"), Some(1));
        assert_eq!(snap.counter("chaos.faults_injected"), Some(2));
    }
}

//! Antecedent-hash partitioning of one rule generation into S shards.
//!
//! [`ShardedRuleIndex::build`] splits one [`MiningResult`]'s rule set
//! deterministically: every rule lands on exactly one shard, keyed by an
//! FNV-1a hash of its antecedent. Because [`RuleIndex::recommend`]'s
//! answer is the first `k` *applying* rules in the deterministic global
//! order (confidence desc, antecedent, consequent), and "applies" is a
//! per-rule predicate, the global top-k is a subset of the union of
//! per-shard top-k candidate lists — so a scatter-gather merge
//! ([`ShardedRuleIndex::merge`]) that sorts the union by global rule id
//! and truncates to `k` is *provably* byte-identical to the single-index
//! path. `tests/fabric.rs` pins that differentially against
//! [`reference_recommend`].
//!
//! [`RuleIndex::recommend`]: crate::serve::index::RuleIndex::recommend
//! [`reference_recommend`]: crate::serve::index::reference_recommend

use std::collections::HashMap;

use crate::apriori::rules::{generate_rules, Rule};
use crate::apriori::{Itemset, MiningResult};
use crate::data::{is_subset, ItemId};

/// Same bound as the single `RuleIndex`: baskets up to this size use
/// indexed subset enumeration; larger ones fall back to a full shard
/// scan with identical output.
const MAX_INDEXED_BASKET: usize = 16;

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// The shard a rule with this antecedent lives on: FNV-1a over the
/// antecedent's little-endian item bytes, mod the shard count. Depends
/// only on the antecedent and `n_shards`, so the same rule always maps
/// to the same shard across rebuilds and generations.
pub fn shard_of(antecedent: &[ItemId], n_shards: usize) -> usize {
    assert!(n_shards >= 1, "shard_of: n_shards must be >= 1");
    let mut h = FNV_OFFSET;
    for &item in antecedent {
        for b in item.to_le_bytes() {
            h = (h ^ b as u64).wrapping_mul(FNV_PRIME);
        }
    }
    (h % n_shards as u64) as usize
}

/// Are sorted `a` and sorted `b` disjoint? (Local copy of the private
/// `serve::index` helper — the semantics must match exactly.)
fn is_disjoint(a: &[ItemId], b: &[ItemId]) -> bool {
    let (mut i, mut j) = (0, 0);
    while i < a.len() && j < b.len() {
        match a[i].cmp(&b[j]) {
            std::cmp::Ordering::Equal => return false,
            std::cmp::Ordering::Less => i += 1,
            std::cmp::Ordering::Greater => j += 1,
        }
    }
    true
}

/// Serving applicability: basket covers the antecedent and lacks every
/// consequent item.
fn applies(r: &Rule, basket: &[ItemId]) -> bool {
    is_subset(&r.antecedent, basket) && is_disjoint(&r.consequent, basket)
}

/// Sort + dedup a basket into canonical itemset form.
fn normalize_basket(basket: &[ItemId]) -> Itemset {
    let mut b = basket.to_vec();
    b.sort_unstable();
    b.dedup();
    b
}

/// The global rule order `generate_rules` emits. A strict total order:
/// (antecedent, consequent) pairs are unique across rules, so re-sorting
/// any concatenation of shard slices reproduces the exact global
/// sequence (confidence compares by `total_cmp`, bit-preserved by the
/// store codec).
pub fn global_rule_cmp(a: &Rule, b: &Rule) -> std::cmp::Ordering {
    b.confidence
        .total_cmp(&a.confidence)
        .then_with(|| a.antecedent.cmp(&b.antecedent))
        .then_with(|| a.consequent.cmp(&b.consequent))
}

/// One shard's slice of the rule set, each rule tagged with its *global*
/// id (its index in the full `generate_rules` order). Candidate lists
/// come back ascending by global id, which is what makes the
/// scatter-gather merge exact.
#[derive(Debug)]
pub struct RuleShard {
    /// (global id, rule), ascending by global id.
    entries: Vec<(u32, Rule)>,
    /// Antecedent -> indices into `entries` (ascending).
    by_antecedent: HashMap<Itemset, Vec<u32>>,
    /// Longest antecedent on this shard — the enumeration prune bound.
    max_antecedent_len: usize,
}

impl RuleShard {
    fn from_entries(entries: Vec<(u32, Rule)>) -> Self {
        debug_assert!(entries.windows(2).all(|w| w[0].0 < w[1].0));
        let mut by_antecedent: HashMap<Itemset, Vec<u32>> = HashMap::new();
        let mut max_antecedent_len = 0;
        for (i, (_, r)) in entries.iter().enumerate() {
            max_antecedent_len = max_antecedent_len.max(r.antecedent.len());
            by_antecedent.entry(r.antecedent.clone()).or_default().push(i as u32);
        }
        Self { entries, by_antecedent, max_antecedent_len }
    }

    pub fn n_rules(&self) -> usize {
        self.entries.len()
    }

    /// This shard's rules in global order (persistence path).
    pub fn rules(&self) -> impl Iterator<Item = &Rule> {
        self.entries.iter().map(|(_, r)| r)
    }

    /// The shard's answer to a scatter: the first `top_k` rules *on this
    /// shard* that apply to the basket, as (global id, rule) ascending by
    /// global id. Mirrors `RuleIndex::recommend` exactly (indexed subset
    /// enumeration with the same oversized-basket scan fallback), so the
    /// union over shards always contains the global top-k.
    pub fn candidates(&self, basket: &[ItemId], top_k: usize) -> Vec<(u32, Rule)> {
        let basket = normalize_basket(basket);
        if basket.is_empty() || top_k == 0 {
            return Vec::new();
        }
        if basket.len() > MAX_INDEXED_BASKET {
            return self
                .entries
                .iter()
                .filter(|(_, r)| applies(r, &basket))
                .take(top_k)
                .cloned()
                .collect();
        }
        let m = basket.len();
        let limit = 1u32 << m;
        let mut hits: Vec<u32> = Vec::new();
        for s in 1..=self.max_antecedent_len.min(m) {
            let mut mask = (1u32 << s) - 1;
            while mask < limit {
                let subset: Itemset = (0..m)
                    .filter(|&i| mask & (1 << i) != 0)
                    .map(|i| basket[i])
                    .collect();
                if let Some(ids) = self.by_antecedent.get(&subset) {
                    hits.extend_from_slice(ids);
                }
                // Gosper: next mask with the same popcount, ascending
                let c = mask & mask.wrapping_neg();
                let r = mask + c;
                mask = (((r ^ mask) >> 2) / c) | r;
            }
        }
        // entries are ascending by global id, so ascending entry indices
        // are ascending global ids
        hits.sort_unstable();
        hits.iter()
            .map(|&i| self.entries[i as usize].clone())
            .filter(|(_, r)| is_disjoint(&r.consequent, &basket))
            .take(top_k)
            .collect()
    }
}

/// One generation's rule set, partitioned into S shards by antecedent
/// hash. Immutable once built — generation flips swap the whole value
/// through a `SnapshotCell`, so a reader never sees a mixed cut.
#[derive(Debug)]
pub struct ShardedRuleIndex {
    shards: Vec<RuleShard>,
    /// |D| of the generation this cut was mined from.
    pub n_transactions: usize,
    /// The confidence floor the cut was built with.
    pub min_confidence: f64,
}

impl ShardedRuleIndex {
    /// Partition one mining generation into `n_shards` shards.
    pub fn build(result: &MiningResult, min_confidence: f64, n_shards: usize) -> Self {
        Self::from_rules(
            generate_rules(result, min_confidence),
            result.n_transactions,
            min_confidence,
            n_shards,
        )
    }

    /// Assemble a cut from rules already in the deterministic global
    /// order (the fabric store's load path re-sorts with
    /// [`global_rule_cmp`] before calling this).
    pub fn from_rules(
        rules: Vec<Rule>,
        n_transactions: usize,
        min_confidence: f64,
        n_shards: usize,
    ) -> Self {
        assert!(n_shards >= 1, "a cut needs at least one shard");
        debug_assert!(
            rules.windows(2).all(|w| global_rule_cmp(&w[0], &w[1]).is_lt()),
            "from_rules requires the deterministic global order"
        );
        let mut per_shard: Vec<Vec<(u32, Rule)>> = (0..n_shards).map(|_| Vec::new()).collect();
        for (id, rule) in rules.into_iter().enumerate() {
            let s = shard_of(&rule.antecedent, n_shards);
            per_shard[s].push((id as u32, rule));
        }
        Self {
            shards: per_shard.into_iter().map(RuleShard::from_entries).collect(),
            n_transactions,
            min_confidence,
        }
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    pub fn shard(&self, s: usize) -> &RuleShard {
        &self.shards[s]
    }

    /// Total rules across shards.
    pub fn n_rules(&self) -> usize {
        self.shards.iter().map(|s| s.n_rules()).sum()
    }

    /// Per-shard rule counts, as recorded in the fabric manifest.
    pub fn shard_rule_counts(&self) -> Vec<u64> {
        self.shards.iter().map(|s| s.n_rules() as u64).collect()
    }

    /// Gather: merge per-shard candidate lists into the global top-k.
    /// Sorting by global id restores the deterministic global order, and
    /// the first `k` of the union are exactly the single-index answer
    /// (each globally chosen rule is within its own shard's first `k`
    /// applying rules).
    pub fn merge(mut candidates: Vec<(u32, Rule)>, top_k: usize) -> Vec<Rule> {
        candidates.sort_unstable_by_key(|(id, _)| *id);
        candidates.truncate(top_k);
        candidates.into_iter().map(|(_, r)| r).collect()
    }

    /// Scatter-gather over all shards in-process (the router adds
    /// replica selection, hedging, and network costing on top of this).
    pub fn recommend(&self, basket: &[ItemId], top_k: usize) -> Vec<Rule> {
        let mut all = Vec::new();
        for shard in &self.shards {
            all.extend(shard.candidates(basket, top_k));
        }
        Self::merge(all, top_k)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apriori::classical::{tests::textbook_db, ClassicalApriori};
    use crate::apriori::AprioriConfig;
    use crate::serve::index::{reference_recommend, render_lines, RuleIndex};
    use crate::util::proptest::check;

    fn mined() -> MiningResult {
        ClassicalApriori::default().mine(
            &textbook_db(),
            &AprioriConfig { min_support: 2.0 / 9.0, max_k: 0 },
        )
    }

    #[test]
    fn every_rule_lands_on_exactly_one_deterministic_shard() {
        let result = mined();
        let rules = generate_rules(&result, 0.0);
        for n_shards in [1, 2, 3, 5, 8] {
            let cut = ShardedRuleIndex::build(&result, 0.0, n_shards);
            assert_eq!(cut.n_shards(), n_shards);
            assert_eq!(cut.n_rules(), rules.len(), "no rule lost or duplicated");
            for r in &rules {
                let s = shard_of(&r.antecedent, n_shards);
                assert_eq!(s, shard_of(&r.antecedent, n_shards), "deterministic");
                assert!(s < n_shards);
                assert!(
                    cut.shard(s).rules().any(|q| q == r),
                    "rule must live on its hash shard"
                );
            }
        }
    }

    #[test]
    fn single_shard_cut_equals_the_unsharded_index() {
        let result = mined();
        let idx = RuleIndex::build(&result, 0.0);
        let cut = ShardedRuleIndex::build(&result, 0.0, 1);
        for basket in [vec![0u32], vec![0, 1], vec![1, 2, 3], vec![0, 1, 2, 3, 4]] {
            assert_eq!(
                render_lines(&cut.recommend(&basket, 10)),
                render_lines(&idx.recommend(&basket, 10)),
            );
        }
    }

    #[test]
    fn scatter_gather_matches_reference_across_shard_counts() {
        let result = mined();
        let rules = generate_rules(&result, 0.0);
        for n_shards in [2, 3, 4, 7] {
            let cut = ShardedRuleIndex::build(&result, 0.0, n_shards);
            for basket in [
                vec![0u32],
                vec![0, 1],
                vec![0, 4],
                vec![1, 2, 3],
                vec![0, 1, 2, 3, 4],
                vec![7, 8],
                (0..20).collect::<Vec<_>>(), // oversized: scan fallback
            ] {
                for k in [1, 3, 100] {
                    assert_eq!(
                        render_lines(&cut.recommend(&basket, k)),
                        render_lines(&reference_recommend(&rules, &basket, k)),
                        "basket {basket:?} k={k} shards={n_shards}"
                    );
                }
            }
        }
    }

    #[test]
    fn prop_sharded_equals_reference_on_random_baskets() {
        let result = mined();
        let rules = generate_rules(&result, 0.0);
        let cuts: Vec<_> =
            (1..=5).map(|s| ShardedRuleIndex::build(&result, 0.0, s)).collect();
        check(
            "sharded scatter-gather equals the direct filter",
            0xFAB_51,
            300,
            |rng| {
                let len = rng.range_usize(0, 6);
                (0..len).map(|_| rng.gen_range(6) as ItemId).collect::<Vec<_>>()
            },
            |basket| {
                let direct = render_lines(&reference_recommend(&rules, basket, 5));
                for cut in &cuts {
                    let served = render_lines(&cut.recommend(basket, 5));
                    if served != direct {
                        return Err(format!(
                            "shards={}: served\n{served}\ndirect\n{direct}",
                            cut.n_shards()
                        ));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn from_rules_roundtrips_through_global_resort() {
        // The load path concatenates per-shard slices and re-sorts with
        // global_rule_cmp; the result must be the identical cut.
        let result = mined();
        let cut = ShardedRuleIndex::build(&result, 0.3, 3);
        let mut rules: Vec<Rule> = (0..cut.n_shards())
            .flat_map(|s| cut.shard(s).rules().cloned().collect::<Vec<_>>())
            .collect();
        rules.sort_unstable_by(global_rule_cmp);
        let reloaded =
            ShardedRuleIndex::from_rules(rules, cut.n_transactions, cut.min_confidence, 3);
        assert_eq!(reloaded.shard_rule_counts(), cut.shard_rule_counts());
        for basket in [vec![0u32, 1], vec![0, 1, 2, 3, 4]] {
            assert_eq!(
                render_lines(&reloaded.recommend(&basket, 10)),
                render_lines(&cut.recommend(&basket, 10)),
            );
        }
    }
}

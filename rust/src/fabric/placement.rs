//! Replica placement for the serving fabric: each shard's R replicas are
//! placed on [`ClusterConfig`] nodes through the existing rack-aware
//! `dfs` policy (first replica on the least-used node, second off-rack,
//! third back on the second's rack) — the same machinery the mining side
//! uses for HDFS blocks, now carrying rule shards. Placement also rides
//! the datanodes' byte accounting, so fabric storage shows up in
//! [`Dfs::utilization`]-style reporting.
//!
//! [`Dfs::utilization`]: crate::dfs::Dfs::utilization

use crate::cluster::{ClusterConfig, ClusterConfigError, NodeId};
use crate::dfs::{Dfs, DfsError};

/// Why a fabric layout could not be placed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PlacementError {
    /// The cluster cannot host the requested replication factor.
    Cluster(ClusterConfigError),
    /// The datanode layer refused a block (capacity/decommission).
    Dfs(DfsError),
}

impl std::fmt::Display for PlacementError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Self::Cluster(e) => write!(f, "fabric placement: {e}"),
            Self::Dfs(e) => write!(f, "fabric placement: {e}"),
        }
    }
}

impl std::error::Error for PlacementError {}

impl From<ClusterConfigError> for PlacementError {
    fn from(e: ClusterConfigError) -> Self {
        Self::Cluster(e)
    }
}

impl From<DfsError> for PlacementError {
    fn from(e: DfsError) -> Self {
        Self::Dfs(e)
    }
}

/// Where each shard's replicas live. Immutable once placed; the router
/// consults it on every scatter.
#[derive(Debug)]
pub struct FabricPlacement {
    /// Per shard: replica holders, primary first (dfs order).
    replicas: Vec<Vec<NodeId>>,
    /// Per shard: encoded bytes the placement accounted for.
    shard_bytes: Vec<u64>,
    /// The datanode state backing the placement (byte accounting).
    dfs: Dfs,
}

impl FabricPlacement {
    /// Place `shard_bytes.len()` shards with `replicas` copies each on
    /// the cluster's nodes, rack-aware. Validates the replication factor
    /// against the cluster (typed error, never a silent cap).
    pub fn place(
        cluster: &ClusterConfig,
        replicas: usize,
        shard_bytes: &[u64],
    ) -> Result<Self, PlacementError> {
        let cluster = cluster.clone().with_replication(replicas)?;
        let mut dfs = Dfs::new(&cluster);
        let mut placed = Vec::with_capacity(shard_bytes.len());
        for &bytes in shard_bytes {
            // even an empty shard occupies a placement slot
            let id = dfs.put_bytes(bytes.max(1))?;
            placed.push(dfs.locations(id)?.to_vec());
        }
        Ok(Self { replicas: placed, shard_bytes: shard_bytes.to_vec(), dfs })
    }

    pub fn n_shards(&self) -> usize {
        self.replicas.len()
    }

    /// Replica holders of one shard, primary first.
    pub fn replicas_of(&self, shard: usize) -> &[NodeId] {
        &self.replicas[shard]
    }

    /// Bytes the placement accounted for one shard.
    pub fn shard_bytes(&self, shard: usize) -> u64 {
        self.shard_bytes[shard]
    }

    /// Cluster-wide storage utilization including the fabric's shards.
    pub fn utilization(&self) -> f64 {
        self.dfs.utilization()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn replicas_land_on_distinct_nodes() {
        let cluster = ClusterConfig::fhssc(4);
        let p = FabricPlacement::place(&cluster, 2, &[1000, 2000, 3000, 4000]).unwrap();
        assert_eq!(p.n_shards(), 4);
        for s in 0..4 {
            let r = p.replicas_of(s);
            assert_eq!(r.len(), 2);
            assert_ne!(r[0], r[1], "shard {s} replicas must be on distinct nodes");
        }
        assert!(p.utilization() > 0.0);
        assert_eq!(p.shard_bytes(2), 3000);
    }

    #[test]
    fn rack_aware_spread_puts_second_replica_off_rack() {
        let cluster = ClusterConfig::fhssc(4).with_racks(2);
        let p = FabricPlacement::place(&cluster, 2, &[1 << 20, 1 << 20]).unwrap();
        for s in 0..2 {
            let r = p.replicas_of(s);
            assert_ne!(
                cluster.rack_of[r[0]], cluster.rack_of[r[1]],
                "shard {s}: second replica must cross racks"
            );
        }
    }

    #[test]
    fn impossible_replication_is_a_typed_error() {
        let cluster = ClusterConfig::fhssc(2);
        let err = FabricPlacement::place(&cluster, 3, &[100]).unwrap_err();
        assert_eq!(
            err,
            PlacementError::Cluster(ClusterConfigError::ReplicationExceedsNodes {
                replication: 3,
                nodes: 2,
            })
        );
        assert!(FabricPlacement::place(&cluster, 0, &[100]).is_err());
    }
}

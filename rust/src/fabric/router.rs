//! Scatter-gather query routing with replica failover and hedging.
//!
//! A [`QueryRouter`] answers one basket query by fanning it out to every
//! shard of the current cut (one replica each), merging the per-shard
//! candidate lists into the global top-k, and costing each leg through
//! the [`simnet`] flow model (`Network::transfer_secs`, the same model
//! the mining simulator uses for shuffle traffic). Per-replica fault
//! injection ([`QueryRouter::set_node_down`]) fails a shard's scatter
//! over to its surviving replica; a shard with no live replica is a
//! typed error, never a silently partial answer.
//!
//! Hedging: each shard's request nominally goes to the primary replica;
//! when a second live replica exists, a hedge fires after a p95-derived
//! delay (the shard's own observed p95 once it has enough samples, else
//! the configured `hedge_ms` floor) and the effective latency is
//! `min(primary, delay + secondary)` — the standard tail-at-scale
//! recipe. The cut itself is one `SnapshotCell` load per query, so every
//! shard answers from the same generation by construction.
//!
//! [`simnet`]: crate::simnet

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::apriori::rules::Rule;
use crate::cluster::{ClusterConfig, NodeId};
use crate::data::ItemId;
use crate::metrics::histogram::{HistogramSnapshot, LatencyHistogram};
use crate::metrics::Counter;
use crate::obs::{MetricsRegistry, RegistryError, TraceCtx};
use crate::serve::snapshot::SnapshotCell;
use crate::simnet::{Flow, Network};

use super::placement::FabricPlacement;
use super::shard::ShardedRuleIndex;

/// Hedge delays fall back to the configured floor until a shard has this
/// many latency samples to derive a p95 from.
const HEDGE_MIN_SAMPLES: u64 = 32;

/// Why a routed query failed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RouterError {
    /// Every replica of this shard is down — the cut cannot be answered
    /// completely, and a partial answer would break byte-identity.
    ShardUnavailable { shard: usize },
}

impl std::fmt::Display for RouterError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Self::ShardUnavailable { shard } => {
                write!(f, "shard {shard}: no live replica")
            }
        }
    }
}

impl std::error::Error for RouterError {}

/// One answered scatter-gather query.
#[derive(Debug)]
pub struct RoutedResponse {
    /// Generation of the cut every shard answered from.
    pub generation: u64,
    /// The merged global top-k, byte-identical to the single-index path.
    pub recommendations: Vec<Rule>,
    /// Simulated end-to-end latency: max over the per-shard legs.
    pub sim_latency_secs: f64,
}

/// Router counters + tail quantiles, for reports and the bench.
#[derive(Debug, Clone)]
pub struct RouterStats {
    pub queries: u64,
    /// Queries where at least one shard was served by a non-primary.
    pub failovers: u64,
    /// Shard legs whose primary exceeded the hedge delay (hedge sent).
    pub hedges_fired: u64,
    /// Fired hedges where the secondary beat the primary.
    pub hedge_wins: u64,
    /// Merged (end-to-end) latency quantiles.
    pub merged_p50_p95_p99: (Duration, Duration, Duration),
    /// All per-shard legs merged into one distribution
    /// (`HistogramSnapshot::merge` — no double counting).
    pub shard_p50_p95_p99: (Duration, Duration, Duration),
}

/// Scatter-gather front-end over one [`ShardedRuleIndex`] cut.
#[derive(Debug)]
pub struct QueryRouter {
    cut: Arc<SnapshotCell<ShardedRuleIndex>>,
    placement: FabricPlacement,
    net: Network,
    /// The node the router itself runs on (scatter source / gather sink).
    router_node: NodeId,
    /// Fault-injection flags, one per cluster node.
    node_down: Vec<AtomicBool>,
    /// Hedge-delay floor until a shard has a p95 of its own.
    hedge: Duration,
    /// Hedging off = pure primary-replica latency (the ablation's
    /// baseline arm). Failover is unaffected.
    hedging: bool,
    // Instruments live behind `Arc` so the same atomics can be
    // registered with a `MetricsRegistry` without an indirection on the
    // hot path (see [`QueryRouter::register_metrics`]).
    shard_latency: Vec<Arc<LatencyHistogram>>,
    merged_latency: Arc<LatencyHistogram>,
    queries: Arc<Counter>,
    failovers: Arc<Counter>,
    hedges_fired: Arc<Counter>,
    hedge_wins: Arc<Counter>,
}

impl QueryRouter {
    /// Build a router over a placed cut. `cluster` must be the same
    /// config the placement was made against (node count is asserted).
    pub fn new(
        cut: Arc<SnapshotCell<ShardedRuleIndex>>,
        placement: FabricPlacement,
        cluster: &ClusterConfig,
        hedge_ms: u64,
    ) -> Self {
        let net = Network::new(
            cluster.switch.clone(),
            cluster.nodes.iter().map(|n| n.nic_mbps).collect(),
        )
        .with_racks(cluster.rack_of.clone(), cluster.switch.backplane_mbps / 4.0);
        let n_nodes = cluster.n_nodes();
        let n_shards = placement.n_shards();
        Self {
            cut,
            placement,
            net,
            router_node: 0,
            node_down: (0..n_nodes).map(|_| AtomicBool::new(false)).collect(),
            hedge: Duration::from_millis(hedge_ms),
            hedging: true,
            shard_latency: (0..n_shards)
                .map(|_| Arc::new(LatencyHistogram::new()))
                .collect(),
            merged_latency: Arc::new(LatencyHistogram::new()),
            queries: Arc::new(Counter::new()),
            failovers: Arc::new(Counter::new()),
            hedges_fired: Arc::new(Counter::new()),
            hedge_wins: Arc::new(Counter::new()),
        }
    }

    /// Register the router's counters and latency histograms under
    /// `prefix` (conventionally `fabric`): the four scatter counters,
    /// the merged end-to-end latency, and one histogram per shard.
    pub fn register_metrics(
        &self,
        registry: &MetricsRegistry,
        prefix: &str,
    ) -> Result<(), RegistryError> {
        registry.register_counter(&format!("{prefix}.queries"), Arc::clone(&self.queries))?;
        registry.register_counter(&format!("{prefix}.failovers"), Arc::clone(&self.failovers))?;
        registry.register_counter(
            &format!("{prefix}.hedges_fired"),
            Arc::clone(&self.hedges_fired),
        )?;
        registry
            .register_counter(&format!("{prefix}.hedge_wins"), Arc::clone(&self.hedge_wins))?;
        registry
            .register_histogram(&format!("{prefix}.latency"), Arc::clone(&self.merged_latency))?;
        for (s, h) in self.shard_latency.iter().enumerate() {
            registry.register_histogram(&format!("{prefix}.shard.{s}.latency"), Arc::clone(h))?;
        }
        Ok(())
    }

    /// Disable hedging (ablation arm); failover still works.
    pub fn with_hedging(mut self, on: bool) -> Self {
        self.hedging = on;
        self
    }

    /// Simulate a node failure: every replica on `node` stops answering.
    pub fn set_node_down(&self, node: NodeId) {
        self.node_down[node].store(true, Ordering::Release);
    }

    /// Bring a node back.
    pub fn set_node_up(&self, node: NodeId) {
        self.node_down[node].store(false, Ordering::Release);
    }

    pub fn is_node_down(&self, node: NodeId) -> bool {
        self.node_down[node].load(Ordering::Acquire)
    }

    /// Which replicas of a shard are currently live, primary first.
    pub fn live_replicas(&self, shard: usize) -> Vec<NodeId> {
        self.placement
            .replicas_of(shard)
            .iter()
            .copied()
            .filter(|&n| !self.is_node_down(n))
            .collect()
    }

    /// The serving cut cell (the refresher publishes new generations
    /// through it; one load per query = a consistent cross-shard cut).
    pub fn cut(&self) -> &Arc<SnapshotCell<ShardedRuleIndex>> {
        &self.cut
    }

    pub fn generation(&self) -> u64 {
        self.cut.generation()
    }

    /// The shard→replica placement this router scatters over (the
    /// refresher consults it to skip down replicas when re-publishing).
    pub fn placement(&self) -> &FabricPlacement {
        &self.placement
    }

    /// One scatter leg: request out, candidates back, as simulated wire
    /// time. Requests and top-k replies are single-MTU-class payloads,
    /// so the small-payload fast path keeps the cost latency-dominated.
    fn leg_secs(&self, replica: NodeId, request_bytes: u64, reply_bytes: u64, fan: usize) -> f64 {
        let out = Flow { src: self.router_node, dst: replica, bytes: request_bytes };
        let back = Flow { src: replica, dst: self.router_node, bytes: reply_bytes };
        self.net.transfer_secs(&out, fan, 1, fan) + self.net.transfer_secs(&back, 1, fan, fan)
    }

    /// The delay after which a shard's hedge fires: its own observed p95
    /// once it has [`HEDGE_MIN_SAMPLES`], else the configured floor.
    fn hedge_delay(&self, shard: usize) -> Duration {
        let snap = self.shard_latency[shard].snapshot();
        if snap.count() >= HEDGE_MIN_SAMPLES {
            snap.quantile(0.95)
        } else {
            self.hedge
        }
    }

    /// Answer one basket query by scatter-gather over every shard of the
    /// current cut.
    pub fn route(&self, basket: &[ItemId], top_k: usize) -> Result<RoutedResponse, RouterError> {
        self.route_traced(basket, top_k, None)
    }

    /// [`route`](Self::route) with tracing: a `scatter` span (cat
    /// `serve`, wall clock) covers the fan-out, and every per-replica
    /// leg records an `rpc` span whose duration is the **simulated**
    /// wire time. When a hedge fires both the primary and the hedge leg
    /// are recorded — winner and loser — with `winner`/`hedged` flags.
    pub fn route_traced(
        &self,
        basket: &[ItemId],
        top_k: usize,
        ctx: Option<&TraceCtx>,
    ) -> Result<RoutedResponse, RouterError> {
        let (cut, generation) = self.cut.load_with_generation();
        let n_shards = cut.n_shards();
        assert_eq!(
            n_shards,
            self.placement.n_shards(),
            "cut and placement must agree on the shard count"
        );
        let scatter = ctx.map(|c| {
            let mut sp = c.span("serve", "scatter");
            sp.add("shards", n_shards as f64);
            sp.add("generation", generation as f64);
            sp
        });
        let scatter_ctx = scatter.as_ref().map(|sp| sp.ctx());
        let request_bytes = 16 + 4 * basket.len() as u64;
        let mut candidates = Vec::new();
        let mut merged_secs = 0.0f64;
        for s in 0..n_shards {
            let live = self.live_replicas(s);
            let Some(&primary) = live.first() else {
                return Err(RouterError::ShardUnavailable { shard: s });
            };
            if primary != self.placement.replicas_of(s)[0] {
                self.failovers.inc();
            }
            let shard_answer = cut.shard(s).candidates(basket, top_k);
            // a rule is ~an id + two small itemsets + three measures
            let reply_bytes = 16 + 56 * shard_answer.len() as u64;
            let rpc_span = |replica: NodeId, secs: f64, winner: bool, hedged: bool| {
                if let Some(c) = scatter_ctx.as_ref() {
                    let mut sp = c.span("rpc", format!("rpc.shard.{s}"));
                    sp.add("shard", s as f64);
                    sp.add("replica", replica as f64);
                    sp.add("bytes", (request_bytes + reply_bytes) as f64);
                    sp.add("winner", if winner { 1.0 } else { 0.0 });
                    sp.add("hedged", if hedged { 1.0 } else { 0.0 });
                    sp.set_dur_us((secs * 1e6) as u64);
                }
            };
            let primary_secs = self.leg_secs(primary, request_bytes, reply_bytes, n_shards);
            let leg_secs = match (self.hedging, live.get(1)) {
                (true, Some(&secondary)) => {
                    let delay = self.hedge_delay(s).as_secs_f64();
                    if primary_secs > delay {
                        self.hedges_fired.inc();
                        let hedged =
                            delay + self.leg_secs(secondary, request_bytes, reply_bytes, n_shards);
                        let secondary_won = hedged < primary_secs;
                        if secondary_won {
                            self.hedge_wins.inc();
                        }
                        rpc_span(primary, primary_secs, !secondary_won, true);
                        rpc_span(secondary, hedged, secondary_won, true);
                        primary_secs.min(hedged)
                    } else {
                        rpc_span(primary, primary_secs, true, false);
                        primary_secs
                    }
                }
                _ => {
                    rpc_span(primary, primary_secs, true, false);
                    primary_secs
                }
            };
            self.shard_latency[s].record(Duration::from_secs_f64(leg_secs));
            merged_secs = merged_secs.max(leg_secs);
            candidates.extend(shard_answer);
        }
        self.merged_latency.record(Duration::from_secs_f64(merged_secs));
        self.queries.inc();
        if let Some(mut sp) = scatter {
            sp.add("sim_latency_ms", merged_secs * 1e3);
        }
        Ok(RoutedResponse {
            generation,
            recommendations: ShardedRuleIndex::merge(candidates, top_k),
            sim_latency_secs: merged_secs,
        })
    }

    /// Counters + tails. Per-shard histograms aggregate through
    /// [`HistogramSnapshot::merge`], so every leg is counted exactly once
    /// in the fabric-level distribution.
    pub fn stats(&self) -> RouterStats {
        let mut legs: Option<HistogramSnapshot> = None;
        for h in &self.shard_latency {
            let s = h.snapshot();
            legs = Some(match legs {
                Some(acc) => acc.merge(&s),
                None => s,
            });
        }
        let shard_tails = legs
            .map(|s| s.p50_p95_p99())
            .unwrap_or((Duration::ZERO, Duration::ZERO, Duration::ZERO));
        RouterStats {
            queries: self.queries.get(),
            failovers: self.failovers.get(),
            hedges_fired: self.hedges_fired.get(),
            hedge_wins: self.hedge_wins.get(),
            merged_p50_p95_p99: self.merged_latency.snapshot().p50_p95_p99(),
            shard_p50_p95_p99: shard_tails,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apriori::classical::{tests::textbook_db, ClassicalApriori};
    use crate::apriori::rules::generate_rules;
    use crate::apriori::{AprioriConfig, MiningResult};
    use crate::serve::index::{reference_recommend, render_lines};

    fn mined() -> MiningResult {
        ClassicalApriori::default().mine(
            &textbook_db(),
            &AprioriConfig { min_support: 2.0 / 9.0, max_k: 0 },
        )
    }

    fn router(n_shards: usize, replicas: usize) -> QueryRouter {
        let result = mined();
        let cut = ShardedRuleIndex::build(&result, 0.0, n_shards);
        let cluster = ClusterConfig::fhssc(4);
        let bytes: Vec<u64> = cut.shard_rule_counts().iter().map(|&n| 56 * n + 16).collect();
        let placement = FabricPlacement::place(&cluster, replicas, &bytes).unwrap();
        QueryRouter::new(Arc::new(SnapshotCell::new(Arc::new(cut))), placement, &cluster, 5)
    }

    #[test]
    fn routed_answer_matches_reference_and_costs_wire_time() {
        let r = router(3, 2);
        let rules = generate_rules(&mined(), 0.0);
        for basket in [vec![0u32], vec![0, 1], vec![1, 2, 3], vec![0, 1, 2, 3, 4]] {
            let resp = r.route(&basket, 5).unwrap();
            assert_eq!(resp.generation, 0);
            assert_eq!(
                render_lines(&resp.recommendations),
                render_lines(&reference_recommend(&rules, &basket, 5)),
                "basket {basket:?}"
            );
            assert!(resp.sim_latency_secs > 0.0, "a scatter always pays wire time");
        }
        let stats = r.stats();
        assert_eq!(stats.queries, 4);
        assert_eq!(stats.failovers, 0);
        assert!(stats.merged_p50_p95_p99.1 >= stats.merged_p50_p95_p99.0);
    }

    #[test]
    fn killed_primary_fails_over_with_identical_answer() {
        let r = router(2, 2);
        let basket = vec![0u32, 1];
        let before = r.route(&basket, 5).unwrap();
        // kill every shard's primary that lives on some node
        let victim = r.placement.replicas_of(0)[0];
        r.set_node_down(victim);
        let after = r.route(&basket, 5).unwrap();
        assert_eq!(
            render_lines(&before.recommendations),
            render_lines(&after.recommendations),
            "failover must not change the answer"
        );
        assert!(r.stats().failovers >= 1, "the surviving replica served");
        r.set_node_up(victim);
        assert!(!r.is_node_down(victim));
    }

    #[test]
    fn all_replicas_down_is_a_typed_error() {
        let r = router(2, 2);
        for &n in r.placement.replicas_of(1) {
            r.set_node_down(n);
        }
        assert!(matches!(
            r.route(&[0, 1], 5),
            Err(RouterError::ShardUnavailable { .. })
        ));
    }

    #[test]
    fn generation_flip_swaps_the_whole_cut_atomically() {
        let r = router(2, 2);
        let result = mined();
        // stricter confidence = fewer rules: a distinguishable new cut
        let next = ShardedRuleIndex::build(&result, 0.99, 2);
        let g = r.cut().store(Arc::new(next));
        assert_eq!(g, 1);
        let resp = r.route(&[0, 1], 50).unwrap();
        assert_eq!(resp.generation, 1);
        let oracle = reference_recommend(&generate_rules(&result, 0.99), &[0, 1], 50);
        assert_eq!(render_lines(&resp.recommendations), render_lines(&oracle));
    }

    #[test]
    fn traced_route_records_scatter_and_one_rpc_per_shard() {
        use crate::obs::{TraceCtx, TraceSink};
        let r = router(3, 2);
        let registry = MetricsRegistry::new();
        r.register_metrics(&registry, "fabric").unwrap();
        let sink = TraceSink::new();
        let ctx = TraceCtx::root(Arc::clone(&sink));
        let traced = r.route_traced(&[0, 1], 5, Some(&ctx)).unwrap();
        let plain = r.route(&[0, 1], 5).unwrap();
        assert_eq!(
            render_lines(&traced.recommendations),
            render_lines(&plain.recommendations),
            "tracing must not change the answer"
        );
        let events = sink.events();
        let scatter = events.iter().find(|e| e.name == "scatter").unwrap();
        assert_eq!(scatter.cat, "serve");
        let rpcs: Vec<_> = events.iter().filter(|e| e.cat == "rpc").collect();
        // no hedges on a cold router (floor delay >> simulated legs)
        assert_eq!(rpcs.len(), 3);
        for rpc in &rpcs {
            assert_eq!(rpc.parent_id, scatter.span_id);
            assert!(rpc.dur_us > 0, "simulated wire time must be recorded");
        }
        // the registry sees the same counters the stats path reports
        let snap = registry.snapshot();
        assert_eq!(snap.counter("fabric.queries"), Some(r.stats().queries));
        assert_eq!(snap.counter("fabric.failovers"), Some(0));
    }

    #[test]
    fn hedging_cannot_worsen_latency() {
        let hedged = router(3, 2);
        let plain = router(3, 2).with_hedging(false);
        for _ in 0..50 {
            let a = hedged.route(&[0, 1, 2], 5).unwrap();
            let b = plain.route(&[0, 1, 2], 5).unwrap();
            assert!(a.sim_latency_secs <= b.sim_latency_secs + 1e-12);
            assert_eq!(
                render_lines(&a.recommendations),
                render_lines(&b.recommendations)
            );
        }
        assert_eq!(plain.stats().hedges_fired, 0);
    }
}

//! The serving fabric: sharded, replicated rule serving with
//! scatter-gather queries and failover.
//!
//! The paper's premise is that one machine cannot hold the workload — it
//! distributes *mining* across FHSSC/FHDSC nodes. This subsystem applies
//! the same partitioning principle to the *query* path (the ROADMAP's
//! "millions of users" item): instead of one `RuleIndex` per process,
//! the rule set is split by antecedent hash into S shards, each placed
//! with R replicas on [`ClusterConfig`] nodes through the rack-aware
//! `dfs` policy, and a basket query scatters to every shard and gathers
//! a provably byte-identical global top-k.
//!
//! * [`shard`] — [`ShardedRuleIndex`]: deterministic partitioning + the
//!   exact merge (per-shard candidates carry global rule ids).
//! * [`placement`] — [`FabricPlacement`]: replica placement with typed
//!   errors instead of silently under-replicating.
//! * [`router`] — [`QueryRouter`]: scatter-gather, per-replica fault
//!   injection with failover, hedged requests after a p95-derived
//!   delay, per-shard + merged latency histograms.
//! * [`publish`] — [`FabricStore`]: a two-phase (prepare shards, flip
//!   one manifest) crash-consistent publish, so readers never observe a
//!   mixed-generation cut.
//!
//! [`ClusterConfig`]: crate::cluster::ClusterConfig

pub mod placement;
pub mod publish;
pub mod router;
pub mod shard;

pub use placement::{FabricPlacement, PlacementError};
pub use publish::{FabricStore, FabricStoreError, PublishStep};
pub use router::{QueryRouter, RoutedResponse, RouterError, RouterStats};
pub use shard::{global_rule_cmp, shard_of, RuleShard, ShardedRuleIndex};

/// `[fabric]` section of an experiment config: the serving fabric's
/// shape. `shards == 0` (the default) turns the fabric off — the server
/// runs its classic single-index backend.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FabricConfig {
    /// Shard count (the antecedent-hash modulus); 0 disables the fabric.
    pub shards: usize,
    /// Replicas per shard.
    pub replicas: usize,
    /// Hedge-delay floor in milliseconds, used until a shard has enough
    /// samples to derive its own p95.
    pub hedge_ms: u64,
}

impl Default for FabricConfig {
    fn default() -> Self {
        Self { shards: 0, replicas: 2, hedge_ms: 5 }
    }
}

impl FabricConfig {
    /// Is the fabric backend requested?
    pub fn enabled(&self) -> bool {
        self.shards > 0
    }
}

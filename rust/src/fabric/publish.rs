//! Two-phase, crash-consistent publication of a sharded cut.
//!
//! Phase one (**prepare**) writes every shard's rules once per live
//! replica as `shard-<s>-r<r>-gen-<g>.shard` (a `TAG_RULE_INDEX` frame),
//! each through the store's write-temp → fsync → atomic-rename protocol.
//! Phase two (**commit**) flips a single `FABRIC` manifest
//! ([`FabricManifest`], its own frame type) the same way. Readers load
//! manifest-first; a torn or missing manifest degrades to the *newest
//! generation where every shard still has at least one intact replica
//! file* — by construction a complete cross-shard cut, never a mix of
//! generations. A replica that is down at refresh time is simply skipped
//! (the refresh fails over, it does not drop the generation); a shard
//! with *no* live replica fails the publish with a typed error.
//!
//! [`FabricManifest`]: crate::store::FabricManifest

use std::fs::{self, File};
use std::io::Write;
use std::path::{Path, PathBuf};

use crate::serve::index::RuleIndex;
use crate::store::codec::{
    decode_fabric_manifest, decode_rule_index, encode_fabric_manifest, encode_rule_index,
};
use crate::store::FabricManifest;

use super::shard::{global_rule_cmp, ShardedRuleIndex};

/// The cross-shard cut pointer, committed last.
const MANIFEST_NAME: &str = "FABRIC";

/// Commit boundaries a test hook can crash at (return `false` to stop
/// the publish right before the step executes — simulating a crash).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PublishStep {
    /// About to write one shard replica's temp file.
    ShardTempWritten { shard: usize, replica: usize },
    /// About to fsync that temp file.
    ShardSynced { shard: usize, replica: usize },
    /// About to rename it into place.
    ShardRenamed { shard: usize, replica: usize },
    /// About to write the manifest temp file.
    ManifestTempWritten,
    /// About to fsync it.
    ManifestSynced,
    /// About to rename it into place — the commit point.
    ManifestRenamed,
}

/// Why a fabric publish or load failed.
#[derive(Debug)]
pub enum FabricStoreError {
    /// Filesystem failure (path + os error text).
    Io { path: PathBuf, err: String },
    /// A shard had no live replica to prepare on — committing would
    /// publish a cut that cannot be read back.
    NoLiveReplica { shard: usize },
}

impl std::fmt::Display for FabricStoreError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Self::Io { path, err } => write!(f, "fabric store: {}: {err}", path.display()),
            Self::NoLiveReplica { shard } => {
                write!(f, "fabric store: shard {shard} has no live replica to prepare on")
            }
        }
    }
}

impl std::error::Error for FabricStoreError {}

/// The on-disk side of the serving fabric: one directory holding shard
/// replica files plus the `FABRIC` manifest.
#[derive(Debug)]
pub struct FabricStore {
    dir: PathBuf,
    n_shards: usize,
    replicas: usize,
    /// Generations whose shard files survive pruning (the degradation
    /// window for a torn manifest).
    retain: usize,
}

impl FabricStore {
    /// Open (creating if needed) a fabric store for a fixed shard layout.
    /// The layout is part of the store's identity: recovery needs to know
    /// how many shards a *complete* cut has even when the manifest is
    /// gone.
    pub fn open(
        dir: impl Into<PathBuf>,
        n_shards: usize,
        replicas: usize,
    ) -> Result<Self, FabricStoreError> {
        assert!(n_shards >= 1 && replicas >= 1);
        let dir = dir.into();
        fs::create_dir_all(&dir).map_err(|e| io_err(&dir, e))?;
        Ok(Self { dir, n_shards, replicas, retain: 2 })
    }

    /// Keep shard files of the newest `retain` generations (>= 1).
    pub fn with_retain(mut self, retain: usize) -> Self {
        self.retain = retain.max(1);
        self
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    fn shard_path(&self, shard: usize, replica: usize, generation: u64) -> PathBuf {
        self.dir.join(format!("shard-{shard}-r{replica}-gen-{generation}.shard"))
    }

    fn manifest_path(&self) -> PathBuf {
        self.dir.join(MANIFEST_NAME)
    }

    /// Publish a cut at `generation` with every replica up.
    pub fn publish(
        &self,
        cut: &ShardedRuleIndex,
        generation: u64,
    ) -> Result<FabricManifest, FabricStoreError> {
        self.publish_partial(cut, generation, &|_, _| true)
    }

    /// Publish with per-replica availability: `up(shard, replica)` false
    /// skips that replica's prepare (refresh failover). Every shard still
    /// needs at least one live replica.
    pub fn publish_partial(
        &self,
        cut: &ShardedRuleIndex,
        generation: u64,
        up: &dyn Fn(usize, usize) -> bool,
    ) -> Result<FabricManifest, FabricStoreError> {
        let done = self.publish_with_hook(cut, generation, up, &mut |_| true)?;
        Ok(done.expect("an all-true hook never aborts"))
    }

    /// Full-control publish: the hook sees every commit boundary *before*
    /// it executes and returns `false` to simulate a crash there
    /// (`Ok(None)`). Mirrors `SnapshotStore::publish_with_hook`.
    pub fn publish_with_hook(
        &self,
        cut: &ShardedRuleIndex,
        generation: u64,
        up: &dyn Fn(usize, usize) -> bool,
        hook: &mut dyn FnMut(PublishStep) -> bool,
    ) -> Result<Option<FabricManifest>, FabricStoreError> {
        assert_eq!(cut.n_shards(), self.n_shards, "cut must match the store layout");
        // phase one: prepare every live replica of every shard
        for s in 0..self.n_shards {
            let live: Vec<usize> = (0..self.replicas).filter(|&r| up(s, r)).collect();
            if live.is_empty() {
                return Err(FabricStoreError::NoLiveReplica { shard: s });
            }
            let rules: Vec<_> = cut.shard(s).rules().cloned().collect();
            let index = RuleIndex::from_parts(
                rules,
                Vec::new(),
                cut.n_transactions,
                cut.min_confidence,
            );
            let bytes = encode_rule_index(&index);
            for r in live {
                let steps = [
                    PublishStep::ShardTempWritten { shard: s, replica: r },
                    PublishStep::ShardSynced { shard: s, replica: r },
                    PublishStep::ShardRenamed { shard: s, replica: r },
                ];
                if !self.commit_file(&self.shard_path(s, r, generation), &bytes, steps, hook)? {
                    return Ok(None);
                }
            }
        }
        // phase two: flip the manifest — the single commit point
        let manifest = FabricManifest {
            generation,
            n_shards: self.n_shards,
            replicas: self.replicas,
            shard_rules: cut.shard_rule_counts(),
        };
        let steps = [
            PublishStep::ManifestTempWritten,
            PublishStep::ManifestSynced,
            PublishStep::ManifestRenamed,
        ];
        let bytes = encode_fabric_manifest(&manifest);
        if !self.commit_file(&self.manifest_path(), &bytes, steps, hook)? {
            return Ok(None);
        }
        self.prune(generation);
        Ok(Some(manifest))
    }

    /// write-temp → fsync → atomic rename, with a hook boundary before
    /// each step. Returns `Ok(false)` when the hook aborted (crash).
    fn commit_file(
        &self,
        path: &Path,
        bytes: &[u8],
        steps: [PublishStep; 3],
        hook: &mut dyn FnMut(PublishStep) -> bool,
    ) -> Result<bool, FabricStoreError> {
        let tmp = path.with_extension("tmp");
        if !hook(steps[0]) {
            return Ok(false);
        }
        let mut f = File::create(&tmp).map_err(|e| io_err(&tmp, e))?;
        f.write_all(bytes).map_err(|e| io_err(&tmp, e))?;
        if !hook(steps[1]) {
            return Ok(false);
        }
        f.sync_all().map_err(|e| io_err(&tmp, e))?;
        drop(f);
        if !hook(steps[2]) {
            return Ok(false);
        }
        fs::rename(&tmp, path).map_err(|e| io_err(path, e))?;
        // best-effort directory sync, like the snapshot store
        if let Ok(d) = File::open(&self.dir) {
            let _ = d.sync_all();
        }
        Ok(true)
    }

    /// Drop shard files older than the newest `retain` generations. The
    /// manifest's generation is always among the kept ones. Best-effort.
    fn prune(&self, live_generation: u64) {
        let mut gens = self.scan_generations();
        gens.retain(|&g| g <= live_generation);
        if gens.len() <= self.retain {
            return;
        }
        let cutoff = gens[gens.len() - self.retain];
        let Ok(entries) = fs::read_dir(&self.dir) else { return };
        for entry in entries.flatten() {
            if let Some((_, _, g)) = parse_shard_name(&entry.file_name().to_string_lossy()) {
                if g < cutoff {
                    let _ = fs::remove_file(entry.path());
                }
            }
        }
    }

    /// Every generation any shard file on disk mentions, ascending.
    pub fn scan_generations(&self) -> Vec<u64> {
        let mut gens = Vec::new();
        if let Ok(entries) = fs::read_dir(&self.dir) {
            for entry in entries.flatten() {
                if let Some((_, _, g)) = parse_shard_name(&entry.file_name().to_string_lossy()) {
                    gens.push(g);
                }
            }
        }
        gens.sort_unstable();
        gens.dedup();
        gens
    }

    /// The currently committed manifest, if it reads back intact.
    pub fn load_manifest(&self) -> Option<FabricManifest> {
        let bytes = fs::read(self.manifest_path()).ok()?;
        decode_fabric_manifest(&bytes).ok()
    }

    /// Load the newest complete cross-shard cut. Manifest-first: a torn
    /// manifest (or one whose cut lost a shard) degrades to the newest
    /// generation where *every* shard has >= 1 intact replica file — the
    /// loaded cut is always generation-consistent, never mixed.
    pub fn load_cut(&self) -> Option<(FabricManifest, ShardedRuleIndex)> {
        if let Some(m) = self.load_manifest() {
            if m.n_shards == self.n_shards && m.shard_rules.len() == self.n_shards {
                if let Some(cut) = self.try_load_generation(m.generation, Some(&m.shard_rules)) {
                    return Some((m, cut));
                }
            }
        }
        for &g in self.scan_generations().iter().rev() {
            if let Some(cut) = self.try_load_generation(g, None) {
                let manifest = FabricManifest {
                    generation: g,
                    n_shards: self.n_shards,
                    replicas: self.replicas,
                    shard_rules: cut.shard_rule_counts(),
                };
                return Some((manifest, cut));
            }
        }
        None
    }

    /// One generation, all shards, first intact replica each; `None`
    /// unless every shard decodes (a partial cut is not a cut).
    fn try_load_generation(
        &self,
        generation: u64,
        expect_rules: Option<&[u64]>,
    ) -> Option<ShardedRuleIndex> {
        let mut all_rules = Vec::new();
        let mut n_transactions = 0;
        let mut min_confidence = 0.0;
        for s in 0..self.n_shards {
            let mut found = None;
            for r in 0..self.replicas {
                let Ok(bytes) = fs::read(self.shard_path(s, r, generation)) else {
                    continue;
                };
                let Ok(index) = decode_rule_index(&bytes) else { continue };
                if let Some(expect) = expect_rules {
                    if index.n_rules() as u64 != expect[s] {
                        continue;
                    }
                }
                found = Some(index);
                break;
            }
            let index = found?;
            n_transactions = index.n_transactions;
            min_confidence = index.min_confidence;
            all_rules.extend(index.rules().iter().cloned());
        }
        all_rules.sort_unstable_by(global_rule_cmp);
        Some(ShardedRuleIndex::from_rules(
            all_rules,
            n_transactions,
            min_confidence,
            self.n_shards,
        ))
    }
}

fn io_err(path: &Path, e: std::io::Error) -> FabricStoreError {
    FabricStoreError::Io { path: path.to_path_buf(), err: e.to_string() }
}

/// Parse `shard-<s>-r<r>-gen-<g>.shard` (temp files don't match).
fn parse_shard_name(name: &str) -> Option<(usize, usize, u64)> {
    let rest = name.strip_prefix("shard-")?.strip_suffix(".shard")?;
    let mut parts = rest.split('-');
    let shard = parts.next()?.parse().ok()?;
    let replica = parts.next()?.strip_prefix('r')?.parse().ok()?;
    let generation = parts.next()?.strip_prefix("gen")?;
    // "gen" is its own dash-separated token; the number follows
    let generation = if generation.is_empty() { parts.next()? } else { generation };
    if parts.next().is_some() {
        return None;
    }
    Some((shard, replica, generation.parse().ok()?))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apriori::classical::{tests::textbook_db, ClassicalApriori};
    use crate::apriori::{AprioriConfig, MiningResult};
    use crate::serve::index::render_lines;
    use crate::util::tempdir::TempDir;

    fn mined() -> MiningResult {
        ClassicalApriori::default().mine(
            &textbook_db(),
            &AprioriConfig { min_support: 2.0 / 9.0, max_k: 0 },
        )
    }

    fn cut(conf: f64, shards: usize) -> ShardedRuleIndex {
        ShardedRuleIndex::build(&mined(), conf, shards)
    }

    #[test]
    fn parse_shard_names() {
        assert_eq!(parse_shard_name("shard-0-r1-gen-42.shard"), Some((0, 1, 42)));
        assert_eq!(parse_shard_name("shard-12-r0-gen-7.shard"), Some((12, 0, 7)));
        assert_eq!(parse_shard_name("shard-0-r1-gen-42.tmp"), None);
        assert_eq!(parse_shard_name("FABRIC"), None);
        assert_eq!(parse_shard_name("shard-x-r1-gen-42.shard"), None);
    }

    #[test]
    fn publish_then_load_roundtrips_the_cut() {
        let tmp = TempDir::new("fabric-roundtrip");
        let store = FabricStore::open(tmp.path(), 3, 2).unwrap();
        let c = cut(0.3, 3);
        let m = store.publish(&c, 5).unwrap();
        assert_eq!(m.generation, 5);
        assert_eq!(m.shard_rules, c.shard_rule_counts());
        let (back_m, back) = store.load_cut().unwrap();
        assert_eq!(back_m, m);
        assert_eq!(back.shard_rule_counts(), c.shard_rule_counts());
        for basket in [vec![0u32, 1], vec![0, 1, 2, 3, 4]] {
            assert_eq!(
                render_lines(&back.recommend(&basket, 10)),
                render_lines(&c.recommend(&basket, 10)),
            );
        }
    }

    #[test]
    fn down_replica_skipped_but_cut_still_commits() {
        let tmp = TempDir::new("fabric-failover");
        let store = FabricStore::open(tmp.path(), 2, 2).unwrap();
        let c = cut(0.3, 2);
        // replica 1 of every shard is down: refresh fails over, the
        // generation still publishes
        store.publish_partial(&c, 1, &|_, r| r == 0).unwrap();
        let (m, back) = store.load_cut().unwrap();
        assert_eq!(m.generation, 1);
        assert_eq!(back.n_rules(), c.n_rules());
        // but a shard with no live replica at all refuses to publish
        let err = store.publish_partial(&c, 2, &|s, _| s != 0).unwrap_err();
        assert!(matches!(err, FabricStoreError::NoLiveReplica { shard: 0 }));
        // the failed publish did not move the committed cut
        assert_eq!(store.load_cut().unwrap().0.generation, 1);
    }

    #[test]
    fn crash_at_every_boundary_leaves_previous_cut_readable() {
        let tmp = TempDir::new("fabric-crash");
        let store = FabricStore::open(tmp.path(), 2, 2).unwrap();
        let c1 = cut(0.3, 2);
        let c2 = cut(0.6, 2);
        store.publish(&c1, 1).unwrap();
        // crash before the i-th boundary of the gen-2 publish; before the
        // manifest rename the reader must still see gen 1, after it gen 2
        for crash_at in 0..100 {
            let mut step = 0;
            let mut renamed_manifest = false;
            let done = store
                .publish_with_hook(&c2, 2, &|_, _| true, &mut |s| {
                    if step == crash_at {
                        return false;
                    }
                    if s == PublishStep::ManifestRenamed {
                        renamed_manifest = true;
                    }
                    step += 1;
                    true
                })
                .unwrap();
            let (m, back) = store.load_cut().expect("a cut must always be readable");
            if done.is_some() || renamed_manifest {
                assert_eq!(m.generation, 2, "crash_at={crash_at}");
                assert_eq!(back.n_rules(), c2.n_rules());
                break; // committed; later crash points need a fresh dir
            }
            assert_eq!(m.generation, 1, "crash_at={crash_at}");
            assert_eq!(back.n_rules(), c1.n_rules());
            // clean up partial gen-2 files so the next iteration starts
            // from the same pre-publish state
            for e in fs::read_dir(tmp.path()).unwrap().flatten() {
                if let Some((_, _, 2)) = parse_shard_name(&e.file_name().to_string_lossy()) {
                    fs::remove_file(e.path()).unwrap();
                }
                if e.file_name().to_string_lossy().ends_with(".tmp") {
                    fs::remove_file(e.path()).unwrap();
                }
            }
        }
    }

    #[test]
    fn torn_manifest_degrades_to_newest_complete_cut() {
        let tmp = TempDir::new("fabric-torn");
        let store = FabricStore::open(tmp.path(), 2, 2).unwrap();
        let c1 = cut(0.3, 2);
        store.publish(&c1, 1).unwrap();
        store.publish(&c1, 2).unwrap();
        // tear the manifest mid-byte
        let mpath = store.manifest_path();
        let mut bytes = fs::read(&mpath).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x5A;
        fs::write(&mpath, &bytes).unwrap();
        assert!(store.load_manifest().is_none(), "tear must be detected");
        let (m, back) = store.load_cut().unwrap();
        assert_eq!(m.generation, 2, "degrades to the newest complete cut");
        assert_eq!(back.n_rules(), c1.n_rules());
    }

    #[test]
    fn partial_prepare_is_never_served_as_a_cut() {
        let tmp = TempDir::new("fabric-partial");
        let store = FabricStore::open(tmp.path(), 2, 2).unwrap();
        let c1 = cut(0.3, 2);
        store.publish(&c1, 1).unwrap();
        // a crashed prepare left gen 2 with only shard 0 on disk and no
        // manifest flip; then the manifest was lost entirely
        let c2 = cut(0.6, 2);
        store
            .publish_with_hook(&c2, 2, &|_, _| true, &mut |s| {
                !matches!(s, PublishStep::ShardTempWritten { shard: 1, .. })
            })
            .unwrap();
        fs::remove_file(store.manifest_path()).unwrap();
        let (m, back) = store.load_cut().unwrap();
        assert_eq!(m.generation, 1, "gen 2 is incomplete and must be skipped");
        assert_eq!(back.n_rules(), c1.n_rules());
    }

    #[test]
    fn pruning_keeps_the_retain_window() {
        let tmp = TempDir::new("fabric-prune");
        let store = FabricStore::open(tmp.path(), 2, 1).unwrap().with_retain(2);
        let c = cut(0.3, 2);
        for g in 1..=5 {
            store.publish(&c, g).unwrap();
        }
        assert_eq!(store.scan_generations(), vec![4, 5]);
        assert_eq!(store.load_cut().unwrap().0.generation, 5);
    }
}

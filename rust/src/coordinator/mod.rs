//! The leader: plans the level-wise Apriori loop as MapReduce jobs, routes
//! splits through the DFS, aggregates counts, and records everything the
//! benches need to replay the run against any simulated cluster (the
//! paper's fig 4/5 methodology).
//!
//! Responsibilities, mirroring the paper's Hadoop master:
//! * write the dataset into the DFS (block placement + replication);
//! * per level k: broadcast the candidate set, run the counting job,
//!   filter by min-support, generate the next level's candidates;
//! * collect [`JobStats`] and produce a [`WorkloadProfile`] — the per-level
//!   cost summary [`simulate`] uses to predict the same workload's makespan
//!   on a different cluster shape without re-mining.
//!
//! Besides the CLI and the benches, the serving layer's refresher
//! (`serve::refresh`) drives this same driver from a background thread:
//! each micro-batch re-mines the grown database through [`MrApriori::mine`]
//! (either schedule) while the previous snapshot keeps serving reads.
//!
//! Two execution modes share the loop:
//!
//! * **synchronous** (the paper's baseline): one counting job per level,
//!   run to completion before the next level is even planned — every level
//!   pays full job setup latency with an idle cluster between levels;
//! * **pipelined** ([`PipelineConfig`]): a job DAG. Look-ahead candidate
//!   sets are generated *optimistically* from the predecessor's candidate
//!   set (a superset of the exact `generate(F_k)`, by downward closure),
//!   so job k+1's map wave starts while job k's reduce wave is still
//!   running; exactness is restored by intersecting each job's
//!   (threshold-filtered) counts with the exact candidate set once the
//!   previous level's frequent itemsets resolve. With `batch_levels = 2`
//!   each job counts two adjacent levels in one shared scan
//!   ([`SupportEngine::count_batch`]), halving the number of dataset
//!   passes and job setups. Both modes emit byte-identical frequent
//!   itemsets (`tests/mr_invariants.rs` proves it property-style).

use std::sync::Arc;
use std::time::Instant;

use crate::apriori::mr::{CandidateCountApp, ItemCountApp};
use crate::apriori::{candidates, AprioriConfig, Itemset, LevelStats, MiningResult};
use crate::chaos::FaultClock;
use crate::cluster::ClusterConfig;
use crate::data::split::{plan_splits, Split};
use crate::data::TransactionDb;
use crate::dfs::{BlockId, Dfs, DfsError};
use crate::engine::{EngineKind, IndexCache, SupportEngine};
use crate::mapreduce::app::MapReduceApp;
use crate::mapreduce::{
    JobConfig, JobError, JobRunner, JobStats, SimJobSpec, SimMapTask, SimReport, Simulator,
};
use crate::obs::{MetricsRegistry, Span, TraceCtx};

#[derive(Debug)]
pub enum MineError {
    Dfs(DfsError),
    Job(JobError),
}

impl std::fmt::Display for MineError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Self::Dfs(e) => write!(f, "dfs: {e}"),
            Self::Job(e) => write!(f, "job: {e}"),
        }
    }
}

impl std::error::Error for MineError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Self::Dfs(e) => Some(e),
            Self::Job(e) => Some(e),
        }
    }
}

impl From<DfsError> for MineError {
    fn from(e: DfsError) -> Self {
        Self::Dfs(e)
    }
}

impl From<JobError> for MineError {
    fn from(e: JobError) -> Self {
        Self::Job(e)
    }
}

impl From<crate::mapreduce::AdhocJobError> for MineError {
    fn from(e: crate::mapreduce::AdhocJobError) -> Self {
        match e {
            crate::mapreduce::AdhocJobError::Dfs(e) => Self::Dfs(e),
            crate::mapreduce::AdhocJobError::Job(e) => Self::Job(e),
        }
    }
}

/// Pipelined-execution knobs. Disabled by default — the paper's baseline
/// is strictly synchronous, and every published figure replays that mode.
#[derive(Debug, Clone)]
pub struct PipelineConfig {
    /// Overlap successor map waves with predecessor reduce waves using
    /// optimistic (candidate-derived) look-ahead candidate sets.
    pub enabled: bool,
    /// Adjacent levels counted per job through the engines' shared-scan
    /// `count_batch` path: 1 = one level per job (classic), 2 = pairs of
    /// levels per job (half the jobs, half the dataset passes).
    pub batch_levels: usize,
    /// Give up on an optimistic candidate set when it exceeds this
    /// multiple of its parent set's size; the driver then waits for the
    /// exact frequent itemsets instead (degrading that level to the
    /// synchronous schedule) so speculative counting work stays bounded.
    pub max_blowup: f64,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        Self {
            enabled: false,
            batch_levels: 2,
            max_blowup: 8.0,
        }
    }
}

impl PipelineConfig {
    /// Fully-enabled preset (overlap + two-level batched scans).
    pub fn pipelined() -> Self {
        Self {
            enabled: true,
            ..Self::default()
        }
    }
}

/// Per-level cost summary — everything the simulator needs, nothing more.
#[derive(Debug, Clone)]
pub struct LevelProfile {
    pub k: usize,
    pub n_candidates: usize,
    pub n_frequent: usize,
    /// Map compute per transaction (work units).
    pub work_per_tx: f64,
    /// Shuffle bytes emitted per map task (post-combiner).
    pub shuffle_bytes_per_map: u64,
    /// Reduce compute (work units, total).
    pub reduce_work: f64,
}

/// A mined workload's replayable cost profile.
#[derive(Debug, Clone)]
pub struct WorkloadProfile {
    pub n_tx: usize,
    pub db_bytes: usize,
    pub levels: Vec<LevelProfile>,
}

/// One level's full count capture: *every* candidate the level counted,
/// with its exact support — the frequent ones meet the threshold, the
/// rest are the level's negative border. Zero-count candidates (never
/// emitted by any map task) are zero-filled, so `counted` always aligns
/// with the exact candidate list, sorted.
#[derive(Debug, Clone)]
pub struct LevelCapture {
    pub k: usize,
    pub counted: Vec<(Itemset, u64)>,
}

/// The per-level captures of one [`MrApriori::mine_captured`] run —
/// everything `incremental::MinedState` needs to seed FUP-style border
/// maintenance.
#[derive(Debug, Clone)]
pub struct MiningCapture {
    /// Item-universe width the level-1 capture spans (ids `0..n_items`).
    pub n_items: usize,
    /// Absolute threshold the frequent/border split used.
    pub threshold: u64,
    pub levels: Vec<LevelCapture>,
}

/// Everything one coordinated run produces.
#[derive(Debug)]
pub struct RunReport {
    pub result: MiningResult,
    /// JobStats per counting job `(first level covered, stats)` — a
    /// batched pipelined job covers more than one level.
    pub jobs: Vec<(usize, JobStats)>,
    pub profile: WorkloadProfile,
    pub wall_secs: f64,
    /// Fraction of DFS blocks placed past node capacity.
    pub spill_fraction: f64,
}

/// The Map/Reduce Apriori driver.
pub struct MrApriori {
    pub cluster: ClusterConfig,
    pub apriori: AprioriConfig,
    pub job: JobConfig,
    pub pipeline: PipelineConfig,
    /// Transactions per map split (HDFS block granularity).
    pub split_tx: usize,
    engine: Box<dyn SupportEngine>,
    /// Split-keyed resident vertical-index cache, shared by every job the
    /// driver schedules (level loops, delta Δ-scans, exact recounts). A
    /// generation bump per dataset view keeps stale indexes unservable.
    cache: IndexCache,
    /// When set, every mine opens a root `mine` span under this context;
    /// level jobs and their map/reduce tasks nest beneath it.
    trace: Option<TraceCtx>,
    /// When set, per-job metrics (`mr.job.{k}.map_ms`, `mr.jobs`,
    /// `mr.shuffle.records`, ...) and the resident index-cache counters
    /// are published here.
    registry: Option<Arc<MetricsRegistry>>,
    /// Shared fault clock. When set, every job the driver schedules
    /// (level loops, the pipelined DAG, delta jobs, exact recounts)
    /// injects the plan's faults, and the level loop recovers from node
    /// loss by reaping dead nodes from the DFS and resuming from the
    /// last completed level instead of restarting the mine.
    chaos: Option<Arc<FaultClock>>,
}

/// What a pipelined reduce lane hands back.
type ReduceOutcome = Result<(Vec<(Itemset, u64)>, JobStats), JobError>;

impl MrApriori {
    /// Driver with the default (vertical TID-bitset) engine.
    pub fn new(cluster: ClusterConfig, apriori: AprioriConfig) -> Self {
        Self {
            cluster,
            apriori,
            job: JobConfig { n_reducers: 3, ..Default::default() },
            pipeline: PipelineConfig::default(),
            split_tx: 1000,
            // Vertical is the measured-fastest CPU engine (EXPERIMENTS.md
            // §Perf; BENCH_engines.json asserts the win per CI run), and
            // every engine is byte-identical on every mining path. The
            // paper-faithful horizontal matchers stay one `--engine trie`
            // / `with_engine` away.
            engine: crate::engine::build_engine(EngineKind::Vertical, None),
            cache: IndexCache::new(),
            trace: None,
            registry: None,
            chaos: None,
        }
    }

    pub fn with_engine(mut self, engine: Box<dyn SupportEngine>) -> Self {
        self.engine = engine;
        self
    }

    pub fn with_job(mut self, job: JobConfig) -> Self {
        self.job = job;
        self
    }

    pub fn with_pipeline(mut self, pipeline: PipelineConfig) -> Self {
        assert!(
            (1..=2).contains(&pipeline.batch_levels),
            "batch_levels must be 1 or 2"
        );
        assert!(
            pipeline.max_blowup.is_finite() && pipeline.max_blowup >= 0.0,
            "max_blowup must be a finite value >= 0"
        );
        self.pipeline = pipeline;
        self
    }

    pub fn with_split_tx(mut self, split_tx: usize) -> Self {
        assert!(split_tx > 0);
        self.split_tx = split_tx;
        self
    }

    /// Attach (or detach) a tracing context. `None` — the default — is
    /// the zero-cost off path: no spans are created anywhere.
    pub fn with_trace(mut self, trace: Option<TraceCtx>) -> Self {
        self.trace = trace;
        self
    }

    /// Attach (or detach) a shared fault clock. `None` — the default —
    /// is the zero-cost off path: no fault checks anywhere on the hot
    /// loops beyond one `Option` test.
    pub fn with_chaos(mut self, chaos: Option<Arc<FaultClock>>) -> Self {
        self.chaos = chaos;
        self
    }

    /// The attached fault clock, if any. The incremental delta jobs and
    /// the refresher read it so faults span every schedule the driver
    /// owns, not just the level loop.
    pub fn chaos(&self) -> Option<&Arc<FaultClock>> {
        self.chaos.as_ref()
    }

    /// Publish this driver's metrics (per-job timings/counters plus the
    /// resident index-cache hit/miss counters) to `registry`.
    pub fn with_registry(mut self, registry: Arc<MetricsRegistry>) -> Self {
        self.cache
            .register_metrics(&registry, "engine.cache")
            .expect("engine.cache metrics already registered");
        self.registry = Some(registry);
        self
    }

    /// Publish one finished counting job's headline numbers: last-value
    /// gauges keyed per first-level-covered, cumulative run counters.
    fn record_job_metrics(&self, k: usize, stats: &JobStats) {
        let Some(reg) = &self.registry else { return };
        reg.gauge(&format!("mr.job.{k}.map_ms")).set(stats.map_secs * 1e3);
        reg.gauge(&format!("mr.job.{k}.reduce_ms"))
            .set(stats.reduce_secs * 1e3);
        reg.counter("mr.jobs").inc();
        reg.counter("mr.shuffle.records")
            .add(stats.shuffle_records as u64);
        reg.counter("mr.output.records")
            .add(stats.output_records as u64);
    }

    /// Sample one level's workload statistics — the calibration inputs
    /// the `perfmodel/` autotuner consumes: a 1 µs `profile.level.{k}`
    /// span under `level_ctx` (cat `profile`, so `repro analyze` can
    /// collect it per level) plus `profile.level.{k}.*` gauges.
    /// `n_prev_frequent` is the predecessor level's frequent-set size
    /// (1 for level 1 — the empty itemset), making `candidate_fanout`
    /// the blowup this level paid.
    fn sample_workload(
        &self,
        level_ctx: Option<TraceCtx>,
        k: usize,
        shape: Option<&DbShape>,
        n_candidates: usize,
        n_prev_frequent: usize,
    ) {
        let Some(shape) = shape else { return };
        let fanout = n_candidates as f64 / n_prev_frequent.max(1) as f64;
        if let Some(ctx) = level_ctx {
            let mut s = ctx.span("profile", format!("profile.level.{k}"));
            s.set_dur_us(1);
            s.add("density", shape.density);
            s.add("item_skew", shape.item_skew);
            s.add("avg_basket_width", shape.avg_basket_width);
            s.add("candidate_fanout", fanout);
        }
        if let Some(reg) = &self.registry {
            reg.gauge(&format!("profile.level.{k}.density"))
                .set(shape.density);
            reg.gauge(&format!("profile.level.{k}.item_skew"))
                .set(shape.item_skew);
            reg.gauge(&format!("profile.level.{k}.avg_basket_width"))
                .set(shape.avg_basket_width);
            reg.gauge(&format!("profile.level.{k}.candidate_fanout"))
                .set(fanout);
        }
    }

    /// The counting engine map tasks run (the incremental delta jobs
    /// reuse it so the delta path counts exactly like the batch path).
    pub fn engine(&self) -> &dyn SupportEngine {
        self.engine.as_ref()
    }

    /// Cumulative resident-index-cache telemetry (hits, misses, bytes).
    /// The serve log reports per-refresh-cycle deltas of these totals.
    pub fn cache_stats(&self) -> crate::engine::CacheStats {
        self.cache.stats()
    }

    /// The driver's resident index cache — the incremental delta job
    /// attaches it to its wrapped counting app under a fresh generation.
    pub(crate) fn index_cache(&self) -> &IndexCache {
        &self.cache
    }

    /// Attach the resident cache to a counting app, but only when the
    /// active engine is the vertical one: cached [`VerticalIndex`] builds
    /// are exactly what `count_with_index` consumes, while the horizontal
    /// engines never look at them and would just pay the build.
    ///
    /// [`VerticalIndex`]: crate::engine::VerticalIndex
    fn attach_cache<'e>(
        &'e self,
        app: CandidateCountApp<'e>,
        generation: u64,
    ) -> CandidateCountApp<'e> {
        if self.engine.name() == "vertical" {
            app.with_cache(&self.cache, generation)
        } else {
            app
        }
    }

    /// Run one level's counting job with node-loss recovery: fire the
    /// fault plan's level-boundary events, reap already-dead nodes from
    /// the DFS (re-replicating their blocks onto survivors, namenode
    /// style), build a fresh runner over the updated placement, and —
    /// when the job strands mid-run because its nodes died under it —
    /// retry the level against the survivors. Levels already mined stand
    /// untouched, so a recovered mine resumes from the last completed
    /// level instead of restarting. Bounded: after `LEVEL_RETRIES`
    /// stranded attempts (or with no live node left) the error surfaces.
    fn run_level_job<A: MapReduceApp>(
        &self,
        k: usize,
        app: &A,
        db: &TransactionDb,
        splits: &[Split],
        dfs: &mut Dfs,
        blocks: &[BlockId],
        trace: Option<TraceCtx>,
    ) -> Result<(Vec<(A::K, A::V)>, JobStats), MineError> {
        const LEVEL_RETRIES: usize = 2;
        let mut tries = 0usize;
        loop {
            if let Some(clock) = &self.chaos {
                clock.begin_level(k);
                dfs.reap_dead_nodes(&clock.dead_nodes());
            }
            let mut runner =
                JobRunner::new(&self.cluster, dfs, blocks).with_chaos(self.chaos.clone());
            runner.trace = trace.clone();
            match runner.run(app, db, splits, &self.job) {
                Err(JobError::NodesLost { .. })
                    if tries < LEVEL_RETRIES
                        && self
                            .chaos
                            .as_ref()
                            .is_some_and(|c| c.dead_nodes().len() < self.cluster.n_nodes()) =>
                {
                    // The heartbeat noticed the loss after the job
                    // stranded: reap at the loop top and resume this
                    // level on the survivors.
                    tries += 1;
                }
                other => return Ok(other?),
            }
        }
    }

    /// Mine `db`: real multi-threaded MapReduce execution, synchronous or
    /// pipelined per [`PipelineConfig`]. Both modes produce identical
    /// frequent itemsets.
    pub fn mine(&self, db: &TransactionDb) -> Result<RunReport, MineError> {
        if self.pipeline.enabled {
            self.mine_pipelined(db)
        } else {
            self.mine_sync(db)
        }
    }

    /// Synchronous mine that additionally captures every level's full
    /// count table (frequent **and** negative border, zero-filled) — the
    /// seed state for the incremental subsystem. The mining result is
    /// byte-identical to [`mine`](Self::mine) (both run the same
    /// [`Self::mine_level_loop`]); only the job shuffle carries the
    /// extra below-threshold records, so the captured run's
    /// `WorkloadProfile` is not comparable to a baseline profile.
    pub fn mine_captured(
        &self,
        db: &TransactionDb,
    ) -> Result<(RunReport, MiningCapture), MineError> {
        let (report, capture) = self.mine_level_loop(db, true)?;
        Ok((report, capture.expect("capture mode returns a capture")))
    }

    /// Targeted scan: exact supports for an arbitrary (possibly
    /// mixed-length, possibly duplicated) itemset list over `db`, as one
    /// unfiltered counting job through the engine's shared-scan path.
    /// Counts align with the input order; itemsets no transaction
    /// contains come back 0. One-shot wrapper over [`ExactCounter`] —
    /// callers issuing several scans against the same database (the
    /// incremental frontier walk) should hold an `ExactCounter` instead
    /// so splits are planned and blocks placed once.
    pub fn count_exact(
        &self,
        db: &TransactionDb,
        itemsets: &[Itemset],
    ) -> Result<Vec<u64>, MineError> {
        if itemsets.is_empty() || db.is_empty() {
            return Ok(vec![0; itemsets.len()]);
        }
        let mut counter = ExactCounter::new(self, db)?;
        counter.count(db, itemsets)
    }

    /// The paper's baseline: run job k to completion, then plan job k+1.
    fn mine_sync(&self, db: &TransactionDb) -> Result<RunReport, MineError> {
        self.mine_level_loop(db, false).map(|(report, _)| report)
    }

    /// The synchronous level loop behind [`Self::mine_sync`] and
    /// [`Self::mine_captured`]. With `capture` set, every counting job
    /// keeps below-threshold reduce output (`capture_all`), the
    /// frequent filter moves here, and the zero-filled per-level count
    /// tables come back as a [`MiningCapture`]; the mining result is
    /// identical either way.
    fn mine_level_loop(
        &self,
        db: &TransactionDb,
        capture: bool,
    ) -> Result<(RunReport, Option<MiningCapture>), MineError> {
        let t0 = Instant::now();
        let threshold = self.apriori.threshold(db.len());
        let splits = plan_splits(db, self.split_tx);
        let mut dfs = Dfs::new(&self.cluster);
        if let Some(clock) = &self.chaos {
            // Nodes the plan killed before the mine even started never
            // receive block placements.
            dfs.reap_dead_nodes(&clock.dead_nodes());
        }
        let blocks = dfs.write_splits(&splits)?;
        let mine_span = self.trace.as_ref().map(|ctx| mine_span(ctx, db, threshold, false));
        let mine_ctx = mine_span.as_ref().map(|s| s.ctx());
        // One dataset view per mine: every level job (and its speculative
        // twins) reuses the same per-split index builds.
        let cache_gen = self.cache.begin_generation();
        // Workload shape is sampled once per mine and reused by every
        // level's profile span; the extra dataset pass is skipped
        // entirely when nothing is observing.
        let shape = (self.trace.is_some() || self.registry.is_some()).then(|| db_shape(db));

        let mut result = MiningResult {
            n_transactions: db.len(),
            ..Default::default()
        };
        let mut jobs = Vec::new();
        let mut profiles = Vec::new();
        let mut captures = Vec::new();

        // ---- level 1 ----
        let app = ItemCountApp { threshold, capture_all: capture };
        let span = mine_ctx.as_ref().map(|c| level_span(c, 1, db.n_items));
        let lt0 = Instant::now();
        let (out, stats) = self.run_level_job(
            1,
            &app,
            db,
            &splits,
            &mut dfs,
            &blocks,
            span.as_ref().map(|s| s.ctx()),
        )?;
        let f1 = if capture {
            let counted = zero_fill(candidates::unit_candidates(db.n_items), &out);
            let f1: Vec<(Itemset, u64)> = counted
                .iter()
                .filter(|(_, s)| *s >= threshold)
                .cloned()
                .collect();
            captures.push(LevelCapture { k: 1, counted });
            f1
        } else {
            out
        };
        self.sample_workload(span.as_ref().map(|s| s.ctx()), 1, shape.as_ref(), db.n_items, 1);
        close_level_span(span, f1.len(), &stats);
        push_level(
            &mut result,
            &mut profiles,
            1,
            db.n_items,
            &f1,
            &stats,
            app.map_cost_hint(avg_split(&splits)),
            app.record_bytes_hint(),
            lt0.elapsed().as_secs_f64(),
        );
        jobs.push((1, stats));
        let mut frequent_prev: Vec<Itemset> = f1.iter().map(|(is, _)| is.clone()).collect();
        result.frequent.extend(f1);

        // ---- levels k >= 2 ----
        let mut k = 2usize;
        while !frequent_prev.is_empty() && self.apriori.level_allowed(k) {
            let cands = candidates::generate(&frequent_prev);
            if cands.is_empty() {
                break;
            }
            let n_cands = cands.len();
            let mut app =
                CandidateCountApp::new(cands.clone(), self.engine.as_ref(), db.n_items, threshold);
            app.capture_all = capture;
            let app = self.attach_cache(app, cache_gen);
            let span = mine_ctx.as_ref().map(|c| level_span(c, k, n_cands));
            let lt0 = Instant::now();
            let (out, stats) = self.run_level_job(
                k,
                &app,
                db,
                &splits,
                &mut dfs,
                &blocks,
                span.as_ref().map(|s| s.ctx()),
            )?;
            let fk = if capture {
                let counted = zero_fill(cands, &out);
                let fk: Vec<(Itemset, u64)> = counted
                    .iter()
                    .filter(|(_, s)| *s >= threshold)
                    .cloned()
                    .collect();
                captures.push(LevelCapture { k, counted });
                fk
            } else {
                out
            };
            self.sample_workload(
                span.as_ref().map(|s| s.ctx()),
                k,
                shape.as_ref(),
                n_cands,
                frequent_prev.len(),
            );
            close_level_span(span, fk.len(), &stats);
            push_level(
                &mut result,
                &mut profiles,
                k,
                n_cands,
                &fk,
                &stats,
                app.map_cost_hint(avg_split(&splits)),
                app.record_bytes_hint(),
                lt0.elapsed().as_secs_f64(),
            );
            jobs.push((k, stats));
            frequent_prev = fk.iter().map(|(is, _)| is.clone()).collect();
            result.frequent.extend(fk);
            k += 1;
        }
        result.normalize();
        if let Some(mut s) = mine_span {
            s.add("levels", result.levels.len() as f64);
        }
        for (k, stats) in &jobs {
            self.record_job_metrics(*k, stats);
        }

        // Charge the cache's resident index bytes to the datanode fleet
        // (like `dfs::BlockStore` checkpoint blocks): residency must show
        // up in spill accounting instead of being free memory.
        let cache_bytes = self.cache.resident_bytes();
        if cache_bytes > 0 {
            dfs.put_bytes(cache_bytes as u64)?;
        }

        let report = RunReport {
            result,
            jobs,
            profile: WorkloadProfile {
                n_tx: db.len(),
                db_bytes: db.approx_bytes(),
                levels: profiles,
            },
            wall_secs: t0.elapsed().as_secs_f64(),
            spill_fraction: dfs.spill_fraction(),
        };
        let capture_out = capture.then(|| MiningCapture {
            n_items: db.n_items,
            threshold,
            levels: captures,
        });
        Ok((report, capture_out))
    }

    /// The pipelined job DAG.
    ///
    /// Level 1 runs synchronously (everything depends on F1). From level 2
    /// on, each counting job's candidate set is generated from the
    /// *predecessor job's candidate set* — a superset of the exact
    /// `generate(F_prev)` by downward closure — so the job's map wave is
    /// schedulable the moment the predecessor's map wave drains, and it
    /// overlaps the predecessor's reduce wave, which runs on a spare lane.
    /// When a job's reduce output lands, its counts are intersected with
    /// the exact candidate set (known by then) to recover exactly the
    /// synchronous driver's frequent itemsets and supports.
    fn mine_pipelined(&self, db: &TransactionDb) -> Result<RunReport, MineError> {
        let t0 = Instant::now();
        let threshold = self.apriori.threshold(db.len());
        let splits = plan_splits(db, self.split_tx);
        let avg_split_tx = avg_split(&splits);
        let mut dfs = Dfs::new(&self.cluster);
        if let Some(clock) = &self.chaos {
            // Pipelined jobs overlap, so the DFS cannot be reaped between
            // levels (the whole DAG borrows one placement). Reap the
            // plan's pre-mine kills here; nodes lost mid-DAG are handled
            // by the runner alone — workers on dead nodes exit and their
            // tasks requeue to survivors (heartbeat-lag semantics), with
            // namenode re-replication deferred to the next mine.
            dfs.reap_dead_nodes(&clock.dead_nodes());
        }
        let blocks = dfs.write_splits(&splits)?;
        let mine_span = self.trace.as_ref().map(|ctx| mine_span(ctx, db, threshold, true));
        let mut runner =
            JobRunner::new(&self.cluster, &dfs, &blocks).with_chaos(self.chaos.clone());
        // Levels overlap in the job DAG, so task spans attach directly to
        // the mine root instead of per-level spans.
        runner.trace = mine_span.as_ref().map(|s| s.ctx());
        let runner = &runner;
        // One dataset view for the whole job DAG: overlapping map waves of
        // successive jobs hit the same per-split index builds.
        let cache_gen = self.cache.begin_generation();
        // Profile samples attach straight to the mine root (like the task
        // spans): the job DAG has no per-level spans.
        let mine_ctx = mine_span.as_ref().map(|s| s.ctx());
        let shape = (self.trace.is_some() || self.registry.is_some()).then(|| db_shape(db));

        let mut result = MiningResult {
            n_transactions: db.len(),
            ..Default::default()
        };
        let mut jobs: Vec<(usize, JobStats)> = Vec::new();
        let mut profiles: Vec<LevelProfile> = Vec::new();

        // ---- level 1 (synchronous root of the DAG) ----
        let app = ItemCountApp::new(threshold);
        let lt0 = Instant::now();
        let (f1, stats) = runner.run(&app, db, &splits, &self.job)?;
        push_level(
            &mut result,
            &mut profiles,
            1,
            db.n_items,
            &f1,
            &stats,
            app.map_cost_hint(avg_split_tx),
            app.record_bytes_hint(),
            lt0.elapsed().as_secs_f64(),
        );
        jobs.push((1, stats));
        self.sample_workload(mine_ctx.clone(), 1, shape.as_ref(), db.n_items, 1);
        let mut freq_by_level: Vec<Vec<Itemset>> = vec![Vec::new(), Vec::new()];
        freq_by_level[1] = f1.iter().map(|(is, _)| is.clone()).collect();
        result.frequent.extend(f1);

        // Single source of truth for the profile's shuffle-record size:
        // the same hint the synchronous path reads off its per-level apps.
        let record_bytes =
            CandidateCountApp::new(Vec::new(), self.engine.as_ref(), db.n_items, threshold)
                .record_bytes_hint();
        let splits_ref: &[Split] = &splits;
        let outcome: Result<(), MineError> = std::thread::scope(|scope| {
            // The in-flight predecessor: (first level, counted groups,
            // reduce lane handle). At most one job's reduce is pending.
            let mut pending: Option<(
                usize,
                Vec<Vec<Itemset>>,
                std::thread::ScopedJoinHandle<'_, ReduceOutcome>,
            )> = None;
            let mut k = 2usize;
            let mut chain_dead = false;

            while !chain_dead && self.apriori.level_allowed(k) {
                if let Some(clock) = &self.chaos {
                    // Fire level-boundary faults as the DAG reaches each
                    // level; the runner's own checks see the deaths.
                    clock.begin_level(k);
                }
                // -- candidate groups for the job starting at level k --
                let mut base: Vec<Itemset> = match &pending {
                    Some((_, prev_groups, _)) => {
                        candidates::generate(prev_groups.last().expect("job has groups"))
                    }
                    None => candidates::generate(&freq_by_level[k - 1]),
                };
                let parent_len = pending
                    .as_ref()
                    .map(|(_, groups, _)| groups.last().expect("job has groups").len().max(1));
                if let Some(parent) = parent_len {
                    if base.len() as f64 > self.pipeline.max_blowup * parent as f64 {
                        // Optimism exploded: wait for the exact frequent
                        // sets (synchronous schedule for this level).
                        let (bk, groups, handle) = pending.take().expect("checked above");
                        let (out, stats) = handle.join().expect("reduce lane")?;
                        chain_dead = resolve_job(
                            bk,
                            &groups,
                            out,
                            stats,
                            avg_split_tx,
                            record_bytes,
                            &mut result,
                            &mut profiles,
                            &mut jobs,
                            &mut freq_by_level,
                        );
                        if chain_dead {
                            break;
                        }
                        base = candidates::generate(&freq_by_level[k - 1]);
                    }
                }
                if base.is_empty() {
                    break;
                }
                // Fanout against the set the candidates were generated
                // from: the optimistic predecessor group while the lane
                // is pending, the exact frequent set otherwise.
                let n_parent = match &pending {
                    Some((_, groups, _)) => groups.last().expect("job has groups").len(),
                    None => freq_by_level[k - 1].len(),
                };
                self.sample_workload(mine_ctx.clone(), k, shape.as_ref(), base.len(), n_parent);
                let mut groups = vec![base];
                if self.pipeline.batch_levels >= 2 && self.apriori.level_allowed(k + 1) {
                    let ahead = candidates::generate(&groups[0]);
                    if !ahead.is_empty()
                        && ahead.len() as f64 <= self.pipeline.max_blowup * groups[0].len() as f64
                    {
                        groups.push(ahead);
                    }
                }

                let app = self.attach_cache(
                    CandidateCountApp::new(
                        groups.concat(),
                        self.engine.as_ref(),
                        db.n_items,
                        threshold,
                    ),
                    cache_gen,
                );
                // Map wave for this job — overlaps the pending reduce lane.
                let map_outputs = runner.map_stage(&app, db, &splits, &self.job)?;
                // Resolve the predecessor before opening a new reduce lane
                // (bounds look-ahead to one job and keeps level order).
                if let Some((bk, prev_groups, handle)) = pending.take() {
                    let (out, stats) = handle.join().expect("reduce lane")?;
                    chain_dead = resolve_job(
                        bk,
                        &prev_groups,
                        out,
                        stats,
                        avg_split_tx,
                        record_bytes,
                        &mut result,
                        &mut profiles,
                        &mut jobs,
                        &mut freq_by_level,
                    );
                }
                if chain_dead {
                    // The predecessor just proved the chain ends before this
                    // job's levels: drop its map outputs instead of paying a
                    // shuffle + reduce wave that would resolve to nothing.
                    break;
                }
                let n_levels = groups.len();
                let job_cfg = &self.job;
                let handle = scope
                    .spawn(move || runner.reduce_stage(&app, db, splits_ref, map_outputs, job_cfg));
                pending = Some((k, groups, handle));
                k += n_levels;
            }
            // Drain the last lane. If the chain died earlier its counts
            // resolve to nothing (exact candidate sets are empty).
            if let Some((bk, groups, handle)) = pending.take() {
                let (out, stats) = handle.join().expect("reduce lane")?;
                resolve_job(
                    bk,
                    &groups,
                    out,
                    stats,
                    avg_split_tx,
                    record_bytes,
                    &mut result,
                    &mut profiles,
                    &mut jobs,
                    &mut freq_by_level,
                );
            }
            Ok(())
        });
        outcome?;
        result.normalize();
        if let Some(mut s) = mine_span {
            s.add("levels", result.levels.len() as f64);
        }
        for (k, stats) in &jobs {
            self.record_job_metrics(*k, stats);
        }

        // Same residency charge as the synchronous loop: the cache's
        // index bytes count against datanode capacity.
        let cache_bytes = self.cache.resident_bytes();
        if cache_bytes > 0 {
            dfs.put_bytes(cache_bytes as u64)?;
        }

        Ok(RunReport {
            result,
            jobs,
            profile: WorkloadProfile {
                n_tx: db.len(),
                db_bytes: db.approx_bytes(),
                levels: profiles,
            },
            wall_secs: t0.elapsed().as_secs_f64(),
            spill_fraction: dfs.spill_fraction(),
        })
    }
}

/// A reusable targeted-scan context over one database: splits planned
/// and blocks placed **once**, then any number of unfiltered exact
/// counting jobs run against the same placement. The incremental
/// subsystem's frontier walk creates one per delta and reuses it for
/// every level's recount instead of re-planning the full database each
/// time.
pub struct ExactCounter<'a> {
    driver: &'a MrApriori,
    splits: Vec<Split>,
    dfs: Dfs,
    blocks: Vec<BlockId>,
    /// Cache generation opened for this counter's placement: every
    /// `count` call reuses the same per-split index builds.
    cache_gen: u64,
    /// The DFS block currently charged for resident cache bytes, so
    /// repeated counts re-charge instead of stacking blocks.
    charged: Option<(BlockId, u64)>,
}

impl<'a> ExactCounter<'a> {
    pub fn new(driver: &'a MrApriori, db: &TransactionDb) -> Result<Self, MineError> {
        let splits = plan_splits(db, driver.split_tx);
        let mut dfs = Dfs::new(&driver.cluster);
        let blocks = dfs.write_splits(&splits)?;
        let cache_gen = driver.cache.begin_generation();
        Ok(Self { driver, splits, dfs, blocks, cache_gen, charged: None })
    }

    /// Exact supports for `itemsets` over the database this counter was
    /// planned for (pass the same `db`), aligned with the input order.
    /// Duplicates in the list are fine: counting runs over the
    /// deduplicated set and results scatter back per entry.
    pub fn count(
        &mut self,
        db: &TransactionDb,
        itemsets: &[Itemset],
    ) -> Result<Vec<u64>, MineError> {
        if itemsets.is_empty() || db.is_empty() {
            return Ok(vec![0; itemsets.len()]);
        }
        let mut unique = itemsets.to_vec();
        unique.sort();
        unique.dedup();
        let app = CandidateCountApp::new(unique, self.driver.engine.as_ref(), db.n_items, 0)
            .with_capture();
        let app = self.driver.attach_cache(app, self.cache_gen);
        // Same recovery discipline as the level loop: reap dead nodes
        // before each scan (the placement is long-lived, so a node lost
        // between counts must be evicted from it), retry once if nodes
        // die under the scan itself.
        let mut tries = 0usize;
        let (out, _stats) = loop {
            if let Some(clock) = self.driver.chaos() {
                self.dfs.reap_dead_nodes(&clock.dead_nodes());
            }
            let runner = JobRunner::new(&self.driver.cluster, &self.dfs, &self.blocks)
                .with_chaos(self.driver.chaos().cloned());
            match runner.run(&app, db, &self.splits, &self.driver.job) {
                Err(JobError::NodesLost { .. })
                    if tries < 2
                        && self.driver.chaos().is_some_and(|c| {
                            c.dead_nodes().len() < self.driver.cluster.n_nodes()
                        }) =>
                {
                    tries += 1;
                }
                other => break other?,
            }
        };
        let counts: std::collections::HashMap<&Itemset, u64> =
            out.iter().map(|(is, s)| (is, *s)).collect();
        self.recharge_cache_bytes()?;
        Ok(itemsets
            .iter()
            .map(|c| counts.get(c).copied().unwrap_or(0))
            .collect())
    }

    /// Keep exactly one DFS block charged for the cache's resident index
    /// bytes across repeated counts: drop the stale charge and place a
    /// fresh one whenever residency changed.
    fn recharge_cache_bytes(&mut self) -> Result<(), MineError> {
        let resident = self.driver.cache.resident_bytes() as u64;
        if self.charged.map(|(_, bytes)| bytes) == Some(resident) {
            return Ok(());
        }
        if let Some((old, _)) = self.charged.take() {
            self.dfs.remove_block(old)?;
        }
        if resident > 0 {
            let id = self.dfs.put_bytes(resident)?;
            self.charged = Some((id, resident));
        }
        Ok(())
    }
}

/// Fold one finished (possibly multi-level) counting job back into the
/// mining state: for each level the job counted, intersect its
/// threshold-filtered counts with the exact candidate set generated from
/// the previous level's (now known) frequent itemsets. Returns `true`
/// when the level chain is exhausted — an exact candidate set or a
/// frequent set came up empty.
#[allow(clippy::too_many_arguments)]
fn resolve_job(
    base_k: usize,
    groups: &[Vec<Itemset>],
    output: Vec<(Itemset, u64)>,
    stats: JobStats,
    avg_split_tx: usize,
    record_bytes: usize,
    result: &mut MiningResult,
    profiles: &mut Vec<LevelProfile>,
    jobs: &mut Vec<(usize, JobStats)>,
    freq_by_level: &mut Vec<Vec<Itemset>>,
) -> bool {
    use std::collections::HashMap;
    // Levels differ in itemset length, so one lookup covers the union.
    let counts: HashMap<&Itemset, u64> = output.iter().map(|(is, s)| (is, *s)).collect();
    let n_maps = stats.maps_total.max(1);
    let total_counted: usize = groups.iter().map(|g| g.len()).sum::<usize>().max(1);
    let mut dead = false;

    for (i, group) in groups.iter().enumerate() {
        let k = base_k + i;
        while freq_by_level.len() <= k {
            freq_by_level.push(Vec::new());
        }
        let exact = candidates::generate(&freq_by_level[k - 1]);
        if exact.is_empty() {
            // The synchronous driver would never have run this level; the
            // speculative counts for it are discarded.
            dead = true;
            break;
        }
        // `exact ⊆ group` by downward closure, so every exact candidate
        // at or above threshold is present in the job output.
        let frequent: Vec<(Itemset, u64)> = exact
            .iter()
            .filter_map(|c| counts.get(c).map(|&s| (c.clone(), s)))
            .collect();
        let share = group.len() as f64 / total_counted as f64;
        result.levels.push(LevelStats {
            k,
            n_candidates: exact.len(),
            n_frequent: frequent.len(),
            // actual probes spent on this level's (optimistic) group
            work_units: (avg_split_tx * group.len()) as f64 * n_maps as f64,
            wall_secs: stats.total_secs * share,
        });
        let level_shuffle = stats.shuffle_records * group.len() / total_counted;
        profiles.push(LevelProfile {
            k,
            n_candidates: exact.len(),
            n_frequent: frequent.len(),
            work_per_tx: group.len().max(1) as f64,
            shuffle_bytes_per_map: (level_shuffle * record_bytes / n_maps) as u64,
            reduce_work: level_shuffle as f64,
        });
        freq_by_level[k] = frequent.iter().map(|(is, _)| is.clone()).collect();
        result.frequent.extend(frequent);
        if freq_by_level[k].is_empty() {
            dead = true;
            break;
        }
    }
    jobs.push((base_k, stats));
    dead
}

/// Align a job's (sparse) reduce output with the exact candidate list:
/// candidates no map task emitted get support 0.
fn zero_fill(cands: Vec<Itemset>, out: &[(Itemset, u64)]) -> Vec<(Itemset, u64)> {
    use std::collections::HashMap;
    let counts: HashMap<&Itemset, u64> = out.iter().map(|(is, s)| (is, *s)).collect();
    cands
        .into_iter()
        .map(|c| {
            let s = counts.get(&c).copied().unwrap_or(0);
            (c, s)
        })
        .collect()
}

/// Open the root `mine` span (cat `mine`) for one driver run.
fn mine_span(ctx: &TraceCtx, db: &TransactionDb, threshold: u64, pipelined: bool) -> Span {
    let mut s = ctx.span("mine", "mine");
    s.add("n_tx", db.len() as f64);
    s.add("threshold", threshold as f64);
    s.add("pipelined", if pipelined { 1.0 } else { 0.0 });
    s
}

/// Open one level job's span (`level.{k}`, cat `mine`) under the mine
/// root, stamped with the level's candidate count.
fn level_span(ctx: &TraceCtx, k: usize, n_candidates: usize) -> Span {
    let mut s = ctx.span("mine", format!("level.{k}"));
    s.add("k", k as f64);
    s.add("candidates", n_candidates as f64);
    s
}

/// Annotate a finished level's span with the job's headline counters;
/// the drop records it.
fn close_level_span(span: Option<Span>, n_frequent: usize, stats: &JobStats) {
    if let Some(mut s) = span {
        s.add("frequent", n_frequent as f64);
        s.add("map_ms", stats.map_secs * 1e3);
        s.add("reduce_ms", stats.reduce_secs * 1e3);
        s.add("shuffle_records", stats.shuffle_records as f64);
    }
}

/// Database shape statistics, computed once per mine and shared by
/// every level's `profile.level.{k}` sample.
struct DbShape {
    /// Average fraction of the item universe present per basket.
    density: f64,
    /// Most-frequent-item support over mean item support.
    item_skew: f64,
    avg_basket_width: f64,
}

fn db_shape(db: &TransactionDb) -> DbShape {
    if db.is_empty() || db.n_items == 0 {
        return DbShape { density: 0.0, item_skew: 0.0, avg_basket_width: 0.0 };
    }
    let mut counts = vec![0u64; db.n_items];
    for tx in &db.transactions {
        for &item in &tx.items {
            if let Some(c) = counts.get_mut(item as usize) {
                *c += 1;
            }
        }
    }
    let total: u64 = counts.iter().sum();
    let max = counts.iter().copied().max().unwrap_or(0);
    let mean = total as f64 / db.n_items as f64;
    let avg_basket_width = total as f64 / db.len() as f64;
    DbShape {
        density: avg_basket_width / db.n_items as f64,
        item_skew: if mean > 0.0 { max as f64 / mean } else { 0.0 },
        avg_basket_width,
    }
}

fn avg_split(splits: &[Split]) -> usize {
    if splits.is_empty() {
        return 0;
    }
    splits.iter().map(|s| s.len()).sum::<usize>() / splits.len()
}

#[allow(clippy::too_many_arguments)]
fn push_level(
    result: &mut MiningResult,
    profiles: &mut Vec<LevelProfile>,
    k: usize,
    n_candidates: usize,
    frequent: &[(Itemset, u64)],
    stats: &JobStats,
    work_per_map: f64,
    record_bytes: usize,
    wall_secs: f64,
) {
    let n_maps = stats.maps_total.max(1);
    result.levels.push(LevelStats {
        k,
        n_candidates,
        n_frequent: frequent.len(),
        work_units: work_per_map * n_maps as f64,
        wall_secs,
    });
    profiles.push(LevelProfile {
        k,
        n_candidates,
        n_frequent: frequent.len(),
        work_per_tx: if n_candidates == 0 { 1.0 } else { n_candidates as f64 },
        shuffle_bytes_per_map: (stats.shuffle_records * record_bytes / n_maps) as u64,
        reduce_work: stats.shuffle_records as f64,
    });
}

/// Build the per-level job specs that replay a profile on a cluster —
/// shared by the synchronous and pipelined simulators.
fn plan_sim_specs(
    cluster: &ClusterConfig,
    profile: &WorkloadProfile,
    split_tx: usize,
    job: &JobConfig,
) -> Vec<SimJobSpec> {
    // Re-plan placement for this cluster (same logic as the real path).
    let n_splits = profile.n_tx.div_ceil(split_tx).max(1);
    let bytes_per_split = (profile.db_bytes / n_splits.max(1)) as u64;
    let mut dfs = Dfs::new(cluster);
    let pseudo_splits: Vec<Split> = (0..n_splits)
        .map(|i| Split {
            id: i,
            start: i * split_tx,
            end: ((i + 1) * split_tx).min(profile.n_tx),
            bytes: bytes_per_split as usize,
        })
        .collect();
    let blocks = dfs
        .write_splits(&pseudo_splits)
        .expect("placement on simulated cluster");

    let tx_per_split = (profile.n_tx as f64 / n_splits as f64).max(1.0);
    profile
        .levels
        .iter()
        .map(|level| SimJobSpec {
            map_tasks: blocks
                .iter()
                .map(|&b| {
                    let meta = dfs.meta(b).expect("block meta");
                    SimMapTask {
                        bytes: meta.bytes,
                        work: level.work_per_tx * tx_per_split,
                        replicas: meta.replicas.clone(),
                        spilled: meta.spilled,
                    }
                })
                .collect(),
            n_reducers: job.n_reducers,
            shuffle_bytes_per_map: level.shuffle_bytes_per_map,
            reduce_work: level.reduce_work,
            speculative: job.speculative,
            surprise: None,
        })
        .collect()
}

/// Replay a mined workload's cost profile on an arbitrary cluster shape —
/// the fig 4/5 methodology: mine once, predict everywhere. Deterministic.
pub fn simulate(
    cluster: &ClusterConfig,
    profile: &WorkloadProfile,
    split_tx: usize,
    job: &JobConfig,
) -> SimReport {
    let specs = plan_sim_specs(cluster, profile, split_tx, job);
    Simulator::new(cluster.clone()).run_sequence(&specs)
}

/// Same replay, but the level jobs execute as the pipelined DAG: each
/// job's map wave starts when the predecessor's map wave drains, with
/// shuffle/reduce overlapped. The delta against [`simulate`] is the
/// framework latency the pipelined driver removes.
pub fn simulate_pipelined(
    cluster: &ClusterConfig,
    profile: &WorkloadProfile,
    split_tx: usize,
    job: &JobConfig,
) -> SimReport {
    let specs = plan_sim_specs(cluster, profile, split_tx, job);
    Simulator::new(cluster.clone()).run_pipelined_sequence(&specs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apriori::classical::{tests::textbook_db, ClassicalApriori};
    use crate::data::quest::{QuestGenerator, QuestParams};

    fn quick_cfg() -> AprioriConfig {
        AprioriConfig { min_support: 0.05, max_k: 3 }
    }

    #[test]
    fn mr_matches_classical_on_textbook() {
        let db = textbook_db();
        let cfg = AprioriConfig { min_support: 2.0 / 9.0, max_k: 0 };
        let classical = ClassicalApriori::default().mine(&db, &cfg);
        let report = MrApriori::new(ClusterConfig::fhssc(3), cfg)
            .with_split_tx(3)
            .mine(&db)
            .unwrap();
        assert_eq!(report.result.frequent, classical.frequent);
        assert!(report.jobs.len() >= 3); // L1..L3 at least
        assert_eq!(report.result.n_transactions, 9);
    }

    #[test]
    fn mr_matches_classical_on_quest() {
        let db = QuestGenerator::new(QuestParams::goswami_2k()).generate();
        let cfg = quick_cfg();
        let classical = ClassicalApriori::default().mine(&db, &cfg);
        for preset in [
            ClusterConfig::standalone(),
            ClusterConfig::pseudo_distributed(),
            ClusterConfig::fhssc(3),
            ClusterConfig::fhdsc(4),
        ] {
            let report = MrApriori::new(preset, cfg.clone())
                .with_split_tx(250)
                .mine(&db)
                .unwrap();
            assert_eq!(report.result.frequent, classical.frequent);
        }
    }

    #[test]
    fn pipelined_matches_classical_on_textbook() {
        let db = textbook_db();
        let cfg = AprioriConfig { min_support: 2.0 / 9.0, max_k: 0 };
        let classical = ClassicalApriori::default().mine(&db, &cfg);
        for batch_levels in [1usize, 2] {
            let report = MrApriori::new(ClusterConfig::fhssc(3), cfg.clone())
                .with_split_tx(3)
                .with_pipeline(PipelineConfig {
                    enabled: true,
                    batch_levels,
                    ..Default::default()
                })
                .mine(&db)
                .unwrap();
            assert_eq!(
                report.result.frequent, classical.frequent,
                "batch_levels={batch_levels}"
            );
        }
    }

    #[test]
    fn pipelined_matches_synchronous_on_quest_presets() {
        let db = QuestGenerator::new(QuestParams::goswami_2k()).generate();
        let cfg = quick_cfg();
        let sync = MrApriori::new(ClusterConfig::fhssc(3), cfg.clone())
            .with_split_tx(250)
            .mine(&db)
            .unwrap();
        for preset in [
            ClusterConfig::standalone(),
            ClusterConfig::fhssc(3),
            ClusterConfig::fhdsc(4),
        ] {
            for batch_levels in [1usize, 2] {
                let piped = MrApriori::new(preset.clone(), cfg.clone())
                    .with_split_tx(250)
                    .with_pipeline(PipelineConfig {
                        enabled: true,
                        batch_levels,
                        ..Default::default()
                    })
                    .mine(&db)
                    .unwrap();
                assert_eq!(
                    piped.result.frequent, sync.result.frequent,
                    "preset {:?} batch_levels={batch_levels}",
                    preset.mode
                );
            }
        }
    }

    #[test]
    fn pipelined_zero_blowup_budget_degrades_to_exact_schedule() {
        // max_blowup = 0 forces the optimism guard on every level, so the
        // driver continually waits for exact frequent sets — results must
        // still be identical (and the run must not deadlock).
        let db = QuestGenerator::new(QuestParams::dense(400)).generate();
        let cfg = AprioriConfig { min_support: 0.05, max_k: 4 };
        let sync = MrApriori::new(ClusterConfig::fhssc(2), cfg.clone())
            .with_split_tx(100)
            .mine(&db)
            .unwrap();
        let piped = MrApriori::new(ClusterConfig::fhssc(2), cfg)
            .with_split_tx(100)
            .with_pipeline(PipelineConfig {
                enabled: true,
                batch_levels: 1,
                max_blowup: 0.0,
            })
            .mine(&db)
            .unwrap();
        assert_eq!(piped.result.frequent, sync.result.frequent);
    }

    #[test]
    fn pipelined_batching_runs_fewer_jobs() {
        let db = QuestGenerator::new(QuestParams::dense(500)).generate();
        let cfg = AprioriConfig { min_support: 0.05, max_k: 4 };
        let sync = MrApriori::new(ClusterConfig::fhssc(3), cfg.clone())
            .with_split_tx(100)
            .mine(&db)
            .unwrap();
        let piped = MrApriori::new(ClusterConfig::fhssc(3), cfg)
            .with_split_tx(100)
            .with_pipeline(PipelineConfig::pipelined())
            .mine(&db)
            .unwrap();
        assert_eq!(piped.result.frequent, sync.result.frequent);
        assert!(
            piped.jobs.len() < sync.jobs.len(),
            "batched pipeline should merge level jobs: {} vs {}",
            piped.jobs.len(),
            sync.jobs.len()
        );
        // levels still reported per level, in ascending order
        let ks: Vec<usize> = piped.result.levels.iter().map(|l| l.k).collect();
        let mut sorted = ks.clone();
        sorted.sort_unstable();
        assert_eq!(ks, sorted);
    }

    #[test]
    fn profile_captures_levels() {
        let db = QuestGenerator::new(QuestParams::dense(500)).generate();
        let cfg = AprioriConfig { min_support: 0.05, max_k: 3 };
        let report = MrApriori::new(ClusterConfig::fhssc(3), cfg)
            .with_split_tx(100)
            .mine(&db)
            .unwrap();
        assert_eq!(report.profile.n_tx, 500);
        assert!(report.profile.levels.len() >= 2);
        let l2 = report.profile.levels.iter().find(|l| l.k == 2).unwrap();
        assert!(l2.n_candidates > 0);
        assert!(l2.work_per_tx >= l2.n_candidates as f64);
        assert!(report.wall_secs > 0.0);
    }

    #[test]
    fn simulate_replays_profile_deterministically() {
        let db = QuestGenerator::new(QuestParams::dense(400)).generate();
        let report = MrApriori::new(ClusterConfig::fhssc(3), quick_cfg())
            .with_split_tx(100)
            .mine(&db)
            .unwrap();
        let job = JobConfig::default();
        let a = simulate(&ClusterConfig::fhssc(3), &report.profile, 100, &job);
        let b = simulate(&ClusterConfig::fhssc(3), &report.profile, 100, &job);
        assert_eq!(a.total_secs, b.total_secs);
        assert!(a.total_secs > 0.0);
    }

    #[test]
    fn simulate_pipelined_beats_synchronous_replay() {
        let db = QuestGenerator::new(QuestParams::t10_i4(1000)).generate();
        let report = MrApriori::new(ClusterConfig::fhssc(3), quick_cfg())
            .with_split_tx(100)
            .mine(&db)
            .unwrap();
        assert!(report.profile.levels.len() >= 2, "need a multi-level workload");
        let job = JobConfig::default();
        for cluster in [ClusterConfig::fhssc(3), ClusterConfig::fhdsc(4)] {
            let sync = simulate(&cluster, &report.profile, 100, &job);
            let piped = simulate_pipelined(&cluster, &report.profile, 100, &job);
            assert!(
                piped.total_secs < sync.total_secs,
                "pipelined replay {} must beat synchronous {}",
                piped.total_secs,
                sync.total_secs
            );
        }
    }

    #[test]
    fn simulate_shows_fig4_ordering() {
        let db = QuestGenerator::new(QuestParams::t10_i4(1000)).generate();
        let report = MrApriori::new(ClusterConfig::fhssc(3), quick_cfg())
            .with_split_tx(100)
            .mine(&db)
            .unwrap();
        let job = JobConfig::default();
        for n in [2usize, 3, 6] {
            let hom = simulate(&ClusterConfig::fhssc(n), &report.profile, 100, &job);
            let het = simulate(&ClusterConfig::fhdsc(n), &report.profile, 100, &job);
            assert!(
                het.total_secs > hom.total_secs,
                "n={n}: FHDSC {} <= FHSSC {}",
                het.total_secs,
                hom.total_secs
            );
        }
    }

    #[test]
    fn mine_captured_matches_mine_and_captures_border() {
        let db = textbook_db();
        let cfg = AprioriConfig { min_support: 2.0 / 9.0, max_k: 0 };
        let driver = MrApriori::new(ClusterConfig::fhssc(3), cfg).with_split_tx(3);
        let plain = driver.mine(&db).unwrap();
        let (report, capture) = driver.mine_captured(&db).unwrap();
        assert_eq!(report.result.frequent, plain.result.frequent);
        assert_eq!(capture.n_items, db.n_items);
        assert_eq!(capture.threshold, 2);
        // level 1 covers the whole universe, supports exact
        let l1 = &capture.levels[0];
        assert_eq!(l1.k, 1);
        assert_eq!(l1.counted.len(), db.n_items);
        for (is, s) in &l1.counted {
            assert_eq!(*s, db.support(is) as u64, "{is:?}");
        }
        // every deeper level = exact candidate set, frequent + border
        for lc in &capture.levels[1..] {
            let n_frequent = lc.counted.iter().filter(|(_, s)| *s >= 2).count();
            assert_eq!(n_frequent, report.result.level(lc.k).count());
            for (is, s) in &lc.counted {
                assert_eq!(*s, db.support(is) as u64, "{is:?}");
            }
        }
    }

    #[test]
    fn count_exact_matches_oracle_on_mixed_lengths() {
        let db = textbook_db();
        let cfg = AprioriConfig { min_support: 2.0 / 9.0, max_k: 0 };
        let driver = MrApriori::new(ClusterConfig::standalone(), cfg).with_split_tx(4);
        let itemsets: Vec<Itemset> = vec![
            vec![0],
            vec![3],
            vec![0, 1],
            vec![3, 4], // never co-occur -> 0
            vec![0, 1, 2],
            vec![7], // beyond any transaction -> 0
            vec![0], // duplicate entry: counted once, reported per entry
        ];
        let counts = driver.count_exact(&db, &itemsets).unwrap();
        let want: Vec<u64> = itemsets.iter().map(|is| db.support(is) as u64).collect();
        assert_eq!(counts, want);
        assert!(driver.count_exact(&db, &[]).unwrap().is_empty());
        // a reusable counter over the same placement answers identically
        let mut counter = ExactCounter::new(&driver, &db).unwrap();
        assert_eq!(counter.count(&db, &itemsets).unwrap(), want);
        assert_eq!(counter.count(&db, &[vec![1]]).unwrap(), vec![db.support(&[1]) as u64]);
    }

    #[test]
    fn mine_recovers_from_mid_mine_node_loss_byte_identically() {
        let db = QuestGenerator::new(QuestParams::dense(400)).generate();
        let cfg = AprioriConfig { min_support: 0.05, max_k: 4 };
        let clean = MrApriori::new(ClusterConfig::fhssc(3), cfg.clone())
            .with_split_tx(100)
            .mine(&db)
            .unwrap();

        // Synchronous: a node dies at the level-2 boundary; the loop
        // reaps it, re-replicates, and resumes from level 2.
        let clock = Arc::new(FaultClock::new(
            crate::chaos::FaultPlan::parse("kill:1@level:2").unwrap(),
        ));
        let chaotic = MrApriori::new(ClusterConfig::fhssc(3), cfg.clone())
            .with_split_tx(100)
            .with_chaos(Some(Arc::clone(&clock)))
            .mine(&db)
            .unwrap();
        assert_eq!(chaotic.result.frequent, clean.result.frequent);
        assert_eq!(clock.dead_nodes(), vec![1]);

        // Pipelined: a node dies mid map wave; the runner requeues its
        // work to survivors without touching the shared placement.
        let clock = Arc::new(FaultClock::new(
            crate::chaos::FaultPlan::parse("kill:2@maps:3").unwrap(),
        ));
        let piped = MrApriori::new(ClusterConfig::fhssc(3), cfg)
            .with_split_tx(100)
            .with_pipeline(PipelineConfig::pipelined())
            .with_chaos(Some(Arc::clone(&clock)))
            .mine(&db)
            .unwrap();
        assert_eq!(piped.result.frequent, clean.result.frequent);
        assert_eq!(clock.dead_nodes(), vec![2]);
    }

    #[test]
    fn count_exact_survives_a_pre_declared_dead_node() {
        let db = textbook_db();
        let cfg = AprioriConfig { min_support: 2.0 / 9.0, max_k: 0 };
        let clock = Arc::new(FaultClock::new(
            crate::chaos::FaultPlan::parse("kill:0@now").unwrap(),
        ));
        let driver = MrApriori::new(ClusterConfig::fhssc(3), cfg)
            .with_split_tx(3)
            .with_chaos(Some(clock));
        let itemsets: Vec<Itemset> = vec![vec![0], vec![0, 1], vec![3, 4]];
        let counts = driver.count_exact(&db, &itemsets).unwrap();
        let want: Vec<u64> = itemsets.iter().map(|is| db.support(is) as u64).collect();
        assert_eq!(counts, want);
    }

    #[test]
    fn engine_selection_preserves_results() {
        let db = QuestGenerator::new(QuestParams::dense(300)).generate();
        let cfg = AprioriConfig { min_support: 0.05, max_k: 3 };
        let base = MrApriori::new(ClusterConfig::fhssc(2), cfg.clone())
            .with_split_tx(100)
            .mine(&db)
            .unwrap();
        for kind in [EngineKind::Trie, EngineKind::Vertical] {
            let alt = MrApriori::new(ClusterConfig::fhssc(2), cfg.clone())
                .with_engine(crate::engine::build_engine(kind, None))
                .with_split_tx(100)
                .mine(&db)
                .unwrap();
            assert_eq!(base.result.frequent, alt.result.frequent, "{kind}");
        }
    }
}

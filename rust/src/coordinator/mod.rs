//! The leader: plans the level-wise Apriori loop as a sequence of
//! MapReduce jobs, routes splits through the DFS, aggregates counts, and
//! records everything the benches need to replay the run against any
//! simulated cluster (the paper's fig 4/5 methodology).
//!
//! Responsibilities, mirroring the paper's Hadoop master:
//! * write the dataset into the DFS (block placement + replication);
//! * per level k: broadcast the candidate set, run the counting job,
//!   filter by min-support, generate the next level's candidates;
//! * collect [`JobStats`] and produce a [`WorkloadProfile`] — the per-level
//!   cost summary [`simulate`] uses to predict the same workload's makespan
//!   on a different cluster shape without re-mining.

use std::time::Instant;

use crate::apriori::mr::{CandidateCountApp, ItemCountApp};
use crate::apriori::{candidates, AprioriConfig, Itemset, LevelStats, MiningResult};
use crate::cluster::ClusterConfig;
use crate::data::split::{plan_splits, Split};
use crate::data::TransactionDb;
use crate::dfs::{Dfs, DfsError};
use crate::engine::{EngineKind, SupportEngine};
use crate::mapreduce::app::MapReduceApp;
use crate::mapreduce::{
    JobConfig, JobError, JobRunner, JobStats, SimJobSpec, SimMapTask, SimReport, Simulator,
};

#[derive(Debug, thiserror::Error)]
pub enum MineError {
    #[error("dfs: {0}")]
    Dfs(#[from] DfsError),
    #[error("job: {0}")]
    Job(#[from] JobError),
}

/// Per-level cost summary — everything the simulator needs, nothing more.
#[derive(Debug, Clone)]
pub struct LevelProfile {
    pub k: usize,
    pub n_candidates: usize,
    pub n_frequent: usize,
    /// Map compute per transaction (work units).
    pub work_per_tx: f64,
    /// Shuffle bytes emitted per map task (post-combiner).
    pub shuffle_bytes_per_map: u64,
    /// Reduce compute (work units, total).
    pub reduce_work: f64,
}

/// A mined workload's replayable cost profile.
#[derive(Debug, Clone)]
pub struct WorkloadProfile {
    pub n_tx: usize,
    pub db_bytes: usize,
    pub levels: Vec<LevelProfile>,
}

/// Everything one coordinated run produces.
#[derive(Debug)]
pub struct RunReport {
    pub result: MiningResult,
    /// JobStats per level (k, stats).
    pub jobs: Vec<(usize, JobStats)>,
    pub profile: WorkloadProfile,
    pub wall_secs: f64,
    /// Fraction of DFS blocks placed past node capacity.
    pub spill_fraction: f64,
}

/// The Map/Reduce Apriori driver.
pub struct MrApriori {
    pub cluster: ClusterConfig,
    pub apriori: AprioriConfig,
    pub job: JobConfig,
    /// Transactions per map split (HDFS block granularity).
    pub split_tx: usize,
    engine: Box<dyn SupportEngine>,
}

impl MrApriori {
    /// Driver with the default hash-tree engine.
    pub fn new(cluster: ClusterConfig, apriori: AprioriConfig) -> Self {
        Self {
            cluster,
            apriori,
            job: JobConfig { n_reducers: 3, ..Default::default() },
            split_tx: 1000,
            // Trie is the measured-fastest CPU matcher on every A1 width
            // (EXPERIMENTS.md §Perf); hash-tree/naive/tensor via with_engine.
            engine: crate::engine::build_engine(EngineKind::Trie, None),
        }
    }

    pub fn with_engine(mut self, engine: Box<dyn SupportEngine>) -> Self {
        self.engine = engine;
        self
    }

    pub fn with_job(mut self, job: JobConfig) -> Self {
        self.job = job;
        self
    }

    pub fn with_split_tx(mut self, split_tx: usize) -> Self {
        assert!(split_tx > 0);
        self.split_tx = split_tx;
        self
    }

    /// Mine `db`: real multi-threaded MapReduce execution.
    pub fn mine(&self, db: &TransactionDb) -> Result<RunReport, MineError> {
        let t0 = Instant::now();
        let threshold = self.apriori.threshold(db.len());
        let splits = plan_splits(db, self.split_tx);
        let mut dfs = Dfs::new(&self.cluster);
        let blocks = dfs.write_splits(&splits)?;
        let runner = JobRunner::new(&self.cluster, &dfs, &blocks);

        let mut result = MiningResult {
            n_transactions: db.len(),
            ..Default::default()
        };
        let mut jobs = Vec::new();
        let mut profiles = Vec::new();

        // ---- level 1 ----
        let app = ItemCountApp { threshold };
        let lt0 = Instant::now();
        let (f1, stats) = runner.run(&app, db, &splits, &self.job)?;
        push_level(
            &mut result,
            &mut profiles,
            1,
            db.n_items,
            &f1,
            &stats,
            app.map_cost_hint(avg_split(&splits)),
            app.record_bytes_hint(),
            lt0.elapsed().as_secs_f64(),
        );
        jobs.push((1, stats));
        let mut frequent_prev: Vec<Itemset> = f1.iter().map(|(is, _)| is.clone()).collect();
        result.frequent.extend(f1);

        // ---- levels k >= 2 ----
        let mut k = 2usize;
        while !frequent_prev.is_empty() && self.apriori.level_allowed(k) {
            let cands = candidates::generate(&frequent_prev);
            if cands.is_empty() {
                break;
            }
            let app = CandidateCountApp {
                candidates: cands.clone(),
                engine: self.engine.as_ref(),
                n_items: db.n_items,
                threshold,
            };
            let lt0 = Instant::now();
            let (fk, stats) = runner.run(&app, db, &splits, &self.job)?;
            push_level(
                &mut result,
                &mut profiles,
                k,
                cands.len(),
                &fk,
                &stats,
                app.map_cost_hint(avg_split(&splits)),
                app.record_bytes_hint(),
                lt0.elapsed().as_secs_f64(),
            );
            jobs.push((k, stats));
            frequent_prev = fk.iter().map(|(is, _)| is.clone()).collect();
            result.frequent.extend(fk);
            k += 1;
        }
        result.normalize();

        Ok(RunReport {
            result,
            jobs,
            profile: WorkloadProfile {
                n_tx: db.len(),
                db_bytes: db.approx_bytes(),
                levels: profiles,
            },
            wall_secs: t0.elapsed().as_secs_f64(),
            spill_fraction: dfs.spill_fraction(),
        })
    }
}

fn avg_split(splits: &[Split]) -> usize {
    if splits.is_empty() {
        return 0;
    }
    splits.iter().map(|s| s.len()).sum::<usize>() / splits.len()
}

#[allow(clippy::too_many_arguments)]
fn push_level(
    result: &mut MiningResult,
    profiles: &mut Vec<LevelProfile>,
    k: usize,
    n_candidates: usize,
    frequent: &[(Itemset, u64)],
    stats: &JobStats,
    work_per_map: f64,
    record_bytes: usize,
    wall_secs: f64,
) {
    let n_maps = stats.maps_total.max(1);
    result.levels.push(LevelStats {
        k,
        n_candidates,
        n_frequent: frequent.len(),
        work_units: work_per_map * n_maps as f64,
        wall_secs,
    });
    profiles.push(LevelProfile {
        k,
        n_candidates,
        n_frequent: frequent.len(),
        work_per_tx: if n_candidates == 0 { 1.0 } else { n_candidates as f64 },
        shuffle_bytes_per_map: (stats.shuffle_records * record_bytes / n_maps) as u64,
        reduce_work: stats.shuffle_records as f64,
    });
}

/// Replay a mined workload's cost profile on an arbitrary cluster shape —
/// the fig 4/5 methodology: mine once, predict everywhere. Deterministic.
pub fn simulate(
    cluster: &ClusterConfig,
    profile: &WorkloadProfile,
    split_tx: usize,
    job: &JobConfig,
) -> SimReport {
    // Re-plan placement for this cluster (same logic as the real path).
    let n_splits = profile.n_tx.div_ceil(split_tx).max(1);
    let bytes_per_split = (profile.db_bytes / n_splits.max(1)) as u64;
    let mut dfs = Dfs::new(cluster);
    let pseudo_splits: Vec<Split> = (0..n_splits)
        .map(|i| Split {
            id: i,
            start: i * split_tx,
            end: ((i + 1) * split_tx).min(profile.n_tx),
            bytes: bytes_per_split as usize,
        })
        .collect();
    let blocks = dfs
        .write_splits(&pseudo_splits)
        .expect("placement on simulated cluster");

    let tx_per_split = (profile.n_tx as f64 / n_splits as f64).max(1.0);
    let specs: Vec<SimJobSpec> = profile
        .levels
        .iter()
        .map(|level| SimJobSpec {
            map_tasks: blocks
                .iter()
                .map(|&b| {
                    let meta = dfs.meta(b).expect("block meta");
                    SimMapTask {
                        bytes: meta.bytes,
                        work: level.work_per_tx * tx_per_split,
                        replicas: meta.replicas.clone(),
                        spilled: meta.spilled,
                    }
                })
                .collect(),
            n_reducers: job.n_reducers,
            shuffle_bytes_per_map: level.shuffle_bytes_per_map,
            reduce_work: level.reduce_work,
            speculative: job.speculative,
            surprise: None,
        })
        .collect();
    Simulator::new(cluster.clone()).run_sequence(&specs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apriori::classical::{tests::textbook_db, ClassicalApriori};
    use crate::data::quest::{QuestGenerator, QuestParams};

    fn quick_cfg() -> AprioriConfig {
        AprioriConfig { min_support: 0.05, max_k: 3 }
    }

    #[test]
    fn mr_matches_classical_on_textbook() {
        let db = textbook_db();
        let cfg = AprioriConfig { min_support: 2.0 / 9.0, max_k: 0 };
        let classical = ClassicalApriori::default().mine(&db, &cfg);
        let report = MrApriori::new(ClusterConfig::fhssc(3), cfg)
            .with_split_tx(3)
            .mine(&db)
            .unwrap();
        assert_eq!(report.result.frequent, classical.frequent);
        assert!(report.jobs.len() >= 3); // L1..L3 at least
        assert_eq!(report.result.n_transactions, 9);
    }

    #[test]
    fn mr_matches_classical_on_quest() {
        let db = QuestGenerator::new(QuestParams::goswami_2k()).generate();
        let cfg = quick_cfg();
        let classical = ClassicalApriori::default().mine(&db, &cfg);
        for preset in [
            ClusterConfig::standalone(),
            ClusterConfig::pseudo_distributed(),
            ClusterConfig::fhssc(3),
            ClusterConfig::fhdsc(4),
        ] {
            let report = MrApriori::new(preset, cfg.clone())
                .with_split_tx(250)
                .mine(&db)
                .unwrap();
            assert_eq!(report.result.frequent, classical.frequent);
        }
    }

    #[test]
    fn profile_captures_levels() {
        let db = QuestGenerator::new(QuestParams::dense(500)).generate();
        let cfg = AprioriConfig { min_support: 0.05, max_k: 3 };
        let report = MrApriori::new(ClusterConfig::fhssc(3), cfg)
            .with_split_tx(100)
            .mine(&db)
            .unwrap();
        assert_eq!(report.profile.n_tx, 500);
        assert!(report.profile.levels.len() >= 2);
        let l2 = report.profile.levels.iter().find(|l| l.k == 2).unwrap();
        assert!(l2.n_candidates > 0);
        assert!(l2.work_per_tx >= l2.n_candidates as f64);
        assert!(report.wall_secs > 0.0);
    }

    #[test]
    fn simulate_replays_profile_deterministically() {
        let db = QuestGenerator::new(QuestParams::dense(400)).generate();
        let report = MrApriori::new(ClusterConfig::fhssc(3), quick_cfg())
            .with_split_tx(100)
            .mine(&db)
            .unwrap();
        let job = JobConfig::default();
        let a = simulate(&ClusterConfig::fhssc(3), &report.profile, 100, &job);
        let b = simulate(&ClusterConfig::fhssc(3), &report.profile, 100, &job);
        assert_eq!(a.total_secs, b.total_secs);
        assert!(a.total_secs > 0.0);
    }

    #[test]
    fn simulate_shows_fig4_ordering() {
        let db = QuestGenerator::new(QuestParams::t10_i4(1000)).generate();
        let report = MrApriori::new(ClusterConfig::fhssc(3), quick_cfg())
            .with_split_tx(100)
            .mine(&db)
            .unwrap();
        let job = JobConfig::default();
        for n in [2usize, 3, 6] {
            let hom = simulate(&ClusterConfig::fhssc(n), &report.profile, 100, &job);
            let het = simulate(&ClusterConfig::fhdsc(n), &report.profile, 100, &job);
            assert!(
                het.total_secs > hom.total_secs,
                "n={n}: FHDSC {} <= FHSSC {}",
                het.total_secs,
                hom.total_secs
            );
        }
    }

    #[test]
    fn engine_selection_preserves_results() {
        let db = QuestGenerator::new(QuestParams::dense(300)).generate();
        let cfg = AprioriConfig { min_support: 0.05, max_k: 3 };
        let base = MrApriori::new(ClusterConfig::fhssc(2), cfg.clone())
            .with_split_tx(100)
            .mine(&db)
            .unwrap();
        let trie = MrApriori::new(ClusterConfig::fhssc(2), cfg)
            .with_engine(crate::engine::build_engine(EngineKind::Trie, None))
            .with_split_tx(100)
            .mine(&db)
            .unwrap();
        assert_eq!(base.result.frequent, trie.result.frequent);
    }
}

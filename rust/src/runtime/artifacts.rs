//! Artifact manifest: which AOT-lowered HLO modules exist, with their
//! (t, i, c) tile shapes. Written by `python/compile/aot.py`; parsed here
//! with the in-tree JSON parser.

use std::path::{Path, PathBuf};

use crate::util::json::Json;

/// One lowered module from `manifest.json`.
#[derive(Debug, Clone, PartialEq)]
pub struct ModuleSpec {
    /// Graph name: `count_split` (pallas) or `count_split_ref` (jnp oracle).
    pub graph: String,
    /// Shape-variant name: `small` / `medium` / `large`.
    pub variant: String,
    /// HLO text file, relative to the manifest's directory.
    pub path: PathBuf,
    /// Tile shape: transactions per call, item width, candidate width.
    pub t: usize,
    pub i: usize,
    pub c: usize,
}

#[derive(Debug)]
pub enum ManifestError {
    Io {
        path: PathBuf,
        source: std::io::Error,
    },
    Parse(String),
    Format(f64),
}

impl std::fmt::Display for ManifestError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Self::Io { path, source } => write!(f, "cannot read {}: {source}", path.display()),
            Self::Parse(msg) => write!(f, "manifest parse: {msg}"),
            Self::Format(v) => write!(f, "manifest format {v} unsupported (want 1)"),
        }
    }
}

impl std::error::Error for ManifestError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Self::Io { source, .. } => Some(source),
            _ => None,
        }
    }
}

/// The parsed artifact manifest.
#[derive(Debug, Clone)]
pub struct ArtifactManifest {
    pub dir: PathBuf,
    pub modules: Vec<ModuleSpec>,
}

impl ArtifactManifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Self, ManifestError> {
        let mpath = dir.join("manifest.json");
        let text = std::fs::read_to_string(&mpath).map_err(|source| ManifestError::Io {
            path: mpath.clone(),
            source,
        })?;
        Self::parse(dir, &text)
    }

    /// Parse manifest text (separated for testability).
    pub fn parse(dir: &Path, text: &str) -> Result<Self, ManifestError> {
        let j = Json::parse(text).map_err(|e| ManifestError::Parse(e.to_string()))?;
        let fmt = j
            .get("format")
            .and_then(Json::as_f64)
            .ok_or_else(|| ManifestError::Parse("missing 'format'".into()))?;
        if fmt != 1.0 {
            return Err(ManifestError::Format(fmt));
        }
        let mods = j
            .get("modules")
            .and_then(|m| m.as_arr())
            .ok_or_else(|| ManifestError::Parse("missing 'modules'".into()))?;
        let mut modules = Vec::with_capacity(mods.len());
        for m in mods {
            let field = |k: &str| -> Result<&Json, ManifestError> {
                m.get(k)
                    .ok_or_else(|| ManifestError::Parse(format!("module missing '{k}'")))
            };
            modules.push(ModuleSpec {
                graph: field("graph")?
                    .as_str()
                    .ok_or_else(|| ManifestError::Parse("graph not a string".into()))?
                    .to_string(),
                variant: field("variant")?
                    .as_str()
                    .ok_or_else(|| ManifestError::Parse("variant not a string".into()))?
                    .to_string(),
                path: dir.join(
                    field("path")?
                        .as_str()
                        .ok_or_else(|| ManifestError::Parse("path not a string".into()))?,
                ),
                t: field("t")?
                    .as_usize()
                    .ok_or_else(|| ManifestError::Parse("t not a number".into()))?,
                i: field("i")?
                    .as_usize()
                    .ok_or_else(|| ManifestError::Parse("i not a number".into()))?,
                c: field("c")?
                    .as_usize()
                    .ok_or_else(|| ManifestError::Parse("c not a number".into()))?,
            });
        }
        Ok(Self { dir: dir.to_path_buf(), modules })
    }

    /// Find a module by graph + variant.
    pub fn find(&self, graph: &str, variant: &str) -> Option<&ModuleSpec> {
        self.modules
            .iter()
            .find(|m| m.graph == graph && m.variant == variant)
    }

    /// Smallest variant of `graph` whose item width fits `n_items` and
    /// candidate width fits `n_cands` — the shape-selection policy of the
    /// tensor engine (prefer the least padding waste).
    pub fn best_fit(&self, graph: &str, n_items: usize, n_cands: usize) -> Option<&ModuleSpec> {
        self.modules
            .iter()
            .filter(|m| m.graph == graph && m.i >= n_items)
            .min_by_key(|m| {
                // waste = padded candidate slots (rounded up to full calls)
                // tie-broken by item-width padding.
                let calls = n_cands.div_ceil(m.c);
                (calls * m.c - n_cands, m.i - n_items, m.t)
            })
    }

    /// Default artifacts directory: `$MR_APRIORI_ARTIFACTS` or `artifacts/`
    /// next to the workspace root.
    pub fn default_dir() -> PathBuf {
        if let Ok(p) = std::env::var("MR_APRIORI_ARTIFACTS") {
            return PathBuf::from(p);
        }
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "format": 1,
      "modules": [
        {"graph":"count_split","variant":"small","path":"count_split_small.hlo.txt","t":256,"i":64,"c":64,"sha256":"x","bytes":10},
        {"graph":"count_split","variant":"medium","path":"count_split_medium.hlo.txt","t":1024,"i":256,"c":256,"sha256":"y","bytes":10},
        {"graph":"count_split_ref","variant":"small","path":"count_split_ref_small.hlo.txt","t":256,"i":64,"c":64,"sha256":"z","bytes":10}
      ]
    }"#;

    #[test]
    fn parses_sample() {
        let m = ArtifactManifest::parse(Path::new("/art"), SAMPLE).unwrap();
        assert_eq!(m.modules.len(), 3);
        let s = m.find("count_split", "small").unwrap();
        assert_eq!((s.t, s.i, s.c), (256, 64, 64));
        assert_eq!(s.path, Path::new("/art/count_split_small.hlo.txt"));
        assert!(m.find("count_split", "huge").is_none());
    }

    #[test]
    fn best_fit_prefers_least_padding() {
        let m = ArtifactManifest::parse(Path::new("/a"), SAMPLE).unwrap();
        // 30 items, 50 candidates -> small (64 wide) fits with least waste
        let s = m.best_fit("count_split", 30, 50).unwrap();
        assert_eq!(s.variant, "small");
        // 200 items require the 256-wide medium
        let s = m.best_fit("count_split", 200, 50).unwrap();
        assert_eq!(s.variant, "medium");
        // 300 items fit nothing
        assert!(m.best_fit("count_split", 300, 50).is_none());
    }

    #[test]
    fn best_fit_large_candidate_sets_prefer_wide_c() {
        let m = ArtifactManifest::parse(Path::new("/a"), SAMPLE).unwrap();
        // 60 items, 512 candidates: small needs 8 calls with 0 waste;
        // medium needs 2 calls with 0 waste — both zero-waste, tie-break on
        // item padding picks small (64-30=4 < 256-60).
        let s = m.best_fit("count_split", 60, 512).unwrap();
        assert_eq!(s.variant, "small");
    }

    #[test]
    fn rejects_bad_manifests() {
        assert!(ArtifactManifest::parse(Path::new("/a"), "{}").is_err());
        assert!(ArtifactManifest::parse(Path::new("/a"), "not json").is_err());
        assert!(
            ArtifactManifest::parse(Path::new("/a"), r#"{"format":2,"modules":[]}"#).is_err()
        );
        assert!(ArtifactManifest::parse(
            Path::new("/a"),
            r#"{"format":1,"modules":[{"graph":"g"}]}"#
        )
        .is_err());
    }

    #[test]
    fn loads_real_artifacts_if_built() {
        let dir = ArtifactManifest::default_dir();
        if !dir.join("manifest.json").exists() {
            crate::log!(Warn, "skipping: run `make artifacts` first");
            return;
        }
        let m = ArtifactManifest::load(&dir).unwrap();
        assert!(m.find("count_split", "small").is_some());
        assert!(m.find("count_split_ref", "small").is_some());
        for spec in &m.modules {
            assert!(spec.path.exists(), "{:?} missing", spec.path);
        }
    }
}

//! L3 ↔ L1/L2 bridge: load the AOT-compiled HLO artifacts and serve
//! support-count executions to map tasks over a channel.
//!
//! PJRT handles are not `Send` (`xla` crate types wrap raw pointers), so a
//! dedicated **service thread** owns the `PjRtClient` and all compiled
//! executables; the rest of the system talks to it through the cloneable
//! [`TensorServiceHandle`]. This mirrors how a real deployment would pin an
//! accelerator context to a device-owning thread, with map tasks queueing
//! batched count requests.

pub mod artifacts;
pub mod service;
pub mod xla_stub;

pub use artifacts::{ArtifactManifest, ModuleSpec};
pub use service::{CountRequest, TensorService, TensorServiceHandle};

//! The tensor service: a device-owning thread wrapping the PJRT CPU client.
//!
//! `xla` crate handles are `!Send`, so one thread owns the client and the
//! compile cache; everything else holds a cloneable [`TensorServiceHandle`]
//! and performs synchronous `count` RPCs over mpsc channels. Requests carry
//! encoded bitmap blocks of *any* live size — the service chunks them into
//! the artifact's fixed (t, i, c) tile shape, pads, executes, and reduces.

use std::collections::HashMap;
use std::sync::mpsc;
use std::thread::JoinHandle;

use crate::data::bitmap::{BitmapBlock, CandidateBlock};

// The PJRT client API. The offline build binds the in-tree stub (every
// call errors with `ServiceError::Xla`); linking the real `xla` crate is a
// one-line swap here once the native toolchain is available.
use super::xla_stub as xla;

use super::artifacts::{ArtifactManifest, ModuleSpec};

/// One support-count request over encoded blocks.
#[derive(Debug)]
pub struct CountRequest {
    /// Graph to run: `count_split` (pallas) or `count_split_ref` (oracle).
    pub graph: String,
    /// Transactions, already bitmap-encoded at some item width.
    pub block: BitmapBlock,
    /// Candidates encoded at the same item width.
    pub cands: CandidateBlock,
}

#[derive(Debug)]
pub enum ServiceError {
    NoFit {
        graph: String,
        items: usize,
        cands: usize,
    },
    Xla(String),
    Stopped,
    WidthMismatch { block: usize, cands: usize },
}

impl std::fmt::Display for ServiceError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Self::NoFit { graph, items, cands } => {
                write!(f, "no artifact fits graph={graph} items={items} cands={cands}")
            }
            Self::Xla(msg) => write!(f, "xla: {msg}"),
            Self::Stopped => write!(f, "tensor service stopped"),
            Self::WidthMismatch { block, cands } => {
                write!(f, "item width mismatch: block {block} vs cands {cands}")
            }
        }
    }
}

impl std::error::Error for ServiceError {}

enum Msg {
    Count {
        req: CountRequest,
        reply: mpsc::Sender<Result<Vec<u32>, ServiceError>>,
    },
    /// Number of modules compiled so far (introspection for tests/metrics).
    Stats {
        reply: mpsc::Sender<usize>,
    },
    Shutdown,
}

/// Handle to the service thread. Clone freely; all clones talk to the same
/// PJRT client. The sender sits behind a mutex so the handle is `Sync` and
/// can be shared by reference across tasktracker threads (`std::mpsc`
/// senders are `Send` but not `Sync`); the critical section is just the
/// enqueue, not the execution.
pub struct TensorServiceHandle {
    tx: std::sync::Mutex<mpsc::Sender<Msg>>,
}

impl Clone for TensorServiceHandle {
    fn clone(&self) -> Self {
        Self {
            tx: std::sync::Mutex::new(self.tx.lock().unwrap().clone()),
        }
    }
}

impl TensorServiceHandle {
    fn send(&self, msg: Msg) -> Result<(), ServiceError> {
        self.tx
            .lock()
            .unwrap()
            .send(msg)
            .map_err(|_| ServiceError::Stopped)
    }

    /// Count supports: returns one count per **live** candidate row.
    pub fn count(&self, req: CountRequest) -> Result<Vec<u32>, ServiceError> {
        let (rtx, rrx) = mpsc::channel();
        self.send(Msg::Count { req, reply: rtx })?;
        rrx.recv().map_err(|_| ServiceError::Stopped)?
    }

    /// How many distinct modules have been compiled.
    pub fn compiled_modules(&self) -> Result<usize, ServiceError> {
        let (rtx, rrx) = mpsc::channel();
        self.send(Msg::Stats { reply: rtx })?;
        rrx.recv().map_err(|_| ServiceError::Stopped)
    }
}

/// The running service; dropping it shuts the thread down.
pub struct TensorService {
    tx: mpsc::Sender<Msg>,
    join: Option<JoinHandle<()>>,
}

impl TensorService {
    /// Start the service against an artifact directory. Fails fast if the
    /// manifest is unreadable; PJRT client creation happens on the service
    /// thread (first error surfaces on the first request).
    pub fn start(manifest: ArtifactManifest) -> Self {
        let (tx, rx) = mpsc::channel::<Msg>();
        let join = std::thread::Builder::new()
            .name("tensor-service".into())
            .spawn(move || service_loop(manifest, rx))
            .expect("spawn tensor-service");
        Self { tx, join: Some(join) }
    }

    /// Start from the default artifacts directory.
    pub fn start_default() -> Result<Self, super::artifacts::ManifestError> {
        Ok(Self::start(ArtifactManifest::load(
            &ArtifactManifest::default_dir(),
        )?))
    }

    pub fn handle(&self) -> TensorServiceHandle {
        TensorServiceHandle {
            tx: std::sync::Mutex::new(self.tx.clone()),
        }
    }
}

impl Drop for TensorService {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

struct Compiled {
    exe: xla::PjRtLoadedExecutable,
    spec: ModuleSpec,
}

fn service_loop(manifest: ArtifactManifest, rx: mpsc::Receiver<Msg>) {
    let mut client: Option<xla::PjRtClient> = None;
    let mut cache: HashMap<(String, String), Compiled> = HashMap::new();
    while let Ok(msg) = rx.recv() {
        match msg {
            Msg::Shutdown => break,
            Msg::Stats { reply } => {
                let _ = reply.send(cache.len());
            }
            Msg::Count { req, reply } => {
                let res = handle_count(&manifest, &mut client, &mut cache, req);
                let _ = reply.send(res);
            }
        }
    }
}

fn handle_count(
    manifest: &ArtifactManifest,
    client: &mut Option<xla::PjRtClient>,
    cache: &mut HashMap<(String, String), Compiled>,
    req: CountRequest,
) -> Result<Vec<u32>, ServiceError> {
    if req.block.n_items != req.cands.n_items {
        return Err(ServiceError::WidthMismatch {
            block: req.block.n_items,
            cands: req.cands.n_items,
        });
    }
    let spec = manifest
        .best_fit(&req.graph, req.block.n_items, req.cands.n_live.max(1))
        .ok_or_else(|| ServiceError::NoFit {
            graph: req.graph.clone(),
            items: req.block.n_items,
            cands: req.cands.n_live,
        })?
        .clone();

    if client.is_none() {
        *client = Some(xla::PjRtClient::cpu().map_err(|e| ServiceError::Xla(e.to_string()))?);
    }
    let key = (spec.graph.clone(), spec.variant.clone());
    if !cache.contains_key(&key) {
        let proto = xla::HloModuleProto::from_text_file(&spec.path)
            .map_err(|e| ServiceError::Xla(format!("load {:?}: {e}", spec.path)))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .as_ref()
            .unwrap()
            .compile(&comp)
            .map_err(|e| ServiceError::Xla(format!("compile {:?}: {e}", spec.path)))?;
        cache.insert(key.clone(), Compiled { exe, spec: spec.clone() });
    }
    let compiled = cache.get(&key).unwrap();
    execute_chunked(compiled, &req)
}

/// Chunk an arbitrary-size (block × candidates) request into the module's
/// fixed (t, i, c) shape: transactions chunk along rows (counts summed),
/// candidates chunk along columns (counts concatenated). Inputs narrower
/// than the module's item width are zero-padded on the right; padded
/// candidate slots carry an unmatchable cardinality (encoder invariant).
fn execute_chunked(compiled: &Compiled, req: &CountRequest) -> Result<Vec<u32>, ServiceError> {
    let spec = &compiled.spec;
    let (bt, bi) = (req.block.t_pad, req.block.n_items);
    let n_live_c = req.cands.n_live;
    let mut counts = vec![0u32; n_live_c];

    for c0 in (0..n_live_c).step_by(spec.c) {
        let c1 = (c0 + spec.c).min(n_live_c);
        // Build the (spec.c, spec.i) candidate tile.
        let mut cand = vec![0f32; spec.c * spec.i];
        let mut sizes = vec![(spec.i + 1) as f32; spec.c];
        for (dst, src) in (c0..c1).enumerate() {
            let s = &req.cands.cand[src * bi..(src + 1) * bi];
            cand[dst * spec.i..dst * spec.i + bi].copy_from_slice(s);
            sizes[dst] = req.cands.sizes[src];
        }
        for t0 in (0..bt).step_by(spec.t) {
            let t1 = (t0 + spec.t).min(bt);
            if req.block.mask[t0..t1].iter().all(|&m| m == 0.0) {
                continue; // fully padded row chunk contributes nothing
            }
            // Build the (spec.t, spec.i) transaction tile + mask column.
            let mut tx = vec![0f32; spec.t * spec.i];
            let mut mask = vec![0f32; spec.t];
            for (dst, src) in (t0..t1).enumerate() {
                let s = &req.block.tx[src * bi..(src + 1) * bi];
                tx[dst * spec.i..dst * spec.i + bi].copy_from_slice(s);
                mask[dst] = req.block.mask[src];
            }
            let partial = execute_one(compiled, &tx, &mask, &cand, &sizes)?;
            for (dst, src) in (c0..c1).enumerate() {
                counts[src] += partial[dst] as u32;
            }
        }
    }
    Ok(counts)
}

/// One PJRT execution at exactly the module's shape.
fn execute_one(
    compiled: &Compiled,
    tx: &[f32],
    mask: &[f32],
    cand: &[f32],
    sizes: &[f32],
) -> Result<Vec<f32>, ServiceError> {
    let spec = &compiled.spec;
    let xla_err = |e: xla::Error| ServiceError::Xla(e.to_string());
    let (t, i, c) = (spec.t as i64, spec.i as i64, spec.c as i64);
    let tx_l = xla::Literal::vec1(tx).reshape(&[t, i]).map_err(xla_err)?;
    let mask_l = xla::Literal::vec1(mask).reshape(&[t, 1]).map_err(xla_err)?;
    let cand_l = xla::Literal::vec1(cand).reshape(&[c, i]).map_err(xla_err)?;
    let sizes_l = xla::Literal::vec1(sizes).reshape(&[1, c]).map_err(xla_err)?;
    let result = compiled
        .exe
        .execute::<xla::Literal>(&[tx_l, mask_l, cand_l, sizes_l])
        .map_err(xla_err)?[0][0]
        .to_literal_sync()
        .map_err(xla_err)?;
    // Lowered with return_tuple=True → unwrap the 1-tuple.
    let out = result.to_tuple1().map_err(xla_err)?;
    out.to_vec::<f32>().map_err(xla_err)
}

#[cfg(test)]
mod tests {
    //! Service tests require built artifacts (`make artifacts`); they skip
    //! (with a note) when the manifest is absent so `cargo test` stays
    //! green on a fresh checkout. Full coverage runs in CI order:
    //! `make artifacts && cargo test`.
    use super::*;
    use crate::data::bitmap::count_on_host;
    use crate::data::quest::{QuestGenerator, QuestParams};
    use crate::data::Transaction;
    use crate::util::rng::Xoshiro256;

    fn service() -> Option<TensorService> {
        let dir = ArtifactManifest::default_dir();
        if !dir.join("manifest.json").exists() {
            crate::log!(Warn, "skipping tensor-service test: run `make artifacts`");
            return None;
        }
        Some(TensorService::start(ArtifactManifest::load(&dir).unwrap()))
    }

    fn tiny_request(graph: &str) -> CountRequest {
        let txs = vec![
            Transaction::new([0u32, 1, 2]),
            Transaction::new([0u32, 2]),
            Transaction::new([1u32]),
        ];
        let cands = vec![vec![0u32], vec![0, 2], vec![1, 2], vec![3]];
        CountRequest {
            graph: graph.into(),
            block: BitmapBlock::encode(&txs, 64, 64).unwrap(),
            cands: CandidateBlock::encode(&cands, 64, 8).unwrap(),
        }
    }

    #[test]
    fn pallas_artifact_counts_tiny_db() {
        let Some(svc) = service() else { return };
        let counts = svc.handle().count(tiny_request("count_split")).unwrap();
        assert_eq!(counts, vec![2, 2, 1, 0]);
    }

    #[test]
    fn ref_artifact_matches_pallas_artifact() {
        let Some(svc) = service() else { return };
        let h = svc.handle();
        let a = h.count(tiny_request("count_split")).unwrap();
        let b = h.count(tiny_request("count_split_ref")).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn chunked_execution_matches_host_reference() {
        let Some(svc) = service() else { return };
        let h = svc.handle();
        // 600 transactions (3 chunks of t=256) × 150 candidates (3 chunks
        // of c=64 on the small variant) over a 64-item dictionary.
        let db = QuestGenerator::new(QuestParams {
            n_items: 64,
            ..QuestParams::dense(600)
        })
        .generate();
        let mut rng = Xoshiro256::seed_from_u64(12);
        let cands: Vec<Vec<u32>> = (0..150)
            .map(|_| {
                let k = rng.range_usize(1, 4);
                let mut v: Vec<u32> =
                    rng.sample_distinct(64, k).into_iter().map(|x| x as u32).collect();
                v.sort_unstable();
                v
            })
            .collect();
        let block = BitmapBlock::encode(&db.transactions, 64, 256).unwrap();
        let cblock = CandidateBlock::encode(&cands, 64, 64).unwrap();
        let host = count_on_host(&block, &cblock);
        let got = h
            .count(CountRequest {
                graph: "count_split".into(),
                block,
                cands: cblock,
            })
            .unwrap();
        assert_eq!(got.len(), 150);
        assert_eq!(&host[..150], &got[..]);
    }

    #[test]
    fn width_mismatch_rejected() {
        let Some(svc) = service() else { return };
        let h = svc.handle();
        let req = CountRequest {
            graph: "count_split".into(),
            block: BitmapBlock::encode(&[Transaction::new([0u32])], 64, 64).unwrap(),
            cands: CandidateBlock::encode(&[vec![0u32]], 32, 8).unwrap(),
        };
        assert!(matches!(
            h.count(req),
            Err(ServiceError::WidthMismatch { .. })
        ));
    }

    #[test]
    fn unknown_graph_is_no_fit() {
        let Some(svc) = service() else { return };
        let req = CountRequest {
            graph: "nonexistent".into(),
            ..tiny_request("x")
        };
        assert!(matches!(
            svc.handle().count(req),
            Err(ServiceError::NoFit { .. })
        ));
    }

    #[test]
    fn compile_cache_reuses_modules() {
        let Some(svc) = service() else { return };
        let h = svc.handle();
        h.count(tiny_request("count_split")).unwrap();
        h.count(tiny_request("count_split")).unwrap();
        h.count(tiny_request("count_split")).unwrap();
        assert_eq!(h.compiled_modules().unwrap(), 1);
    }

    #[test]
    fn handles_are_cloneable_across_threads() {
        let Some(svc) = service() else { return };
        let h = svc.handle();
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let h = h.clone();
                std::thread::spawn(move || h.count(tiny_request("count_split")).unwrap())
            })
            .collect();
        for t in handles {
            assert_eq!(t.join().unwrap(), vec![2, 2, 1, 0]);
        }
    }
}

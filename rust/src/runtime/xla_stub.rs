//! Offline stand-in for the `xla` (PJRT) crate's API surface.
//!
//! The build environment has no native XLA toolchain, so the service binds
//! this stub instead of the real client: every entry point returns an
//! [`Error`], which the service surfaces as `ServiceError::Xla` on the
//! first count request. The tensor-path tests all skip when no artifacts
//! are built, so a stubbed runtime keeps `cargo test` green while leaving
//! the full three-layer wiring (manifest → compile cache → chunked
//! execution) compiled and exercised by the type checker. Swapping in the
//! real crate is the single `use` alias in `runtime::service`.

use std::path::Path;

/// Mirrors `xla::Error`'s `Display` surface.
#[derive(Debug)]
pub struct Error(String);

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

fn unavailable() -> Error {
    Error("PJRT runtime not linked in this build (runtime::xla_stub)".into())
}

/// Stub of `xla::PjRtClient`; construction always fails.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<Self, Error> {
        Err(unavailable())
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        Err(unavailable())
    }
}

/// Stub of `xla::HloModuleProto`.
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: impl AsRef<Path>) -> Result<Self, Error> {
        Err(unavailable())
    }
}

/// Stub of `xla::XlaComputation`.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        Self
    }
}

/// Stub of `xla::PjRtLoadedExecutable`.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(unavailable())
    }
}

/// Stub of `xla::PjRtBuffer`.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Err(unavailable())
    }
}

/// Stub of `xla::Literal`.
#[derive(Debug)]
pub struct Literal;

impl Literal {
    pub fn vec1(_values: &[f32]) -> Self {
        Self
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, Error> {
        Err(unavailable())
    }

    pub fn to_tuple1(&self) -> Result<Literal, Error> {
        Err(unavailable())
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
        Err(unavailable())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_entry_point_reports_unavailable() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("/nonexistent.hlo.txt").is_err());
        let exe = PjRtLoadedExecutable;
        assert!(exe.execute::<Literal>(&[]).is_err());
        let lit = Literal::vec1(&[1.0, 2.0]);
        assert!(lit.reshape(&[1, 2]).is_err());
        assert!(lit.to_tuple1().is_err());
        assert!(lit.to_vec::<f32>().is_err());
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("PJRT runtime not linked"));
    }
}

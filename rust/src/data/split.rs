//! Split planner: carve a transaction database into HDFS-block-sized map
//! splits, the unit of map-task scheduling (one map task per split, as in
//! Hadoop's FileInputFormat).

use super::{Transaction, TransactionDb};

/// One input split: a contiguous range of transactions.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Split {
    pub id: usize,
    /// Transaction index range `[start, end)` in the source db.
    pub start: usize,
    pub end: usize,
    /// Approximate byte size (drives block placement and cost models).
    pub bytes: usize,
}

impl Split {
    pub fn len(&self) -> usize {
        self.end - self.start
    }

    pub fn is_empty(&self) -> bool {
        self.start == self.end
    }
}

/// Plan splits of at most `max_tx` transactions each (Hadoop splits by
/// bytes; transactions here are near-constant-size so counting rows keeps
/// the tests exact while `bytes` still carries the size signal).
pub fn plan_splits(db: &TransactionDb, max_tx: usize) -> Vec<Split> {
    assert!(max_tx > 0, "split size must be positive");
    let mut splits = Vec::new();
    let mut start = 0usize;
    let mut id = 0usize;
    while start < db.len() {
        let end = (start + max_tx).min(db.len());
        let bytes: usize = db.transactions[start..end]
            .iter()
            .map(|t| t.len() * 6 + 1)
            .sum();
        splits.push(Split { id, start, end, bytes });
        id += 1;
        start = end;
    }
    splits
}

/// Materialize the transactions of one split.
pub fn split_transactions<'a>(db: &'a TransactionDb, s: &Split) -> &'a [Transaction] {
    &db.transactions[s.start..s.end]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::quest::{QuestGenerator, QuestParams};

    #[test]
    fn covers_db_exactly_without_overlap() {
        let db = QuestGenerator::new(QuestParams::t10_i4(1003)).generate();
        let splits = plan_splits(&db, 100);
        assert_eq!(splits.len(), 11);
        assert_eq!(splits[0].len(), 100);
        assert_eq!(splits[10].len(), 3);
        let mut covered = 0;
        for (i, s) in splits.iter().enumerate() {
            assert_eq!(s.id, i);
            assert_eq!(s.start, covered);
            covered = s.end;
            assert!(s.bytes > 0);
        }
        assert_eq!(covered, db.len());
    }

    #[test]
    fn single_split_when_db_fits() {
        let db = QuestGenerator::new(QuestParams::t10_i4(10)).generate();
        let splits = plan_splits(&db, 100);
        assert_eq!(splits.len(), 1);
        assert_eq!(splits[0].len(), 10);
    }

    #[test]
    fn empty_db_no_splits() {
        let db = TransactionDb::new(vec![]);
        assert!(plan_splits(&db, 10).is_empty());
    }

    #[test]
    fn split_transactions_slices() {
        let db = QuestGenerator::new(QuestParams::t10_i4(50)).generate();
        let splits = plan_splits(&db, 20);
        let total: usize = splits
            .iter()
            .map(|s| split_transactions(&db, s).len())
            .sum();
        assert_eq!(total, 50);
        assert_eq!(
            split_transactions(&db, &splits[1])[0],
            db.transactions[20]
        );
    }
}

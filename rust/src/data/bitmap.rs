//! Bitmap block encoding for the tensor engine.
//!
//! Mirrors `python/tests/test_kernel.py::encode_bitmaps`: a block of
//! transactions becomes a row-major f32 `{0,1}` matrix `(t_pad × n_items)`
//! plus a `(t_pad × 1)` liveness mask; a candidate level becomes a
//! `(c_pad × n_items)` matrix plus a `(1 × c_pad)` cardinality row. Padding
//! candidates get an impossible cardinality (`n_items + 1`) so they can
//! never match a transaction — their counts come back 0 and are dropped.

use super::{ItemId, Transaction};

/// An item id fell outside the encoder's dictionary width — the caller
/// failed to project the database before encoding. Typed (rather than a
/// panic) because the width is a runtime artifact property: a serving
/// node fed an unprojected delta must surface a counting error, not die.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct EncodeError {
    /// The offending item id.
    pub item: ItemId,
    /// The encoder width it did not fit (`items must be < width`).
    pub width: usize,
    /// Which matrix was being encoded ("transaction" | "candidate").
    pub what: &'static str,
}

impl std::fmt::Display for EncodeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{} item {} out of encoder width {}",
            self.what, self.item, self.width
        )
    }
}

impl std::error::Error for EncodeError {}

/// A padded, bitmap-encoded transaction block ready for PJRT upload.
#[derive(Debug, Clone)]
pub struct BitmapBlock {
    /// Row-major `(t_pad, n_items)` {0,1} matrix.
    pub tx: Vec<f32>,
    /// `(t_pad, 1)` row-liveness mask.
    pub mask: Vec<f32>,
    pub t_pad: usize,
    pub n_items: usize,
    /// Number of live (unpadded) rows.
    pub n_live: usize,
}

impl BitmapBlock {
    /// Encode `transactions` into a block padded up to a multiple of
    /// `t_pad_to` rows (and at least one tile). Items `>= n_items` error —
    /// the caller must have projected the db to the engine's item width.
    pub fn encode(
        transactions: &[Transaction],
        n_items: usize,
        t_pad_to: usize,
    ) -> Result<Self, EncodeError> {
        assert!(t_pad_to > 0);
        let n_live = transactions.len();
        let t_pad = pad_up(n_live.max(1), t_pad_to);
        let mut tx = vec![0f32; t_pad * n_items];
        let mut mask = vec![0f32; t_pad];
        for (r, t) in transactions.iter().enumerate() {
            mask[r] = 1.0;
            for &item in &t.items {
                if (item as usize) >= n_items {
                    return Err(EncodeError { item, width: n_items, what: "transaction" });
                }
                tx[r * n_items + item as usize] = 1.0;
            }
        }
        Ok(Self { tx, mask, t_pad, n_items, n_live })
    }

    /// VMEM-style footprint of the block in bytes (f32).
    pub fn bytes(&self) -> usize {
        (self.tx.len() + self.mask.len()) * 4
    }
}

/// A padded, bitmap-encoded candidate level.
#[derive(Debug, Clone)]
pub struct CandidateBlock {
    /// Row-major `(c_pad, n_items)` {0,1} matrix.
    pub cand: Vec<f32>,
    /// `(1, c_pad)` candidate cardinalities (impossible value on padding).
    pub sizes: Vec<f32>,
    pub c_pad: usize,
    pub n_items: usize,
    /// Number of live (unpadded) candidate rows.
    pub n_live: usize,
}

impl CandidateBlock {
    /// Encode sorted candidate itemsets, padding up to a multiple of
    /// `c_pad_to` rows. Items `>= n_items` error, like
    /// [`BitmapBlock::encode`].
    pub fn encode(
        candidates: &[Vec<ItemId>],
        n_items: usize,
        c_pad_to: usize,
    ) -> Result<Self, EncodeError> {
        assert!(c_pad_to > 0);
        let n_live = candidates.len();
        let c_pad = pad_up(n_live.max(1), c_pad_to);
        let mut cand = vec![0f32; c_pad * n_items];
        // Impossible cardinality on padding rows: a zero candidate row with
        // size n_items+1 can never equal any overlap, so padded rows always
        // count 0 (matches the python encoder's semantics via mask+sizes).
        let mut sizes = vec![(n_items + 1) as f32; c_pad];
        for (r, items) in candidates.iter().enumerate() {
            sizes[r] = items.len() as f32;
            for &item in items {
                if (item as usize) >= n_items {
                    return Err(EncodeError { item, width: n_items, what: "candidate" });
                }
                cand[r * n_items + item as usize] = 1.0;
            }
        }
        Ok(Self { cand, sizes, c_pad, n_items, n_live })
    }

    pub fn bytes(&self) -> usize {
        (self.cand.len() + self.sizes.len()) * 4
    }
}

/// Round `n` up to a multiple of `m`.
pub fn pad_up(n: usize, m: usize) -> usize {
    n.div_ceil(m) * m
}

/// CPU reference of the containment count over encoded blocks — used to
/// differential-test the PJRT path byte-for-byte (see engine::tensor).
pub fn count_on_host(block: &BitmapBlock, cands: &CandidateBlock) -> Vec<u32> {
    assert_eq!(block.n_items, cands.n_items);
    let (ni, t_pad, c_pad) = (block.n_items, block.t_pad, cands.c_pad);
    let mut counts = vec![0u32; c_pad];
    for r in 0..t_pad {
        if block.mask[r] == 0.0 {
            continue;
        }
        let row = &block.tx[r * ni..(r + 1) * ni];
        for c in 0..c_pad {
            let crow = &cands.cand[c * ni..(c + 1) * ni];
            let overlap: f32 = row
                .iter()
                .zip(crow.iter())
                .map(|(a, b)| a * b)
                .sum();
            if overlap == cands.sizes[c] {
                counts[c] += 1;
            }
        }
    }
    counts
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::TransactionDb;

    fn tx(items: &[u32]) -> Transaction {
        Transaction::new(items.iter().copied())
    }

    #[test]
    fn pad_up_math() {
        assert_eq!(pad_up(0, 8), 0);
        assert_eq!(pad_up(1, 8), 8);
        assert_eq!(pad_up(8, 8), 8);
        assert_eq!(pad_up(9, 8), 16);
    }

    #[test]
    fn encode_shapes_and_mask() {
        let b = BitmapBlock::encode(&[tx(&[0, 2]), tx(&[1])], 4, 8).unwrap();
        assert_eq!(b.t_pad, 8);
        assert_eq!(b.n_live, 2);
        assert_eq!(b.tx.len(), 8 * 4);
        assert_eq!(&b.mask[..3], &[1.0, 1.0, 0.0]);
        assert_eq!(&b.tx[0..4], &[1.0, 0.0, 1.0, 0.0]);
        assert_eq!(&b.tx[4..8], &[0.0, 1.0, 0.0, 0.0]);
    }

    #[test]
    fn empty_block_still_one_tile() {
        let b = BitmapBlock::encode(&[], 4, 8).unwrap();
        assert_eq!(b.t_pad, 8);
        assert_eq!(b.n_live, 0);
        assert!(b.mask.iter().all(|&m| m == 0.0));
    }

    #[test]
    fn candidate_padding_is_unmatchable() {
        let c = CandidateBlock::encode(&[vec![0]], 4, 8).unwrap();
        assert_eq!(c.c_pad, 8);
        assert_eq!(c.sizes[0], 1.0);
        // padding rows: size 5 (=n_items+1) with all-zero row
        assert!(c.sizes[1..].iter().all(|&s| s == 5.0));
        let b = BitmapBlock::encode(&[tx(&[0, 1, 2, 3])], 4, 8).unwrap();
        let counts = count_on_host(&b, &c);
        assert_eq!(counts[0], 1);
        assert!(counts[1..].iter().all(|&x| x == 0));
    }

    #[test]
    fn host_count_matches_db_support() {
        let db = TransactionDb::new(vec![
            tx(&[0, 1, 2]),
            tx(&[0, 2]),
            tx(&[1]),
            tx(&[0, 1, 2, 3]),
        ]);
        let cands = vec![vec![0], vec![0, 2], vec![1, 2], vec![3]];
        let b = BitmapBlock::encode(&db.transactions, 4, 4).unwrap();
        let c = CandidateBlock::encode(&cands, 4, 4).unwrap();
        let counts = count_on_host(&b, &c);
        for (i, cand) in cands.iter().enumerate() {
            assert_eq!(counts[i] as usize, db.support(cand), "cand {cand:?}");
        }
    }

    #[test]
    fn oversized_item_is_a_typed_error_not_a_panic() {
        let err = BitmapBlock::encode(&[tx(&[9])], 4, 4).unwrap_err();
        assert_eq!(err, EncodeError { item: 9, width: 4, what: "transaction" });
        assert!(err.to_string().contains("out of encoder width 4"), "{err}");
        let err = CandidateBlock::encode(&[vec![2, 7]], 4, 4).unwrap_err();
        assert_eq!(err, EncodeError { item: 7, width: 4, what: "candidate" });
        // and it surfaces through the engine error type
        let engine_err = crate::engine::EngineError::from(err);
        assert!(engine_err.to_string().contains("bitmap encode"), "{engine_err}");
    }
}

//! IBM Quest-style synthetic transaction generator.
//!
//! The paper never names its dataset, so we substitute the standard
//! market-basket benchmark family (Agrawal & Srikant's Quest generator,
//! the source of T10.I4.D100K etc.): a pool of correlated "maximal
//! potentially-frequent itemsets" is drawn once, then each transaction is
//! assembled from a few pool patterns with corruption noise. This produces
//! the skewed support distribution Apriori's pruning exploits — uniform
//! random baskets would make every algorithm look identical.

use super::{ItemId, Transaction, TransactionDb};
use crate::util::rng::Xoshiro256;

/// Generator parameters, named after the Quest conventions:
/// `T` = average transaction length, `I` = average pattern length,
/// `D` = number of transactions, `N` = item universe, `L` = pattern pool.
#[derive(Debug, Clone)]
pub struct QuestParams {
    /// Number of transactions (|D|).
    pub n_transactions: usize,
    /// Item universe size (N).
    pub n_items: usize,
    /// Average transaction length (T).
    pub avg_tx_len: f64,
    /// Average maximal-pattern length (I).
    pub avg_pattern_len: f64,
    /// Number of potentially-frequent patterns in the pool (L).
    pub n_patterns: usize,
    /// Probability an item from a chosen pattern is dropped (corruption).
    pub corruption: f64,
    /// RNG seed — same seed, same dataset, across runs and machines.
    pub seed: u64,
}

impl QuestParams {
    /// The classic T10.I4 profile over a 1k-item universe, sized to `d`
    /// transactions — the fig-5 sweep uses this with varying `d`.
    pub fn t10_i4(d: usize) -> Self {
        Self {
            n_transactions: d,
            n_items: 1000,
            avg_tx_len: 10.0,
            avg_pattern_len: 4.0,
            n_patterns: 200,
            corruption: 0.25,
            seed: 0xACE5_2012,
        }
    }

    /// A small dense profile (few items, long baskets) where candidate
    /// explosion at k=2..3 is visible — exercises the `large` tile variant.
    pub fn dense(d: usize) -> Self {
        Self {
            n_transactions: d,
            n_items: 100,
            avg_tx_len: 15.0,
            avg_pattern_len: 5.0,
            n_patterns: 40,
            corruption: 0.15,
            seed: 0xDE45E, // dense-profile default seed
        }
    }

    /// The ~2000-transaction profile used by the paper's reference [8]
    /// (Goswami et al.) for the baseline comparison (ablation A3).
    pub fn goswami_2k() -> Self {
        Self {
            n_transactions: 2000,
            n_items: 120,
            avg_tx_len: 8.0,
            avg_pattern_len: 3.0,
            n_patterns: 60,
            corruption: 0.2,
            seed: 0x605A,
        }
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }
}

/// The generator itself. Deterministic for a given `QuestParams`.
#[derive(Debug)]
pub struct QuestGenerator {
    params: QuestParams,
}

impl QuestGenerator {
    pub fn new(params: QuestParams) -> Self {
        assert!(params.n_items >= 2, "need at least 2 items");
        assert!(params.avg_tx_len >= 1.0);
        assert!(params.avg_pattern_len >= 1.0);
        Self { params }
    }

    /// Draw the pattern pool: each pattern is a set of items, with some
    /// inter-pattern overlap (a fraction of items is reused from the
    /// previous pattern, per the original Quest design).
    fn pattern_pool(&self, rng: &mut Xoshiro256) -> Vec<Vec<ItemId>> {
        let p = &self.params;
        let mut pool: Vec<Vec<ItemId>> = Vec::with_capacity(p.n_patterns);
        for i in 0..p.n_patterns {
            let len = (1 + rng.poisson(p.avg_pattern_len - 1.0)).min(p.n_items);
            let mut items: Vec<ItemId> = Vec::with_capacity(len);
            // reuse ~half the items from the previous pattern for correlation
            if i > 0 && !pool[i - 1].is_empty() {
                let prev = &pool[i - 1];
                let reuse = (len / 2).min(prev.len());
                for &idx in rng.sample_distinct(prev.len(), reuse).iter() {
                    items.push(prev[idx]);
                }
            }
            while items.len() < len {
                let candidate = rng.gen_range(p.n_items as u64) as ItemId;
                if !items.contains(&candidate) {
                    items.push(candidate);
                }
            }
            items.sort_unstable();
            items.dedup();
            pool.push(items);
        }
        pool
    }

    /// Generate the full database.
    pub fn generate(&self) -> TransactionDb {
        let p = &self.params;
        let mut rng = Xoshiro256::seed_from_u64(p.seed);
        let pool = self.pattern_pool(&mut rng);
        // Pattern popularity is exponentially skewed (Quest uses an
        // exponential weight per pattern).
        let mut weights: Vec<f64> = (0..pool.len()).map(|_| rng.exponential(1.0)).collect();
        let total: f64 = weights.iter().sum();
        for w in &mut weights {
            *w /= total;
        }
        // cumulative distribution for pattern picking
        let mut cdf = Vec::with_capacity(weights.len());
        let mut acc = 0.0;
        for w in &weights {
            acc += w;
            cdf.push(acc);
        }

        let mut transactions = Vec::with_capacity(p.n_transactions);
        for _ in 0..p.n_transactions {
            let target_len = 1 + rng.poisson(p.avg_tx_len - 1.0);
            let mut items: Vec<ItemId> = Vec::with_capacity(target_len + 4);
            let mut guard = 0;
            while items.len() < target_len && guard < 64 {
                guard += 1;
                // pick a pattern by weight
                let u = rng.next_f64();
                let idx = cdf.partition_point(|&c| c < u).min(pool.len() - 1);
                for &item in &pool[idx] {
                    if rng.bool_with(p.corruption) {
                        continue; // corrupted away
                    }
                    items.push(item);
                    if items.len() >= target_len + 4 {
                        break;
                    }
                }
            }
            if items.is_empty() {
                // ensure non-empty baskets: add one uniform item
                items.push(rng.gen_range(p.n_items as u64) as ItemId);
            }
            transactions.push(Transaction::new(items));
        }
        let mut db = TransactionDb::new(transactions);
        // The universe is the configured N even if the tail never appears.
        db.n_items = p.n_items;
        db
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let a = QuestGenerator::new(QuestParams::t10_i4(500)).generate();
        let b = QuestGenerator::new(QuestParams::t10_i4(500)).generate();
        assert_eq!(a.transactions, b.transactions);
    }

    #[test]
    fn seed_changes_dataset() {
        let a = QuestGenerator::new(QuestParams::t10_i4(200)).generate();
        let b = QuestGenerator::new(QuestParams::t10_i4(200).with_seed(99)).generate();
        assert_ne!(a.transactions, b.transactions);
    }

    #[test]
    fn shape_matches_params() {
        let p = QuestParams::t10_i4(1000);
        let db = QuestGenerator::new(p.clone()).generate();
        assert_eq!(db.len(), 1000);
        assert_eq!(db.n_items, p.n_items);
        assert!(db.transactions.iter().all(|t| !t.is_empty()));
        let avg = db.total_items() as f64 / db.len() as f64;
        assert!(
            (avg - p.avg_tx_len).abs() < p.avg_tx_len * 0.5,
            "avg basket len {avg} vs configured {}",
            p.avg_tx_len
        );
    }

    #[test]
    fn support_distribution_is_skewed() {
        // Pattern reuse must create items far above the uniform-support
        // baseline — that skew is what makes Apriori's pruning meaningful.
        let db = QuestGenerator::new(QuestParams::t10_i4(2000)).generate();
        let mut supports: Vec<usize> = (0..db.n_items as u32)
            .map(|i| db.support(&[i]))
            .collect();
        supports.sort_unstable_by(|a, b| b.cmp(a));
        let uniform = db.total_items() as f64 / db.n_items as f64;
        assert!(
            supports[0] as f64 > uniform * 5.0,
            "top item support {} should dominate uniform {uniform}",
            supports[0]
        );
    }

    #[test]
    fn dense_profile_is_denser() {
        let sparse = QuestGenerator::new(QuestParams::t10_i4(500)).generate();
        let dense = QuestGenerator::new(QuestParams::dense(500)).generate();
        let d_sparse = sparse.total_items() as f64 / (sparse.len() * sparse.n_items) as f64;
        let d_dense = dense.total_items() as f64 / (dense.len() * dense.n_items) as f64;
        assert!(d_dense > d_sparse * 5.0);
    }

    #[test]
    fn goswami_profile_sizes() {
        let db = QuestGenerator::new(QuestParams::goswami_2k()).generate();
        assert_eq!(db.len(), 2000);
        assert_eq!(db.n_items, 120);
    }
}

//! On-disk `.dat` transaction format (the FIMI repository convention the
//! Apriori literature uses): one transaction per line, space-separated
//! integer item ids. Reader tolerates blank lines and `#` comments.

use std::io::{BufRead, BufReader, BufWriter, Write};
use std::path::Path;

use super::{Transaction, TransactionDb};

#[derive(Debug)]
pub enum DatError {
    Io(std::io::Error),
    BadItem { line: usize, token: String },
}

impl std::fmt::Display for DatError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Self::Io(e) => write!(f, "io error: {e}"),
            Self::BadItem { line, token } => write!(f, "line {line}: bad item '{token}'"),
        }
    }
}

impl std::error::Error for DatError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Self::Io(e) => Some(e),
            Self::BadItem { .. } => None,
        }
    }
}

impl From<std::io::Error> for DatError {
    fn from(e: std::io::Error) -> Self {
        Self::Io(e)
    }
}

/// Write a database in `.dat` format.
pub fn write_dat(db: &TransactionDb, path: &Path) -> Result<(), DatError> {
    let mut w = BufWriter::new(std::fs::File::create(path)?);
    for t in &db.transactions {
        let mut first = true;
        for item in &t.items {
            if !first {
                write!(w, " ")?;
            }
            write!(w, "{item}")?;
            first = false;
        }
        writeln!(w)?;
    }
    w.flush()?;
    Ok(())
}

/// Read a `.dat` database.
pub fn read_dat(path: &Path) -> Result<TransactionDb, DatError> {
    let r = BufReader::new(std::fs::File::open(path)?);
    let mut transactions = Vec::new();
    for (ln, line) in r.lines().enumerate() {
        let line = line?;
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut items = Vec::new();
        for token in line.split_ascii_whitespace() {
            let item = token.parse::<u32>().map_err(|_| DatError::BadItem {
                line: ln + 1,
                token: token.to_string(),
            })?;
            items.push(item);
        }
        transactions.push(Transaction::new(items));
    }
    Ok(TransactionDb::new(transactions))
}

/// Serialize one transaction to its `.dat` line (used by the DFS block
/// writer, which stores line-delimited slices of the db).
pub fn tx_to_line(t: &Transaction) -> String {
    t.items
        .iter()
        .map(|i| i.to_string())
        .collect::<Vec<_>>()
        .join(" ")
}

/// Parse one `.dat` line (used by map tasks reading DFS blocks).
pub fn line_to_tx(line: &str) -> Option<Transaction> {
    let line = line.trim();
    if line.is_empty() || line.starts_with('#') {
        return None;
    }
    let items: Option<Vec<u32>> = line
        .split_ascii_whitespace()
        .map(|t| t.parse::<u32>().ok())
        .collect();
    items.map(Transaction::new)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::quest::{QuestGenerator, QuestParams};

    #[test]
    fn roundtrip_through_file() {
        let db = QuestGenerator::new(QuestParams::t10_i4(200)).generate();
        let dir = std::env::temp_dir().join("mr_apriori_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("roundtrip.dat");
        write_dat(&db, &p).unwrap();
        let back = read_dat(&p).unwrap();
        assert_eq!(db.transactions, back.transactions);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn reader_skips_comments_and_blanks() {
        let dir = std::env::temp_dir().join("mr_apriori_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("comments.dat");
        std::fs::write(&p, "# header\n1 2 3\n\n4 5\n# trailer\n").unwrap();
        let db = read_dat(&p).unwrap();
        assert_eq!(db.len(), 2);
        assert_eq!(db.transactions[0].items, vec![1, 2, 3]);
        assert_eq!(db.transactions[1].items, vec![4, 5]);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn reader_rejects_garbage() {
        let dir = std::env::temp_dir().join("mr_apriori_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad.dat");
        std::fs::write(&p, "1 2 x\n").unwrap();
        let err = read_dat(&p).unwrap_err();
        assert!(matches!(err, DatError::BadItem { line: 1, .. }));
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn line_roundtrip() {
        let t = Transaction::new([3, 1, 2]);
        let line = tx_to_line(&t);
        assert_eq!(line, "1 2 3");
        assert_eq!(line_to_tx(&line).unwrap(), t);
        assert!(line_to_tx("# comment").is_none());
        assert!(line_to_tx("   ").is_none());
        assert!(line_to_tx("1 bad").is_none());
    }
}

//! Transaction data substrate: item dictionary, transaction database,
//! IBM Quest-style synthetic workload generator, on-disk `.dat` format,
//! bitmap block encoding for the tensor engine, the columnar (CSR)
//! flattened block the vertical engine indexes from, and the split
//! planner that carves a database into HDFS-block-sized map splits.

pub mod bitmap;
pub mod columnar;
pub mod io;
pub mod quest;
pub mod split;

use std::collections::BTreeSet;

/// Dense item identifier. The paper's datasets are market-basket style —
/// items are SKUs; we re-encode to dense u32 ids at load time.
pub type ItemId = u32;

/// Sorted-merge containment: does sorted `b` contain every item of sorted
/// `a`? The shared primitive behind [`Transaction::contains_all`], the
/// closed/maximal post-processing and the serving rule index.
pub fn is_subset(a: &[ItemId], b: &[ItemId]) -> bool {
    let mut it = b.iter();
    'outer: for want in a {
        for have in it.by_ref() {
            match have.cmp(want) {
                std::cmp::Ordering::Equal => continue 'outer,
                std::cmp::Ordering::Greater => return false,
                std::cmp::Ordering::Less => {}
            }
        }
        return false;
    }
    true
}

/// First index `>= lo` with `b[idx] >= x` (or `b.len()`), by exponential
/// probe + binary search — the galloping step that makes skewed-size
/// sorted-list intersections cost `O(small · log large)`.
fn gallop(b: &[u32], lo: usize, x: u32) -> usize {
    if lo >= b.len() || b[lo] >= x {
        return lo;
    }
    // Invariant: b[prev] < x; probe doubles until it overshoots.
    let mut prev = lo;
    let mut step = 1usize;
    loop {
        let cur = prev + step;
        if cur >= b.len() {
            break;
        }
        if b[cur] >= x {
            break;
        }
        prev = cur;
        step *= 2;
    }
    let hi = (prev + step).min(b.len());
    let (mut l, mut r) = (prev + 1, hi);
    while l < r {
        let m = l + (r - l) / 2;
        if b[m] < x {
            l = m + 1;
        } else {
            r = m;
        }
    }
    l
}

/// Galloping intersection of two sorted `u32` lists into `out`
/// (cleared). The shared primitive behind the vertical engine's sparse
/// TID index and [`crate::apriori::intersection::IntersectionApriori`]'s
/// tidset miner — like [`is_subset`], one copy for every sorted-merge
/// consumer.
pub fn intersect_sorted_into(a: &[u32], b: &[u32], out: &mut Vec<u32>) {
    out.clear();
    let (small, large) = if a.len() <= b.len() { (a, b) } else { (b, a) };
    let mut lo = 0usize;
    for &x in small {
        lo = gallop(large, lo, x);
        if lo == large.len() {
            break;
        }
        if large[lo] == x {
            out.push(x);
            lo += 1;
        }
    }
}

/// Galloping count-only intersection of two sorted `u32` lists — nothing
/// is materialized.
pub fn intersect_sorted_count(a: &[u32], b: &[u32]) -> u64 {
    let (small, large) = if a.len() <= b.len() { (a, b) } else { (b, a) };
    let mut count = 0u64;
    let mut lo = 0usize;
    for &x in small {
        lo = gallop(large, lo, x);
        if lo == large.len() {
            break;
        }
        if large[lo] == x {
            count += 1;
            lo += 1;
        }
    }
    count
}

/// One transaction: a sorted, deduplicated set of item ids.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Transaction {
    pub items: Vec<ItemId>,
}

impl Transaction {
    /// Build from any iterator, sorting + deduplicating.
    pub fn new(items: impl IntoIterator<Item = ItemId>) -> Self {
        let set: BTreeSet<ItemId> = items.into_iter().collect();
        Self { items: set.into_iter().collect() }
    }

    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    /// Sorted-merge containment test: does this transaction contain every
    /// item of `subset` (which must be sorted ascending)?
    pub fn contains_all(&self, subset: &[ItemId]) -> bool {
        is_subset(subset, &self.items)
    }
}

/// An in-memory transaction database plus its item universe.
#[derive(Debug, Clone, Default)]
pub struct TransactionDb {
    pub transactions: Vec<Transaction>,
    /// Number of distinct item ids (ids are `0..n_items`).
    pub n_items: usize,
}

impl TransactionDb {
    pub fn new(transactions: Vec<Transaction>) -> Self {
        let n_items = transactions
            .iter()
            .flat_map(|t| t.items.iter())
            .map(|&i| i as usize + 1)
            .max()
            .unwrap_or(0);
        Self { transactions, n_items }
    }

    pub fn len(&self) -> usize {
        self.transactions.len()
    }

    pub fn is_empty(&self) -> bool {
        self.transactions.is_empty()
    }

    /// Total item occurrences (the "volume" knob in fig 5 terms).
    pub fn total_items(&self) -> usize {
        self.transactions.iter().map(|t| t.len()).sum()
    }

    /// Approximate on-disk size in bytes under the `.dat` text format —
    /// used by the DFS to account block storage against node capacity.
    pub fn approx_bytes(&self) -> usize {
        // each item ~6 chars incl separator, newline per tx
        self.total_items() * 6 + self.len()
    }

    /// Absolute support count of one (sorted) itemset — the slow oracle
    /// every optimized counting path is tested against.
    pub fn support(&self, itemset: &[ItemId]) -> usize {
        self.transactions
            .iter()
            .filter(|t| t.contains_all(itemset))
            .count()
    }

    /// Append a delta of transactions in place (micro-batch ingest for
    /// the serving layer), growing the item universe if the delta
    /// introduces ids beyond it.
    pub fn append(&mut self, delta: impl IntoIterator<Item = Transaction>) {
        for t in delta {
            if let Some(&max) = t.items.last() {
                self.n_items = self.n_items.max(max as usize + 1);
            }
            self.transactions.push(t);
        }
    }

    /// Re-encode keeping only `keep` items (sorted), remapping them to
    /// dense ids `0..keep.len()`. Returns the new db and the mapping
    /// `new_id -> old_id`. This is the classic Apriori dictionary-shrink:
    /// after F1, only frequent items matter, which keeps the bitmap item
    /// width small for the tensor engine.
    pub fn project(&self, keep: &[ItemId]) -> (TransactionDb, Vec<ItemId>) {
        let mut old_to_new = vec![u32::MAX; self.n_items];
        for (new, &old) in keep.iter().enumerate() {
            old_to_new[old as usize] = new as u32;
        }
        let transactions = self
            .transactions
            .iter()
            .map(|t| Transaction {
                items: t
                    .items
                    .iter()
                    .filter_map(|&i| {
                        let n = old_to_new[i as usize];
                        (n != u32::MAX).then_some(n)
                    })
                    .collect(),
            })
            .collect();
        (
            TransactionDb { transactions, n_items: keep.len() },
            keep.to_vec(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tx(items: &[u32]) -> Transaction {
        Transaction::new(items.iter().copied())
    }

    #[test]
    fn transaction_sorts_and_dedups() {
        let t = tx(&[5, 1, 3, 1, 5]);
        assert_eq!(t.items, vec![1, 3, 5]);
        assert_eq!(t.len(), 3);
    }

    #[test]
    fn contains_all_sorted_merge() {
        let t = tx(&[1, 3, 5, 9]);
        assert!(t.contains_all(&[]));
        assert!(t.contains_all(&[1]));
        assert!(t.contains_all(&[3, 9]));
        assert!(t.contains_all(&[1, 3, 5, 9]));
        assert!(!t.contains_all(&[2]));
        assert!(!t.contains_all(&[1, 4]));
        assert!(!t.contains_all(&[9, 10]));
    }

    #[test]
    fn empty_transaction_contains_only_empty() {
        let t = tx(&[]);
        assert!(t.contains_all(&[]));
        assert!(!t.contains_all(&[0]));
    }

    #[test]
    fn gallop_finds_lower_bound() {
        let b = [2u32, 4, 4, 8, 16, 32, 64];
        assert_eq!(gallop(&b, 0, 1), 0);
        assert_eq!(gallop(&b, 0, 2), 0);
        assert_eq!(gallop(&b, 0, 3), 1);
        assert_eq!(gallop(&b, 0, 9), 4);
        assert_eq!(gallop(&b, 0, 64), 6);
        assert_eq!(gallop(&b, 0, 65), 7);
        assert_eq!(gallop(&b, 3, 4), 3); // lo already at the match stays put
        assert_eq!(gallop(&[], 0, 5), 0);
    }

    #[test]
    fn sorted_intersections_match_sorted_merge() {
        let a = vec![1u32, 3, 5, 7, 9, 100, 200];
        let b = vec![3u32, 4, 5, 8, 9, 200, 201];
        let mut out = Vec::new();
        intersect_sorted_into(&a, &b, &mut out);
        assert_eq!(out, vec![3, 5, 9, 200]);
        assert_eq!(intersect_sorted_count(&a, &b), 4);
        // skew (galloping path) both ways
        let big: Vec<u32> = (0..1000).collect();
        intersect_sorted_into(&[500, 999], &big, &mut out);
        assert_eq!(out, vec![500, 999]);
        assert_eq!(intersect_sorted_count(&big, &[0, 1000]), 1);
        intersect_sorted_into(&[], &big, &mut out);
        assert!(out.is_empty());
    }

    #[test]
    fn db_support_counts() {
        let db = TransactionDb::new(vec![tx(&[0, 1, 2]), tx(&[0, 2]), tx(&[1])]);
        assert_eq!(db.n_items, 3);
        assert_eq!(db.support(&[0]), 2);
        assert_eq!(db.support(&[0, 2]), 2);
        assert_eq!(db.support(&[1, 2]), 1);
        assert_eq!(db.support(&[]), 3);
        assert_eq!(db.support(&[2, 1, 0].to_vec().as_slice()), 0); // unsorted -> no match
    }

    #[test]
    fn db_volume_accounting() {
        let db = TransactionDb::new(vec![tx(&[0, 1]), tx(&[2])]);
        assert_eq!(db.total_items(), 3);
        assert!(db.approx_bytes() > 0);
    }

    #[test]
    fn project_remaps_and_filters() {
        let db = TransactionDb::new(vec![tx(&[0, 2, 4]), tx(&[1, 2]), tx(&[4])]);
        let (p, map) = db.project(&[2, 4]);
        assert_eq!(p.n_items, 2);
        assert_eq!(map, vec![2, 4]);
        assert_eq!(p.transactions[0].items, vec![0, 1]); // {2,4} -> {0,1}
        assert_eq!(p.transactions[1].items, vec![0]); // {2} -> {0}
        assert_eq!(p.transactions[2].items, vec![1]); // {4} -> {1}
        // support is preserved under projection
        assert_eq!(p.support(&[0]), db.support(&[2]));
        assert_eq!(p.support(&[0, 1]), db.support(&[2, 4]));
    }

    #[test]
    fn append_grows_db_and_item_universe() {
        let mut db = TransactionDb::new(vec![tx(&[0, 1])]);
        assert_eq!((db.len(), db.n_items), (1, 2));
        db.append([tx(&[1, 4]), tx(&[0])]);
        assert_eq!((db.len(), db.n_items), (3, 5));
        assert_eq!(db.support(&[1]), 2);
        db.append(std::iter::empty());
        assert_eq!((db.len(), db.n_items), (3, 5));
        // empty transactions don't shrink the universe
        db.append([tx(&[])]);
        assert_eq!((db.len(), db.n_items), (4, 5));
    }

    #[test]
    fn empty_db() {
        let db = TransactionDb::new(vec![]);
        assert_eq!(db.n_items, 0);
        assert_eq!(db.support(&[1]), 0);
        assert!(db.is_empty());
    }
}

//! Columnar (CSR) transaction block layout — the cache-friendly form of
//! a map split.
//!
//! A `Vec<Transaction>` slice is a pointer chase: every transaction is
//! its own heap allocation, so a counting inner loop that streams the
//! whole split touches one allocation per row. [`FlatBlock`] flattens a
//! split once into two dense arrays — `items` (every item occurrence,
//! transaction-major) and `offsets` (CSR row starts) — so index builds
//! and per-transaction scans walk contiguous memory. The vertical
//! engine ([`crate::engine::VerticalEngine`]) builds its item→TID index
//! from this layout, and the block's occupancy statistics
//! ([`density`](FlatBlock::density)) drive its dense/sparse cutover.

use super::{ItemId, Transaction};

/// A flattened transaction block: CSR over item occurrences.
#[derive(Debug, Clone)]
pub struct FlatBlock {
    /// Every item occurrence, transaction-major; row `t` occupies
    /// `items[offsets[t]..offsets[t+1]]` and inherits the transaction's
    /// sorted order.
    items: Vec<ItemId>,
    /// Row starts, `len() + 1` entries, `offsets[0] == 0`. `u32` keeps
    /// the block half the size of `usize` offsets; a map split holds
    /// far fewer than 2^32 item occurrences.
    offsets: Vec<u32>,
    /// Dictionary width the block spans: at least the caller's hint,
    /// grown to cover any item id actually present.
    n_items: usize,
}

impl FlatBlock {
    /// Flatten a transaction slice. `n_items_hint` is the projected
    /// dictionary width the caller counts over; ids beyond it grow the
    /// block's width rather than erroring (the naive oracle ignores the
    /// hint too, and the engines must agree with it byte-for-byte).
    pub fn from_transactions(txs: &[Transaction], n_items_hint: usize) -> Self {
        let total: usize = txs.iter().map(|t| t.len()).sum();
        assert!(
            total < u32::MAX as usize,
            "flat block overflows u32 offsets ({total} item occurrences)"
        );
        let mut items = Vec::with_capacity(total);
        let mut offsets = Vec::with_capacity(txs.len() + 1);
        offsets.push(0u32);
        let mut n_items = n_items_hint;
        for t in txs {
            if let Some(&max) = t.items.last() {
                n_items = n_items.max(max as usize + 1);
            }
            items.extend_from_slice(&t.items);
            offsets.push(items.len() as u32);
        }
        Self { items, offsets, n_items }
    }

    /// Number of transactions (rows).
    pub fn len(&self) -> usize {
        self.offsets.len() - 1
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Dictionary width the block spans (hint grown to max id + 1).
    pub fn n_items(&self) -> usize {
        self.n_items
    }

    /// Total item occurrences across all rows.
    pub fn total_items(&self) -> usize {
        self.items.len()
    }

    /// One transaction's (sorted) items.
    pub fn tx(&self, t: usize) -> &[ItemId] {
        &self.items[self.offsets[t] as usize..self.offsets[t + 1] as usize]
    }

    /// Iterate rows in transaction order.
    pub fn iter(&self) -> impl Iterator<Item = &[ItemId]> + '_ {
        (0..self.len()).map(move |t| self.tx(t))
    }

    /// Occupancy of the (n_tx × n_items) bit matrix this block describes
    /// — the vertical engine's dense/sparse cutover signal.
    pub fn density(&self) -> f64 {
        let cells = self.len() * self.n_items;
        if cells == 0 {
            return 0.0;
        }
        self.items.len() as f64 / cells as f64
    }

    /// Resident size of the flattened arrays in bytes.
    pub fn bytes(&self) -> usize {
        (self.items.len() + self.offsets.len()) * std::mem::size_of::<u32>()
    }

    /// Invert the block into one sorted TID list per item — the vertical
    /// engine's raw material. Each list is pre-sized from a counting pass
    /// so the build never regrows mid-insert, and TIDs arrive in
    /// ascending order because rows are walked transaction-major.
    pub fn tid_lists(&self) -> Vec<Vec<u32>> {
        let mut lens = vec![0usize; self.n_items];
        for tx in self.iter() {
            for &item in tx {
                lens[item as usize] += 1;
            }
        }
        let mut lists: Vec<Vec<u32>> = lens.iter().map(|&n| Vec::with_capacity(n)).collect();
        for (tid, tx) in self.iter().enumerate() {
            for &item in tx {
                lists[item as usize].push(tid as u32);
            }
        }
        lists
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tx(items: &[u32]) -> Transaction {
        Transaction::new(items.iter().copied())
    }

    #[test]
    fn flattens_rows_in_order() {
        let txs = vec![tx(&[2, 0, 5]), tx(&[]), tx(&[1])];
        let b = FlatBlock::from_transactions(&txs, 6);
        assert_eq!(b.len(), 3);
        assert_eq!(b.total_items(), 4);
        assert_eq!(b.tx(0), &[0, 2, 5]);
        assert_eq!(b.tx(1), &[] as &[u32]);
        assert_eq!(b.tx(2), &[1]);
        let rows: Vec<&[u32]> = b.iter().collect();
        assert_eq!(rows.len(), 3);
        assert_eq!(rows[0], &[0, 2, 5]);
    }

    #[test]
    fn width_grows_past_the_hint() {
        let b = FlatBlock::from_transactions(&[tx(&[9])], 4);
        assert_eq!(b.n_items(), 10);
        // and the hint holds when it already covers the data
        let b = FlatBlock::from_transactions(&[tx(&[1])], 4);
        assert_eq!(b.n_items(), 4);
    }

    #[test]
    fn empty_block() {
        let b = FlatBlock::from_transactions(&[], 7);
        assert!(b.is_empty());
        assert_eq!(b.len(), 0);
        assert_eq!(b.n_items(), 7);
        assert_eq!(b.density(), 0.0);
        assert_eq!(b.iter().count(), 0);
    }

    #[test]
    fn tid_lists_invert_the_block() {
        let txs = vec![tx(&[0, 2]), tx(&[1, 2]), tx(&[2]), tx(&[])];
        let b = FlatBlock::from_transactions(&txs, 3);
        let lists = b.tid_lists();
        assert_eq!(lists, vec![vec![0u32], vec![1], vec![0, 1, 2]]);
        assert!(FlatBlock::from_transactions(&[], 2).tid_lists().iter().all(|l| l.is_empty()));
    }

    #[test]
    fn density_and_bytes() {
        // 2 rows × 4 items, 4 occurrences -> density 0.5
        let b = FlatBlock::from_transactions(&[tx(&[0, 1, 2]), tx(&[3])], 4);
        assert_eq!(b.density(), 0.5);
        assert_eq!(b.bytes(), (4 + 3) * 4);
        // width-0 hint with empty rows: no cells, density 0
        let b = FlatBlock::from_transactions(&[tx(&[])], 0);
        assert_eq!(b.density(), 0.0);
    }
}

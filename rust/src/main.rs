//! `repro` — the leader CLI.
//!
//! Subcommands:
//!   generate  — emit a Quest-style synthetic dataset as `.dat`
//!   mine      — run Map/Reduce Apriori on a dataset (real execution)
//!   rules     — mine, then print the association rules
//!   serve     — mine, then run the online rule server (one-shot load)
//!   simulate  — replay a workload on a simulated cluster (fig-4/5 method)
//!   analyze   — critical-path/straggler report over a --trace-out file
//!   bench     — regenerate a paper figure (fig4 | fig5 | eta)
//!   report    — print artifact + kernel-roofline info
//!
//! Flag parsing is hand-rolled (offline build, no clap — DESIGN.md
//! §Substitutions): `--key value` pairs after the subcommand.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::process::ExitCode;
use std::sync::Arc;
use std::time::Instant;

use mr_apriori::prelude::*;
use mr_apriori::{apriori, coordinator, data, engine, log, obs, perfmodel, runtime};

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some((cmd, rest)) = args.split_first() else {
        eprintln!("{USAGE}");
        return ExitCode::FAILURE;
    };
    // `analyze` takes a positional path and a bare `--json` switch, so
    // it parses its own arguments instead of the `--key value` flag bag.
    if cmd == "analyze" {
        return match cmd_analyze(rest) {
            Ok(()) => ExitCode::SUCCESS,
            Err(e) => {
                log!(Error, "{e}");
                ExitCode::FAILURE
            }
        };
    }
    let flags = match Flags::parse(rest) {
        Ok(f) => f,
        Err(e) => {
            log!(Error, "{e}");
            eprintln!("{USAGE}");
            return ExitCode::FAILURE;
        }
    };
    let result = match cmd.as_str() {
        "generate" => cmd_generate(&flags),
        "mine" => cmd_mine(&flags),
        "rules" => cmd_rules(&flags),
        "serve" => cmd_serve(&flags),
        "simulate" => cmd_simulate(&flags),
        "bench" => cmd_bench(&flags),
        "report" => cmd_report(&flags),
        "help" | "--help" | "-h" => {
            println!("{USAGE}");
            Ok(())
        }
        other => Err(format!("unknown subcommand '{other}'")),
    };
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            log!(Error, "{e}");
            ExitCode::FAILURE
        }
    }
}

const USAGE: &str = "\
repro — Map/Reduce Apriori (ACIJ 2012 reproduction)

USAGE:
  repro generate --transactions N [--profile t10i4|dense|goswami] [--seed S] --out FILE
  repro mine [--config FILE] [--preset standalone|pseudo|fhssc|fhdsc] [--nodes N]
             [--min-support F] [--max-k K] [--engine hash-tree|trie|vertical|naive|tensor]
             [--split-tx N] [--transactions N | --input FILE] [--rules CONF]
             [--pipeline true|false] [--batch-levels 1|2]
             [--store-dir DIR] [--retain N] [--min-confidence F]
             [--fault-plan SPEC] [--chaos-seed N]
             [--trace-out FILE] [--flight-dir DIR]
             [--log-level error|warn|info|debug]
  repro rules  <mine flags> [--min-confidence F] [--top N]
  repro serve  <mine flags> [--min-confidence F] [--top K] [--workers N]
               [--queue-depth N] [--internal-queue-depth N] [--deadline-ms MS]
               [--queries N] [--check true|false] [--refresh-batches B]
               [--refresh-tx N] [--refresh-mode full|incremental]
               [--check-final true|false] [--store-dir DIR] [--retain N]
               [--no-persist true|false] [--shards S] [--replicas R]
               [--hedge-ms MS] [--kill-node N] [--fault-plan SPEC]
               [--chaos-seed N] [--trace-out FILE] [--flight-dir DIR]
               [--slo-p99-ms MS] [--slo-window-ms MS] [--slo-min-requests N]
               [--log-level error|warn|info|debug]
  repro simulate [--config FILE] [--preset P] [--nodes N] [--transactions N]
                 [--pipeline true|false]
  repro analyze TRACE.json [--json]
  repro bench --figure fig4|fig5|eta
  repro report
";

/// `--key value` flag bag.
struct Flags(HashMap<String, String>);

impl Flags {
    fn parse(args: &[String]) -> Result<Self, String> {
        let mut m = HashMap::new();
        let mut it = args.iter();
        while let Some(a) = it.next() {
            let Some(key) = a.strip_prefix("--") else {
                return Err(format!("expected --flag, got '{a}'"));
            };
            let val = it
                .next()
                .ok_or_else(|| format!("flag --{key} needs a value"))?;
            m.insert(key.to_string(), val.clone());
        }
        Ok(Self(m))
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.0.get(key).map(|s| s.as_str())
    }

    fn parse_opt<T: std::str::FromStr>(&self, key: &str) -> Result<Option<T>, String>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(key) {
            None => Ok(None),
            Some(v) => v
                .parse::<T>()
                .map(Some)
                .map_err(|e| format!("--{key}: {e}")),
        }
    }
}

/// Assemble an ExperimentConfig from `--config` plus flag overrides.
fn experiment_config(flags: &Flags) -> Result<ExperimentConfig, String> {
    let mut cfg = match flags.get("config") {
        Some(path) => ExperimentConfig::load(Path::new(path)).map_err(|e| e.to_string())?,
        None => ExperimentConfig::default(),
    };
    if let Some(p) = flags.parse_opt::<Preset>("preset")? {
        cfg.preset = p;
    }
    if let Some(n) = flags.parse_opt::<usize>("nodes")? {
        cfg.nodes = n;
    }
    if let Some(s) = flags.parse_opt::<f64>("min-support")? {
        cfg.apriori.min_support = s;
    }
    if let Some(k) = flags.parse_opt::<usize>("max-k")? {
        cfg.apriori.max_k = k;
    }
    if let Some(e) = flags.parse_opt::<EngineKind>("engine")? {
        cfg.engine = e;
    }
    if let Some(n) = flags.parse_opt::<usize>("split-tx")? {
        cfg.split_tx = n;
    }
    if let Some(n) = flags.parse_opt::<usize>("transactions")? {
        cfg.transactions = n;
    }
    if let Some(s) = flags.parse_opt::<u64>("seed")? {
        cfg.seed = s;
    }
    if let Some(p) = flags.parse_opt::<bool>("pipeline")? {
        cfg.pipeline.enabled = p;
    }
    if let Some(b) = flags.parse_opt::<usize>("batch-levels")? {
        if !(1..=2).contains(&b) {
            return Err("--batch-levels: must be 1 or 2".into());
        }
        cfg.pipeline.batch_levels = b;
    }
    if let Some(w) = flags.parse_opt::<usize>("workers")? {
        if w == 0 {
            return Err("--workers: must be >= 1".into());
        }
        cfg.serve.workers = w;
    }
    if let Some(d) = flags.parse_opt::<usize>("queue-depth")? {
        if d == 0 {
            return Err("--queue-depth: must be >= 1".into());
        }
        cfg.serve.queue_depth = d;
    }
    if let Some(k) = flags.parse_opt::<usize>("top")? {
        if k == 0 {
            return Err("--top: must be >= 1".into());
        }
        cfg.serve.top_k = k;
    }
    if let Some(c) = flags.parse_opt::<f64>("min-confidence")? {
        if !(0.0..=1.0).contains(&c) {
            return Err("--min-confidence: must be in [0, 1]".into());
        }
        cfg.serve.min_confidence = c;
    }
    if let Some(n) = flags.parse_opt::<usize>("refresh-tx")? {
        if n == 0 {
            return Err("--refresh-tx: must be >= 1".into());
        }
        cfg.serve.refresh_tx = n;
    }
    if let Some(b) = flags.parse_opt::<usize>("refresh-batches")? {
        cfg.serve.refresh_batches = b;
    }
    if let Some(ms) = flags.parse_opt::<u64>("deadline-ms")? {
        cfg.serve.deadline_ms = ms;
    }
    if let Some(mode) = flags.parse_opt::<RefreshMode>("refresh-mode")? {
        cfg.incremental.enabled = mode == RefreshMode::Incremental;
    }
    if let Some(d) = flags.parse_opt::<usize>("internal-queue-depth")? {
        if d == 0 {
            return Err("--internal-queue-depth: must be >= 1".into());
        }
        cfg.serve.internal_queue_depth = d;
    }
    if let Some(n) = flags.parse_opt::<usize>("shards")? {
        // 0 is legal: it means "fabric off"
        cfg.fabric.shards = n;
    }
    if let Some(r) = flags.parse_opt::<usize>("replicas")? {
        if r == 0 {
            return Err("--replicas: must be >= 1".into());
        }
        cfg.fabric.replicas = r;
    }
    if let Some(ms) = flags.parse_opt::<u64>("hedge-ms")? {
        cfg.fabric.hedge_ms = ms;
    }
    if let Some(dir) = flags.get("store-dir") {
        cfg.store.dir = Some(PathBuf::from(dir));
    }
    if let Some(r) = flags.parse_opt::<usize>("retain")? {
        if r == 0 {
            return Err("--retain: must be >= 1".into());
        }
        cfg.store.retain = r;
    }
    if let Some(b) = flags.parse_opt::<bool>("no-persist")? {
        cfg.store.no_persist = b;
    }
    if let Some(spec) = flags.get("fault-plan") {
        // Validate eagerly: a typo'd plan must fail before any mining.
        FaultPlan::parse(spec).map_err(|e| format!("--fault-plan: {e}"))?;
        cfg.chaos.plan = Some(spec.to_string());
    }
    if let Some(s) = flags.parse_opt::<u64>("chaos-seed")? {
        cfg.chaos.seed = s;
    }
    if let Some(ms) = flags.parse_opt::<f64>("slo-p99-ms")? {
        cfg.slo.p99_ms = ms;
    }
    if let Some(ms) = flags.parse_opt::<u64>("slo-window-ms")? {
        cfg.slo.window_ms = ms;
    }
    if let Some(n) = flags.parse_opt::<u64>("slo-min-requests")? {
        cfg.slo.min_requests = n;
    }
    cfg.slo.validate().map_err(|e| format!("slo: {e}"))?;
    if let Some(l) = flags.parse_opt::<LogLevel>("log-level")? {
        cfg.obs.log_level = l;
    }
    // Apply the resolved level right away: every command that assembles
    // a config gets leveled logging without per-command wiring.
    obs::set_log_level(cfg.obs.log_level);
    Ok(cfg)
}

/// `--trace-out FILE`: the sink a traced run records spans into, plus
/// where the exporters write when the run finishes.
fn trace_sink(flags: &Flags) -> Option<(PathBuf, Arc<TraceSink>)> {
    flags
        .get("trace-out")
        .map(|p| (PathBuf::from(p), TraceSink::new()))
}

/// The sink spans record into: the `--trace-out` one when tracing,
/// otherwise a fresh sink created just so `--flight-dir` has something
/// to tee off (its ring is then the only consumer — nothing exported).
fn span_sink(flags: &Flags, trace: &Option<(PathBuf, Arc<TraceSink>)>) -> Option<Arc<TraceSink>> {
    match (trace, flags.get("flight-dir")) {
        (Some((_, s)), _) => Some(Arc::clone(s)),
        (None, Some(_)) => Some(TraceSink::new()),
        (None, None) => None,
    }
}

/// `--flight-dir DIR`: attach a flight recorder to the run's sink. The
/// ring only dumps when a trigger fires (job error, chaos kill
/// escalation, SLO breach) — steady-state runs write nothing.
fn attach_flight(flags: &Flags, sink: Option<&Arc<TraceSink>>) -> Option<Arc<FlightRecorder>> {
    let dir = flags.get("flight-dir")?;
    let sink = sink?;
    let recorder = FlightRecorder::new(PathBuf::from(dir), obs::flight::DEFAULT_CAPACITY);
    sink.attach_flight(Arc::clone(&recorder));
    Some(recorder)
}

/// Dump the flight ring (with a coherent metrics cut) for `reason`.
/// Failure to write the incident file is logged, never fatal — the
/// recorder must not turn an incident into a second error.
fn flight_dump(flight: Option<&Arc<FlightRecorder>>, registry: &MetricsRegistry, reason: &str) {
    let Some(rec) = flight else { return };
    match rec.dump(reason, Some(&registry.snapshot())) {
        Ok(path) => log!(Warn, "flight recorder dumped to {} ({reason})", path.display()),
        Err(e) => log!(Error, "flight dump to {} failed: {e}", rec.dir().display()),
    }
}

/// Write the Chrome `trace_event` file and its `.jsonl` sibling.
fn export_trace(path: &Path, sink: &TraceSink) -> Result<(), String> {
    let events = sink.events();
    obs::write_chrome_trace(path, &events).map_err(|e| e.to_string())?;
    let jsonl = path.with_extension("jsonl");
    obs::write_jsonl(&jsonl, &events).map_err(|e| e.to_string())?;
    log!(
        Info,
        "wrote {} trace events to {} (+ {})",
        events.len(),
        path.display(),
        jsonl.display()
    );
    Ok(())
}

/// The one-page metrics dump: always at `--trace-out` exit, otherwise
/// only when someone asked for `--log-level debug`.
fn dump_metrics(registry: &MetricsRegistry, tracing: bool) {
    let gate = if tracing { LogLevel::Info } else { LogLevel::Debug };
    if obs::enabled(gate) {
        eprint!("{}", registry.render_text());
    }
}

/// Resolve the `[chaos]` section (or `--fault-plan`/`--chaos-seed`)
/// into the run's shared fault clock. `None` when chaos is off — the
/// default, with zero overhead anywhere on the hot path.
fn fault_clock(cfg: &ExperimentConfig) -> Result<Option<Arc<FaultClock>>, String> {
    let cluster = cfg.cluster();
    let replication = Dfs::new(&cluster).replication;
    let plan = cfg
        .chaos
        .resolve(cluster.n_nodes(), replication)
        .map_err(|e| format!("fault plan: {e}"))?;
    Ok(plan.map(|p| Arc::new(FaultClock::new(p))))
}

/// Open the configured snapshot store (even with `--no-persist true` —
/// warm restart still reads it; only writes are gated), with its bytes
/// charged against a simulated DFS of the configured cluster.
fn open_store(
    cfg: &ExperimentConfig,
    chaos: Option<&Arc<FaultClock>>,
) -> Result<Option<Arc<SnapshotStore>>, String> {
    let Some(dir) = &cfg.store.dir else {
        return Ok(None);
    };
    let mut store = SnapshotStore::open(dir, cfg.store.retain)
        .map_err(|e| e.to_string())?
        .with_block_accounting(Box::new(Dfs::new(&cfg.cluster())));
    if let Some(clock) = chaos {
        store = store.with_chaos(Arc::clone(clock));
    }
    Ok(Some(Arc::new(store)))
}

/// Persist the cold-start (generation 0) snapshot — shared by
/// `mine --store-dir` and `serve`'s cold-start path.
fn publish_generation_zero(
    store: &SnapshotStore,
    cfg: &ExperimentConfig,
    base: BaseRef,
    result: &MiningResult,
    state: Option<&MinedState>,
    index: &RuleIndex,
) -> Result<(), String> {
    store
        .publish(&SnapshotRef {
            generation: 0,
            base,
            min_support: cfg.apriori.min_support,
            max_k: cfg.apriori.max_k,
            delta: &[],
            result,
            state,
            index,
        })
        .map_err(|e| e.to_string())
}

/// Shard an index into a fabric cut. The index keeps its rules in the
/// deterministic global order, so the cut serves byte-identically.
fn shard_index(index: &RuleIndex, n_shards: usize) -> ShardedRuleIndex {
    ShardedRuleIndex::from_rules(
        index.rules().to_vec(),
        index.n_transactions,
        index.min_confidence,
        n_shards,
    )
}

fn load_or_generate(flags: &Flags, cfg: &ExperimentConfig) -> Result<TransactionDb, String> {
    match flags.get("input") {
        Some(path) => data::io::read_dat(Path::new(path)).map_err(|e| e.to_string()),
        None => {
            let params = QuestParams::t10_i4(cfg.transactions).with_seed(cfg.seed);
            Ok(QuestGenerator::new(params).generate())
        }
    }
}

fn build_engine_for(cfg: &ExperimentConfig) -> Result<Box<dyn SupportEngine>, String> {
    if cfg.engine == EngineKind::Tensor {
        let svc = runtime::TensorService::start_default().map_err(|e| e.to_string())?;
        // Keep the service thread alive for the whole mining run; the CLI
        // process exits right after, so this one-shot leak is deliberate.
        let handle = svc.handle();
        std::mem::forget(svc);
        Ok(engine::build_engine(EngineKind::Tensor, Some(handle)))
    } else {
        Ok(engine::build_engine(cfg.engine, None))
    }
}

/// Assemble the Map/Reduce driver a config describes (mine/rules/serve
/// all run the same mining stack underneath).
fn build_driver(cfg: &ExperimentConfig) -> Result<MrApriori, String> {
    Ok(MrApriori::new(cfg.cluster(), cfg.apriori.clone())
        .with_engine(build_engine_for(cfg)?)
        .with_job(cfg.job.clone())
        .with_pipeline(cfg.pipeline.clone())
        .with_split_tx(cfg.split_tx))
}

fn cmd_generate(flags: &Flags) -> Result<(), String> {
    let n: usize = flags
        .parse_opt("transactions")?
        .ok_or("--transactions required")?;
    let out: PathBuf = flags.get("out").ok_or("--out required")?.into();
    let seed: u64 = flags.parse_opt("seed")?.unwrap_or(0xACE5_2012);
    let params = match flags.get("profile").unwrap_or("t10i4") {
        "t10i4" => QuestParams::t10_i4(n),
        "dense" => QuestParams::dense(n),
        "goswami" => QuestParams::goswami_2k(),
        other => return Err(format!("unknown profile '{other}'")),
    }
    .with_seed(seed);
    let db = QuestGenerator::new(params).generate();
    data::io::write_dat(&db, &out).map_err(|e| e.to_string())?;
    println!(
        "wrote {} transactions ({} item occurrences, {} distinct items) to {}",
        db.len(),
        db.total_items(),
        db.n_items,
        out.display()
    );
    Ok(())
}

fn cmd_mine(flags: &Flags) -> Result<(), String> {
    let cfg = experiment_config(flags)?;
    let db = load_or_generate(flags, &cfg)?;
    let trace = trace_sink(flags);
    let sink = span_sink(flags, &trace);
    let flight = attach_flight(flags, sink.as_ref());
    let registry = Arc::new(MetricsRegistry::new());
    let chaos = fault_clock(&cfg)?;
    if let Some(clock) = &chaos {
        clock
            .register_metrics(&registry, "chaos")
            .map_err(|e| e.to_string())?;
        // Fault injections record `cat: chaos` spans so the exported
        // trace (and any flight dump) carries the fault context inline.
        if let Some(s) = &sink {
            clock.attach_trace(TraceCtx::root(Arc::clone(s)));
        }
        log!(Info, "chaos: injecting fault plan '{}'", clock.plan());
    }
    let driver = build_driver(&cfg)?
        .with_trace(sink.as_ref().map(|s| TraceCtx::root(Arc::clone(s))))
        .with_registry(Arc::clone(&registry))
        .with_chaos(chaos.clone());
    // Open (and thereby validate) the store *before* the mine — an
    // unwritable --store-dir must not cost a completed mining run.
    let store = if cfg.store.writes_enabled() {
        open_store(&cfg, chaos.as_ref())?
    } else {
        None
    };
    log!(
        Info,
        "mining {} transactions on {:?}/{} nodes (engine={}, min_support={}, schedule={})",
        db.len(),
        cfg.preset,
        cfg.cluster().n_nodes(),
        cfg.engine,
        cfg.apriori.min_support,
        if cfg.pipeline.enabled {
            "pipelined"
        } else {
            "synchronous"
        },
    );
    // With a store attached, mine in capture mode (byte-identical
    // result) so the border state lands in the generation-0 snapshot and
    // an incremental `serve --store-dir` warm-starts without any mining.
    let mined = if store.is_some() {
        MinedState::capture(&driver, &db)
            .map(|(r, st)| (r, Some(st)))
            .map_err(|e| e.to_string())
    } else {
        driver.mine(&db).map(|r| (r, None)).map_err(|e| e.to_string())
    };
    let (report, captured_state) = match mined {
        Ok(out) => out,
        Err(e) => {
            // The job failed: the ring holds the last spans before death.
            flight_dump(flight.as_ref(), &registry, &format!("mine error: {e}"));
            return Err(e);
        }
    };

    println!("\nlevel | candidates | frequent | wall(s)");
    for l in &report.result.levels {
        println!(
            "{:>5} | {:>10} | {:>8} | {:.3}",
            l.k, l.n_candidates, l.n_frequent, l.wall_secs
        );
    }
    println!(
        "\n{} frequent itemsets in {:.3}s wall ({} MR jobs, locality {:.0}%)",
        report.result.frequent.len(),
        report.wall_secs,
        report.jobs.len(),
        report
            .jobs
            .iter()
            .map(|(_, s)| s.locality_fraction())
            .sum::<f64>()
            / report.jobs.len().max(1) as f64
            * 100.0
    );
    if let Some(clock) = &chaos {
        let cs = clock.stats();
        println!(
            "chaos: plan '{}' fired {} fault(s) — {} node(s) dead {:?}, {} fetch fault(s), \
             {} store fault(s), blacklist {:?}; mined on the survivors",
            clock.plan(),
            cs.faults_injected,
            cs.nodes_killed,
            clock.dead_nodes(),
            cs.fetch_faults,
            cs.store_faults,
            clock.blacklisted(),
        );
        if cs.nodes_killed > 0 {
            // Node loss is the chaos escalation the recorder is for:
            // keep the last spans around the kill for the post-mortem.
            flight_dump(flight.as_ref(), &registry, "chaos kill escalation");
        }
    }
    if let Some(conf) = flags.parse_opt::<f64>("rules")? {
        let rules = generate_rules(&report.result, conf);
        println!("\n{} association rules at confidence >= {conf}:", rules.len());
        for r in rules.iter().take(20) {
            println!("  {}", format_rule(r));
        }
        if rules.len() > 20 {
            println!("  ... ({} more)", rules.len() - 20);
        }
    }
    if let Some(state) = captured_state {
        let store = store.expect("captured_state implies an open store");
        let index = RuleIndex::build(&report.result, cfg.serve.min_confidence);
        publish_generation_zero(
            &store,
            &cfg,
            BaseRef::of(&db),
            &report.result,
            Some(&state),
            &index,
        )?;
        println!(
            "persisted generation 0 ({} itemsets, {} rules, {} border itemsets) to {}",
            index.n_itemsets(),
            index.n_rules(),
            state.n_border(),
            store.dir().display(),
        );
    }
    if let Some((path, sink)) = &trace {
        export_trace(path, sink)?;
    }
    dump_metrics(&registry, trace.is_some());
    Ok(())
}

fn cmd_rules(flags: &Flags) -> Result<(), String> {
    let cfg = experiment_config(flags)?;
    let top: usize = flags.parse_opt("top")?.unwrap_or(50);
    let db = load_or_generate(flags, &cfg)?;
    let driver = build_driver(&cfg)?;
    let report = driver.mine(&db).map_err(|e| e.to_string())?;
    let conf = cfg.serve.min_confidence;
    let rules = generate_rules(&report.result, conf);
    println!(
        "{} association rules at confidence >= {conf} ({} frequent itemsets, {} tx):",
        rules.len(),
        report.result.frequent.len(),
        db.len(),
    );
    for r in rules.iter().take(top) {
        println!("{}", format_rule(r));
    }
    if rules.len() > top {
        println!("... ({} more; raise --top to see them)", rules.len() - top);
    }
    Ok(())
}

fn cmd_serve(flags: &Flags) -> Result<(), String> {
    let cfg = experiment_config(flags)?;
    let trace = trace_sink(flags);
    let sink = span_sink(flags, &trace);
    let flight = attach_flight(flags, sink.as_ref());
    // Each call derives a fresh root context on the shared sink, so the
    // cold-start mine, the refresher, and every served request get their
    // own trace ids while landing in one exported file.
    let root_ctx = || sink.as_ref().map(|s| TraceCtx::root(Arc::clone(s)));
    let registry = Arc::new(MetricsRegistry::new());
    let queries: usize = flags.parse_opt("queries")?.unwrap_or(200);
    let check: bool = flags.parse_opt("check")?.unwrap_or(false);
    let check_final: bool = flags.parse_opt("check-final")?.unwrap_or(false);
    let mut db = load_or_generate(flags, &cfg)?;
    let base_tx = db.len();
    let chaos = fault_clock(&cfg)?;
    if let Some(clock) = &chaos {
        clock
            .register_metrics(&registry, "chaos")
            .map_err(|e| e.to_string())?;
        if let Some(s) = &sink {
            clock.attach_trace(TraceCtx::root(Arc::clone(s)));
        }
        log!(Info, "chaos: injecting fault plan '{}'", clock.plan());
    }
    let store = open_store(&cfg, chaos.as_ref())?;
    // Base identity before any recovered delta lands: the store journals
    // cumulative deltas relative to this exact database. The O(|D|)
    // fingerprint only runs when a store is actually configured.
    let base_ref = store.as_ref().map(|_| BaseRef::of(&db));
    let persist = cfg.store.writes_enabled();
    let s = cfg.serve.clone();

    // Warm restart: resume at the newest intact persisted generation for
    // this base instead of cold re-mining. A store written for different
    // data refuses to resume (cold start with a warning); corrupt or
    // truncated files already degraded inside `resume_serving`.
    let mut resumed = None;
    if let Some(store) = &store {
        match mr_apriori::store::resume_serving(store, &mut db, base_ref.expect("store is open")) {
            Ok(r) => resumed = r,
            Err(StoreError::BaseMismatch { .. }) => log!(
                Warn,
                "store at {} belongs to a different base database; cold-starting \
                 (a store directory serves one dataset — use a fresh --store-dir)",
                store.dir().display()
            ),
            Err(e) => return Err(e.to_string()),
        }
    }

    let warm_restart = resumed.is_some();
    let (cell, result, start_generation, seed_state) = match resumed {
        Some(r) => {
            // a persisted generation is exact only under the parameters
            // it was produced with — refuse a silent drift (every
            // snapshot carries them, state-less full-mode ones included)
            if r.min_support != cfg.apriori.min_support || r.max_k != cfg.apriori.max_k {
                return Err(format!(
                    "store was mined with min_support {} / max_k {}; rerun with \
                     matching flags or a fresh --store-dir",
                    r.min_support, r.max_k
                ));
            }
            if r.min_confidence != s.min_confidence {
                return Err(format!(
                    "store's serving index was built at min_confidence {}; rerun with \
                     matching --min-confidence or a fresh --store-dir",
                    r.min_confidence
                ));
            }
            println!(
                "warm restart: resumed generation {} from {} — {} tx ({} recovered delta), \
                 {} itemsets, {} rules, no re-mine",
                r.generation,
                store.as_ref().expect("resumed implies a store").dir().display(),
                db.len(),
                db.len() - base_tx,
                r.result.frequent.len(),
                r.cell.load().n_rules(),
            );
            (r.cell, r.result, r.generation, r.state)
        }
        None => {
            // The refresher's driver is the long-lived miner, so it gets
            // the registry when refreshes run; this one-shot cold-start
            // driver takes it otherwise (`engine.cache.*` registers once).
            let mut driver = build_driver(&cfg)?
                .with_trace(root_ctx())
                .with_chaos(chaos.clone());
            if s.refresh_batches == 0 {
                driver = driver.with_registry(Arc::clone(&registry));
            }
            log!(
                Info,
                "mining {} transactions for the serving snapshot ...",
                db.len()
            );
            // Capture the border state whenever it will be persisted (so
            // a restarted incremental serve resumes from it) — results
            // are byte-identical to a plain mine.
            let (result, state0) = if persist && cfg.incremental.enabled {
                let (report, st) = MinedState::capture(&driver, &db).map_err(|e| e.to_string())?;
                (report.result, Some(st))
            } else {
                (driver.mine(&db).map_err(|e| e.to_string())?.result, None)
            };
            let index = RuleIndex::build(&result, s.min_confidence);
            if persist {
                let store = store.as_ref().expect("writes_enabled implies a dir");
                publish_generation_zero(
                    store,
                    &cfg,
                    base_ref.expect("persist implies an open store"),
                    &result,
                    state0.as_ref(),
                    &index,
                )?;
            }
            let cell = Arc::new(SnapshotCell::new(Arc::new(index)));
            (cell, result, 0, state0)
        }
    };
    println!(
        "snapshot gen {start_generation}: {} itemsets, {} rules at confidence >= {} \
         (refresh mode: {}, persistence: {})",
        cell.load().n_itemsets(),
        cell.load().n_rules(),
        s.min_confidence,
        if cfg.incremental.enabled { "incremental" } else { "full" },
        if persist { "on" } else { "off" },
    );
    let direct = check.then(|| generate_rules(&result, s.min_confidence));

    let singles: Vec<u32> = result.level(1).map(|(is, _)| is[0]).collect();
    if singles.is_empty() {
        return Err("nothing frequent to query; lower --min-support".into());
    }
    let baskets = synth_baskets(&singles, queries, cfg.seed ^ 0x5E21_E5E2);

    // Fabric backend: shard the snapshot, place replicas on the cluster,
    // scatter-gather through the router. `shards = 0` (the default)
    // keeps the classic single-index backend untouched.
    let kill_node: Option<usize> = flags.parse_opt("kill-node")?;
    if kill_node.is_some() && !cfg.fabric.enabled() {
        return Err("--kill-node needs the fabric (--shards >= 1)".into());
    }
    let (router, fabric_store) = if cfg.fabric.enabled() {
        let cluster = cfg.cluster();
        if let Some(n) = kill_node {
            if n >= cluster.n_nodes() {
                return Err(format!(
                    "--kill-node: node {n} out of range (cluster has {} nodes)",
                    cluster.n_nodes()
                ));
            }
        }
        let fstore = if persist {
            let dir = cfg
                .store
                .dir
                .as_ref()
                .expect("writes_enabled implies a dir")
                .join("fabric");
            Some(Arc::new(
                FabricStore::open(&dir, cfg.fabric.shards, cfg.fabric.replicas)
                    .map_err(|e| e.to_string())?
                    .with_retain(cfg.store.retain),
            ))
        } else {
            None
        };
        // Warm start: a restarted fabric reloads the persisted shard cut
        // for the resumed generation instead of re-sharding the snapshot
        // — the on-disk replicas already *are* this cut, so the router
        // serves the byte-identical generation with no shard rebuild. A
        // missing/older/mismatched cut quietly falls back to re-sharding.
        let mut warm_cut = None;
        if warm_restart {
            if let Some(fs) = &fstore {
                if let Some((m, cut)) = fs.load_cut() {
                    if m.generation == start_generation {
                        warm_cut = Some(cut);
                    }
                }
            }
        }
        let from_store = warm_cut.is_some();
        let sharded = match warm_cut {
            Some(cut) => cut,
            None => shard_index(&cell.load(), cfg.fabric.shards),
        };
        // a rule is ~an id + two small itemsets + three measures
        let shard_bytes: Vec<u64> =
            sharded.shard_rule_counts().iter().map(|&n| 16 + 56 * n).collect();
        let placement = FabricPlacement::place(&cluster, cfg.fabric.replicas, &shard_bytes)
            .map_err(|e| e.to_string())?;
        println!(
            "fabric: {} shards x {} replicas on {} nodes \
             (hedge floor {}ms, simulated DFS utilization {:.2}%{})",
            cfg.fabric.shards,
            cfg.fabric.replicas,
            cluster.n_nodes(),
            cfg.fabric.hedge_ms,
            placement.utilization() * 100.0,
            if from_store {
                format!(", cut warm-started at generation {start_generation}")
            } else {
                String::new()
            },
        );
        let cut = Arc::new(SnapshotCell::with_generation(
            Arc::new(sharded),
            start_generation,
        ));
        let router = Arc::new(QueryRouter::new(cut, placement, &cluster, cfg.fabric.hedge_ms));
        router
            .register_metrics(&registry, "fabric")
            .map_err(|e| e.to_string())?;
        if let Some(fs) = &fstore {
            // Re-publishing a warm-started cut would be a no-op rewrite
            // of the very files it was loaded from; skip it.
            if !from_store {
                fs.publish(&router.cut().load(), start_generation)
                    .map_err(|e| e.to_string())?;
            }
        }
        (Some(router), fstore)
    } else {
        (None, None)
    };

    let backend = match &router {
        Some(r) => Backend::Fabric(Arc::clone(r)),
        None => Backend::Local(Arc::clone(&cell)),
    };
    let server = Arc::new(RuleServer::start_with_backend(
        backend,
        ServeOptions {
            workers: s.workers,
            queue_depth: s.queue_depth,
            internal_queue_depth: s.internal_queue_depth,
            deadline: (s.deadline_ms > 0)
                .then(|| std::time::Duration::from_millis(s.deadline_ms)),
            trace: root_ctx(),
        },
    ));
    server
        .register_metrics(&registry, "serve")
        .map_err(|e| e.to_string())?;

    // SLO watcher: judge the user lane's p99 per burn-rate window on its
    // own thread. A breach logs at Warn, bumps the `slo.*` counters, and
    // triggers the flight recorder. The evaluation itself is pure
    // (`SloWatcher::evaluate`); this thread only owns the cadence.
    let slo_stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
    let slo_handle = cfg.slo.enabled().then(|| {
        let watcher = SloWatcher::new(cfg.slo.clone(), server.latency_histogram())
            .register_metrics(&registry);
        let stop = Arc::clone(&slo_stop);
        let flight = flight.clone();
        let registry = Arc::clone(&registry);
        std::thread::spawn(move || {
            let window = std::time::Duration::from_millis(watcher.config().window_ms);
            // sleep in short slices so shutdown stays prompt
            let slice = std::time::Duration::from_millis(10).min(window);
            let mut elapsed = std::time::Duration::ZERO;
            loop {
                std::thread::sleep(slice);
                elapsed += slice;
                let stopping = stop.load(std::sync::atomic::Ordering::Relaxed);
                // the final (partial) window is still judged at stop
                if elapsed >= window || stopping {
                    elapsed = std::time::Duration::ZERO;
                    if let Some(v) = watcher.evaluate() {
                        if v.breached {
                            log!(
                                Warn,
                                "SLO breach: p99 {:?} > {:?} target over {} requests \
                                 (burn rate {:.1}x)",
                                v.p99,
                                watcher.config().target(),
                                v.requests,
                                v.burn_rate
                            );
                            flight_dump(flight.as_ref(), &registry, "slo breach");
                        }
                    }
                }
                if stopping {
                    break;
                }
            }
        })
    });

    // Optional concurrent micro-batch refresh (the db moves to that
    // thread and comes back with the outcome; queries keep hitting
    // whatever snapshot is current). Each published generation is
    // validated by probe queries on the server's *internal* lane — they
    // can never crowd out user traffic.
    let refresh_handle = if s.refresh_batches > 0 {
        let driver = build_driver(&cfg)?
            .with_trace(root_ctx())
            .with_registry(Arc::clone(&registry))
            .with_chaos(chaos.clone());
        let refresher = Refresher::new(driver, s.min_confidence)
            .with_incremental(cfg.incremental.clone())
            .with_trace(root_ctx());
        let refresher = match (&store, persist) {
            (Some(store), true) => refresher.with_store(
                Arc::clone(store),
                base_ref.expect("store is open"),
                base_tx,
            ),
            _ => refresher,
        };
        if cfg.incremental.enabled {
            if let Some(st) = seed_state {
                refresher.seed_state(st);
            }
        }
        let batches: Vec<Vec<data::Transaction>> = (0..s.refresh_batches)
            .map(|b| {
                synth_delta(
                    s.refresh_tx,
                    db.n_items,
                    cfg.seed ^ (start_generation + b as u64 + 1),
                )
            })
            .collect();
        let cell = Arc::clone(&cell);
        let probe_server = Arc::clone(&server);
        let probes: Vec<Vec<u32>> = baskets.iter().take(4).cloned().collect();
        let top_k = s.top_k;
        let min_confidence = s.min_confidence;
        let refresh_router = router.clone();
        let refresh_fstore = fabric_store.clone();
        let n_shards = cfg.fabric.shards;
        let cycle_registry = Arc::clone(&registry);
        let cycle_dump = trace.is_some();
        let mut moved_db = std::mem::take(&mut db);
        Some(std::thread::spawn(move || {
            let mut all = Vec::new();
            for delta in batches {
                let (report, st) = match refresher.refresh_once(&mut moved_db, delta, &cell) {
                    Ok(out) => out,
                    Err(e) => return (Err(e.to_string()), moved_db),
                };
                // Fabric: prepare the next generation's shard replicas on
                // disk first (two-phase, skipping down replicas — refresh
                // fails over without dropping a generation), then flip
                // the in-memory cut; queries never see a mixed cut.
                if let Some(router) = &refresh_router {
                    let next = Arc::new(shard_index(&cell.load(), n_shards));
                    if let Some(fs) = &refresh_fstore {
                        let up = |shard: usize, replica: usize| {
                            !router.is_node_down(router.placement().replicas_of(shard)[replica])
                        };
                        if let Err(e) = fs.publish_partial(&next, st.generation, &up) {
                            return (Err(e.to_string()), moved_db);
                        }
                    }
                    let flipped = router.cut().store(next);
                    debug_assert_eq!(flipped, st.generation);
                }
                // Checked for real: the refresher is the only publisher,
                // so every probe answer attributes to the generation just
                // swapped in and must be byte-identical to the direct
                // generate_rules path over that generation's result.
                let direct = generate_rules(&report.result, min_confidence);
                for basket in &probes {
                    // shed probes are fine: the lane is bounded and
                    // strictly lower priority by design
                    let Ok(ticket) = probe_server.submit_internal(basket, top_k) else {
                        continue;
                    };
                    let Ok(resp) = ticket.wait() else {
                        continue;
                    };
                    if resp.generation == st.generation {
                        let want = render_lines(&reference_recommend(&direct, basket, top_k));
                        if resp.render() != want {
                            return (
                                Err(format!(
                                    "post-swap probe mismatch at generation {} for basket \
                                     {basket:?}",
                                    st.generation
                                )),
                                moved_db,
                            );
                        }
                    }
                }
                all.push(st);
                // the per-cycle metrics page (DESIGN.md §Observability)
                dump_metrics(&cycle_registry, cycle_dump);
            }
            (Ok(all), moved_db)
        }))
    } else {
        None
    };

    let t0 = Instant::now();
    let mut checked = 0u64;
    for (i, basket) in baskets.iter().enumerate() {
        // Mid-run fault injection: kill one node and keep querying —
        // every shard on it fails over to a surviving replica.
        if i == queries / 2 {
            if let (Some(router), Some(n)) = (&router, kill_node) {
                router.set_node_down(n);
                println!("fabric: killed node {n} after {i} queries");
            }
        }
        match server.query(basket, s.top_k) {
            Ok(resp) => {
                if let Some(direct) = &direct {
                    if resp.generation == start_generation {
                        let want = render_lines(&reference_recommend(direct, basket, s.top_k));
                        if resp.render() != want {
                            return Err(format!("differential mismatch for basket {basket:?}"));
                        }
                        checked += 1;
                    }
                }
            }
            // shedding is load behaviour, not a failure (counted below)
            Err(ServeError::QueueFull) | Err(ServeError::DeadlineExceeded) => {}
            Err(e) => return Err(e.to_string()),
        }
    }
    let wall = t0.elapsed().as_secs_f64();

    // User traffic is done: close out the SLO watcher (it judges the
    // final partial window on the way out).
    slo_stop.store(true, std::sync::atomic::Ordering::Relaxed);
    if let Some(handle) = slo_handle {
        handle
            .join()
            .map_err(|_| "slo watcher thread panicked".to_string())?;
    }

    let mut final_db = None;
    if let Some(handle) = refresh_handle {
        let (outcome, moved_db) = handle
            .join()
            .map_err(|_| "refresh thread panicked".to_string())?;
        let refresh_stats = outcome.map_err(|e| e.to_string())?;
        for st in &refresh_stats {
            let strategy = match (&st.incremental, st.fell_back) {
                (Some(inc), _) => format!(
                    "delta-applied: {} tracked, {} frontier recounts, +{} promoted, -{} demoted",
                    inc.tracked, inc.frontier_recounted, inc.promoted, inc.demoted
                ),
                (None, true) => "full re-mine (frontier blowup fallback)".into(),
                (None, false) => "full re-mine".into(),
            };
            log!(
                Info,
                "refresh gen {}: +{} tx -> {} tx, {} itemsets, {} rules \
                 (mine {:.3}s, build {:.3}s; cache {}h/{}m; {strategy})",
                st.generation,
                st.delta_tx,
                st.total_tx,
                st.n_frequent,
                st.n_rules,
                st.mine_secs,
                st.build_secs,
                st.cache_hits,
                st.cache_misses,
            );
        }
        final_db = Some(moved_db);
    }

    let server = Arc::into_inner(server).expect("refresh thread joined, no probe refs remain");
    let stats = server.shutdown();
    let (p50, p95, p99) = stats.latency.p50_p95_p99();
    println!(
        "\nserved {} of {queries} queries in {wall:.3}s ({:.0} QPS closed-loop), \
         shed {} (overflow) + {} (deadline)",
        stats.served,
        stats.served as f64 / wall.max(1e-9),
        stats.rejected,
        stats.deadline_shed,
    );
    println!("latency p50 {p50:?} | p95 {p95:?} | p99 {p99:?}");
    if stats.unavailable > 0 {
        return Err(format!(
            "{} queries found a shard with no live replica (availability broken)",
            stats.unavailable
        ));
    }
    if let Some(router) = &router {
        let rs = router.stats();
        let (mp50, mp95, mp99) = rs.merged_p50_p95_p99;
        println!(
            "fabric: {} scatter-gather queries, {} failovers, {} hedges fired ({} won); \
             simulated merge p50 {mp50:?} | p95 {mp95:?} | p99 {mp99:?}",
            rs.queries, rs.failovers, rs.hedges_fired, rs.hedge_wins,
        );
        if let Some(fs) = &fabric_store {
            println!(
                "fabric store {}: generation(s) {:?} retained",
                fs.dir().display(),
                fs.scan_generations(),
            );
        }
    }
    if stats.internal_served + stats.internal_rejected + stats.internal_deadline_shed > 0 {
        println!(
            "internal lane: {} probe answers, shed {} (overflow) + {} (deadline) — \
             user tails above exclude all of these",
            stats.internal_served,
            stats.internal_rejected,
            stats.internal_deadline_shed,
        );
    }
    if let Some(store) = &store {
        let mut gens = store.scan_generations().map_err(|e| e.to_string())?;
        gens.sort_unstable();
        println!(
            "store {}: {} generation(s) retained {:?}, {} bytes committed{}",
            store.dir().display(),
            gens.len(),
            gens,
            store.bytes_written(),
            store
                .utilization()
                .map(|u| format!(", simulated DFS utilization {:.2}%", u * 100.0))
                .unwrap_or_default(),
        );
    }
    if let Some(clock) = &chaos {
        let cs = clock.stats();
        println!(
            "chaos: plan '{}' fired {} fault(s) — {} node(s) dead {:?}, {} fetch fault(s), \
             {} store fault(s), blacklist {:?}",
            clock.plan(),
            cs.faults_injected,
            cs.nodes_killed,
            clock.dead_nodes(),
            cs.fetch_faults,
            cs.store_faults,
            clock.blacklisted(),
        );
        if cs.nodes_killed > 0 {
            flight_dump(flight.as_ref(), &registry, "chaos kill escalation");
        }
    }
    if check {
        println!("differential check: {checked} answers byte-identical to direct generate_rules");
    }
    if check_final {
        // The published snapshot must equal a from-scratch batch mine of
        // the final database — the end-to-end proof that N refresh
        // cycles (incremental or full) drifted nothing.
        let final_db = final_db.as_ref().unwrap_or(&db);
        let full = build_driver(&cfg)?.mine(final_db).map_err(|e| e.to_string())?;
        let rebuilt = RuleIndex::build(&full.result, s.min_confidence);
        let served = cell.load();
        if served.n_itemsets() != rebuilt.n_itemsets() || served.n_rules() != rebuilt.n_rules() {
            return Err(format!(
                "final-state mismatch: served {} itemsets / {} rules, \
                 from-scratch mine has {} / {}",
                served.n_itemsets(),
                served.n_rules(),
                rebuilt.n_itemsets(),
                rebuilt.n_rules()
            ));
        }
        for basket in &baskets {
            let a = render_lines(&served.recommend(basket, s.top_k));
            let b = render_lines(&rebuilt.recommend(basket, s.top_k));
            if a != b {
                return Err(format!("final-state mismatch for basket {basket:?}"));
            }
        }
        // With the fabric up the scatter-gather path itself must match
        // too — even with the killed node still down (failover answers).
        if let Some(router) = &router {
            for basket in &baskets {
                let routed = router.route(basket, s.top_k).map_err(|e| e.to_string())?;
                let want = render_lines(&rebuilt.recommend(basket, s.top_k));
                if render_lines(&routed.recommendations) != want {
                    return Err(format!("fabric final-state mismatch for basket {basket:?}"));
                }
            }
            println!(
                "final-state check: fabric scatter-gather answers byte-identical \
                 across {} baskets",
                baskets.len(),
            );
        }
        println!(
            "final-state check: served snapshot ({} itemsets, {} rules) byte-identical \
             to a from-scratch mine of the final {} transactions",
            served.n_itemsets(),
            served.n_rules(),
            final_db.len(),
        );
    }
    if let Some((path, sink)) = &trace {
        export_trace(path, sink)?;
    }
    dump_metrics(&registry, trace.is_some());
    Ok(())
}

fn cmd_simulate(flags: &Flags) -> Result<(), String> {
    let cfg = experiment_config(flags)?;
    let db = load_or_generate(flags, &cfg)?;
    // Profile via a real run, then replay on the configured cluster.
    let driver = MrApriori::new(cfg.cluster(), cfg.apriori.clone())
        .with_job(cfg.job.clone())
        .with_split_tx(cfg.split_tx);
    let report = driver.mine(&db).map_err(|e| e.to_string())?;
    let sim = if cfg.pipeline.enabled {
        coordinator::simulate_pipelined(&cfg.cluster(), &report.profile, cfg.split_tx, &cfg.job)
    } else {
        coordinator::simulate(&cfg.cluster(), &report.profile, cfg.split_tx, &cfg.job)
    };
    println!(
        "simulated {:?}/{} nodes: startup {:.1}s + map {:.1}s + shuffle {:.1}s + reduce {:.1}s = {:.1}s (locality {:.0}%, spill {:.0}%)",
        cfg.preset,
        cfg.cluster().n_nodes(),
        sim.startup_secs,
        sim.map_secs,
        sim.shuffle_secs,
        sim.reduce_secs,
        sim.total_secs,
        sim.locality_fraction * 100.0,
        sim.spill_fraction * 100.0,
    );
    Ok(())
}

fn cmd_bench(flags: &Flags) -> Result<(), String> {
    let fig = flags.get("figure").ok_or("--figure required")?;
    let bench = match fig {
        "fig4" => "fig4_fhdsc_vs_fhssc",
        "fig5" => "fig5_tx_vs_config",
        "eta" => "eta_model",
        other => return Err(format!("unknown figure '{other}'")),
    };
    println!("regenerate with: cargo bench --bench {bench}");
    Ok(())
}

/// `repro analyze <trace-file> [--json]`: the post-hoc critical-path
/// report over a `--trace-out` file — stage attribution, per-wave
/// straggler verdicts cross-referenced against chaos faults, and the
/// sampled per-level workload statistics.
fn cmd_analyze(args: &[String]) -> Result<(), String> {
    let mut path: Option<PathBuf> = None;
    let mut json = false;
    for a in args {
        match a.as_str() {
            "--json" => json = true,
            other if !other.starts_with('-') && path.is_none() => path = Some(other.into()),
            other => return Err(format!("analyze: unexpected argument '{other}'")),
        }
    }
    let path = path.ok_or("analyze: usage: repro analyze <trace-file> [--json]")?;
    let profile = obs::profile::analyze_file(&path).map_err(|e| e.to_string())?;
    if json {
        println!("{}", obs::profile::to_json(&profile));
    } else {
        print!("{}", obs::profile::render_table(&profile));
    }
    Ok(())
}

fn cmd_report(_flags: &Flags) -> Result<(), String> {
    let dir = runtime::ArtifactManifest::default_dir();
    println!("artifacts dir: {}", dir.display());
    match runtime::ArtifactManifest::load(&dir) {
        Ok(m) => {
            println!("{} AOT modules:", m.modules.len());
            for spec in &m.modules {
                let roof = perfmodel::KernelRoofline {
                    tile_t: spec.t.min(256),
                    i: spec.i,
                    c: spec.c,
                    elem_bytes: 4,
                };
                println!(
                    "  {:<28} t={:<5} i={:<4} c={:<4} vmem={:>7.1} KiB  AI={:>6.1}  MXU~{:.0}%",
                    format!("{}:{}", spec.graph, spec.variant),
                    spec.t,
                    spec.i,
                    spec.c,
                    roof.vmem_bytes() as f64 / 1024.0,
                    roof.arithmetic_intensity(),
                    roof.mxu_utilization_estimate() * 100.0
                );
            }
        }
        Err(e) => println!("no artifacts ({e}); run `make artifacts`"),
    }
    let _ = apriori::AprioriConfig::default();
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn flags(args: &[&str]) -> Result<Flags, String> {
        Flags::parse(&args.iter().map(|s| s.to_string()).collect::<Vec<_>>())
    }

    #[test]
    fn flags_parse_pairs() {
        let f = flags(&["--nodes", "5", "--preset", "fhdsc"]).unwrap();
        assert_eq!(f.get("nodes"), Some("5"));
        assert_eq!(f.get("preset"), Some("fhdsc"));
        assert_eq!(f.get("missing"), None);
    }

    #[test]
    fn flags_reject_bare_values_and_dangling() {
        assert!(flags(&["nodes", "5"]).is_err());
        assert!(flags(&["--nodes"]).is_err());
    }

    #[test]
    fn experiment_config_overrides_apply() {
        let f = flags(&[
            "--preset", "fhdsc", "--nodes", "7", "--min-support", "0.04",
            "--max-k", "2", "--engine", "trie", "--split-tx", "123",
            "--transactions", "4567", "--seed", "9",
        ])
        .unwrap();
        let cfg = experiment_config(&f).unwrap();
        assert_eq!(cfg.preset, Preset::Fhdsc);
        assert_eq!(cfg.nodes, 7);
        assert_eq!(cfg.apriori.min_support, 0.04);
        assert_eq!(cfg.apriori.max_k, 2);
        assert_eq!(cfg.engine, EngineKind::Trie);
        assert_eq!(cfg.split_tx, 123);
        assert_eq!(cfg.transactions, 4567);
        assert_eq!(cfg.seed, 9);
    }

    #[test]
    fn pipeline_flags_apply() {
        let f = flags(&["--pipeline", "true", "--batch-levels", "1"]).unwrap();
        let cfg = experiment_config(&f).unwrap();
        assert!(cfg.pipeline.enabled);
        assert_eq!(cfg.pipeline.batch_levels, 1);
        let f = flags(&["--batch-levels", "9"]).unwrap();
        assert!(experiment_config(&f).is_err());
    }

    #[test]
    fn serve_flags_apply_and_validate() {
        let f = flags(&[
            "--workers", "6", "--queue-depth", "32", "--top", "7",
            "--min-confidence", "0.8", "--refresh-tx", "100", "--refresh-batches", "3",
        ])
        .unwrap();
        let cfg = experiment_config(&f).unwrap();
        assert_eq!(cfg.serve.workers, 6);
        assert_eq!(cfg.serve.queue_depth, 32);
        assert_eq!(cfg.serve.top_k, 7);
        assert_eq!(cfg.serve.min_confidence, 0.8);
        assert_eq!(cfg.serve.refresh_tx, 100);
        assert_eq!(cfg.serve.refresh_batches, 3);
        for bad in [
            ["--workers", "0"],
            ["--queue-depth", "0"],
            ["--top", "0"],
            ["--min-confidence", "1.5"],
            ["--refresh-tx", "0"],
        ] {
            let f = flags(&bad).unwrap();
            assert!(experiment_config(&f).is_err(), "{bad:?} must be rejected");
        }
    }

    #[test]
    fn refresh_mode_and_deadline_flags_apply() {
        let f = flags(&["--refresh-mode", "incremental", "--deadline-ms", "250"]).unwrap();
        let cfg = experiment_config(&f).unwrap();
        assert!(cfg.incremental.enabled);
        assert_eq!(cfg.serve.deadline_ms, 250);
        let f = flags(&["--refresh-mode", "full"]).unwrap();
        assert!(!experiment_config(&f).unwrap().incremental.enabled);
        let f = flags(&["--refresh-mode", "magic"]).unwrap();
        assert!(experiment_config(&f).is_err());
    }

    #[test]
    fn store_and_lane_flags_apply_and_validate() {
        let f = flags(&[
            "--store-dir", "/tmp/snaps", "--retain", "2", "--no-persist", "true",
            "--internal-queue-depth", "9",
        ])
        .unwrap();
        let cfg = experiment_config(&f).unwrap();
        assert_eq!(
            cfg.store.dir.as_deref(),
            Some(std::path::Path::new("/tmp/snaps"))
        );
        assert_eq!(cfg.store.retain, 2);
        assert!(cfg.store.no_persist);
        assert!(!cfg.store.writes_enabled());
        assert_eq!(cfg.serve.internal_queue_depth, 9);
        // without --no-persist a store dir enables writes
        let f = flags(&["--store-dir", "/tmp/snaps"]).unwrap();
        assert!(experiment_config(&f).unwrap().store.writes_enabled());
        for bad in [["--retain", "0"], ["--internal-queue-depth", "0"]] {
            let f = flags(&bad).unwrap();
            assert!(experiment_config(&f).is_err(), "{bad:?} must be rejected");
        }
    }

    #[test]
    fn fabric_flags_apply_and_validate() {
        let f = flags(&["--shards", "4", "--replicas", "3", "--hedge-ms", "2"]).unwrap();
        let cfg = experiment_config(&f).unwrap();
        assert_eq!(cfg.fabric.shards, 4);
        assert_eq!(cfg.fabric.replicas, 3);
        assert_eq!(cfg.fabric.hedge_ms, 2);
        assert!(cfg.fabric.enabled());
        // --shards 0 is explicit "fabric off", not an error
        let f = flags(&["--shards", "0"]).unwrap();
        assert!(!experiment_config(&f).unwrap().fabric.enabled());
        // defaults: off
        assert!(!experiment_config(&flags(&[]).unwrap()).unwrap().fabric.enabled());
        for bad in [["--replicas", "0"], ["--shards", "many"], ["--hedge-ms", "-1"]] {
            let f = flags(&bad).unwrap();
            assert!(experiment_config(&f).is_err(), "{bad:?} must be rejected");
        }
    }

    #[test]
    fn obs_flags_apply_and_validate() {
        // cfg carries the parsed level (the global setter also runs, but
        // concurrent tests share that atomic, so only the cfg is asserted)
        let f = flags(&["--log-level", "debug"]).unwrap();
        let cfg = experiment_config(&f).unwrap();
        assert_eq!(cfg.obs.log_level, LogLevel::Debug);
        obs::set_log_level(LogLevel::Info);
        let f = flags(&["--log-level", "loud"]).unwrap();
        assert!(experiment_config(&f).is_err());
        let f = flags(&["--trace-out", "/tmp/t.json"]).unwrap();
        let (path, sink) = trace_sink(&f).expect("a sink when --trace-out is given");
        assert_eq!(path, PathBuf::from("/tmp/t.json"));
        assert!(sink.is_empty());
        assert!(trace_sink(&flags(&[]).unwrap()).is_none());
    }

    #[test]
    fn chaos_flags_apply_and_validate() {
        let f = flags(&["--fault-plan", "kill:1@level:2;storeio:2@now"]).unwrap();
        let cfg = experiment_config(&f).unwrap();
        assert_eq!(
            cfg.chaos.plan.as_deref(),
            Some("kill:1@level:2;storeio:2@now")
        );
        assert!(cfg.chaos.enabled());
        let clock = fault_clock(&cfg).unwrap().expect("chaos is on");
        assert_eq!(clock.plan().to_string(), "kill:1@level:2;storeio:2@now");
        // a seed alone derives a survivable random plan for the cluster
        let f = flags(&["--chaos-seed", "7", "--nodes", "3"]).unwrap();
        let cfg = experiment_config(&f).unwrap();
        assert_eq!(cfg.chaos.seed, 7);
        let clock = fault_clock(&cfg).unwrap().expect("seeded chaos is on");
        let cluster = cfg.cluster();
        assert!(clock
            .plan()
            .is_survivable(cluster.n_nodes(), Dfs::new(&cluster).replication));
        // off by default: no clock anywhere near the hot path
        let cfg = experiment_config(&flags(&[]).unwrap()).unwrap();
        assert!(!cfg.chaos.enabled());
        assert!(fault_clock(&cfg).unwrap().is_none());
        // a typo'd plan fails at flag time, before any mining runs
        let f = flags(&["--fault-plan", "explode:1@now"]).unwrap();
        assert!(experiment_config(&f).is_err());
    }

    #[test]
    fn slo_and_flight_flags_apply_and_validate() {
        let f = flags(&[
            "--slo-p99-ms", "5", "--slo-window-ms", "500", "--slo-min-requests", "10",
        ])
        .unwrap();
        let cfg = experiment_config(&f).unwrap();
        assert_eq!(cfg.slo.p99_ms, 5.0);
        assert_eq!(cfg.slo.window_ms, 500);
        assert_eq!(cfg.slo.min_requests, 10);
        assert!(cfg.slo.enabled());
        // off by default: no watcher thread, no instruments
        assert!(!experiment_config(&flags(&[]).unwrap()).unwrap().slo.enabled());
        for bad in [["--slo-p99-ms", "-1"], ["--slo-window-ms", "0"]] {
            let f = flags(&bad).unwrap();
            assert!(experiment_config(&f).is_err(), "{bad:?} must be rejected");
        }
        // --flight-dir without --trace-out still gets a sink to tee off
        let f = flags(&["--flight-dir", "/tmp/flights"]).unwrap();
        let trace = trace_sink(&f);
        assert!(trace.is_none());
        let sink = span_sink(&f, &trace).expect("a sink when --flight-dir is given");
        let rec = attach_flight(&f, Some(&sink)).expect("a recorder too");
        assert_eq!(rec.dir(), Path::new("/tmp/flights"));
        // neither flag: no sink, no recorder
        let f = flags(&[]).unwrap();
        assert!(span_sink(&f, &trace_sink(&f)).is_none());
        assert!(attach_flight(&f, Some(&sink)).is_none());
    }

    #[test]
    fn analyze_args_parse_and_surface_typed_errors() {
        assert!(cmd_analyze(&[]).is_err());
        let err = cmd_analyze(&["/nonexistent/trace.json".to_string()]).unwrap_err();
        assert!(err.contains("trace file"), "io error surfaces: {err}");
        let err =
            cmd_analyze(&["a.json".to_string(), "b.json".to_string()]).unwrap_err();
        assert!(err.contains("unexpected"));
    }

    #[test]
    fn experiment_config_rejects_bad_values() {
        let f = flags(&["--engine", "gpu"]).unwrap();
        assert!(experiment_config(&f).is_err());
        let f = flags(&["--nodes", "many"]).unwrap();
        assert!(experiment_config(&f).is_err());
    }

    #[test]
    fn shipped_config_files_parse() {
        for name in [
            "fig5_fhssc3.toml",
            "tensor_smoke.toml",
            "vertical_smoke.toml",
            "standalone_baseline.toml",
            "serve_smoke.toml",
            "store_smoke.toml",
            "fabric_smoke.toml",
        ] {
            let p = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
                .join("configs")
                .join(name);
            let cfg = ExperimentConfig::load(&p).unwrap_or_else(|e| panic!("{name}: {e}"));
            assert!(cfg.transactions > 0, "{name}");
        }
    }
}

//! Versioned, checksummed binary codec for the durable snapshot store.
//!
//! Zero dependencies (no serde in the offline crate set — DESIGN.md
//! §Substitutions). Every top-level value is wrapped in one **frame**:
//!
//! ```text
//! magic "MRAS" (4) | version u16 | tag u8 | payload_len u64
//!   | payload (payload_len bytes) | fnv1a64(payload) u64
//! ```
//!
//! all integers little-endian, floats as IEEE-754 bit patterns. The
//! decoder verifies magic, version, tag, exact length and checksum
//! *before* touching the payload, and every payload read is
//! bounds-checked, so corruption of any kind — bit flips, truncated
//! tails, appended garbage, a wrong file fed to the wrong decoder —
//! surfaces as a typed [`CodecError`], never a panic, an allocation
//! explosion, or a silently wrong value. (FNV-1a's per-byte step is
//! XOR-then-multiply-by-odd-prime, both invertible mod 2^64, so any
//! single-byte change is *guaranteed* to change the digest —
//! `tests/store.rs` asserts the exhaustive bit-flip corpus.)
//!
//! Sequences are length-prefixed with a sanity bound: a decoded length
//! may never imply more elements than the remaining bytes could hold, so
//! a corrupt length field cannot trigger a huge allocation.

use crate::apriori::rules::Rule;
use crate::apriori::{AprioriConfig, Itemset, LevelStats, MiningResult};
use crate::data::{ItemId, Transaction, TransactionDb};
use crate::incremental::{LevelState, MinedState};
use crate::serve::index::RuleIndex;

use super::{BaseRef, FabricManifest, Manifest, Snapshot, SnapshotRef};

/// File magic: "MR Apriori Snapshot".
pub const MAGIC: [u8; 4] = *b"MRAS";
/// On-disk format version; bump on any layout change.
pub const VERSION: u16 = 1;

const HEADER_LEN: usize = 4 + 2 + 1 + 8;
const CHECKSUM_LEN: usize = 8;

/// Frame kind tags (one per persisted type).
pub const TAG_MINING_RESULT: u8 = 1;
pub const TAG_MINED_STATE: u8 = 2;
pub const TAG_RULE_INDEX: u8 = 3;
pub const TAG_DELTA: u8 = 4;
pub const TAG_SNAPSHOT: u8 = 5;
pub const TAG_MANIFEST: u8 = 6;
pub const TAG_FABRIC_MANIFEST: u8 = 7;

/// Why a buffer failed to decode. Every variant is a detected corruption
/// (or a wrong-file mistake); none of them can escape as a panic.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum CodecError {
    /// Fewer bytes than the format requires at this point.
    Truncated { need: usize, have: usize },
    /// The frame does not start with [`MAGIC`].
    BadMagic([u8; 4]),
    /// A version this build does not understand.
    UnsupportedVersion(u16),
    /// The frame holds a different type than the caller asked for.
    WrongTag { want: u8, got: u8 },
    /// Payload digest mismatch: the bytes changed after encoding.
    Checksum { want: u64, got: u64 },
    /// Bytes beyond the end of a well-formed frame.
    TrailingBytes(usize),
    /// A sequence length field implies more data than the buffer holds.
    LengthOverflow { len: u64, remaining: usize },
}

impl std::fmt::Display for CodecError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Self::Truncated { need, have } => {
                write!(f, "truncated: need {need} bytes, have {have}")
            }
            Self::BadMagic(m) => write!(f, "bad magic {m:?} (want {MAGIC:?})"),
            Self::UnsupportedVersion(v) => {
                write!(f, "unsupported format version {v} (this build reads {VERSION})")
            }
            Self::WrongTag { want, got } => {
                write!(f, "frame holds tag {got}, caller wants tag {want}")
            }
            Self::Checksum { want, got } => {
                write!(f, "checksum mismatch: stored {want:#018x}, computed {got:#018x}")
            }
            Self::TrailingBytes(n) => write!(f, "{n} trailing bytes after the frame"),
            Self::LengthOverflow { len, remaining } => {
                write!(f, "length {len} exceeds the {remaining} remaining bytes")
            }
        }
    }
}

impl std::error::Error for CodecError {}

// ---------------------------------------------------------------- fnv1a

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// FNV-1a over a byte slice.
pub(crate) fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = FNV_OFFSET;
    for &b in bytes {
        h = (h ^ b as u64).wrapping_mul(FNV_PRIME);
    }
    h
}

fn fnv1a_u64(h: u64, v: u64) -> u64 {
    let mut h = h;
    for b in v.to_le_bytes() {
        h = (h ^ b as u64).wrapping_mul(FNV_PRIME);
    }
    h
}

/// Content fingerprint of a transaction database (order-sensitive: the
/// delta journal is positional relative to the base).
pub(crate) fn fingerprint_db(db: &TransactionDb) -> u64 {
    let mut h = fnv1a_u64(FNV_OFFSET, db.len() as u64);
    for t in &db.transactions {
        h = fnv1a_u64(h, t.items.len() as u64);
        for &i in &t.items {
            h = fnv1a_u64(h, i as u64);
        }
    }
    h
}

// ---------------------------------------------------------------- frame

fn frame(tag: u8, payload: Vec<u8>) -> Vec<u8> {
    let mut out = Vec::with_capacity(HEADER_LEN + payload.len() + CHECKSUM_LEN);
    out.extend_from_slice(&MAGIC);
    out.extend_from_slice(&VERSION.to_le_bytes());
    out.push(tag);
    out.extend_from_slice(&(payload.len() as u64).to_le_bytes());
    let sum = fnv1a(&payload);
    out.extend_from_slice(&payload);
    out.extend_from_slice(&sum.to_le_bytes());
    out
}

fn unframe(want_tag: u8, bytes: &[u8]) -> Result<&[u8], CodecError> {
    if bytes.len() < HEADER_LEN + CHECKSUM_LEN {
        return Err(CodecError::Truncated {
            need: HEADER_LEN + CHECKSUM_LEN,
            have: bytes.len(),
        });
    }
    let magic: [u8; 4] = bytes[0..4].try_into().expect("4 bytes");
    if magic != MAGIC {
        return Err(CodecError::BadMagic(magic));
    }
    let version = u16::from_le_bytes(bytes[4..6].try_into().expect("2 bytes"));
    if version != VERSION {
        return Err(CodecError::UnsupportedVersion(version));
    }
    let tag = bytes[6];
    if tag != want_tag {
        return Err(CodecError::WrongTag { want: want_tag, got: tag });
    }
    let payload_len = u64::from_le_bytes(bytes[7..15].try_into().expect("8 bytes"));
    // checked: a corrupt length near u64::MAX must be an error, not an
    // arithmetic-overflow panic in debug builds
    let Some(total) = payload_len.checked_add((HEADER_LEN + CHECKSUM_LEN) as u64) else {
        return Err(CodecError::LengthOverflow {
            len: payload_len,
            remaining: bytes.len() - HEADER_LEN - CHECKSUM_LEN,
        });
    };
    if (bytes.len() as u64) < total {
        return Err(CodecError::LengthOverflow {
            len: payload_len,
            remaining: bytes.len() - HEADER_LEN - CHECKSUM_LEN,
        });
    }
    if bytes.len() as u64 > total {
        return Err(CodecError::TrailingBytes(bytes.len() - total as usize));
    }
    let payload = &bytes[HEADER_LEN..HEADER_LEN + payload_len as usize];
    let stored =
        u64::from_le_bytes(bytes[bytes.len() - CHECKSUM_LEN..].try_into().expect("8 bytes"));
    let computed = fnv1a(payload);
    if stored != computed {
        return Err(CodecError::Checksum { want: stored, got: computed });
    }
    Ok(payload)
}

// ------------------------------------------------------------- writers

fn put_u8(buf: &mut Vec<u8>, v: u8) {
    buf.push(v);
}

fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_f64(buf: &mut Vec<u8>, v: f64) {
    put_u64(buf, v.to_bits());
}

fn put_itemset(buf: &mut Vec<u8>, is: &[ItemId]) {
    put_u64(buf, is.len() as u64);
    for &i in is {
        put_u32(buf, i);
    }
}

fn put_counted(buf: &mut Vec<u8>, rows: &[(Itemset, u64)]) {
    put_u64(buf, rows.len() as u64);
    for (is, s) in rows {
        put_itemset(buf, is);
        put_u64(buf, *s);
    }
}

fn put_transactions(buf: &mut Vec<u8>, txs: &[Transaction]) {
    put_u64(buf, txs.len() as u64);
    for t in txs {
        put_itemset(buf, &t.items);
    }
}

fn put_rule(buf: &mut Vec<u8>, r: &Rule) {
    put_itemset(buf, &r.antecedent);
    put_itemset(buf, &r.consequent);
    put_u64(buf, r.support);
    put_f64(buf, r.confidence);
    put_f64(buf, r.lift);
}

fn put_mining_result(buf: &mut Vec<u8>, r: &MiningResult) {
    put_u64(buf, r.n_transactions as u64);
    put_u64(buf, r.levels.len() as u64);
    for l in &r.levels {
        put_u64(buf, l.k as u64);
        put_u64(buf, l.n_candidates as u64);
        put_u64(buf, l.n_frequent as u64);
        put_f64(buf, l.work_units);
        put_f64(buf, l.wall_secs);
    }
    put_counted(buf, &r.frequent);
}

fn put_mined_state(buf: &mut Vec<u8>, s: &MinedState) {
    put_f64(buf, s.apriori.min_support);
    put_u64(buf, s.apriori.max_k as u64);
    put_u64(buf, s.n_transactions as u64);
    put_u64(buf, s.n_items as u64);
    put_u64(buf, s.levels.len() as u64);
    for l in &s.levels {
        put_counted(buf, &l.frequent);
        put_counted(buf, &l.border);
    }
}

fn put_rule_index(buf: &mut Vec<u8>, idx: &RuleIndex) {
    put_u64(buf, idx.n_transactions as u64);
    put_f64(buf, idx.min_confidence);
    put_counted(buf, &idx.support_entries());
    let rules = idx.rules();
    put_u64(buf, rules.len() as u64);
    for r in rules {
        put_rule(buf, r);
    }
}

// ------------------------------------------------------------- readers

struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], CodecError> {
        if self.remaining() < n {
            return Err(CodecError::Truncated { need: n, have: self.remaining() });
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, CodecError> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32, CodecError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().expect("4 bytes")))
    }

    fn u64(&mut self) -> Result<u64, CodecError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().expect("8 bytes")))
    }

    fn f64(&mut self) -> Result<f64, CodecError> {
        Ok(f64::from_bits(self.u64()?))
    }

    fn usize(&mut self) -> Result<usize, CodecError> {
        let v = self.u64()?;
        usize::try_from(v)
            .map_err(|_| CodecError::LengthOverflow { len: v, remaining: self.remaining() })
    }

    /// A sequence length whose elements take at least `min_elem_bytes`
    /// each — bounds the implied size against the remaining buffer so a
    /// corrupt length cannot drive a huge allocation.
    fn seq_len(&mut self, min_elem_bytes: usize) -> Result<usize, CodecError> {
        let len = self.u64()?;
        let remaining = self.remaining();
        let implied = len.checked_mul(min_elem_bytes.max(1) as u64);
        match implied {
            Some(bytes) if bytes <= remaining as u64 => Ok(len as usize),
            _ => Err(CodecError::LengthOverflow { len, remaining }),
        }
    }

    fn itemset(&mut self) -> Result<Itemset, CodecError> {
        let n = self.seq_len(4)?;
        let mut is = Vec::with_capacity(n);
        for _ in 0..n {
            is.push(self.u32()?);
        }
        Ok(is)
    }

    fn counted(&mut self) -> Result<Vec<(Itemset, u64)>, CodecError> {
        // each row is at least an empty itemset (8) plus a count (8)
        let n = self.seq_len(16)?;
        let mut rows = Vec::with_capacity(n);
        for _ in 0..n {
            let is = self.itemset()?;
            let s = self.u64()?;
            rows.push((is, s));
        }
        Ok(rows)
    }

    fn transactions(&mut self) -> Result<Vec<Transaction>, CodecError> {
        let n = self.seq_len(8)?;
        let mut txs = Vec::with_capacity(n);
        for _ in 0..n {
            // Transaction::new re-canonicalizes (sort + dedup); encoded
            // transactions are already canonical, so this is the identity
            // on round-trips and an invariant repair on anything else.
            txs.push(Transaction::new(self.itemset()?));
        }
        Ok(txs)
    }

    fn rule(&mut self) -> Result<Rule, CodecError> {
        Ok(Rule {
            antecedent: self.itemset()?,
            consequent: self.itemset()?,
            support: self.u64()?,
            confidence: self.f64()?,
            lift: self.f64()?,
        })
    }

    fn mining_result(&mut self) -> Result<MiningResult, CodecError> {
        let n_transactions = self.usize()?;
        let n_levels = self.seq_len(40)?;
        let mut levels = Vec::with_capacity(n_levels);
        for _ in 0..n_levels {
            levels.push(LevelStats {
                k: self.usize()?,
                n_candidates: self.usize()?,
                n_frequent: self.usize()?,
                work_units: self.f64()?,
                wall_secs: self.f64()?,
            });
        }
        let frequent = self.counted()?;
        Ok(MiningResult { frequent, levels, n_transactions })
    }

    fn mined_state(&mut self) -> Result<MinedState, CodecError> {
        let min_support = self.f64()?;
        let max_k = self.usize()?;
        let n_transactions = self.usize()?;
        let n_items = self.usize()?;
        let n_levels = self.seq_len(16)?;
        let mut levels = Vec::with_capacity(n_levels);
        for _ in 0..n_levels {
            levels.push(LevelState {
                frequent: self.counted()?,
                border: self.counted()?,
            });
        }
        Ok(MinedState {
            apriori: AprioriConfig { min_support, max_k },
            n_transactions,
            n_items,
            levels,
        })
    }

    fn rule_index(&mut self) -> Result<RuleIndex, CodecError> {
        let n_transactions = self.usize()?;
        let min_confidence = self.f64()?;
        let support = self.counted()?;
        let n_rules = self.seq_len(40)?;
        let mut rules = Vec::with_capacity(n_rules);
        for _ in 0..n_rules {
            rules.push(self.rule()?);
        }
        Ok(RuleIndex::from_parts(rules, support, n_transactions, min_confidence))
    }

    fn done(&self) -> Result<(), CodecError> {
        if self.remaining() == 0 {
            Ok(())
        } else {
            Err(CodecError::TrailingBytes(self.remaining()))
        }
    }
}

// ------------------------------------------------------ public framed API

/// Encode a [`MiningResult`] as one framed buffer.
pub fn encode_mining_result(r: &MiningResult) -> Vec<u8> {
    let mut buf = Vec::new();
    put_mining_result(&mut buf, r);
    frame(TAG_MINING_RESULT, buf)
}

pub fn decode_mining_result(bytes: &[u8]) -> Result<MiningResult, CodecError> {
    let mut r = Reader::new(unframe(TAG_MINING_RESULT, bytes)?);
    let out = r.mining_result()?;
    r.done()?;
    Ok(out)
}

/// Encode a [`MinedState`] (frequent itemsets + negative border).
pub fn encode_mined_state(s: &MinedState) -> Vec<u8> {
    let mut buf = Vec::new();
    put_mined_state(&mut buf, s);
    frame(TAG_MINED_STATE, buf)
}

pub fn decode_mined_state(bytes: &[u8]) -> Result<MinedState, CodecError> {
    let mut r = Reader::new(unframe(TAG_MINED_STATE, bytes)?);
    let out = r.mined_state()?;
    r.done()?;
    Ok(out)
}

/// Encode a serving [`RuleIndex`]. The support table is written in the
/// canonical (len, lexicographic) order so identical indexes encode to
/// identical bytes regardless of hash-map iteration order.
pub fn encode_rule_index(idx: &RuleIndex) -> Vec<u8> {
    let mut buf = Vec::new();
    put_rule_index(&mut buf, idx);
    frame(TAG_RULE_INDEX, buf)
}

pub fn decode_rule_index(bytes: &[u8]) -> Result<RuleIndex, CodecError> {
    let mut r = Reader::new(unframe(TAG_RULE_INDEX, bytes)?);
    let out = r.rule_index()?;
    r.done()?;
    Ok(out)
}

/// Encode a transaction delta (the journal payload).
pub fn encode_delta(delta: &[Transaction]) -> Vec<u8> {
    let mut buf = Vec::new();
    put_transactions(&mut buf, delta);
    frame(TAG_DELTA, buf)
}

pub fn decode_delta(bytes: &[u8]) -> Result<Vec<Transaction>, CodecError> {
    let mut r = Reader::new(unframe(TAG_DELTA, bytes)?);
    let out = r.transactions()?;
    r.done()?;
    Ok(out)
}

/// Encode one whole generation (delta + result + optional state + index).
///
/// `s.index` must have been built from `s.result` (every writer in this
/// crate does exactly that): the index's support table *is*
/// `result.frequent`, so only the rules are written and the table is
/// reconstructed at decode — the dominant payload is stored once, not
/// twice.
pub fn encode_snapshot(s: &SnapshotRef<'_>) -> Vec<u8> {
    // Hard precondition, checked in release too (O(1)): silently
    // persisting an index that disagrees with `result` would decode to a
    // *different* index — exactly the wrong-value class this codec
    // promises cannot happen.
    assert_eq!(
        s.index.n_itemsets(),
        s.result.frequent.len(),
        "snapshot index must be built from the snapshot's result"
    );
    assert_eq!(
        s.index.n_transactions, s.result.n_transactions,
        "snapshot index must be built from the snapshot's result"
    );
    let mut buf = Vec::new();
    put_u64(&mut buf, s.generation);
    put_u64(&mut buf, s.base.n_tx);
    put_u64(&mut buf, s.base.fingerprint);
    put_f64(&mut buf, s.min_support);
    put_u64(&mut buf, s.max_k as u64);
    put_f64(&mut buf, s.index.min_confidence);
    put_transactions(&mut buf, s.delta);
    match s.state {
        Some(state) => {
            put_u8(&mut buf, 1);
            put_mined_state(&mut buf, state);
        }
        None => put_u8(&mut buf, 0),
    }
    put_mining_result(&mut buf, s.result);
    let rules = s.index.rules();
    put_u64(&mut buf, rules.len() as u64);
    for rule in rules {
        put_rule(&mut buf, rule);
    }
    frame(TAG_SNAPSHOT, buf)
}

pub fn decode_snapshot(bytes: &[u8]) -> Result<Snapshot, CodecError> {
    let mut r = Reader::new(unframe(TAG_SNAPSHOT, bytes)?);
    let generation = r.u64()?;
    let base = BaseRef { n_tx: r.u64()?, fingerprint: r.u64()? };
    let min_support = r.f64()?;
    let max_k = r.usize()?;
    let min_confidence = r.f64()?;
    let delta = r.transactions()?;
    let state = match r.u8()? {
        0 => None,
        _ => Some(r.mined_state()?),
    };
    let result = r.mining_result()?;
    let n_rules = r.seq_len(40)?;
    let mut rules = Vec::with_capacity(n_rules);
    for _ in 0..n_rules {
        rules.push(r.rule()?);
    }
    r.done()?;
    let index = RuleIndex::from_parts(
        rules,
        result.frequent.clone(),
        result.n_transactions,
        min_confidence,
    );
    Ok(Snapshot { generation, base, min_support, max_k, delta, result, state, index })
}

/// Encode the store manifest (live generation + retained history).
pub fn encode_manifest(m: &Manifest) -> Vec<u8> {
    let mut buf = Vec::new();
    put_u64(&mut buf, m.live);
    put_u64(&mut buf, m.retained.len() as u64);
    for &g in &m.retained {
        put_u64(&mut buf, g);
    }
    frame(TAG_MANIFEST, buf)
}

pub fn decode_manifest(bytes: &[u8]) -> Result<Manifest, CodecError> {
    let mut r = Reader::new(unframe(TAG_MANIFEST, bytes)?);
    let live = r.u64()?;
    let n = r.seq_len(8)?;
    let mut retained = Vec::with_capacity(n);
    for _ in 0..n {
        retained.push(r.u64()?);
    }
    r.done()?;
    Ok(Manifest { live, retained })
}

/// Encode the serving fabric's cross-shard cut manifest — the frame whose
/// atomic flip publishes a generation across every shard at once.
pub fn encode_fabric_manifest(m: &FabricManifest) -> Vec<u8> {
    let mut buf = Vec::new();
    put_u64(&mut buf, m.generation);
    put_u64(&mut buf, m.n_shards as u64);
    put_u64(&mut buf, m.replicas as u64);
    put_u64(&mut buf, m.shard_rules.len() as u64);
    for &n in &m.shard_rules {
        put_u64(&mut buf, n);
    }
    frame(TAG_FABRIC_MANIFEST, buf)
}

pub fn decode_fabric_manifest(bytes: &[u8]) -> Result<FabricManifest, CodecError> {
    let mut r = Reader::new(unframe(TAG_FABRIC_MANIFEST, bytes)?);
    let generation = r.u64()?;
    let n_shards = r.usize()?;
    let replicas = r.usize()?;
    let n = r.seq_len(8)?;
    let mut shard_rules = Vec::with_capacity(n);
    for _ in 0..n {
        shard_rules.push(r.u64()?);
    }
    r.done()?;
    Ok(FabricManifest { generation, n_shards, replicas, shard_rules })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apriori::classical::{tests::textbook_db, ClassicalApriori};
    use crate::cluster::ClusterConfig;
    use crate::coordinator::MrApriori;
    use crate::serve::index::render_lines;

    fn cfg() -> AprioriConfig {
        AprioriConfig { min_support: 2.0 / 9.0, max_k: 0 }
    }

    fn mined() -> MiningResult {
        ClassicalApriori::default().mine(&textbook_db(), &cfg())
    }

    #[test]
    fn fnv1a_known_vectors() {
        // FNV-1a 64-bit reference values.
        assert_eq!(fnv1a(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a(b"foobar"), 0x85944171f73967e8);
    }

    #[test]
    fn mining_result_roundtrip_is_exact() {
        let r = mined();
        let bytes = encode_mining_result(&r);
        let back = decode_mining_result(&bytes).unwrap();
        assert_eq!(format!("{r:?}"), format!("{back:?}"));
    }

    #[test]
    fn mined_state_roundtrip_is_exact() {
        let db = textbook_db();
        let driver = MrApriori::new(ClusterConfig::standalone(), cfg()).with_split_tx(3);
        let (_, state) = MinedState::capture(&driver, &db).unwrap();
        let bytes = encode_mined_state(&state);
        let back = decode_mined_state(&bytes).unwrap();
        assert_eq!(format!("{state:?}"), format!("{back:?}"));
        assert_eq!(back.to_result().frequent, state.to_result().frequent);
    }

    #[test]
    fn rule_index_roundtrip_serves_identically_and_encodes_canonically() {
        let r = mined();
        let idx = RuleIndex::build(&r, 0.3);
        let bytes = encode_rule_index(&idx);
        // hash-map iteration order must not leak into the encoding
        assert_eq!(bytes, encode_rule_index(&idx));
        let back = decode_rule_index(&bytes).unwrap();
        assert_eq!(back.n_rules(), idx.n_rules());
        assert_eq!(back.n_itemsets(), idx.n_itemsets());
        assert_eq!(back.n_transactions, idx.n_transactions);
        for basket in [vec![0u32], vec![0, 1], vec![1, 2, 3], vec![0, 1, 2, 3, 4]] {
            assert_eq!(
                render_lines(&back.recommend(&basket, 10)),
                render_lines(&idx.recommend(&basket, 10)),
                "basket {basket:?}"
            );
        }
        for (is, s) in &r.frequent {
            assert_eq!(back.support_of(is), Some(*s));
        }
    }

    #[test]
    fn delta_and_manifest_roundtrip() {
        let delta = vec![
            Transaction::new([3u32, 1, 4]),
            Transaction::new([]),
            Transaction::new([9u32]),
        ];
        assert_eq!(decode_delta(&encode_delta(&delta)).unwrap(), delta);
        let m = Manifest { live: 7, retained: vec![5, 6, 7] };
        assert_eq!(decode_manifest(&encode_manifest(&m)).unwrap(), m);
    }

    #[test]
    fn fabric_manifest_roundtrip_and_corruption_rejected() {
        let m = FabricManifest {
            generation: 42,
            n_shards: 4,
            replicas: 2,
            shard_rules: vec![10, 0, 7, 3],
        };
        let bytes = encode_fabric_manifest(&m);
        assert_eq!(decode_fabric_manifest(&bytes).unwrap(), m);
        // the fabric manifest is its own frame type, not the store manifest
        assert!(matches!(
            decode_manifest(&bytes),
            Err(CodecError::WrongTag { want: TAG_MANIFEST, got: TAG_FABRIC_MANIFEST })
        ));
        assert!(matches!(
            decode_fabric_manifest(&encode_manifest(&Manifest { live: 1, retained: vec![1] })),
            Err(CodecError::WrongTag { want: TAG_FABRIC_MANIFEST, got: TAG_MANIFEST })
        ));
        // any payload bit flip fails the checksum; a torn tail truncates
        for i in HEADER_LEN..bytes.len() {
            let mut bad = bytes.clone();
            bad[i] ^= 0x04;
            assert!(decode_fabric_manifest(&bad).is_err(), "flip at {i} accepted");
        }
        assert!(decode_fabric_manifest(&bytes[..bytes.len() - 3]).is_err());
    }

    #[test]
    fn snapshot_roundtrip_with_and_without_state() {
        let db = textbook_db();
        let r = mined();
        let idx = RuleIndex::build(&r, 0.3);
        let driver = MrApriori::new(ClusterConfig::standalone(), cfg()).with_split_tx(3);
        let (_, state) = MinedState::capture(&driver, &db).unwrap();
        let delta = vec![Transaction::new([0u32, 1])];
        for state_opt in [None, Some(&state)] {
            let snap = SnapshotRef {
                generation: 3,
                base: BaseRef::of(&db),
                min_support: 2.0 / 9.0,
                max_k: 0,
                delta: &delta,
                result: &r,
                state: state_opt,
                index: &idx,
            };
            let back = decode_snapshot(&encode_snapshot(&snap)).unwrap();
            assert_eq!(back.generation, 3);
            assert_eq!(back.base, BaseRef::of(&db));
            assert_eq!(back.min_support, 2.0 / 9.0);
            assert_eq!(back.max_k, 0);
            assert_eq!(back.delta, delta);
            assert_eq!(format!("{:?}", back.result), format!("{r:?}"));
            assert_eq!(back.state.is_some(), state_opt.is_some());
            if let (Some(a), Some(b)) = (&back.state, state_opt) {
                assert_eq!(format!("{a:?}"), format!("{b:?}"));
            }
            assert_eq!(back.index.n_rules(), idx.n_rules());
        }
    }

    #[test]
    fn wrong_tag_and_wrong_type_rejected() {
        let bytes = encode_delta(&[]);
        assert!(matches!(
            decode_manifest(&bytes),
            Err(CodecError::WrongTag { want: TAG_MANIFEST, got: TAG_DELTA })
        ));
        assert!(decode_mining_result(&bytes).is_err());
    }

    #[test]
    fn header_corruptions_each_hit_their_typed_error() {
        let good = encode_manifest(&Manifest { live: 1, retained: vec![1] });
        let mut bad_magic = good.clone();
        bad_magic[0] ^= 0xFF;
        assert!(matches!(decode_manifest(&bad_magic), Err(CodecError::BadMagic(_))));
        let mut bad_version = good.clone();
        bad_version[4] ^= 0x01;
        assert!(matches!(
            decode_manifest(&bad_version),
            Err(CodecError::UnsupportedVersion(_))
        ));
        let mut bad_len = good.clone();
        bad_len[7] ^= 0x01; // payload_len low byte
        assert!(decode_manifest(&bad_len).is_err());
        let mut trailing = good.clone();
        trailing.push(0);
        assert!(matches!(
            decode_manifest(&trailing),
            Err(CodecError::TrailingBytes(1))
        ));
        assert!(matches!(
            decode_manifest(&good[..good.len() - 1]),
            Err(CodecError::Truncated { .. }) | Err(CodecError::LengthOverflow { .. })
        ));
    }

    #[test]
    fn corrupt_length_field_cannot_allocate_past_the_buffer() {
        // A huge in-payload sequence length must be rejected by the
        // remaining-bytes bound, not attempted as an allocation. Build a
        // valid frame whose payload *content* lies about its length —
        // checksummed correctly, so only the bound catches it.
        let mut payload = Vec::new();
        put_u64(&mut payload, 1); // live
        put_u64(&mut payload, u64::MAX); // retained count: absurd
        let bytes = frame(TAG_MANIFEST, payload);
        assert!(matches!(
            decode_manifest(&bytes),
            Err(CodecError::LengthOverflow { .. })
        ));
    }

    #[test]
    fn nan_lift_rules_round_trip_bit_exactly() {
        let rule = Rule {
            antecedent: vec![1],
            consequent: vec![2],
            support: 3,
            confidence: 0.5,
            lift: f64::NAN,
        };
        let idx = RuleIndex::from_parts(vec![rule], vec![(vec![1], 3)], 10, 0.5);
        let back = decode_rule_index(&encode_rule_index(&idx)).unwrap();
        assert!(back.rules()[0].lift.is_nan());
        assert_eq!(
            back.rules()[0].lift.to_bits(),
            idx.rules()[0].lift.to_bits()
        );
    }
}

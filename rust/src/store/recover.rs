//! Warm restart: rehydrate the newest intact persisted generation into
//! the serving/incremental stack.
//!
//! The contract the acceptance tests pin: a `serve` process killed at any
//! point and restarted with the same base database and `--store-dir`
//! resumes at the last *published* generation — the serving cell seeds at
//! that generation number, the union database is reconstructed from the
//! persisted cumulative delta, and (in incremental mode) the `Refresher`
//! is re-seeded with the persisted [`MinedState`] border, so the next
//! micro-batch refresh runs the delta path instead of a cold
//! capture-mine of the full database. Served answers after recovery are
//! byte-identical to an uninterrupted run at that generation.

use std::sync::Arc;

use crate::apriori::MiningResult;
use crate::data::{Transaction, TransactionDb};
use crate::incremental::MinedState;
use crate::serve::index::RuleIndex;
use crate::serve::snapshot::SnapshotCell;

use super::{BaseRef, SnapshotStore, StoreError};

/// Everything the newest intact generation holds, verified against the
/// caller's base database.
#[derive(Debug)]
pub struct WarmStart {
    /// The recovered generation number (the serving cell seeds here).
    pub generation: u64,
    /// Confidence floor the persisted index was built with.
    pub min_confidence: f64,
    /// Mining parameters the generation was produced under — callers
    /// must refuse to resume refreshing under drifted flags.
    pub min_support: f64,
    pub max_k: usize,
    /// Canonical mining result of the generation.
    pub result: MiningResult,
    /// Incremental border state, when the generation carried one.
    pub state: Option<MinedState>,
    /// The serving index, decoded — no `generate_rules` re-derivation.
    pub index: RuleIndex,
    /// Cumulative transactions to append to the base to rebuild the
    /// union database of `generation`.
    pub delta: Vec<Transaction>,
}

/// Load the newest intact generation and verify it belongs to the base
/// identified by `want` (computed once by the caller via [`BaseRef::of`]
/// — the O(|D|) fingerprint pass is not repeated here).
///
/// * `Ok(None)` — the store holds no intact generation (cold start).
/// * `Err(BaseMismatch)` — the store was written for different data; the
///   caller must not resume from it (serving answers about the wrong
///   database is worse than a cold start).
pub fn warm_start(store: &SnapshotStore, want: BaseRef) -> Result<Option<WarmStart>, StoreError> {
    let Some(snap) = store.load_latest()? else {
        return Ok(None);
    };
    if snap.base != want {
        return Err(StoreError::BaseMismatch { want, got: snap.base });
    }
    let min_confidence = snap.index.min_confidence;
    Ok(Some(WarmStart {
        generation: snap.generation,
        min_confidence,
        min_support: snap.min_support,
        max_k: snap.max_k,
        result: snap.result,
        state: snap.state,
        index: snap.index,
        delta: snap.delta,
    }))
}

/// A warm-started serving stack, ready to answer queries.
#[derive(Debug)]
pub struct Resumed {
    /// Serving cell seeded with the recovered index *at the recovered
    /// generation number* — response generations continue the pre-kill
    /// sequence instead of restarting at zero.
    pub cell: Arc<SnapshotCell<RuleIndex>>,
    pub generation: u64,
    pub min_confidence: f64,
    /// Mining parameters the generation was produced under.
    pub min_support: f64,
    pub max_k: usize,
    pub result: MiningResult,
    /// Seed for `Refresher::seed_state` in incremental mode.
    pub state: Option<MinedState>,
}

/// One-call warm restart: `db` must be the pristine base database and
/// `base` its [`BaseRef`]; on success `db` is extended to the persisted
/// union and a serving cell is returned seeded at the recovered
/// generation.
pub fn resume_serving(
    store: &SnapshotStore,
    db: &mut TransactionDb,
    base: BaseRef,
) -> Result<Option<Resumed>, StoreError> {
    let Some(warm) = warm_start(store, base)? else {
        return Ok(None);
    };
    debug_assert_eq!(
        db.len() + warm.delta.len(),
        warm.result.n_transactions,
        "persisted delta must extend the base to the generation's union"
    );
    db.append(warm.delta);
    let cell = Arc::new(SnapshotCell::with_generation(
        Arc::new(warm.index),
        warm.generation,
    ));
    Ok(Some(Resumed {
        cell,
        generation: warm.generation,
        min_confidence: warm.min_confidence,
        min_support: warm.min_support,
        max_k: warm.max_k,
        result: warm.result,
        state: warm.state,
    }))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apriori::classical::{tests::textbook_db, ClassicalApriori};
    use crate::apriori::AprioriConfig;
    use crate::cluster::ClusterConfig;
    use crate::coordinator::MrApriori;
    use crate::serve::index::render_lines;
    use crate::store::SnapshotRef;
    use crate::util::tempdir::TempDir;

    fn cfg() -> AprioriConfig {
        AprioriConfig { min_support: 2.0 / 9.0, max_k: 0 }
    }

    #[test]
    fn resume_extends_db_and_seeds_cell_at_the_persisted_generation() {
        let tmp = TempDir::new("recover_resume");
        let store = SnapshotStore::open(tmp.path(), 4).unwrap();
        let base = textbook_db();
        let delta = vec![
            crate::data::Transaction::new([0u32, 1]),
            crate::data::Transaction::new([2u32, 4]),
        ];
        let mut union = base.clone();
        union.append(delta.clone());
        let driver = MrApriori::new(ClusterConfig::standalone(), cfg()).with_split_tx(4);
        let (report, state) = MinedState::capture(&driver, &union).unwrap();
        let index = RuleIndex::build(&report.result, 0.3);
        store
            .publish(&SnapshotRef {
                generation: 2,
                base: BaseRef::of(&base),
                min_support: 2.0 / 9.0,
                max_k: 0,
                delta: &delta,
                result: &report.result,
                state: Some(&state),
                index: &index,
            })
            .unwrap();

        // "restart": pristine base, everything else from disk
        let mut db = base.clone();
        let resumed =
            resume_serving(&store, &mut db, BaseRef::of(&base)).unwrap().expect("warm");
        assert_eq!(resumed.generation, 2);
        assert_eq!(db.len(), union.len());
        assert_eq!(db.transactions, union.transactions);
        assert_eq!(resumed.cell.generation(), 2);
        assert_eq!(resumed.min_confidence, 0.3);
        let recovered_state = resumed.state.expect("state persisted");
        assert_eq!(
            recovered_state.to_result().frequent,
            ClassicalApriori::default().mine(&db, &cfg()).frequent
        );
        // the recovered index answers like a freshly built one
        let fresh = RuleIndex::build(&report.result, 0.3);
        let served = resumed.cell.load();
        for basket in [vec![0u32, 1], vec![1, 2], vec![0, 4]] {
            assert_eq!(
                render_lines(&served.recommend(&basket, 5)),
                render_lines(&fresh.recommend(&basket, 5))
            );
        }
    }

    #[test]
    fn empty_store_is_a_cold_start() {
        let tmp = TempDir::new("cold");
        let store = SnapshotStore::open(tmp.path(), 4).unwrap();
        let mut db = textbook_db();
        assert!(resume_serving(&store, &mut db, BaseRef::of(&db)).unwrap().is_none());
        assert_eq!(db.len(), 9);
    }

    #[test]
    fn mismatched_base_refuses_to_resume() {
        let tmp = TempDir::new("mismatch");
        let store = SnapshotStore::open(tmp.path(), 4).unwrap();
        let base = textbook_db();
        let result = ClassicalApriori::default().mine(&base, &cfg());
        let index = RuleIndex::build(&result, 0.3);
        store
            .publish(&SnapshotRef {
                generation: 1,
                base: BaseRef::of(&base),
                min_support: 2.0 / 9.0,
                max_k: 0,
                delta: &[],
                result: &result,
                state: None,
                index: &index,
            })
            .unwrap();
        let mut other = base.clone();
        other.transactions.pop();
        assert!(matches!(
            warm_start(&store, BaseRef::of(&other)),
            Err(StoreError::BaseMismatch { .. })
        ));
    }
}

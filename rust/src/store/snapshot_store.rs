//! The generation-aware on-disk snapshot store and its crash-consistent
//! commit protocol.
//!
//! Layout (all files in one directory):
//!
//! ```text
//! <dir>/gen-00000042.snap   one framed Snapshot per generation
//! <dir>/MANIFEST            framed Manifest: live generation + retained
//! <dir>/*.tmp               in-flight writes (ignored by recovery)
//! ```
//!
//! **Commit protocol** (per published generation g):
//!
//! 1. write `gen-g.tmp`, fsync it;
//! 2. atomically rename it to `gen-g.snap` (+ best-effort dir fsync);
//! 3. write `MANIFEST.tmp` (live = g, retained window), fsync it;
//! 4. atomically rename it to `MANIFEST` (+ best-effort dir fsync);
//! 5. prune generations outside the retained window.
//!
//! A crash at any boundary leaves either the old `MANIFEST` pointing at
//! the previous intact generation, or the new one pointing at g whose
//! file is already durable — never a manifest pointing at a missing or
//! partial snapshot. Recovery ([`SnapshotStore::load_latest`]) trusts the
//! manifest first; if the manifest is missing, corrupt, or points at a
//! damaged file, it degrades to scanning for the newest generation that
//! decodes intact. Corruption of any retained file therefore costs at
//! most a fallback to an older generation — never a panic.
//!
//! [`publish_with_hook`](SnapshotStore::publish_with_hook) exposes every
//! protocol boundary to tests, which kill the commit at each step and
//! assert recovery still lands on a complete generation.

use std::fs;
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::chaos::FaultClock;
use crate::dfs::BlockStore;

use super::{codec, BaseRef, CodecError, Manifest, Snapshot, SnapshotRef};

/// Why a store operation failed. Codec errors are wrapped with the file
/// they came from; `load_latest` treats them as "skip this generation",
/// so they only surface when *nothing* intact remains.
#[derive(Debug)]
pub enum StoreError {
    Io { path: PathBuf, err: std::io::Error },
    Codec { path: PathBuf, err: CodecError },
    /// A commit syscall kept failing past the bounded retry budget
    /// (transient-fault tolerance exhausted); `err` is the last failure.
    Exhausted { op: &'static str, path: PathBuf, attempts: usize, err: std::io::Error },
    /// A generation file decoded to a different generation number than
    /// its name claims — treated like corruption.
    GenerationMismatch { path: PathBuf, want: u64, got: u64 },
    /// The store was written against a different base database; warm
    /// restart refuses to resume over the wrong data.
    BaseMismatch { want: BaseRef, got: BaseRef },
}

impl std::fmt::Display for StoreError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Self::Io { path, err } => write!(f, "{}: {err}", path.display()),
            Self::Codec { path, err } => write!(f, "{}: {err}", path.display()),
            Self::Exhausted { op, path, attempts, err } => write!(
                f,
                "{}: {op} still failing after {attempts} attempts: {err}",
                path.display()
            ),
            Self::GenerationMismatch { path, want, got } => write!(
                f,
                "{}: file named generation {want} decodes as generation {got}",
                path.display()
            ),
            Self::BaseMismatch { want, got } => write!(
                f,
                "store was written for a different base database \
                 (want {} tx / fingerprint {:#018x}, store has {} tx / {:#018x})",
                want.n_tx, want.fingerprint, got.n_tx, got.fingerprint
            ),
        }
    }
}

impl std::error::Error for StoreError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Self::Io { err, .. } => Some(err),
            Self::Codec { err, .. } => Some(err),
            Self::Exhausted { err, .. } => Some(err),
            _ => None,
        }
    }
}

/// One boundary of the commit protocol, in order. The publish hook fires
/// *after* the step completes; returning `false` abandons the commit
/// there — exactly the on-disk state a kill at that boundary leaves.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CommitStep {
    /// Snapshot bytes written to the temp file (not yet synced).
    SnapTempWritten,
    /// Temp file fsynced.
    SnapSynced,
    /// Temp renamed to `gen-N.snap` — the generation is durable, but the
    /// manifest still points at the previous one.
    SnapRenamed,
    /// New manifest written to `MANIFEST.tmp` (not yet synced).
    ManifestTempWritten,
    /// Manifest temp fsynced.
    ManifestSynced,
    /// Manifest renamed — generation N is now the published live one.
    ManifestRenamed,
}

impl CommitStep {
    /// Every boundary, in protocol order (tests iterate this).
    pub const ALL: [CommitStep; 6] = [
        CommitStep::SnapTempWritten,
        CommitStep::SnapSynced,
        CommitStep::SnapRenamed,
        CommitStep::ManifestTempWritten,
        CommitStep::ManifestSynced,
        CommitStep::ManifestRenamed,
    ];
}

/// The durable snapshot store for one serving/mining process.
pub struct SnapshotStore {
    dir: PathBuf,
    retain: usize,
    /// Total snapshot + manifest bytes committed (the restart ablation's
    /// per-cycle write-overhead column).
    bytes_written: AtomicU64,
    /// Optional simulator hook: each committed snapshot is charged as one
    /// block against the simulated datanode capacity; pruned (and
    /// overwritten) generations are credited back, tracked per
    /// generation in `charged`.
    accounting: Mutex<Option<Box<dyn BlockStore + Send>>>,
    charged: Mutex<std::collections::HashMap<u64, crate::dfs::BlockId>>,
    /// Optional fault clock: when set, each commit syscall first asks it
    /// for an injected transient error (consumed from the plan's
    /// `storeio` budget) before touching the disk.
    chaos: Option<Arc<FaultClock>>,
}

impl std::fmt::Debug for SnapshotStore {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("SnapshotStore")
            .field("dir", &self.dir)
            .field("retain", &self.retain)
            .field("bytes_written", &self.bytes_written.load(Ordering::Relaxed))
            .finish()
    }
}

impl SnapshotStore {
    /// Open (creating if needed) a store directory, retaining up to
    /// `retain` generations (0 is treated as 1: the live generation is
    /// always kept).
    pub fn open(dir: impl Into<PathBuf>, retain: usize) -> Result<Self, StoreError> {
        let dir = dir.into();
        fs::create_dir_all(&dir).map_err(|err| StoreError::Io { path: dir.clone(), err })?;
        Ok(Self {
            dir,
            retain: retain.max(1),
            bytes_written: AtomicU64::new(0),
            accounting: Mutex::new(None),
            charged: Mutex::new(std::collections::HashMap::new()),
            chaos: None,
        })
    }

    /// Attach a shared fault clock (chaos harness): transient injected
    /// I/O errors exercise the commit path's bounded retry.
    pub fn with_chaos(mut self, clock: Arc<FaultClock>) -> Self {
        self.chaos = Some(clock);
        self
    }

    /// Charge each committed snapshot's bytes against a simulated block
    /// store (the DFS capacity model); placement failures are ignored —
    /// accounting is bookkeeping, never a reason to fail a commit.
    pub fn with_block_accounting(self, block_store: Box<dyn BlockStore + Send>) -> Self {
        *self.accounting.lock().unwrap() = Some(block_store);
        self
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn retain(&self) -> usize {
        self.retain
    }

    /// Snapshot + manifest bytes committed by this handle so far.
    pub fn bytes_written(&self) -> u64 {
        self.bytes_written.load(Ordering::Relaxed)
    }

    /// Simulated storage utilization, when block accounting is attached.
    pub fn utilization(&self) -> Option<f64> {
        self.accounting.lock().unwrap().as_ref().map(|b| b.utilization())
    }

    fn generation_path(&self, generation: u64) -> PathBuf {
        self.dir.join(format!("gen-{generation:08}.snap"))
    }

    fn manifest_path(&self) -> PathBuf {
        self.dir.join("MANIFEST")
    }

    fn io_err(path: &Path) -> impl Fn(std::io::Error) -> StoreError + '_ {
        move |err| StoreError::Io { path: path.to_path_buf(), err }
    }

    /// How many times a commit syscall is retried before the typed
    /// [`StoreError::Exhausted`] surfaces (so a commit sees at most
    /// `1 + IO_RETRIES` attempts per step).
    const IO_RETRIES: usize = 3;

    /// Run one commit step with bounded retry-with-backoff around
    /// transient I/O errors. When a fault clock is attached, an injected
    /// fault is consumed *instead of* issuing the syscall, so injection
    /// never leaves partial on-disk state behind; real errors retry the
    /// closure whole (every caller's closure is restartable — `create`
    /// truncates). Backoff doubles from 1ms, capped at 4ms: enough to
    /// model "the disk came back", cheap enough for tests.
    fn retry_io<T>(
        &self,
        op: &'static str,
        path: &Path,
        mut step: impl FnMut() -> std::io::Result<T>,
    ) -> Result<T, StoreError> {
        let mut attempt = 0usize;
        loop {
            attempt += 1;
            let injected = self.chaos.as_deref().is_some_and(FaultClock::take_store_fault);
            let res = if injected {
                Err(std::io::Error::new(
                    std::io::ErrorKind::Interrupted,
                    "injected transient store fault",
                ))
            } else {
                step()
            };
            match res {
                Ok(v) => return Ok(v),
                Err(err) if attempt > Self::IO_RETRIES => {
                    return Err(StoreError::Exhausted { op, path: path.to_path_buf(), attempts: attempt, err });
                }
                Err(_) => {
                    std::thread::sleep(Duration::from_millis(1 << (attempt - 1).min(2)));
                }
            }
        }
    }

    /// Best-effort directory fsync (makes the rename itself durable on
    /// filesystems that need it; failure is not fatal for the simulator).
    fn sync_dir(&self) {
        if let Ok(d) = fs::File::open(&self.dir) {
            let _ = d.sync_all();
        }
    }

    /// Commit one generation with the full protocol.
    pub fn publish(&self, snap: &SnapshotRef<'_>) -> Result<(), StoreError> {
        self.publish_with_hook(snap, &mut |_| true).map(|_| ())
    }

    /// [`publish`](Self::publish), recording a `store.publish` span (cat
    /// `store`, wall clock — this is real fsync time) annotated with the
    /// generation and the bytes the commit added.
    pub fn publish_traced(
        &self,
        snap: &SnapshotRef<'_>,
        ctx: Option<&crate::obs::TraceCtx>,
    ) -> Result<(), StoreError> {
        let Some(ctx) = ctx else {
            return self.publish(snap);
        };
        let mut span = ctx.span("store", "store.publish");
        span.add("generation", snap.generation as f64);
        let before = self.bytes_written();
        let out = self.publish(snap);
        span.add("bytes", self.bytes_written().saturating_sub(before) as f64);
        span.add("ok", if out.is_ok() { 1.0 } else { 0.0 });
        out
    }

    /// Commit with a crash-injection hook: `keep_going` fires after each
    /// [`CommitStep`]; returning `false` abandons the commit right there
    /// (returning `Ok(false)`), leaving the disk exactly as a kill at
    /// that boundary would. Production callers use [`publish`].
    ///
    /// [`publish`]: Self::publish
    pub fn publish_with_hook(
        &self,
        snap: &SnapshotRef<'_>,
        keep_going: &mut dyn FnMut(CommitStep) -> bool,
    ) -> Result<bool, StoreError> {
        let bytes = codec::encode_snapshot(snap);
        let final_path = self.generation_path(snap.generation);
        let tmp_path = self.dir.join(format!("gen-{:08}.tmp", snap.generation));

        // 1-2: temp write + fsync (each step retried around transient
        // faults — `create` truncates, so a retried write restarts clean)
        {
            let f = self.retry_io("snapshot write", &tmp_path, || {
                let mut f = fs::File::create(&tmp_path)?;
                f.write_all(&bytes)?;
                Ok(f)
            })?;
            if !keep_going(CommitStep::SnapTempWritten) {
                return Ok(false);
            }
            self.retry_io("snapshot fsync", &tmp_path, || f.sync_all())?;
        }
        if !keep_going(CommitStep::SnapSynced) {
            return Ok(false);
        }

        // 3: atomic rename — the generation becomes durable
        self.retry_io("snapshot rename", &final_path, || {
            fs::rename(&tmp_path, &final_path)
        })?;
        self.sync_dir();
        if !keep_going(CommitStep::SnapRenamed) {
            return Ok(false);
        }

        // 4-5: manifest temp write + fsync + rename — the generation
        // becomes *published*
        let manifest = {
            let mut gens = self.scan_generations()?;
            gens.sort_unstable();
            let cut = gens.len().saturating_sub(self.retain);
            let mut retained = gens.split_off(cut);
            // The window is the newest `retain` generation *numbers* — but
            // the just-published one is always kept, even when a previous
            // session left higher-numbered files behind (e.g. a fresh
            // generation 0 over an old store): pruning the live generation
            // would leave the new manifest dangling. Evict the oldest
            // non-live entry instead to hold the window size.
            if !retained.contains(&snap.generation) {
                retained.push(snap.generation);
                retained.sort_unstable();
                while retained.len() > self.retain {
                    let Some(i) = retained.iter().position(|&g| g != snap.generation) else {
                        break;
                    };
                    retained.remove(i);
                }
            }
            Manifest { live: snap.generation, retained }
        };
        let mbytes = codec::encode_manifest(&manifest);
        let mtmp = self.dir.join("MANIFEST.tmp");
        {
            let f = self.retry_io("manifest write", &mtmp, || {
                let mut f = fs::File::create(&mtmp)?;
                f.write_all(&mbytes)?;
                Ok(f)
            })?;
            if !keep_going(CommitStep::ManifestTempWritten) {
                return Ok(false);
            }
            self.retry_io("manifest fsync", &mtmp, || f.sync_all())?;
        }
        if !keep_going(CommitStep::ManifestSynced) {
            return Ok(false);
        }
        let mpath = self.manifest_path();
        self.retry_io("manifest rename", &mpath, || fs::rename(&mtmp, &mpath))?;
        self.sync_dir();
        if !keep_going(CommitStep::ManifestRenamed) {
            return Ok(false);
        }

        // 6: prune outside the retained window (a crash mid-prune is
        // harmless — stray intact generations are simply extra fallbacks)
        let mut pruned = Vec::new();
        for g in self.scan_generations()? {
            if !manifest.retained.contains(&g) {
                let _ = fs::remove_file(self.generation_path(g));
                pruned.push(g);
            }
        }

        self.bytes_written
            .fetch_add((bytes.len() + mbytes.len()) as u64, Ordering::Relaxed);
        // Simulated capacity accounting mirrors the on-disk lifecycle:
        // charge the new generation (crediting whatever an earlier
        // publish of the same number charged), credit the pruned ones.
        if let Some(bs) = self.accounting.lock().unwrap().as_mut() {
            let mut charged = self.charged.lock().unwrap();
            if let Ok(id) = bs.put_bytes(bytes.len() as u64) {
                if let Some(old) = charged.insert(snap.generation, id) {
                    let _ = bs.remove_block(old);
                }
            }
            for g in pruned {
                if let Some(id) = charged.remove(&g) {
                    let _ = bs.remove_block(id);
                }
            }
        }
        Ok(true)
    }

    /// The manifest, if present and intact.
    pub fn load_manifest(&self) -> Option<Manifest> {
        let bytes = fs::read(self.manifest_path()).ok()?;
        codec::decode_manifest(&bytes).ok()
    }

    /// Generation numbers with a (named) snapshot file on disk, unsorted.
    /// Unparseable names and `.tmp` leftovers are ignored.
    pub fn scan_generations(&self) -> Result<Vec<u64>, StoreError> {
        let entries =
            fs::read_dir(&self.dir).map_err(|err| StoreError::Io { path: self.dir.clone(), err })?;
        let mut gens = Vec::new();
        for entry in entries {
            let entry = entry.map_err(|err| StoreError::Io { path: self.dir.clone(), err })?;
            let name = entry.file_name();
            let Some(name) = name.to_str() else { continue };
            if let Some(g) = name
                .strip_prefix("gen-")
                .and_then(|s| s.strip_suffix(".snap"))
                .and_then(|s| s.parse::<u64>().ok())
            {
                gens.push(g);
            }
        }
        Ok(gens)
    }

    /// Read + fully verify one generation file.
    pub fn load_generation(&self, generation: u64) -> Result<Snapshot, StoreError> {
        let path = self.generation_path(generation);
        let bytes = fs::read(&path).map_err(Self::io_err(&path))?;
        let snap = codec::decode_snapshot(&bytes)
            .map_err(|err| StoreError::Codec { path: path.clone(), err })?;
        if snap.generation != generation {
            return Err(StoreError::GenerationMismatch {
                path,
                want: generation,
                got: snap.generation,
            });
        }
        Ok(snap)
    }

    /// The newest recoverable generation: the manifest's live generation
    /// when it is intact, otherwise (missing/corrupt manifest, or a
    /// manifest pointing at a damaged file) the newest generation that
    /// decodes intact, otherwise `None`. Truncated tails, bit flips and
    /// half-committed publishes all degrade here — never a panic.
    pub fn load_latest(&self) -> Result<Option<Snapshot>, StoreError> {
        if let Some(manifest) = self.load_manifest() {
            if let Ok(snap) = self.load_generation(manifest.live) {
                return Ok(Some(snap));
            }
        }
        let mut gens = self.scan_generations()?;
        gens.sort_unstable();
        for g in gens.into_iter().rev() {
            if let Ok(snap) = self.load_generation(g) {
                return Ok(Some(snap));
            }
        }
        Ok(None)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apriori::classical::{tests::textbook_db, ClassicalApriori};
    use crate::apriori::{AprioriConfig, MiningResult};
    use crate::data::{Transaction, TransactionDb};
    use crate::serve::index::RuleIndex;
    use crate::util::tempdir::TempDir;

    fn mined(db: &TransactionDb) -> MiningResult {
        ClassicalApriori::default()
            .mine(db, &AprioriConfig { min_support: 2.0 / 9.0, max_k: 0 })
    }

    /// A generation-`g` snapshot over the textbook base with `g` delta
    /// transactions appended (distinct per generation so contents differ).
    fn publish_gen(store: &SnapshotStore, base: &TransactionDb, g: u64) {
        let delta: Vec<Transaction> =
            (0..g).map(|i| Transaction::new([i as u32, (i + 1) as u32])).collect();
        let mut union = base.clone();
        union.append(delta.clone());
        let result = mined(&union);
        let index = RuleIndex::build(&result, 0.3);
        store
            .publish(&SnapshotRef {
                generation: g,
                base: BaseRef::of(base),
                min_support: 2.0 / 9.0,
                max_k: 0,
                delta: &delta,
                result: &result,
                state: None,
                index: &index,
            })
            .unwrap();
    }

    #[test]
    fn publish_then_load_latest_roundtrips() {
        let tmp = TempDir::new("roundtrip");
        let store = SnapshotStore::open(tmp.path(), 4).unwrap();
        let base = textbook_db();
        assert!(store.load_latest().unwrap().is_none());
        publish_gen(&store, &base, 1);
        publish_gen(&store, &base, 2);
        let snap = store.load_latest().unwrap().expect("two generations in");
        assert_eq!(snap.generation, 2);
        assert_eq!(snap.delta.len(), 2);
        assert_eq!(snap.base, BaseRef::of(&base));
        assert!(store.bytes_written() > 0);
        let manifest = store.load_manifest().expect("manifest committed");
        assert_eq!(manifest.live, 2);
        assert_eq!(manifest.retained, vec![1, 2]);
    }

    #[test]
    fn retain_window_prunes_old_generations() {
        let tmp = TempDir::new("retain");
        let store = SnapshotStore::open(tmp.path(), 2).unwrap();
        let base = textbook_db();
        for g in 1..=5 {
            publish_gen(&store, &base, g);
        }
        let mut gens = store.scan_generations().unwrap();
        gens.sort_unstable();
        assert_eq!(gens, vec![4, 5]);
        assert_eq!(store.load_manifest().unwrap().retained, vec![4, 5]);
        // pruned generations are unreadable, the live one intact
        assert!(store.load_generation(3).is_err());
        assert_eq!(store.load_latest().unwrap().unwrap().generation, 5);
    }

    #[test]
    fn publishing_a_lower_generation_over_an_old_store_never_prunes_itself() {
        // Regression: the retained window is the newest generation
        // *numbers*; a fresh session publishing generation 0 over leftover
        // higher-numbered files must not prune its own live snapshot.
        let tmp = TempDir::new("low_gen_republish");
        let store = SnapshotStore::open(tmp.path(), 2).unwrap();
        let base = textbook_db();
        for g in 1..=3 {
            publish_gen(&store, &base, g);
        }
        publish_gen(&store, &base, 0);
        let manifest = store.load_manifest().expect("manifest committed");
        assert_eq!(manifest.live, 0);
        assert!(manifest.retained.contains(&0), "{:?}", manifest.retained);
        assert!(manifest.retained.len() <= 2, "{:?}", manifest.retained);
        // recovery serves the just-published generation, not a stale one
        assert_eq!(store.load_latest().unwrap().unwrap().generation, 0);
    }

    #[test]
    fn corrupt_live_generation_falls_back_to_previous() {
        let tmp = TempDir::new("corrupt_live");
        let store = SnapshotStore::open(tmp.path(), 4).unwrap();
        let base = textbook_db();
        publish_gen(&store, &base, 1);
        publish_gen(&store, &base, 2);
        // flip one byte mid-file: checksum must catch it
        let path = store.generation_path(2);
        let mut bytes = fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x40;
        fs::write(&path, &bytes).unwrap();
        assert!(matches!(
            store.load_generation(2),
            Err(StoreError::Codec { .. })
        ));
        let snap = store.load_latest().unwrap().expect("gen 1 still intact");
        assert_eq!(snap.generation, 1);
    }

    #[test]
    fn truncated_tail_falls_back_to_previous() {
        let tmp = TempDir::new("truncated");
        let store = SnapshotStore::open(tmp.path(), 4).unwrap();
        let base = textbook_db();
        publish_gen(&store, &base, 1);
        publish_gen(&store, &base, 2);
        let path = store.generation_path(2);
        let bytes = fs::read(&path).unwrap();
        fs::write(&path, &bytes[..bytes.len() / 3]).unwrap();
        assert_eq!(store.load_latest().unwrap().unwrap().generation, 1);
    }

    #[test]
    fn missing_or_corrupt_manifest_degrades_to_scan() {
        let tmp = TempDir::new("manifest");
        let store = SnapshotStore::open(tmp.path(), 4).unwrap();
        let base = textbook_db();
        publish_gen(&store, &base, 1);
        publish_gen(&store, &base, 2);
        fs::remove_file(store.manifest_path()).unwrap();
        assert_eq!(store.load_latest().unwrap().unwrap().generation, 2);
        fs::write(store.manifest_path(), b"not a manifest").unwrap();
        assert_eq!(store.load_latest().unwrap().unwrap().generation, 2);
    }

    #[test]
    fn interrupted_commit_before_rename_leaves_previous_generation_live() {
        let tmp = TempDir::new("interrupt_early");
        let store = SnapshotStore::open(tmp.path(), 4).unwrap();
        let base = textbook_db();
        publish_gen(&store, &base, 1);
        let result = mined(&base);
        let index = RuleIndex::build(&result, 0.3);
        let snap = SnapshotRef {
            generation: 2,
            base: BaseRef::of(&base),
            min_support: 2.0 / 9.0,
            max_k: 0,
            delta: &[],
            result: &result,
            state: None,
            index: &index,
        };
        let committed = store
            .publish_with_hook(&snap, &mut |step| step != CommitStep::SnapTempWritten)
            .unwrap();
        assert!(!committed);
        // the temp file exists but recovery ignores it
        assert_eq!(store.load_latest().unwrap().unwrap().generation, 1);
        // a retried publish of the same generation succeeds cleanly
        store.publish(&snap).unwrap();
        assert_eq!(store.load_latest().unwrap().unwrap().generation, 2);
    }

    #[test]
    fn interrupted_commit_after_rename_recovers_the_new_generation() {
        let tmp = TempDir::new("interrupt_late");
        let store = SnapshotStore::open(tmp.path(), 4).unwrap();
        let base = textbook_db();
        publish_gen(&store, &base, 1);
        let result = mined(&base);
        let index = RuleIndex::build(&result, 0.3);
        let snap = SnapshotRef {
            generation: 2,
            base: BaseRef::of(&base),
            min_support: 2.0 / 9.0,
            max_k: 0,
            delta: &[],
            result: &result,
            state: None,
            index: &index,
        };
        // killed between snapshot rename and manifest rename: the stale
        // manifest still points at gen 1 — the published generation —
        // which is exactly what recovery must serve
        store
            .publish_with_hook(&snap, &mut |step| step != CommitStep::SnapRenamed)
            .unwrap();
        assert_eq!(store.load_latest().unwrap().unwrap().generation, 1);
        // ...but if the manifest is also gone, the newest intact file wins
        fs::remove_file(store.manifest_path()).unwrap();
        assert_eq!(store.load_latest().unwrap().unwrap().generation, 2);
    }

    #[test]
    fn transient_store_faults_are_retried_then_the_commit_succeeds() {
        use crate::chaos::FaultPlan;
        let tmp = TempDir::new("chaos_retry");
        let clock = Arc::new(FaultClock::new(FaultPlan::parse("storeio:2@now").unwrap()));
        let store = SnapshotStore::open(tmp.path(), 4)
            .unwrap()
            .with_chaos(Arc::clone(&clock));
        let base = textbook_db();
        publish_gen(&store, &base, 1);
        assert_eq!(store.load_latest().unwrap().unwrap().generation, 1);
        assert_eq!(clock.stats().store_faults, 2, "both injected faults consumed");
    }

    #[test]
    fn exhausted_store_faults_surface_typed_and_leave_the_previous_generation_live() {
        use crate::chaos::FaultPlan;
        let tmp = TempDir::new("chaos_exhausted");
        let base = textbook_db();
        let healthy = SnapshotStore::open(tmp.path(), 4).unwrap();
        publish_gen(&healthy, &base, 1);

        let clock = Arc::new(FaultClock::new(FaultPlan::parse("storeio:99@now").unwrap()));
        let store = SnapshotStore::open(tmp.path(), 4).unwrap().with_chaos(clock);
        let result = mined(&base);
        let index = RuleIndex::build(&result, 0.3);
        let snap = SnapshotRef {
            generation: 2,
            base: BaseRef::of(&base),
            min_support: 2.0 / 9.0,
            max_k: 0,
            delta: &[],
            result: &result,
            state: None,
            index: &index,
        };
        match store.publish(&snap) {
            Err(StoreError::Exhausted { attempts, .. }) => assert_eq!(attempts, 4),
            other => panic!("want StoreError::Exhausted, got {other:?}"),
        }
        // the failed commit never moved the published state
        assert_eq!(healthy.load_latest().unwrap().unwrap().generation, 1);
    }

    #[test]
    fn block_accounting_charges_the_simulated_dfs_and_credits_pruned_generations() {
        use crate::cluster::ClusterConfig;
        use crate::dfs::Dfs;
        let tmp = TempDir::new("accounting");
        let store = SnapshotStore::open(tmp.path(), 1)
            .unwrap()
            .with_block_accounting(Box::new(Dfs::new(&ClusterConfig::fhssc(3))));
        assert_eq!(store.utilization(), Some(0.0));
        let base = textbook_db();
        publish_gen(&store, &base, 1);
        let one_gen = store.utilization().unwrap();
        assert!(one_gen > 0.0);
        // republishing the same generation replaces its charge exactly
        // (identical content ⇒ identical bytes ⇒ identical utilization)
        for _ in 0..4 {
            publish_gen(&store, &base, 1);
        }
        assert_eq!(store.utilization().unwrap(), one_gen);
        // with retain = 1, publishing gen 2 prunes (and credits) gen 1:
        // usage tracks the retained snapshot set, it does not accumulate
        publish_gen(&store, &base, 2);
        assert!(store.utilization().unwrap() < one_gen * 1.8);
        assert_eq!(store.scan_generations().unwrap(), vec![2]);
    }
}

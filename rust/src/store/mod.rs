//! Durable snapshot store: checkpoint/journal persistence + warm restart.
//!
//! The paper's premise is that voluminous data lives in a DFS precisely so
//! work survives node churn — yet everything above the batch miner
//! (serving snapshots, the incremental `MinedState`) was in-memory only: a
//! restarted server had to cold re-mine the full database before answering
//! a single query. This subsystem closes that gap:
//!
//! * [`codec`] — a zero-dependency, versioned, checksummed binary codec
//!   for [`MiningResult`], [`MinedState`], [`RuleIndex`] and
//!   [`TransactionDb`] deltas. Every frame is length-prefixed and
//!   FNV-1a-checksummed; any bit flip or truncated tail decodes to a
//!   typed [`CodecError`], never a panic or a silently wrong value.
//! * [`snapshot_store`] — the generation-aware on-disk store. Each
//!   published generation commits via **write-temp → fsync → atomic
//!   rename**, then the `MANIFEST` (live generation + retained history)
//!   commits the same way; a crash at any write boundary leaves the
//!   previous generation fully readable.
//! * [`recover`] — warm restart: rehydrate the newest intact generation
//!   into a [`SnapshotCell`]`<RuleIndex>` at its persisted generation
//!   number and re-seed the `Refresher`'s [`MinedState`], so incremental
//!   refresh resumes from the persisted border instead of a cold
//!   capture-mine.
//!
//! A snapshot is **self-contained**: it carries the cumulative delta
//! relative to the immutable base database (identified by a
//! [`BaseRef`] fingerprint), so any single intact generation file
//! reconstructs the exact union database — pruning old generations never
//! breaks recovery. A store directory belongs to **one base database**:
//! recovery refuses a mismatched base, and mixing datasets in one
//! directory leaves stale foreign generations competing for the retain
//! window — use a fresh `--store-dir` per dataset. `serve --store-dir` /
//! `mine --store-dir` wire it into the CLI; the `[store]` config section
//! carries the same knobs.
//!
//! [`MiningResult`]: crate::apriori::MiningResult
//! [`MinedState`]: crate::incremental::MinedState
//! [`RuleIndex`]: crate::serve::index::RuleIndex
//! [`TransactionDb`]: crate::data::TransactionDb
//! [`SnapshotCell`]: crate::serve::snapshot::SnapshotCell
//! [`CodecError`]: codec::CodecError

pub mod codec;
pub mod recover;
pub mod snapshot_store;

use std::path::PathBuf;

use crate::apriori::MiningResult;
use crate::data::{Transaction, TransactionDb};
use crate::incremental::MinedState;
use crate::serve::index::RuleIndex;

pub use codec::CodecError;
pub use recover::{resume_serving, warm_start, Resumed, WarmStart};
pub use snapshot_store::{CommitStep, SnapshotStore, StoreError};

/// `[store]` section of an experiment config: where (and whether) the
/// serving stack persists its published generations.
#[derive(Debug, Clone)]
pub struct StoreConfig {
    /// Snapshot directory; `None` disables persistence entirely.
    pub dir: Option<PathBuf>,
    /// Generations retained on disk (older ones are pruned after each
    /// successful commit). 0 is treated as 1 — the live generation is
    /// always kept.
    pub retain: usize,
    /// Master off-switch: `--no-persist true` serves from an existing
    /// store (warm restart still works) without writing new generations.
    pub no_persist: bool,
}

impl Default for StoreConfig {
    fn default() -> Self {
        Self {
            dir: None,
            retain: Self::DEFAULT_RETAIN,
            no_persist: false,
        }
    }
}

impl StoreConfig {
    /// Default retained-generation window.
    pub const DEFAULT_RETAIN: usize = 4;

    /// Should this run write snapshots?
    pub fn writes_enabled(&self) -> bool {
        self.dir.is_some() && !self.no_persist
    }
}

/// Identity of the immutable base database a snapshot's cumulative delta
/// is relative to. A warm restart refuses to resume over a different base
/// (that would silently serve answers about the wrong data).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BaseRef {
    /// Transactions in the base database.
    pub n_tx: u64,
    /// FNV-1a fingerprint over the base's transactions.
    pub fingerprint: u64,
}

impl BaseRef {
    /// Fingerprint a (pristine, pre-delta) base database.
    pub fn of(db: &TransactionDb) -> Self {
        Self {
            n_tx: db.len() as u64,
            fingerprint: codec::fingerprint_db(db),
        }
    }
}

/// The manifest the store commits last: which generation is live and
/// which are retained on disk.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Manifest {
    /// The published (durable) generation a restart resumes from.
    pub live: u64,
    /// Generations kept on disk, ascending (live included).
    pub retained: Vec<u64>,
}

/// The serving fabric's cross-shard cut: the single frame whose atomic
/// flip is phase two of the fabric publish. Phase one prepares every
/// shard's replica files at `generation`; only once they are all durable
/// does this manifest commit (write-temp → fsync → atomic rename), so a
/// crash at any point leaves readers on the previous complete cut —
/// never a mix of generations.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FabricManifest {
    /// Generation every shard of this cut was prepared at.
    pub generation: u64,
    /// Shard count the cut was built with (the antecedent-hash modulus).
    pub n_shards: usize,
    /// Replicas per shard the prepare phase targeted.
    pub replicas: usize,
    /// Rule count per shard — a cheap cross-check that a shard file
    /// decoded for this cut actually belongs to it.
    pub shard_rules: Vec<u64>,
}

/// Borrowed view of one generation, as handed to
/// [`SnapshotStore::publish`] — the writer never needs to clone the index
/// or the mined state it is about to serve.
#[derive(Debug, Clone, Copy)]
pub struct SnapshotRef<'a> {
    /// Generation number (matches the serving cell's counter).
    pub generation: u64,
    /// The base database this snapshot's delta is relative to.
    pub base: BaseRef,
    /// Mining parameters the generation was produced under — persisted
    /// in every snapshot (not just state-carrying ones) so a restart
    /// can refuse to resume under drifted flags.
    pub min_support: f64,
    pub max_k: usize,
    /// Cumulative transactions appended since the base (the journal,
    /// flattened: base ++ delta == the union database of `generation`).
    pub delta: &'a [Transaction],
    /// Canonical mining result of the generation.
    pub result: &'a MiningResult,
    /// Incremental border state, when the generation was produced by (or
    /// seeds) border maintenance; `None` for full-re-mine generations.
    pub state: Option<&'a MinedState>,
    /// The serving index, persisted so recovery does not re-derive
    /// rules. Must have been built from `result` — the codec stores the
    /// rules only and reconstructs the (identical) support table from
    /// `result.frequent` at decode.
    pub index: &'a RuleIndex,
}

/// One fully decoded generation, as recovered from disk.
#[derive(Debug)]
pub struct Snapshot {
    pub generation: u64,
    pub base: BaseRef,
    pub min_support: f64,
    pub max_k: usize,
    pub delta: Vec<Transaction>,
    pub result: MiningResult,
    pub state: Option<MinedState>,
    pub index: RuleIndex,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Transaction;

    fn tx(items: &[u32]) -> Transaction {
        Transaction::new(items.iter().copied())
    }

    #[test]
    fn base_ref_fingerprints_content_not_identity() {
        let a = TransactionDb::new(vec![tx(&[0, 1]), tx(&[2])]);
        let b = TransactionDb::new(vec![tx(&[0, 1]), tx(&[2])]);
        assert_eq!(BaseRef::of(&a), BaseRef::of(&b));
        let c = TransactionDb::new(vec![tx(&[0, 1]), tx(&[3])]);
        assert_ne!(BaseRef::of(&a), BaseRef::of(&c));
        // same multiset, different order is a different base (the delta
        // journal is positional)
        let d = TransactionDb::new(vec![tx(&[2]), tx(&[0, 1])]);
        assert_ne!(BaseRef::of(&a), BaseRef::of(&d));
    }

    #[test]
    fn store_config_gates() {
        let off = StoreConfig::default();
        assert!(!off.writes_enabled());
        let on = StoreConfig {
            dir: Some("/tmp/x".into()),
            retain: 2,
            no_persist: false,
        };
        assert!(on.writes_enabled());
        let frozen = StoreConfig { no_persist: true, ..on };
        assert!(!frozen.writes_enabled());
    }
}

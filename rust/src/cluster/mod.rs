//! Simulated cluster substrate: node hardware profiles and deployment
//! modes standing in for the paper's physical testbed (three Intel Core2
//! Duo boxes with 80 GB disks on a managed switch).
//!
//! The paper's two headline comparisons are *hardware-shape* experiments:
//!
//! * **FHSSC** — "fully-configured similar system configuration": every
//!   node identical (the paper's actual testbed).
//! * **FHDSC** — "fully-configured differential system configuration":
//!   heterogeneous nodes, which the paper reports as uniformly slower.
//!
//! `NodeProfile` carries the knobs the cost model consumes (relative CPU
//! speed, disk and NIC bandwidth, storage capacity); presets reproduce the
//! 2006-era hardware ratios the paper implies.

use crate::simnet::SwitchConfig;

/// Node identifier within a cluster (0 = master/namenode, like the paper's
/// `master` host; workers are `slave1..`).
pub type NodeId = usize;

/// A cluster shape that cannot exist. Returned instead of silently
/// "fixing" the request (the old `with_replication` capped `r` at the
/// node count, which meant a config asking for 3-way durability could
/// run 2-way without anyone noticing).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ClusterConfigError {
    /// Replication factor exceeds the number of nodes — there is no way
    /// to place `replication` replicas on distinct machines.
    ReplicationExceedsNodes { replication: usize, nodes: usize },
    /// A replication factor of zero stores nothing.
    ZeroReplication,
}

impl std::fmt::Display for ClusterConfigError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Self::ReplicationExceedsNodes { replication, nodes } => write!(
                f,
                "replication {replication} exceeds cluster size {nodes}: \
                 replicas must land on distinct nodes"
            ),
            Self::ZeroReplication => write!(f, "replication must be >= 1"),
        }
    }
}

impl std::error::Error for ClusterConfigError {}

/// Hardware profile of one node — the inputs to the discrete-event cost
/// model (`mapreduce::sim`).
#[derive(Debug, Clone, PartialEq)]
pub struct NodeProfile {
    /// Human-readable name for reports.
    pub name: String,
    /// Relative CPU speed; 1.0 = the reference Core2 Duo E-series.
    pub cpu_factor: f64,
    /// Sequential disk bandwidth (MB/s) — HDFS block reads/writes.
    pub disk_mbps: f64,
    /// NIC bandwidth (Mbit/s) — shuffle and replication traffic.
    pub nic_mbps: f64,
    /// Map/reduce task slots (Hadoop default: one per core).
    pub slots: usize,
    /// Local storage capacity in bytes (the paper's 80 GB/node cap is the
    /// cause of its fig-5 knee; benches scale this down proportionally).
    pub storage_bytes: u64,
}

impl NodeProfile {
    /// The paper's testbed node: Intel Core2 Duo, SATA disk, GigE, 80 GB.
    pub fn core2_duo() -> Self {
        Self {
            name: "core2duo".into(),
            cpu_factor: 1.0,
            disk_mbps: 60.0,
            nic_mbps: 1000.0,
            slots: 2,
            storage_bytes: 80 * 1_000_000_000,
        }
    }

    /// A slower, older box (differential configs mix these in).
    pub fn pentium4() -> Self {
        Self {
            name: "pentium4".into(),
            cpu_factor: 0.45,
            disk_mbps: 35.0,
            nic_mbps: 100.0,
            slots: 1,
            storage_bytes: 40 * 1_000_000_000,
        }
    }

    /// A faster contemporary box.
    pub fn xeon() -> Self {
        Self {
            name: "xeon".into(),
            cpu_factor: 1.8,
            disk_mbps: 90.0,
            nic_mbps: 1000.0,
            slots: 4,
            storage_bytes: 160 * 1_000_000_000,
        }
    }

    /// Scale storage capacity (benches shrink the 80 GB cap so the fig-5
    /// knee appears at laptop-scale transaction volumes).
    pub fn with_storage(mut self, bytes: u64) -> Self {
        self.storage_bytes = bytes;
        self
    }

    pub fn with_slots(mut self, slots: usize) -> Self {
        assert!(slots > 0);
        self.slots = slots;
        self
    }

    pub fn with_cpu_factor(mut self, f: f64) -> Self {
        assert!(f > 0.0);
        self.cpu_factor = f;
        self
    }
}

/// Deployment mode, matching §3.1 of the paper.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DeployMode {
    /// Plain single-process execution, no Hadoop daemons at all.
    Standalone,
    /// Pseudo-distributed: all daemons on one box — full MR machinery
    /// (shuffle, task scheduling) but no parallel hardware and extra
    /// framework overhead.
    PseudoDistributed,
    /// Fully-distributed over N nodes.
    FullyDistributed,
}

/// Cluster description: profiles + interconnect + deployment mode.
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    pub nodes: Vec<NodeProfile>,
    pub switch: SwitchConfig,
    pub mode: DeployMode,
    /// HDFS replication factor (Hadoop default 3, capped at cluster size).
    pub replication: usize,
    /// Rack id per node. The paper's testbed is one managed switch (a
    /// single rack); multi-rack layouts enable Hadoop's rack-aware
    /// placement in `dfs` and the oversubscribed-uplink model in `simnet`.
    pub rack_of: Vec<usize>,
}

impl ClusterConfig {
    /// Standalone single node (the paper's "standalone PC" series).
    pub fn standalone() -> Self {
        Self {
            nodes: vec![NodeProfile::core2_duo()],
            switch: SwitchConfig::loopback(),
            mode: DeployMode::Standalone,
            replication: 1,
            rack_of: vec![0],
        }
    }

    /// Pseudo-distributed single node (paper §3.1.1.1).
    pub fn pseudo_distributed() -> Self {
        Self {
            nodes: vec![NodeProfile::core2_duo()],
            switch: SwitchConfig::loopback(),
            mode: DeployMode::PseudoDistributed,
            replication: 1,
            rack_of: vec![0],
        }
    }

    /// FHSSC: N identical Core2 Duo nodes on the managed switch — the
    /// paper's homogeneous configuration.
    pub fn fhssc(n: usize) -> Self {
        assert!(n >= 1);
        Self {
            nodes: vec![NodeProfile::core2_duo(); n],
            switch: SwitchConfig::managed_gige(),
            mode: DeployMode::FullyDistributed,
            replication: 3.min(n),
            rack_of: vec![0; n],
        }
    }

    /// FHDSC: N nodes of *differential* configuration — a mix of slow
    /// Pentium-4-class, reference Core2, and faster Xeon-class boxes in a
    /// repeating pattern biased toward the slow end (the paper reports
    /// FHDSC >= FHSSC, i.e. stragglers dominate).
    pub fn fhdsc(n: usize) -> Self {
        assert!(n >= 1);
        let nodes = (0..n)
            .map(|i| match i % 5 {
                0 | 2 => NodeProfile::pentium4(),
                4 => NodeProfile::xeon(),
                _ => NodeProfile::core2_duo(),
            })
            .collect();
        Self {
            nodes,
            switch: SwitchConfig::managed_mixed(),
            mode: DeployMode::FullyDistributed,
            replication: 3.min(n),
            rack_of: vec![0; n],
        }
    }

    /// Spread nodes round-robin across `n_racks` racks (Hadoop-style
    /// multi-rack layout; enables rack-aware placement + uplink modelling).
    pub fn with_racks(mut self, n_racks: usize) -> Self {
        assert!(n_racks >= 1);
        self.rack_of = (0..self.nodes.len()).map(|i| i % n_racks).collect();
        self
    }

    /// Number of distinct racks.
    pub fn n_racks(&self) -> usize {
        self.rack_of.iter().copied().max().unwrap_or(0) + 1
    }

    /// Uniformly scale every node's storage (fig-5 knee calibration).
    pub fn with_storage_per_node(mut self, bytes: u64) -> Self {
        for n in &mut self.nodes {
            n.storage_bytes = bytes;
        }
        self
    }

    /// Set the HDFS replication factor. Errors (rather than silently
    /// capping) when `r` exceeds the node count — fewer replicas than
    /// asked for is a durability downgrade the caller must decide on.
    pub fn with_replication(mut self, r: usize) -> Result<Self, ClusterConfigError> {
        if r == 0 {
            return Err(ClusterConfigError::ZeroReplication);
        }
        if r > self.nodes.len() {
            return Err(ClusterConfigError::ReplicationExceedsNodes {
                replication: r,
                nodes: self.nodes.len(),
            });
        }
        self.replication = r;
        Ok(self)
    }

    pub fn n_nodes(&self) -> usize {
        self.nodes.len()
    }

    /// Total map/reduce slots across the cluster.
    pub fn total_slots(&self) -> usize {
        self.nodes.iter().map(|n| n.slots).sum()
    }

    /// Aggregate storage capacity in bytes.
    pub fn total_storage(&self) -> u64 {
        self.nodes.iter().map(|n| n.storage_bytes).sum()
    }

    /// Harmonic-mean CPU factor — the effective per-slot speed when work is
    /// spread evenly, which is what makes FHDSC slower than FHSSC even at
    /// equal node counts (stragglers gate the wave).
    pub fn harmonic_cpu(&self) -> f64 {
        let s: f64 = self.nodes.iter().map(|n| 1.0 / n.cpu_factor).sum();
        self.nodes.len() as f64 / s
    }

    /// Slowest node's CPU factor (wave makespan is gated by it).
    pub fn min_cpu(&self) -> f64 {
        self.nodes
            .iter()
            .map(|n| n.cpu_factor)
            .fold(f64::INFINITY, f64::min)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_shape() {
        let s = ClusterConfig::standalone();
        assert_eq!(s.n_nodes(), 1);
        assert_eq!(s.mode, DeployMode::Standalone);

        let p = ClusterConfig::pseudo_distributed();
        assert_eq!(p.mode, DeployMode::PseudoDistributed);

        let f = ClusterConfig::fhssc(3);
        assert_eq!(f.n_nodes(), 3);
        assert_eq!(f.replication, 3);
        assert!(f.nodes.iter().all(|n| n.name == "core2duo"));

        let d = ClusterConfig::fhdsc(5);
        assert_eq!(d.n_nodes(), 5);
        let names: Vec<_> = d.nodes.iter().map(|n| n.name.as_str()).collect();
        assert!(names.contains(&"pentium4"));
        assert!(names.contains(&"xeon"));
    }

    #[test]
    fn replication_validated_against_cluster_size() {
        // Presets still derive a sane default from the node count...
        assert_eq!(ClusterConfig::fhssc(2).replication, 2);
        assert_eq!(ClusterConfig::fhssc(8).replication, 3);
        // ...and explicit requests within bounds are honoured exactly.
        assert_eq!(
            ClusterConfig::fhssc(8).with_replication(5).unwrap().replication,
            5
        );
        assert_eq!(
            ClusterConfig::fhssc(2).with_replication(2).unwrap().replication,
            2
        );
        // Impossible requests are typed errors, never silent downgrades.
        assert_eq!(
            ClusterConfig::fhssc(2).with_replication(5).unwrap_err(),
            ClusterConfigError::ReplicationExceedsNodes { replication: 5, nodes: 2 }
        );
        assert_eq!(
            ClusterConfig::fhssc(3).with_replication(0).unwrap_err(),
            ClusterConfigError::ZeroReplication
        );
        let msg = ClusterConfig::fhssc(2).with_replication(5).unwrap_err().to_string();
        assert!(msg.contains("replication 5 exceeds cluster size 2"), "{msg}");
    }

    #[test]
    fn fhdsc_is_slower_in_aggregate() {
        for n in [2, 3, 5, 8, 16] {
            let hom = ClusterConfig::fhssc(n);
            let het = ClusterConfig::fhdsc(n);
            assert!(
                het.harmonic_cpu() < hom.harmonic_cpu(),
                "n={n}: heterogeneous harmonic cpu {} should trail {}",
                het.harmonic_cpu(),
                hom.harmonic_cpu()
            );
            assert!(het.min_cpu() < hom.min_cpu());
        }
    }

    #[test]
    fn storage_scaling() {
        let c = ClusterConfig::fhssc(3).with_storage_per_node(1_000_000);
        assert_eq!(c.total_storage(), 3_000_000);
        assert_eq!(NodeProfile::core2_duo().with_storage(42).storage_bytes, 42);
    }

    #[test]
    fn slot_accounting() {
        assert_eq!(ClusterConfig::fhssc(3).total_slots(), 6);
        assert!(ClusterConfig::fhdsc(5).total_slots() < 20); // p4s drag it down
    }

    #[test]
    fn rack_layout() {
        let c = ClusterConfig::fhssc(6).with_racks(2);
        assert_eq!(c.rack_of, vec![0, 1, 0, 1, 0, 1]);
        assert_eq!(c.n_racks(), 2);
        assert_eq!(ClusterConfig::fhssc(3).n_racks(), 1);
    }

    #[test]
    fn harmonic_mean_identical_nodes_is_identity() {
        let c = ClusterConfig::fhssc(4);
        assert!((c.harmonic_cpu() - 1.0).abs() < 1e-12);
    }
}

//! Simulated interconnect: the paper's "managed switch linked to private
//! LAN" as a flow-level bandwidth/latency model.
//!
//! The discrete-event simulator (`mapreduce::sim`) asks this module how
//! long a transfer takes given concurrent flow counts; we model a
//! store-and-forward switch with per-port bandwidth, a switching latency,
//! and fair sharing when several flows target the same destination port
//! (shuffle fan-in — the dominant contention pattern in MapReduce).

use crate::cluster::NodeId;
use crate::obs::TraceCtx;

/// Switch/link parameters.
#[derive(Debug, Clone, PartialEq)]
pub struct SwitchConfig {
    /// Per-port line rate, Mbit/s.
    pub port_mbps: f64,
    /// One-way switching + propagation latency, milliseconds.
    pub latency_ms: f64,
    /// Aggregate backplane capacity, Mbit/s (managed switches are usually
    /// non-blocking; cheap ones oversubscribe).
    pub backplane_mbps: f64,
}

impl SwitchConfig {
    /// Loopback "network" for standalone / pseudo-distributed modes:
    /// effectively memory-speed, near-zero latency.
    pub fn loopback() -> Self {
        Self {
            port_mbps: 40_000.0,
            latency_ms: 0.01,
            backplane_mbps: 400_000.0,
        }
    }

    /// The paper's managed GigE switch with Cat-6 runs.
    pub fn managed_gige() -> Self {
        Self {
            port_mbps: 1000.0,
            latency_ms: 0.3,
            backplane_mbps: 16_000.0,
        }
    }

    /// Mixed-NIC environment (FHDSC): the switch is the same, but ports
    /// negotiate down to the slowest NIC; modelled per-flow in
    /// [`Network::flow_mbps`] using node NIC speeds.
    pub fn managed_mixed() -> Self {
        Self {
            port_mbps: 1000.0,
            latency_ms: 0.5,
            backplane_mbps: 8_000.0,
        }
    }
}

/// Payloads of at most one Ethernet MTU ride the small-payload fast
/// path in [`Network::transfer_secs`]: a single frame is never
/// fair-shared mid-flight, so it is charged the uncontended line rate
/// instead of a contention-divided share. This keeps per-query costing
/// (a basket out, a top-k answer back) latency-dominated and strictly
/// positive rather than underflowing toward zero under heavy `active`
/// counts.
pub const SMALL_PAYLOAD_BYTES: u64 = 1500;

/// A point-to-point transfer request.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Flow {
    pub src: NodeId,
    pub dst: NodeId,
    pub bytes: u64,
}

/// Flow-level network model over a switch and per-node NIC speeds, with
/// optional rack topology: flows crossing racks share an uplink of
/// `inter_rack_mbps` (classic oversubscribed top-of-rack design).
#[derive(Debug, Clone)]
pub struct Network {
    pub switch: SwitchConfig,
    /// Per-node NIC speed (Mbit/s), indexed by NodeId.
    pub nic_mbps: Vec<f64>,
    /// Rack id per node (all-zero = the paper's single managed switch).
    pub rack_of: Vec<usize>,
    /// Aggregate inter-rack uplink capacity, Mbit/s.
    pub inter_rack_mbps: f64,
}

impl Network {
    pub fn new(switch: SwitchConfig, nic_mbps: Vec<f64>) -> Self {
        assert!(!nic_mbps.is_empty());
        let n = nic_mbps.len();
        Self {
            switch,
            nic_mbps,
            rack_of: vec![0; n],
            inter_rack_mbps: f64::INFINITY,
        }
    }

    /// Attach a rack topology (rack id per node + uplink capacity).
    pub fn with_racks(mut self, rack_of: Vec<usize>, inter_rack_mbps: f64) -> Self {
        assert_eq!(rack_of.len(), self.nic_mbps.len());
        assert!(inter_rack_mbps > 0.0);
        self.rack_of = rack_of;
        self.inter_rack_mbps = inter_rack_mbps;
        self
    }

    /// Do two nodes share a rack?
    pub fn same_rack(&self, a: NodeId, b: NodeId) -> bool {
        self.rack_of[a] == self.rack_of[b]
    }

    /// Effective bandwidth of one flow when `fanin` flows converge on the
    /// destination and `fanout` flows leave the source concurrently:
    /// min(port, src NIC / fanout, dst NIC / fanin), all floored by the
    /// backplane share.
    pub fn flow_mbps(&self, f: &Flow, fanout: usize, fanin: usize, active_flows: usize) -> f64 {
        if f.src == f.dst {
            // Node-local transfer: memory/disk path, not the switch.
            return self.switch.port_mbps * 4.0;
        }
        let src_share = self.nic_mbps[f.src] / fanout.max(1) as f64;
        let dst_share = self.nic_mbps[f.dst] / fanin.max(1) as f64;
        let backplane_share = self.switch.backplane_mbps / active_flows.max(1) as f64;
        let mut mbps = self
            .switch
            .port_mbps
            .min(src_share)
            .min(dst_share)
            .min(backplane_share);
        if !self.same_rack(f.src, f.dst) {
            // cross-rack flows share the oversubscribed uplink
            mbps = mbps.min(self.inter_rack_mbps / active_flows.max(1) as f64);
        }
        mbps
    }

    /// Transfer time in seconds under the given concurrency. Payloads of
    /// at most [`SMALL_PAYLOAD_BYTES`] (one MTU — a single frame) skip
    /// the fair-sharing model and serialize at the uncontended line
    /// rate: a lone frame occupies the wire for its full serialization
    /// time no matter how many other flows are active, so dividing its
    /// bandwidth by `active` would both understate nothing and let the
    /// cost of a per-query RPC collapse toward zero.
    pub fn transfer_secs(&self, f: &Flow, fanout: usize, fanin: usize, active: usize) -> f64 {
        let latency = self.switch.latency_ms / 1000.0;
        if f.bytes == 0 {
            return latency;
        }
        let mbps = if f.bytes <= SMALL_PAYLOAD_BYTES {
            self.flow_mbps(f, 1, 1, 1)
        } else {
            self.flow_mbps(f, fanout, fanin, active)
        };
        latency + (f.bytes as f64 * 8.0) / (mbps * 1_000_000.0)
    }

    /// [`transfer_secs`](Self::transfer_secs), recording a `net` span
    /// under `ctx` when tracing is on. The span's duration is the
    /// **simulated** wire time (via the span's duration override), not
    /// the host-side cost of evaluating the model — exporters label the
    /// category so wall-clock containment checks skip it.
    pub fn transfer_secs_traced(
        &self,
        f: &Flow,
        fanout: usize,
        fanin: usize,
        active: usize,
        ctx: Option<&TraceCtx>,
        name: &'static str,
    ) -> f64 {
        let secs = self.transfer_secs(f, fanout, fanin, active);
        if let Some(ctx) = ctx {
            let mut span = ctx.span("net", name);
            span.add("src", f.src as f64);
            span.add("dst", f.dst as f64);
            span.add("bytes", f.bytes as f64);
            span.add("sim_ms", secs * 1e3);
            span.set_dur_us((secs * 1e6) as u64);
        }
        secs
    }

    /// Makespan (seconds) of an all-to-all shuffle: every (src, dst) pair
    /// carries `matrix[src][dst]` bytes. Flows are served concurrently;
    /// each flow sees its steady-state fair share and the makespan is the
    /// slowest flow — a standard flow-level approximation of the shuffle
    /// phase (§fig-4/5 cost model).
    pub fn shuffle_makespan(&self, matrix: &[Vec<u64>]) -> f64 {
        let n = matrix.len();
        let mut flows = Vec::new();
        for (src, row) in matrix.iter().enumerate() {
            assert_eq!(row.len(), n, "shuffle matrix must be square");
            for (dst, &bytes) in row.iter().enumerate() {
                if bytes > 0 {
                    flows.push(Flow { src, dst, bytes });
                }
            }
        }
        if flows.is_empty() {
            return 0.0;
        }
        let active = flows.len();
        let mut worst: f64 = 0.0;
        for f in &flows {
            let fanout = flows.iter().filter(|g| g.src == f.src).count();
            let fanin = flows.iter().filter(|g| g.dst == f.dst).count();
            worst = worst.max(self.transfer_secs(f, fanout, fanin, active));
        }
        worst
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gige(n: usize) -> Network {
        Network::new(SwitchConfig::managed_gige(), vec![1000.0; n])
    }

    #[test]
    fn zero_bytes_costs_latency_only() {
        let net = gige(2);
        let f = Flow { src: 0, dst: 1, bytes: 0 };
        let t = net.transfer_secs(&f, 1, 1, 1);
        assert!((t - 0.0003).abs() < 1e-9);
    }

    #[test]
    fn gige_transfer_time_sanity() {
        // 125 MB over an uncontended GigE link ≈ 1 second.
        let net = gige(2);
        let f = Flow { src: 0, dst: 1, bytes: 125_000_000 };
        let t = net.transfer_secs(&f, 1, 1, 1);
        assert!((t - 1.0).abs() < 0.01, "got {t}");
    }

    #[test]
    fn fanin_contention_slows_flows() {
        let net = gige(4);
        let f = Flow { src: 0, dst: 3, bytes: 10_000_000 };
        let alone = net.transfer_secs(&f, 1, 1, 1);
        let contended = net.transfer_secs(&f, 1, 3, 3);
        assert!(contended > alone * 2.5, "{contended} vs {alone}");
    }

    #[test]
    fn local_transfers_bypass_switch() {
        let net = gige(2);
        let local = Flow { src: 1, dst: 1, bytes: 125_000_000 };
        let remote = Flow { src: 0, dst: 1, bytes: 125_000_000 };
        assert!(
            net.transfer_secs(&local, 1, 1, 1) < net.transfer_secs(&remote, 1, 1, 1) / 2.0
        );
    }

    #[test]
    fn slow_nic_gates_flow() {
        // FHDSC: a 100 Mbit NIC on the destination caps the flow.
        let net = Network::new(SwitchConfig::managed_mixed(), vec![1000.0, 100.0]);
        let f = Flow { src: 0, dst: 1, bytes: 125_000_000 };
        let t = net.transfer_secs(&f, 1, 1, 1);
        assert!(t > 9.0, "100 Mbit should take ~10s, got {t}");
    }

    #[test]
    fn shuffle_makespan_scales_with_nodes_and_bytes() {
        let net3 = gige(3);
        let m_small = vec![vec![0, 1_000_000, 1_000_000]; 3];
        let m_big = vec![vec![0, 10_000_000, 10_000_000]; 3];
        let s = net3.shuffle_makespan(&m_small);
        let b = net3.shuffle_makespan(&m_big);
        assert!(b > s * 5.0);
        assert_eq!(net3.shuffle_makespan(&vec![vec![0; 3]; 3]), 0.0);
    }

    #[test]
    #[should_panic(expected = "square")]
    fn shuffle_matrix_must_be_square() {
        gige(2).shuffle_makespan(&[vec![0, 1], vec![2]]);
    }

    #[test]
    fn flow_mbps_pins_same_rack_and_inter_rack_rates() {
        // 4 GigE nodes, 2 racks, 200 Mbit uplink. Same-rack flow gets the
        // full port rate; the cross-rack flow is pinned to the uplink.
        let net = gige(4).with_racks(vec![0, 0, 1, 1], 200.0);
        let intra = Flow { src: 0, dst: 1, bytes: 1 };
        let inter = Flow { src: 0, dst: 2, bytes: 1 };
        assert_eq!(net.flow_mbps(&intra, 1, 1, 1), 1000.0);
        assert_eq!(net.flow_mbps(&inter, 1, 1, 1), 200.0);
        // With 4 active flows the uplink is split four ways; the
        // same-rack flow only pays its backplane share (not binding).
        assert_eq!(net.flow_mbps(&inter, 1, 1, 4), 50.0);
        assert_eq!(net.flow_mbps(&intra, 1, 1, 4), 1000.0);
    }

    #[test]
    fn flow_mbps_pins_oversubscription_division() {
        // managed_gige: 1000 Mbit ports, 16 Gbit backplane. 32 active
        // flows oversubscribe the backplane: each gets 16000/32 = 500.
        let net = gige(4);
        let f = Flow { src: 0, dst: 1, bytes: 1 };
        assert_eq!(net.flow_mbps(&f, 1, 1, 32), 500.0);
        // fanout/fanin split the NICs: 4-way fanout = 250 Mbit.
        assert_eq!(net.flow_mbps(&f, 4, 1, 1), 250.0);
        assert_eq!(net.flow_mbps(&f, 1, 8, 1), 125.0);
        // The binding constraint is the minimum of all shares.
        assert_eq!(net.flow_mbps(&f, 4, 8, 32), 125.0);
    }

    #[test]
    fn shuffle_makespan_oversubscribed_uplink_case() {
        // All-to-all over 2 racks: 12 flows active, 8 of them cross-rack
        // on a 400 Mbit uplink shared 12 ways (33.3 Mbit each) — far
        // slower than the flat topology's fanin-limited share.
        let flat = gige(4);
        let racked = gige(4).with_racks(vec![0, 0, 1, 1], 400.0);
        let bytes = 10_000_000u64;
        let mut m = vec![vec![bytes; 4]; 4];
        for (i, row) in m.iter_mut().enumerate() {
            row[i] = 0;
        }
        let t_flat = flat.shuffle_makespan(&m);
        let t_racked = racked.shuffle_makespan(&m);
        // Flat: slowest flow sees min(1000, 1000/3 fanout, 1000/3 fanin,
        // 16000/12 backplane) = 333.3 Mbit -> 0.24s + latency.
        assert!((t_flat - (0.0003 + bytes as f64 * 8.0 / (1000.0 / 3.0 * 1e6))).abs() < 1e-3);
        // Racked: cross-rack flows pinned to 400/12 = 33.3 Mbit -> 2.4s.
        assert!((t_racked - (0.0003 + bytes as f64 * 8.0 / (400.0 / 12.0 * 1e6))).abs() < 1e-2);
        assert!(t_racked > t_flat * 6.0);
    }

    #[test]
    fn small_payloads_charge_uncontended_line_rate() {
        let net = gige(4);
        let small = Flow { src: 0, dst: 1, bytes: SMALL_PAYLOAD_BYTES };
        // Heavy contention must not change a single-frame transfer...
        let alone = net.transfer_secs(&small, 1, 1, 1);
        let contended = net.transfer_secs(&small, 8, 8, 64);
        assert_eq!(alone, contended);
        // ...and the cost stays strictly above the bare latency.
        assert!(alone > net.switch.latency_ms / 1000.0);
        // One byte past the MTU pays the fair-shared rate again.
        let big = Flow { src: 0, dst: 1, bytes: SMALL_PAYLOAD_BYTES + 1 };
        assert!(net.transfer_secs(&big, 8, 8, 64) > net.transfer_secs(&big, 1, 1, 1));
    }

    #[test]
    fn cross_rack_flows_gated_by_uplink() {
        let net = gige(4).with_racks(vec![0, 0, 1, 1], 200.0);
        let intra = Flow { src: 0, dst: 1, bytes: 25_000_000 };
        let inter = Flow { src: 0, dst: 2, bytes: 25_000_000 };
        let t_intra = net.transfer_secs(&intra, 1, 1, 1);
        let t_inter = net.transfer_secs(&inter, 1, 1, 1);
        assert!(
            t_inter > t_intra * 4.0,
            "200 Mbit uplink must gate cross-rack: {t_inter} vs {t_intra}"
        );
        assert!(net.same_rack(0, 1));
        assert!(!net.same_rack(1, 2));
    }

    #[test]
    fn single_rack_default_is_neutral() {
        let plain = gige(3);
        let racked = gige(3).with_racks(vec![0, 0, 0], 100.0);
        let f = Flow { src: 0, dst: 2, bytes: 10_000_000 };
        assert_eq!(
            plain.transfer_secs(&f, 1, 1, 1),
            racked.transfer_secs(&f, 1, 1, 1),
            "same-rack flows never touch the uplink"
        );
    }

    #[test]
    fn traced_transfer_matches_and_records_simulated_duration() {
        use crate::obs::{TraceCtx, TraceSink};
        let net = gige(2);
        let f = Flow { src: 0, dst: 1, bytes: 125_000_000 };
        let sink = TraceSink::new();
        let ctx = TraceCtx::root(std::sync::Arc::clone(&sink));
        let secs = net.transfer_secs_traced(&f, 1, 1, 1, Some(&ctx), "rpc.leg");
        assert_eq!(secs, net.transfer_secs(&f, 1, 1, 1));
        let events = sink.events();
        assert_eq!(events.len(), 1);
        assert_eq!(events[0].cat, "net");
        // ~1 simulated second on the wire, recorded as the span duration
        // even though evaluating the model took ~no wall time.
        assert_eq!(events[0].dur_us, (secs * 1e6) as u64);
        // None is the zero-cost off path: no span recorded.
        net.transfer_secs_traced(&f, 1, 1, 1, None, "rpc.leg");
        assert_eq!(sink.len(), 1);
    }

    #[test]
    fn rack_aware_shuffle_slower_than_flat() {
        let flat = gige(4);
        let racked = gige(4).with_racks(vec![0, 0, 1, 1], 100.0);
        let m = vec![vec![2_000_000u64; 4]; 4];
        assert!(racked.shuffle_makespan(&m) > flat.shuffle_makespan(&m) * 2.0);
    }
}

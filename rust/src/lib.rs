//! # mr-apriori — Map/Reduce Apriori for voluminous data-sets
//!
//! A from-scratch reproduction of *"Map/Reduce Design and Implementation of
//! Apriori Algorithm for Handling Voluminous Data-Sets"* (ACIJ 2012,
//! DOI 10.5121/acij.2012.3604) as a three-layer Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — a Hadoop-like MapReduce substrate (simulated
//!   HDFS block store, jobtracker/tasktracker scheduling, shuffle,
//!   combiners, speculative execution) plus the level-wise Apriori driver
//!   that plans one counting job per candidate level.
//! * **L2/L1 (python/, build-time only)** — the support-count hot-spot as a
//!   Pallas bitmap-matmul kernel inside a jax graph, AOT-lowered to HLO
//!   text artifacts.
//! * **runtime** — a PJRT CPU client that loads the artifacts and serves
//!   count requests to map tasks; python never runs on the request path.
//! * **serve** — the online consumption layer: immutable rule-index
//!   snapshots over the mined output, atomic hot-swap, a worker-pool
//!   query server with admission control, and micro-batch background
//!   refresh that re-mines without pausing reads.
//! * **incremental** — the stateful mining layer: FUP-style border
//!   maintenance so a refresh counts the delta (plus a promoted
//!   frontier), not the whole database.
//! * **store** — the durable snapshot store: a versioned checksummed
//!   codec, a crash-consistent generation store (write-temp → fsync →
//!   atomic rename, manifest last), and warm restart that resumes
//!   serving and incremental refresh at the last published generation
//!   instead of cold re-mining.
//!
//! See `DESIGN.md` for the module inventory and the experiment index, and
//! `EXPERIMENTS.md` for paper-vs-measured results.

pub mod apriori;
pub mod chaos;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod dfs;
pub mod engine;
pub mod fabric;
pub mod incremental;
pub mod mapreduce;
pub mod metrics;
pub mod obs;
pub mod perfmodel;
pub mod runtime;
pub mod serve;
pub mod simnet;
pub mod store;
pub mod util;

/// Convenience re-exports covering the public API surface used by the
/// examples and benches.
pub mod prelude {
    pub use crate::apriori::{
        classical::{ClassicalApriori, MatcherKind},
        fp_growth::FpGrowth,
        intersection::IntersectionApriori,
        record_filter::RecordFilterApriori,
        postprocess::{closed_itemsets, maximal_itemsets},
        rules::{format_rule, generate_rules, Rule},
        son::{SonApriori, SonReport},
        AprioriConfig, Itemset, MiningResult,
    };
    pub use crate::chaos::{
        ChaosConfig, ChaosStats, FaultClock, FaultEvent, FaultKind, FaultPlan, FaultTrigger,
    };
    pub use crate::cluster::{ClusterConfig, ClusterConfigError, DeployMode, NodeProfile};
    pub use crate::config::{ExperimentConfig, Preset};
    pub use crate::coordinator::{
        simulate, simulate_pipelined, MrApriori, PipelineConfig, RunReport, WorkloadProfile,
    };
    pub use crate::data::{
        bitmap::BitmapBlock, columnar::FlatBlock, quest::QuestGenerator, quest::QuestParams,
        TransactionDb,
    };
    pub use crate::dfs::{BlockStore, Dfs};
    pub use crate::engine::{
        build_engine, CacheStats, EngineKind, IndexCache, SupportEngine, VerticalEngine,
        VerticalIndex,
    };
    pub use crate::fabric::{
        shard_of, FabricConfig, FabricPlacement, FabricStore, QueryRouter, RoutedResponse,
        RouterError, RouterStats, ShardedRuleIndex,
    };
    pub use crate::incremental::{
        DeltaApply, DeltaStats, IncrementalConfig, LevelState, MinedState,
    };
    pub use crate::mapreduce::{JobConfig, JobStats, SimReport, Simulator};
    pub use crate::metrics::bench::{BenchTable, Series};
    pub use crate::metrics::histogram::{HistogramSnapshot, LatencyHistogram};
    pub use crate::obs::{
        FlightRecorder, LogLevel, MetricsRegistry, MetricsSnapshot, MineProfile, ParsedSpan,
        ProfileError, RegistryError, SloConfig, SloVerdict, SloWatcher, Span, TraceCtx,
        TraceSink,
    };
    pub use crate::perfmodel::{EtaModel, KernelRoofline};
    pub use crate::runtime::{ArtifactManifest, TensorService, TensorServiceHandle};
    pub use crate::serve::{
        index::{reference_recommend, render_lines, RuleIndex},
        refresh::{
            synth_baskets, synth_delta, RefreshError, RefreshMode, Refresher, RefreshStats,
        },
        server::{
            Backend, QueryClass, QueryResponse, RuleServer, ServeError, ServeOptions,
            ServerStats,
        },
        snapshot::SnapshotCell,
        ServeConfig,
    };
    pub use crate::store::{
        resume_serving, warm_start, BaseRef, CodecError, CommitStep, FabricManifest, Manifest,
        Resumed, Snapshot, SnapshotRef, SnapshotStore, StoreConfig, StoreError, WarmStart,
    };
}
